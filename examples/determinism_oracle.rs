//! Determinism oracle tour: execute the attention backward pass through
//! every schedule generator, prove the deterministic ones are bitwise
//! stable across machine widths and completion shuffles, and watch the
//! oracle catch atomic accumulation in bf16.
//!
//! Run: `cargo run --release --example determinism_oracle`
//! (the `dash verify` subcommand drives the same machinery with full
//! control over the matrix — see docs/CLI.md)

use dash::bench_harness::{render_table, verify_matrix, VerifyOptions};
use dash::exec::{execute_backward, ExecConfig};
use dash::mask::MaskSpec;
use dash::numerics::Precision;
use dash::schedule::{fa3, ProblemSpec, ScheduleKind};

fn main() {
    // The determinism-vs-throughput table: simulated makespans next to
    // executed-gradient verdicts. Tuned is omitted here to keep the
    // example free of tuning-cache side effects; `dash verify` includes it.
    let opts = VerifyOptions {
        kinds: vec![
            ScheduleKind::Fa3Atomic,
            ScheduleKind::Fa3,
            ScheduleKind::Descending,
            ScheduleKind::Shift,
            ScheduleKind::SymmetricShift,
            ScheduleKind::TwoPass,
            ScheduleKind::Lpt,
        ],
        ..VerifyOptions::defaults(6, 2, 42)
    };
    let rows = verify_matrix(&opts).expect("verification matrix runs");
    println!("determinism vs throughput (n=6, heads=2, 2 runs x SMs {:?}):\n", opts.sm_counts);
    println!("{}", render_table(&rows));

    // The money shot, element by element: one deterministic schedule, one
    // injected-atomic run, same data — different bf16 bits. Like the
    // oracle, try several completion shuffles: any one divergence is a
    // catch.
    let spec = ProblemSpec::square(6, 4, MaskSpec::causal());
    let s = fa3(&spec, true);
    let det = ExecConfig { precision: Precision::Bf16, ..ExecConfig::new(42) };
    let a = execute_backward(&s, &det).expect("legal schedule");
    let b = execute_backward(&s, &det).expect("legal schedule");
    assert_eq!(a.grad_hash, b.grad_hash);
    let c = (1..=4u64)
        .map(|perturb| {
            let injected = ExecConfig { inject_atomic: true, perturb, n_sm: 3, ..det };
            execute_backward(&s, &injected).expect("legal schedule")
        })
        .find(|r| r.grad_hash != a.grad_hash)
        .expect("injected atomic order must move bf16 gradient bits");
    println!("fa3-det bf16 grad hash, run 1: {:016x}", a.grad_hash);
    println!("fa3-det bf16 grad hash, run 2: {:016x}  (bitwise identical)", b.grad_hash);
    println!("fa3-det + injected atomic:     {:016x}  (caught)", c.grad_hash);
    let drifted = a.dq.iter().zip(&c.dq).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    println!("dQ elements with drifted bits under injection: {drifted}/{}", a.dq.len());
}
