//! Schedule explorer: renders the paper's Gantt figures (2, 3, 4, 6, 7)
//! as ASCII timelines, verifies the closed-form costs of §3 against the
//! simulator across a parameter sweep, and demonstrates Lemma 1.
//!
//! Run: `cargo run --release --example schedule_explorer`

use dash::attention::{t_causal_fa3, t_causal_opt, t_full_fa3, t_full_opt, t_reversed};
use dash::dag::{check_depth_monotone, ChainSpec};
use dash::schedule::{descending, fa3, shift, symmetric_shift, MaskSpec, ProblemSpec, Schedule};
use dash::sim::{render_gantt, simulate, CostModel, SimConfig};

fn show(title: &str, s: &Schedule, n_sm: usize) {
    let cfg = SimConfig {
        n_sm,
        cost: CostModel::default(),
        record_spans: true,
        writer_depth: 0,
        occupancy: 1,
        hw_fingerprint: 0,
    };
    let r = simulate(s, &cfg).expect("legal schedule");
    println!("\n--- {title} (makespan {:.2}, stalls {:.2}) ---", r.makespan, r.stall_time);
    println!("{}", render_gantt(&r.spans, n_sm, 96));
}

fn main() {
    // Figure 2: the naive 2x2 problem.
    let tiny = ProblemSpec::square(2, 1, MaskSpec::full());
    show("Fig 2: naive schedule, 2 KV-tiles x 2 Q-tiles", &fa3(&tiny, true), 2);

    // Figure 3: FA3 baseline, both masks.
    let n = 4;
    show(
        "Fig 3a: FA3 baseline, full mask",
        &fa3(&ProblemSpec::square(n, 2, MaskSpec::full()), true),
        n,
    );
    show(
        "Fig 3b: FA3 baseline, causal mask (note the per-head bubble)",
        &fa3(&ProblemSpec::square(n, 2, MaskSpec::causal()), true),
        n,
    );

    // Figure 4: descending Q-tile iteration.
    show(
        "Fig 4: Descending Q-tile, causal (bubbles drained)",
        &descending(&ProblemSpec::square(n, 2, MaskSpec::causal())),
        n,
    );

    // Figure 6: shift scheduling on a full mask.
    show(
        "Fig 6: Shift scheduling, full mask (conflict-free diagonal)",
        &shift(&ProblemSpec::square(n, 2, MaskSpec::full())).expect("full masks support shift"),
        n,
    );

    // Figure 7: symmetric shift with two-phase folding.
    show(
        "Fig 7: Symmetric shift, causal (two-phase workload folding)",
        &symmetric_shift(&ProblemSpec::square(8, 2, MaskSpec::causal())),
        8,
    );

    // §3 closed forms vs simulator.
    println!("\n--- closed-form cross-validation (c = 1, r = 0.25) ---");
    println!(
        "{:>4} {:>4} | {:>10} {:>10} | {:>10} {:>10} | {:>10} {:>10}",
        "n", "m", "fa3_full", "formula", "shift", "formula", "symshift", "formula"
    );
    for &(n, m) in &[(4usize, 2usize), (8, 4), (16, 6), (32, 8)] {
        let cfg = SimConfig::ideal(n);
        let f_base = simulate(&fa3(&ProblemSpec::square(n, m, MaskSpec::full()), true), &cfg)
            .unwrap()
            .makespan;
        let f_shift = simulate(
            &shift(&ProblemSpec::square(n, m, MaskSpec::full())).unwrap(),
            &cfg,
        )
        .unwrap()
        .makespan;
        let f_sym =
            simulate(&symmetric_shift(&ProblemSpec::square(n, m, MaskSpec::causal())), &cfg)
                .unwrap()
                .makespan;
        println!(
            "{n:>4} {m:>4} | {f_base:>10.2} {:>10.2} | {f_shift:>10.2} {:>10.2} | {f_sym:>10.2} {:>10.2}",
            t_full_fa3(n, m, 1.0, 0.25),
            t_full_opt(n, m, 1.0, 0.25),
            t_causal_opt(n, m, 1.0, 0.25),
        );
    }
    println!(
        "\n(descending causal, n=16 m=8: sim {:.2} vs formula {:.2}; fa3 causal formula {:.2})",
        simulate(&descending(&ProblemSpec::square(16, 8, MaskSpec::causal())), &SimConfig::ideal(16))
            .unwrap()
            .makespan,
        t_reversed(16, 8, 1.0, 0.25),
        t_causal_fa3(16, 8, 1.0, 0.25),
    );

    // Lemma 1.
    println!("\n--- Lemma 1: depth-monotone edges preserve the critical path ---");
    let spec = ChainSpec { n_chains: 3, chain_len: 5, edge_weight: 1.0 };
    for (du, dv) in [(1usize, 4usize), (3, 3), (4, 1)] {
        let r = check_depth_monotone(&spec, &[(spec.node(0, du), spec.node(1, dv))]);
        println!(
            "  edge depth {du} -> {dv}: CP {} -> {}  ({})",
            r.base_cp,
            r.final_cp.unwrap(),
            if r.predicts_preserved() { "preserved, as Lemma 1 predicts" } else { "LENGTHENED — violates depth-monotonicity" }
        );
    }
}
