//! Determinism audit — the paper's Table 1 experiment end-to-end:
//!
//! 1. Kernel level (when artifacts exist): run the AOT attention backward
//!    10x on identical inputs — deterministic kernel must produce one bit
//!    pattern; the shuffled-order kernel (attn_bwd_shuffled, whose fold
//!    order is an input) produces O(1e-4) deviations across orders.
//! 2. Coordinator level: two training runs with fixed vs shuffled
//!    microbatch gradient accumulation — fixed is bitwise stable, shuffled
//!    diverges.
//!
//! Run: `cargo run --release --example determinism_audit`

use dash::bench_harness::{render_table, table1_determinism};
use dash::coordinator::config::DeterminismMode;
use dash::coordinator::{TrainConfig, Trainer};
use dash::runtime::{ArtifactManifest, Engine};
use dash::util::DetRng;

fn main() -> dash::Result<()> {
    // ---- softfloat Table 1 (always available) ---------------------------
    println!("# Table 1 (softfloat model)\n");
    println!("{}", render_table(&table1_determinism(10, 42)));

    // ---- kernel-level, via PJRT artifacts --------------------------------
    if ArtifactManifest::available("artifacts") {
        println!("# Kernel-level audit (PJRT, AOT Pallas kernels)\n");
        let manifest = ArtifactManifest::load("artifacts")?;
        let engine = Engine::cpu()?;
        let bwd = engine.load(&manifest, "attn_bwd")?;
        let spec = manifest.spec("attn_bwd")?;
        let mut rng = DetRng::new(3);
        let args: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .map(|t| {
                let data: Vec<f32> =
                    (0..t.numel()).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
                dash::runtime::literal_f32(&data, &t.shape)
            })
            .collect::<dash::Result<_>>()?;
        let reference = dash::runtime::f32_vec(&bwd.run_literals(&args)?[0])?;
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..10 {
            let out = dash::runtime::f32_vec(&bwd.run_literals(&args)?[0])?;
            let max_dev = out
                .iter()
                .zip(&reference)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            distinct.insert(dash::coordinator::fingerprint_f32(&out));
            assert_eq!(max_dev, 0.0, "deterministic kernel deviated");
        }
        println!("attn_bwd x10: {} distinct bit pattern(s), max dev 0 — deterministic ✓\n", distinct.len());
    } else {
        println!("(artifacts/ missing — kernel-level audit skipped; run `make artifacts`)\n");
    }

    // ---- coordinator-level ------------------------------------------------
    if !ArtifactManifest::available("artifacts") {
        println!("(coordinator-level audit also needs artifacts — done)");
        return Ok(());
    }
    println!("# Coordinator-level audit (gradient accumulation order)\n");
    let base = TrainConfig {
        steps: 8,
        batch: 8,
        microbatches: 4,
        log_every: 1,
        ..TrainConfig::default()
    };

    let run = |mode: DeterminismMode, salt: u64| -> dash::Result<_> {
        let mut cfg = base.clone();
        cfg.determinism = mode;
        let mut t = Trainer::new(cfg)?;
        t.shuffle_salt = salt;
        t.run()?;
        Ok(t.fingerprint.clone())
    };

    let d1 = run(DeterminismMode::Deterministic, 1)?;
    let d2 = run(DeterminismMode::Deterministic, 2)?;
    println!(
        "deterministic accumulation: {}",
        if d1.matches(&d2) { "bitwise identical across runs ✓" } else { "DIVERGED ✗" }
    );

    let s1 = run(DeterminismMode::Shuffled, 1)?;
    let s2 = run(DeterminismMode::Shuffled, 2)?;
    match s1.first_divergence(&s2) {
        Some(step) => println!("shuffled accumulation: diverged at step {step} (expected) ✓"),
        None => println!("shuffled accumulation: did not diverge (unexpected at this scale)"),
    }
    Ok(())
}
