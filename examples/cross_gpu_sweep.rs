//! Cross-GPU tuned-schedule sweep: the scenario axis the hardware-profile
//! layer opens — the *same* workload grid, tuned and scored under two
//! different GPU profiles, compared side by side and emitted as a JSON
//! artifact.
//!
//! Run: `cargo run --release --example cross_gpu_sweep`
//! (equivalent CLI: `dash tune --sweep --gpu h800,h100 --json cross_gpu_sweep.json`)

use dash::bench_harness::{cross_gpu_json, cross_gpu_sweep, render_table};
use dash::hw::presets;

fn main() {
    let profiles = [presets::h800(), presets::h100()];
    println!(
        "cross-GPU tuned sweep: {} ({} SMs) vs {} ({} SMs)\n",
        profiles[0].name, profiles[0].n_sm, profiles[1].name, profiles[1].n_sm
    );

    let rows = cross_gpu_sweep(&profiles, 4, 150, 42);
    println!("{}", render_table(&rows));

    // The cross-GPU story in one number pair: the same workload's tuned
    // wall-clock on each part.
    for gpu in ["h800", "h100"] {
        let total_us: f64 =
            rows.iter().filter(|r| r.gpu == gpu).map(|r| r.tuned_us).sum();
        let wins = rows
            .iter()
            .filter(|r| r.gpu == gpu && r.speedup > 1.0 + 1e-9)
            .count();
        println!(
            "{gpu}: grid total {total_us:.1} us tuned; tuner strictly beats the best \
             analytic schedule on {wins} points"
        );
    }

    let path = "cross_gpu_sweep.json";
    std::fs::write(path, cross_gpu_json(&rows).dump()).expect("write artifact");
    println!("\njson artifact -> {path}");
}
