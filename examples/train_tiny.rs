//! End-to-end driver: train a small transformer LM (configurable up to
//! ~100M params) on the synthetic Markov corpus through the full
//! three-layer stack — Pallas kernels (L1) inside the JAX model (L2),
//! AOT-compiled to HLO and executed by the Rust coordinator (L3) via PJRT —
//! and prove the run is bitwise reproducible.
//!
//! Run: `make artifacts && cargo run --release --example train_tiny`
//! Env: TRAIN_STEPS / TRAIN_CONFIG override defaults. The loss curve is
//! written to `train_tiny_loss.csv` and recorded in EXPERIMENTS.md.

use dash::coordinator::{TrainConfig, Trainer};
use dash::runtime::ArtifactManifest;

fn main() -> dash::Result<()> {
    let mut cfg = match std::env::var("TRAIN_CONFIG") {
        Ok(p) => TrainConfig::load(p)?,
        Err(_) => TrainConfig::default(),
    };
    if let Ok(s) = std::env::var("TRAIN_STEPS") {
        cfg.steps = s.parse()?;
    }
    if !ArtifactManifest::available(&cfg.artifacts_dir) {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    println!(
        "train_tiny: {} params | {} layers x d{} | batch {} x seq {} | {} steps",
        cfg.param_count(),
        cfg.n_layers,
        cfg.d_model,
        cfg.batch,
        cfg.seqlen,
        cfg.steps
    );

    // Run 1.
    let mut t1 = Trainer::new(cfg.clone())?;
    t1.run()?;
    let first = t1.metrics.first_loss();
    let last = t1.metrics.final_loss(5);
    println!(
        "\nrun 1: loss {first:.4} -> {last:.4} over {} steps ({:.0} tok/s)",
        cfg.steps,
        t1.metrics.tokens_per_second()
    );
    std::fs::write("train_tiny_loss.csv", t1.metrics.to_csv())?;
    println!("loss curve -> train_tiny_loss.csv");

    // The model must actually learn: cross-entropy starts near ln(vocab).
    let ln_v = (cfg.vocab as f32).ln();
    println!("ln(vocab) = {ln_v:.3}; learned delta = {:.3}", first - last);
    anyhow::ensure!(last < first - 0.5, "model failed to learn (loss {first} -> {last})");

    // Run 2: bitwise reproducibility.
    let mut t2 = Trainer::new(cfg.clone())?;
    t2.run()?;
    match t1.fingerprint.first_divergence(&t2.fingerprint) {
        None => println!("\nREPRODUCIBILITY PASS: two runs bitwise identical at every checkpoint"),
        Some(s) => {
            println!("\nREPRODUCIBILITY FAIL: diverged at step {s}");
            std::process::exit(1);
        }
    }
    Ok(())
}
