//! Quickstart: the whole stack in one page.
//!
//! 1. Schedule theory — compare the paper's four schedules on the abstract
//!    machine and verify the closed-form optima.
//! 2. Numerics — show why accumulation *order* decides bits.
//! 3. Runtime — if `make artifacts` has been run, load the AOT-compiled
//!    attention kernel pair via PJRT and show deterministic vs shuffled
//!    accumulation on real gradients.
//!
//! Run: `cargo run --release --example quickstart`

use dash::attention::{t_causal_opt, t_full_opt};
use dash::numerics::{deviation_across_orders, sum_f32_ordered};
use dash::runtime::{ArtifactManifest, Engine};
use dash::schedule::{descending, fa3, shift, symmetric_shift, MaskSpec, ProblemSpec};
use dash::sim::{simulate, SimConfig};
use dash::util::DetRng;

fn main() -> dash::Result<()> {
    // ---- 1. schedules on the abstract machine --------------------------
    let (n, m) = (8, 4);
    println!("# 1. Schedules (n = {n} tiles/SMs, m = {m} heads, c = 1, r = 0.25)\n");
    let cfg = SimConfig::ideal(n);
    let full = ProblemSpec::square(n, m, MaskSpec::full());
    let causal = ProblemSpec::square(n, m, MaskSpec::causal());

    let rows = [
        ("fa3-det      (full)  ", simulate(&fa3(&full, true), &cfg)?),
        ("shift        (full)  ", simulate(&shift(&full)?, &cfg)?),
        ("fa3-det      (causal)", simulate(&fa3(&causal, true), &cfg)?),
        ("descending   (causal)", simulate(&descending(&causal), &cfg)?),
        ("symm-shift   (causal)", simulate(&symmetric_shift(&causal), &cfg)?),
    ];
    for (name, r) in &rows {
        println!("  {name}  makespan {:>7.2}  stalls {:>6.2}", r.makespan, r.stall_time);
    }
    println!(
        "\n  paper optima: T_full_opt = {:.2}, T_causal_opt = {:.2}",
        t_full_opt(n, m, 1.0, 0.25),
        t_causal_opt(n, m, 1.0, 0.25)
    );

    // ---- 2. order decides bits -----------------------------------------
    println!("\n# 2. Floating-point accumulation order\n");
    let v = [1e8f32, 1e-6, -1e8];
    println!("  (1e8 + 1e-6) - 1e8 = {}", sum_f32_ordered(&v, &[0, 1, 2]));
    println!("  1e8 - 1e8 + 1e-6   = {}", sum_f32_ordered(&v, &[0, 2, 1]));
    let mut rng = DetRng::new(1);
    let grads: Vec<f32> = (0..4096)
        .map(|_| rng.gen_f32_range(-1.0, 1.0) * rng.gen_f32_range(-1.0, 1.0))
        .collect();
    let det = deviation_across_orders(&grads, 10, false, 42);
    let nondet = deviation_across_orders(&grads, 10, true, 42);
    println!(
        "  10 runs, fixed order:    {} distinct results, max dev {:.1e}",
        det.distinct_results, det.max_abs_deviation
    );
    println!(
        "  10 runs, shuffled order: {} distinct results, max dev {:.1e}",
        nondet.distinct_results, nondet.max_abs_deviation
    );

    // ---- 3. the real kernels via PJRT ----------------------------------
    println!("\n# 3. AOT kernels via PJRT");
    if !ArtifactManifest::available("artifacts") {
        println!("  (artifacts/ missing — run `make artifacts`, then re-run)");
        return Ok(());
    }
    let manifest = ArtifactManifest::load("artifacts")?;
    let engine = Engine::cpu()?;
    println!("  platform: {}", engine.platform());

    // Deterministic attention backward: same inputs twice -> same bits.
    let bwd = engine.load(&manifest, "attn_bwd")?;
    let spec = manifest.spec("attn_bwd")?;
    let mut rng = DetRng::new(7);
    let args: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| {
            if t.dtype == "i32" {
                // The dQ fold-order input: ascending causal order.
                let nt = t.shape[0];
                let data: Vec<i32> = (0..nt)
                    .flat_map(|q| (0..nt).map(move |x| if x <= q { x as i32 } else { -1 }))
                    .collect();
                dash::runtime::literal_i32(&data, &t.shape)
            } else {
                let n: usize = t.numel();
                let data: Vec<f32> = (0..n).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
                dash::runtime::literal_f32(&data, &t.shape)
            }
        })
        .collect::<dash::Result<_>>()?;
    let out1 = bwd.run_literals(&args)?;
    let out2 = bwd.run_literals(&args)?;
    let dq1 = dash::runtime::f32_vec(&out1[0])?;
    let dq2 = dash::runtime::f32_vec(&out2[0])?;
    let identical = dq1
        .iter()
        .zip(&dq2)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "  attn_bwd twice on identical inputs: bitwise identical = {identical} (dQ[0..4] = {:?})",
        &dq1[..4.min(dq1.len())]
    );
    Ok(())
}
