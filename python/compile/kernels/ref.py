"""Pure-jnp reference attention — the correctness oracle for the Pallas
kernels. Naive O(S^2) materialized attention with explicit backward-pass
formulas (Algorithm 1's math without tiling), so every kernel output can be
checked with `assert_allclose` and every gradient against `jax.grad`.
"""

import jax
import jax.numpy as jnp


def attention_fwd(q, k, v, causal: bool):
    """Reference forward: softmax(QK^T * scale [masked]) V.

    Args:
      q, k, v: [S, D] single-head arrays.
      causal: lower-triangular masking.

    Returns:
      (out [S, D], lse [S]) — lse is the row log-sum-exp the backward needs.
    """
    s_len, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    scores = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * scale
    if causal:
        row = jnp.arange(s_len)[:, None]
        col = jnp.arange(s_len)[None, :]
        scores = jnp.where(col <= row, scores, -jnp.inf)
    lse = jax.scipy.special.logsumexp(scores, axis=-1)
    p = jnp.exp(scores - lse[:, None])
    out = p @ v.astype(jnp.float32)
    return out.astype(q.dtype), lse


def attention_bwd(q, k, v, out, d_out, lse, causal: bool):
    """Reference backward: the five-GEMM gradient of Algorithm 1.

    Returns (dq, dk, dv), all [S, D] in the input dtype.
    """
    s_len, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    dof = d_out.astype(jnp.float32)
    of = out.astype(jnp.float32)

    scores = (qf @ kf.T) * scale
    if causal:
        row = jnp.arange(s_len)[:, None]
        col = jnp.arange(s_len)[None, :]
        scores = jnp.where(col <= row, scores, -jnp.inf)
    p = jnp.exp(scores - lse[:, None])

    dv = p.T @ dof
    dp = dof @ vf.T
    delta = jnp.sum(dof * of, axis=-1)  # D = rowsum(dO ∘ O)
    ds = p * (dp - delta[:, None]) * scale
    dq = ds @ kf
    dk = ds.T @ qf
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def attention(q, k, v, causal: bool):
    """Forward-only convenience (differentiable through jax.grad)."""
    return attention_fwd(q, k, v, causal)[0]


def mha(q, k, v, causal: bool):
    """Multi-head reference: inputs [B, H, S, D]."""
    f = jax.vmap(jax.vmap(lambda a, b, c: attention(a, b, c, causal)))
    return f(q, k, v)
