"""Layer-1 Pallas kernels: deterministic flash-attention backward.

This is the paper's object of study. The backward splits into:

* a **preprocess** computing `delta = rowsum(dO ∘ O)` (Algorithm 1 line 1);
* a **dK/dV kernel** parallel over KV tiles — reductions are local to the
  tile's accumulator (register/VMEM-resident), deterministic by
  construction; the Q-tile *visit order* (ascending FA3 / descending DASH)
  is a kernel parameter because it changes the bitwise result;
* a **dQ kernel** parallel over Q tiles whose per-tile KV *fold order* is
  an explicit `[n_q, n_kv]` int32 input — the serialized accumulation
  order the schedules in `schedules.py` (mirroring rust/src/schedule/)
  prescribe. A fixed order gives bitwise-identical gradients run to run;
  a per-run shuffled order reproduces atomicAdd nondeterminism (Table 1).

All kernels run under `interpret=True` (see flash_fwd.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .flash_fwd import NEG_INF, _pick_block


def preprocess(out, d_out):
    """delta = rowsum(dO ∘ O), computed in f32. Shapes [..., S, D] -> [..., S]."""
    return jnp.sum(d_out.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)


def _dkdv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    *, causal, descending, block_q, block_kv, seqlen,
):
    kvi = pl.program_id(0)
    d = q_ref.shape[-1]
    scale = 1.0 / (d**0.5)
    kblk = k_ref[...].astype(jnp.float32)  # [bk, D]
    vblk = v_ref[...].astype(jnp.float32)

    n_q = seqlen // block_q
    # Causal: Q tiles below the diagonal are dead for this KV tile.
    lower = (kvi * block_kv) // block_q if causal else 0

    def body(t, carry):
        dk, dv = carry
        # Ascending visits lower..n_q-1; descending visits n_q-1..lower.
        qt = (n_q - 1) - t if descending else lower + t
        qblk = pl.load(q_ref, (pl.ds(qt * block_q, block_q), slice(None))).astype(
            jnp.float32
        )
        doblk = pl.load(do_ref, (pl.ds(qt * block_q, block_q), slice(None))).astype(
            jnp.float32
        )
        lse = pl.load(lse_ref, (pl.ds(qt * block_q, block_q),))
        delta = pl.load(delta_ref, (pl.ds(qt * block_q, block_q),))
        s = (qblk * scale) @ kblk.T  # [bq, bk]
        if causal:
            rows = qt * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kvi * block_kv + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # [bq, bk]
        dv = dv + p.T @ doblk
        dp = doblk @ vblk.T
        ds = p * (dp - delta[:, None]) * scale
        dk = dk + ds.T @ qblk
        return dk, dv

    steps = n_q - lower
    dk0 = jnp.zeros((block_kv, d), jnp.float32)
    dv0 = jnp.zeros((block_kv, d), jnp.float32)
    dk, dv = lax.fori_loop(0, steps, body, (dk0, dv0))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, order_ref, dq_ref,
    *, causal, block_q, block_kv, seqlen,
):
    qi = pl.program_id(0)
    d = q_ref.shape[-1]
    scale = 1.0 / (d**0.5)
    qblk = q_ref[...].astype(jnp.float32)  # [bq, D]
    doblk = do_ref[...].astype(jnp.float32)
    lse = lse_ref[...]
    delta = delta_ref[...]
    n_kv = seqlen // block_kv

    def body(t, acc):
        kv = order_ref[0, t]
        valid = kv >= 0
        kvi = jnp.maximum(kv, 0)
        # Tile selection via lax.switch over static offsets rather than a
        # dynamic slice at a *loaded* start index: xla_extension 0.5.1's
        # CPU backend miscompiles the latter (OOB reads -> NaN); branch
        # selection by a computed scalar is handled correctly and only the
        # selected branch executes.
        def pick(j):
            return lambda: (
                pl.load(k_ref, (pl.ds(j * block_kv, block_kv), slice(None))),
                pl.load(v_ref, (pl.ds(j * block_kv, block_kv), slice(None))),
            )

        kblk, vblk = lax.switch(kvi, [pick(j) for j in range(n_kv)])
        kblk = kblk.astype(jnp.float32)
        vblk = vblk.astype(jnp.float32)
        s = (qblk * scale) @ kblk.T
        if causal:
            rows = qi * block_q + lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = kvi * block_kv + lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = doblk @ vblk.T
        ds = p * (dp - delta[:, None]) * scale
        contrib = ds @ kblk
        # The fold: a *serial*, order-controlled f32 accumulation — the
        # deterministic-attention semantics the schedules prescribe.
        return jnp.where(valid, acc + contrib, acc)

    acc = lax.fori_loop(0, n_kv, body, jnp.zeros((block_q, d), jnp.float32))
    dq_ref[...] = acc.astype(dq_ref.dtype)


def flash_attention_bwd(
    q, k, v, out, d_out, lse, order, *,
    causal: bool, descending: bool = False, block_q=None, block_kv=None,
):
    """Single-head deterministic backward.

    Args:
      q, k, v, out, d_out: [S, D]; lse: [S] from the forward.
      order: [n_q_tiles, n_kv_tiles] int32 fold order for dQ (-1 padded),
        from `schedules.order_for`.
      causal: mask shape.
      descending: Q-tile visit order in the dK/dV kernel (the DASH
        heuristic; changes bits, not math).

    Returns (dq, dk, dv) in the input dtypes.
    """
    s_len, d = q.shape
    bq = _pick_block(s_len, block_q)
    bk = _pick_block(s_len, block_kv)
    n_q, n_kv = s_len // bq, s_len // bk
    assert order.shape == (n_q, n_kv), f"order {order.shape} != {(n_q, n_kv)}"
    delta = preprocess(out, d_out)

    dkdv = pl.pallas_call(
        functools.partial(
            _dkdv_kernel,
            causal=causal,
            descending=descending,
            block_q=bq,
            block_kv=bk,
            seqlen=s_len,
        ),
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((s_len, d), lambda i: (0, 0)),  # Q resident
            pl.BlockSpec((bk, d), lambda i: (i, 0)),  # K tile
            pl.BlockSpec((bk, d), lambda i: (i, 0)),  # V tile
            pl.BlockSpec((s_len, d), lambda i: (0, 0)),  # dO resident
            pl.BlockSpec((s_len,), lambda i: (0,)),  # lse
            pl.BlockSpec((s_len,), lambda i: (0,)),  # delta
        ],
        out_specs=[
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
            pl.BlockSpec((bk, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_len, d), k.dtype),
            jax.ShapeDtypeStruct((s_len, d), v.dtype),
        ],
        interpret=True,
    )
    dk, dv = dkdv(q, k, v, d_out, lse, delta)

    dq = pl.pallas_call(
        functools.partial(
            _dq_kernel, causal=causal, block_q=bq, block_kv=bk, seqlen=s_len
        ),
        grid=(n_q,),
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),  # Q tile
            pl.BlockSpec((s_len, d), lambda i: (0, 0)),  # K resident
            pl.BlockSpec((s_len, d), lambda i: (0, 0)),  # V resident
            pl.BlockSpec((bq, d), lambda i: (i, 0)),  # dO tile
            pl.BlockSpec((bq,), lambda i: (i,)),  # lse tile
            pl.BlockSpec((bq,), lambda i: (i,)),  # delta tile
            pl.BlockSpec((1, n_kv), lambda i: (i, 0)),  # fold-order row
        ],
        out_specs=[pl.BlockSpec((bq, d), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((s_len, d), q.dtype)],
        interpret=True,
    )(q, k, v, d_out, lse, delta, order)[0]
    return dq, dk, dv


def mha_bwd(q, k, v, out, d_out, lse, order, *, causal, descending=False,
            block_q=None, block_kv=None):
    """Multi-head backward over [B, H, S, D] (order shared across heads)."""
    f = functools.partial(
        flash_attention_bwd,
        causal=causal,
        descending=descending,
        block_q=block_q,
        block_kv=block_kv,
    )
    g = lambda qq, kk, vv, oo, dd, ll: f(qq, kk, vv, oo, dd, ll, order)
    return jax.vmap(jax.vmap(g))(q, k, v, out, d_out, lse)
