"""Python mirror of the Rust schedule generators (rust/src/schedule/).

The kernel's dQ accumulation order is an explicit input array; these
functions generate the same per-(q-tile) KV orders as the Rust side. A
golden-case test (`python/tests/test_schedules.py`) pins both
implementations to the same values.

Order arrays are `[n_q, n_kv]` int32; entry `[j, t]` is the KV tile whose
contribution is folded t-th into dQ tile j, or -1 padding once the live
contributions for that row are exhausted.
"""

import numpy as np


def _live(kv: int, q: int, causal: bool) -> bool:
    return (not causal) or q >= kv


def fa3_order(n_kv: int, n_q: int, causal: bool) -> np.ndarray:
    """FA3 baseline (and Descending): ascending KV index — the CTA-index
    semaphore order."""
    out = np.full((n_q, n_kv), -1, dtype=np.int32)
    for q in range(n_q):
        live = [kv for kv in range(n_kv) if _live(kv, q, causal)]
        out[q, : len(live)] = live
    return out


def shift_order(n: int) -> np.ndarray:
    """Shift scheduling (full mask, square n): dQ tile j receives
    kv = j, j-1, …, j+1 (mod n) — the conflict-free timestamp order."""
    out = np.zeros((n, n), dtype=np.int32)
    for j in range(n):
        out[j] = [(j - t) % n for t in range(n)]
    return out


def symmetric_shift_order(n: int) -> np.ndarray:
    """Symmetric Shift (causal, even square n): the two-phase folded
    timestamp order (see rust/src/schedule/symmetric_shift.rs)."""
    assert n % 2 == 0 and n >= 2, "folded construction needs even n"
    h = n // 2
    # (timestamp, kv) pairs per q row, mirroring the Rust construction:
    # chain A (kv = s < h): rect steps t in [0, h): q = h + (s+t) % h;
    #                       tri steps  t in [h, 2h-s): q = s + (t - h).
    # chain B (kv = n-1-s): steps t' in [0, s+1) at global (2h - s) + t',
    #                       q = n-1-t'.
    buckets: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for s in range(h):
        for t in range(h):
            buckets[h + (s + t) % h].append((t, s))
        for i, q in enumerate(range(s, h)):
            buckets[q].append((h + i, s))
        for t2, q in enumerate(range(n - 1, n - 2 - s, -1)):
            buckets[q].append((2 * h - s + t2, n - 1 - s))
    out = np.full((n, n), -1, dtype=np.int32)
    for q in range(n):
        order = [kv for (_, kv) in sorted(buckets[q])]
        out[q, : len(order)] = order
    return out


def shuffled_order(n_kv: int, n_q: int, causal: bool, seed: int) -> np.ndarray:
    """A per-run random permutation of each row — models the uncontrolled
    completion order of atomicAdd accumulation (Table 1's non-deterministic
    arm). Same seed -> same order; different seeds -> run-to-run drift."""
    rng = np.random.default_rng(seed)
    out = np.full((n_q, n_kv), -1, dtype=np.int32)
    for q in range(n_q):
        live = np.array(
            [kv for kv in range(n_kv) if _live(kv, q, causal)], dtype=np.int32
        )
        rng.shuffle(live)
        out[q, : len(live)] = live
    return out


def order_for(kind: str, n_kv: int, n_q: int, causal: bool, seed: int = 0) -> np.ndarray:
    """Dispatch by schedule name (matches the Rust CLI names)."""
    if kind in ("fa3", "fa3-det", "descending"):
        return fa3_order(n_kv, n_q, causal)
    if kind == "shift":
        assert not causal and n_kv == n_q
        return shift_order(n_kv)
    if kind in ("symshift", "symmetric-shift"):
        assert causal and n_kv == n_q
        return symmetric_shift_order(n_kv)
    if kind == "shuffled":
        return shuffled_order(n_kv, n_q, causal, seed)
    raise ValueError(f"unknown schedule kind {kind!r}")
