"""Layer-1 Pallas kernel: tiled flash-attention forward (online softmax).

TPU-style adaptation of the paper's CUDA substrate (DESIGN.md
§Hardware-Adaptation): tiles are sized for VMEM/MXU (128-lane friendly),
the HBM<->VMEM schedule is expressed with BlockSpecs over Q tiles, and the
kernel runs under `interpret=True` so the AOT path lowers to plain HLO the
CPU PJRT client can execute (real-TPU lowering would emit a Mosaic
custom-call).

The forward pass needs no global reduction (each Q tile's softmax stats are
private), so it is deterministic by construction — the paper's determinism
problem lives entirely in the backward (see flash_bwd.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30  # large-negative mask value (true -inf NaNs the online max)


def _pick_block(s_len: int, requested: int | None) -> int:
    if requested is not None:
        assert s_len % requested == 0, f"block {requested} must divide seqlen {s_len}"
        return requested
    for cand in (128, 64, 32, 16, 8):
        if s_len % cand == 0:
            return min(cand, s_len)
    return s_len


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, causal, block_q, block_kv, seqlen):
    qi = pl.program_id(0)
    d = q_ref.shape[-1]
    scale = 1.0 / (d**0.5)
    qblk = q_ref[...].astype(jnp.float32) * scale  # [bq, D]

    n_kv = seqlen // block_kv
    if causal:
        # Last KV tile with any live element for this Q tile.
        upper = (qi * block_q + block_q - 1) // block_kv + 1
    else:
        upper = n_kv

    def body(i, carry):
        m, l, acc = carry
        kblk = pl.load(k_ref, (pl.ds(i * block_kv, block_kv), slice(None))).astype(
            jnp.float32
        )
        vblk = pl.load(v_ref, (pl.ds(i * block_kv, block_kv), slice(None))).astype(
            jnp.float32
        )
        s = qblk @ kblk.T  # [bq, bk]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = i * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ vblk
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m, l, acc = lax.fori_loop(0, upper, body, (m0, l0, acc0))

    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(l)


def flash_attention_fwd(q, k, v, *, causal: bool, block_q=None, block_kv=None):
    """Single-head tiled forward.

    Args:
      q, k, v: [S, D].
      causal: lower-triangular masking.

    Returns:
      (out [S, D] in q's dtype, lse [S] f32).
    """
    s_len, d = q.shape
    bq = _pick_block(s_len, block_q)
    bk = _pick_block(s_len, block_kv)
    grid = (s_len // bq,)
    kernel = functools.partial(
        _fwd_kernel, causal=causal, block_q=bq, block_kv=bk, seqlen=s_len
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),  # Q: one tile per step
            pl.BlockSpec((s_len, d), lambda i: (0, 0)),  # K: resident
            pl.BlockSpec((s_len, d), lambda i: (0, 0)),  # V: resident
        ],
        out_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_len, d), q.dtype),
            jax.ShapeDtypeStruct((s_len,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)


def mha_fwd(q, k, v, *, causal: bool, block_q=None, block_kv=None):
    """Multi-head forward over [B, H, S, D] via vmap."""
    f = functools.partial(
        flash_attention_fwd, causal=causal, block_q=block_q, block_kv=block_kv
    )
    return jax.vmap(jax.vmap(f))(q, k, v)
