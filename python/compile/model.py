"""Layer-2 JAX model: a transformer LM whose attention runs on the Layer-1
deterministic Pallas kernels (fwd + order-controlled bwd via custom_vjp).

Lowered once by aot.py to HLO text; the Rust coordinator executes the
resulting artifacts via PJRT. Python never runs at training time.

Parameter layout (flat, position == artifact argument order):
  embed [V, D]
  per layer: ln1 [D], wqkv [D, 3D], wo [D, D], ln2 [D],
             w_gate [D, F], w_up [D, F], w_down [F, D]
  ln_f [D]
Unembedding is tied to `embed`.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import schedules
from .kernels.flash_bwd import mha_bwd
from .kernels.flash_fwd import mha_fwd


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static model/run geometry — must match the Rust TrainConfig."""

    vocab: int = 512
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 1024
    seqlen: int = 128
    batch: int = 8
    micro_batch: int = 2
    lr: float = 3e-2
    momentum: float = 0.9
    causal: bool = True
    # Attention schedule: dQ fold order + dK/dV visit order (DASH deploys
    # Descending at head_dim >= 128; here it demonstrates the machinery).
    schedule: str = "descending"
    block: int = 32

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_tiles(self) -> int:
        assert self.seqlen % self.block == 0
        return self.seqlen // self.block

    def order(self) -> np.ndarray:
        """The dQ fold order for this config's schedule."""
        kind = "fa3" if self.schedule in ("fa3", "descending") else self.schedule
        return schedules.order_for(kind, self.n_tiles, self.n_tiles, self.causal)

    def param_names(self) -> list[str]:
        names = ["embed"]
        for l in range(self.n_layers):
            names += [
                f"l{l}.ln1",
                f"l{l}.wqkv",
                f"l{l}.wo",
                f"l{l}.ln2",
                f"l{l}.w_gate",
                f"l{l}.w_up",
                f"l{l}.w_down",
            ]
        names.append("ln_f")
        return names

    def param_shapes(self) -> list[tuple[int, ...]]:
        d, f = self.d_model, self.d_ff
        shapes = [(self.vocab, d)]
        for _ in range(self.n_layers):
            shapes += [(d,), (d, 3 * d), (d, d), (d,), (d, f), (d, f), (f, d)]
        shapes.append((d,))
        return shapes


def make_attention(cfg: ModelConfig):
    """Build the custom-vjp attention over [B, H, S, Dh] using the L1
    kernels: forward = online-softmax Pallas kernel, backward = the
    deterministic, schedule-ordered Pallas kernels."""
    order = jnp.asarray(cfg.order())
    descending = cfg.schedule == "descending"
    causal = cfg.causal
    block = cfg.block

    @jax.custom_vjp
    def attn(q, k, v):
        out, _ = mha_fwd(q, k, v, causal=causal, block_q=block, block_kv=block)
        return out

    def fwd(q, k, v):
        out, lse = mha_fwd(q, k, v, causal=causal, block_q=block, block_kv=block)
        return out, (q, k, v, out, lse)

    def bwd(res, d_out):
        q, k, v, out, lse = res
        dq, dk, dv = mha_bwd(
            q, k, v, out, d_out, lse, order,
            causal=causal, descending=descending, block_q=block, block_kv=block,
        )
        return dq, dk, dv

    attn.defvjp(fwd, bwd)
    return attn


def rmsnorm(x, scale):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def init_params(cfg: ModelConfig, seed):
    """Deterministic on-device init (exported as the `init_params` artifact;
    `seed` is a traced i32 scalar so one artifact serves every seed)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in zip(cfg.param_names(), cfg.param_shapes()):
        key, sub = jax.random.split(key)
        if len(shape) == 1:
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            std = 0.02 if name == "embed" else 1.0 / np.sqrt(fan_in)
            params.append(std * jax.random.normal(sub, shape, jnp.float32))
    return params


def forward(cfg: ModelConfig, params, tokens):
    """Token ids [B, S] -> logits [B, S, V]."""
    attn = make_attention(cfg)
    it = iter(params)
    embed = next(it)
    x = embed[tokens]  # [B, S, D]
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    for _ in range(cfg.n_layers):
        ln1, wqkv, wo, ln2, w_gate, w_up, w_down = (next(it) for _ in range(7))
        y = rmsnorm(x, ln1)
        qkv = y @ wqkv  # [B, S, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # [B, S, D] -> [B, H, S, Dh]
        to_heads = lambda t: t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        o = attn(to_heads(q), to_heads(k), to_heads(v))
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ wo
        y = rmsnorm(x, ln2)
        x = x + (jax.nn.silu(y @ w_gate) * (y @ w_up)) @ w_down
    ln_f = next(it)
    x = rmsnorm(x, ln_f)
    return x @ embed.T


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    """Mean cross-entropy in nats."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(picked)


def grad_step(cfg: ModelConfig, params, tokens, targets):
    """Gradients + loss (microbatch path: the Rust coordinator folds
    several of these in its deterministic accumulation order)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(
        list(params)
    )
    return tuple(grads) + (loss,)


def apply_update(cfg: ModelConfig, params, moms, grads):
    """SGD with momentum: m' = mu m + g; p' = p - lr m'."""
    new_params, new_moms = [], []
    for p, m, g in zip(params, moms, grads):
        m2 = cfg.momentum * m + g
        new_params.append(p - cfg.lr * m2)
        new_moms.append(m2)
    return tuple(new_params) + tuple(new_moms)


def train_step(cfg: ModelConfig, params, moms, tokens, targets):
    """Fused step: grads + SGD-momentum update + loss."""
    out = grad_step(cfg, params, tokens, targets)
    grads, loss = out[:-1], out[-1]
    updated = apply_update(cfg, params, moms, grads)
    return updated + (loss,)


def attn_fwd_entry(cfg: ModelConfig, q, k, v):
    """Standalone attention forward artifact ([B, H, S, Dh])."""
    return mha_fwd(q, k, v, causal=cfg.causal, block_q=cfg.block, block_kv=cfg.block)


def attn_bwd_entry(cfg: ModelConfig, q, k, v, out, d_out, lse, order):
    """Standalone deterministic backward artifact. `order` is an input so
    the Rust determinism audit can permute the fold order per run."""
    return mha_bwd(
        q, k, v, out, d_out, lse, order,
        causal=cfg.causal,
        descending=cfg.schedule == "descending",
        block_q=cfg.block,
        block_kv=cfg.block,
    )
