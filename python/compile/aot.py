"""AOT exporter: lower every Layer-2 entry point to HLO *text* and write
`artifacts/` + `manifest.json` for the Rust runtime.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
published `xla` crate's backend) rejects; the text parser reassigns ids and
round-trips cleanly. See /opt/xla-example/README.md.

Usage: (cd python && python -m compile.aot --out-dir ../artifacts)
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name, x) -> dict:
    dt = {"float32": "f32", "int32": "i32", "bfloat16": "bf16"}[str(x.dtype)]
    return {"name": name, "shape": list(x.shape), "dtype": dt}


def _lower(fn, args):
    return jax.jit(fn).lower(*args)


def export(out_dir: pathlib.Path, cfg: M.ModelConfig) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    f32 = jnp.float32
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct

    param_specs = [sds(s, f32) for s in cfg.param_shapes()]
    param_names = cfg.param_names()
    n_params = len(param_specs)
    tok = sds((cfg.batch, cfg.seqlen), i32)
    tgt = sds((cfg.batch, cfg.seqlen), i32)
    mtok = sds((cfg.micro_batch, cfg.seqlen), i32)
    mtgt = sds((cfg.micro_batch, cfg.seqlen), i32)

    b, h, s, dh = 2, cfg.n_heads, cfg.seqlen, cfg.head_dim
    qkv = sds((b, h, s, dh), f32)
    lse = sds((b, h, s), f32)
    nt = cfg.n_tiles
    order = sds((nt, nt), i32)

    modules = {}

    def emit(name, fn, args, input_names, output_names, output_shapes, meta=None):
        lowered = _lower(fn, args)
        text = to_hlo_text(lowered)
        hlo_file = f"{name}.hlo.txt"
        (out_dir / hlo_file).write_text(text)
        modules[name] = {
            "hlo_file": hlo_file,
            "inputs": [
                _spec(n, a) for n, a in zip(input_names, args)
            ],
            "outputs": [
                {"name": n, "shape": list(shp), "dtype": dt}
                for n, (shp, dt) in zip(output_names, output_shapes)
            ],
            "meta": {"n_params": n_params, **(meta or {})},
        }
        print(f"  {name}: {len(text)} chars")

    pshape = cfg.param_shapes()

    # init_params(seed) -> params
    emit(
        "init_params",
        lambda seed: tuple(M.init_params(cfg, seed)),
        (sds((), i32),),
        ["seed"],
        param_names,
        [(s, "f32") for s in pshape],
    )

    # model_fwd(params, tokens) -> logits
    emit(
        "model_fwd",
        lambda *a: (M.forward(cfg, list(a[:n_params]), a[n_params]),),
        (*param_specs, tok),
        param_names + ["tokens"],
        ["logits"],
        [((cfg.batch, cfg.seqlen, cfg.vocab), "f32")],
    )

    # train_step(params, moms, tokens, targets) -> (params', moms', loss)
    emit(
        "train_step",
        lambda *a: M.train_step(
            cfg, list(a[:n_params]), list(a[n_params : 2 * n_params]),
            a[2 * n_params], a[2 * n_params + 1],
        ),
        (*param_specs, *param_specs, tok, tgt),
        param_names + [f"m.{n}" for n in param_names] + ["tokens", "targets"],
        param_names + [f"m.{n}" for n in param_names] + ["loss"],
        [(s, "f32") for s in pshape] + [(s, "f32") for s in pshape] + [((), "f32")],
        meta={"batch": cfg.batch, "lr": cfg.lr, "momentum": cfg.momentum},
    )

    # grad_step(params, tokens, targets) -> (grads, loss)  [microbatch size]
    emit(
        "grad_step",
        lambda *a: M.grad_step(cfg, list(a[:n_params]), a[n_params], a[n_params + 1]),
        (*param_specs, mtok, mtgt),
        param_names + ["tokens", "targets"],
        [f"g.{n}" for n in param_names] + ["loss"],
        [(s, "f32") for s in pshape] + [((), "f32")],
        meta={"micro_batch": cfg.micro_batch},
    )

    # apply_update(params, moms, grads) -> (params', moms')
    emit(
        "apply_update",
        lambda *a: M.apply_update(
            cfg, list(a[:n_params]), list(a[n_params : 2 * n_params]),
            list(a[2 * n_params :]),
        ),
        (*param_specs, *param_specs, *param_specs),
        param_names
        + [f"m.{n}" for n in param_names]
        + [f"g.{n}" for n in param_names],
        param_names + [f"m.{n}" for n in param_names],
        [(s, "f32") for s in pshape] * 2,
    )

    # attn_fwd(q, k, v) -> (out, lse)
    emit(
        "attn_fwd",
        lambda q, k, v: M.attn_fwd_entry(cfg, q, k, v),
        (qkv, qkv, qkv),
        ["q", "k", "v"],
        ["out", "lse"],
        [((b, h, s, dh), "f32"), ((b, h, s), "f32")],
        meta={"causal": cfg.causal, "block": cfg.block},
    )

    # attn_bwd(q, k, v, out, d_out, lse, order) -> (dq, dk, dv)
    emit(
        "attn_bwd",
        lambda q, k, v, o, do, l, ordr: M.attn_bwd_entry(cfg, q, k, v, o, do, l, ordr),
        (qkv, qkv, qkv, qkv, qkv, lse, order),
        ["q", "k", "v", "out", "d_out", "lse", "order"],
        ["dq", "dk", "dv"],
        [((b, h, s, dh), "f32")] * 3,
        meta={"causal": cfg.causal, "block": cfg.block, "n_tiles": nt,
              "schedule": cfg.schedule},
    )

    manifest = {
        "modules": modules,
        # Global config so the Rust side can cross-check TrainConfig.
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seqlen": cfg.seqlen,
            "batch": cfg.batch,
            "micro_batch": cfg.micro_batch,
            "schedule": cfg.schedule,
        },
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(legacy) single-file target; ignored")
    args = ap.parse_args()
    cfg = M.ModelConfig()
    out_dir = pathlib.Path(args.out_dir)
    print(f"exporting artifacts for {cfg} -> {out_dir}")
    export(out_dir, cfg)
    print("done")


if __name__ == "__main__":
    main()
