"""Schedule-mirror tests: the Python order generators must match the Rust
generators (rust/src/schedule/) on golden cases, and satisfy the same
invariants (coverage, conflict-freeness)."""

import numpy as np
import pytest

from compile.kernels import schedules


def test_fa3_order_golden():
    # Mirrors rust fa3.rs::reduction_order_is_ascending_kv (n=4 causal).
    o = schedules.fa3_order(4, 4, causal=True)
    assert o[3].tolist() == [0, 1, 2, 3]
    assert o[1].tolist() == [0, 1, -1, -1]


def test_shift_order_golden():
    # Mirrors rust shift.rs::reduction_order_descends_cyclically_from_diagonal.
    o = schedules.shift_order(4)
    assert o[2].tolist() == [2, 1, 0, 3]
    assert o[0].tolist() == [0, 3, 2, 1]


def test_symmetric_shift_order_properties():
    # Every causal-live contribution exactly once per row; padding after.
    for n in (2, 4, 8, 16):
        o = schedules.symmetric_shift_order(n)
        for q in range(n):
            row = o[q]
            live = row[row >= 0]
            assert sorted(live.tolist()) == list(range(q + 1)), f"n={n} q={q}"
            assert (row[q + 1 :] == -1).all()


def test_symmetric_shift_is_conflict_free():
    """Reconstruct per-SM timelines from the construction and assert no two
    SMs fold the same q at the same timestamp — the Lemma-1 precondition
    (mirrors rust symmetric_shift.rs::folded_steps_are_conflict_free)."""
    n = 8
    h = n // 2
    # Rebuild (timestamp, q, sm) tuples exactly as the generator does.
    events = []
    for s in range(h):
        for t in range(h):
            events.append((t, h + (s + t) % h, s))
        for i, q in enumerate(range(s, h)):
            events.append((h + i, q, s))
        for t2, q in enumerate(range(n - 1, n - 2 - s, -1)):
            events.append((2 * h - s + t2, q, s))
    seen = {}
    for ts, q, sm in events:
        assert (ts, q) not in seen, f"conflict at t={ts} q={q}"
        seen[(ts, q)] = sm


def test_shuffled_reproducible_by_seed():
    a = schedules.shuffled_order(8, 8, True, seed=5)
    b = schedules.shuffled_order(8, 8, True, seed=5)
    c = schedules.shuffled_order(8, 8, True, seed=6)
    assert (a == b).all()
    assert (a != c).any()
    # Rows are permutations of the live set.
    for q in range(8):
        live = a[q][a[q] >= 0]
        assert sorted(live.tolist()) == list(range(q + 1))


def test_order_for_dispatch():
    assert (schedules.order_for("fa3", 4, 4, True) == schedules.fa3_order(4, 4, True)).all()
    assert (schedules.order_for("shift", 4, 4, False) == schedules.shift_order(4)).all()
    with pytest.raises(ValueError):
        schedules.order_for("nope", 4, 4, True)


def test_full_mask_rows_are_permutations():
    for kind in ("fa3", "shift"):
        o = schedules.order_for(kind, 8, 8, False)
        for q in range(8):
            assert sorted(o[q].tolist()) == list(range(8)), kind
