"""Layer-2 model tests: shapes, loss semantics, optimizer algebra, and a
short real-training check (loss decreases on learnable data)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

TINY = M.ModelConfig(
    vocab=64, d_model=32, n_layers=2, n_heads=2, d_ff=64,
    seqlen=32, batch=4, micro_batch=2, block=16, lr=0.05,
)


@pytest.fixture(scope="module")
def tiny_params():
    return M.init_params(TINY, 42)


def test_param_layout_consistent(tiny_params):
    names, shapes = TINY.param_names(), TINY.param_shapes()
    assert len(names) == len(shapes) == len(tiny_params)
    for p, s in zip(tiny_params, shapes):
        assert tuple(p.shape) == tuple(s)
    assert names[0] == "embed" and names[-1] == "ln_f"


def test_init_deterministic_in_seed():
    a = M.init_params(TINY, 7)
    b = M.init_params(TINY, 7)
    c = M.init_params(TINY, 8)
    for x, y in zip(a, b):
        assert (np.asarray(x) == np.asarray(y)).all()
    assert any((np.asarray(x) != np.asarray(y)).any() for x, y in zip(a, c))


def test_forward_shapes_and_finite(tiny_params):
    tok = jnp.zeros((TINY.batch, TINY.seqlen), jnp.int32)
    logits = M.forward(TINY, tiny_params, tok)
    assert logits.shape == (TINY.batch, TINY.seqlen, TINY.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_initial_loss_near_uniform(tiny_params):
    rng = np.random.default_rng(0)
    tok = jnp.asarray(rng.integers(0, TINY.vocab, (TINY.batch, TINY.seqlen)), jnp.int32)
    loss = M.loss_fn(TINY, tiny_params, tok, tok)
    assert abs(float(loss) - np.log(TINY.vocab)) < 0.3


def test_grad_step_outputs(tiny_params):
    tok = jnp.zeros((TINY.micro_batch, TINY.seqlen), jnp.int32)
    out = M.grad_step(TINY, tiny_params, tok, tok)
    assert len(out) == len(tiny_params) + 1
    for g, p in zip(out[:-1], tiny_params):
        assert g.shape == p.shape
        assert bool(jnp.isfinite(g).all())


def test_apply_update_is_sgd_momentum(tiny_params):
    moms = [jnp.ones_like(p) for p in tiny_params]
    grads = [jnp.full_like(p, 2.0) for p in tiny_params]
    out = M.apply_update(TINY, tiny_params, moms, grads)
    p = len(tiny_params)
    new_p, new_m = out[:p], out[p:]
    # m' = mu*1 + 2 ; p' = p - lr*m'
    want_m = TINY.momentum * 1.0 + 2.0
    np.testing.assert_allclose(np.asarray(new_m[0]), want_m, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(new_p[0]),
        np.asarray(tiny_params[0]) - TINY.lr * want_m,
        rtol=1e-5, atol=1e-7,
    )


def test_train_step_reduces_loss_on_fixed_batch(tiny_params):
    """A few fused steps on one batch must fit it (loss strictly drops)."""
    rng = np.random.default_rng(1)
    tok = jnp.asarray(
        rng.integers(0, TINY.vocab, (TINY.batch, TINY.seqlen + 1)), jnp.int32
    )
    x, y = tok[:, :-1], tok[:, 1:]
    p = list(tiny_params)
    m = [jnp.zeros_like(t) for t in p]
    step = jax.jit(lambda pp, mm: M.train_step(TINY, pp, mm, x, y))
    losses = []
    n = len(p)
    for _ in range(6):
        out = step(p, m)
        p, m, loss = list(out[:n]), list(out[n : 2 * n]), out[2 * n]
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.05, losses


def test_train_step_bitwise_deterministic(tiny_params):
    tok = jnp.zeros((TINY.batch, TINY.seqlen), jnp.int32)
    m = [jnp.zeros_like(t) for t in tiny_params]
    step = jax.jit(lambda: M.train_step(TINY, list(tiny_params), m, tok, tok))
    a = step()
    b = step()
    for x, y in zip(a, b):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()


def test_schedule_choice_changes_gradient_bits_not_math(tiny_params):
    """Two deterministic schedules: gradients agree numerically but not
    bitwise — the determinism-pins-an-order story at model level."""
    # 8x8 tiles: at 4x4 the symshift order differs from fa3 only by a
    # commutative swap of the first two contributions (identical bits,
    # correctly — f32 addition is commutative, just not associative).
    cfg_a = dataclasses.replace(TINY, seqlen=64, block=8, schedule="fa3")
    cfg_b = dataclasses.replace(TINY, seqlen=64, block=8, schedule="symshift")
    tok = jnp.asarray(
        np.random.default_rng(2).integers(0, TINY.vocab, (2, 64)), jnp.int32
    )
    ga = M.grad_step(cfg_a, tiny_params, tok, tok)
    gb = M.grad_step(cfg_b, tiny_params, tok, tok)
    total_a = np.concatenate([np.asarray(g).ravel() for g in ga[:-1]])
    total_b = np.concatenate([np.asarray(g).ravel() for g in gb[:-1]])
    np.testing.assert_allclose(total_a, total_b, rtol=1e-3, atol=1e-5)
    assert (total_a != total_b).any()
