"""Kernel-vs-oracle correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes/masks; every Pallas output is checked
against the pure-jnp reference with assert_allclose, and gradients against
jax.grad through the naive attention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, schedules
from compile.kernels.flash_bwd import flash_attention_bwd, mha_bwd, preprocess
from compile.kernels.flash_fwd import flash_attention_fwd, mha_fwd


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tols(dtype):
    return dict(rtol=3e-5, atol=3e-5) if dtype == jnp.float32 else dict(rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("seqlen,block", [(32, 16), (64, 16), (128, 32), (48, 48)])
def test_fwd_matches_reference(causal, seqlen, block):
    rng = np.random.default_rng(0)
    q, k, v = (_rand(rng, (seqlen, 16), jnp.float32) for _ in range(3))
    o, lse = flash_attention_fwd(q, k, v, causal=causal, block_q=block, block_kv=block)
    o_ref, lse_ref = ref.attention_fwd(q, k, v, causal)
    np.testing.assert_allclose(o, o_ref, **_tols(jnp.float32))
    np.testing.assert_allclose(lse, lse_ref, **_tols(jnp.float32))


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("kind", ["fa3", "shuffled"])
def test_bwd_matches_reference(causal, kind):
    rng = np.random.default_rng(1)
    seqlen, block, d = 64, 16, 16
    n = seqlen // block
    q, k, v, do = (_rand(rng, (seqlen, d), jnp.float32) for _ in range(4))
    o, lse = flash_attention_fwd(q, k, v, causal=causal, block_q=block, block_kv=block)
    order = jnp.asarray(schedules.order_for(kind, n, n, causal, seed=3))
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, do, lse, order, causal=causal, block_q=block, block_kv=block
    )
    rq, rk, rv = ref.attention_bwd(q, k, v, o, do, lse, causal)
    np.testing.assert_allclose(dq, rq, **_tols(jnp.float32))
    np.testing.assert_allclose(dk, rk, **_tols(jnp.float32))
    np.testing.assert_allclose(dv, rv, **_tols(jnp.float32))


@pytest.mark.parametrize("kind", ["shift", "symshift"])
def test_bwd_dash_schedules_match_reference(kind):
    causal = kind == "symshift"
    rng = np.random.default_rng(2)
    seqlen, block, d = 64, 16, 8
    n = seqlen // block
    q, k, v, do = (_rand(rng, (seqlen, d), jnp.float32) for _ in range(4))
    o, lse = flash_attention_fwd(q, k, v, causal=causal, block_q=block, block_kv=block)
    order = jnp.asarray(schedules.order_for(kind, n, n, causal))
    dq, _, _ = flash_attention_bwd(
        q, k, v, o, do, lse, order, causal=causal, block_q=block, block_kv=block
    )
    rq, _, _ = ref.attention_bwd(q, k, v, o, do, lse, causal)
    np.testing.assert_allclose(dq, rq, **_tols(jnp.float32))


def test_descending_visit_order_matches_reference_and_changes_bits():
    rng = np.random.default_rng(3)
    seqlen, block, d = 64, 16, 16
    n = seqlen // block
    q, k, v, do = (_rand(rng, (seqlen, d), jnp.float32) for _ in range(4))
    o, lse = flash_attention_fwd(q, k, v, causal=True, block_q=block, block_kv=block)
    order = jnp.asarray(schedules.fa3_order(n, n, True))
    args = (q, k, v, o, do, lse, order)
    asc = flash_attention_bwd(*args, causal=True, descending=False, block_q=block, block_kv=block)
    desc = flash_attention_bwd(*args, causal=True, descending=True, block_q=block, block_kv=block)
    rq, rk, rv = ref.attention_bwd(q, k, v, o, do, lse, True)
    for a, b, r in zip(asc, desc, (rq, rk, rv)):
        np.testing.assert_allclose(a, r, **_tols(jnp.float32))
        np.testing.assert_allclose(b, r, **_tols(jnp.float32))
    # Visit order changes the fold sequence of dK/dV -> different bits
    # (mathematically equal, bitwise distinct: FP non-associativity).
    assert (np.asarray(asc[1]) != np.asarray(desc[1])).any()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mha_shapes_and_dtypes(dtype):
    rng = np.random.default_rng(4)
    b, h, s, d = 2, 3, 32, 8
    q, k, v = (_rand(rng, (b, h, s, d), dtype) for _ in range(3))
    o, lse = mha_fwd(q, k, v, causal=True, block_q=16, block_kv=16)
    assert o.shape == (b, h, s, d) and o.dtype == dtype
    assert lse.shape == (b, h, s) and lse.dtype == jnp.float32
    o_ref = ref.mha(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), True)
    np.testing.assert_allclose(o.astype(jnp.float32), o_ref, **_tols(dtype))


def test_grad_through_custom_kernels_matches_autodiff():
    """End-to-end gradient: flash kernels composed via VJP vs jax.grad of
    the naive reference."""
    rng = np.random.default_rng(5)
    s, d, block = 32, 8, 16
    n = s // block
    q, k, v = (_rand(rng, (s, d), jnp.float32) for _ in range(3))
    order = jnp.asarray(schedules.fa3_order(n, n, True))

    def flash_loss(q, k, v):
        o, lse = flash_attention_fwd(q, k, v, causal=True, block_q=block, block_kv=block)
        return jnp.sum(jnp.sin(o))

    def ref_loss(q, k, v):
        return jnp.sum(jnp.sin(ref.attention(q, k, v, True)))

    # flash grad assembled manually from the bwd kernels:
    o, lse = flash_attention_fwd(q, k, v, causal=True, block_q=block, block_kv=block)
    do = jnp.cos(o)
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, do, lse, order, causal=True, block_q=block, block_kv=block
    )
    gq, gk, gv = jax.grad(ref_loss, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, gq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dk, gk, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dv, gv, rtol=1e-4, atol=1e-4)


def test_preprocess_delta():
    rng = np.random.default_rng(6)
    o, do = (_rand(rng, (8, 4), jnp.float32) for _ in range(2))
    np.testing.assert_allclose(preprocess(o, do), np.sum(np.asarray(o) * np.asarray(do), -1))


@settings(max_examples=12, deadline=None)
@given(
    s_tiles=st.integers(1, 4),
    block=st.sampled_from([8, 16]),
    d=st.sampled_from([4, 8, 16]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_fwd_bwd_property_sweep(s_tiles, block, d, causal, seed):
    """Hypothesis sweep over tile counts, block sizes, head dims, masks."""
    s = s_tiles * block
    rng = np.random.default_rng(seed)
    q, k, v, do = (_rand(rng, (s, d), jnp.float32) for _ in range(4))
    o, lse = flash_attention_fwd(q, k, v, causal=causal, block_q=block, block_kv=block)
    o_ref, lse_ref = ref.attention_fwd(q, k, v, causal)
    np.testing.assert_allclose(o, o_ref, rtol=5e-5, atol=5e-5)
    order = jnp.asarray(schedules.order_for("fa3", s_tiles, s_tiles, causal))
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, do, lse, order, causal=causal, block_q=block, block_kv=block
    )
    rq, rk, rv = ref.attention_bwd(q, k, v, o, do, lse, causal)
    np.testing.assert_allclose(dq, rq, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dk, rk, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dv, rv, rtol=1e-4, atol=1e-4)
