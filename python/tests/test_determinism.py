"""Table 1 through the real kernels: deterministic fold orders give bitwise
identical gradients across runs; shuffled (atomicAdd-like) orders give
O(1e-4)-scale deviations."""

import jax.numpy as jnp
import numpy as np

from compile.kernels import schedules
from compile.kernels.flash_bwd import flash_attention_bwd
from compile.kernels.flash_fwd import flash_attention_fwd

S, BLOCK, D = 128, 16, 32
N = S // BLOCK


def _setup(causal, seed=0):
    rng = np.random.default_rng(seed)
    q, k, v, do = (
        jnp.asarray(rng.normal(size=(S, D)), jnp.float32) for _ in range(4)
    )
    o, lse = flash_attention_fwd(q, k, v, causal=causal, block_q=BLOCK, block_kv=BLOCK)
    return q, k, v, o, do, lse


def _dq(args, order, causal):
    q, k, v, o, do, lse = args
    dq, _, _ = flash_attention_bwd(
        q, k, v, o, do, lse, jnp.asarray(order), causal=causal,
        block_q=BLOCK, block_kv=BLOCK,
    )
    return np.asarray(dq)


def test_fixed_order_bitwise_identical_over_10_runs():
    for causal in (False, True):
        args = _setup(causal)
        order = schedules.fa3_order(N, N, causal)
        runs = [_dq(args, order, causal) for _ in range(10)]
        bits = {r.tobytes() for r in runs}
        assert len(bits) == 1, f"deterministic kernel produced {len(bits)} results"


def _setup_bf16(causal, seqlen=256, seed=0):
    rng = np.random.default_rng(seed)
    q, k, v, do = (
        jnp.asarray(rng.normal(size=(seqlen, D)) * 2, jnp.bfloat16) for _ in range(4)
    )
    o, lse = flash_attention_fwd(q, k, v, causal=causal, block_q=BLOCK, block_kv=BLOCK)
    return q, k, v, o, do, lse


def test_shuffled_orders_deviate_at_table1_scale():
    # Paper Table 1 (bf16, production shapes): max |q_r - q_ref| = 2.4e-4
    # (full) / 4.9e-4 (causal) for non-deterministic accumulation, 0 for
    # deterministic. At our bf16/seq-256 scale we measure ~1e-3 with the
    # same causal ~2x full ratio (recorded in EXPERIMENTS.md).
    n = 256 // BLOCK
    devs = {}
    for causal, paper_dev in ((False, 2.4e-4), (True, 4.9e-4)):
        args = _setup_bf16(causal)
        q, k, v, o, do, lse = args
        ref = np.asarray(
            flash_attention_bwd(
                q, k, v, o, do, lse, jnp.asarray(schedules.fa3_order(n, n, causal)),
                causal=causal, block_q=BLOCK, block_kv=BLOCK,
            )[0].astype(jnp.float32)
        )
        max_dev = 0.0
        distinct = set()
        for run in range(10):
            order = schedules.shuffled_order(n, n, causal, seed=run)
            dq = np.asarray(
                flash_attention_bwd(
                    q, k, v, o, do, lse, jnp.asarray(order),
                    causal=causal, block_q=BLOCK, block_kv=BLOCK,
                )[0].astype(jnp.float32)
            )
            distinct.add(dq.tobytes())
            max_dev = max(max_dev, float(np.max(np.abs(dq - ref))))
        assert len(distinct) > 1, "shuffled orders must differ bitwise"
        # Table-1 order of magnitude (data-dependent; allow a decade).
        assert paper_dev / 10 < max_dev < paper_dev * 50, (
            f"max dev {max_dev} not at Table-1 scale {paper_dev}"
        )
        devs[causal] = max_dev
    # The paper's causal deviation exceeds its full-mask one; ours too.
    assert devs[True] >= devs[False]


def test_dash_schedules_are_deterministic_but_distinct_orders():
    """Shift/symshift orders are just as deterministic as FA3's — and give
    *different* (all correct) bit patterns, showing determinism pins an
    order, not a unique value."""
    causal = True
    args = _setup(causal)
    a = _dq(args, schedules.fa3_order(N, N, causal), causal)
    b = _dq(args, schedules.symmetric_shift_order(N), causal)
    a2 = _dq(args, schedules.fa3_order(N, N, causal), causal)
    assert a.tobytes() == a2.tobytes()
    assert a.tobytes() != b.tobytes()
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
