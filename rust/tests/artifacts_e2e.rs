//! Integration tests over the real AOT artifacts + PJRT runtime.
//! These skip gracefully when `make artifacts` has not been run.

use dash::coordinator::config::DeterminismMode;
use dash::coordinator::{TrainConfig, Trainer};
use dash::runtime::{ArtifactManifest, Engine};
use dash::util::DetRng;

fn artifacts() -> Option<ArtifactManifest> {
    if !ArtifactManifest::available("artifacts") {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(ArtifactManifest::load("artifacts").unwrap())
}

#[test]
fn manifest_modules_present() {
    let Some(m) = artifacts() else { return };
    for module in ["init_params", "train_step", "grad_step", "apply_update", "model_fwd", "attn_fwd", "attn_bwd"] {
        assert!(m.spec(module).is_ok(), "missing module {module}");
    }
    // Signature arithmetic: train_step inputs = 2P + 2.
    let p = m.spec("init_params").unwrap().outputs.len();
    assert_eq!(m.spec("train_step").unwrap().inputs.len(), 2 * p + 2);
    assert_eq!(m.spec("train_step").unwrap().outputs.len(), 2 * p + 1);
    assert_eq!(m.spec("apply_update").unwrap().inputs.len(), 3 * p);
}

#[test]
fn init_params_deterministic_per_seed() {
    let Some(m) = artifacts() else { return };
    let e = Engine::cpu().unwrap();
    let init = e.load(&m, "init_params").unwrap();
    let run = |seed: i32| -> u64 {
        let lit = dash::runtime::literal_i32(&[seed], &[]).unwrap();
        let out = init.run_literals(&[lit]).unwrap();
        let vecs: Vec<Vec<f32>> =
            out.iter().map(|o| dash::runtime::f32_vec(o).unwrap()).collect();
        dash::coordinator::repro::fingerprint_params(vecs.iter().map(|v| v.as_slice()))
    };
    assert_eq!(run(42), run(42), "same seed must init identically");
    assert_ne!(run(42), run(43), "different seeds must differ");
}

#[test]
fn attn_bwd_artifact_is_bitwise_deterministic() {
    let Some(m) = artifacts() else { return };
    let e = Engine::cpu().unwrap();
    let bwd = e.load(&m, "attn_bwd").unwrap();
    let spec = m.spec("attn_bwd").unwrap();
    let mut rng = DetRng::new(11);
    let args: Vec<xla::Literal> = spec
        .inputs
        .iter()
        .map(|t| {
            if t.dtype == "i32" {
                // The fold-order input: ascending causal order.
                let nt = t.shape[0];
                let data: Vec<i32> = (0..nt)
                    .flat_map(|q| (0..nt).map(move |x| if x <= q { x as i32 } else { -1 }))
                    .collect();
                dash::runtime::literal_i32(&data, &t.shape).unwrap()
            } else {
                let data: Vec<f32> =
                    (0..t.numel()).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
                dash::runtime::literal_f32(&data, &t.shape).unwrap()
            }
        })
        .collect();
    let a = dash::runtime::f32_vec(&bwd.run_literals(&args).unwrap()[0]).unwrap();
    let b = dash::runtime::f32_vec(&bwd.run_literals(&args).unwrap()[0]).unwrap();
    assert!(a.iter().all(|x| x.is_finite()), "dq must be finite");
    assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
}

#[test]
fn short_training_run_is_reproducible_and_finite() {
    if !ArtifactManifest::available("artifacts") {
        eprintln!("skipping: artifacts/ missing");
        return;
    }
    let cfg = TrainConfig { steps: 3, log_every: 1, ..TrainConfig::default() };
    let mut t1 = Trainer::new(cfg.clone()).unwrap();
    t1.run().unwrap();
    assert!(t1.metrics.final_loss(1).is_finite());
    let mut t2 = Trainer::new(cfg).unwrap();
    t2.run().unwrap();
    assert!(
        t1.fingerprint.matches(&t2.fingerprint),
        "two identical runs must be bitwise identical"
    );
}

#[test]
fn microbatched_deterministic_accumulation_reproducible() {
    if !ArtifactManifest::available("artifacts") {
        eprintln!("skipping: artifacts/ missing");
        return;
    }
    let cfg = TrainConfig {
        steps: 2,
        batch: 8,
        microbatches: 4,
        determinism: DeterminismMode::Deterministic,
        log_every: 1,
        ..TrainConfig::default()
    };
    let run = |salt: u64| {
        let mut t = Trainer::new(cfg.clone()).unwrap();
        t.shuffle_salt = salt;
        t.run().unwrap();
        t.fingerprint.clone()
    };
    // Salt must not matter in deterministic mode.
    assert!(run(1).matches(&run(2)));
}
