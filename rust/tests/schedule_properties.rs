//! Property-style randomized invariants over analytic AND tuned schedules:
//! every generator must produce a legal schedule (§3.1 invariants via
//! `schedule::validate`) on random geometries, and every successful
//! simulation must respect the autotuner's DAG lower-bound oracle.

use dash::autotune::{lower_bound, tune, TuneOptions};
use dash::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass, validate, Mask,
    ProblemSpec, Schedule,
};
use dash::sim::{simulate, SimConfig};
use dash::util::DetRng;

/// Random (n, heads, mask, n_sm) draw. Sizes stay small enough that the
/// whole suite sweeps dozens of geometries in well under a second.
fn random_spec(rng: &mut DetRng) -> (ProblemSpec, usize) {
    let n = 2 + rng.gen_range(14); // 2..=15
    let heads = 1 + rng.gen_range(5); // 1..=5
    let mask = if rng.gen_range(2) == 0 { Mask::Full } else { Mask::Causal };
    let n_sm = [4usize, 8, 13, n][rng.gen_range(4)];
    (ProblemSpec::square(n, heads, mask), n_sm)
}

/// Generators defined for this spec's mask (shift and symmetric shift
/// assert their home mask).
fn analytic_schedules(spec: ProblemSpec, n_sm: usize) -> Vec<Schedule> {
    let mut out = vec![
        fa3(spec, true),
        fa3(spec, false),
        descending(spec),
        two_pass(spec),
        lpt_schedule(spec, n_sm),
    ];
    match spec.mask {
        Mask::Full => out.push(shift(spec)),
        Mask::Causal => out.push(symmetric_shift(spec)),
    }
    out
}

#[test]
fn every_analytic_schedule_validates_on_random_draws() {
    let mut rng = DetRng::new(0xA11A);
    for _ in 0..60 {
        let (spec, n_sm) = random_spec(&mut rng);
        for s in analytic_schedules(spec, n_sm) {
            validate(&s).unwrap_or_else(|e| {
                panic!("{:?} invalid on {spec:?} (n_sm={n_sm}): {e}", s.kind)
            });
        }
    }
}

#[test]
fn simulated_makespan_never_beats_the_lower_bound() {
    let mut rng = DetRng::new(0xB0B);
    for _ in 0..40 {
        let (spec, n_sm) = random_spec(&mut rng);
        let cfg = SimConfig::ideal(n_sm);
        let lb = lower_bound(&spec, &cfg).overall();
        for s in analytic_schedules(spec, n_sm) {
            // The oracle's guarantee covers the fused-kernel task model
            // (every tile pays c + ordered r) — the space the tuner
            // searches. Two-pass (free local folds, duplicated compute)
            // and the atomic baseline (unordered folds) sit outside it.
            if !s.chains.iter().all(|c| c.ordered && c.reduce_scale == 1.0) {
                continue;
            }
            // Pinned closed forms may deadlock off their home regime
            // (machine narrower than a wave) — a clean error, not a bound
            // violation; skip those runs.
            let Ok(r) = simulate(&s, &cfg) else { continue };
            assert!(
                r.makespan >= lb - 1e-9,
                "{:?} on {spec:?} n_sm={n_sm}: makespan {} < bound {lb}",
                s.kind,
                r.makespan
            );
        }
    }
}

#[test]
fn dynamic_generators_always_simulate() {
    // FA3 / Descending / LPT must never deadlock on ANY machine width —
    // their launch, placement, and reduction orders are co-monotone.
    let mut rng = DetRng::new(0xD1CE);
    for _ in 0..40 {
        let (spec, n_sm) = random_spec(&mut rng);
        let cfg = SimConfig::ideal(n_sm);
        for s in [fa3(spec, true), descending(spec), lpt_schedule(spec, n_sm)] {
            let r = simulate(&s, &cfg)
                .unwrap_or_else(|e| panic!("{:?} deadlocked on {spec:?} n_sm={n_sm}: {e}", s.kind));
            assert_eq!(r.n_tasks, s.total_tasks());
        }
    }
}

#[test]
fn tuned_schedules_validate_and_bracket_between_bound_and_seed() {
    let mut rng = DetRng::new(0x7E57);
    for round in 0u64..8 {
        let (spec, n_sm) = random_spec(&mut rng);
        let opts = TuneOptions { budget: 25, seed: round, sim: SimConfig::ideal(n_sm) };
        let r = tune(spec, &opts).expect("tuning always has a feasible seed");
        validate(&r.schedule)
            .unwrap_or_else(|e| panic!("tuned invalid on {spec:?} (n_sm={n_sm}): {e}"));
        assert!(
            r.makespan <= r.seed_makespan + 1e-9,
            "tuned {} worse than analytic {} on {spec:?} n_sm={n_sm}",
            r.makespan,
            r.seed_makespan
        );
        assert!(
            r.makespan >= r.bound.overall() - 1e-9,
            "tuned {} beats the lower bound {} on {spec:?} n_sm={n_sm}",
            r.makespan,
            r.bound.overall()
        );
        // And the tuned schedule re-simulates to exactly the reported time.
        let again = simulate(&r.schedule, &SimConfig::ideal(n_sm)).unwrap();
        assert!((again.makespan - r.makespan).abs() < 1e-9);
    }
}
