//! Property-style randomized invariants over analytic AND tuned schedules:
//! every generator must produce a legal schedule (§3.1 invariants via
//! `schedule::validate`) on random geometries — square and rectangular —
//! under every mask shape, and every successful simulation must respect
//! the autotuner's DAG lower-bound oracle.

use dash::autotune::{lower_bound, tune, TuneOptions};
use dash::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass, validate, MaskSpec,
    ProblemSpec, Schedule,
};
use dash::sim::{simulate, SimConfig};
use dash::util::DetRng;

/// A random mask over an `n_kv x n_q` grid, covering every `MaskSpec`
/// shape (including a random-but-deterministic block-sparse bitmap).
fn random_mask(rng: &mut DetRng, n_kv: usize, n_q: usize) -> MaskSpec {
    match rng.gen_range(6) {
        0 => MaskSpec::full(),
        1 => MaskSpec::causal(),
        2 => MaskSpec::causal_with_offset(rng.gen_range(5) as isize - 2),
        3 => MaskSpec::sliding_window(1 + rng.gen_range(n_q.max(1))),
        4 => {
            let n = n_kv.max(n_q);
            let mut b = Vec::new();
            for t in 1..n {
                if rng.gen_range(3) == 0 {
                    b.push(t);
                }
            }
            MaskSpec::document(b)
        }
        _ => {
            let bitmap: Vec<bool> = (0..n_kv * n_q).map(|_| rng.gen_range(3) > 0).collect();
            MaskSpec::block_sparse(n_kv, n_q, bitmap)
        }
    }
}

/// Random (n_kv, n_q, heads, mask, n_sm) draw — rectangular roughly half
/// the time. Sizes stay small enough that the whole suite sweeps dozens of
/// geometries in well under a second.
fn random_spec(rng: &mut DetRng) -> (ProblemSpec, usize) {
    let n_kv = 2 + rng.gen_range(14); // 2..=15
    let n_q = if rng.gen_range(2) == 0 { n_kv } else { 2 + rng.gen_range(14) };
    let heads = 1 + rng.gen_range(5); // 1..=5
    let mask = random_mask(rng, n_kv, n_q);
    let n_sm = [4usize, 8, 13, n_kv][rng.gen_range(4)];
    (ProblemSpec { n_kv, n_q, n_heads: heads, mask }, n_sm)
}

/// Every generator applied to this spec. Shift joins only where its
/// structural check passes (its `Err` branch is itself an invariant: a
/// typed error, never a silently invalid schedule).
fn analytic_schedules(spec: &ProblemSpec, n_sm: usize) -> Vec<Schedule> {
    let mut out = vec![
        fa3(spec, true),
        fa3(spec, false),
        descending(spec),
        two_pass(spec),
        lpt_schedule(spec, n_sm),
        symmetric_shift(spec),
    ];
    if let Ok(s) = shift(spec) {
        out.push(s);
    }
    out
}

#[test]
fn every_analytic_schedule_validates_on_random_draws() {
    let mut rng = DetRng::new(0xA11A);
    for _ in 0..80 {
        let (spec, n_sm) = random_spec(&mut rng);
        for s in analytic_schedules(&spec, n_sm) {
            validate(&s).unwrap_or_else(|e| {
                panic!("{:?} invalid on {spec:?} (n_sm={n_sm}): {e}", s.kind)
            });
        }
    }
}

#[test]
fn every_generator_covers_exactly_the_live_tiles() {
    // Task-count conservation across the whole (generator x mask x grid)
    // product: single-pass schedules own each live tile exactly once;
    // two-pass owns it once per pass.
    let mut rng = DetRng::new(0xC0DE);
    for _ in 0..60 {
        let (spec, n_sm) = random_spec(&mut rng);
        let live = spec.total_tiles();
        for s in analytic_schedules(&spec, n_sm) {
            let per_pass =
                if s.kind == dash::schedule::ScheduleKind::TwoPass { 2 } else { 1 };
            assert_eq!(
                s.total_tasks(),
                live * per_pass,
                "{:?} on {spec:?}: task count != live tiles",
                s.kind
            );
        }
    }
}

#[test]
fn shift_supports_exactly_the_uniform_full_row_structures() {
    // The typed-error contract: shift succeeds iff every KV row is fully
    // live and rows fit distinct cyclic starts (n_kv <= n_q).
    let mut rng = DetRng::new(0x5117);
    for _ in 0..60 {
        let (spec, _) = random_spec(&mut rng);
        let uniform = (0..spec.n_kv).all(|kv| spec.chain_len(kv) == spec.n_q);
        let supported = uniform && spec.n_kv <= spec.n_q;
        match shift(&spec) {
            Ok(s) => {
                assert!(supported, "shift accepted an unsupported spec {spec:?}");
                validate(&s).unwrap();
            }
            Err(e) => {
                assert!(!supported, "shift rejected a supported spec {spec:?}: {e}");
            }
        }
    }
}

#[test]
fn simulated_makespan_never_beats_the_lower_bound() {
    let mut rng = DetRng::new(0xB0B);
    for _ in 0..50 {
        let (spec, n_sm) = random_spec(&mut rng);
        let cfg = SimConfig::ideal(n_sm);
        let lb = lower_bound(&spec, &cfg).overall();
        for s in analytic_schedules(&spec, n_sm) {
            // The oracle's guarantee covers the fused-kernel task model
            // (every tile pays c + ordered r) — the space the tuner
            // searches. Two-pass (free local folds, duplicated compute)
            // and the atomic baseline (unordered folds) sit outside it.
            if !s.chains.iter().all(|c| c.ordered && c.reduce_scale == 1.0) {
                continue;
            }
            // Pinned closed forms may deadlock off their home regime
            // (machine narrower than a wave) — a clean error, not a bound
            // violation; skip those runs.
            let Ok(r) = simulate(&s, &cfg) else { continue };
            assert!(
                r.makespan >= lb - 1e-9,
                "{:?} on {spec:?} n_sm={n_sm}: makespan {} < bound {lb}",
                s.kind,
                r.makespan
            );
            assert!(r.makespan.is_finite(), "{:?} on {spec:?}: non-finite makespan", s.kind);
        }
    }
}

#[test]
fn dynamic_generators_always_simulate() {
    // FA3 / Descending / LPT must never deadlock on ANY machine width or
    // mask — their launch, placement, and reduction orders are co-monotone
    // in KV index; every wait targets an earlier-launched chain, so
    // progress is guaranteed. Makespans stay finite.
    let mut rng = DetRng::new(0xD1CE);
    for _ in 0..50 {
        let (spec, n_sm) = random_spec(&mut rng);
        let cfg = SimConfig::ideal(n_sm);
        for s in [fa3(&spec, true), descending(&spec), lpt_schedule(&spec, n_sm)] {
            let r = simulate(&s, &cfg).unwrap_or_else(|e| {
                panic!("{:?} deadlocked on {spec:?} n_sm={n_sm}: {e}", s.kind)
            });
            assert_eq!(r.n_tasks, s.total_tasks());
            assert!(r.makespan.is_finite() && r.makespan >= 0.0);
        }
    }
}

#[test]
fn rectangular_causal_runs_through_every_generator() {
    // The acceptance-criterion regression: a rectangular causal spec must
    // produce bottom-right-aligned masks and validated schedules from
    // every generator (or a typed unsupported-mask error for shift).
    for (n_kv, n_q) in [(8usize, 4usize), (4, 8), (6, 3), (3, 6), (9, 7)] {
        let spec = ProblemSpec { n_kv, n_q, n_heads: 2, mask: MaskSpec::causal() };
        // Bottom-right alignment: the last Q tile sees every KV tile.
        assert!((0..n_kv).all(|kv| spec.live(kv, n_q - 1)), "{n_kv}x{n_q}");
        for s in analytic_schedules(&spec, 4) {
            validate(&s).unwrap_or_else(|e| {
                panic!("{:?} invalid on causal {n_kv}x{n_q}: {e}", s.kind)
            });
        }
        // Off-square causal can never support shift's full-row cycle.
        assert!(shift(&spec).is_err());
        // And the dynamic family simulates without deadlock.
        for s in [fa3(&spec, true), descending(&spec), lpt_schedule(&spec, 4)] {
            let r = simulate(&s, &SimConfig::ideal(4)).unwrap();
            assert_eq!(r.n_tasks, spec.total_tiles());
        }
    }
}

#[test]
fn tuned_schedules_validate_and_bracket_between_bound_and_seed() {
    let mut rng = DetRng::new(0x7E57);
    for round in 0u64..10 {
        let (spec, n_sm) = random_spec(&mut rng);
        let opts = TuneOptions {
            budget: 25,
            seed: round,
            sim: SimConfig::ideal(n_sm),
            batch: 1,
            threads: 1,
        };
        let r = tune(&spec, &opts).expect("tuning always has a feasible seed");
        validate(&r.schedule)
            .unwrap_or_else(|e| panic!("tuned invalid on {spec:?} (n_sm={n_sm}): {e}"));
        assert!(
            r.makespan <= r.seed_makespan + 1e-9,
            "tuned {} worse than analytic {} on {spec:?} n_sm={n_sm}",
            r.makespan,
            r.seed_makespan
        );
        assert!(
            r.makespan >= r.bound.overall() - 1e-9,
            "tuned {} beats the lower bound {} on {spec:?} n_sm={n_sm}",
            r.makespan,
            r.bound.overall()
        );
        // And the tuned schedule re-simulates to exactly the reported time.
        let again = simulate(&r.schedule, &SimConfig::ideal(n_sm)).unwrap();
        assert!((again.makespan - r.makespan).abs() < 1e-9);
    }
}
