//! Property tests for the serving-trace layer — the CLI-boundary analogue
//! of `hw_cluster_properties.rs`:
//!
//! * JSON round-trip: a spec dumps -> parses -> identical value and
//!   byte-identical re-dump, in memory and through the file system (the
//!   `dash trace generate --export` / `--spec` contract).
//! * Malformed input: truncated JSON, missing fields, unknown models, and
//!   invalid parameters are typed errors at the parse boundary — never
//!   panics, never silent fallbacks.
//! * Cache sharing: a batched serving step keys the autotune cache
//!   byte-identically to the same document layout spelled by hand
//!   (`doc:b1,b2,...`), through the same resolver the CLI's `--mask`
//!   flag uses.
//! * Composition: a single-request step composes to exactly the plain
//!   generator's schedule — batching adds requests, never overhead.

use dash::autotune::WorkloadFingerprint;
use dash::schedule::{descending, fa3, two_pass, MaskSpec, ProblemSpec, ScheduleKind};
use dash::sim::{simulate, SimConfig};
use dash::traceload::{
    compile, compose_step_schedule, generate, ArrivalModel, BatchConfig, LengthModel, TraceSpec,
};
use std::path::PathBuf;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dash-traceprop-{}-{tag}.json", std::process::id()))
}

// ---------------------------------------------------------------- JSON i/o

#[test]
fn spec_round_trips_byte_identically_through_the_file_system() {
    let specs = vec![
        TraceSpec::smoke(42),
        TraceSpec {
            name: "bursty-fixed".into(),
            seed: 7,
            requests: 5,
            prompt: LengthModel::Fixed { tiles: 3 },
            decode: LengthModel::Zipf { max_tiles: 4, exponent: 1.6 },
            arrival: ArrivalModel::Bursty { rate: 2.0, period: 3 },
        },
    ];
    for spec in &specs {
        let path = tmp_path(&spec.name);
        let path_s = path.to_str().unwrap().to_string();
        spec.save(&path_s).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = TraceSpec::load(&path_s).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(&back, spec, "{}", spec.name);
        assert_eq!(back.dump(), text, "{}: re-dump must be byte-identical", spec.name);
        // The round-tripped spec generates the identical trace.
        assert_eq!(generate(&back).unwrap(), generate(spec).unwrap());
    }
}

// ------------------------------------------------------------ malformed input

#[test]
fn malformed_documents_are_typed_errors() {
    for (bad, why) in [
        ("", "empty"),
        ("{\"name\": \"x\"", "truncated"),
        ("[1, 2]", "not an object"),
        ("{\"name\": \"x\", \"seed\": 1, \"requests\": 2}", "missing models"),
    ] {
        assert!(TraceSpec::parse(bad).is_err(), "{why} input must not parse");
    }
    // Unknown models and invalid parameters die at the same boundary.
    let good = TraceSpec::smoke(1).dump();
    let poisoned = good.replace("zipf", "pareto");
    assert!(TraceSpec::parse(&poisoned).is_err(), "unknown model must not parse");
    let negative = good.replace("1.5", "-1.5"); // the Poisson rate
    assert!(TraceSpec::parse(&negative).is_err(), "negative rate must not parse");
}

#[test]
fn loading_a_missing_or_garbage_file_fails_loudly() {
    assert!(TraceSpec::load("/definitely/not/a/trace-spec.json").is_err());
    let path = tmp_path("garbage");
    let path_s = path.to_str().unwrap().to_string();
    std::fs::write(&path, "]{ not json").unwrap();
    let res = TraceSpec::load(&path_s);
    let _ = std::fs::remove_file(&path);
    assert!(res.is_err());
}

// ----------------------------------------------------- autotune cache sharing

#[test]
fn serving_steps_share_cache_keys_with_hand_built_document_masks() {
    let trace = generate(&TraceSpec::smoke(42)).unwrap();
    let steps = compile(&trace, &BatchConfig::new(4, 2)).unwrap();
    let step = steps.iter().max_by_key(|s| s.slices.len()).unwrap();
    assert!(step.slices.len() > 1, "the smoke trace batches at least one step");
    let spelled = format!(
        "doc:{}",
        step.slices[1..].iter().map(|s| s.start_tile.to_string()).collect::<Vec<_>>().join(",")
    );
    // Through the same resolver the CLI's --mask flag uses.
    let hand = dash::mask::resolve(&spelled).unwrap();
    assert_eq!(hand, step.spec.mask, "one layout, one mask value");
    let hand_spec = ProblemSpec::square(step.total_tiles(), 2, hand);
    let sim = SimConfig::ideal(step.total_tiles());
    assert_eq!(
        WorkloadFingerprint::new(&step.spec, &sim).key(),
        WorkloadFingerprint::new(&hand_spec, &sim).key(),
        "trace-compiled and hand-built layouts must share one tuning-cache key"
    );
}

// ------------------------------------------------------- schedule composition

#[test]
fn composed_singleton_steps_match_the_plain_generator() {
    // A step holding one request is the degenerate batch: its composed
    // schedule must simulate to exactly the plain generator's makespan on
    // the equal-sized full-mask problem.
    let trace = generate(&TraceSpec::smoke(42)).unwrap();
    let steps = compile(&trace, &BatchConfig::new(1, 2)).unwrap();
    let step = steps
        .iter()
        .find(|s| s.slices.len() == 1 && s.total_tiles() > 1)
        .expect("batch 1 serves a multi-tile prefill alone");
    let plain_spec = ProblemSpec::square(step.total_tiles(), 2, MaskSpec::full());
    let sim = SimConfig::ideal(step.total_tiles());
    for (kind, plain) in [
        (ScheduleKind::Fa3, fa3(&plain_spec, true)),
        (ScheduleKind::Descending, descending(&plain_spec)),
        (ScheduleKind::TwoPass, two_pass(&plain_spec)),
    ] {
        let composed = compose_step_schedule(step, kind).unwrap();
        let a = simulate(&composed, &sim).unwrap();
        let b = simulate(&plain, &sim).unwrap();
        assert_eq!(a.makespan, b.makespan, "{kind:?}: composition added overhead");
        assert_eq!(a.n_tasks, b.n_tasks, "{kind:?}: composition changed the work");
    }
}
