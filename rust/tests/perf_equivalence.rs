//! Perf-equivalence suite: the hot-path machinery (buffer-reusing
//! [`Simulator`], parallel [`simulate_batch`], batched tuner scoring) is
//! allowed to change wall-clock time and nothing else. Every test here
//! pins a bitwise identity between an optimized path and the single-shot
//! serial path it replaced — across all seven generators, every mask
//! family, rectangular grids, error runs, thread counts, and the CLI.

use dash::autotune::{tune, TuneOptions, TuneResult};
use dash::schedule::fa3::fa3_atomic;
use dash::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass, MaskSpec, ProblemSpec,
    Schedule,
};
use dash::sim::{simulate, simulate_batch, CostModel, SimConfig, SimError, Simulator};
use std::process::Command;

/// Every mask family over an `n_kv x n_q` grid (the block-sparse bitmap
/// is a fixed near-banded pattern so each row and column stays live).
fn masks(n_kv: usize, n_q: usize) -> Vec<MaskSpec> {
    let bitmap: Vec<bool> =
        (0..n_kv).flat_map(|kv| (0..n_q).map(move |q| kv <= q + 2)).collect();
    vec![
        MaskSpec::full(),
        MaskSpec::causal(),
        MaskSpec::sliding_window(3),
        MaskSpec::document(vec![n_kv.div_ceil(2)]),
        MaskSpec::block_sparse(n_kv, n_q, bitmap),
    ]
}

/// All seven generators on this spec; shift joins where its structural
/// check passes (full-mask square grids).
fn generators(spec: &ProblemSpec, n_sm: usize) -> Vec<Schedule> {
    let mut out = vec![
        fa3(spec, true),
        fa3_atomic(spec),
        descending(spec),
        symmetric_shift(spec),
        two_pass(spec),
        lpt_schedule(spec, n_sm),
    ];
    if let Ok(s) = shift(spec) {
        out.push(s);
    }
    out
}

/// The grids the sweep runs on: the paper's square setting plus both
/// rectangular orientations (more Q than KV and vice versa).
fn specs(mask: MaskSpec) -> Vec<ProblemSpec> {
    vec![
        ProblemSpec::square(8, 2, mask.clone()),
        ProblemSpec { n_kv: 6, n_q: 10, n_heads: 2, mask: mask.clone() },
        ProblemSpec { n_kv: 10, n_q: 6, n_heads: 3, mask },
    ]
}

fn assert_bitwise_eq(a: &dash::sim::SimResult, b: &dash::sim::SimResult, what: &str) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
    assert_eq!(a.busy_time.to_bits(), b.busy_time.to_bits(), "{what}: busy_time");
    assert_eq!(a.reduce_busy.to_bits(), b.reduce_busy.to_bits(), "{what}: reduce_busy");
    assert_eq!(a.stall_time.to_bits(), b.stall_time.to_bits(), "{what}: stall_time");
    assert_eq!(a.n_tasks, b.n_tasks, "{what}: n_tasks");
    assert_eq!(a.n_sm_used, b.n_sm_used, "{what}: n_sm_used");
    assert_eq!(a.spans, b.spans, "{what}: spans");
    assert_eq!(a.links, b.links, "{what}: links");
}

#[test]
fn buffered_simulator_matches_single_shot_everywhere() {
    // ONE Simulator across the whole generator x mask x grid x config
    // product — hundreds of runs through the same buffers, interleaved
    // with deliberately failing runs — must reproduce fresh-allocation
    // results bit for bit, spans included.
    let mut sim = Simulator::new();
    let mut deadlock = fa3(&ProblemSpec::square(4, 1, MaskSpec::full()), true);
    deadlock.reduction_order[0] = vec![0, 2, 3]; // kv=1 dropped -> deadlock
    let mut runs = 0usize;
    for mask in masks(8, 8) {
        for spec in specs(mask) {
            let mut cfgs = vec![SimConfig::ideal(spec.n_kv), SimConfig::ideal(5)];
            cfgs.push(SimConfig::fa3_pipeline(7, CostModel::default(), 2));
            for mut cfg in cfgs {
                cfg.record_spans = runs % 3 == 0; // exercise both span modes
                for s in generators(&spec, cfg.n_sm) {
                    if runs % 7 == 0 {
                        // Dirty the buffers with a failing run in between.
                        let err = sim.run(&deadlock, &SimConfig::ideal(4)).unwrap_err();
                        assert!(matches!(err, SimError::Deadlock { .. }));
                    }
                    let buffered = sim.run(&s, &cfg).unwrap_or_else(|e| {
                        panic!("{:?} on {spec:?} failed: {e}", s.kind)
                    });
                    let fresh = simulate(&s, &cfg).unwrap();
                    let what = format!("{:?}/{}/n_sm{}", s.kind, spec.mask.name(), cfg.n_sm);
                    assert_bitwise_eq(&buffered, &fresh, &what);
                    runs += 1;
                }
            }
        }
    }
    assert!(runs > 200, "sweep shrank unexpectedly ({runs} runs)");
}

#[test]
fn error_paths_are_identical_between_entry_points() {
    // Both failure modes (up-front cost validation, mid-run deadlock)
    // must produce the same typed error from every entry point.
    let spec = ProblemSpec::square(4, 1, MaskSpec::full());
    let mut bad_schedule = fa3(&spec, true);
    bad_schedule.reduction_order[0] = vec![0, 2, 3];
    let cfg = SimConfig::ideal(4);
    let mut sim = Simulator::new();
    let a = simulate(&bad_schedule, &cfg).unwrap_err();
    let b = sim.run(&bad_schedule, &cfg).unwrap_err();
    assert_eq!(a, b);
    let mut bad_cfg = cfg;
    bad_cfg.cost.reduce = f64::NAN;
    let good = fa3(&spec, true);
    let a = simulate(&good, &bad_cfg).unwrap_err();
    let b = sim.run(&good, &bad_cfg).unwrap_err();
    assert_eq!(a, b);
    assert!(matches!(a, SimError::NonFiniteCost { .. }));
    // ... and the simulator still works after both failures.
    let after = sim.run(&good, &cfg).unwrap();
    assert_bitwise_eq(&after, &simulate(&good, &cfg).unwrap(), "post-error run");
}

#[test]
fn simulate_batch_is_thread_count_invariant() {
    let mut schedules = Vec::new();
    for mask in masks(8, 8) {
        let spec = ProblemSpec::square(8, 2, mask);
        schedules.extend(generators(&spec, 8));
    }
    let cfg = SimConfig::ideal(8);
    let serial: Vec<_> = schedules.iter().map(|s| simulate(s, &cfg).unwrap()).collect();
    for threads in [0usize, 1, 2, 3, 8, 31] {
        let batch = simulate_batch(&schedules, &cfg, threads);
        assert_eq!(batch.len(), serial.len());
        for (i, (b, s)) in batch.iter().zip(&serial).enumerate() {
            let what = format!("threads={threads} item={i}");
            assert_bitwise_eq(b.as_ref().unwrap(), s, &what);
        }
    }
}

#[test]
fn simulate_batch_is_thread_count_invariant_for_cluster_schedules() {
    // Multi-device schedules ride the same batch machinery; the
    // interconnect lanes (links) must come back bitwise-identical at
    // every thread count, alongside everything else.
    use dash::schedule::{cluster_schedule, ClusterStrategy, ScheduleKind};
    let mut schedules = Vec::new();
    for (strategy, intra, mask, devices) in [
        (ClusterStrategy::Ring, ScheduleKind::Shift, MaskSpec::full(), 2usize),
        (ClusterStrategy::Ring, ScheduleKind::Descending, MaskSpec::causal(), 4),
        (ClusterStrategy::Zigzag, ScheduleKind::Descending, MaskSpec::causal(), 2),
        (ClusterStrategy::Zigzag, ScheduleKind::Fa3, MaskSpec::sliding_window(3), 4),
        (ClusterStrategy::Ring, ScheduleKind::SymmetricShift, MaskSpec::causal(), 1),
    ] {
        let spec = ProblemSpec::square(8, 2, mask);
        let mut s = cluster_schedule(&spec, strategy, intra, devices).unwrap();
        if let Some(c) = s.cluster.as_mut() {
            c.hop_cost = 2.5; // non-unit hop so link timing actually varies
        }
        schedules.push(s);
    }
    let mut cfg = SimConfig::ideal(8);
    cfg.record_spans = true;
    let serial: Vec<_> = schedules.iter().map(|s| simulate(s, &cfg).unwrap()).collect();
    assert!(
        serial.iter().any(|r| !r.links.is_empty()),
        "cluster sweep must exercise interconnect lanes"
    );
    let mut sim = Simulator::new();
    for (i, (s, r)) in schedules.iter().zip(&serial).enumerate() {
        let buffered = sim.run(s, &cfg).unwrap();
        assert_bitwise_eq(&buffered, r, &format!("buffered cluster item={i}"));
    }
    for threads in [0usize, 1, 2, 8] {
        let batch = simulate_batch(&schedules, &cfg, threads);
        assert_eq!(batch.len(), serial.len());
        for (i, (b, s)) in batch.iter().zip(&serial).enumerate() {
            let what = format!("cluster threads={threads} item={i}");
            assert_bitwise_eq(b.as_ref().unwrap(), s, &what);
        }
    }
}

fn assert_same_tune(a: &TuneResult, b: &TuneResult, what: &str) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{what}: makespan");
    assert_eq!(a.seed_makespan.to_bits(), b.seed_makespan.to_bits(), "{what}: seed");
    assert_eq!(a.seed_kind, b.seed_kind, "{what}: seed kind");
    assert_eq!(a.schedule.chains, b.schedule.chains, "{what}: chains");
    assert_eq!(a.schedule.pinned, b.schedule.pinned, "{what}: pins");
    assert_eq!(a.schedule.reduction_order, b.schedule.reduction_order, "{what}: fold order");
    assert_eq!(a.evaluated, b.evaluated, "{what}: evaluated");
    assert_eq!(a.improvements, b.improvements, "{what}: improvements");
    assert_eq!(a.skipped_invalid, b.skipped_invalid, "{what}: skipped_invalid");
    assert_eq!(a.skipped_sim, b.skipped_sim, "{what}: skipped_sim");
}

#[test]
fn tune_winner_is_thread_count_invariant() {
    // Off-regime point (nothing divides evenly) so search genuinely
    // improves on the seed — then the whole result, counters included,
    // must be identical at every thread count.
    let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
    let opts = |threads: usize| TuneOptions {
        budget: 120,
        seed: 5,
        sim: SimConfig::ideal(5),
        batch: 4,
        threads,
    };
    let one = tune(&spec, &opts(1)).unwrap();
    for threads in [0usize, 2, 8] {
        let t = tune(&spec, &opts(threads)).unwrap();
        assert_same_tune(&one, &t, &format!("threads={threads}"));
    }
}

#[test]
fn batch_of_one_reproduces_the_classic_serial_loop() {
    // batch = 1, threads = 1 is exactly the pre-batching search loop:
    // one proposal per round through the reused simulator. Any other
    // thread count over the same batch must not change the trajectory.
    for (mask, n_sm) in [(MaskSpec::causal(), 6), (MaskSpec::full(), 4)] {
        let spec = ProblemSpec::square(8, 2, mask);
        let base = TuneOptions {
            budget: 60,
            seed: 13,
            sim: SimConfig::ideal(n_sm),
            batch: 1,
            threads: 1,
        };
        let serial = tune(&spec, &base).unwrap();
        let threaded = tune(&spec, &TuneOptions { threads: 4, ..base }).unwrap();
        assert_same_tune(&serial, &threaded, "batch=1 threads=4");
    }
}

#[test]
fn cli_tune_output_is_thread_count_invariant() {
    let bin = env!("CARGO_BIN_EXE_dash");
    let run = |threads: &str| {
        let out = Command::new(bin)
            .args(["tune", "--no-cache", "--n", "9", "--heads", "2", "--n-sm", "5"])
            .args(["--budget", "80", "--batch", "4", "--threads", threads])
            .output()
            .expect("run dash tune");
        assert!(out.status.success(), "dash tune --threads {threads} failed: {out:?}");
        String::from_utf8(out.stdout).expect("utf8 tune output")
    };
    let one = run("1");
    let two = run("2");
    // The skipped-proposals line names the thread setting; every other
    // line (winner, bound, gap, counters) must match byte for byte.
    let strip = |s: &str| {
        s.lines().filter(|l| !l.contains("threads")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(strip(&one), strip(&two), "tune output differs across thread counts");
    assert!(one.contains("proposals evaluated"));
}
