//! Fleet-tuning acceptance (ISSUE: fleet-scale autotuner): neighbor
//! selection is a pure function of the key set, portfolio racing is
//! bitwise-stable in the thread count, queue drains are independent of
//! spec order, and warm starts agree with cold searches when budgets
//! saturate — including the tuned-at-64/applied-at-96 generalization pair
//! the committed BENCH_tune.json pins.

use dash::autotune::{
    nearest_neighbor, run_queue, tune, tune_portfolio, tune_warm, PortfolioOptions, Provenance,
    QueueSpec, ScheduleCache, StructuredKey, TuneOptions, WorkloadFingerprint,
};
use dash::schedule::{MaskSpec, ProblemSpec, Schedule};
use dash::sim::SimConfig;

fn causal_key(n: usize, heads: usize, n_sm: usize) -> StructuredKey {
    StructuredKey {
        n_kv: n,
        n_q: n,
        heads,
        mask_fingerprint: "causal".to_string(),
        n_sm,
        cost_hash: 0xc0ffee,
        n_devices: 1,
        cluster_hash: 0,
    }
}

/// A cache path that never exists on disk: opened empty, never saved.
fn ephemeral_cache(tag: &str) -> ScheduleCache {
    ScheduleCache::open(
        std::env::temp_dir().join(format!("dash-fleet-it-{}-{tag}.json", std::process::id())),
    )
}

fn chain_ids(s: &Schedule) -> Vec<(usize, usize)> {
    s.chains.iter().map(|c| (c.head, c.kv)).collect()
}

#[test]
fn neighbor_selection_is_a_pure_function_of_the_key_set() {
    let target = causal_key(64, 2, 64);
    let mut keys: Vec<String> =
        [32usize, 96, 48].iter().map(|&n| causal_key(n, 2, n).key()).collect();
    keys.push(causal_key(48, 4, 48).key()); // wrong head count: incompatible
    keys.push(StructuredKey { mask_fingerprint: "full".into(), ..causal_key(48, 2, 48) }.key());
    keys.push(target.key()); // the exact key is never its own neighbor
    let want = causal_key(48, 2, 48).key();
    for rotation in 0..keys.len() {
        let mut rotated = keys.clone();
        rotated.rotate_left(rotation);
        let got = nearest_neighbor(&target, rotated.iter().map(|s| s.as_str()))
            .expect("a compatible neighbor exists");
        assert_eq!(got.key(), want, "rotation {rotation}");
    }
}

#[test]
fn neighbor_ties_break_toward_the_smaller_workload() {
    // 56 and 72 are both 8 KV tiles from 64; the documented tie-break
    // (smaller n_kv first) must pick 56 whatever the candidate order.
    let target = causal_key(64, 2, 64);
    let a = causal_key(56, 2, 56).key();
    let b = causal_key(72, 2, 72).key();
    for keys in [[a.clone(), b.clone()], [b.clone(), a.clone()]] {
        let got = nearest_neighbor(&target, keys.iter().map(|s| s.as_str())).unwrap();
        assert_eq!(got.key(), a);
    }
}

#[test]
fn portfolio_is_bitwise_identical_across_thread_counts() {
    // Off the home regime (odd n, machine much narrower than a wave) so
    // every replica genuinely searches instead of certifying its seed.
    let spec = ProblemSpec::square(11, 3, MaskSpec::causal());
    let base = PortfolioOptions {
        replicas: 4,
        budget: 96,
        seed: 11,
        sim: SimConfig::ideal(5),
        batch: 4,
        threads: 1,
    };
    let one = tune_portfolio(&spec, &base).unwrap();
    for threads in [2usize, 8] {
        let t = tune_portfolio(&spec, &PortfolioOptions { threads, ..base }).unwrap();
        assert_eq!(t.winner_index, one.winner_index, "threads={threads}");
        assert_eq!(t.winner.makespan.to_bits(), one.winner.makespan.to_bits());
        assert_eq!(chain_ids(&t.winner.schedule), chain_ids(&one.winner.schedule));
        assert_eq!(t.winner.schedule.reduction_order, one.winner.schedule.reduction_order);
        assert_eq!(t.winner.schedule.pinned, one.winner.schedule.pinned);
        for (ra, rb) in one.replicas.iter().zip(&t.replicas) {
            assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits(), "threads={threads}");
            assert_eq!(
                (ra.evaluated, ra.improvements, ra.uphill, ra.skipped_invalid, ra.skipped_sim),
                (rb.evaluated, rb.improvements, rb.uphill, rb.skipped_invalid, rb.skipped_sim),
                "threads={threads} replica={}",
                ra.index
            );
        }
    }
}

#[test]
fn queue_report_is_independent_of_spec_order() {
    let mk = |n: usize, heads: usize| QueueSpec {
        spec: ProblemSpec::square(n, heads, MaskSpec::causal()),
        n_sm: n,
        budget: Some(24),
    };
    // Includes one exact duplicate (n = 8 twice) to exercise dedup.
    let queue = vec![mk(8, 2), mk(6, 2), mk(10, 3), mk(8, 2)];
    let base = TuneOptions { budget: 24, seed: 5, sim: SimConfig::ideal(8), batch: 4, threads: 1 };
    let forward = run_queue(&queue, &base, 8, &mut ephemeral_cache("fwd")).unwrap();
    let mut reversed_queue = queue.clone();
    reversed_queue.reverse();
    let reversed = run_queue(&reversed_queue, &base, 8, &mut ephemeral_cache("rev")).unwrap();

    assert_eq!(forward.deduped, 1);
    assert_eq!(reversed.deduped, 1);
    assert_eq!(forward.tally(), reversed.tally());
    assert_eq!(forward.outcomes.len(), 3);
    assert_eq!(forward.outcomes.len(), reversed.outcomes.len());
    for (a, b) in forward.outcomes.iter().zip(&reversed.outcomes) {
        assert_eq!(a.key, b.key);
        assert_eq!(a.provenance, b.provenance);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{}", a.key);
        assert_eq!(a.bound.to_bits(), b.bound.to_bits(), "{}", a.key);
        assert_eq!(a.evaluated, b.evaluated, "{}", a.key);
    }
    // Sorted key order is part of the contract the CLI table relies on.
    let keys: Vec<&str> = forward.outcomes.iter().map(|o| o.key.as_str()).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
}

#[test]
fn rerunning_a_drained_queue_is_all_hits() {
    let queue = vec![QueueSpec {
        spec: ProblemSpec::square(8, 2, MaskSpec::causal()),
        n_sm: 8,
        budget: Some(16),
    }];
    let base = TuneOptions { budget: 16, seed: 5, sim: SimConfig::ideal(8), batch: 4, threads: 1 };
    let mut cache = ephemeral_cache("rerun");
    let first = run_queue(&queue, &base, 0, &mut cache).unwrap();
    assert_eq!(first.tally(), (0, 0, 1));
    let second = run_queue(&queue, &base, 0, &mut cache).unwrap();
    assert_eq!(second.tally(), (1, 0, 0));
    assert_eq!(second.outcomes[0].evaluated, 0);
    assert_eq!(
        second.outcomes[0].makespan.to_bits(),
        first.outcomes[0].makespan.to_bits()
    );
}

#[test]
fn warm_and_cold_agree_when_budgets_saturate() {
    // Home regime at n = 64: the cold search certifies the analytic seed
    // at the work bound (65 * 1.25 = 81.25). A warm start from an n = 32
    // donor at a 10x smaller budget must land on the same certified
    // makespan, bit for bit.
    let causal = MaskSpec::causal();
    let spec32 = ProblemSpec::square(32, 2, causal.clone());
    let sim32 = SimConfig::ideal(32);
    let cold_opts = TuneOptions { budget: 400, seed: 42, sim: sim32, batch: 8, threads: 1 };
    let donor = tune(&spec32, &cold_opts).unwrap();
    let mut cache = ephemeral_cache("warmcold");
    cache.put(&WorkloadFingerprint::new(&spec32, &sim32).key(), &donor);

    let spec64 = ProblemSpec::square(64, 2, causal.clone());
    let sim64 = SimConfig::ideal(64);
    let cold64 = tune(&spec64, &TuneOptions { sim: sim64, ..cold_opts }).unwrap();
    assert_eq!(cold64.makespan, 81.25);
    let key64 = WorkloadFingerprint::new(&spec64, &sim64).key();
    let warm64 =
        tune_warm(&spec64, &TuneOptions { budget: 40, sim: sim64, ..cold_opts }, &key64, &cache)
            .unwrap();
    assert_eq!(
        warm64.source.as_deref(),
        Some(WorkloadFingerprint::new(&spec32, &sim32).key().as_str())
    );
    assert_eq!(warm64.result.makespan.to_bits(), cold64.makespan.to_bits());
    assert!(warm64.result.gap() < 1e-9, "warm run must stay certified optimal");

    // The ROADMAP generalization pair: tuned at n = 64, applied at n = 96
    // on the 10x smaller budget — zero gap against the DAG oracle.
    cache.put(&key64, &cold64);
    let spec96 = ProblemSpec::square(96, 2, causal);
    let sim96 = SimConfig::ideal(96);
    let key96 = WorkloadFingerprint::new(&spec96, &sim96).key();
    let warm96 =
        tune_warm(&spec96, &TuneOptions { budget: 40, sim: sim96, ..cold_opts }, &key96, &cache)
            .unwrap();
    assert!(warm96.source.is_some(), "n = 64 entry must be found as a donor");
    assert_eq!(warm96.result.makespan, 121.25);
    assert!(warm96.result.gap() < 1e-9);

    // Off the home regime a warm start is still never worse than the best
    // analytic seed — the tune_seeded construction guarantee.
    let spec10 = ProblemSpec::square(10, 2, MaskSpec::causal());
    let sim10 = SimConfig::ideal(4);
    let key10 = WorkloadFingerprint::new(&spec10, &sim10).key();
    let warm10 =
        tune_warm(&spec10, &TuneOptions { budget: 60, sim: sim10, ..cold_opts }, &key10, &cache)
            .unwrap();
    assert!(warm10.result.makespan <= warm10.result.seed_makespan + 1e-9);
}
