//! Property tests for the cluster-profile layer — the multi-device
//! analogue of `hw_profile_properties.rs`:
//!
//! * JSON round-trip: serialize -> parse -> identical cluster + identical
//!   fingerprint, through both the in-memory codec and the file system.
//! * `--cluster` grammar: every spelling `resolve_cluster` documents
//!   (`<link>:<n>x<gpu>`, `abstract:<n>`, a JSON path) resolves, and
//!   malformed spellings are errors, not fallbacks.
//! * Homogeneity: mixed GPU profiles are rejected at validation *and* at
//!   the JSON boundary unless `allow_mixed` is set explicitly.
//! * Cache keying: cluster identity (device count, link, GPU) re-keys the
//!   autotune fingerprint; the fully-abstract cluster keys to the
//!   historical single-GPU format.

use dash::autotune::WorkloadFingerprint;
use dash::hw::{presets, resolve_cluster, ClusterProfile, GpuProfile, LinkModel};
use dash::schedule::{MaskSpec, ProblemSpec};
use dash::sim::SimConfig;
use dash::util::Json;
use std::path::PathBuf;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dash-clusterprop-{}-{tag}.json", std::process::id()))
}

// ---------------------------------------------------------------- JSON i/o

#[test]
fn json_round_trip_preserves_identity_and_fingerprint() {
    let mut calibrated = presets::h800();
    calibrated.name = "h800-calibrated".into();
    calibrated.clock_ghz = 1.87;
    let clusters = vec![
        ClusterProfile::uniform("nv2", 2, presets::h800(), LinkModel::nvlink()),
        ClusterProfile::uniform("ib4", 4, presets::a100(), LinkModel::infiniband()),
        ClusterProfile::uniform("abs8", 8, presets::abstract_machine(), LinkModel::ideal()),
        ClusterProfile::uniform(
            "custom",
            3,
            calibrated,
            LinkModel { name: "pcie".into(), bandwidth_gbps: 25.0, latency_us: 9.5 },
        ),
    ];
    for c in &clusters {
        let text = c.to_json().dump();
        let back = ClusterProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(&back, c, "{}", c.name);
        assert_eq!(back.fingerprint(), c.fingerprint(), "{}", c.name);
        assert_eq!(
            back.hop_cycles(128, 64).to_bits(),
            c.hop_cycles(128, 64).to_bits(),
            "{}",
            c.name
        );
    }
}

#[test]
fn cluster_file_round_trips_through_resolve() {
    let path = tmp_path("resolve");
    let mut c = ClusterProfile::uniform("nv2-tweaked", 2, presets::h800(), LinkModel::nvlink());
    c.link.bandwidth_gbps = 360.0; // calibrated, non-preset number
    c.save(&path).unwrap();
    let back = resolve_cluster(path.to_str().unwrap()).unwrap();
    assert_eq!(back, c);
    assert_eq!(back.fingerprint(), c.fingerprint());
    let _ = std::fs::remove_file(&path);
}

// ---------------------------------------------------------- --cluster grammar

#[test]
fn preset_grammar_resolves_every_documented_spelling() {
    let nv = resolve_cluster("nvlink:2xh800").unwrap();
    assert_eq!(nv.n_devices(), 2);
    assert_eq!(nv.link, LinkModel::nvlink());
    assert_eq!(nv.devices[0].name, presets::h800().name);

    let ib = resolve_cluster("ib:4xa100").unwrap();
    assert_eq!(ib.n_devices(), 4);
    assert_eq!(ib.link, LinkModel::infiniband());

    let abs = resolve_cluster("abstract:3").unwrap();
    assert_eq!(abs.n_devices(), 3);
    assert!(abs.link.is_ideal());
    assert_eq!(abs.fingerprint(), 0, "abstract cluster is the paper's machine: hash 0");
    assert_eq!(abs.hop_cycles(128, 64), 1.0);
}

#[test]
fn malformed_cluster_specs_are_errors() {
    for bad in [
        "nvlink:h800",      // missing count
        "nvlink:0xh800",    // zero devices
        "abstract:0",       // zero devices
        "nvlink:2xnosuch",  // unknown GPU preset
        "warp:2xh800",      // unknown link, not a file
        "no-such-file.json",
    ] {
        assert!(resolve_cluster(bad).is_err(), "'{bad}' must not resolve");
    }
}

// ------------------------------------------------------------- homogeneity

#[test]
fn mixed_clusters_are_rejected_at_the_json_boundary_without_opt_in() {
    let mut mixed = ClusterProfile::uniform("mix", 2, presets::h800(), LinkModel::nvlink());
    mixed.devices[1] = presets::a100();
    // Emit the document claiming allow_mixed = false: the strict decoder
    // must refuse it even though the struct can be built in memory.
    let text = mixed.to_json().dump();
    let err = ClusterProfile::from_json(&Json::parse(&text).unwrap()).unwrap_err();
    assert!(err.to_string().contains("allow_mixed"), "{err}");

    // The explicit opt-in round-trips, fingerprinting both device kinds.
    mixed.allow_mixed = true;
    let text = mixed.to_json().dump();
    let back = ClusterProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, mixed);
    let uniform = ClusterProfile::uniform("mix", 2, presets::h800(), LinkModel::nvlink());
    assert_ne!(back.fingerprint(), uniform.fingerprint());

    // File loads hit the same wall: a saved mixed cluster without the
    // opt-in cannot come back.
    mixed.allow_mixed = false;
    let path = tmp_path("mixed");
    mixed.save(&path).unwrap();
    assert!(ClusterProfile::load(&path).is_err());
    let _ = std::fs::remove_file(&path);
}

// ----------------------------------------------------- autotune cache safety

fn key_for(spec: &ProblemSpec, devices: usize, cluster: &ClusterProfile) -> String {
    WorkloadFingerprint::new(spec, &SimConfig::ideal(8))
        .with_cluster(devices, cluster.fingerprint())
        .key()
}

#[test]
fn cluster_identity_rekeys_the_autotune_cache() {
    let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
    let nv2 = resolve_cluster("nvlink:2xh800").unwrap();
    let nv4 = resolve_cluster("nvlink:4xh800").unwrap();
    let ib2 = resolve_cluster("ib:2xh800").unwrap();
    let a100 = resolve_cluster("nvlink:2xa100").unwrap();

    let base = key_for(&spec, 2, &nv2);
    assert_ne!(base, key_for(&spec, 4, &nv4), "device count must re-key");
    assert_ne!(base, key_for(&spec, 2, &ib2), "interconnect must re-key");
    assert_ne!(base, key_for(&spec, 2, &a100), "GPU part must re-key");

    // The fully-abstract cluster at one device is the single-GPU problem:
    // byte-identical to the historical key.
    let abs1 = resolve_cluster("abstract:1").unwrap();
    let single = WorkloadFingerprint::new(&spec, &SimConfig::ideal(8)).key();
    assert_eq!(key_for(&spec, 1, &abs1), single);
}

// ------------------------------------------------------------ hop-cost model

#[test]
fn hop_costs_order_like_the_physical_links() {
    let ideal = resolve_cluster("abstract:2").unwrap();
    let nv = resolve_cluster("nvlink:2xh800").unwrap();
    let ib = resolve_cluster("ib:2xh800").unwrap();
    let hop_nv = nv.hop_cycles(128, 64);
    let hop_ib = ib.hop_cycles(128, 64);
    assert_eq!(ideal.hop_cycles(128, 64), 1.0);
    assert!(hop_nv > 1.0, "a physical link costs more than the unit hop");
    assert!(hop_ib > hop_nv, "IB ({hop_ib}) must cost more than NVLink ({hop_nv})");
    // Payload scaling: bigger tiles serialize longer on the same link.
    assert!(nv.hop_cycles(256, 64) > hop_nv);
    assert!(nv.hop_cycles(128, 128) > hop_nv);
    // Latency dominates small transfers: quadrupling the payload on IB
    // must not quadruple the hop (it is not bandwidth-bound at this size).
    assert!(ib.hop_cycles(512, 64) < 4.0 * hop_ib);
}

// ---------------------------------------------------------------- validation

#[test]
fn validate_rejects_degenerate_clusters() {
    let empty = ClusterProfile {
        name: "empty".into(),
        devices: Vec::<GpuProfile>::new(),
        link: LinkModel::ideal(),
        allow_mixed: false,
    };
    assert!(empty.validate().is_err());

    let mut half = LinkModel::nvlink();
    half.bandwidth_gbps = 0.0; // half-written sentinel
    let c = ClusterProfile::uniform("half", 2, presets::h800(), half);
    assert!(c.validate().is_err());

    let mut nan = LinkModel::nvlink();
    nan.latency_us = f64::NAN;
    let c = ClusterProfile::uniform("nan", 2, presets::h800(), nan);
    assert!(c.validate().is_err());
}
