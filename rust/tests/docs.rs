//! Documentation drift tests: `docs/CLI.md` must embed every subcommand's
//! live `--help` output verbatim, the binary must actually print those
//! texts, and no doc may reference a repo path that no longer exists.
//!
//! Regenerate the CLI reference after changing `rust/src/cli.rs` with:
//!
//! ```sh
//! DASH_REGEN_DOCS=1 cargo test --test docs
//! ```

use dash::cli;
use std::path::{Path, PathBuf};
use std::process::Command;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("rust/ has a parent").to_path_buf()
}

/// The canonical rendering of docs/CLI.md from the help constants.
fn render_cli_md() -> String {
    let mut out = String::from(
        "# `dash` CLI reference\n\
         \n\
         This file is generated-and-verified: `rust/tests/docs.rs` asserts that it\n\
         embeds the binary's live `--help` output verbatim (regenerate with\n\
         `DASH_REGEN_DOCS=1 cargo test --test docs`). Edit `rust/src/cli.rs`, not\n\
         this file.\n\
         \n\
         Layer-by-layer background lives in [ARCHITECTURE.md](ARCHITECTURE.md).\n\
         \n\
         ## Global usage\n\
         \n",
    );
    out.push_str("```text\n");
    out.push_str(cli::USAGE);
    out.push_str("\n```\n");
    for (name, help) in cli::COMMANDS {
        out.push_str(&format!("\n## `dash {name}`\n\n```text\n{help}\n```\n"));
    }
    out
}

#[test]
fn cli_md_embeds_every_help_text_verbatim() {
    let path = repo_root().join("docs/CLI.md");
    let rendered = render_cli_md();
    if std::env::var("DASH_REGEN_DOCS").is_ok() {
        std::fs::write(&path, &rendered).expect("write docs/CLI.md");
    }
    let doc = std::fs::read_to_string(&path).expect("docs/CLI.md exists");
    assert!(
        doc.contains(cli::USAGE),
        "docs/CLI.md drifted from the global usage text — \
         run DASH_REGEN_DOCS=1 cargo test --test docs"
    );
    for (name, help) in cli::COMMANDS {
        assert!(
            doc.contains(help),
            "docs/CLI.md drifted from `dash {name} --help` — \
             run DASH_REGEN_DOCS=1 cargo test --test docs"
        );
        assert!(
            doc.contains(&format!("## `dash {name}`")),
            "docs/CLI.md is missing the `dash {name}` section header"
        );
    }
}

#[test]
fn live_help_output_matches_the_constants() {
    let bin = env!("CARGO_BIN_EXE_dash");
    for (name, help) in cli::COMMANDS {
        let out = Command::new(bin).args([*name, "--help"]).output().expect("run dash");
        assert!(out.status.success(), "`dash {name} --help` failed: {out:?}");
        let stdout = String::from_utf8(out.stdout).expect("utf8 help");
        assert_eq!(
            stdout.trim_end(),
            help.trim_end(),
            "`dash {name} --help` drifted from cli::COMMANDS"
        );
    }
    let out = Command::new(bin).arg("help").output().expect("run dash help");
    assert!(out.status.success());
    let stdout = String::from_utf8(out.stdout).expect("utf8 usage");
    assert_eq!(stdout.trim_end(), cli::USAGE.trim_end(), "`dash help` drifted");
}

/// Repo-relative path-like tokens (`rust/...`, `python/...`, `docs/...`,
/// `examples/...`, `.github/...`) found in a document.
fn path_like_tokens(text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut flush = |cur: &mut String| {
        if !cur.is_empty() {
            let tok = cur.trim_end_matches(|c| c == '.' || c == '/');
            for root in ["rust/", "python/", "docs/", "examples/", ".github/"] {
                if tok.starts_with(root) {
                    out.push(tok.to_string());
                    break;
                }
            }
            cur.clear();
        }
    };
    for ch in text.chars() {
        if ch.is_ascii_alphanumeric() || matches!(ch, '_' | '.' | '/' | '-') {
            cur.push(ch);
        } else {
            flush(&mut cur);
        }
    }
    flush(&mut cur);
    out
}

#[test]
fn docs_reference_only_paths_that_exist() {
    let root = repo_root();
    let mut checked = 0usize;
    for doc in ["README.md", "docs/ARCHITECTURE.md", "docs/CLI.md"] {
        let text = std::fs::read_to_string(root.join(doc))
            .unwrap_or_else(|_| panic!("{doc} must exist"));
        for token in path_like_tokens(&text) {
            checked += 1;
            assert!(
                root.join(&token).exists(),
                "{doc} references '{token}', which does not exist in the tree"
            );
        }
    }
    assert!(checked >= 10, "stale-reference scanner found implausibly few paths ({checked})");
}

#[test]
fn path_scanner_finds_and_trims_tokens() {
    let toks =
        path_like_tokens("see `rust/src/cli.rs`, and docs/CLI.md. Not my_gpu.json or docs/*.md");
    assert_eq!(toks, vec!["rust/src/cli.rs".to_string(), "docs/CLI.md".to_string()]);
}
