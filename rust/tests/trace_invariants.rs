//! Invariants of the trace layer (`dash::trace`), enforced end-to-end:
//!
//! * per-lane events are sorted and never overlap, for sim and exec traces
//!   of every deterministic generator;
//! * on the paper's ideal machine every lane tiles gaplessly from t = 0,
//!   so the flamegraph's `attributed + idle == makespan * lanes` identity
//!   holds exactly;
//! * trace content hashes are bitwise-stable across repeated runs;
//! * sim and exec traces of the same schedule agree on the per-(head, q)
//!   dQ fold order, and both match the schedule's declared reduction order;
//! * the timeline HTML emitted by the binary is self-contained (no network
//!   references), single-trace and diff alike;
//! * `dash baseline check` passes against the committed CI snapshot and
//!   exits nonzero on an injected regression.

use dash::exec::ExecConfig;
use dash::schedule::fa3::fa3_atomic;
use dash::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass, MaskSpec, ProblemSpec,
    Schedule,
};
use dash::sim::SimConfig;
use dash::trace::baseline::{compare, run_suite, BaselineSnapshot};
use dash::trace::flamegraph::attribute;
use dash::trace::{reduce_order_by_task, trace_execution, trace_simulation, SimTrace, TraceKind};
use std::path::{Path, PathBuf};
use std::process::Command;

const EPS: f64 = 1e-6;

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("rust/ has a parent").to_path_buf()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("dash_trace_inv_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).expect("create temp dir");
    d
}

/// All seven deterministic generators that exist for `spec` (shift needs
/// uniform full-row chains, so it drops out on structured masks).
fn generators(spec: &ProblemSpec, n_sm: usize) -> Vec<Schedule> {
    let mut out = vec![
        fa3(spec, true),
        fa3_atomic(spec),
        descending(spec),
        symmetric_shift(spec),
        two_pass(spec),
        lpt_schedule(spec, n_sm),
    ];
    if let Ok(s) = shift(spec) {
        out.push(s);
    }
    out
}

fn assert_lanes_sorted_and_disjoint(tr: &SimTrace, what: &str) {
    for w in tr.events.windows(2) {
        let (p, e) = (&w[0], &w[1]);
        assert!(
            p.sm < e.sm || (p.sm == e.sm && p.t_start <= e.t_start + EPS),
            "{what}: events out of (sm, t_start) order"
        );
        if p.sm == e.sm {
            assert!(
                e.t_start >= p.t_end - EPS,
                "{what}: overlap on lane {}: [{}, {}] then [{}, {}]",
                p.sm,
                p.t_start,
                p.t_end,
                e.t_start,
                e.t_end
            );
        }
    }
}

#[test]
fn per_lane_events_are_sorted_and_non_overlapping() {
    let spec = ProblemSpec::square(8, 2, MaskSpec::full());
    for s in generators(&spec, 8) {
        let sim = trace_simulation(&s, &SimConfig::ideal(8)).expect("simulate");
        assert_lanes_sorted_and_disjoint(&sim, &format!("sim/{}", s.kind.name()));
        let exec = trace_execution(&s, &ExecConfig { n_sm: 8, ..ExecConfig::new(1) });
        assert_lanes_sorted_and_disjoint(&exec, &format!("exec/{}", s.kind.name()));
    }
    let causal = ProblemSpec::square(8, 2, MaskSpec::causal());
    for s in generators(&causal, 8) {
        let sim = trace_simulation(&s, &SimConfig::ideal(8)).expect("simulate");
        assert_lanes_sorted_and_disjoint(&sim, &format!("sim-causal/{}", s.kind.name()));
    }
}

#[test]
fn ideal_lanes_tile_gaplessly_and_attribution_covers_the_budget() {
    for mask in [MaskSpec::full(), MaskSpec::causal()] {
        let spec = ProblemSpec::square(8, 2, mask);
        for s in generators(&spec, 8) {
            let what = format!("{}/{}", s.kind.name(), s.spec.mask.name());
            let tr = trace_simulation(&s, &SimConfig::ideal(8)).expect("simulate");
            // Per lane: the first event starts at t = 0 and every event
            // abuts the next — on the synchronous abstract machine an SM
            // is never idle mid-timeline, only after its last task.
            for sm in 0..tr.n_lanes {
                let mut cursor = 0.0f64;
                for e in tr.events.iter().filter(|e| e.sm == sm) {
                    assert!(
                        (e.t_start - cursor).abs() < EPS,
                        "{what}: gap on lane {sm} at t={cursor} (next event starts {})",
                        e.t_start
                    );
                    cursor = e.t_end;
                }
            }
            // The same fact through the flamegraph: per-chain buckets plus
            // end-of-lane idle account for 100% of makespan x lanes.
            let r = attribute(&tr);
            assert!(r.budget() > 0.0, "{what}: empty budget");
            assert!(
                (r.attributed() + r.idle - r.budget()).abs() < EPS,
                "{what}: attributed {} + idle {} != budget {}",
                r.attributed(),
                r.idle,
                r.budget()
            );
        }
    }
}

#[test]
fn trace_hashes_are_bitwise_stable_across_runs() {
    let spec = ProblemSpec::square(8, 2, MaskSpec::full());
    let again = ProblemSpec::square(8, 2, MaskSpec::full());
    let (first, second) = (generators(&spec, 8), generators(&again, 8));
    assert_eq!(first.len(), 7, "all seven generators exist on the full mask");
    for (a, b) in first.iter().zip(&second) {
        let cfg = SimConfig::ideal(8);
        let (sa, sb) =
            (trace_simulation(a, &cfg).unwrap(), trace_simulation(b, &cfg).unwrap());
        assert_eq!(
            sa.content_hash(),
            sb.content_hash(),
            "sim trace hash unstable for {}",
            a.kind.name()
        );
        let ecfg = ExecConfig { n_sm: 8, ..ExecConfig::new(7) };
        let (ea, eb) = (trace_execution(a, &ecfg), trace_execution(b, &ecfg));
        assert_eq!(
            ea.content_hash(),
            eb.content_hash(),
            "exec trace hash unstable for {}",
            a.kind.name()
        );
        assert_ne!(
            sa.content_hash(),
            ea.content_hash(),
            "sim and exec traces of {} must hash apart (different sources)",
            a.kind.name()
        );
    }
}

#[test]
fn sim_and_exec_traces_agree_on_fold_order() {
    let spec = ProblemSpec::square(6, 2, MaskSpec::full());
    // The fused, order-carrying generators: every chain emits ordered dQ
    // partials, so both engines must fold each (head, q) accumulator in
    // the schedule's declared reduction order.
    let fused: Vec<Schedule> = vec![
        fa3(&spec, true),
        descending(&spec),
        shift(&spec).expect("shift exists for full mask"),
        symmetric_shift(&spec),
        lpt_schedule(&spec, 6),
    ];
    for s in fused {
        let sim = trace_simulation(&s, &SimConfig::ideal(6)).expect("simulate");
        let exec = trace_execution(&s, &ExecConfig { n_sm: 6, ..ExecConfig::new(1) });
        let (so, eo) = (reduce_order_by_task(&sim), reduce_order_by_task(&exec));
        assert_eq!(so, eo, "sim vs exec fold order for {}", s.kind.name());
        for ((head, q), kvs) in &so {
            assert_eq!(
                kvs.as_slice(),
                s.reduction_order_of(*head, *q),
                "{}: fold order for ({head}, {q}) drifted from the schedule",
                s.kind.name()
            );
        }
        let n_folds: usize = so.iter().map(|(_, kvs)| kvs.len()).sum();
        assert_eq!(n_folds, s.total_tasks(), "{}: every task folds once", s.kind.name());
    }
}

#[test]
fn exec_trace_covers_every_task() {
    let spec = ProblemSpec::square(6, 2, MaskSpec::full());
    for s in generators(&spec, 6) {
        let tr = trace_execution(&s, &ExecConfig { n_sm: 6, ..ExecConfig::new(1) });
        let n_compute = tr.events.iter().filter(|e| e.kind == TraceKind::Compute).count();
        assert_eq!(n_compute, s.total_tasks(), "{}: one compute event per task", s.kind.name());
    }
}

#[test]
fn timeline_binary_output_is_self_contained() {
    let bin = env!("CARGO_BIN_EXE_dash");
    let dir = tmp_dir("timeline");
    let single = dir.join("single.html");
    let out = Command::new(bin)
        .args(["timeline", "--schedule", "fa3-det", "--n", "6", "--out"])
        .arg(&single)
        .output()
        .expect("run dash timeline");
    assert!(out.status.success(), "dash timeline failed: {out:?}");
    let diff = dir.join("diff.html");
    let out = Command::new(bin)
        .args(["timeline", "--schedule", "shift", "--diff", "fa3-det", "--n", "6"])
        .args(["--mask", "full", "--out"])
        .arg(&diff)
        .output()
        .expect("run dash timeline --diff");
    assert!(out.status.success(), "dash timeline --diff failed: {out:?}");
    for path in [&single, &diff] {
        let html = std::fs::read_to_string(path).expect("timeline html");
        assert!(html.contains("<!DOCTYPE html>"));
        assert!(
            !html.to_lowercase().contains("http"),
            "{} references the network",
            path.display()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flamegraph_binary_reports_the_determinism_cost() {
    let bin = env!("CARGO_BIN_EXE_dash");
    let out = Command::new(bin)
        .args(["flamegraph", "--schedule", "fa3-det", "--n", "6"])
        .output()
        .expect("run dash flamegraph");
    assert!(out.status.success(), "dash flamegraph failed: {out:?}");
    let text = String::from_utf8(out.stdout).expect("utf8");
    assert!(text.contains("attributed") && text.contains("determinism cost"));
    let out = Command::new(bin)
        .args(["flamegraph", "--schedule", "fa3-det", "--n", "6", "--folded"])
        .output()
        .expect("run dash flamegraph --folded");
    assert!(out.status.success());
    let folded = String::from_utf8(out.stdout).expect("utf8");
    assert!(folded.lines().all(|l| l.starts_with("dash;")), "folded stacks format");
}

#[test]
fn committed_ci_snapshot_matches_a_fresh_smoke_run() {
    let path = repo_root().join("BENCH_ci_smoke.json");
    let committed = BaselineSnapshot::load(&path).expect("committed BENCH_ci_smoke.json parses");
    assert_eq!(committed.suite, "smoke");
    assert_eq!(committed.points.len(), 4);
    let fresh = run_suite("smoke").expect("smoke suite runs");
    // Zero tolerance: every smoke value is a closed form the engine tests
    // pin, so the committed snapshot must match bit-for-bit.
    let report = compare(&committed, &fresh, 0.0);
    assert!(report.passed(), "committed snapshot drifted: {report:?}");
    let reverse = compare(&fresh, &committed, 0.0);
    assert!(reverse.passed(), "fresh run has points the snapshot lacks: {reverse:?}");
}

#[test]
fn baseline_check_gates_an_injected_regression() {
    let bin = env!("CARGO_BIN_EXE_dash");
    let dir = tmp_dir("baseline");

    // A clean save/check round trip passes.
    let out = Command::new(bin)
        .args(["baseline", "save", "--suite", "smoke", "--dir"])
        .arg(&dir)
        .output()
        .expect("run dash baseline save");
    assert!(out.status.success(), "dash baseline save failed: {out:?}");
    let out = Command::new(bin)
        .args(["baseline", "check", "--name", "smoke", "--dir"])
        .arg(&dir)
        .output()
        .expect("run dash baseline check");
    assert!(out.status.success(), "clean baseline check failed: {out:?}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("PASS"));

    // Tamper: claim the makespan used to be lower than the engine can
    // deliver — the fresh re-run must read as a regression and exit 1.
    let mut tampered = run_suite("smoke").expect("smoke suite runs");
    tampered.name = "tampered".to_string();
    for m in &mut tampered.points[0].metrics {
        if m.0 == "makespan" {
            m.1 *= 0.9;
        }
    }
    tampered.save(&dir).expect("save tampered snapshot");
    let out = Command::new(bin)
        .args(["baseline", "check", "--name", "tampered", "--dir"])
        .arg(&dir)
        .output()
        .expect("run dash baseline check (tampered)");
    assert!(!out.status.success(), "tampered baseline check must exit nonzero");
    assert!(String::from_utf8_lossy(&out.stdout).contains("REGRESSION"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn baseline_list_finds_saved_snapshots() {
    let bin = env!("CARGO_BIN_EXE_dash");
    let dir = tmp_dir("list");
    let snap = run_suite("smoke").expect("smoke suite runs");
    snap.save(&dir).expect("save snapshot");
    let out = Command::new(bin)
        .args(["baseline", "list", "--dir"])
        .arg(&dir)
        .output()
        .expect("run dash baseline list");
    assert!(out.status.success(), "dash baseline list failed: {out:?}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("BENCH_smoke.json"));
    let _ = std::fs::remove_dir_all(&dir);
}
