//! End-to-end determinism-oracle tests: every deterministic generator —
//! all seven, including a search-synthesized tuned schedule — must
//! produce bitwise-identical gradient hashes across repeated runs,
//! machine widths, and completion shuffles, for every mask shape it
//! supports, in both f32 and bf16; the atomic baseline and the injected
//! run must be flagged; and the executed FLOPs must match the
//! `attention::flops` analytics exactly.

use dash::attention::flops::{
    attention_bwd_flops, bwd_tile_flops, BWD_FUSED_GEMMS, BWD_TWO_PASS_GEMMS,
};
use dash::autotune::{tune, TuneOptions};
use dash::coordinator::ReproManifest;
use dash::exec::{
    execute_backward, expected_flops, reference_backward, verify_batch_invariance,
    verify_device_counts, verify_schedule, ExecConfig, OracleOptions,
};
use dash::mask::MaskSpec;
use dash::numerics::Precision;
use dash::schedule::{
    cluster_schedule, descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass,
    ClusterStrategy, ProblemSpec, Schedule, ScheduleKind,
};
use dash::sim::SimConfig;
use dash::traceload::{generate, TraceSpec};

/// The mask sweep: four shapes (the acceptance floor) plus rectangular
/// variants where the generator family supports them.
fn masks(n: usize) -> Vec<MaskSpec> {
    vec![
        MaskSpec::full(),
        MaskSpec::causal(),
        MaskSpec::sliding_window(2),
        MaskSpec::document(vec![n.div_ceil(2)]),
    ]
}

/// Every deterministic generator applicable to `spec` — seven kinds, with
/// Shift contributing only where its structure exists and Tuned
/// synthesized by a small hermetic search (no disk cache involved).
fn deterministic_schedules(spec: &ProblemSpec) -> Vec<Schedule> {
    let mut out = vec![
        fa3(spec, true),
        descending(spec),
        symmetric_shift(spec),
        two_pass(spec),
        lpt_schedule(spec, spec.n_kv),
    ];
    if let Ok(s) = shift(spec) {
        out.push(s);
    }
    let sim = SimConfig::ideal(spec.n_kv);
    let tuned = tune(spec, &TuneOptions { budget: 24, seed: 7, sim, batch: 1, threads: 1 })
        .expect("tuning always has a feasible FA3 seed");
    out.push(tuned.schedule);
    out
}

#[test]
fn seven_generators_cover_the_kind_space() {
    let spec = ProblemSpec::square(4, 2, MaskSpec::full());
    let kinds: std::collections::HashSet<ScheduleKind> =
        deterministic_schedules(&spec).iter().map(|s| s.kind).collect();
    assert_eq!(kinds.len(), 7, "{kinds:?}");
    assert!(kinds.iter().all(|k| k.deterministic()));
}

#[test]
fn all_deterministic_generators_are_bitwise_stable_across_the_matrix() {
    let n = 6;
    for mask in masks(n) {
        let spec = ProblemSpec::square(n, 2, mask);
        for s in deterministic_schedules(&spec) {
            for precision in [Precision::F32, Precision::Bf16] {
                let o = OracleOptions {
                    runs: 3,
                    sm_counts: vec![3, 6, 13],
                    precision,
                    ..OracleOptions::quick(42)
                };
                let v = verify_schedule(&s, &o).expect("legal schedule executes");
                assert!(
                    v.deterministic(),
                    "{:?} on {} in {:?}: {} hashes over {} executions",
                    s.kind,
                    spec.mask.name(),
                    precision,
                    v.distinct_hashes,
                    v.executions
                );
                assert_eq!(v.max_abs_dev, 0.0, "{:?} deviated", s.kind);
                assert!(
                    v.flops_ok(),
                    "{:?} flops {} != {}",
                    s.kind,
                    v.executed_flops,
                    v.expected_flops
                );
            }
        }
    }
}

#[test]
fn rectangular_grids_verify_too() {
    // Decode-style wide-KV grid and its transpose, causal + full.
    for (n_kv, n_q) in [(8usize, 4usize), (4, 8)] {
        for mask in [MaskSpec::full(), MaskSpec::causal()] {
            let spec = ProblemSpec { n_kv, n_q, n_heads: 2, mask };
            for s in [fa3(&spec, true), descending(&spec), two_pass(&spec)] {
                let v = verify_schedule(&s, &OracleOptions::quick(5)).unwrap();
                assert!(v.deterministic(), "{:?} {}x{}", s.kind, n_kv, n_q);
                assert!(v.flops_ok());
            }
        }
    }
}

#[test]
fn atomic_and_injected_runs_are_flagged_in_bf16() {
    let spec = ProblemSpec::square(6, 8, MaskSpec::causal());
    let bf16 = OracleOptions {
        runs: 3,
        precision: Precision::Bf16,
        ..OracleOptions::quick(42)
    };
    // fa3-atomic: genuinely nondeterministic accumulation.
    let atomic = verify_schedule(&fa3(&spec, false), &bf16).unwrap();
    assert!(!atomic.deterministic(), "{atomic:?}");
    assert!(atomic.max_abs_dev > 0.0);
    assert!(atomic.flops_ok(), "nondeterminism must not change the work");
    // Injection: the same deterministic fa3 schedule, arrival-order fold.
    let injected = OracleOptions { inject_atomic: true, ..bf16 };
    let v = verify_schedule(&fa3(&spec, true), &injected).unwrap();
    assert!(!v.deterministic(), "oracle must catch the injected order: {v:?}");
}

#[test]
fn cluster_schedules_are_bitwise_stable_across_device_counts() {
    // The acceptance matrix: ring and zigzag sharding, each over
    // {1, 2, 4} devices x 2 runs x 3 machine widths, in f32 and bf16 —
    // ONE gradient hash per (strategy, intra, mask, precision) cell.
    let n = 8;
    let sweeps = [
        (ClusterStrategy::Ring, ScheduleKind::Shift, MaskSpec::full()),
        (ClusterStrategy::Ring, ScheduleKind::Descending, MaskSpec::causal()),
        (ClusterStrategy::Zigzag, ScheduleKind::Descending, MaskSpec::causal()),
        (ClusterStrategy::Zigzag, ScheduleKind::Fa3, MaskSpec::sliding_window(2)),
    ];
    for (strategy, intra, mask) in sweeps {
        let spec = ProblemSpec::square(n, 2, mask);
        for precision in [Precision::F32, Precision::Bf16] {
            let o = OracleOptions {
                runs: 2,
                sm_counts: vec![3, n, 2 * n + 1],
                precision,
                ..OracleOptions::quick(42)
            };
            let v = verify_device_counts(&spec, strategy, intra, &[1, 2, 4], &o)
                .expect("cluster sweep executes");
            assert!(
                v.deterministic(),
                "{strategy:?}-{intra:?} on {} in {precision:?}: {} hashes over {} executions",
                spec.mask.name(),
                v.distinct_hashes,
                v.executions
            );
            assert_eq!(v.max_abs_dev, 0.0, "{strategy:?}-{intra:?} deviated");
            assert!(v.flops_ok(), "{strategy:?}-{intra:?} flops drifted");
        }
    }
}

#[test]
fn sharded_execution_reproduces_the_unsharded_gradient_bits() {
    // Stronger than device-count stability: the 4-device sharded backward
    // pass lands on the SAME bits as the plain single-GPU schedule it was
    // built from — sharding decides placement, never arithmetic.
    let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
    let cfg = ExecConfig { perturb: 9, ..ExecConfig::new(5) };
    let plain = execute_backward(&descending(&spec), &cfg).unwrap();
    for strategy in [ClusterStrategy::Ring, ClusterStrategy::Zigzag] {
        for d in [1usize, 2, 4] {
            let s = cluster_schedule(&spec, strategy, ScheduleKind::Descending, d).unwrap();
            let r = execute_backward(&s, &cfg).unwrap();
            assert_eq!(
                r.grad_hash, plain.grad_hash,
                "{strategy:?} at {d} devices diverged from the unsharded bits"
            );
        }
    }
}

#[test]
fn injected_unordered_cross_device_fold_is_caught() {
    // The multi-GPU negative control: folding per-device partials in a
    // seeded arrival-style order instead of the fixed tree must scatter
    // the hash set — and the oracle must see it in both precisions.
    let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
    for precision in [Precision::F32, Precision::Bf16] {
        let o = OracleOptions {
            runs: 3,
            precision,
            inject_xdev: true,
            ..OracleOptions::quick(42)
        };
        let v = verify_device_counts(
            &spec,
            ClusterStrategy::Ring,
            ScheduleKind::Descending,
            &[2, 4],
            &o,
        )
        .unwrap();
        assert!(
            !v.deterministic(),
            "oracle must catch the injected cross-device fold in {precision:?}: {v:?}"
        );
        assert!(v.flops_ok(), "reordering must not change the work");
        // Single-device schedules have no cross-device fold to scramble:
        // the same injection flag is inert at D = 1.
        let single =
            verify_device_counts(&spec, ClusterStrategy::Ring, ScheduleKind::Descending, &[1], &o)
                .unwrap();
        assert!(single.deterministic(), "inject-xdev must be a no-op at one device");
    }
}

#[test]
fn batch_invariance_holds_for_every_generator_and_precision() {
    // The serving acceptance matrix: every deterministic generator, both
    // precisions, batch sizes {1, 2, 4} x 3 admission orders (order 0 =
    // FIFO, the rest seeded shuffles) — ONE gradient hash per request
    // across the whole matrix, with machine width and completion jitter
    // varied per step along the way.
    let trace = generate(&TraceSpec::smoke(42)).unwrap();
    for kind in [
        ScheduleKind::Fa3,
        ScheduleKind::Descending,
        ScheduleKind::Shift,
        ScheduleKind::SymmetricShift,
        ScheduleKind::TwoPass,
        ScheduleKind::Lpt,
        ScheduleKind::Tuned,
    ] {
        for precision in [Precision::F32, Precision::Bf16] {
            let o = OracleOptions { precision, ..OracleOptions::quick(42) };
            let v = verify_batch_invariance(&trace, kind, &[1, 2, 4], 3, 2, &o)
                .expect("serving matrix executes");
            assert!(
                v.invariant(),
                "{kind:?} in {precision:?}: {} request hashes over {} requests ({} cells)",
                v.distinct_hashes(),
                v.requests,
                v.cells
            );
            assert_eq!(v.cells, 9, "3 batch sizes x 3 orders");
            assert_eq!(v.requests, trace.requests.len());
            assert!(v.flops_ok(), "{kind:?} flops drifted");
        }
    }
}

#[test]
fn injected_batch_layout_fold_is_caught_and_inert_at_batch_one() {
    // The serving negative control, end to end: leaking the batch layout
    // into the dQ fold order must break per-request invariance wherever
    // steps hold several documents — and must be provably inert at batch
    // size 1, where every step is a single document.
    let trace = generate(&TraceSpec::smoke(42)).unwrap();
    for precision in [Precision::F32, Precision::Bf16] {
        let o = OracleOptions { precision, inject_batch: true, ..OracleOptions::quick(42) };
        let v = verify_batch_invariance(&trace, ScheduleKind::Fa3, &[2, 4], 2, 2, &o).unwrap();
        assert!(
            !v.invariant(),
            "oracle must catch the injected batch-layout fold in {precision:?}: {v:?}"
        );
        assert!(v.flops_ok(), "reordering must not change the work");
        let single =
            verify_batch_invariance(&trace, ScheduleKind::Fa3, &[1], 3, 2, &o).unwrap();
        assert!(single.invariant(), "inject-batch must be a no-op at batch 1");
    }
}

#[test]
fn executed_flops_match_attention_analytics_exactly() {
    let n = 4;
    let heads = 3;
    let (block, head_dim) = (4usize, 8usize);
    // Full mask: the executor's count equals the paper's closed form
    // exactly (seqlen = n * block, batch 1).
    let spec = ProblemSpec::square(n, heads, MaskSpec::full());
    let s = fa3(&spec, true);
    let r = execute_backward(&s, &ExecConfig::new(1)).unwrap();
    assert_eq!(r.flops, expected_flops(&s, block, head_dim));
    assert_eq!(r.flops, spec.total_tiles() as f64 * bwd_tile_flops(block, head_dim));
    assert_eq!(r.flops, attention_bwd_flops(1, heads, n * block, head_dim, false));
    // Two-pass pays exactly the 7/5 recompute ratio.
    let tp = two_pass(&spec);
    let r2 = execute_backward(&tp, &ExecConfig::new(1)).unwrap();
    assert_eq!(r2.flops, r.flops * BWD_TWO_PASS_GEMMS as f64 / BWD_FUSED_GEMMS as f64);
    assert_eq!(r2.tiles_executed, 2 * r.tiles_executed);
}

#[test]
fn deterministic_schedules_agree_with_the_dense_reference() {
    let spec = ProblemSpec::square(5, 2, MaskSpec::causal());
    let cfg = ExecConfig::new(9);
    let truth = reference_backward(&spec, &cfg);
    for s in deterministic_schedules(&spec) {
        let r = execute_backward(&s, &cfg).unwrap();
        let dev = r
            .dq
            .iter()
            .zip(&truth.dq)
            .map(|(&a, &b)| (f64::from(a) - b).abs())
            .fold(0.0, f64::max);
        assert!(dev < 1e-3, "{:?}: dq deviates from dense reference by {dev}", s.kind);
    }
}

#[test]
fn different_generators_may_differ_in_bits_but_each_is_reproducible() {
    // Determinism fixes *an* order per schedule, not "the" value: the
    // per-generator hashes are each perfectly stable, while the set of
    // hashes across generators typically has more than one member.
    let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
    let mut hashes = Vec::new();
    for s in [fa3(&spec, true), descending(&spec), symmetric_shift(&spec)] {
        let a = execute_backward(&s, &ExecConfig::new(3)).unwrap();
        let b = execute_backward(&s, &ExecConfig::new(3)).unwrap();
        assert_eq!(a.grad_hash, b.grad_hash, "{:?} not reproducible", s.kind);
        hashes.push(a.grad_hash);
    }
    hashes.sort_unstable();
    hashes.dedup();
    assert!(hashes.len() > 1, "distinct reduction orders should yield distinct bits");
}

#[test]
fn manifest_round_trip_attests_numeric_state() {
    let spec = ProblemSpec::square(4, 2, MaskSpec::causal());
    let s = fa3(&spec, true);
    let cfg = ExecConfig { precision: Precision::Bf16, ..ExecConfig::new(13) };
    let r = execute_backward(&s, &cfg).unwrap();
    let m = ReproManifest::from_exec(s.kind.name(), &spec.mask.name(), &spec, &cfg, &r);

    let path =
        std::env::temp_dir().join(format!("dash-oracle-manifest-{}.json", std::process::id()));
    let path_s = path.to_str().unwrap().to_string();
    m.save(&path_s).unwrap();
    let loaded = ReproManifest::load(&path_s).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, m);

    // Rebuild the workload purely from the manifest and re-attest.
    let mask = MaskSpec::parse(&loaded.mask).unwrap();
    let spec2 = ProblemSpec {
        n_kv: loaded.n_kv,
        n_q: loaded.n_q,
        n_heads: loaded.n_heads,
        mask,
    };
    let kind = ScheduleKind::parse(&loaded.schedule).unwrap();
    assert_eq!(kind, ScheduleKind::Fa3);
    let cfg2 = ExecConfig {
        block: loaded.block,
        head_dim: loaded.head_dim,
        seed: loaded.seed,
        precision: loaded.precision,
        n_sm: 9, // a different machine must not matter
        perturb: 77,
        inject_atomic: false,
        inject_xdev: false,
        inject_batch: false,
    };
    let again = execute_backward(&fa3(&spec2, true), &cfg2).unwrap();
    assert!(loaded.attests(&again), "manifest round-trip must attest the same bits");
}
