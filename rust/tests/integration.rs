//! Cross-module integration tests: schedules -> DAG -> simulator agreement,
//! figure-harness sanity, coordinator plumbing without artifacts.

use dash::dag::{build_schedule_dag, DagBuildOptions};
use dash::schedule::{
    descending, fa3, shift, symmetric_shift, two_pass, validate, MaskSpec, ProblemSpec,
    ScheduleKind,
};
use dash::sim::{simulate, CostModel, L2Model, SimConfig};

/// Engine and DAG longest-path must agree for fully pinned schedules
/// (static placement): both compute ASAP schedules over the same graph.
#[test]
fn engine_matches_dag_critical_path_for_pinned_schedules() {
    for n in [4usize, 8] {
        for m in [1usize, 2, 4] {
            let shift_s = shift(&ProblemSpec::square(n, m, MaskSpec::full())).unwrap();
            let sym_s = symmetric_shift(&ProblemSpec::square(n, m, MaskSpec::causal()));
            for s in [&shift_s, &sym_s] {
                let opts = DagBuildOptions {
                    compute_cost: 1.0,
                    reduce_cost: 0.25,
                    dependency_latency: 0.0,
                };
                let dag = build_schedule_dag(s, n, opts);
                let sim = simulate(s, &SimConfig::ideal(n)).unwrap();
                assert!(
                    (dag.makespan() - sim.makespan).abs() < 1e-9,
                    "{:?} n={n} m={m}: dag {} vs sim {}",
                    s.kind,
                    dag.makespan(),
                    sim.makespan
                );
            }
        }
    }
}

/// Every generator yields a legal schedule across a parameter sweep
/// (coverage, contiguity, total reduction orders) — the §3.1 invariants.
#[test]
fn all_generators_legal_across_sweep() {
    for n in [2usize, 4, 6, 8, 16] {
        for m in [1usize, 2, 3, 8] {
            for mask in [
                MaskSpec::full(),
                MaskSpec::causal(),
                MaskSpec::sliding_window(2),
                MaskSpec::document(vec![n / 2]),
            ] {
                let spec = ProblemSpec::square(n, m, mask);
                validate(&fa3(&spec, true)).unwrap();
                validate(&fa3(&spec, false)).unwrap();
                validate(&descending(&spec)).unwrap();
                validate(&two_pass(&spec)).unwrap();
                validate(&symmetric_shift(&spec)).unwrap();
                if let Ok(s) = shift(&spec) {
                    validate(&s).unwrap();
                }
            }
        }
    }
}

/// Simulated makespans respect the paper's dominance ordering on the ideal
/// machine: optimal <= heuristic <= baseline; atomic <= all deterministic.
#[test]
fn dominance_ordering_holds() {
    for n in [4usize, 8, 16] {
        for m in [2usize, 4, 8] {
            let causal = ProblemSpec::square(n, m, MaskSpec::causal());
            let full = ProblemSpec::square(n, m, MaskSpec::full());
            let cfg = SimConfig::ideal(n);
            let t = |s: &dash::schedule::Schedule| simulate(s, &cfg).unwrap().makespan;
            let eps = 1e-9;
            assert!(t(&symmetric_shift(&causal)) <= t(&fa3(&causal, true)) + eps);
            assert!(t(&descending(&causal)) <= t(&fa3(&causal, true)) + eps);
            assert!(t(&shift(&full).unwrap()) <= t(&fa3(&full, true)) + eps);
            assert!(t(&fa3(&causal, false)) <= t(&fa3(&causal, true)) + eps);
        }
    }
}

/// Property sweep: every simulated schedule executes exactly its task count
/// and never reports negative stalls.
#[test]
fn simulation_conservation_laws() {
    let l2 = L2Model::default();
    for n in [4usize, 8] {
        for m in [1usize, 3] {
            for mask in [MaskSpec::full(), MaskSpec::causal()] {
                let spec = ProblemSpec::square(n, m, mask);
                for sched in [fa3(&spec, true), descending(&spec), two_pass(&spec)] {
                    for depth in [0usize, 2] {
                        let cfg = SimConfig {
                            n_sm: n + 1, // deliberately != n
                            cost: CostModel {
                                compute: 3.0,
                                reduce: 1.0,
                                spill_factor: 1.1,
                                l2,
                            },
                            record_spans: false,
                            writer_depth: depth,
                            occupancy: 2,
                            hw_fingerprint: 0,
                        };
                        let r = simulate(&sched, &cfg).unwrap();
                        assert_eq!(r.n_tasks, sched.total_tasks(), "{:?}", sched.kind);
                        assert!(r.stall_time >= 0.0);
                        assert!(r.makespan > 0.0);
                    }
                }
            }
        }
    }
}

/// The full figure harness runs end to end and respects the paper's
/// qualitative claims (already covered per-figure in unit tests; this is
/// the "everything composes" smoke).
#[test]
fn figure_harness_composes() {
    use dash::bench_harness as figs;
    use dash::hw::{presets, Machine};
    let m = Machine::real(presets::h800());
    assert_eq!(figs::fig1_degradation(&m).len(), 24);
    assert_eq!(figs::fig8_full_mask(&m).len(), 36);
    assert_eq!(figs::fig9_causal_mask(&m).len(), 48);
    assert_eq!(figs::fig10a_end_to_end(&m).len(), 13);
    assert_eq!(figs::fig10b_breakdown(&m).len(), 7);
    assert_eq!(figs::table1_determinism(10, 42).len(), 2);
}

/// The figure harness is machine-generic: the same artifact functions run
/// under a different profile and the hardware difference shows up in the
/// numbers (same workload, slower/narrower part -> lower throughput).
#[test]
fn figure_harness_is_gpu_generic() {
    use dash::bench_harness as figs;
    use dash::hw::{presets, Machine};
    let h800 = Machine::real(presets::h800());
    let a100 = Machine::real(presets::a100());
    let fast = figs::fig8_full_mask(&h800);
    let slow = figs::fig8_full_mask(&a100);
    assert_eq!(fast.len(), slow.len());
    for (f, s) in fast.iter().zip(&slow) {
        assert_eq!((f.schedule.as_str(), f.head_dim, f.seqlen), (s.schedule.as_str(), s.head_dim, s.seqlen));
        assert!(
            s.tflops < f.tflops,
            "{} hd{} seq{}: a100 {} !< h800 {}",
            f.schedule,
            f.head_dim,
            f.seqlen,
            s.tflops,
            f.tflops
        );
    }
}

/// Coordinator pieces that don't need artifacts.
#[test]
fn coordinator_deterministic_plumbing() {
    use dash::coordinator::{accumulate_grads, AccumOrder, SyntheticCorpus};
    let c = SyntheticCorpus::new(64, 9);
    let (x1, y1) = c.batch(3, 0, 4, 16);
    let (x2, _) = c.batch(3, 0, 4, 16);
    assert_eq!(x1, x2, "same (seed, step, mb) must give the same batch");
    assert_eq!(x1[1], y1[0]);

    let grads = vec![vec![1.0f32, 1e-8], vec![-1.0, 1e-8], vec![1e8, -1e8]];
    let a = accumulate_grads(&grads, AccumOrder::Fixed);
    let b = accumulate_grads(&grads, AccumOrder::Fixed);
    assert_eq!(a[0].to_bits(), b[0].to_bits());
}

/// Register model drives the paper's schedule-selection rule.
#[test]
fn schedule_selection_reflects_register_pressure() {
    use dash::bench_harness::dash_schedule_for;
    assert_eq!(dash_schedule_for(&MaskSpec::causal(), 64), ScheduleKind::SymmetricShift);
    assert_eq!(dash_schedule_for(&MaskSpec::causal(), 128), ScheduleKind::Descending);
    assert_eq!(dash_schedule_for(&MaskSpec::full(), 128), ScheduleKind::Shift);
}
