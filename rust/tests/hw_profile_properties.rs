//! Property tests for the hardware-profile layer, and the cache-safety
//! regressions it must uphold:
//!
//! * JSON round-trip: serialize -> parse -> identical profile + identical
//!   fingerprint (a calibrated profile survives the file system).
//! * Cost-model linearity: scaling the clock leaves makespan-in-cycles
//!   invariant (cycles are clock-free; only wall-clock/TFLOPs change), and
//!   widening the machine never increases the makespan of pin-free,
//!   unordered schedules.
//! * Autotune keying: profiles differing only in `n_sm` or only in clock
//!   produce distinct fingerprints, and a schedule cache populated under
//!   one profile misses under the other — H100-tuned schedules can never
//!   serve H800 queries.

use dash::autotune::{tune, ScheduleCache, TuneOptions, WorkloadFingerprint};
use dash::hw::{presets, GpuProfile, Machine};
use dash::schedule::{MaskSpec, ProblemSpec, ScheduleKind};
use dash::sim::workload::{run_point, BenchConfig};
use dash::sim::SimConfig;
use dash::util::Json;
use std::path::PathBuf;

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("dash-hwprop-{}-{tag}.json", std::process::id()))
}

// ---------------------------------------------------------------- JSON i/o

#[test]
fn json_round_trip_preserves_identity_and_fingerprint() {
    // Presets, plus a custom part to cover non-preset numbers.
    let mut custom = presets::h800();
    custom.name = "h800-calibrated".into();
    custom.clock_ghz = 1.87;
    custom.flops_per_cycle_per_sm = 2311.5;
    custom.l2_segments = 8;

    let mut profiles: Vec<GpuProfile> =
        presets::PRESET_NAMES.iter().map(|n| presets::preset(n).unwrap()).collect();
    profiles.push(custom);

    for p in &profiles {
        let text = p.to_json().dump();
        let back = GpuProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(&back, p, "{}", p.name);
        assert_eq!(back.fingerprint(), p.fingerprint(), "{}", p.name);
    }
}

#[test]
fn profile_file_round_trips_through_resolve() {
    let path = tmp_path("resolve");
    let mut p = presets::a100();
    p.name = "a100-tweaked".into();
    p.n_sm = 100;
    p.save(&path).unwrap();
    let back = dash::hw::resolve(path.to_str().unwrap()).unwrap();
    assert_eq!(back, p);
    assert_eq!(back.fingerprint(), p.fingerprint());
    let _ = std::fs::remove_file(&path);
}

// ------------------------------------------------------ cost-model linearity

#[test]
fn clock_scaling_leaves_cycle_makespan_invariant() {
    // The cost model is denominated in cycles; the clock only converts to
    // wall-time. Doubling it must leave every simulated cycle count
    // untouched while doubling throughput.
    let mut overclocked = presets::h800();
    overclocked.name = "h800-2x".into();
    overclocked.clock_ghz *= 2.0;

    let base = Machine::real(presets::h800());
    let fast = Machine::real(overclocked);

    for (seqlen, hd, mask) in
        [(2048usize, 64usize, MaskSpec::full()), (4096, 128, MaskSpec::causal())]
    {
        let cfg = BenchConfig::paper(seqlen, hd, mask);
        let a = run_point(&cfg, ScheduleKind::Fa3, &base);
        let b = run_point(&cfg, ScheduleKind::Fa3, &fast);
        assert!(
            (a.makespan_cycles - b.makespan_cycles).abs() < 1e-9,
            "seq{seqlen} hd{hd}: {} vs {}",
            a.makespan_cycles,
            b.makespan_cycles
        );
        let ratio = b.tflops / a.tflops;
        assert!((ratio - 2.0).abs() < 1e-9, "throughput ratio {ratio}");
    }
}

#[test]
fn more_sms_never_increase_makespan_for_unpinned_unordered_schedules() {
    // Pin-free dynamic assignment of *unordered* chains is greedy list
    // scheduling of independent jobs: adding machines cannot hurt. (Ordered
    // schedules are excluded — serialized reductions admit Graham-style
    // anomalies by design.)
    let mut wider = presets::h800();
    wider.name = "h800-wide".into();
    wider.n_sm *= 2;

    let narrow = Machine::real(presets::h800());
    let wide = Machine::real(wider);

    for (seqlen, hd, mask) in [
        (2048usize, 64usize, MaskSpec::full()),
        (4096, 128, MaskSpec::causal()),
        (1024, 128, MaskSpec::full()),
    ] {
        let cfg = BenchConfig::paper(seqlen, hd, mask);
        let a = run_point(&cfg, ScheduleKind::Fa3Atomic, &narrow);
        let b = run_point(&cfg, ScheduleKind::Fa3Atomic, &wide);
        assert!(
            b.makespan_cycles <= a.makespan_cycles + 1e-9,
            "seq{seqlen} hd{hd} {:?}: wide {} > narrow {}",
            cfg.mask,
            b.makespan_cycles,
            a.makespan_cycles
        );
    }
}

// ----------------------------------------------------- autotune cache safety

fn sim_for(profile: &GpuProfile, n: usize) -> SimConfig {
    Machine::real(profile.clone()).sim_config(ScheduleKind::Fa3, n, 128, 64)
}

#[test]
fn nsm_only_and_clock_only_changes_produce_distinct_fingerprints() {
    let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
    let base = presets::h800();

    let mut clocked = base.clone();
    clocked.clock_ghz *= 1.1;
    let mut widened = base.clone();
    widened.n_sm += 12;

    let key_base = WorkloadFingerprint::new(&spec, &sim_for(&base, 8)).key();
    let key_clock = WorkloadFingerprint::new(&spec, &sim_for(&clocked, 8)).key();
    let key_wide = WorkloadFingerprint::new(&spec, &sim_for(&widened, 8)).key();

    // Clock-only: identical per-cycle costs, still a distinct key.
    assert_ne!(key_base, key_clock, "clock-only change must re-key the cache");
    assert_ne!(key_base, key_wide, "n_sm-only change must re-key the cache");
    assert_ne!(key_clock, key_wide);
}

#[test]
fn cache_populated_under_one_profile_misses_under_another() {
    let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
    let h800 = presets::h800();
    let mut h800_oc = h800.clone();
    h800_oc.clock_ghz *= 1.25; // same cycles, different part

    let sim_a = sim_for(&h800, 6);
    let sim_b = sim_for(&h800_oc, 6);
    let key_a = WorkloadFingerprint::new(&spec, &sim_a).key();
    let key_b = WorkloadFingerprint::new(&spec, &sim_b).key();
    assert_ne!(key_a, key_b);

    let result = tune(&spec, &TuneOptions { budget: 20, seed: 1, sim: sim_a, batch: 1, threads: 1 })
        .unwrap();

    let path = tmp_path("crossprofile");
    let mut cache = ScheduleCache::open(&path);
    cache.put(&key_a, &result);
    cache.save().unwrap();

    let reloaded = ScheduleCache::open(&path);
    assert!(
        reloaded.get(&key_b, &spec).is_none(),
        "schedule tuned under one profile must not serve another"
    );
    assert!(reloaded.get(&key_a, &spec).is_some(), "the owning profile still hits");
    let _ = std::fs::remove_file(&path);
}

// ------------------------------------------------------------ preset coverage

#[test]
fn every_preset_runs_a_point_end_to_end() {
    // Every `--gpu`-reachable preset drives the whole stack: profile ->
    // cost model -> schedule -> simulate -> finite numbers.
    let cfg = BenchConfig::paper(1024, 64, MaskSpec::causal());
    for name in presets::PRESET_NAMES {
        let m = Machine::real(presets::preset(name).unwrap());
        let p = run_point(&cfg, ScheduleKind::Fa3, &m);
        assert!(
            p.makespan_cycles > 0.0 && p.makespan_cycles.is_finite(),
            "{name}: {p:?}"
        );
        assert!(p.utilization > 0.0 && p.utilization <= 1.0 + 1e-9, "{name}: {p:?}");
        let expected_n_sm = if name == "abstract" { cfg.n_tiles() } else { m.profile.n_sm };
        assert_eq!(p.n_sm, expected_n_sm, "{name}");
    }
}
