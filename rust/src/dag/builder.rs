//! Construct the §3.1 scheduling DAG from a concrete [`Schedule`] plus a
//! cost model, exposing critical-path analysis of entire schedules.
//!
//! Node layout per task (one live tile): `compute_begin -> reduce_begin ->
//! reduce_end`, with phase-edge weights `c` and `r`. SM serialization links
//! `reduce_end -> next compute_begin` (zero weight), and the deterministic
//! accumulation order links `reduce_end(pred) -> reduce_begin(succ)` with
//! the inter-SM signalling latency as weight (zero in the idealized model).
//!
//! The resulting critical path equals the event-driven simulator's makespan
//! under static chain assignment — an invariant the integration tests pin.

use super::graph::{Dag, EdgeKind, NodeId};
use crate::schedule::{Schedule, ScheduleKind};
use std::collections::HashMap;

/// Cost and topology options for DAG construction.
#[derive(Debug, Clone, Copy)]
pub struct DagBuildOptions {
    /// Compute cost per tile (`c`).
    pub compute_cost: f64,
    /// Global-reduction cost per tile (`r`).
    pub reduce_cost: f64,
    /// Weight of accumulation dependency edges (inter-SM signalling
    /// latency; 0 = the paper's idealized model).
    pub dependency_latency: f64,
}

impl Default for DagBuildOptions {
    fn default() -> Self {
        Self { compute_cost: 1.0, reduce_cost: 0.25, dependency_latency: 0.0 }
    }
}

/// A built schedule DAG with node bookkeeping for analysis/rendering.
#[derive(Debug, Clone)]
pub struct ScheduleDag {
    /// The graph itself.
    pub dag: Dag,
    /// For each chain (by schedule index), the per-task node triples
    /// `(compute_begin, reduce_begin, reduce_end)`.
    pub task_nodes: Vec<Vec<(NodeId, NodeId, NodeId)>>,
    /// Options used.
    pub options: DagBuildOptions,
}

impl ScheduleDag {
    /// Critical-path length (= static-assignment makespan).
    pub fn makespan(&self) -> f64 {
        self.dag.critical_path().expect("schedule DAGs are acyclic")
    }

    /// Task start times: for chain `ci`, task `t`, the (compute start,
    /// reduce start) times under ASAP execution.
    pub fn task_times(&self) -> Vec<Vec<(f64, f64)>> {
        let lp = self.dag.longest_paths().expect("acyclic");
        self.task_nodes
            .iter()
            .map(|tasks| tasks.iter().map(|&(c, r, _)| (lp[c], lp[r])).collect())
            .collect()
    }
}

/// Build the schedule DAG. Chains must be statically placed: pinned chains
/// use their pin; unpinned chains are placed round-robin in launch order
/// over `n_sm` SMs (matching the engine's behaviour when every chain is
/// ready immediately).
pub fn build_schedule_dag(
    schedule: &Schedule,
    n_sm: usize,
    options: DagBuildOptions,
) -> ScheduleDag {
    let spec = &schedule.spec;
    let mut dag = Dag::new();

    // --- assign chains to SMs ------------------------------------------
    let mut sm_chains: Vec<Vec<usize>> = vec![Vec::new(); n_sm];
    {
        let mut rr = 0usize;
        for i in 0..schedule.chains.len() {
            let sm = schedule.placement(i, n_sm).unwrap_or_else(|| {
                let s = rr % n_sm;
                rr += 1;
                s
            });
            sm_chains[sm].push(i);
        }
    }

    // --- create task nodes ----------------------------------------------
    let mut task_nodes: Vec<Vec<(NodeId, NodeId, NodeId)>> =
        vec![Vec::new(); schedule.chains.len()];
    for (ci, chain) in schedule.chains.iter().enumerate() {
        for _ in &chain.q_order {
            let c0 = dag.add_node();
            let r0 = dag.add_node();
            let r1 = dag.add_node();
            dag.add_edge(c0, r0, options.compute_cost * chain.compute_scale, EdgeKind::Phase);
            dag.add_edge(r0, r1, options.reduce_cost * chain.reduce_scale, EdgeKind::Phase);
            task_nodes[ci].push((c0, r0, r1));
        }
    }

    // --- SM serialization edges ------------------------------------------
    for chains in &sm_chains {
        let mut prev_end: Option<NodeId> = None;
        for &ci in chains {
            for &(c0, _, r1) in &task_nodes[ci] {
                if let Some(p) = prev_end {
                    dag.add_edge(p, c0, 0.0, EdgeKind::Dependency);
                }
                prev_end = Some(r1);
            }
        }
    }

    // --- accumulation-order edges ----------------------------------------
    // Map (head, q, kv) -> (chain, local step) for ordered chains.
    if schedule.chains.iter().any(|c| c.ordered) {
        let mut where_is: HashMap<(usize, usize, usize), (usize, usize)> = HashMap::new();
        for (ci, chain) in schedule.chains.iter().enumerate() {
            if !chain.ordered {
                continue;
            }
            for (t, &q) in chain.q_order.iter().enumerate() {
                where_is.insert((chain.head, q, chain.kv), (ci, t));
            }
        }
        for head in 0..spec.n_heads {
            for q in 0..spec.n_q {
                let idx = head * spec.n_q + q;
                if idx >= schedule.reduction_order.len() {
                    continue;
                }
                let order = &schedule.reduction_order[idx];
                for w in order.windows(2) {
                    let Some(&(ci_a, t_a)) = where_is.get(&(head, q, w[0])) else { continue };
                    let Some(&(ci_b, t_b)) = where_is.get(&(head, q, w[1])) else { continue };
                    let pred_end = task_nodes[ci_a][t_a].2;
                    let succ_rbegin = task_nodes[ci_b][t_b].1;
                    dag.add_edge(
                        pred_end,
                        succ_rbegin,
                        options.dependency_latency,
                        EdgeKind::Dependency,
                    );
                }
            }
        }
    }

    // Analytic single-pass schedules must produce acyclic DAGs. Two-pass
    // reuses head/kv indices across passes, and tuned schedules may pin
    // differently than this builder's round-robin placement for unpinned
    // chains — both are checked by their callers instead.
    debug_assert!(
        matches!(schedule.kind, ScheduleKind::TwoPass | ScheduleKind::Tuned)
            || dag.is_acyclic(),
        "schedule DAG must be acyclic"
    );
    ScheduleDag { dag, task_nodes, options }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{descending, fa3, shift, symmetric_shift, MaskSpec, ProblemSpec};

    const OPTS: DagBuildOptions =
        DagBuildOptions { compute_cost: 1.0, reduce_cost: 0.25, dependency_latency: 0.0 };

    #[test]
    fn shift_full_mask_hits_paper_optimum() {
        // T_full_opt = m * n * (c + r)
        let n = 8;
        let m = 3;
        let s = shift(&ProblemSpec::square(n, m, MaskSpec::full())).unwrap();
        let d = build_schedule_dag(&s, n, OPTS);
        let expect = (m * n) as f64 * 1.25;
        assert!((d.makespan() - expect).abs() < 1e-9, "{} vs {expect}", d.makespan());
    }

    #[test]
    fn fa3_full_mask_matches_closed_form() {
        // T_full = m*n*(c+r) + (n-1)*r  (Fig 3a analysis, head-major
        // launch — the paper's model; LPT interleaving only helps).
        let n = 6;
        let m = 2;
        let s = crate::schedule::fa3::fa3_with_interleave(
            &ProblemSpec::square(n, m, MaskSpec::full()),
            true,
            1,
        );
        let d = build_schedule_dag(&s, n, OPTS);
        let expect = (m * n) as f64 * 1.25 + (n as f64 - 1.0) * 0.25;
        assert!((d.makespan() - expect).abs() < 1e-9, "{} vs {expect}", d.makespan());
    }

    #[test]
    fn symmetric_shift_causal_hits_paper_optimum() {
        // T_causal_opt = m * (n+1) * (c+r) / 2 for even heads.
        let n = 8;
        let m = 2;
        let s = symmetric_shift(&ProblemSpec::square(n, m, MaskSpec::causal()));
        let d = build_schedule_dag(&s, n, OPTS);
        let expect = (m * (n + 1)) as f64 * 1.25 / 2.0;
        assert!((d.makespan() - expect).abs() < 1e-9, "{} vs {expect}", d.makespan());
    }

    #[test]
    fn fa3_causal_is_slower_than_descending() {
        let n = 8;
        let m = 4;
        let spec = ProblemSpec::square(n, m, MaskSpec::causal());
        let base = build_schedule_dag(&fa3(&spec, true), n, OPTS).makespan();
        let desc = build_schedule_dag(&descending(&spec), n, OPTS).makespan();
        assert!(
            desc < base,
            "descending ({desc}) should beat fa3 baseline ({base}) on causal"
        );
    }

    #[test]
    fn dependency_latency_lengthens_critical_path_beyond_slack() {
        // Shift has exactly `c` of slack per handoff (the consumer's own
        // compute overlaps the signal); latency below `c` is absorbed,
        // latency above it compounds along the critical path.
        let n = 8;
        let spec = ProblemSpec::square(n, 2, MaskSpec::full());
        let ideal = build_schedule_dag(&shift(&spec).unwrap(), n, OPTS).makespan();
        let absorbed = build_schedule_dag(
            &shift(&spec).unwrap(),
            n,
            DagBuildOptions { dependency_latency: 0.5, ..OPTS },
        )
        .makespan();
        assert!((absorbed - ideal).abs() < 1e-9, "latency < c must be absorbed");
        let lossy = build_schedule_dag(
            &shift(&spec).unwrap(),
            n,
            DagBuildOptions { dependency_latency: 2.0, ..OPTS },
        )
        .makespan();
        assert!(lossy > ideal, "latency > c must lengthen the critical path");
    }

    #[test]
    fn task_times_monotone_within_chain() {
        let n = 4;
        let s = fa3(&ProblemSpec::square(n, 1, MaskSpec::causal()), true);
        let d = build_schedule_dag(&s, n, OPTS);
        for chain in d.task_times() {
            for w in chain.windows(2) {
                assert!(w[1].0 >= w[0].1, "compute must follow previous reduce");
            }
        }
    }
}
