//! Lemma 1 machinery: depth-monotone zero-weight edges preserve the
//! critical path of a bundle of parallel isomorphic chains.
//!
//! The paper (Appendix B) proves: given `G0` = `n` parallel isomorphic
//! chains with strictly positive edge weights between a virtual source and
//! sink, adding zero-weight dependency edges `e_i = (u_i, v_i)` keeps
//! `CP(G_k) = CP(G_0)` **iff** every added edge satisfies
//! `depth(u_i) <= depth(v_i)`.
//!
//! This module provides both directions as executable checks:
//! [`check_depth_monotone`] classifies a set of proposed dependency edges,
//! and the tests empirically confirm the iff by measuring critical paths.

use super::graph::{Dag, EdgeKind, NodeId};

/// Specification of the chain bundle `G0`: `n_chains` isomorphic chains of
/// `chain_len` positively-weighted edges each (so `chain_len + 1` nodes per
/// chain, plus virtual source/sink added internally).
#[derive(Debug, Clone, Copy)]
pub struct ChainSpec {
    /// Number of parallel chains (`n` in the paper: one per SM/KV tile).
    pub n_chains: usize,
    /// Edges per chain; each alternating compute/reduce phase is one edge.
    pub chain_len: usize,
    /// Weight of every chain edge (isomorphism makes them uniform here;
    /// the lemma only needs strict positivity).
    pub edge_weight: f64,
}

impl ChainSpec {
    /// Node id of position `depth` (0-based, `0..=chain_len`) on `chain`.
    /// Ids: source = 0, sink = 1, then chain-major node blocks.
    pub fn node(&self, chain: usize, depth: usize) -> NodeId {
        assert!(chain < self.n_chains && depth <= self.chain_len);
        2 + chain * (self.chain_len + 1) + depth
    }

    /// Depth of a node id produced by [`ChainSpec::node`].
    pub fn depth(&self, node: NodeId) -> usize {
        assert!(node >= 2, "source/sink have no chain depth");
        (node - 2) % (self.chain_len + 1)
    }

    /// Build `G0`: source -> chains -> sink. Source/sink edges carry the
    /// chain edge weight too (strictly positive, preserving the lemma's
    /// preconditions; a common constant offset does not affect the iff).
    pub fn build(&self) -> Dag {
        let n_nodes = 2 + self.n_chains * (self.chain_len + 1);
        let mut g = Dag::with_nodes(n_nodes);
        for c in 0..self.n_chains {
            g.add_edge(0, self.node(c, 0), self.edge_weight, EdgeKind::Phase);
            for d in 0..self.chain_len {
                g.add_edge(
                    self.node(c, d),
                    self.node(c, d + 1),
                    self.edge_weight,
                    EdgeKind::Phase,
                );
            }
            g.add_edge(self.node(c, self.chain_len), 1, self.edge_weight, EdgeKind::Phase);
        }
        g
    }

    /// `CP(G0)` in closed form: (chain_len + 2) * edge_weight.
    pub fn base_critical_path(&self) -> f64 {
        (self.chain_len as f64 + 2.0) * self.edge_weight
    }
}

/// A single violation of Lemma 1's condition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LemmaViolation {
    /// The offending edge (src, dst).
    pub edge: (NodeId, NodeId),
    /// depth(src) — strictly greater than depth(dst).
    pub src_depth: usize,
    /// depth(dst).
    pub dst_depth: usize,
}

/// Outcome of checking a proposed set of zero-weight dependency edges.
#[derive(Debug, Clone)]
pub struct LemmaReport {
    /// Violating edges (`depth(u) > depth(v)`), in input order.
    pub violations: Vec<LemmaViolation>,
    /// True iff adding all edges (in order) keeps the graph acyclic —
    /// the lemma's standing premise.
    pub stays_acyclic: bool,
    /// `CP(G0)`.
    pub base_cp: f64,
    /// `CP(G_k)` after adding all edges, if acyclic.
    pub final_cp: Option<f64>,
}

impl LemmaReport {
    /// True iff Lemma 1 predicts the critical path is preserved.
    pub fn predicts_preserved(&self) -> bool {
        self.stays_acyclic && self.violations.is_empty()
    }
}

/// Check a set of proposed zero-weight dependency edges against Lemma 1 and
/// *also* measure the actual critical path, so callers can cross-validate
/// prediction against measurement (done exhaustively in tests).
pub fn check_depth_monotone(spec: &ChainSpec, edges: &[(NodeId, NodeId)]) -> LemmaReport {
    let mut g = spec.build();
    let base_cp = g.critical_path().expect("G0 is a DAG");
    debug_assert!((base_cp - spec.base_critical_path()).abs() < 1e-9);

    let mut violations = Vec::new();
    let mut stays_acyclic = true;
    for &(u, v) in edges {
        let (du, dv) = (spec.depth(u), spec.depth(v));
        if du > dv {
            violations.push(LemmaViolation { edge: (u, v), src_depth: du, dst_depth: dv });
        }
        g.add_edge(u, v, 0.0, EdgeKind::Dependency);
        if stays_acyclic && !g.is_acyclic() {
            stays_acyclic = false;
        }
    }
    let final_cp = if stays_acyclic { g.critical_path() } else { None };
    LemmaReport { violations, stays_acyclic, base_cp, final_cp }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: ChainSpec = ChainSpec { n_chains: 4, chain_len: 6, edge_weight: 1.0 };

    #[test]
    fn base_graph_cp_matches_closed_form() {
        let g = SPEC.build();
        assert_eq!(g.critical_path(), Some(SPEC.base_critical_path()));
    }

    #[test]
    fn forward_edge_preserves_cp() {
        // depth 2 -> depth 5 across chains: allowed.
        let r = check_depth_monotone(&SPEC, &[(SPEC.node(0, 2), SPEC.node(1, 5))]);
        assert!(r.predicts_preserved());
        assert_eq!(r.final_cp, Some(r.base_cp));
    }

    #[test]
    fn equal_depth_edge_preserves_cp() {
        let r = check_depth_monotone(&SPEC, &[(SPEC.node(2, 3), SPEC.node(3, 3))]);
        assert!(r.predicts_preserved());
        assert_eq!(r.final_cp, Some(r.base_cp));
    }

    #[test]
    fn backward_edge_lengthens_cp() {
        // depth 5 -> depth 2: Lemma 1 says CP strictly grows.
        let r = check_depth_monotone(&SPEC, &[(SPEC.node(0, 5), SPEC.node(1, 2))]);
        assert_eq!(r.violations.len(), 1);
        assert!(r.final_cp.unwrap() > r.base_cp);
    }

    #[test]
    fn iff_holds_exhaustively_for_single_edges() {
        // Empirical verification of the iff over every cross-chain pair.
        let spec = ChainSpec { n_chains: 3, chain_len: 4, edge_weight: 2.0 };
        for du in 0..=spec.chain_len {
            for dv in 0..=spec.chain_len {
                let r = check_depth_monotone(&spec, &[(spec.node(0, du), spec.node(1, dv))]);
                let preserved = (r.final_cp.unwrap() - r.base_cp).abs() < 1e-9;
                assert_eq!(
                    preserved,
                    du <= dv,
                    "lemma iff failed for depths {du} -> {dv}"
                );
            }
        }
    }

    #[test]
    fn chain_of_monotone_edges_preserves_cp() {
        // A full serialized reduction order at one depth: 0->1->2->3 at depth 4.
        let edges: Vec<_> = (0..SPEC.n_chains - 1)
            .map(|c| (SPEC.node(c, 4), SPEC.node(c + 1, 4)))
            .collect();
        let r = check_depth_monotone(&SPEC, &edges);
        assert!(r.predicts_preserved());
        assert_eq!(r.final_cp, Some(r.base_cp));
    }

    #[test]
    fn cycle_from_contradictory_edges_detected() {
        let edges = [
            (SPEC.node(0, 3), SPEC.node(1, 3)),
            (SPEC.node(1, 3), SPEC.node(0, 3)),
        ];
        let r = check_depth_monotone(&SPEC, &edges);
        assert!(!r.stays_acyclic);
        assert!(r.final_cp.is_none());
    }

    #[test]
    fn depth_roundtrip() {
        for c in 0..SPEC.n_chains {
            for d in 0..=SPEC.chain_len {
                assert_eq!(SPEC.depth(SPEC.node(c, d)), d);
            }
        }
    }
}
