//! Weighted DAG with longest-path (critical-path) computation.
//!
//! Node payloads are kept out of the graph itself; callers map [`NodeId`]s to
//! domain objects (tile tasks, phases). Edge weights are `f64` durations in
//! abstract time units (the paper's `c` and `r`); dependency edges are
//! zero-weight unless an L2-latency model assigns them a signalling cost.

use std::collections::VecDeque;

/// Index of a node in a [`Dag`]. Dense, assigned in insertion order.
pub type NodeId = usize;

/// Classification of an edge, mirroring the paper's DAG construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// A positively-weighted phase edge: tile compute or global reduction.
    Phase,
    /// A dependency edge encoding accumulation order / chain contiguity.
    /// Zero-weight in the idealized model; may carry an L2 signalling
    /// latency in the hardware-aware model (§4.2 of the paper).
    Dependency,
}

#[derive(Debug, Clone)]
struct Edge {
    dst: NodeId,
    weight: f64,
    kind: EdgeKind,
}

/// A growable weighted DAG.
///
/// Cycle detection happens lazily in [`Dag::longest_paths`]; [`Dag::is_acyclic`]
/// can be used for an explicit check (Lemma 1's "must remain a DAG" premise).
#[derive(Debug, Clone, Default)]
pub struct Dag {
    adj: Vec<Vec<Edge>>,
    radj: Vec<Vec<NodeId>>,
    in_degree: Vec<usize>,
    n_edges: usize,
}

impl Dag {
    /// Create an empty DAG.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create a DAG with `n` isolated nodes.
    pub fn with_nodes(n: usize) -> Self {
        Self {
            adj: vec![Vec::new(); n],
            radj: vec![Vec::new(); n],
            in_degree: vec![0; n],
            n_edges: 0,
        }
    }

    /// Add a node and return its id.
    pub fn add_node(&mut self) -> NodeId {
        self.adj.push(Vec::new());
        self.radj.push(Vec::new());
        self.in_degree.push(0);
        self.adj.len() - 1
    }

    /// Number of nodes.
    pub fn node_count(&self) -> usize {
        self.adj.len()
    }

    /// Number of edges.
    pub fn edge_count(&self) -> usize {
        self.n_edges
    }

    /// Add a weighted edge. Panics on out-of-range nodes or negative weight.
    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, weight: f64, kind: EdgeKind) {
        assert!(src < self.adj.len() && dst < self.adj.len(), "node out of range");
        assert!(weight >= 0.0, "negative edge weight");
        self.adj[src].push(Edge { dst, weight, kind });
        self.radj[dst].push(src);
        self.in_degree[dst] += 1;
        self.n_edges += 1;
    }

    /// Iterate over `(src, dst, weight, kind)` tuples.
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId, f64, EdgeKind)> + '_ {
        self.adj.iter().enumerate().flat_map(|(src, es)| {
            es.iter().map(move |e| (src, e.dst, e.weight, e.kind))
        })
    }

    /// Kahn topological order; `None` if the graph has a cycle.
    pub fn topo_order(&self) -> Option<Vec<NodeId>> {
        let mut indeg = self.in_degree.clone();
        let mut queue: VecDeque<NodeId> = indeg
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(self.adj.len());
        while let Some(u) = queue.pop_front() {
            order.push(u);
            for e in &self.adj[u] {
                indeg[e.dst] -= 1;
                if indeg[e.dst] == 0 {
                    queue.push_back(e.dst);
                }
            }
        }
        (order.len() == self.adj.len()).then_some(order)
    }

    /// True iff the graph is acyclic.
    pub fn is_acyclic(&self) -> bool {
        self.topo_order().is_some()
    }

    /// Longest path from any source to every node (`LP(v)` in the paper's
    /// Appendix B). Returns `None` on cycles.
    pub fn longest_paths(&self) -> Option<Vec<f64>> {
        let order = self.topo_order()?;
        let mut lp = vec![0.0f64; self.adj.len()];
        for &u in &order {
            for e in &self.adj[u] {
                let cand = lp[u] + e.weight;
                if cand > lp[e.dst] {
                    lp[e.dst] = cand;
                }
            }
        }
        Some(lp)
    }

    /// Critical-path length `CP(G)`: the maximum over nodes of the longest
    /// path from a source. `None` on cycles; `0.0` for an empty graph.
    pub fn critical_path(&self) -> Option<f64> {
        self.longest_paths()
            .map(|lp| lp.into_iter().fold(0.0f64, f64::max))
    }

    /// One concrete critical path as a node sequence (useful for Gantt
    /// annotation and for explaining *why* a schedule is slow).
    pub fn critical_path_nodes(&self) -> Option<Vec<NodeId>> {
        let lp = self.longest_paths()?;
        // Find the sink of the critical path.
        let mut end = 0;
        for (i, &v) in lp.iter().enumerate() {
            if v > lp[end] {
                end = i;
            }
        }
        // Walk backwards along tight predecessors.
        let mut path = vec![end];
        let mut cur = end;
        'outer: loop {
            for &p in &self.radj[cur] {
                for e in &self.adj[p] {
                    if e.dst == cur && (lp[p] + e.weight - lp[cur]).abs() < 1e-9 {
                        path.push(p);
                        cur = p;
                        continue 'outer;
                    }
                }
            }
            break;
        }
        path.reverse();
        Some(path)
    }

    /// Earliest start time of each node under list-scheduling semantics:
    /// identical to `longest_paths` (a node starts when all in-edges have
    /// completed). Exposed under the domain name for the simulator.
    pub fn earliest_start_times(&self) -> Option<Vec<f64>> {
        self.longest_paths()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Dag {
        // 0 -> 1 -> 3, 0 -> 2 -> 3 with weights 1,2 / 3,4
        let mut g = Dag::with_nodes(4);
        g.add_edge(0, 1, 1.0, EdgeKind::Phase);
        g.add_edge(1, 3, 2.0, EdgeKind::Phase);
        g.add_edge(0, 2, 3.0, EdgeKind::Phase);
        g.add_edge(2, 3, 4.0, EdgeKind::Phase);
        g
    }

    #[test]
    fn empty_graph_critical_path_is_zero() {
        assert_eq!(Dag::new().critical_path(), Some(0.0));
    }

    #[test]
    fn single_chain_longest_path() {
        let mut g = Dag::with_nodes(3);
        g.add_edge(0, 1, 1.5, EdgeKind::Phase);
        g.add_edge(1, 2, 2.5, EdgeKind::Phase);
        assert_eq!(g.critical_path(), Some(4.0));
    }

    #[test]
    fn diamond_takes_heavier_branch() {
        assert_eq!(diamond().critical_path(), Some(7.0));
    }

    #[test]
    fn critical_path_nodes_follow_heavy_branch() {
        assert_eq!(diamond().critical_path_nodes(), Some(vec![0, 2, 3]));
    }

    #[test]
    fn zero_weight_edge_does_not_extend_path() {
        let mut g = diamond();
        // A dependency edge from the light branch into the heavy one.
        g.add_edge(1, 2, 0.0, EdgeKind::Dependency);
        assert_eq!(g.critical_path(), Some(7.0));
    }

    #[test]
    fn cycle_detected() {
        let mut g = Dag::with_nodes(2);
        g.add_edge(0, 1, 1.0, EdgeKind::Phase);
        g.add_edge(1, 0, 1.0, EdgeKind::Phase);
        assert!(!g.is_acyclic());
        assert!(g.critical_path().is_none());
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        let pos = |n: NodeId| order.iter().position(|&x| x == n).unwrap();
        for (s, d, _, _) in g.edges() {
            assert!(pos(s) < pos(d));
        }
    }

    #[test]
    fn parallel_chains_independent() {
        // Two disconnected chains; CP is the longer one.
        let mut g = Dag::with_nodes(6);
        for i in 0..2 {
            g.add_edge(3 * i, 3 * i + 1, 1.0 + i as f64, EdgeKind::Phase);
            g.add_edge(3 * i + 1, 3 * i + 2, 1.0 + i as f64, EdgeKind::Phase);
        }
        assert_eq!(g.critical_path(), Some(4.0));
    }
}
