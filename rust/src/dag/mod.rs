//! Directed-acyclic-graph model of the deterministic attention backward pass.
//!
//! This is the paper's §3.1 formalization: each tile task is a linear path of
//! nodes connected by positively-weighted *phase* edges (compute, then global
//! reduction), and zero-weight *dependency* edges encode the legal
//! accumulation orderings across tasks. The scheduling objective is to
//! minimize the critical-path length of the resulting DAG.
//!
//! The module provides:
//! * [`Dag`] — a weighted DAG with O(V+E) longest-path computation,
//! * [`lemma`] — the Lemma 1 machinery (depth-monotone zero-edge checks),
//! * [`builder`] — construction of the backward-pass DAG from a
//!   [`crate::schedule::Schedule`].

mod builder;
mod graph;
mod lemma;

pub use builder::{build_schedule_dag, DagBuildOptions, ScheduleDag};
pub use graph::{Dag, EdgeKind, NodeId};
pub use lemma::{check_depth_monotone, ChainSpec, LemmaReport, LemmaViolation};
