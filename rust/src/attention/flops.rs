//! FLOP accounting for attention forward/backward, used to convert
//! simulated makespans into the TFLOPs/s the paper plots and to build the
//! Fig 10b kernel-time breakdown.

/// GEMMs per fused backward tile (Algorithm 1): S = QKᵀ, dP = dO Vᵀ,
/// dV += Pᵀ dO, dK += dSᵀ Q, dQ = dS K.
pub const BWD_FUSED_GEMMS: usize = 5;

/// GEMMs per live tile of the two-pass baseline: pass 1 computes
/// S, dP, dV, dK (no dQ write) and pass 2 recomputes S, dP and emits dQ —
/// the recompute overhead [`crate::schedule::two_pass`] charges.
pub const BWD_TWO_PASS_GEMMS: usize = 7;

/// FLOPs of one `block x block` tile GEMM against a `head_dim`-wide
/// operand: `2 * Bq * Bc * d` (every GEMM of Algorithm 1 has this shape).
/// The tile executor ([`crate::exec`]) counts executed work in these
/// units, which makes its totals exactly cross-checkable against the
/// closed forms below.
pub fn tile_gemm_flops(block: usize, head_dim: usize) -> f64 {
    2.0 * (block * block * head_dim) as f64
}

/// FLOPs of one backward tile: the five GEMMs of Algorithm 1
/// (S = QKᵀ, dP = dO Vᵀ, dV += Pᵀ dO, dK += dSᵀ Q, dQ = dS K),
/// each `2 * Bq * Bc * d`.
pub fn bwd_tile_flops(block: usize, head_dim: usize) -> f64 {
    BWD_FUSED_GEMMS as f64 * tile_gemm_flops(block, head_dim)
}

/// FLOPs of one forward tile: two GEMMs (S = QKᵀ, O += P V).
pub fn fwd_tile_flops(block: usize, head_dim: usize) -> f64 {
    2.0 * tile_gemm_flops(block, head_dim)
}

/// Total attention forward FLOPs for a (batch, heads, seqlen, head_dim)
/// problem; `causal` halves the live area.
pub fn attention_fwd_flops(
    batch: usize,
    heads: usize,
    seqlen: usize,
    head_dim: usize,
    causal: bool,
) -> f64 {
    let full = 4.0 * (batch * heads) as f64 * (seqlen * seqlen) as f64 * head_dim as f64;
    if causal {
        full / 2.0
    } else {
        full
    }
}

/// Total attention backward FLOPs (2.5x forward: 5 GEMMs vs 2).
pub fn attention_bwd_flops(
    batch: usize,
    heads: usize,
    seqlen: usize,
    head_dim: usize,
    causal: bool,
) -> f64 {
    attention_fwd_flops(batch, heads, seqlen, head_dim, causal) * 2.5
}

/// GEMM FLOPs for the non-attention parts of one transformer block
/// (QKV/out projections + MLP), fwd only: `2 * tokens * hidden * width`
/// summed over the standard projections with an `mlp_ratio` MLP.
pub fn block_gemm_fwd_flops(tokens: usize, hidden: usize, mlp_ratio: f64) -> f64 {
    let h = hidden as f64;
    let t = tokens as f64;
    // QKV (3h^2), out proj (h^2), MLP up+down (2 * ratio * h^2).
    2.0 * t * h * h * (4.0 + 2.0 * mlp_ratio)
}

/// Backward GEMM FLOPs are 2x forward (dgrad + wgrad).
pub fn block_gemm_bwd_flops(tokens: usize, hidden: usize, mlp_ratio: f64) -> f64 {
    2.0 * block_gemm_fwd_flops(tokens, hidden, mlp_ratio)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bwd_is_2_5x_fwd_per_tile() {
        assert_eq!(bwd_tile_flops(128, 64) / fwd_tile_flops(128, 64), 2.5);
    }

    #[test]
    fn tile_flops_decompose_into_gemms() {
        assert_eq!(bwd_tile_flops(64, 32), 5.0 * tile_gemm_flops(64, 32));
        assert_eq!(fwd_tile_flops(64, 32), 2.0 * tile_gemm_flops(64, 32));
        assert_eq!(tile_gemm_flops(4, 8), 2.0 * (4 * 4 * 8) as f64);
        assert_eq!(BWD_TWO_PASS_GEMMS, BWD_FUSED_GEMMS + 2); // S and dP redone
    }

    #[test]
    fn causal_halves_flops() {
        let f = attention_fwd_flops(1, 16, 4096, 128, false);
        let c = attention_fwd_flops(1, 16, 4096, 128, true);
        assert_eq!(f / c, 2.0);
    }

    #[test]
    fn tile_flops_consistent_with_total() {
        // total = live_tiles * per-tile for full mask.
        let (b, h, s, d) = (2, 8, 2048, 64);
        let tiles = (s / 128) * (s / 128);
        let total = attention_bwd_flops(b, h, s, d, false);
        let per_tile = bwd_tile_flops(128, d) * (tiles * b * h) as f64;
        assert!((total / per_tile - 1.0).abs() < 1e-9);
    }

    #[test]
    fn gemm_flops_positive_and_scale() {
        let a = block_gemm_fwd_flops(4096, 2048, 4.0);
        let b = block_gemm_bwd_flops(4096, 2048, 4.0);
        assert_eq!(b / a, 2.0);
        assert!(a > 0.0);
    }
}
