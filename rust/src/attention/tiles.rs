//! Tile geometry of the FlashAttention backward pass (Algorithm 1).

use crate::mask::MaskSpec;

/// The tile decomposition of one attention head's backward pass:
/// `Tr x Tc` blocks of `(Br, Bc)` rows/columns over a sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TileGrid {
    /// Sequence length (N).
    pub seqlen: usize,
    /// Q-block rows (Br).
    pub block_q: usize,
    /// KV-block rows (Bc).
    pub block_kv: usize,
    /// Head dimension (d).
    pub head_dim: usize,
    /// Mask shape.
    pub mask: MaskSpec,
}

impl TileGrid {
    /// FA3 defaults: 128x128 tiles.
    pub fn fa3(seqlen: usize, head_dim: usize, mask: MaskSpec) -> Self {
        Self { seqlen, block_q: 128, block_kv: 128, head_dim, mask }
    }

    /// Number of Q tiles, `Tr = ceil(N / Br)`.
    pub fn n_q(&self) -> usize {
        self.seqlen.div_ceil(self.block_q)
    }

    /// Number of KV tiles, `Tc = ceil(N / Bc)`.
    pub fn n_kv(&self) -> usize {
        self.seqlen.div_ceil(self.block_kv)
    }

    /// Is the (kv, q) tile live under the mask? Block-granular, matching
    /// FA3's block skipping (a partially masked tile is computed in full
    /// and masked in-register): the decision is delegated to the
    /// [`MaskSpec`] layer at tile granularity, which coincides with the
    /// element-granular rule whenever `block_q == block_kv` (the FA3
    /// default this repo uses throughout).
    pub fn live(&self, kv: usize, q: usize) -> bool {
        self.mask.live(kv, q, self.n_kv(), self.n_q())
    }

    /// Count of live tiles.
    pub fn live_tiles(&self) -> usize {
        (0..self.n_kv())
            .map(|kv| (0..self.n_q()).filter(|&q| self.live(kv, q)).count())
            .sum()
    }

    /// VMEM (or SMEM) footprint in bytes of one tile-step's working set:
    /// Q, K, V, dO tiles in bf16 plus the dS/P scratch in fp32 — the
    /// quantity the TPU adaptation must fit in ~16 MiB VMEM (see the
    /// top-level README.md §Architecture).
    pub fn tile_working_set_bytes(&self) -> usize {
        let bf16 = 2;
        let f32 = 4;
        let q = self.block_q * self.head_dim * bf16;
        let dout = self.block_q * self.head_dim * bf16;
        let k = self.block_kv * self.head_dim * bf16;
        let v = self.block_kv * self.head_dim * bf16;
        let scratch = self.block_q * self.block_kv * f32 * 2; // P and dS
        let accum = self.block_kv * self.head_dim * f32 * 2; // dK, dV
        q + dout + k + v + scratch + accum
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_counts() {
        let g = TileGrid::fa3(16384, 128, MaskSpec::causal());
        assert_eq!(g.n_q(), 128);
        assert_eq!(g.n_kv(), 128);
    }

    #[test]
    fn ragged_sequence_rounds_up() {
        let g = TileGrid::fa3(1000, 64, MaskSpec::full());
        assert_eq!(g.n_q(), 8);
    }

    #[test]
    fn causal_block_liveness_includes_diagonal() {
        let g = TileGrid::fa3(512, 64, MaskSpec::causal());
        assert!(g.live(0, 0));
        assert!(g.live(3, 3));
        assert!(!g.live(3, 0));
        assert!(g.live(1, 2));
    }

    #[test]
    fn causal_live_tiles_triangle() {
        let g = TileGrid::fa3(512, 64, MaskSpec::causal());
        assert_eq!(g.live_tiles(), 10); // 4+3+2+1
    }

    #[test]
    fn working_set_fits_vmem_at_hd128() {
        let g = TileGrid::fa3(8192, 128, MaskSpec::causal());
        // 16 MiB VMEM per TensorCore; one tile-step must fit comfortably.
        assert!(g.tile_working_set_bytes() < 16 * 1024 * 1024 / 4);
    }
}
