//! Attention tile geometry, FLOP accounting, and the paper's closed-form
//! performance model (§3.2–§3.4), cross-validated against the simulator.

pub mod analytic;
pub mod flops;
pub mod tiles;

pub use analytic::{t_causal_fa3, t_causal_opt, t_full_fa3, t_full_opt, t_reversed};
pub use tiles::TileGrid;
