//! The paper's closed-form schedule costs (§3.2–§3.4), in cycles, for the
//! abstract machine (`n` SMs = `n` KV tiles, zero dependency latency).
//!
//! | schedule | mask | formula |
//! |---|---|---|
//! | FA3 baseline | full   | `m·n·(c+r) + (n-1)·r` |
//! | FA3 baseline | causal | `≈ m·n·(c+r) + (n-1)·r` |
//! | Descending   | causal | `≈ m·(n+1)·(c+r)/2 + (n-1)·r` (even m) |
//! | Shift        | full   | `m·n·(c+r)` (optimal) |
//! | Symmetric Shift | causal | `m·(n+1)·(c+r)/2` (optimal) |
//!
//! Integration tests assert the simulator reproduces each of these exactly
//! (or within the paper's own "approximately" slack for the heuristics).

/// FA3 baseline, full mask: `m·n·(c+r) + (n-1)·r`.
pub fn t_full_fa3(n: usize, m: usize, c: f64, r: f64) -> f64 {
    (m * n) as f64 * (c + r) + (n as f64 - 1.0) * r
}

/// FA3 baseline, causal mask: `≈ m·n·(c+r) + (n-1)·r` — the per-head bubble
/// `(n-1)·r` overlaps the next head's startup, leaving the same total as
/// the full-mask case despite half the useful work (the inefficiency the
/// descending heuristic removes).
pub fn t_causal_fa3(n: usize, m: usize, c: f64, r: f64) -> f64 {
    (m * n) as f64 * (c + r) + (n as f64 - 1.0) * r
}

/// Descending Q-tile iteration, causal mask, even `m`:
/// `≈ m·(n+1)·(c+r)/2 + (n-1)·r`.
pub fn t_reversed(n: usize, m: usize, c: f64, r: f64) -> f64 {
    (m * (n + 1)) as f64 * (c + r) / 2.0 + (n as f64 - 1.0) * r
}

/// Shift scheduling, full mask (optimal): `m·n·(c+r)`.
pub fn t_full_opt(n: usize, m: usize, c: f64, r: f64) -> f64 {
    (m * n) as f64 * (c + r)
}

/// Symmetric shift, causal mask (optimal): `m·(n+1)·(c+r)/2`.
pub fn t_causal_opt(n: usize, m: usize, c: f64, r: f64) -> f64 {
    (m * (n + 1)) as f64 * (c + r) / 2.0
}

/// Theoretical speedup of the optimal schedule over the baseline for a
/// mask; the paper's headline "up to 1.28x" corresponds to the causal case
/// with moderate `n` and the measured `r/c`.
pub fn theoretical_speedup_causal(n: usize, m: usize, c: f64, r: f64) -> f64 {
    t_causal_fa3(n, m, c, r) / t_causal_opt(n, m, c, r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn optimal_beats_baseline() {
        let (n, m, c, r) = (16, 8, 1.0, 0.3);
        assert!(t_full_opt(n, m, c, r) < t_full_fa3(n, m, c, r));
        assert!(t_causal_opt(n, m, c, r) < t_causal_fa3(n, m, c, r));
        assert!(t_reversed(n, m, c, r) < t_causal_fa3(n, m, c, r));
    }

    #[test]
    fn causal_speedup_approaches_2x_for_large_n() {
        // As n grows the baseline wastes ~half the machine on causal; the
        // asymptotic ratio tends to 2 (paper's measured 1.28x includes
        // hardware losses the ideal model omits).
        let s = theoretical_speedup_causal(128, 16, 1.0, 0.3);
        assert!(s > 1.8 && s < 2.1, "speedup {s}");
    }

    #[test]
    fn reversed_close_to_optimal() {
        let (n, m, c, r) = (64, 8, 1.0, 0.3);
        let gap = t_reversed(n, m, c, r) / t_causal_opt(n, m, c, r);
        assert!(gap < 1.1);
    }

    #[test]
    fn startup_term_vanishes_relatively_with_heads() {
        let (n, c, r) = (32, 1.0, 0.25);
        let few = t_full_fa3(n, 1, c, r) / t_full_opt(n, 1, c, r);
        let many = t_full_fa3(n, 64, c, r) / t_full_opt(n, 64, c, r);
        assert!(many < few);
    }
}
