//! Order-controlled reductions and run-to-run deviation statistics —
//! the Rust half of the paper's Table 1 experiment.
//!
//! A deterministic attention backward pass folds each dQ element's partial
//! contributions in a *fixed* order; atomicAdd folds them in whatever order
//! CTAs complete. Because FP addition is non-associative, the latter gives
//! run-to-run deviations of `O(1e-4)` at bf16/attention scales while the
//! former is bitwise stable — exactly what [`deviation_across_orders`]
//! measures.

use super::Bf16;
use crate::util::DetRng;

/// Accumulation/storage precision of an ordered reduction — the knob the
/// tile executor ([`crate::exec`]) turns to show that the *same* fold
/// order-sensitivity exists in f32 and is much coarser in bf16 (the
/// storage format the paper benchmarks with).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    /// f32 accumulator, f32 storage.
    F32,
    /// bf16 storage: every partial is rounded to bf16 on store and the
    /// accumulator itself lives in bf16 (widen-add-round per step), the
    /// arithmetic an atomicAdd on a bf16 buffer performs.
    Bf16,
}

impl Precision {
    /// Canonical spelling (`f32` / `bf16`), round-trips through
    /// [`Precision::parse`].
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }

    /// Parse a CLI/manifest spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(Precision::F32),
            "bf16" => Some(Precision::Bf16),
            _ => None,
        }
    }
}

/// Fold per-contributor partial tiles elementwise in an explicit order —
/// the generalized reduction the tile executor accumulates dQ through.
///
/// `partials` holds one flat tile (`len` f32 elements) per contributor;
/// `order` gives the positions into `partials` in fold sequence (it may be
/// a subset — contributors outside `order` are ignored). An empty `order`
/// (a fully-masked dQ tile: no live KV contributions) returns zeros, and a
/// single-element `order` returns that partial unchanged (modulo bf16
/// storage rounding). NaN/Inf propagate exactly as FP addition dictates.
///
/// In [`Precision::Bf16`] every partial is rounded to bf16 *before* the
/// fold and the accumulator is re-rounded after every add — so the result
/// depends on `order` much more strongly than the f32 fold does, which is
/// precisely the sensitivity the determinism oracle exploits.
pub fn reduce_tiles_ordered(
    len: usize,
    partials: &[Vec<f32>],
    order: &[usize],
    precision: Precision,
) -> Vec<f32> {
    for p in partials {
        assert_eq!(p.len(), len, "ragged partial tile");
    }
    match precision {
        Precision::F32 => {
            let mut acc = vec![0.0f32; len];
            for &i in order {
                for (a, &x) in acc.iter_mut().zip(&partials[i]) {
                    *a += x;
                }
            }
            acc
        }
        Precision::Bf16 => {
            let mut acc = vec![Bf16::ZERO; len];
            for &i in order {
                for (a, &x) in acc.iter_mut().zip(&partials[i]) {
                    *a = a.add(Bf16::from_f32(x));
                }
            }
            acc.into_iter().map(Bf16::to_f32).collect()
        }
    }
}

/// Fold `values` left-to-right in f32 following `order` (indices into
/// `values`). This is the serialized deterministic accumulation.
pub fn sum_f32_ordered(values: &[f32], order: &[usize]) -> f32 {
    let mut acc = 0.0f32;
    for &i in order {
        acc += values[i];
    }
    acc
}

/// Fold in natural order.
pub fn sum_in_order(values: &[f32]) -> f32 {
    let order: Vec<usize> = (0..values.len()).collect();
    sum_f32_ordered(values, &order)
}

/// Kahan-compensated sum — reference for "how much error does *any* plain
/// order carry" (near-exact).
pub fn kahan_sum(values: &[f32]) -> f64 {
    let mut sum = 0.0f32;
    let mut c = 0.0f32;
    for &v in values {
        let y = v - c;
        let t = sum + y;
        c = (t - sum) - y;
        sum = t;
    }
    sum as f64
}

/// Pairwise (tree) sum — the order GPU warp-reductions typically use for
/// intra-CTA (deterministic, but a *different* deterministic answer than
/// serial order, demonstrating that determinism fixes an order, not the
/// "true" value).
pub fn pairwise_sum(values: &[f32]) -> f32 {
    match values.len() {
        0 => 0.0,
        1 => values[0],
        n => {
            let mid = n / 2;
            pairwise_sum(&values[..mid]) + pairwise_sum(&values[mid..])
        }
    }
}

/// Deviation statistics across permuted accumulation orders.
#[derive(Debug, Clone, Copy)]
pub struct DeviationStats {
    /// Max |x_run - x_ref| over runs (the paper's `M_r`), where the
    /// reference is the fixed-order result.
    pub max_abs_deviation: f64,
    /// Max relative deviation |x_run - x_ref| / |x_ref|.
    pub max_rel_deviation: f64,
    /// Number of distinct bit patterns observed (1 = bitwise determinism).
    pub distinct_results: usize,
}

/// Run the Table 1 experiment on a vector of partial contributions:
/// `runs` shuffled-order accumulations (seeded per run, modelling
/// uncontrolled CTA completion order) compared against the fixed-order
/// reference. With `shuffle = false` every run uses the fixed order and
/// must produce `distinct_results == 1`.
pub fn deviation_across_orders(values: &[f32], runs: usize, shuffle: bool, seed: u64) -> DeviationStats {
    let reference = sum_in_order(values);
    let mut max_abs = 0.0f64;
    let mut max_rel = 0.0f64;
    let mut patterns = std::collections::HashSet::new();
    patterns.insert(reference.to_bits());
    let mut order: Vec<usize> = (0..values.len()).collect();
    for run in 0..runs {
        let result = if shuffle {
            let mut rng = DetRng::new(seed ^ (run as u64).wrapping_mul(0x9E3779B97F4A7C15));
            rng.shuffle(&mut order);
            sum_f32_ordered(values, &order)
        } else {
            sum_in_order(values)
        };
        patterns.insert(result.to_bits());
        let dev = (result as f64 - reference as f64).abs();
        max_abs = max_abs.max(dev);
        if reference != 0.0 {
            max_rel = max_rel.max(dev / (reference as f64).abs());
        }
    }
    DeviationStats {
        max_abs_deviation: max_abs,
        max_rel_deviation: max_rel,
        distinct_results: patterns.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Attention-like partial contributions: zero-mean, heavy-ish tails
    /// (products of gaussians), magnitudes ~O(1).
    fn attention_like(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = DetRng::new(seed);
        (0..n)
            .map(|_| {
                let a = rng.gen_f32_range(-1.0, 1.0);
                let b = rng.gen_f32_range(-1.0, 1.0);
                a * b * 4.0
            })
            .collect()
    }

    #[test]
    fn paper_motivating_example() {
        // (1e8 + 1e-6) - 1e8 = 0 in f32; 1e8 - 1e8 + 1e-6 = 1e-6.
        let v = [1e8f32, 1e-6, -1e8];
        assert_eq!(sum_f32_ordered(&v, &[0, 1, 2]), 0.0);
        assert_eq!(sum_f32_ordered(&v, &[0, 2, 1]), 1e-6);
    }

    #[test]
    fn fixed_order_is_bitwise_deterministic() {
        let v = attention_like(4096, 7);
        let s = deviation_across_orders(&v, 10, false, 42);
        assert_eq!(s.distinct_results, 1);
        assert_eq!(s.max_abs_deviation, 0.0);
    }

    #[test]
    fn shuffled_orders_deviate() {
        let v = attention_like(4096, 7);
        let s = deviation_across_orders(&v, 10, true, 42);
        assert!(s.distinct_results > 1, "shuffles should produce different bits");
        assert!(s.max_abs_deviation > 0.0);
        // O(1e-4) at these scales (Table 1's order of magnitude).
        assert!(
            s.max_abs_deviation > 1e-7 && s.max_abs_deviation < 1e-1,
            "deviation {} outside plausible band",
            s.max_abs_deviation
        );
    }

    #[test]
    fn kahan_close_to_f64_truth() {
        let v = attention_like(10000, 3);
        let truth: f64 = v.iter().map(|&x| x as f64).sum();
        assert!((kahan_sum(&v) - truth).abs() < 1e-3);
    }

    #[test]
    fn pairwise_deterministic_but_distinct_order() {
        let v = attention_like(4096, 9);
        let a = pairwise_sum(&v);
        let b = pairwise_sum(&v);
        assert_eq!(a.to_bits(), b.to_bits());
        // Usually differs from the serial fold (not guaranteed, but at this
        // size the probability of exact agreement is negligible).
        assert_ne!(a.to_bits(), sum_in_order(&v).to_bits());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(sum_in_order(&[]), 0.0);
        assert_eq!(pairwise_sum(&[3.5]), 3.5);
    }

    // ---- reduce_tiles_ordered: the executor's dependency surface --------

    #[test]
    fn tile_reduce_empty_chain_is_zeros() {
        // A dQ tile with no live KV contributions folds nothing.
        for p in [Precision::F32, Precision::Bf16] {
            assert_eq!(reduce_tiles_ordered(3, &[], &[], p), vec![0.0; 3]);
            // Contributors may exist but the order may select none.
            let parts = vec![vec![1.0f32, 2.0, 3.0]];
            assert_eq!(reduce_tiles_ordered(3, &parts, &[], p), vec![0.0; 3]);
        }
    }

    #[test]
    fn tile_reduce_single_element_chain_is_identity_mod_storage() {
        let parts = vec![vec![1.5f32, -2.25, 1e-8]];
        // f32: bit-exact identity.
        let f = reduce_tiles_ordered(3, &parts, &[0], Precision::F32);
        assert!(f.iter().zip(&parts[0]).all(|(a, b)| a.to_bits() == b.to_bits()));
        // bf16: identity modulo the storage rounding of each element.
        let b = reduce_tiles_ordered(3, &parts, &[0], Precision::Bf16);
        for (got, want) in b.iter().zip(&parts[0]) {
            assert_eq!(*got, Bf16::from_f32(*want).to_f32());
        }
    }

    #[test]
    fn tile_reduce_nan_and_inf_propagate() {
        let parts = vec![vec![f32::NAN, f32::INFINITY], vec![1.0, f32::NEG_INFINITY]];
        for p in [Precision::F32, Precision::Bf16] {
            let r = reduce_tiles_ordered(2, &parts, &[0, 1], p);
            assert!(r[0].is_nan(), "{p:?}: NaN must survive the fold");
            assert!(r[1].is_nan(), "{p:?}: inf + -inf must produce NaN");
        }
        // Same-signed infinities stay infinite.
        let parts = vec![vec![f32::INFINITY], vec![f32::INFINITY]];
        for p in [Precision::F32, Precision::Bf16] {
            assert_eq!(reduce_tiles_ordered(1, &parts, &[0, 1], p), vec![f32::INFINITY]);
        }
    }

    #[test]
    fn tile_reduce_bf16_is_order_sensitive_where_f32_is_not() {
        // 256 + 0.5 - 256: exact in f32 in every order (256.5 is
        // representable), but bf16 rounds 256.5 -> 256, so the fold order
        // decides whether the 0.5 survives — the exact property the
        // determinism oracle exploits to catch atomic accumulation in bf16.
        let parts = vec![vec![256.0f32], vec![0.5], vec![-256.0]];
        let f_a = reduce_tiles_ordered(1, &parts, &[0, 1, 2], Precision::F32);
        let f_b = reduce_tiles_ordered(1, &parts, &[0, 2, 1], Precision::F32);
        assert_eq!(f_a[0].to_bits(), f_b[0].to_bits(), "f32 fold is exact here");
        assert_eq!(f_a, vec![0.5]);
        let b_a = reduce_tiles_ordered(1, &parts, &[0, 1, 2], Precision::Bf16);
        let b_b = reduce_tiles_ordered(1, &parts, &[0, 2, 1], Precision::Bf16);
        assert_eq!(b_a, vec![0.0], "0.5 absorbed into 256 in bf16");
        assert_eq!(b_b, vec![0.5], "fold the large values first and it survives");
        assert_ne!(b_a[0].to_bits(), b_b[0].to_bits());
    }

    #[test]
    fn tile_reduce_f32_order_sensitivity_at_scale() {
        // At attention-like scales the f32 fold is order-sensitive too —
        // determinism requires fixing the order even in f32.
        let parts: Vec<Vec<f32>> =
            attention_like(4096, 11).into_iter().map(|x| vec![x]).collect();
        let fwd: Vec<usize> = (0..parts.len()).collect();
        let rev: Vec<usize> = (0..parts.len()).rev().collect();
        let a = reduce_tiles_ordered(1, &parts, &fwd, Precision::F32);
        let b = reduce_tiles_ordered(1, &parts, &rev, Precision::F32);
        assert_ne!(a[0].to_bits(), b[0].to_bits());
        // Same order twice: bitwise identical in both precisions.
        for p in [Precision::F32, Precision::Bf16] {
            let x = reduce_tiles_ordered(1, &parts, &fwd, p);
            let y = reduce_tiles_ordered(1, &parts, &fwd, p);
            assert_eq!(x[0].to_bits(), y[0].to_bits());
        }
    }

    #[test]
    fn precision_names_round_trip() {
        for p in [Precision::F32, Precision::Bf16] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("fp64"), None);
    }
}
