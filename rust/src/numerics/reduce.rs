//! Order-controlled reductions and run-to-run deviation statistics —
//! the Rust half of the paper's Table 1 experiment.
//!
//! A deterministic attention backward pass folds each dQ element's partial
//! contributions in a *fixed* order; atomicAdd folds them in whatever order
//! CTAs complete. Because FP addition is non-associative, the latter gives
//! run-to-run deviations of `O(1e-4)` at bf16/attention scales while the
//! former is bitwise stable — exactly what [`deviation_across_orders`]
//! measures.

use crate::util::DetRng;

/// Fold `values` left-to-right in f32 following `order` (indices into
/// `values`). This is the serialized deterministic accumulation.
pub fn sum_f32_ordered(values: &[f32], order: &[usize]) -> f32 {
    let mut acc = 0.0f32;
    for &i in order {
        acc += values[i];
    }
    acc
}

/// Fold in natural order.
pub fn sum_in_order(values: &[f32]) -> f32 {
    let order: Vec<usize> = (0..values.len()).collect();
    sum_f32_ordered(values, &order)
}

/// Kahan-compensated sum — reference for "how much error does *any* plain
/// order carry" (near-exact).
pub fn kahan_sum(values: &[f32]) -> f64 {
    let mut sum = 0.0f32;
    let mut c = 0.0f32;
    for &v in values {
        let y = v - c;
        let t = sum + y;
        c = (t - sum) - y;
        sum = t;
    }
    sum as f64
}

/// Pairwise (tree) sum — the order GPU warp-reductions typically use for
/// intra-CTA (deterministic, but a *different* deterministic answer than
/// serial order, demonstrating that determinism fixes an order, not the
/// "true" value).
pub fn pairwise_sum(values: &[f32]) -> f32 {
    match values.len() {
        0 => 0.0,
        1 => values[0],
        n => {
            let mid = n / 2;
            pairwise_sum(&values[..mid]) + pairwise_sum(&values[mid..])
        }
    }
}

/// Deviation statistics across permuted accumulation orders.
#[derive(Debug, Clone, Copy)]
pub struct DeviationStats {
    /// Max |x_run - x_ref| over runs (the paper's `M_r`), where the
    /// reference is the fixed-order result.
    pub max_abs_deviation: f64,
    /// Max relative deviation |x_run - x_ref| / |x_ref|.
    pub max_rel_deviation: f64,
    /// Number of distinct bit patterns observed (1 = bitwise determinism).
    pub distinct_results: usize,
}

/// Run the Table 1 experiment on a vector of partial contributions:
/// `runs` shuffled-order accumulations (seeded per run, modelling
/// uncontrolled CTA completion order) compared against the fixed-order
/// reference. With `shuffle = false` every run uses the fixed order and
/// must produce `distinct_results == 1`.
pub fn deviation_across_orders(values: &[f32], runs: usize, shuffle: bool, seed: u64) -> DeviationStats {
    let reference = sum_in_order(values);
    let mut max_abs = 0.0f64;
    let mut max_rel = 0.0f64;
    let mut patterns = std::collections::HashSet::new();
    patterns.insert(reference.to_bits());
    let mut order: Vec<usize> = (0..values.len()).collect();
    for run in 0..runs {
        let result = if shuffle {
            let mut rng = DetRng::new(seed ^ (run as u64).wrapping_mul(0x9E3779B97F4A7C15));
            rng.shuffle(&mut order);
            sum_f32_ordered(values, &order)
        } else {
            sum_in_order(values)
        };
        patterns.insert(result.to_bits());
        let dev = (result as f64 - reference as f64).abs();
        max_abs = max_abs.max(dev);
        if reference != 0.0 {
            max_rel = max_rel.max(dev / (reference as f64).abs());
        }
    }
    DeviationStats {
        max_abs_deviation: max_abs,
        max_rel_deviation: max_rel,
        distinct_results: patterns.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Attention-like partial contributions: zero-mean, heavy-ish tails
    /// (products of gaussians), magnitudes ~O(1).
    fn attention_like(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = DetRng::new(seed);
        (0..n)
            .map(|_| {
                let a = rng.gen_f32_range(-1.0, 1.0);
                let b = rng.gen_f32_range(-1.0, 1.0);
                a * b * 4.0
            })
            .collect()
    }

    #[test]
    fn paper_motivating_example() {
        // (1e8 + 1e-6) - 1e8 = 0 in f32; 1e8 - 1e8 + 1e-6 = 1e-6.
        let v = [1e8f32, 1e-6, -1e8];
        assert_eq!(sum_f32_ordered(&v, &[0, 1, 2]), 0.0);
        assert_eq!(sum_f32_ordered(&v, &[0, 2, 1]), 1e-6);
    }

    #[test]
    fn fixed_order_is_bitwise_deterministic() {
        let v = attention_like(4096, 7);
        let s = deviation_across_orders(&v, 10, false, 42);
        assert_eq!(s.distinct_results, 1);
        assert_eq!(s.max_abs_deviation, 0.0);
    }

    #[test]
    fn shuffled_orders_deviate() {
        let v = attention_like(4096, 7);
        let s = deviation_across_orders(&v, 10, true, 42);
        assert!(s.distinct_results > 1, "shuffles should produce different bits");
        assert!(s.max_abs_deviation > 0.0);
        // O(1e-4) at these scales (Table 1's order of magnitude).
        assert!(
            s.max_abs_deviation > 1e-7 && s.max_abs_deviation < 1e-1,
            "deviation {} outside plausible band",
            s.max_abs_deviation
        );
    }

    #[test]
    fn kahan_close_to_f64_truth() {
        let v = attention_like(10000, 3);
        let truth: f64 = v.iter().map(|&x| x as f64).sum();
        assert!((kahan_sum(&v) - truth).abs() < 1e-3);
    }

    #[test]
    fn pairwise_deterministic_but_distinct_order() {
        let v = attention_like(4096, 9);
        let a = pairwise_sum(&v);
        let b = pairwise_sum(&v);
        assert_eq!(a.to_bits(), b.to_bits());
        // Usually differs from the serial fold (not guaranteed, but at this
        // size the probability of exact agreement is negligible).
        assert_ne!(a.to_bits(), sum_in_order(&v).to_bits());
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(sum_in_order(&[]), 0.0);
        assert_eq!(pairwise_sum(&[3.5]), 3.5);
    }
}
