//! Software bfloat16: f32 with the bottom 16 mantissa bits rounded away
//! (round-to-nearest-even), matching the BF16 storage the paper benchmarks
//! with. Implemented locally (no `half` dependency) so the accumulation
//! semantics are fully auditable.


/// A bfloat16 value stored as its 16-bit pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Bf16(pub u16);

impl Bf16 {
    /// Positive zero.
    pub const ZERO: Bf16 = Bf16(0);

    /// Round an f32 to bf16 (round-to-nearest-even), as TPU/GPU hardware
    /// converts on store.
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // Quiet NaN, preserving sign.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    /// Widen to f32 (exact).
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }

    /// bf16 addition: widen, add in f32, round back — the arithmetic a
    /// bf16 accumulator in bf16 storage performs.
    pub fn add(self, other: Bf16) -> Bf16 {
        Bf16::from_f32(self.to_f32() + other.to_f32())
    }
}

impl From<f32> for Bf16 {
    fn from(x: f32) -> Self {
        Bf16::from_f32(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for x in [0.0f32, 1.0, -2.5, 0.5, 65280.0] {
            assert_eq!(Bf16::from_f32(x).to_f32(), x, "{x} should be exact in bf16");
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // bf16 keeps 7 mantissa bits, so near 1.0 the tie sits at 2^-8 —
        // exactly between bf16(1.0) and the next value 1.0078125; ties go
        // to even (1.0).
        let x = 1.0f32 + f32::powi(2.0, -8);
        assert_eq!(Bf16::from_f32(x).to_f32(), 1.0);
        // Slightly above the midpoint rounds up.
        let y = 1.0f32 + f32::powi(2.0, -8) + f32::powi(2.0, -16);
        assert_eq!(Bf16::from_f32(y).to_f32(), 1.0078125);
        // Below the midpoint rounds down.
        let z = 1.0f32 + f32::powi(2.0, -9);
        assert_eq!(Bf16::from_f32(z).to_f32(), 1.0);
    }

    #[test]
    fn nan_preserved() {
        assert!(Bf16::from_f32(f32::NAN).to_f32().is_nan());
    }

    #[test]
    fn addition_is_lossy_and_order_sensitive() {
        // (big + small) + (-big) != big + (small + (-big)) in bf16.
        let big = Bf16::from_f32(256.0);
        let small = Bf16::from_f32(0.5);
        let neg = Bf16::from_f32(-256.0);
        let a = big.add(small).add(neg);
        let b = big.add(neg).add(small);
        assert_ne!(a, b);
        assert_eq!(b.to_f32(), 0.5); // exact order recovers the small value
        assert_eq!(a.to_f32(), 0.0); // 256.5 rounds to 256 in bf16
    }

    #[test]
    fn infinity_saturates_correctly() {
        assert_eq!(Bf16::from_f32(f32::INFINITY).to_f32(), f32::INFINITY);
        assert_eq!(Bf16::from_f32(f32::NEG_INFINITY).to_f32(), f32::NEG_INFINITY);
    }
}
