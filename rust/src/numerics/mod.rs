//! Floating-point reduction-order experiments — the arithmetic foundation
//! of the paper (§1: non-associativity of FP addition is *why* atomicAdd
//! accumulation is non-deterministic) and the Rust-side half of Table 1.
//!
//! Provides a software bf16 (round-to-nearest-even truncation of f32, the
//! storage format of the paper's benchmarks), order-controlled reductions,
//! and deviation statistics across permuted accumulation orders.

mod bf16;
mod reduce;

pub use bf16::Bf16;
pub use reduce::{
    deviation_across_orders, kahan_sum, pairwise_sum, reduce_tiles_ordered, sum_f32_ordered,
    sum_in_order, DeviationStats, Precision,
};
