//! Bitwise run fingerprints and reproducibility manifests.
//!
//! Two layers of attestation:
//!
//! * [`RunFingerprint`] — Table 1's methodology applied to entire training
//!   runs: two runs are *reproducible* iff their parameter fingerprints
//!   agree bit-for-bit at every logged step.
//! * [`ReproManifest`] — a persisted claim about *numeric state*, not just
//!   configuration: alongside the workload coordinates it records the
//!   gradient content hash the tile executor ([`crate::exec`]) produced,
//!   so a manifest round-trip (`dash verify --manifest` / `--check`)
//!   re-executes the backward pass and attests the bits, instead of
//!   merely re-reading a config fingerprint.

use crate::exec::{ExecConfig, ExecResult};
use crate::numerics::Precision;
use crate::util::Json;

/// FNV-1a over the exact bit patterns of a float slice — insensitive to
/// -0.0/NaN collapses, sensitive to a single ULP anywhere.
pub fn fingerprint_f32(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in data {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Fingerprint of a full parameter set (order-sensitive across tensors).
pub fn fingerprint_params<'a>(tensors: impl IntoIterator<Item = &'a [f32]>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for t in tensors {
        let f = fingerprint_f32(t);
        for b in f.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// The fingerprint trace of one training run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunFingerprint {
    /// (step, params fingerprint) pairs.
    pub checkpoints: Vec<(usize, u64)>,
    /// Final loss bits (exact).
    pub final_loss_bits: u32,
}

impl RunFingerprint {
    /// Create empty.
    pub fn new() -> Self {
        Self { checkpoints: Vec::new(), final_loss_bits: 0 }
    }

    /// Record a checkpoint.
    pub fn record(&mut self, step: usize, fingerprint: u64) {
        self.checkpoints.push((step, fingerprint));
    }

    /// First step where two runs diverge, if any.
    pub fn first_divergence(&self, other: &Self) -> Option<usize> {
        for ((s1, f1), (s2, f2)) in self.checkpoints.iter().zip(&other.checkpoints) {
            debug_assert_eq!(s1, s2, "fingerprints sampled at different steps");
            if f1 != f2 {
                return Some(*s1);
            }
        }
        None
    }

    /// Bitwise-identical runs?
    pub fn matches(&self, other: &Self) -> bool {
        self.checkpoints == other.checkpoints && self.final_loss_bits == other.final_loss_bits
    }
}

impl Default for RunFingerprint {
    fn default() -> Self {
        Self::new()
    }
}

/// Manifest format version (bump on incompatible field changes).
/// v2 added the mandatory `trace_hash` field — the content hash of the
/// canonical executor trace ([`crate::trace::SimTrace::content_hash`]).
const MANIFEST_VERSION: f64 = 2.0;

/// A persisted reproducibility claim: the workload coordinates of one
/// executor run plus the gradient hashes it produced. `dash verify
/// --check` rebuilds the schedule from these coordinates, re-executes the
/// backward pass, and compares via [`ReproManifest::attests`] — a manifest
/// that round-trips therefore proves the *numeric* state reproduced, not
/// merely that the configuration was unchanged.
#[derive(Debug, Clone, PartialEq)]
pub struct ReproManifest {
    /// Schedule name ([`crate::schedule::ScheduleKind::name`] spelling).
    pub schedule: String,
    /// Mask spelling ([`crate::mask::MaskSpec::name`]).
    pub mask: String,
    /// KV tiles.
    pub n_kv: usize,
    /// Q tiles.
    pub n_q: usize,
    /// Head instances.
    pub n_heads: usize,
    /// Executor tile side (elements).
    pub block: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Accumulation/storage precision of the attested run.
    pub precision: Precision,
    /// Data seed.
    pub seed: u64,
    /// Combined gradient content hash.
    pub grad_hash: u64,
    /// dQ content hash.
    pub dq_hash: u64,
    /// dK content hash.
    pub dk_hash: u64,
    /// dV content hash.
    pub dv_hash: u64,
    /// Content hash of the run's canonical executor trace
    /// ([`crate::trace::trace_execution`] +
    /// [`crate::trace::SimTrace::content_hash`]): the *schedule timeline*
    /// is attested alongside the numeric state. `0` = not recorded.
    pub trace_hash: u64,
    /// FLOPs the run executed (the analytic cross-check value).
    pub flops: f64,
}

impl ReproManifest {
    /// Build a manifest from one executor run.
    pub fn from_exec(
        schedule: &str,
        mask: &str,
        spec: &crate::schedule::ProblemSpec,
        cfg: &ExecConfig,
        r: &ExecResult,
    ) -> Self {
        Self {
            schedule: schedule.to_string(),
            mask: mask.to_string(),
            n_kv: spec.n_kv,
            n_q: spec.n_q,
            n_heads: spec.n_heads,
            block: cfg.block,
            head_dim: cfg.head_dim,
            precision: cfg.precision,
            seed: cfg.seed,
            grad_hash: r.grad_hash,
            dq_hash: r.dq_hash,
            dk_hash: r.dk_hash,
            dv_hash: r.dv_hash,
            trace_hash: 0,
            flops: r.flops,
        }
    }

    /// Stamp the canonical executor-trace hash (builder style):
    /// `ReproManifest::from_exec(...).with_trace_hash(trace.content_hash())`.
    pub fn with_trace_hash(mut self, h: u64) -> Self {
        self.trace_hash = h;
        self
    }

    /// Does a re-execution reproduce the attested numeric state exactly
    /// (every hash and the executed FLOP count)?
    pub fn attests(&self, r: &ExecResult) -> bool {
        self.grad_hash == r.grad_hash
            && self.dq_hash == r.dq_hash
            && self.dk_hash == r.dk_hash
            && self.dv_hash == r.dv_hash
            && self.flops == r.flops
    }

    /// Serialize. Hashes are spelled as 16-digit hex strings — JSON
    /// numbers are f64 and would corrupt them above 2^53.
    pub fn to_json(&self) -> Json {
        let hex = |h: u64| Json::Str(format!("{h:016x}"));
        Json::Obj(vec![
            ("version".into(), Json::Num(MANIFEST_VERSION)),
            ("schedule".into(), Json::Str(self.schedule.clone())),
            ("mask".into(), Json::Str(self.mask.clone())),
            ("n_kv".into(), Json::Num(self.n_kv as f64)),
            ("n_q".into(), Json::Num(self.n_q as f64)),
            ("n_heads".into(), Json::Num(self.n_heads as f64)),
            ("block".into(), Json::Num(self.block as f64)),
            ("head_dim".into(), Json::Num(self.head_dim as f64)),
            ("precision".into(), Json::Str(self.precision.name().into())),
            ("seed".into(), Json::Str(format!("{:016x}", self.seed))),
            ("grad_hash".into(), hex(self.grad_hash)),
            ("dq_hash".into(), hex(self.dq_hash)),
            ("dk_hash".into(), hex(self.dk_hash)),
            ("dv_hash".into(), hex(self.dv_hash)),
            ("trace_hash".into(), hex(self.trace_hash)),
            ("flops".into(), Json::Num(self.flops)),
        ])
    }

    /// Deserialize (inverse of [`ReproManifest::to_json`]).
    pub fn from_json(j: &Json) -> crate::Result<Self> {
        let field = |k: &str| j.get(k).ok_or_else(|| anyhow::anyhow!("manifest missing '{k}'"));
        let num = |k: &str| -> crate::Result<usize> {
            field(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest field '{k}' not an integer"))
        };
        let hex = |k: &str| -> crate::Result<u64> {
            let s = field(k)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest field '{k}' not a string"))?;
            u64::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("manifest field '{k}' not hex"))
        };
        let version = field("version")?.as_f64().unwrap_or(0.0);
        anyhow::ensure!(version == MANIFEST_VERSION, "unsupported manifest version {version}");
        let precision_name = field("precision")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("manifest field 'precision' not a string"))?;
        Ok(Self {
            schedule: field("schedule")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest field 'schedule' not a string"))?
                .to_string(),
            mask: field("mask")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest field 'mask' not a string"))?
                .to_string(),
            n_kv: num("n_kv")?,
            n_q: num("n_q")?,
            n_heads: num("n_heads")?,
            block: num("block")?,
            head_dim: num("head_dim")?,
            precision: Precision::parse(precision_name)
                .ok_or_else(|| anyhow::anyhow!("unknown manifest precision '{precision_name}'"))?,
            seed: hex("seed")?,
            grad_hash: hex("grad_hash")?,
            dq_hash: hex("dq_hash")?,
            dk_hash: hex("dk_hash")?,
            dv_hash: hex("dv_hash")?,
            trace_hash: hex("trace_hash")?,
            flops: field("flops")?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("manifest field 'flops' not a number"))?,
        })
    }

    /// Write to disk as pretty-enough JSON.
    pub fn save(&self, path: &str) -> crate::Result<()> {
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    /// Read from disk.
    pub fn load(path: &str) -> crate::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_ulp_changes_fingerprint() {
        let a = vec![1.0f32; 100];
        let mut b = a.clone();
        b[57] = f32::from_bits(b[57].to_bits() + 1);
        assert_ne!(fingerprint_f32(&a), fingerprint_f32(&b));
    }

    #[test]
    fn negative_zero_distinct_from_zero() {
        assert_ne!(fingerprint_f32(&[0.0]), fingerprint_f32(&[-0.0]));
    }

    #[test]
    fn tensor_order_matters() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32];
        assert_ne!(
            fingerprint_params([&a[..], &b[..]]),
            fingerprint_params([&b[..], &a[..]])
        );
    }

    #[test]
    fn manifest_round_trips_and_attests() {
        use crate::exec::{execute_backward, ExecConfig};
        use crate::mask::MaskSpec;
        use crate::schedule::{fa3, ProblemSpec};

        let spec = ProblemSpec::square(3, 2, MaskSpec::causal());
        let s = fa3(&spec, true);
        let cfg = ExecConfig::new(21);
        let r = execute_backward(&s, &cfg).unwrap();
        let trace = crate::trace::trace_execution(&s, &cfg);
        let m = ReproManifest::from_exec("fa3-det", &spec.mask.name(), &spec, &cfg, &r)
            .with_trace_hash(trace.content_hash());
        assert!(m.attests(&r));
        assert_eq!(m.trace_hash, trace.content_hash());

        // JSON round trip preserves every field exactly (hashes are hex
        // strings, immune to f64 truncation).
        let back = ReproManifest::from_json(&m.to_json()).unwrap();
        assert_eq!(back, m);

        // A re-execution with the same coordinates attests...
        let again = execute_backward(&s, &cfg).unwrap();
        assert!(m.attests(&again));
        // ...and a different seed's numeric state does not.
        let other = execute_backward(&s, &ExecConfig::new(22)).unwrap();
        assert!(!m.attests(&other));
    }

    #[test]
    fn manifest_file_round_trip() {
        use crate::exec::{execute_backward, ExecConfig};
        use crate::mask::MaskSpec;
        use crate::schedule::{fa3, ProblemSpec};

        let spec = ProblemSpec::square(2, 1, MaskSpec::full());
        let cfg = ExecConfig::new(5);
        let r = execute_backward(&fa3(&spec, true), &cfg).unwrap();
        let m = ReproManifest::from_exec("fa3-det", "full", &spec, &cfg, &r);
        let path = std::env::temp_dir()
            .join(format!("dash-manifest-{}.json", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        m.save(&path_s).unwrap();
        let back = ReproManifest::load(&path_s).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back, m);
    }

    #[test]
    fn malformed_manifests_are_rejected() {
        use crate::util::Json;
        assert!(ReproManifest::from_json(&Json::Obj(vec![])).is_err());
        let mut j = Json::parse(
            r#"{"version":2,"schedule":"fa3-det","mask":"full","n_kv":2,"n_q":2,
                "n_heads":1,"block":4,"head_dim":8,"precision":"f32",
                "seed":"0000000000000005","grad_hash":"00ff","dq_hash":"01",
                "dk_hash":"02","dv_hash":"03","trace_hash":"04","flops":10.0}"#,
        )
        .unwrap();
        assert!(ReproManifest::from_json(&j).is_ok());
        // A v1 manifest (no trace_hash) is rejected, not misread.
        if let Json::Obj(fields) = &j {
            let mut v1: Vec<(String, Json)> = fields
                .iter()
                .filter(|(k, _)| k != "trace_hash")
                .cloned()
                .collect();
            for (k, v) in v1.iter_mut() {
                if k == "version" {
                    *v = Json::Num(1.0);
                }
            }
            assert!(ReproManifest::from_json(&Json::Obj(v1)).is_err());
        }
        if let Json::Obj(fields) = &mut j {
            for (k, v) in fields.iter_mut() {
                if k == "precision" {
                    *v = Json::Str("fp8".into());
                }
            }
        }
        assert!(ReproManifest::from_json(&j).is_err());
    }

    #[test]
    fn divergence_detection() {
        let mut r1 = RunFingerprint::new();
        let mut r2 = RunFingerprint::new();
        for s in 0..5 {
            r1.record(s, s as u64);
            r2.record(s, if s < 3 { s as u64 } else { 999 });
        }
        assert_eq!(r1.first_divergence(&r2), Some(3));
        assert!(!r1.matches(&r2));
        assert!(r1.matches(&r1.clone()));
    }
}
