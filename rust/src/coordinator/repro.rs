//! Bitwise run fingerprints: Table 1's methodology applied to entire
//! training runs. Two runs are *reproducible* iff their parameter
//! fingerprints agree bit-for-bit at every logged step.


/// FNV-1a over the exact bit patterns of a float slice — insensitive to
/// -0.0/NaN collapses, sensitive to a single ULP anywhere.
pub fn fingerprint_f32(data: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &x in data {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// Fingerprint of a full parameter set (order-sensitive across tensors).
pub fn fingerprint_params<'a>(tensors: impl IntoIterator<Item = &'a [f32]>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for t in tensors {
        let f = fingerprint_f32(t);
        for b in f.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// The fingerprint trace of one training run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RunFingerprint {
    /// (step, params fingerprint) pairs.
    pub checkpoints: Vec<(usize, u64)>,
    /// Final loss bits (exact).
    pub final_loss_bits: u32,
}

impl RunFingerprint {
    /// Create empty.
    pub fn new() -> Self {
        Self { checkpoints: Vec::new(), final_loss_bits: 0 }
    }

    /// Record a checkpoint.
    pub fn record(&mut self, step: usize, fingerprint: u64) {
        self.checkpoints.push((step, fingerprint));
    }

    /// First step where two runs diverge, if any.
    pub fn first_divergence(&self, other: &Self) -> Option<usize> {
        for ((s1, f1), (s2, f2)) in self.checkpoints.iter().zip(&other.checkpoints) {
            debug_assert_eq!(s1, s2, "fingerprints sampled at different steps");
            if f1 != f2 {
                return Some(*s1);
            }
        }
        None
    }

    /// Bitwise-identical runs?
    pub fn matches(&self, other: &Self) -> bool {
        self.checkpoints == other.checkpoints && self.final_loss_bits == other.final_loss_bits
    }
}

impl Default for RunFingerprint {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_ulp_changes_fingerprint() {
        let a = vec![1.0f32; 100];
        let mut b = a.clone();
        b[57] = f32::from_bits(b[57].to_bits() + 1);
        assert_ne!(fingerprint_f32(&a), fingerprint_f32(&b));
    }

    #[test]
    fn negative_zero_distinct_from_zero() {
        assert_ne!(fingerprint_f32(&[0.0]), fingerprint_f32(&[-0.0]));
    }

    #[test]
    fn tensor_order_matters() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32];
        assert_ne!(
            fingerprint_params([&a[..], &b[..]]),
            fingerprint_params([&b[..], &a[..]])
        );
    }

    #[test]
    fn divergence_detection() {
        let mut r1 = RunFingerprint::new();
        let mut r2 = RunFingerprint::new();
        for s in 0..5 {
            r1.record(s, s as u64);
            r2.record(s, if s < 3 { s as u64 } else { 999 });
        }
        assert_eq!(r1.first_divergence(&r2), Some(3));
        assert!(!r1.matches(&r2));
        assert!(r1.matches(&r1.clone()));
    }
}
