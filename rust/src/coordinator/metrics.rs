//! Training metrics: loss history, step timing, token throughput, CSV dump.

use std::time::Instant;

/// One logged step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    /// Optimizer step index.
    pub step: usize,
    /// Cross-entropy loss (nats).
    pub loss: f32,
    /// Wall-clock step time, seconds.
    pub step_seconds: f64,
    /// Tokens processed this step.
    pub tokens: usize,
}

/// Accumulating run metrics.
#[derive(Debug)]
pub struct TrainMetrics {
    records: Vec<StepRecord>,
    step_start: Option<Instant>,
}

impl TrainMetrics {
    /// New, empty.
    pub fn new() -> Self {
        Self { records: Vec::new(), step_start: None }
    }

    /// Mark step start.
    pub fn begin_step(&mut self) {
        self.step_start = Some(Instant::now());
    }

    /// Mark step end and record.
    pub fn end_step(&mut self, step: usize, loss: f32, tokens: usize) {
        let dt = self.step_start.take().map(|t| t.elapsed().as_secs_f64()).unwrap_or(0.0);
        self.records.push(StepRecord { step, loss, step_seconds: dt, tokens });
    }

    /// All records.
    pub fn records(&self) -> &[StepRecord] {
        &self.records
    }

    /// Mean tokens/second over the run (excluding the first, compile-warm
    /// step).
    pub fn tokens_per_second(&self) -> f64 {
        let steady: Vec<&StepRecord> = self.records.iter().skip(1).collect();
        let t: f64 = steady.iter().map(|r| r.step_seconds).sum();
        let toks: usize = steady.iter().map(|r| r.tokens).sum();
        if t > 0.0 {
            toks as f64 / t
        } else {
            0.0
        }
    }

    /// Smoothed final loss (mean of last k records).
    pub fn final_loss(&self, k: usize) -> f32 {
        let n = self.records.len();
        if n == 0 {
            return f32::NAN;
        }
        let tail = &self.records[n.saturating_sub(k)..];
        tail.iter().map(|r| r.loss).sum::<f32>() / tail.len() as f32
    }

    /// First loss (for "did it learn" checks).
    pub fn first_loss(&self) -> f32 {
        self.records.first().map(|r| r.loss).unwrap_or(f32::NAN)
    }

    /// Dump a CSV of the loss curve.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss,step_seconds,tokens\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.6},{:.4},{}\n",
                r.step, r.loss, r.step_seconds, r.tokens
            ));
        }
        out
    }
}

impl Default for TrainMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_summarize() {
        let mut m = TrainMetrics::new();
        for s in 0..10 {
            m.begin_step();
            m.end_step(s, 6.0 - s as f32 * 0.2, 1024);
        }
        assert_eq!(m.records().len(), 10);
        assert!(m.final_loss(3) < m.first_loss());
        assert!(m.tokens_per_second() > 0.0);
    }

    #[test]
    fn csv_format() {
        let mut m = TrainMetrics::new();
        m.begin_step();
        m.end_step(0, 1.5, 64);
        let csv = m.to_csv();
        assert!(csv.starts_with("step,loss"));
        assert!(csv.contains("0,1.500000"));
    }

    #[test]
    fn empty_metrics_safe() {
        let m = TrainMetrics::new();
        assert!(m.final_loss(5).is_nan());
        assert_eq!(m.tokens_per_second(), 0.0);
    }
}
