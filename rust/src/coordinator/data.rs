//! Deterministic synthetic corpus: a seeded order-1 Markov "language" with
//! Zipfian successor structure (each token has 4 preferred successors with
//! weights 1, 1/2, 1/3, 1/4). A transformer LM trained on it shows a real
//! loss curve — cross-entropy drops from ~ln(V) toward the chain's ~1.8-nat
//! entropy floor as the model memorizes the transition table — which is
//! what the end-to-end driver (`dash train`) logs.
//!
//! Every batch is a pure function of (seed, step, microbatch) — the
//! prerequisite for bitwise run-to-run reproducibility.

use crate::util::DetRng;

/// Synthetic corpus generator.
#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: usize,
    seed: u64,
    /// Per-state transition sparsity: each (prev, cur) state prefers a
    /// small set of successors, giving the chain low entropy to learn.
    branch: usize,
}

impl SyntheticCorpus {
    /// Create a corpus over `vocab` tokens.
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self { vocab, seed, branch: 4 }
    }

    /// Deterministic successor distribution for a token (hash-derived, not
    /// stored — the corpus is infinite and memory-free). Order-1 keeps the
    /// state space equal to the vocabulary, so a small model can actually
    /// learn the transition table from a few hundred batches.
    fn successors(&self, b: u32) -> ([u32; 4], [f32; 4]) {
        let state = (b as u64 + 1).wrapping_mul(0x2545F4914F6CDD1D);
        let mut rng = DetRng::new(self.seed ^ state);
        let mut toks = [0u32; 4];
        let mut w = [0f32; 4];
        for i in 0..self.branch.min(4) {
            toks[i] = rng.gen_range(self.vocab) as u32;
            // Zipf-ish weights 1, 1/2, 1/3, 1/4.
            w[i] = 1.0 / (i as f32 + 1.0);
        }
        (toks, w)
    }

    /// Generate one sample of `seqlen + 1` tokens (inputs + shifted
    /// targets), keyed by (step, index).
    pub fn sample(&self, step: usize, index: usize, seqlen: usize) -> Vec<i32> {
        let mut rng = DetRng::new(
            self.seed
                ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (index as u64).wrapping_mul(0xD1B54A32D192ED03),
        );
        let mut out = Vec::with_capacity(seqlen + 1);
        let mut b = rng.gen_range(self.vocab) as u32;
        out.push(b as i32);
        while out.len() < seqlen + 1 {
            let (toks, w) = self.successors(b);
            let next = toks[rng.weighted(&w)];
            out.push(next as i32);
            b = next;
        }
        out.truncate(seqlen + 1);
        out
    }

    /// A full (inputs, targets) microbatch, flattened row-major
    /// `[micro_batch, seqlen]`.
    pub fn batch(
        &self,
        step: usize,
        microbatch: usize,
        micro_batch_size: usize,
        seqlen: usize,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut inputs = Vec::with_capacity(micro_batch_size * seqlen);
        let mut targets = Vec::with_capacity(micro_batch_size * seqlen);
        for i in 0..micro_batch_size {
            let row = self.sample(step, microbatch * micro_batch_size + i, seqlen);
            inputs.extend(&row[..seqlen]);
            targets.extend(&row[1..=seqlen]);
        }
        (inputs, targets)
    }

    /// Entropy floor of the chain in nats (approximate): the weighted
    /// entropy of the 4-way Zipf successor distribution. A perfectly
    /// trained model's loss approaches this.
    pub fn entropy_floor(&self) -> f64 {
        let w = [1.0f64, 0.5, 1.0 / 3.0, 0.25];
        let z: f64 = w.iter().sum();
        -w.iter().map(|x| (x / z) * (x / z).ln()).sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let a = SyntheticCorpus::new(512, 7).sample(3, 1, 64);
        let b = SyntheticCorpus::new(512, 7).sample(3, 1, 64);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SyntheticCorpus::new(512, 7).sample(0, 0, 64);
        let b = SyntheticCorpus::new(512, 8).sample(0, 0, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn tokens_in_range() {
        let v = 128;
        let s = SyntheticCorpus::new(v, 1).sample(0, 0, 256);
        assert!(s.iter().all(|&t| (t as usize) < v && t >= 0));
    }

    #[test]
    fn batch_shapes_and_shift() {
        let c = SyntheticCorpus::new(64, 3);
        let (x, y) = c.batch(0, 0, 4, 32);
        assert_eq!(x.len(), 128);
        assert_eq!(y.len(), 128);
        // Target row 0 is input row 0 shifted by one.
        assert_eq!(x[1], y[0]);
    }

    #[test]
    fn chain_is_learnable() {
        // Each token's successors come from a 4-element set: sample many
        // transitions and check the support per predecessor is tiny.
        let c = SyntheticCorpus::new(128, 9);
        let mut succ: std::collections::HashMap<i32, std::collections::HashSet<i32>> =
            std::collections::HashMap::new();
        for idx in 0..8 {
            let s = c.sample(0, idx, 2048);
            for w in s.windows(2) {
                succ.entry(w[0]).or_default().insert(w[1]);
            }
        }
        assert!(succ.len() > 32, "should visit many tokens, got {}", succ.len());
        for (tok, set) in &succ {
            assert!(set.len() <= 4, "token {tok} has {} successors", set.len());
        }
    }

    #[test]
    fn entropy_floor_below_uniform() {
        let c = SyntheticCorpus::new(512, 1);
        assert!(c.entropy_floor() < (512f64).ln());
        assert!(c.entropy_floor() > 0.5);
    }
}
