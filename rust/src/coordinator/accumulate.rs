//! Microbatch gradient accumulation with explicit fold order — the
//! coordinator-level twin of the paper's dQ accumulation ordering.
//!
//! When a step's gradient is the sum of several microbatch gradients, the
//! fold order decides the bits of the result. DASH's determinism policy
//! fixes the order (microbatch index); the `Shuffled` mode folds in a
//! per-step pseudo-random order, reproducing the nondeterminism that
//! uncoordinated async reduction (or atomicAdd-style NCCL scatter) causes.

use crate::util::DetRng;

/// Fold-order policy for one accumulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccumOrder {
    /// Microbatch-index order: bitwise deterministic.
    Fixed,
    /// Pseudo-random order seeded by `seed` (models completion-order
    /// nondeterminism; a *different* seed per run/step causes run-to-run
    /// bit drift).
    Shuffled {
        /// Order seed (vary per run to model nondeterminism).
        seed: u64,
    },
}

/// Fold `micro_grads[mb][param_elem]` into a single gradient, element-wise,
/// in the policy's order, scaling by `1/n_microbatches` *after* the fold
/// (matching framework semantics: sum then normalize).
pub fn accumulate_grads(micro_grads: &[Vec<f32>], order: AccumOrder) -> Vec<f32> {
    let n = micro_grads.len();
    assert!(n > 0, "no microbatch gradients");
    let len = micro_grads[0].len();
    assert!(micro_grads.iter().all(|g| g.len() == len), "ragged gradients");

    let fold_order: Vec<usize> = match order {
        AccumOrder::Fixed => (0..n).collect(),
        AccumOrder::Shuffled { seed } => {
            let mut v: Vec<usize> = (0..n).collect();
            DetRng::new(seed).shuffle(&mut v);
            v
        }
    };

    let mut acc = vec![0.0f32; len];
    for &mb in &fold_order {
        let g = &micro_grads[mb];
        for (a, &x) in acc.iter_mut().zip(g.iter()) {
            *a += x;
        }
    }
    let scale = 1.0 / n as f32;
    for a in &mut acc {
        *a *= scale;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads(n_mb: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = DetRng::new(seed);
        (0..n_mb)
            .map(|_| {
                (0..len)
                    .map(|_| {
                        rng.gen_f32_range(-1.0, 1.0)
                            * 1e3_f32.powf(rng.gen_f32_range(-1.0, 1.0))
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn fixed_order_bitwise_stable() {
        let g = grads(8, 1024, 3);
        let a = accumulate_grads(&g, AccumOrder::Fixed);
        let b = accumulate_grads(&g, AccumOrder::Fixed);
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn shuffled_orders_drift() {
        let g = grads(8, 4096, 3);
        let a = accumulate_grads(&g, AccumOrder::Shuffled { seed: 1 });
        let b = accumulate_grads(&g, AccumOrder::Shuffled { seed: 2 });
        let drift = a
            .iter()
            .zip(&b)
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
        assert!(drift > 0, "wide-dynamic-range grads must drift across orders");
    }

    #[test]
    fn same_shuffle_seed_is_reproducible() {
        let g = grads(8, 1024, 5);
        let a = accumulate_grads(&g, AccumOrder::Shuffled { seed: 9 });
        let b = accumulate_grads(&g, AccumOrder::Shuffled { seed: 9 });
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }

    #[test]
    fn mean_is_correct_up_to_fp() {
        let g = vec![vec![1.0f32, 2.0], vec![3.0, 4.0]];
        let a = accumulate_grads(&g, AccumOrder::Fixed);
        assert_eq!(a, vec![2.0, 3.0]);
    }

    #[test]
    fn single_microbatch_trivially_deterministic() {
        let g = grads(1, 64, 7);
        let a = accumulate_grads(&g, AccumOrder::Shuffled { seed: 1 });
        let b = accumulate_grads(&g, AccumOrder::Shuffled { seed: 2 });
        assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
    }
}
