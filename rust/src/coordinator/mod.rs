//! Layer-3 training coordinator: reproducible LLM training on top of the
//! AOT artifacts.
//!
//! The paper's end-to-end claim is that deterministic attention makes whole
//! training runs bitwise reproducible at modest cost. This module is the
//! training-system integration of that claim:
//!
//! * [`config`] — TOML-driven run configuration (model, optimizer, data,
//!   determinism policy);
//! * [`data`] — deterministic synthetic corpus generator (seeded Markov
//!   text, so the loss curve has real structure to learn);
//! * [`trainer`] — the step loop over the AOT `train_step` /
//!   `grad_step` + `apply_step` modules via PJRT (behind the `pjrt`
//!   feature: it binds to the `xla` FFI crate);
//! * [`accumulate`] — microbatch gradient accumulation with a fixed or
//!   shuffled fold order — the coordinator-level analogue of the paper's
//!   dQ accumulation ordering;
//! * [`repro`] — bitwise run fingerprints (the Table-1 methodology applied
//!   to whole training runs) and the executor-backed [`ReproManifest`]
//!   that persists gradient content hashes, so a manifest round-trip
//!   attests numeric state rather than configuration alone;
//! * [`metrics`] — loss/throughput logging.

pub mod accumulate;
pub mod config;
pub mod data;
pub mod metrics;
pub mod repro;
#[cfg(feature = "pjrt")]
pub mod trainer;

pub use accumulate::{accumulate_grads, AccumOrder};
pub use config::TrainConfig;
pub use data::SyntheticCorpus;
pub use metrics::TrainMetrics;
pub use repro::{fingerprint_f32, ReproManifest, RunFingerprint};
#[cfg(feature = "pjrt")]
pub use trainer::Trainer;
