//! Run configuration: a TOML file drives every knob of a training run so
//! experiments are reproducible from config + seed alone.

use crate::util::toml::{parse as toml_parse, TomlValue};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Gradient-accumulation determinism policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DeterminismMode {
    /// Fixed microbatch fold order — bitwise reproducible (DASH mode).
    #[default]
    Deterministic,
    /// Shuffled fold order per step — models atomic-style accumulation.
    Shuffled,
}

impl DeterminismMode {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "deterministic" => Ok(Self::Deterministic),
            "shuffled" => Ok(Self::Shuffled),
            _ => bail!("determinism must be 'deterministic' or 'shuffled', got '{s}'"),
        }
    }
}

/// Complete training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Vocabulary size.
    pub vocab: usize,
    /// Model width.
    pub d_model: usize,
    /// Transformer layers.
    pub n_layers: usize,
    /// Attention heads.
    pub n_heads: usize,
    /// MLP hidden width.
    pub d_ff: usize,
    /// Sequence length per sample.
    pub seqlen: usize,
    /// Samples per optimizer step.
    pub batch: usize,
    /// Microbatches per step (gradient accumulation factor; `batch` must
    /// divide evenly).
    pub microbatches: usize,
    /// Optimizer steps to run.
    pub steps: usize,
    /// Learning rate.
    pub lr: f64,
    /// SGD momentum.
    pub momentum: f64,
    /// Master seed (data + init).
    pub seed: u64,
    /// Gradient-accumulation order policy.
    pub determinism: DeterminismMode,
    /// Attention schedule the kernels were compiled with (metadata for
    /// logging; the artifact itself fixes the order). Must name a known
    /// [`crate::schedule::ScheduleKind`] — including `"lpt"` and `"tuned"`
    /// for autotuned runs; see [`TrainConfig::schedule_kind`].
    pub schedule: String,
    /// Artifacts directory.
    pub artifacts_dir: String,
    /// Log every N steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            vocab: 512,
            d_model: 256,
            n_layers: 4,
            n_heads: 4,
            d_ff: 1024,
            seqlen: 128,
            batch: 8,
            microbatches: 1,
            steps: 200,
            lr: 3e-2,
            momentum: 0.9,
            seed: 42,
            determinism: DeterminismMode::Deterministic,
            schedule: "descending".to_string(),
            artifacts_dir: "artifacts".to_string(),
            log_every: 10,
        }
    }
}

impl TrainConfig {
    /// Load from a TOML file (unknown keys rejected — config typos must not
    /// silently fall back to defaults in a reproducibility system).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        let cfg = Self::from_toml_str(&text)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from TOML text.
    pub fn from_toml_str(text: &str) -> Result<Self> {
        let table = toml_parse(text)?;
        let mut cfg = Self::default();
        cfg.apply(&table)?;
        Ok(cfg)
    }

    fn apply(&mut self, t: &BTreeMap<String, TomlValue>) -> Result<()> {
        for (key, value) in t {
            let us =
                || value.as_usize().with_context(|| format!("'{key}' must be a non-negative int"));
            let fl = || value.as_f64().with_context(|| format!("'{key}' must be a number"));
            let st = || {
                value
                    .as_str()
                    .map(str::to_string)
                    .with_context(|| format!("'{key}' must be a string"))
            };
            match key.as_str() {
                "vocab" => self.vocab = us()?,
                "d_model" => self.d_model = us()?,
                "n_layers" => self.n_layers = us()?,
                "n_heads" => self.n_heads = us()?,
                "d_ff" => self.d_ff = us()?,
                "seqlen" => self.seqlen = us()?,
                "batch" => self.batch = us()?,
                "microbatches" => self.microbatches = us()?,
                "steps" => self.steps = us()?,
                "lr" => self.lr = fl()?,
                "momentum" => self.momentum = fl()?,
                "seed" => self.seed = us()? as u64,
                "determinism" => self.determinism = DeterminismMode::parse(&st()?)?,
                "schedule" => self.schedule = st()?,
                "artifacts_dir" => self.artifacts_dir = st()?,
                "log_every" => self.log_every = us()?.max(1),
                other => bail!("unknown config key '{other}'"),
            }
        }
        Ok(())
    }

    /// Serialize to TOML (round-trips through [`TrainConfig::from_toml_str`]).
    pub fn to_toml(&self) -> String {
        format!(
            "vocab = {}\nd_model = {}\nn_layers = {}\nn_heads = {}\nd_ff = {}\n\
             seqlen = {}\nbatch = {}\nmicrobatches = {}\nsteps = {}\nlr = {}\n\
             momentum = {}\nseed = {}\ndeterminism = \"{}\"\nschedule = \"{}\"\n\
             artifacts_dir = \"{}\"\nlog_every = {}\n",
            self.vocab,
            self.d_model,
            self.n_layers,
            self.n_heads,
            self.d_ff,
            self.seqlen,
            self.batch,
            self.microbatches,
            self.steps,
            self.lr,
            self.momentum,
            self.seed,
            match self.determinism {
                DeterminismMode::Deterministic => "deterministic",
                DeterminismMode::Shuffled => "shuffled",
            },
            self.schedule,
            self.artifacts_dir,
            self.log_every
        )
    }

    /// Sanity checks.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.batch % self.microbatches.max(1) == 0,
            "microbatches must divide batch"
        );
        anyhow::ensure!(self.d_model % self.n_heads == 0, "n_heads must divide d_model");
        anyhow::ensure!(self.vocab > 1 && self.seqlen > 1, "degenerate geometry");
        self.schedule_kind()?;
        Ok(())
    }

    /// The configured attention schedule as a typed kind. Rejects unknown
    /// names — a typo here must not silently train under a different
    /// schedule than the experiment log claims.
    pub fn schedule_kind(&self) -> Result<crate::schedule::ScheduleKind> {
        crate::schedule::ScheduleKind::parse(&self.schedule)
            .ok_or_else(|| anyhow::anyhow!("unknown schedule '{}' in config", self.schedule))
    }

    /// Samples per microbatch.
    pub fn micro_batch(&self) -> usize {
        self.batch / self.microbatches.max(1)
    }

    /// Approximate parameter count (embed + per-layer attn/MLP/norms + final
    /// norm; tied unembedding).
    pub fn param_count(&self) -> usize {
        let d = self.d_model;
        let per_layer = d * 3 * d + d * d + 2 * d + 2 * d * self.d_ff + self.d_ff * d;
        self.vocab * d + self.n_layers * per_layer + d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        TrainConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let cfg = TrainConfig { steps: 17, ..Default::default() };
        let back = TrainConfig::from_toml_str(&cfg.to_toml()).unwrap();
        assert_eq!(back.steps, 17);
        assert_eq!(back.determinism, DeterminismMode::Deterministic);
        assert_eq!(back.lr, cfg.lr);
    }

    #[test]
    fn partial_toml_uses_defaults() {
        let cfg = TrainConfig::from_toml_str("steps = 5\nseed = 7").unwrap();
        assert_eq!(cfg.steps, 5);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.vocab, 512);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(TrainConfig::from_toml_str("stepz = 5").is_err());
    }

    #[test]
    fn bad_microbatch_rejected() {
        let cfg = TrainConfig { batch: 8, microbatches: 3, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn determinism_modes_parse() {
        let cfg = TrainConfig::from_toml_str("determinism = \"shuffled\"").unwrap();
        assert_eq!(cfg.determinism, DeterminismMode::Shuffled);
        assert!(TrainConfig::from_toml_str("determinism = \"chaos\"").is_err());
    }

    #[test]
    fn schedule_names_are_validated() {
        use crate::schedule::ScheduleKind;
        let tuned = TrainConfig { schedule: "tuned".into(), ..Default::default() };
        tuned.validate().unwrap();
        assert_eq!(tuned.schedule_kind().unwrap(), ScheduleKind::Tuned);
        let lpt = TrainConfig { schedule: "lpt".into(), ..Default::default() };
        assert_eq!(lpt.schedule_kind().unwrap(), ScheduleKind::Lpt);
        let typo = TrainConfig { schedule: "descnding".into(), ..Default::default() };
        assert!(typo.validate().is_err());
    }

    #[test]
    fn param_count_scales() {
        let small = TrainConfig::default().param_count();
        let big = TrainConfig { d_model: 512, d_ff: 2048, ..Default::default() }.param_count();
        assert!(big > 3 * small);
    }
}
