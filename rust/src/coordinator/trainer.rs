//! The training step loop: PJRT execution of the AOT `train_step` (fused)
//! or `grad_step` + `apply_update` (microbatched, with coordinator-side
//! deterministic gradient accumulation).
//!
//! Artifact contract (see `python/compile/aot.py`):
//! * `init_params(seed: i32[]) -> params…` — deterministic on-device init;
//! * `train_step(params…, moms…, tokens, targets) -> (params…, moms…, loss)`;
//! * `grad_step(params…, tokens, targets) -> (grads…, loss)`;
//! * `apply_update(params…, moms…, grads…) -> (params…, moms…)`.
//!
//! Every module's manifest entry carries `meta.n_params`.

use super::accumulate::{accumulate_grads, AccumOrder};
use super::config::{DeterminismMode, TrainConfig};
use super::data::SyntheticCorpus;
use super::metrics::TrainMetrics;
use super::repro::{fingerprint_params, RunFingerprint};
use crate::runtime::{ArtifactManifest, Engine, LoadedModule};
use crate::Result;
use std::sync::Arc;

/// A live training run.
pub struct Trainer {
    cfg: TrainConfig,
    engine: Engine,
    train_step: Arc<LoadedModule>,
    grad_step: Option<Arc<LoadedModule>>,
    apply_update: Option<Arc<LoadedModule>>,
    /// Parameter tensors, position-matched to the artifact signature.
    params: Vec<xla::Literal>,
    /// Momentum buffers.
    moms: Vec<xla::Literal>,
    /// Parameter tensor shapes (for rebuilding literals from grads).
    param_shapes: Vec<Vec<usize>>,
    corpus: SyntheticCorpus,
    /// Collected metrics.
    pub metrics: TrainMetrics,
    /// Bitwise fingerprint trace.
    pub fingerprint: RunFingerprint,
    /// Seed used for the shuffled accumulation order (varied per run to
    /// model nondeterminism; fixed for reproducibility experiments).
    pub shuffle_salt: u64,
}

impl Trainer {
    /// Create a trainer: load artifacts, compile modules, init params.
    pub fn new(cfg: TrainConfig) -> Result<Self> {
        cfg.validate()?;
        let manifest = ArtifactManifest::load(&cfg.artifacts_dir)?;
        let engine = Engine::cpu()?;
        let train_step = engine.load(&manifest, "train_step")?;
        // grad/apply path only needed for microbatched accumulation.
        let (grad_step, apply_update) = if cfg.microbatches > 1 {
            (
                Some(engine.load(&manifest, "grad_step")?),
                Some(engine.load(&manifest, "apply_update")?),
            )
        } else {
            (None, None)
        };

        // Deterministic on-device init.
        let init = engine.load(&manifest, "init_params")?;
        let seed_lit = crate::runtime::client::literal_i32(&[cfg.seed as i32], &[])?;
        let params = init.run_literals(&[seed_lit])?;
        let param_shapes: Vec<Vec<usize>> = manifest
            .spec("init_params")?
            .outputs
            .iter()
            .map(|t| t.shape.clone())
            .collect();
        anyhow::ensure!(
            params.len() == param_shapes.len(),
            "init_params returned {} tensors, manifest says {}",
            params.len(),
            param_shapes.len()
        );
        // Zero momentum buffers.
        let moms = param_shapes
            .iter()
            .map(|s| {
                let n: usize = s.iter().product();
                crate::runtime::client::literal_f32(&vec![0.0; n], s)
            })
            .collect::<Result<Vec<_>>>()?;

        let corpus = SyntheticCorpus::new(cfg.vocab, cfg.seed);
        Ok(Self {
            shuffle_salt: cfg.seed,
            cfg,
            engine,
            train_step,
            grad_step,
            apply_update,
            params,
            moms,
            param_shapes,
            corpus,
            metrics: TrainMetrics::new(),
            fingerprint: RunFingerprint::new(),
        })
    }

    /// Run configuration.
    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// PJRT engine (for examples that execute extra modules).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// One optimizer step; returns the loss.
    pub fn step(&mut self, step: usize) -> Result<f32> {
        if self.cfg.microbatches <= 1 {
            self.fused_step(step)
        } else {
            self.microbatched_step(step)
        }
    }

    /// Fused path: the whole step is one XLA program.
    fn fused_step(&mut self, step: usize) -> Result<f32> {
        let (x, y) = self.corpus.batch(step, 0, self.cfg.batch, self.cfg.seqlen);
        let xs = crate::runtime::client::literal_i32(&x, &[self.cfg.batch, self.cfg.seqlen])?;
        let ys = crate::runtime::client::literal_i32(&y, &[self.cfg.batch, self.cfg.seqlen])?;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(self.params.len() * 2 + 2);
        args.extend(self.params.iter());
        args.extend(self.moms.iter());
        args.push(&xs);
        args.push(&ys);
        let mut out = self.train_step.run_literal_refs(&args)?;
        let p = self.params.len();
        anyhow::ensure!(out.len() == 2 * p + 1, "train_step returned {} outputs", out.len());
        let loss_lit = out.pop().unwrap();
        let loss = crate::runtime::client::f32_vec(&loss_lit)?[0];
        self.moms = out.split_off(p);
        self.params = out;
        Ok(loss)
    }

    /// Microbatched path: per-microbatch grads, coordinator-side ordered
    /// accumulation, then the apply module.
    fn microbatched_step(&mut self, step: usize) -> Result<f32> {
        let grad_step = self.grad_step.as_ref().expect("microbatch path").clone();
        let apply = self.apply_update.as_ref().expect("microbatch path").clone();
        let mb_size = self.cfg.micro_batch();
        let p = self.params.len();

        let mut micro_grads: Vec<Vec<Vec<f32>>> = Vec::with_capacity(self.cfg.microbatches);
        let mut losses = Vec::with_capacity(self.cfg.microbatches);
        for mb in 0..self.cfg.microbatches {
            let (x, y) = self.corpus.batch(step, mb, mb_size, self.cfg.seqlen);
            let xs = crate::runtime::client::literal_i32(&x, &[mb_size, self.cfg.seqlen])?;
            let ys = crate::runtime::client::literal_i32(&y, &[mb_size, self.cfg.seqlen])?;
            let mut args: Vec<&xla::Literal> = Vec::with_capacity(p + 2);
            args.extend(self.params.iter());
            args.push(&xs);
            args.push(&ys);
            let mut out = grad_step.run_literal_refs(&args)?;
            let loss_lit = out.pop().unwrap();
            losses.push(crate::runtime::client::f32_vec(&loss_lit)?[0]);
            let grads: Vec<Vec<f32>> = out
                .iter()
                .map(crate::runtime::client::f32_vec)
                .collect::<Result<_>>()?;
            micro_grads.push(grads);
        }

        // Ordered (or shuffled) fold per parameter tensor.
        let order = match self.cfg.determinism {
            DeterminismMode::Deterministic => AccumOrder::Fixed,
            DeterminismMode::Shuffled => AccumOrder::Shuffled {
                seed: self.shuffle_salt ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15),
            },
        };
        let mut grad_lits = Vec::with_capacity(p);
        for t in 0..p {
            let per_mb: Vec<Vec<f32>> =
                micro_grads.iter().map(|g| g[t].clone()).collect();
            let folded = accumulate_grads(&per_mb, order);
            grad_lits.push(crate::runtime::client::literal_f32(
                &folded,
                &self.param_shapes[t],
            )?);
        }

        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * p);
        args.extend(self.params.iter());
        args.extend(self.moms.iter());
        args.extend(grad_lits.iter());
        let mut out = apply.run_literal_refs(&args)?;
        anyhow::ensure!(out.len() == 2 * p, "apply_update returned {} outputs", out.len());
        self.moms = out.split_off(p);
        self.params = out;
        Ok(losses.iter().sum::<f32>() / losses.len() as f32)
    }

    /// Bitwise fingerprint of the current parameters.
    pub fn param_fingerprint(&self) -> Result<u64> {
        let vecs: Vec<Vec<f32>> = self
            .params
            .iter()
            .map(crate::runtime::client::f32_vec)
            .collect::<Result<_>>()?;
        Ok(fingerprint_params(vecs.iter().map(|v| v.as_slice())))
    }

    /// Run the configured number of steps, logging and fingerprinting.
    pub fn run(&mut self) -> Result<()> {
        let tokens_per_step = self.cfg.batch * self.cfg.seqlen;
        for step in 0..self.cfg.steps {
            self.metrics.begin_step();
            let loss = self.step(step)?;
            self.metrics.end_step(step, loss, tokens_per_step);
            if step % self.cfg.log_every == 0 || step + 1 == self.cfg.steps {
                let fp = self.param_fingerprint()?;
                self.fingerprint.record(step, fp);
                eprintln!(
                    "step {step:>5}  loss {loss:.4}  fp {fp:016x}  ({:.0} tok/s)",
                    self.metrics.tokens_per_second()
                );
            }
        }
        self.fingerprint.final_loss_bits = self.metrics.final_loss(1).to_bits();
        Ok(())
    }
}
