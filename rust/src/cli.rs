//! The `dash` CLI surface, shared between the binary and the docs tests.
//!
//! Every subcommand's `--help` text lives here as a constant; `main.rs`
//! prints them and `rust/tests/docs.rs` diffs them against both the live
//! binary output and the fenced blocks in `docs/CLI.md`, so the command
//! reference cannot drift from the implementation in either direction.

/// The shared `--mask` grammar block, appended to every command that
/// accepts a mask.
macro_rules! mask_grammar {
    () => {
        "\
MASK GRAMMAR (shared by every --mask flag):
  full                   dense attention (vision / diffusion)
  causal[:k]             causal, bottom-right aligned on rectangular grids;
                         k shifts the diagonal (+widens, -narrows)
  swa:<W>                sliding window: the W tiles ending at the diagonal
  doc:<b1,b2,...>        document/varlen packing, boundaries in tiles
  doc:<file>             the same boundary list read from a file
  sparse:<KV>x<Q>:<hex>  explicit block-sparse bitmap, row-major hex nibbles"
    };
}

/// Global usage: the command list. Per-command detail lives in the
/// per-command constants (`dash <command> --help`).
pub const USAGE: &str = "\
dash — DASH: deterministic attention scheduling (paper reproduction)

USAGE: dash <COMMAND> [OPTIONS]
       dash <COMMAND> --help    full option reference for one command

COMMANDS:
  simulate   simulate one schedule on a modelled machine
  gantt      render a schedule timeline (paper Figs 2/3/4/6/7)
  timeline   interactive self-contained HTML timeline, with schedule diff
  flamegraph makespan attribution: where schedule time goes, per chain
  figures    regenerate paper artifacts, plus the tune/dvt tables
  tune       search-synthesize a schedule, with a persistent cache
  verify     numeric determinism oracle: execute schedules, hash gradients
  trace      serving traces: generate, batch-compile, prove batch invariance
  baseline   performance snapshots + regression gate (BENCH_*.json)
  hw         hardware profiles: list/show/export GPU presets
  train      reproducible training on the AOT artifacts (pjrt builds)
  audit      two-run bitwise reproducibility audit (pjrt builds)
  explore    schedule comparison table / Lemma-1 demo

GLOBAL:
  --gpu <preset|path>   machine profile: h800|h100|a100|abstract, or a
                        profile JSON (see `dash hw`). Defaults: figures ->
                        h800 (the paper's part); simulate/tune -> abstract.

Full reference: docs/CLI.md (mechanically verified against this output).";

/// `dash simulate --help`.
pub const SIMULATE: &str = concat!(
    "\
dash simulate — simulate one schedule on a modelled machine

USAGE: dash simulate [OPTIONS]

OPTIONS:
  --schedule <kind>     fa3|fa3-atomic|descending|shift|symshift|two-pass|
                        lpt|tuned (default fa3); a schedule that cannot
                        support the mask fails with a typed unsupported-mask
                        error, never a silently invalid schedule
  --n <tiles>           KV tiles per head (default 8)
  --n-q <tiles>         Q tiles per head (default --n; rectangular grids)
  --heads <m>           head instances (default 4)
  --mask <spec>         mask shape (default causal; grammar below)
  --n-sm <k>            override the machine's SM count
  --gpu <preset|path>   machine profile (default abstract)
  --head-dim <d>        head dimension for profile-derived costs
                        (default 128; concrete profiles only)
  --r-over-c <f>        reduce/compute cost ratio (default 0.25; abstract
                        profile only)
  --l2                  enable the segmented-L2 model (abstract profile)
  --writer-depth <s>    dQ-writer pipeline depth (default 0, or the
                        profile's derived value)
  --occupancy <c>       co-resident CTAs per SM (default 1, or derived)
  --devices <d>         context-parallel device count (default 1); needs a
                        cluster schedule, spelled <ring|zigzag>-<kind>
                        (e.g. ring-shift, zigzag-descending)
  --cluster <spec|path> interconnect model pricing the cross-device hop:
                        nvlink:<n>x<gpu> | ib:<n>x<gpu> | abstract:<n> |
                        a cluster JSON (default: ideal link, unit hop)

",
    mask_grammar!()
);

/// `dash gantt --help`.
pub const GANTT: &str = concat!(
    "\
dash gantt — render a schedule timeline (paper Figs 2/3/4/6/7)

USAGE: dash gantt [OPTIONS]

OPTIONS:
  --schedule <kind>     schedule to render (default fa3; see simulate)
  --n <tiles>           KV tiles per head (default 4)
  --n-q <tiles>         Q tiles per head (default --n)
  --heads <m>           head instances (default 2)
  --mask <spec>         mask shape (default causal; grammar below)
  --width <cols>        chart width in characters (default 100)
  --csv                 emit the raw task spans as CSV instead of ASCII art
  --writer-depth <s>    dQ-writer pipeline depth (default 0)
  --occupancy <c>       co-resident CTAs per SM (default 1)
  --devices <d>         context-parallel device count (default 1; needs a
                        <ring|zigzag>-<kind> schedule); lanes namespace as
                        dev<d>/sm<s> plus one link<i> lane per device,
                        with transfers drawn as '='
  --cluster <spec|path> interconnect model pricing the cross-device hop
                        (grammar: see simulate)

",
    mask_grammar!()
);

/// `dash timeline --help`.
pub const TIMELINE: &str = concat!(
    "\
dash timeline — interactive self-contained HTML timeline, with schedule diff

USAGE: dash timeline [OPTIONS]

Renders the typed event trace of a schedule (every compute, reduce, stall
and L2 interval on per-SM lanes, hover detail) as one standalone HTML
file — no network, no external assets. With --diff, two schedules of the
same workload are stacked and divergent intervals highlighted.

OPTIONS:
  --schedule <kind>     schedule to trace (default fa3; see simulate)
  --diff <kind>         second schedule: stacked diff view instead of a
                        single timeline
  --source <engine>     sim|exec — the discrete-event simulator or the
                        numeric executor's machine model (default sim)
  --devices <d>         context-parallel device count (default 1; needs a
                        <ring|zigzag>-<kind> schedule); multi-device traces
                        get dev<d>/sm<s> + link<i> lanes, with transfers
                        as their own event kind
  --cluster <spec|path> interconnect model pricing the cross-device hop
                        (grammar: see simulate)
  --out <file>          output path (default timeline.html)
  --n <tiles>           KV tiles per head (default 8)
  --n-q <tiles>         Q tiles per head (default --n)
  --heads <m>           head instances (default 2)
  --mask <spec>         mask shape (default causal; grammar below)
  --n-sm <k>            override the machine's SM count
  --gpu <preset|path>   machine profile (default abstract)
  --head-dim <d>        head dimension for profile-derived costs
  --r-over-c <f>        reduce/compute cost ratio (abstract profile only)
  --l2                  segmented-L2 model (abstract profile only)
  --writer-depth <s>    dQ-writer pipeline depth (default 0, or derived)
  --occupancy <c>       co-resident CTAs per SM (default 1, or derived)

",
    mask_grammar!()
);

/// `dash flamegraph --help`.
pub const FLAMEGRAPH: &str = concat!(
    "\
dash flamegraph — makespan attribution: where schedule time goes, per chain

USAGE: dash flamegraph [OPTIONS]

Folds a simulated trace into per-chain compute/reduce/stall/l2/wait
buckets plus end-of-timeline idle — the deterministic overhead decomposed
into named stalls. Every lane-cycle of `makespan x lanes` is attributed.
Default output is an aligned text table; --folded emits folded stacks
(`stack;frames count` lines) for standard flamegraph tooling.

OPTIONS:
  --schedule <kind>     schedule to attribute (default fa3; see simulate)
  --folded              folded-stacks output instead of the text table
  --devices <d>         context-parallel device count (default 1; needs a
                        <ring|zigzag>-<kind> schedule); link-lane frames
                        gain a transfer column
  --cluster <spec|path> interconnect model pricing the cross-device hop
                        (grammar: see simulate)
  --out <file>          write to a file instead of stdout
  --n <tiles>           KV tiles per head (default 8)
  --n-q <tiles>         Q tiles per head (default --n)
  --heads <m>           head instances (default 2)
  --mask <spec>         mask shape (default causal; grammar below)
  --n-sm <k>            override the machine's SM count
  --gpu <preset|path>   machine profile (default abstract)
  --head-dim <d>        head dimension for profile-derived costs
  --r-over-c <f>        reduce/compute cost ratio (abstract profile only)
  --l2                  segmented-L2 model (abstract profile only)
  --writer-depth <s>    dQ-writer pipeline depth (default 0, or derived)
  --occupancy <c>       co-resident CTAs per SM (default 1, or derived)

",
    mask_grammar!()
);

/// `dash figures --help`.
pub const FIGURES: &str = "\
dash figures — regenerate the paper's artifacts on a modelled GPU

USAGE: dash figures [OPTIONS]

OPTIONS:
  --fig <which>         1|8|9|10a|10b|table1|all (default all), or one of
                        the explicit-only extras:
                          tune  autotuner tuned-vs-analytic sweep
                          dvt   determinism-vs-throughput table (numeric
                                oracle verdicts next to simulated makespans)
  --gpu <preset|path>   concrete machine profile (default h800; the
                        abstract machine has no clock and is rejected)
  --ideal               idealize L2/register effects (hardware figures)
  --csv                 emit CSV instead of aligned tables
  --no-bench            skip writing the BENCH_figures.json baseline
                        snapshot (written by default so every figures run
                        feeds the perf trajectory; see `dash baseline`)";

/// `dash tune --help`.
pub const TUNE: &str = concat!(
    "\
dash tune — search-synthesize a schedule, with a persistent cache

USAGE: dash tune [OPTIONS]

OPTIONS:
  --n <tiles>           KV tiles per head (default 8)
  --n-q <tiles>         Q tiles per head (default --n)
  --heads <m>           head instances (default 4)
  --mask <spec>         mask shape (default causal; grammar below)
  --n-sm <k>            machine width to tune for
  --budget <proposals>  local-search proposals (default 400)
  --seed <s>            search seed (default 42)
  --batch <k>           candidates proposed and scored per search round
                        (default 8; 1 = the classic serial loop — the
                        winner is identical either way)
  --threads <t>         worker threads for candidate scoring (default 0 =
                        all host cores; results are bitwise-identical at
                        any thread count)
  --portfolio <r>       race r annealed search replicas on independent
                        deterministic RNG streams (replica 0 is the
                        classic tuner, higher replicas climb a temperature
                        ladder); winner is the smallest (makespan, replica
                        index) — bitwise-stable at any --threads
  --queue <specs.json>  batch mode: drain a JSON workload queue ([{\"n\":..,
                        \"n_q\"?, \"heads\"?, \"mask\"?, \"n_sm\"?, \"budget\"?},
                        ...]) into one shared cache under an advisory file
                        lock, deduping identical keys; reports hit / warm /
                        cold provenance per spec
  --no-warm             on a cache miss, skip warm-starting from the
                        nearest structured-key neighbor (cold search only)
  --warm-budget <p>     proposal budget when a warm start is found
                        (default --budget; the fleet setting is ~10x
                        smaller than the cold budget)
  --cache <path>        schedule cache file (default tuned_schedules.json)
  --no-cache            search without reading or writing the cache
  --retune              ignore an existing cache entry, search again, and
                        overwrite it (e.g. with a larger --budget)
  --gpu <preset|path>   machine profile (default abstract); cache keys
                        include the profile fingerprint
  --devices <d>         device count for the cache key (default 1 — the
                        single-GPU key format is unchanged)
  --cluster <spec|path> cluster identity for the cache key: a schedule
                        tuned on one interconnect never serves another
  --head-dim <d>        head dimension for profile-derived costs
  --r-over-c <f>        reduce/compute ratio (abstract profile only)
  --l2                  segmented-L2 model (abstract profile only)
  --writer-depth <s>    dQ-writer pipeline depth override
  --occupancy <c>       co-resident CTAs per SM override
  --sweep               tuned-vs-analytic grid instead of one point; with
                        --gpu a,b the same grid runs per profile
  --csv                 CSV sweep output
  --json <path>         write the cross-GPU sweep artifact as JSON
  --no-bench            skip writing the BENCH_tune_sweep.json baseline
                        snapshot (--sweep runs write one by default; see
                        `dash baseline`)

",
    mask_grammar!()
);

/// `dash verify --help`.
pub const VERIFY: &str = concat!(
    "\
dash verify — numeric determinism oracle: execute the attention backward
pass in software, tile by tile, following each schedule, and prove the
gradient bits are identical across repeated runs, SM counts, completion
shuffles — and, with --devices, device counts — or catch them
scattering (atomic/injected).

USAGE: dash verify [OPTIONS]

OPTIONS:
  --n <tiles>           KV tiles per head (default 6)
  --n-q <tiles>         Q tiles per head (default --n)
  --heads <m>           head instances (default 2)
  --mask <spec>         verify one mask shape (default: sweep full, causal,
                        swa:2, and a doc mask; grammar below)
  --schedule <kind>     verify one schedule (default all: every generator
                        plus the fa3-atomic negative control)
  --runs <r>            oracle runs per machine width (default 2)
  --sms <a,b,...>       machine widths to execute under
                        (default 3,max(n,2),2n+1)
  --block <b>           elements per tile side (default 4)
  --head-dim <d>        head dimension of the synthetic Q/K/V (default 8)
  --precision <p>       f32|bf16|both (default both; one table row each)
  --seed <s>            data seed (default 42)
  --no-inject           skip the injected-nondeterminism demonstration row
  --csv                 CSV output
  --manifest <path>     write a reproducibility manifest (gradient content
                        hashes) for the --schedule/--mask point, then exit
  --check <path>        re-execute a manifest's workload and attest that
                        the numeric state reproduces bit-for-bit
  --devices <a,b,...>   cross-device mode: execute the sharded backward
                        pass at each listed device count and demand one
                        gradient hash across device counts, runs, and
                        machine widths (defaults in this mode: --n 8,
                        --schedule ring-shift,zigzag-descending; schedules
                        must be <ring|zigzag>-<kind> composites)
  --inject-xdev         fold cross-device partials in a seeded shuffled
                        order instead of the fixed tree — the multi-GPU
                        negative control; this mode always exits nonzero

",
    mask_grammar!()
);

/// `dash trace --help`.
pub const TRACE: &str = "\
dash trace — deterministic serving traces, proved batch-invariant

USAGE: dash trace <generate|simulate|verify> [OPTIONS]

`generate` draws a request trace (Zipf/log-normal lengths in tiles,
Poisson or bursty arrivals) from one seed; `simulate` batch-compiles it
(continuous batching, one document per in-flight request) and simulates
every serving step's schedule; `verify` recompiles the same requests at
every batch size and admission order, executes every step through the
numeric oracle with request-seeded operands, and demands ONE gradient
hash per request across the whole matrix — batch invariance as a
bitwise-verified property, not a label.

OPTIONS:
  --seed <s>            trace seed (default 42); the whole request list is
                        a pure function of it
  --requests <k>        request count (default 8)
  --spec <path>         load a trace-spec JSON instead of the built-in
                        smoke workload (ignores --seed/--requests)
  --export <path>       generate: also write the spec JSON (round-trips
                        byte-identically; edit and pass back via --spec)
  --heads <m>           head instances of every compiled step (default 2)
  --schedule <kind>     simulate: generator for step schedules (default
                        fa3); verify: one generator instead of all seven
                        deterministic ones
  --batch <b>           simulate: admission cap per step (default 4)
  --chunk <tiles>       simulate: chunked-prefill tile cap (default 0 =
                        whole prompts)
  --batch-sizes <list>  verify: admission-cap axis (default 1,2,4)
  --orders <k>          verify: admission orders per batch size, order 0 =
                        FIFO (default 3)
  --precision <p>       verify: f32|bf16|both (default both)
  --block <b>           verify: elements per tile side (default 4)
  --head-dim <d>        verify: head dimension (default 8)
  --inject-batch        verify: rotate each dQ fold by a batch-layout key —
                        the serving negative control; this mode always
                        exits nonzero";

/// `dash baseline --help`.
pub const BASELINE: &str = "\
dash baseline — performance snapshots + regression gate (BENCH_*.json)

USAGE: dash baseline <save|list|check> [OPTIONS]

`save` runs a measurement suite on the paper's abstract machine (so the
numbers are machine-independent) and writes BENCH_<name>.json; `list`
tabulates the snapshots in --dir; `check` re-runs a snapshot's suite and
exits nonzero when any gated metric (makespan, utilization, stall
fraction, ...) regresses beyond the tolerance — CI runs it against the
committed BENCH_ci_smoke.json. Gate direction is derived from the metric
name, so snapshots exported by `dash figures`/`dash tune --sweep` gate
the same way via --against.

OPTIONS:
  --name <name>         snapshot name (default: the suite name; check
                        loads BENCH_<name>.json)
  --suite <which>       smoke|grid|core|cluster|trace|tune — re-runnable
                        suite (default smoke): smoke is the four
                        closed-form points the engine tests pin (three
                        single-GPU plus a 2-device ring), grid is every
                        deterministic generator x {full, causal} at n=8,
                        core is the simulator hot-path suite (closed forms
                        at n=256/512, home-regime tuner counters, and an
                        ungated 1000-rep wall-clock comparison of the
                        engine entry points), cluster is the ring/zigzag
                        closed forms at 1/2/4 devices, trace is a pinned
                        serving trace batch-compiled and simulated per
                        step (see `dash trace`), tune is the fleet-tuning
                        closed forms (portfolio races on the home regimes
                        plus the n=64 -> n=96 warm-start transfer pair)
  --dir <path>          snapshot directory (default .)
  --tolerance <f>       relative regression tolerance for check
                        (default 0.02)
  --against <path>      check the named snapshot against another snapshot
                        file instead of re-running its suite (for
                        harness-exported BENCH_*.json)";

/// `dash hw --help`.
pub const HW: &str = "\
dash hw — hardware profiles: list/show/export GPU presets

USAGE: dash hw [OPTIONS]

OPTIONS:
  (none)                list the built-in presets
  --show <preset|path>  print a profile as JSON plus derived quantities
  --export <preset|path>
                        write a profile JSON to edit and pass back as
                        --gpu <file>
  --cluster <spec|path> print a cluster profile plus derived hop cost and
                        fingerprint; spec grammar: nvlink:<n>x<gpu> |
                        ib:<n>x<gpu> | abstract:<n>, or a cluster JSON
  --export-cluster <spec|path>
                        write a cluster-profile JSON to edit and pass back
                        as --cluster <file>
  --out <file>          output path for --export (default <name>.json) and
                        --export-cluster (default cluster.json)";

/// `dash train --help`.
pub const TRAIN: &str = "\
dash train — reproducible training on the AOT artifacts (pjrt builds)

USAGE: dash train [OPTIONS]

Requires `make artifacts` and a binary built with `--features pjrt`.

OPTIONS:
  --config <toml>       run configuration (default: built-in tiny config)
  --steps <n>           override the configured step count
  --loss-csv <path>     write the loss curve as CSV";

/// `dash audit --help`.
pub const AUDIT: &str = "\
dash audit — two identical runs, compared bitwise (pjrt builds)

USAGE: dash audit [OPTIONS]

Requires `make artifacts` and a binary built with `--features pjrt`.

OPTIONS:
  --config <toml>       run configuration (default: built-in audit config)
  --steps <n>           steps per run (default 20)
  --shuffled            shuffle the microbatch fold order per run — the
                        audit must report the resulting divergence";

/// `dash explore --help`.
pub const EXPLORE: &str = "\
dash explore — schedule comparison table / Lemma-1 demo

USAGE: dash explore [OPTIONS]

OPTIONS:
  --n <tiles>           KV tiles per head (default 8)
  --heads <m>           head instances (default 4)
  --lemma               run the Lemma-1 depth-monotonicity demo instead";

/// Every subcommand with its `--help` text, in `USAGE` listing order.
pub const COMMANDS: &[(&str, &str)] = &[
    ("simulate", SIMULATE),
    ("gantt", GANTT),
    ("timeline", TIMELINE),
    ("flamegraph", FLAMEGRAPH),
    ("figures", FIGURES),
    ("tune", TUNE),
    ("verify", VERIFY),
    ("trace", TRACE),
    ("baseline", BASELINE),
    ("hw", HW),
    ("train", TRAIN),
    ("audit", AUDIT),
    ("explore", EXPLORE),
];

/// Help text for one subcommand, if it exists.
pub fn help_for(cmd: &str) -> Option<&'static str> {
    COMMANDS.iter().find(|(name, _)| *name == cmd).map(|(_, help)| *help)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_usage_command_has_help() {
        for (name, help) in COMMANDS {
            assert!(USAGE.contains(&format!("\n  {name}")), "{name} missing from USAGE");
            assert!(help.starts_with(&format!("dash {name} — ")), "{name} help header");
            assert_eq!(help_for(name), Some(*help));
        }
        assert_eq!(help_for("nonsense"), None);
    }

    #[test]
    fn mask_commands_embed_the_shared_grammar() {
        for help in [SIMULATE, GANTT, TIMELINE, FLAMEGRAPH, TUNE, VERIFY] {
            assert!(help.contains("MASK GRAMMAR"), "grammar missing");
            assert!(help.contains("sparse:<KV>x<Q>:<hex>"));
        }
    }
}
