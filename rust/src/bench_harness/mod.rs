//! Figure/table regeneration harness: one function per artifact in the
//! paper's evaluation section (§4), producing printable rows the CLI
//! (`dash figures`) and the bench targets share.

mod cross_gpu;
mod exec_table;
mod fig1;
mod fig10;
mod fig8_9;
mod fleet;
mod table1;
mod tune;

pub use cross_gpu::{
    cross_gpu_json, cross_gpu_sweep, tune_sweep_gpu, CrossGpuRow, CROSS_GPU_HEAD_DIMS,
    CROSS_GPU_NS,
};
pub use exec_table::{determinism_throughput_table, verify_matrix, DvtRow, VerifyOptions};
pub use fig1::{fig1_degradation, Fig1Row};
pub use fig10::{
    dash_schedule_for, fig10a_end_to_end, fig10b_breakdown, Fig10aRow, Fig10bRow, ModelConfig,
    PAPER_MODELS,
};
pub use fig8_9::{fig8_full_mask, fig9_causal_mask, FigRow};
pub use fleet::{queue_rows, replica_rows, QueueRow, ReplicaRow};
pub use table1::{table1_determinism, Table1Row};
pub use tune::{tune_sweep, TuneSweepRow, TUNE_SWEEP_NS, TUNE_SWEEP_SMS};

/// A printable figure/table row: ordered (column, cell) pairs.
pub trait TableRow {
    /// The row's cells in display order; column names must be identical
    /// across rows of one table.
    fn cells(&self) -> Vec<(&'static str, String)>;
}

/// Format a float for table display.
pub fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{v:.0}")
    } else if v.abs() >= 100.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.01 {
        format!("{v:.4}")
    } else {
        format!("{v:.3e}")
    }
}

/// Render rows as an aligned text table.
pub fn render_table<T: TableRow>(rows: &[T]) -> String {
    let Some(first) = rows.first() else { return "(no rows)".into() };
    let cols: Vec<&'static str> = first.cells().iter().map(|(c, _)| *c).collect();
    let mut widths: Vec<usize> = cols.iter().map(|c| c.len()).collect();
    let body: Vec<Vec<String>> = rows
        .iter()
        .map(|r| r.cells().into_iter().map(|(_, v)| v).collect())
        .collect();
    for row in &body {
        for (w, cell) in widths.iter_mut().zip(row) {
            *w = (*w).max(cell.len());
        }
    }
    let mut out = String::new();
    let header: Vec<String> =
        cols.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}")).collect();
    out.push_str(&header.join("  "));
    out.push('\n');
    out.push_str(&"-".repeat(out.len().saturating_sub(1)));
    out.push('\n');
    for row in body {
        let line: Vec<String> =
            row.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}")).collect();
        out.push_str(&line.join("  "));
        out.push('\n');
    }
    out
}

/// Render rows as CSV (for plotting scripts).
pub fn render_csv<T: TableRow>(rows: &[T]) -> String {
    let Some(first) = rows.first() else { return String::new() };
    let mut out = first
        .cells()
        .iter()
        .map(|(c, _)| *c)
        .collect::<Vec<_>>()
        .join(",");
    out.push('\n');
    for r in rows {
        out.push_str(
            &r.cells().into_iter().map(|(_, v)| v).collect::<Vec<_>>().join(","),
        );
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Row {
        name: &'static str,
        value: f64,
    }

    impl TableRow for Row {
        fn cells(&self) -> Vec<(&'static str, String)> {
            vec![("name", self.name.to_string()), ("value", fmt_f64(self.value))]
        }
    }

    #[test]
    fn render_table_aligns() {
        let rows = vec![Row { name: "a", value: 1.5 }, Row { name: "longer", value: 22.25 }];
        let t = render_table(&rows);
        assert!(t.contains("name"));
        assert!(t.contains("22.25"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn empty_rows_ok() {
        let rows: Vec<Row> = vec![];
        assert_eq!(render_table(&rows), "(no rows)");
        assert_eq!(render_csv(&rows), "");
    }

    #[test]
    fn csv_rows() {
        let rows = vec![Row { name: "x", value: 2.0 }];
        assert_eq!(render_csv(&rows), "name,value\nx,2\n");
    }
}
