//! Determinism-vs-throughput table: the numeric oracle's verdict next to
//! the simulator's throughput story, one row per (mask, schedule,
//! precision) — the artifact behind `dash verify` and
//! `dash figures --fig dvt`.
//!
//! Throughput comes from the ideal-machine simulator (makespan, and the
//! speed *cost* of determinism relative to the atomic baseline); the
//! determinism columns come from actually executing the backward pass
//! through [`crate::exec`] across repeated runs, machine widths, and
//! completion shuffles. Injected rows re-run a deterministic schedule
//! with atomic (arrival-order) dQ folding to demonstrate the oracle
//! catches nondeterminism rather than assuming its absence.

use crate::exec::{verify_schedule, OracleOptions};
use crate::mask::MaskSpec;
use crate::numerics::Precision;
use crate::schedule::{self, ProblemSpec, Schedule, ScheduleKind};
use crate::sim::{simulate, SimConfig};

/// Shape of one verification matrix.
#[derive(Debug, Clone)]
pub struct VerifyOptions {
    /// KV tiles.
    pub n_kv: usize,
    /// Q tiles.
    pub n_q: usize,
    /// Head instances.
    pub heads: usize,
    /// Mask shapes to sweep.
    pub masks: Vec<MaskSpec>,
    /// Schedule kinds to verify (kinds that cannot support a mask are
    /// skipped for that mask, mirroring their typed generator errors).
    pub kinds: Vec<ScheduleKind>,
    /// Oracle runs per machine width.
    pub runs: usize,
    /// Machine widths the oracle executes under.
    pub sm_counts: Vec<usize>,
    /// Executor tile side (elements).
    pub block: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Data seed.
    pub seed: u64,
    /// Precisions to verify (each is its own row).
    pub precisions: Vec<Precision>,
    /// Add one injected-nondeterminism row per mask (`fa3-det` with
    /// arrival-order folding, bf16) — the oracle's negative control.
    pub include_injected: bool,
}

impl VerifyOptions {
    /// The default `dash verify` sweep: four mask shapes, every
    /// generator, both precisions, 2 runs x 3 machine widths.
    pub fn defaults(n: usize, heads: usize, seed: u64) -> Self {
        Self {
            n_kv: n,
            n_q: n,
            heads,
            masks: vec![
                MaskSpec::full(),
                MaskSpec::causal(),
                MaskSpec::sliding_window(2),
                MaskSpec::document(vec![n.div_ceil(2)]),
            ],
            kinds: vec![
                ScheduleKind::Fa3Atomic,
                ScheduleKind::Fa3,
                ScheduleKind::Descending,
                ScheduleKind::Shift,
                ScheduleKind::SymmetricShift,
                ScheduleKind::TwoPass,
                ScheduleKind::Lpt,
                ScheduleKind::Tuned,
            ],
            runs: 2,
            sm_counts: vec![3, n.max(2), 2 * n + 1],
            block: 4,
            head_dim: 8,
            seed,
            precisions: vec![Precision::F32, Precision::Bf16],
            include_injected: true,
        }
    }
}

/// One row of the determinism-vs-throughput table.
#[derive(Debug, Clone)]
pub struct DvtRow {
    /// Mask name.
    pub mask: String,
    /// Schedule label (`fa3-det+inject` for injected rows).
    pub schedule: String,
    /// Precision name.
    pub precision: &'static str,
    /// Ideal-machine simulated makespan (throughput proxy).
    pub makespan: f64,
    /// Throughput relative to the atomic baseline on the same mask
    /// (atomic = 1.0; deterministic schedules pay their gap here).
    pub rel_throughput: f64,
    /// Oracle executions performed.
    pub executions: usize,
    /// Distinct gradient hashes observed.
    pub distinct: usize,
    /// Bitwise deterministic across the whole matrix?
    pub deterministic: bool,
    /// Max |dQ| deviation vs the canonical execution.
    pub max_dev: f64,
    /// Executed FLOPs matched the analytic expectation in every run?
    pub flops_ok: bool,
    /// Canonical gradient hash (hex).
    pub hash: String,
}

/// Build `kind` for `spec`, or `None` when the generator does not support
/// the mask (Shift off full-structured grids). LPT and tuned schedules are
/// built for an `n_kv`-wide machine — the oracle then executes them on
/// *other* widths, which must not move the gradient bits.
fn build(kind: ScheduleKind, spec: &ProblemSpec) -> Option<Schedule> {
    let sim = SimConfig::ideal(spec.n_kv.max(1));
    Some(match kind {
        ScheduleKind::Fa3 => schedule::fa3(spec, true),
        ScheduleKind::Fa3Atomic => schedule::fa3(spec, false),
        ScheduleKind::Descending => schedule::descending(spec),
        ScheduleKind::Shift => schedule::shift(spec).ok()?,
        ScheduleKind::SymmetricShift => schedule::symmetric_shift(spec),
        ScheduleKind::TwoPass => schedule::two_pass(spec),
        ScheduleKind::Lpt => schedule::lpt_schedule(spec, sim.n_sm),
        ScheduleKind::Tuned => crate::autotune::tuned_schedule_for(spec, &sim),
    })
}

/// Run the verification matrix. Rows appear mask-major, schedules in the
/// requested order, precisions innermost; injected rows (when enabled)
/// close out each mask block.
pub fn verify_matrix(o: &VerifyOptions) -> crate::Result<Vec<DvtRow>> {
    let mut rows = Vec::new();
    for mask in &o.masks {
        let spec = ProblemSpec {
            n_kv: o.n_kv,
            n_q: o.n_q,
            n_heads: o.heads,
            mask: mask.clone(),
        };
        let sim = SimConfig::ideal(o.n_kv.max(1));
        let atomic_makespan = simulate(&schedule::fa3(&spec, false), &sim)?.makespan;
        let case = |s: &Schedule,
                        label: String,
                        precision: Precision,
                        inject: bool|
         -> crate::Result<DvtRow> {
            let makespan = simulate(s, &sim)?.makespan;
            let oracle = OracleOptions {
                runs: o.runs,
                sm_counts: o.sm_counts.clone(),
                block: o.block,
                head_dim: o.head_dim,
                seed: o.seed,
                precision,
                inject_atomic: inject,
                inject_xdev: false,
            };
            let v = verify_schedule(s, &oracle)?;
            Ok(DvtRow {
                mask: mask.name(),
                schedule: label,
                precision: precision.name(),
                makespan,
                rel_throughput: if makespan > 0.0 { atomic_makespan / makespan } else { 0.0 },
                executions: v.executions,
                distinct: v.distinct_hashes,
                deterministic: v.deterministic(),
                max_dev: v.max_abs_dev,
                flops_ok: v.flops_ok(),
                hash: format!("{:016x}", v.hash),
            })
        };
        for &kind in &o.kinds {
            let Some(s) = build(kind, &spec) else { continue };
            for &p in &o.precisions {
                rows.push(case(&s, kind.name().to_string(), p, false)?);
            }
        }
        if o.include_injected {
            let s = schedule::fa3(&spec, true);
            rows.push(case(&s, "fa3-det+inject".into(), Precision::Bf16, true)?);
        }
    }
    Ok(rows)
}

/// Canned table for `dash figures --fig dvt`: the default verification
/// sweep on an `n x n` grid.
pub fn determinism_throughput_table(
    n: usize,
    heads: usize,
    seed: u64,
) -> crate::Result<Vec<DvtRow>> {
    verify_matrix(&VerifyOptions::defaults(n, heads, seed))
}

impl super::TableRow for DvtRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("mask", self.mask.clone()),
            ("schedule", self.schedule.clone()),
            ("prec", self.precision.to_string()),
            ("makespan", super::fmt_f64(self.makespan)),
            ("x_atomic", format!("{:.3}", self.rel_throughput)),
            ("execs", self.executions.to_string()),
            ("hashes", self.distinct.to_string()),
            ("bitwise", if self.deterministic { "YES".into() } else { "no".into() }),
            ("max_dev", super::fmt_f64(self.max_dev)),
            ("flops", if self.flops_ok { "ok".into() } else { "MISMATCH".into() }),
            ("grad_hash", self.hash.clone()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hermetic option set: no tuned schedules (the inline quick-tune
    /// consults the on-disk cache), small matrix.
    fn opts() -> VerifyOptions {
        VerifyOptions {
            kinds: vec![
                ScheduleKind::Fa3Atomic,
                ScheduleKind::Fa3,
                ScheduleKind::Descending,
                ScheduleKind::Shift,
                ScheduleKind::SymmetricShift,
                ScheduleKind::TwoPass,
                ScheduleKind::Lpt,
            ],
            ..VerifyOptions::defaults(4, 4, 33)
        }
    }

    #[test]
    fn deterministic_rows_hold_one_hash_and_atomic_rows_scatter() {
        let rows = verify_matrix(&opts()).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.flops_ok, "{r:?}");
            let should_hold = r.schedule != "fa3-atomic" && r.schedule != "fa3-det+inject";
            if should_hold {
                assert!(r.deterministic, "{r:?}");
                assert_eq!(r.max_dev, 0.0, "{r:?}");
            }
        }
        // The negative controls must scatter somewhere in bf16.
        assert!(
            rows.iter().any(|r| r.schedule == "fa3-det+inject" && !r.deterministic),
            "injected rows must be caught"
        );
        assert!(
            rows.iter().any(|r| r.schedule == "fa3-atomic"
                && r.precision == "bf16"
                && !r.deterministic),
            "atomic bf16 rows must scatter"
        );
    }

    #[test]
    fn shift_rows_exist_only_for_full_masks() {
        let rows = verify_matrix(&opts()).unwrap();
        assert!(rows.iter().any(|r| r.schedule == "shift" && r.mask == "full"));
        assert!(rows.iter().all(|r| r.schedule != "shift" || r.mask == "full"));
    }

    #[test]
    fn determinism_costs_throughput_on_causal() {
        let rows = verify_matrix(&opts()).unwrap();
        let fa3_det = rows
            .iter()
            .find(|r| r.schedule == "fa3-det" && r.mask == "causal")
            .unwrap();
        assert!(
            fa3_det.rel_throughput <= 1.0 + 1e-9,
            "deterministic FA3 cannot out-run atomic: {fa3_det:?}"
        );
    }
}
