//! Printable rows for the fleet tuning surfaces: the `dash tune --queue`
//! provenance report and the `--portfolio` replica table.

use super::{fmt_f64, TableRow};
use crate::autotune::{PortfolioResult, QueueReport};

/// One drained queue workload, ready for [`super::render_table`] /
/// [`super::render_csv`].
#[derive(Debug, Clone)]
pub struct QueueRow {
    /// The workload's cache key.
    pub workload: String,
    /// Mask name.
    pub mask: String,
    /// KV x Q tile geometry.
    pub n: String,
    /// Head instances.
    pub heads: usize,
    /// Machine width tuned for.
    pub n_sm: usize,
    /// hit / warm / cold.
    pub provenance: &'static str,
    /// Donating cache key for warm starts, `-` otherwise.
    pub warm_src: String,
    /// Makespan of the served or tuned schedule.
    pub mksp: f64,
    /// Optimality gap vs the recorded lower bound, in percent.
    pub gap_pct: f64,
    /// Proposals evaluated (0 for hits).
    pub evaluated: usize,
}

impl TableRow for QueueRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("workload", self.workload.clone()),
            ("mask", self.mask.clone()),
            ("n", self.n.clone()),
            ("heads", self.heads.to_string()),
            ("n_sm", self.n_sm.to_string()),
            ("provenance", self.provenance.to_string()),
            ("warm_src", self.warm_src.clone()),
            ("mksp", fmt_f64(self.mksp)),
            ("gap_pct", fmt_f64(self.gap_pct)),
            ("evaluated", self.evaluated.to_string()),
        ]
    }
}

/// Flatten a [`QueueReport`] into display rows (already in sorted key
/// order — the order-independence the queue tests pin).
pub fn queue_rows(report: &QueueReport) -> Vec<QueueRow> {
    report
        .outcomes
        .iter()
        .map(|o| QueueRow {
            workload: o.key.clone(),
            mask: o.spec.mask.name(),
            n: format!("{}x{}", o.spec.n_kv, o.spec.n_q),
            heads: o.spec.n_heads,
            n_sm: o.n_sm,
            provenance: o.provenance.label(),
            warm_src: match &o.provenance {
                crate::autotune::Provenance::Warm(src) => src.clone(),
                _ => "-".to_string(),
            },
            mksp: o.makespan,
            gap_pct: o.gap() * 100.0,
            evaluated: o.evaluated,
        })
        .collect()
}

/// One portfolio replica for the `dash tune --portfolio` table.
#[derive(Debug, Clone)]
pub struct ReplicaRow {
    /// Replica index (RNG stream and tie-break rank).
    pub replica: usize,
    /// Annealing temperature.
    pub temp: f64,
    /// Best makespan the replica found.
    pub mksp: f64,
    /// Proposals scored without error.
    pub evaluated: usize,
    /// Strict improvements accepted.
    pub improved: usize,
    /// Uphill accepts under the Metropolis rule.
    pub uphill: usize,
    /// `winner` marker column.
    pub won: &'static str,
}

impl TableRow for ReplicaRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("replica", self.replica.to_string()),
            ("temp", fmt_f64(self.temp)),
            ("mksp", fmt_f64(self.mksp)),
            ("evaluated", self.evaluated.to_string()),
            ("improved", self.improved.to_string()),
            ("uphill", self.uphill.to_string()),
            ("won", self.won.to_string()),
        ]
    }
}

/// Flatten a [`PortfolioResult`] into display rows, one per replica.
pub fn replica_rows(result: &PortfolioResult) -> Vec<ReplicaRow> {
    result
        .replicas
        .iter()
        .map(|r| ReplicaRow {
            replica: r.index,
            temp: r.temperature,
            mksp: r.makespan,
            evaluated: r.evaluated,
            improved: r.improvements,
            uphill: r.uphill,
            won: if r.index == result.winner_index { "*" } else { "" },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{run_queue, tune_portfolio, PortfolioOptions, ScheduleCache,
        TuneOptions, QueueSpec};
    use crate::schedule::{MaskSpec, ProblemSpec};
    use crate::sim::SimConfig;

    #[test]
    fn queue_rows_render_provenance_and_sorted_keys() {
        let queue = vec![
            QueueSpec {
                spec: ProblemSpec::square(8, 2, MaskSpec::causal()),
                n_sm: 8,
                budget: None,
            },
            QueueSpec {
                spec: ProblemSpec::square(6, 2, MaskSpec::causal()),
                n_sm: 6,
                budget: Some(10),
            },
        ];
        let base = TuneOptions {
            budget: 20,
            seed: 1,
            sim: SimConfig::ideal(8),
            batch: 1,
            threads: 1,
        };
        let mut cache = ScheduleCache::open("fleet-rows-never-written.json");
        let report = run_queue(&queue, &base, 0, &mut cache).unwrap();
        let rows = queue_rows(&report);
        assert_eq!(rows.len(), 2);
        let mut keys: Vec<&str> = rows.iter().map(|r| r.workload.as_str()).collect();
        let sorted = {
            let mut s = keys.clone();
            s.sort();
            s
        };
        assert_eq!(keys, sorted, "rows must come out in sorted key order");
        keys.dedup();
        assert_eq!(keys.len(), 2);
        let table = super::super::render_table(&rows);
        assert!(table.contains("provenance"));
        assert!(table.contains("warm") || table.contains("cold"));
    }

    #[test]
    fn replica_rows_mark_exactly_one_winner() {
        let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
        let p = tune_portfolio(
            &spec,
            &PortfolioOptions {
                replicas: 3,
                budget: 16,
                seed: 7,
                sim: SimConfig::ideal(8),
                batch: 4,
                threads: 1,
            },
        )
        .unwrap();
        let rows = replica_rows(&p);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows.iter().filter(|r| r.won == "*").count(), 1);
        assert_eq!(rows[p.winner_index].won, "*");
        let csv = super::super::render_csv(&rows);
        assert!(csv.starts_with("replica,temp,mksp,evaluated,improved,uphill,won\n"));
    }
}
