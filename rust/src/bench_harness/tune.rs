//! Tuned-vs-analytic sweep: the autotuner's wins and losses against the
//! paper's closed-form schedules as a regenerable artifact.
//!
//! The grid deliberately mixes the paper's home regimes (where Shift /
//! Symmetric Shift are provably optimal and the tuner must tie them) with
//! off-regime points — machines narrower than a wave (`n_sm = 4`), an SM
//! count that divides nothing (`n_sm = 13`, ~a GPC), tile counts the
//! closed forms were not derived for — where search has room to win.

use crate::autotune::{tune, TuneOptions};
use crate::schedule::{MaskSpec, ProblemSpec};
use crate::sim::SimConfig;
use crate::util::par_map;

/// Tile counts swept.
pub const TUNE_SWEEP_NS: [usize; 4] = [8, 16, 24, 32];
/// Machine widths swept.
pub const TUNE_SWEEP_SMS: [usize; 3] = [4, 8, 13];

/// One grid point of the tuned-vs-analytic sweep.
#[derive(Debug, Clone)]
pub struct TuneSweepRow {
    /// Mask name.
    pub mask: String,
    /// Tiles per side.
    pub n: usize,
    /// SMs.
    pub n_sm: usize,
    /// Best analytic schedule at this point (the tuner's seed).
    pub analytic_name: &'static str,
    /// Its makespan.
    pub analytic: f64,
    /// Tuned makespan (never greater than `analytic`).
    pub tuned: f64,
    /// Lower-bound oracle verdict.
    pub lower_bound: f64,
    /// Tuned optimality gap vs the bound, in percent.
    pub gap_pct: f64,
    /// Tuned speedup over the best analytic schedule.
    pub speedup: f64,
    /// Proposals evaluated (legal + simulated) by the search.
    pub evaluated: usize,
    /// Proposals rejected by the legality validator.
    pub skipped_invalid: usize,
    /// Proposals whose simulation returned an error.
    pub skipped_sim: usize,
}

/// Run the sweep: masks {full, causal} x n in [`TUNE_SWEEP_NS`] x n_sm in
/// [`TUNE_SWEEP_SMS`], `heads` head instances, `budget` search proposals
/// per point. Deterministic given its arguments.
pub fn tune_sweep(heads: usize, budget: usize, seed: u64) -> Vec<TuneSweepRow> {
    let mut points = Vec::new();
    for mask in [MaskSpec::full(), MaskSpec::causal()] {
        for &n in &TUNE_SWEEP_NS {
            for &n_sm in &TUNE_SWEEP_SMS {
                points.push((mask.clone(), n, n_sm));
            }
        }
    }
    // Each grid point is an independent search: fan out across host cores
    // (results reassemble in grid order, so the artifact stays stable).
    par_map(&points, |(mask, n, n_sm): &(MaskSpec, usize, usize)| {
        let (n, n_sm) = (*n, *n_sm);
        let spec = ProblemSpec::square(n, heads, mask.clone());
        let opts =
            TuneOptions { budget, seed, sim: SimConfig::ideal(n_sm), batch: 1, threads: 1 };
        let r = tune(&spec, &opts).expect("FA3 seed is always feasible");
        TuneSweepRow {
            mask: mask.name(),
            n,
            n_sm,
            analytic_name: r.seed_kind.name(),
            analytic: r.seed_makespan,
            tuned: r.makespan,
            lower_bound: r.bound.overall(),
            gap_pct: r.gap() * 100.0,
            speedup: if r.makespan > 0.0 { r.seed_makespan / r.makespan } else { 1.0 },
            evaluated: r.evaluated,
            skipped_invalid: r.skipped_invalid,
            skipped_sim: r.skipped_sim,
        }
    })
}

impl super::TableRow for TuneSweepRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("mask", self.mask.clone()),
            ("n", self.n.to_string()),
            ("n_sm", self.n_sm.to_string()),
            ("analytic", self.analytic_name.to_string()),
            ("analytic_mksp", super::fmt_f64(self.analytic)),
            ("tuned_mksp", super::fmt_f64(self.tuned)),
            ("lower_bound", super::fmt_f64(self.lower_bound)),
            ("gap_pct", super::fmt_f64(self.gap_pct)),
            ("speedup", super::fmt_f64(self.speedup)),
            ("evaluated", self.evaluated.to_string()),
            ("skipped_invalid", self.skipped_invalid.to_string()),
            ("skipped_sim", self.skipped_sim.to_string()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuned_never_loses_and_respects_the_bound() {
        // A reduced-budget pass over the full acceptance grid: tuned must
        // match or beat the best analytic schedule at EVERY point and never
        // undercut the lower bound.
        let rows = tune_sweep(2, 24, 11);
        assert_eq!(rows.len(), 2 * TUNE_SWEEP_NS.len() * TUNE_SWEEP_SMS.len());
        for r in &rows {
            assert!(
                r.tuned <= r.analytic + 1e-9,
                "{} n={} n_sm={}: tuned {} vs analytic {}",
                r.mask,
                r.n,
                r.n_sm,
                r.tuned,
                r.analytic
            );
            assert!(
                r.tuned >= r.lower_bound - 1e-9,
                "{} n={} n_sm={}: tuned {} below bound {}",
                r.mask,
                r.n,
                r.n_sm,
                r.tuned,
                r.lower_bound
            );
            assert!(r.speedup >= 1.0 - 1e-9);
            // Counter conservation: every proposal drawn from the budget
            // is accounted for as evaluated or skipped.
            assert!(r.evaluated + r.skipped_invalid + r.skipped_sim <= 24);
        }
    }

    #[test]
    fn home_regime_points_are_certified_optimal() {
        let rows = tune_sweep(2, 8, 3);
        // Full mask, n = n_sm = 8: Shift meets the bound exactly.
        let home = rows
            .iter()
            .find(|r| r.mask == "full" && r.n == 8 && r.n_sm == 8)
            .unwrap();
        assert!(home.gap_pct < 1e-6, "gap {}%", home.gap_pct);
        // Causal, n = n_sm = 8, even heads: Symmetric Shift ditto.
        let causal = rows
            .iter()
            .find(|r| r.mask == "causal" && r.n == 8 && r.n_sm == 8)
            .unwrap();
        assert!(causal.gap_pct < 1e-6, "gap {}%", causal.gap_pct);
    }
}
