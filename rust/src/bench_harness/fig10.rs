//! Figure 10: end-to-end transformer-block performance across the paper's
//! model zoo — (a) relative speedup of DASH over the FA3-deterministic
//! baseline, (b) kernel-time breakdown.
//!
//! Block time = attention fwd (sim-independent, no serialized reductions)
//! + attention bwd (simulated per schedule) + GEMM fwd/bwd (roofline at the
//! machine's effective FLOPs) + a fixed "other" share (norms, elementwise,
//! optimizer) calibrated to ~10% as in the paper's breakdown. All machine
//! numbers come from the active [`crate::hw::GpuProfile`] — nothing here
//! names a concrete GPU.

use crate::attention::flops;
use crate::hw::Machine;
use crate::schedule::{MaskSpec, ScheduleKind};
use crate::sim::workload::{run_point, BenchConfig};
use crate::util::par_map;

/// A model from the paper's §4.4 zoo.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Display name.
    pub name: &'static str,
    /// Hidden dimension.
    pub hidden: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// MLP expansion ratio (active experts folded in for MoE).
    pub mlp_ratio: f64,
    /// Mask shape (LLMs causal; vision/diffusion full).
    pub mask: MaskSpec,
    /// Batch size used in the paper (1 for LLMs, 16 for full-mask models).
    pub batch: usize,
    /// Sequence lengths evaluated.
    pub seqlens: &'static [usize],
}

/// The paper's evaluated models (Fig 10a): three causal LLMs at 8k/16k/32k,
/// four full-mask models at 4k.
pub static PAPER_MODELS: [ModelConfig; 7] = [
    ModelConfig { name: "LLaMA3-8b", hidden: 4096, head_dim: 128, mlp_ratio: 3.5, mask: MaskSpec::causal(), batch: 1, seqlens: &[8192, 16384, 32768] },
    ModelConfig { name: "Qwen2.5-7b", hidden: 3584, head_dim: 128, mlp_ratio: 5.3, mask: MaskSpec::causal(), batch: 1, seqlens: &[8192, 16384, 32768] },
    ModelConfig { name: "Mistral-8x7b", hidden: 4096, head_dim: 128, mlp_ratio: 7.0, mask: MaskSpec::causal(), batch: 1, seqlens: &[8192, 16384, 32768] },
    ModelConfig { name: "SAM-huge", hidden: 1280, head_dim: 80, mlp_ratio: 4.0, mask: MaskSpec::full(), batch: 16, seqlens: &[4096] },
    ModelConfig { name: "SD3.5-medium", hidden: 1536, head_dim: 64, mlp_ratio: 4.0, mask: MaskSpec::full(), batch: 16, seqlens: &[4096] },
    ModelConfig { name: "SD3.5-large", hidden: 2432, head_dim: 64, mlp_ratio: 4.0, mask: MaskSpec::full(), batch: 16, seqlens: &[4096] },
    ModelConfig { name: "LLaDA-1b", hidden: 2048, head_dim: 64, mlp_ratio: 4.0, mask: MaskSpec::full(), batch: 16, seqlens: &[4096] },
];

/// One Fig-10a row: end-to-end block speedup of DASH vs baseline.
#[derive(Debug, Clone)]
pub struct Fig10aRow {
    /// Model name.
    pub model: &'static str,
    /// Sequence length.
    pub seqlen: usize,
    /// Which DASH schedule was selected (best per mask/headdim rules).
    pub schedule: String,
    /// Baseline block time (ms, modelled).
    pub baseline_ms: f64,
    /// DASH block time (ms, modelled).
    pub dash_ms: f64,
    /// End-to-end block speedup.
    pub speedup: f64,
}

/// One Fig-10b row: kernel-time breakdown fractions.
#[derive(Debug, Clone)]
pub struct Fig10bRow {
    /// Model name.
    pub model: &'static str,
    /// attention backward share of block time, %.
    pub attn_bwd_pct: f64,
    /// attention forward share, %.
    pub attn_fwd_pct: f64,
    /// GEMM share, %.
    pub gemm_pct: f64,
    /// everything else, %.
    pub other_pct: f64,
}

/// Timing components of one block step (seconds).
struct BlockTimes {
    attn_fwd: f64,
    attn_bwd: f64,
    gemm: f64,
    other: f64,
}

fn block_times(
    model: &ModelConfig,
    seqlen: usize,
    attn_kind: ScheduleKind,
    m: &Machine,
) -> BlockTimes {
    let heads = model.hidden / model.head_dim;
    let causal = matches!(model.mask, MaskSpec::Causal { .. });
    let tokens = model.batch * seqlen;
    let machine_flops = m.profile.machine_flops();
    let hz = m.profile.clock_ghz * 1e9;

    // Attention forward: roofline (no serialized reductions in fwd).
    let attn_fwd =
        flops::attention_fwd_flops(model.batch, heads, seqlen, model.head_dim, causal)
            / machine_flops;

    // Attention backward: simulated with the chosen schedule. BenchConfig
    // carries the paper's sweep shape; override geometry for the model.
    let cfg = BenchConfig {
        seqlen,
        total_tokens: tokens,
        hidden: model.hidden,
        head_dim: model.head_dim,
        block: 128,
        mask: model.mask.clone(),
    };
    let p = run_point(&cfg, attn_kind, m);
    let attn_bwd = p.makespan_cycles / hz;

    // GEMMs: fwd + bwd at roofline with a sustained-efficiency derate.
    let gemm_eff = 0.85;
    let gemm = (flops::block_gemm_fwd_flops(tokens, model.hidden, model.mlp_ratio)
        + flops::block_gemm_bwd_flops(tokens, model.hidden, model.mlp_ratio))
        / (machine_flops * gemm_eff);

    // Norms / rotary / elementwise / dropout: ~10% of the rest.
    let other = 0.10 * (attn_fwd + attn_bwd + gemm);
    BlockTimes { attn_fwd, attn_bwd, gemm, other }
}

/// The schedule DASH deploys per the paper's guidance: full mask -> Shift;
/// everything with non-uniform chains (causal, sliding-window, document,
/// sparse) -> Symmetric Shift at hd < 128, Descending at hd >= 128
/// (register pressure, §4.3).
pub fn dash_schedule_for(mask: &MaskSpec, head_dim: usize) -> ScheduleKind {
    match mask {
        MaskSpec::Full => ScheduleKind::Shift,
        _ if head_dim >= 128 => ScheduleKind::Descending,
        _ => ScheduleKind::SymmetricShift,
    }
}

/// Regenerate Fig 10a on a modelled machine.
pub fn fig10a_end_to_end(m: &Machine) -> Vec<Fig10aRow> {
    let mut points = Vec::new();
    for model in &PAPER_MODELS {
        for &seqlen in model.seqlens {
            points.push((model, seqlen));
        }
    }
    par_map(&points, |&(model, seqlen)| {
        let kind = dash_schedule_for(&model.mask, model.head_dim);
        let base = block_times(model, seqlen, ScheduleKind::Fa3, m);
        let dash = block_times(model, seqlen, kind, m);
        let total = |t: &BlockTimes| t.attn_fwd + t.attn_bwd + t.gemm + t.other;
        Fig10aRow {
            model: model.name,
            seqlen,
            schedule: kind.name().to_string(),
            baseline_ms: total(&base) * 1e3,
            dash_ms: total(&dash) * 1e3,
            speedup: total(&base) / total(&dash),
        }
    })
}

/// Regenerate Fig 10b (causal models at 16k as in the paper; full-mask
/// models at their 4k setting).
pub fn fig10b_breakdown(m: &Machine) -> Vec<Fig10bRow> {
    par_map(&PAPER_MODELS, |model| {
        let seqlen =
            if matches!(model.mask, MaskSpec::Causal { .. }) { 16384 } else { model.seqlens[0] };
        let t = block_times(model, seqlen, ScheduleKind::Fa3, m);
        let total = t.attn_fwd + t.attn_bwd + t.gemm + t.other;
        Fig10bRow {
            model: model.name,
            attn_bwd_pct: t.attn_bwd / total * 100.0,
            attn_fwd_pct: t.attn_fwd / total * 100.0,
            gemm_pct: t.gemm / total * 100.0,
            other_pct: t.other / total * 100.0,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn fig10a_speedups_in_paper_band() {
        // Paper: causal 2-10%, full ~4%, average ~5%.
        let rows = fig10a_end_to_end(&Machine::real(presets::h800()));
        for r in &rows {
            assert!(
                r.speedup >= 0.99 && r.speedup < 1.30,
                "{} @ {}: speedup {} outside plausible band",
                r.model,
                r.seqlen,
                r.speedup
            );
        }
        let avg: f64 = rows.iter().map(|r| r.speedup).sum::<f64>() / rows.len() as f64;
        assert!(avg > 1.01 && avg < 1.15, "average speedup {avg}");
    }

    #[test]
    fn fig10b_fractions_sum_to_100() {
        for r in fig10b_breakdown(&Machine::real(presets::h800())) {
            let total = r.attn_bwd_pct + r.attn_fwd_pct + r.gemm_pct + r.other_pct;
            assert!((total - 100.0).abs() < 1e-6, "{r:?}");
            assert!(r.gemm_pct > r.attn_fwd_pct, "GEMMs dominate blocks: {r:?}");
        }
    }

    #[test]
    fn schedule_selection_rules() {
        assert_eq!(dash_schedule_for(&MaskSpec::full(), 64), ScheduleKind::Shift);
        assert_eq!(dash_schedule_for(&MaskSpec::causal(), 64), ScheduleKind::SymmetricShift);
        assert_eq!(dash_schedule_for(&MaskSpec::causal(), 128), ScheduleKind::Descending);
        // New mask shapes route to the mask-generic DASH schedules, never
        // to Shift (whose cycle they cannot support).
        assert_eq!(
            dash_schedule_for(&MaskSpec::sliding_window(4), 64),
            ScheduleKind::SymmetricShift
        );
        assert_eq!(
            dash_schedule_for(&MaskSpec::document(vec![4]), 128),
            ScheduleKind::Descending
        );
    }
}

impl super::TableRow for Fig10aRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("model", self.model.to_string()),
            ("seqlen", self.seqlen.to_string()),
            ("schedule", self.schedule.clone()),
            ("baseline_ms", super::fmt_f64(self.baseline_ms)),
            ("dash_ms", super::fmt_f64(self.dash_ms)),
            ("speedup", super::fmt_f64(self.speedup)),
        ]
    }
}

impl super::TableRow for Fig10bRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("model", self.model.to_string()),
            ("attn_bwd_pct", super::fmt_f64(self.attn_bwd_pct)),
            ("attn_fwd_pct", super::fmt_f64(self.attn_fwd_pct)),
            ("gemm_pct", super::fmt_f64(self.gemm_pct)),
            ("other_pct", super::fmt_f64(self.other_pct)),
        ]
    }
}
