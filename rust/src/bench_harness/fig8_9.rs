//! Figures 8 and 9: backward-pass throughput vs sequence length for every
//! schedule, full mask (Fig 8) and causal mask (Fig 9), head dims 64/128.

use crate::hw::Machine;
use crate::schedule::{MaskSpec, ScheduleKind};
use crate::sim::workload::{run_point, BenchConfig, PAPER_SEQLENS};
use crate::util::par_map;

/// One throughput point on a Fig 8/9 curve.
#[derive(Debug, Clone)]
pub struct FigRow {
    /// Schedule name.
    pub schedule: String,
    /// Head dimension.
    pub head_dim: usize,
    /// Sequence length.
    pub seqlen: usize,
    /// Achieved TFLOPs/s.
    pub tflops: f64,
    /// Speedup over the FA3 deterministic baseline at the same point.
    pub speedup_vs_fa3: f64,
    /// Stall fraction of total SM-time.
    pub stall_frac: f64,
}

fn sweep(mask: MaskSpec, kinds: &[ScheduleKind], m: &Machine) -> Vec<FigRow> {
    let mut points = Vec::new();
    for &hd in &[64usize, 128] {
        for &seqlen in &PAPER_SEQLENS {
            points.push((hd, seqlen));
        }
    }
    // One x-axis point per parallel task (its schedules share the FA3
    // baseline); results reassemble in sweep order.
    par_map(&points, |&(hd, seqlen)| {
        let cfg = BenchConfig::paper(seqlen, hd, mask.clone());
        let base = run_point(&cfg, ScheduleKind::Fa3, m);
        kinds
            .iter()
            .map(|&kind| {
                let p = if kind == ScheduleKind::Fa3 {
                    base.clone()
                } else {
                    run_point(&cfg, kind, m)
                };
                FigRow {
                    schedule: kind.name().to_string(),
                    head_dim: hd,
                    seqlen,
                    tflops: p.tflops,
                    speedup_vs_fa3: p.tflops / base.tflops,
                    stall_frac: p.stall_cycles / (p.makespan_cycles * p.n_sm as f64),
                }
            })
            .collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Fig 8: full-mask backward throughput (baseline, shift, descending).
pub fn fig8_full_mask(m: &Machine) -> Vec<FigRow> {
    sweep(
        MaskSpec::full(),
        &[ScheduleKind::Fa3, ScheduleKind::Shift, ScheduleKind::Descending],
        m,
    )
}

/// Fig 9: causal-mask backward throughput (baseline, descending,
/// symmetric shift, Triton-style two-pass).
pub fn fig9_causal_mask(m: &Machine) -> Vec<FigRow> {
    sweep(
        MaskSpec::causal(),
        &[
            ScheduleKind::Fa3,
            ScheduleKind::Descending,
            ScheduleKind::SymmetricShift,
            ScheduleKind::TwoPass,
        ],
        m,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    fn by<'a>(rows: &'a [FigRow], sched: &str, hd: usize, seqlen: usize) -> &'a FigRow {
        rows.iter()
            .find(|r| r.schedule == sched && r.head_dim == hd && r.seqlen == seqlen)
            .unwrap()
    }

    #[test]
    fn fig8_shift_wins_at_moderate_seqlens() {
        let rows = fig8_full_mask(&Machine::real(presets::h800()));
        // Paper: shift outperforms baseline across most sequence lengths.
        for &sl in &[1024usize, 2048, 4096, 8192] {
            let s = by(&rows, "shift", 128, sl);
            assert!(
                s.speedup_vs_fa3 > 1.0,
                "shift should beat fa3 at seqlen {sl}: {}",
                s.speedup_vs_fa3
            );
        }
    }

    #[test]
    fn fig9_dash_schedules_beat_baseline() {
        let rows = fig9_causal_mask(&Machine::real(presets::h800()));
        for &sl in &[2048usize, 4096, 8192, 16384] {
            for sched in ["descending", "symmetric-shift"] {
                let r = by(&rows, sched, 64, sl);
                assert!(
                    r.speedup_vs_fa3 >= 1.0,
                    "{sched} at seqlen {sl}: {}",
                    r.speedup_vs_fa3
                );
            }
        }
    }

    #[test]
    fn fig9_hd128_inversion_descending_beats_symshift() {
        // §4.3: register spills at hd128 make Descending the practical
        // winner over the theoretically-optimal Symmetric Shift.
        let rows = fig9_causal_mask(&Machine::real(presets::h800()));
        let mut desc_wins = 0;
        let mut total = 0;
        for &sl in &[4096usize, 8192, 16384] {
            let d = by(&rows, "descending", 128, sl);
            let s = by(&rows, "symmetric-shift", 128, sl);
            total += 1;
            if d.tflops > s.tflops {
                desc_wins += 1;
            }
        }
        assert!(desc_wins >= total - 1, "descending should win at hd128 ({desc_wins}/{total})");
    }
}

impl super::TableRow for FigRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("schedule", self.schedule.clone()),
            ("head_dim", self.head_dim.to_string()),
            ("seqlen", self.seqlen.to_string()),
            ("tflops", super::fmt_f64(self.tflops)),
            ("speedup_vs_fa3", super::fmt_f64(self.speedup_vs_fa3)),
            ("stall_frac", super::fmt_f64(self.stall_frac)),
        ]
    }
}
