//! Cross-GPU tuned-schedule sweep — the scenario axis the hardware-profile
//! layer opens: the *same* workload grid, tuned and scored under
//! *different* [`crate::hw::GpuProfile`]s, side by side.
//!
//! Two things become visible that a single-machine harness cannot express:
//!
//! 1. schedule quality depends on the `n_sm`-vs-`n_kv` regime, so the best
//!    schedule (and the tuner's win over the closed forms) shifts between
//!    parts — e.g. a 114-SM H100 PCIe folds the same chain set differently
//!    than a 132-SM H800;
//! 2. the autotune cache keys by profile fingerprint, so per-GPU results
//!    coexist without cross-contamination.
//!
//! Reachable as `dash tune --sweep --gpu <a>,<b> [--json <path>]` and as
//! the `cross_gpu_sweep` example; the JSON artifact is the comparison's
//! machine-readable form.

use crate::autotune::{tune, TuneOptions};
use crate::hw::{GpuProfile, Machine};
use crate::schedule::{MaskSpec, ProblemSpec, ScheduleKind};
use crate::sim::SimConfig;
use crate::util::{par_map, Json};

/// Tile counts swept per GPU.
pub const CROSS_GPU_NS: [usize; 3] = [8, 16, 24];
/// Head dimensions swept per GPU (they change the profile-derived cost
/// model and occupancy, not just the geometry).
pub const CROSS_GPU_HEAD_DIMS: [usize; 2] = [64, 128];

/// One (gpu, workload) grid point of the cross-GPU sweep.
#[derive(Debug, Clone)]
pub struct CrossGpuRow {
    /// Profile name.
    pub gpu: String,
    /// Mask name.
    pub mask: String,
    /// Tiles per side.
    pub n: usize,
    /// Machine width the point ran on (profile SMs; `n` on abstract).
    pub n_sm: usize,
    /// Head dimension calibrating the cost model.
    pub head_dim: usize,
    /// Best analytic schedule at this point (the tuner's seed).
    pub analytic_name: &'static str,
    /// Its makespan, cycles.
    pub analytic: f64,
    /// Tuned makespan, cycles (never greater than `analytic`).
    pub tuned: f64,
    /// Tuned makespan in microseconds at the profile's clock.
    pub tuned_us: f64,
    /// Lower-bound oracle verdict, cycles.
    pub lower_bound: f64,
    /// Tuned optimality gap vs the bound, in percent.
    pub gap_pct: f64,
    /// Tuned speedup over the best analytic schedule.
    pub speedup: f64,
    /// Proposals evaluated (legal + simulated) by the search.
    pub evaluated: usize,
    /// Proposals rejected by the legality validator.
    pub skipped_invalid: usize,
    /// Proposals whose simulation returned an error.
    pub skipped_sim: usize,
}

/// The scoring configuration for one grid point on one GPU — delegates to
/// [`Machine::sim_config`], the single profile-to-SimConfig recipe, scored
/// as [`ScheduleKind::Tuned`] like every other tuner entry point.
fn sim_for(profile: &GpuProfile, n: usize, head_dim: usize) -> SimConfig {
    Machine::real(profile.clone()).sim_config(ScheduleKind::Tuned, n, 128, head_dim)
}

/// Tuned-vs-analytic sweep of one profile over the cross-GPU grid
/// (masks {full, causal} x [`CROSS_GPU_NS`] x [`CROSS_GPU_HEAD_DIMS`]),
/// searches fanned out across host cores. Deterministic given arguments.
pub fn tune_sweep_gpu(
    profile: &GpuProfile,
    heads: usize,
    budget: usize,
    seed: u64,
) -> Vec<CrossGpuRow> {
    let mut points = Vec::new();
    for mask in [MaskSpec::full(), MaskSpec::causal()] {
        for &n in &CROSS_GPU_NS {
            for &head_dim in &CROSS_GPU_HEAD_DIMS {
                points.push((mask.clone(), n, head_dim));
            }
        }
    }
    par_map(&points, |(mask, n, head_dim): &(MaskSpec, usize, usize)| {
        let (n, head_dim) = (*n, *head_dim);
        let spec = ProblemSpec::square(n, heads, mask.clone());
        let sim = sim_for(profile, n, head_dim);
        let r = tune(&spec, &TuneOptions { budget, seed, sim, batch: 1, threads: 1 })
            .expect("FA3 seed is always feasible");
        CrossGpuRow {
            gpu: profile.name.clone(),
            mask: mask.name(),
            n,
            n_sm: sim.n_sm,
            head_dim,
            analytic_name: r.seed_kind.name(),
            analytic: r.seed_makespan,
            tuned: r.makespan,
            tuned_us: r.makespan / (profile.clock_ghz * 1e9) * 1e6,
            lower_bound: r.bound.overall(),
            gap_pct: r.gap() * 100.0,
            speedup: if r.makespan > 0.0 { r.seed_makespan / r.makespan } else { 1.0 },
            evaluated: r.evaluated,
            skipped_invalid: r.skipped_invalid,
            skipped_sim: r.skipped_sim,
        }
    })
}

/// Run [`tune_sweep_gpu`] for each profile and concatenate — the same
/// workloads under different machines, ready to diff.
pub fn cross_gpu_sweep(
    profiles: &[GpuProfile],
    heads: usize,
    budget: usize,
    seed: u64,
) -> Vec<CrossGpuRow> {
    profiles
        .iter()
        .flat_map(|p| tune_sweep_gpu(p, heads, budget, seed))
        .collect()
}

/// The sweep as a JSON artifact (for plotting / regression diffing).
pub fn cross_gpu_json(rows: &[CrossGpuRow]) -> Json {
    let mut gpus: Vec<Json> = Vec::new();
    for r in rows {
        if !gpus.iter().any(|g| g.as_str() == Some(r.gpu.as_str())) {
            gpus.push(Json::Str(r.gpu.clone()));
        }
    }
    Json::Obj(vec![
        ("version".into(), Json::Num(1.0)),
        ("gpus".into(), Json::Arr(gpus)),
        (
            "rows".into(),
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::Obj(vec![
                            ("gpu".into(), Json::Str(r.gpu.clone())),
                            ("mask".into(), Json::Str(r.mask.clone())),
                            ("n".into(), Json::Num(r.n as f64)),
                            ("n_sm".into(), Json::Num(r.n_sm as f64)),
                            ("head_dim".into(), Json::Num(r.head_dim as f64)),
                            ("analytic".into(), Json::Str(r.analytic_name.into())),
                            ("analytic_makespan".into(), Json::Num(r.analytic)),
                            ("tuned_makespan".into(), Json::Num(r.tuned)),
                            ("tuned_us".into(), Json::Num(r.tuned_us)),
                            ("lower_bound".into(), Json::Num(r.lower_bound)),
                            ("gap_pct".into(), Json::Num(r.gap_pct)),
                            ("speedup".into(), Json::Num(r.speedup)),
                            ("evaluated".into(), Json::Num(r.evaluated as f64)),
                            ("skipped_invalid".into(), Json::Num(r.skipped_invalid as f64)),
                            ("skipped_sim".into(), Json::Num(r.skipped_sim as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

impl super::TableRow for CrossGpuRow {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("gpu", self.gpu.clone()),
            ("mask", self.mask.clone()),
            ("n", self.n.to_string()),
            ("n_sm", self.n_sm.to_string()),
            ("head_dim", self.head_dim.to_string()),
            ("analytic", self.analytic_name.to_string()),
            ("analytic_mksp", super::fmt_f64(self.analytic)),
            ("tuned_mksp", super::fmt_f64(self.tuned)),
            ("tuned_us", super::fmt_f64(self.tuned_us)),
            ("gap_pct", super::fmt_f64(self.gap_pct)),
            ("speedup", super::fmt_f64(self.speedup)),
            ("evaluated", self.evaluated.to_string()),
            ("skipped_invalid", self.skipped_invalid.to_string()),
            ("skipped_sim", self.skipped_sim.to_string()),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn sweep_covers_the_grid_and_never_loses() {
        let rows = tune_sweep_gpu(&presets::h100(), 2, 16, 7);
        assert_eq!(rows.len(), 2 * CROSS_GPU_NS.len() * CROSS_GPU_HEAD_DIMS.len());
        for r in &rows {
            assert_eq!(r.gpu, "h100");
            assert_eq!(r.n_sm, 114);
            assert!(r.tuned <= r.analytic + 1e-9, "{r:?}");
            assert!(r.tuned >= r.lower_bound - 1e-9, "{r:?}");
            assert!(r.tuned_us > 0.0 && r.tuned_us.is_finite());
        }
    }

    #[test]
    fn abstract_profile_sweeps_at_workload_width() {
        let rows = tune_sweep_gpu(&presets::abstract_machine(), 2, 8, 3);
        for r in &rows {
            assert_eq!(r.n_sm, r.n, "abstract machine: n_sm follows the workload");
        }
    }

    #[test]
    fn cross_gpu_concatenates_and_jsonifies() {
        let profiles = [presets::h800(), presets::h100()];
        let rows = cross_gpu_sweep(&profiles, 2, 4, 1);
        assert_eq!(rows.len(), 2 * 12);
        let doc = cross_gpu_json(&rows);
        let gpus = doc.get("gpus").unwrap().as_arr().unwrap();
        assert_eq!(gpus.len(), 2);
        assert_eq!(doc.get("rows").unwrap().as_arr().unwrap().len(), rows.len());
        // Round-trips through the in-tree JSON.
        let text = doc.dump();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }
}
