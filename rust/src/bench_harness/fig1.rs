//! Figure 1 (right): throughput degradation of deterministic FA3 relative
//! to its atomic (non-deterministic) counterpart, under causal and full
//! masks and head dims 64/128 — the motivating measurement ("up to 37.9%").

use crate::hw::Machine;
use crate::schedule::{MaskSpec, ScheduleKind};
use crate::sim::workload::{run_point, BenchConfig, PAPER_SEQLENS};
use crate::util::par_map;

/// One row of the Fig-1 degradation table.
#[derive(Debug, Clone)]
pub struct Fig1Row {
    /// Mask name.
    pub mask: String,
    /// Head dimension.
    pub head_dim: usize,
    /// Sequence length.
    pub seqlen: usize,
    /// Non-deterministic (atomic) throughput, TFLOPs/s.
    pub atomic_tflops: f64,
    /// Deterministic throughput, TFLOPs/s.
    pub det_tflops: f64,
    /// Degradation percentage: (atomic - det) / atomic * 100.
    pub degradation_pct: f64,
}

/// Regenerate Fig 1 (right): deterministic-mode degradation sweep on a
/// modelled machine (points simulated across host cores).
pub fn fig1_degradation(m: &Machine) -> Vec<Fig1Row> {
    let mut points = Vec::new();
    for mask in [MaskSpec::causal(), MaskSpec::full()] {
        for &hd in &[64usize, 128] {
            for &seqlen in &PAPER_SEQLENS {
                points.push((mask.clone(), hd, seqlen));
            }
        }
    }
    par_map(&points, |(mask, hd, seqlen): &(MaskSpec, usize, usize)| {
        let cfg = BenchConfig::paper(*seqlen, *hd, mask.clone());
        let atomic = run_point(&cfg, ScheduleKind::Fa3Atomic, m);
        let det = run_point(&cfg, ScheduleKind::Fa3, m);
        Fig1Row {
            mask: mask.name(),
            head_dim: *hd,
            seqlen: *seqlen,
            atomic_tflops: atomic.tflops,
            det_tflops: det.tflops,
            degradation_pct: (atomic.tflops - det.tflops) / atomic.tflops * 100.0,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn degradation_nonnegative_and_grows_with_seqlen_causal() {
        let rows = fig1_degradation(&Machine::real(presets::h800()));
        for r in &rows {
            assert!(r.degradation_pct >= -1e-6, "{r:?}");
            assert!(r.degradation_pct < 60.0, "{r:?}");
        }
        // Causal hd128: long sequences degrade more than short ones.
        let causal128: Vec<&Fig1Row> = rows
            .iter()
            .filter(|r| r.mask == "causal" && r.head_dim == 128)
            .collect();
        let short = causal128.iter().find(|r| r.seqlen == 512).unwrap();
        let long = causal128.iter().find(|r| r.seqlen == 16384).unwrap();
        assert!(
            long.degradation_pct > short.degradation_pct,
            "short {} vs long {}",
            short.degradation_pct,
            long.degradation_pct
        );
    }
}

impl super::TableRow for Fig1Row {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("mask", self.mask.clone()),
            ("head_dim", self.head_dim.to_string()),
            ("seqlen", self.seqlen.to_string()),
            ("atomic_tflops", super::fmt_f64(self.atomic_tflops)),
            ("det_tflops", super::fmt_f64(self.det_tflops)),
            ("degradation_pct", super::fmt_f64(self.degradation_pct)),
        ]
    }
}
