//! Table 1: max gradient deviation over 10 identical backward passes,
//! deterministic vs non-deterministic accumulation — Rust softfloat side.
//! (The Python test suite runs the same experiment through the actual
//! Pallas kernels; see `python/tests/test_determinism.py`.)

use crate::numerics::deviation_across_orders;
use crate::util::DetRng;

/// One Table-1 row.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Masking scheme.
    pub mask: String,
    /// Max deviation with shuffled (atomic-like) accumulation orders.
    pub nondet_max_dev: f64,
    /// Max deviation with the fixed order (must be exactly 0).
    pub det_max_dev: f64,
    /// Distinct bit patterns over non-deterministic runs.
    pub nondet_distinct: usize,
    /// Distinct bit patterns over deterministic runs (must be 1).
    pub det_distinct: usize,
}

/// Generate dQ-element partial contributions with attention-like scale:
/// each contribution is a dot-product of dS-row and K-column entries,
/// zero-mean, variance ~1. `n_contribs` = number of KV tiles folded.
fn gradient_contributions(n_contribs: usize, seed: u64) -> Vec<f32> {
    let mut rng = DetRng::new(seed);
    (0..n_contribs)
        .map(|_| {
            // Sum of 8 products emulates a partial dot-product's magnitude
            // distribution (heavier tails than a single gaussian).
            (0..8)
                .map(|_| rng.gen_f32_range(-1.0, 1.0) * rng.gen_f32_range(-1.0, 1.0))
                .sum::<f32>()
        })
        .collect()
}

/// Regenerate Table 1 with `runs` backward passes per cell.
///
/// Causal masks fold fewer contributions per dQ element on average (half
/// the KV tiles are masked) but the deviation magnitude is the same order;
/// the paper reports 2.4e-4 (full) and 4.9e-4 (causal) for real gradients.
pub fn table1_determinism(runs: usize, seed: u64) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for (mask, n_contribs) in [("full", 128usize), ("causal", 64usize)] {
        // Aggregate max deviation over many dQ elements, as the paper's
        // max |q_r - q_ref| is over the whole gradient tensor.
        let mut nondet_max = 0.0f64;
        let mut det_max = 0.0f64;
        let mut nondet_distinct = 0usize;
        let mut det_distinct = 1usize;
        for elem in 0..256 {
            let values = gradient_contributions(n_contribs, seed ^ (elem as u64) << 8);
            let nd = deviation_across_orders(&values, runs, true, seed + elem);
            let d = deviation_across_orders(&values, runs, false, seed + elem);
            nondet_max = nondet_max.max(nd.max_abs_deviation);
            det_max = det_max.max(d.max_abs_deviation);
            nondet_distinct = nondet_distinct.max(nd.distinct_results);
            det_distinct = det_distinct.max(d.distinct_results);
        }
        rows.push(Table1Row {
            mask: mask.to_string(),
            nondet_max_dev: nondet_max,
            det_max_dev: det_max,
            nondet_distinct,
            det_distinct,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_is_bitwise_stable() {
        for row in table1_determinism(10, 42) {
            assert_eq!(row.det_max_dev, 0.0, "{row:?}");
            assert_eq!(row.det_distinct, 1, "{row:?}");
        }
    }

    #[test]
    fn nondeterministic_deviates_at_table1_order() {
        for row in table1_determinism(10, 42) {
            assert!(row.nondet_distinct > 1, "{row:?}");
            // O(1e-4)-ish: within two orders of magnitude of the paper's
            // 2.4e-4 / 4.9e-4 (exact value depends on the data distribution).
            assert!(
                row.nondet_max_dev > 1e-6 && row.nondet_max_dev < 1e-2,
                "{row:?}"
            );
        }
    }
}

impl super::TableRow for Table1Row {
    fn cells(&self) -> Vec<(&'static str, String)> {
        vec![
            ("mask", self.mask.clone()),
            ("nondet_max_dev", super::fmt_f64(self.nondet_max_dev)),
            ("det_max_dev", super::fmt_f64(self.det_max_dev)),
            ("nondet_distinct", self.nondet_distinct.to_string()),
            ("det_distinct", self.det_distinct.to_string()),
        ]
    }
}
