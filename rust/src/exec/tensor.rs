//! Minimal dense row-major f32 matrix for the tile executor. Internal to
//! [`crate::exec`]: the executor's arithmetic must be auditable down to
//! loop order (the bits of every gradient depend on it), so the type is a
//! thin `Vec<f32>` wrapper with explicit indexing and nothing clever.

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub(crate) struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Mutable element accessor.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    /// Row slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Serial f32 dot product — ascending index, the one reduction order every
/// executor GEMM uses, so recomputed logits match forward logits bitwise.
#[inline]
pub(crate) fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_row_major() {
        let mut m = Mat::zeros(2, 3);
        *m.at_mut(1, 2) = 7.0;
        assert_eq!(m.data[5], 7.0);
        assert_eq!(m.at(1, 2), 7.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 7.0]);
    }

    #[test]
    fn dot_is_serial_ascending() {
        // Serial fold: ((1e8 + 1) - 1e8) with unit partners = 0 in f32;
        // any tree order would give 1. Pin the serial semantics.
        let a = [1.0f32, 1.0, 1.0];
        let b = [1e8f32, 1.0, -1e8];
        assert_eq!(dot_f32(&a, &b), 0.0);
        assert_eq!(dot_f32(&[2.0, 3.0], &[4.0, 5.0]), 23.0);
    }
}
