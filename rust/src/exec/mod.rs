//! Tile-level reference executor: actually *runs* the attention backward
//! pass in software, following any [`Schedule`] — the empirical leg of the
//! repo's determinism claims.
//!
//! Everywhere else in the crate a schedule's "determinism" is a structural
//! property (a total per-(head, q) reduction order exists). This module
//! executes the schedule numerically and proves the property at the bit
//! level: seeded synthetic Q/K/V/dO ([`crate::util::DetRng`]), per-tile
//! dQ/dK/dV partials computed with the five GEMMs of Algorithm 1, dQ
//! folded through [`crate::numerics::reduce_tiles_ordered`] in the
//! schedule's reduction order (f32 or bf16 storage), and a content hash
//! ([`crate::coordinator::fingerprint_f32`]) of the final gradients.
//!
//! The machine model is deliberately thin: chains *complete* in an order
//! decided by a greedy `n_sm`-wide list scheduler plus an optional seeded
//! jitter (`perturb` — the "thread shuffle" axis). For a deterministic
//! schedule the completion order fills the per-(head, q) partial buffers
//! in machine-dependent order but the fold drains them in the schedule's
//! prescribed order, so the gradient bits cannot depend on `n_sm` or
//! `perturb` — which the [`oracle`] verifies rather than assumes. With
//! `inject_atomic` (or a schedule that never had a reduction order, like
//! `fa3-atomic`) the fold follows raw arrival order instead: atomicAdd
//! semantics, whose bf16 hash divergence the oracle must catch.
//!
//! Scope: this is a *reference* executor for small tile grids (the
//! default is 4x4-element tiles at head dim 8), not a performance kernel.
//! Its loop orders are fixed and documented so every bit of the output is
//! reproducible from the seed alone.

pub mod oracle;
pub mod reference;
mod tensor;

use crate::attention::flops::tile_gemm_flops;
use crate::coordinator::fingerprint_f32;
use crate::numerics::{reduce_tiles_ordered, Precision};
use crate::schedule::{validate, ClusterSchedule, Schedule};
use crate::util::{fnv1a_words, DetRng};
use tensor::{dot_f32, Mat};

pub use oracle::{
    verify_batch_invariance, verify_device_counts, verify_schedule, BatchVerdict, OracleOptions,
    OracleVerdict, RequestInvariance,
};
pub use reference::{reference_backward, RefGrads};

/// Per-tensor seed tags, mixed with the data seed and head index so the
/// four operands of one head draw from disjoint streams.
const TAG_Q: u64 = 1;
const TAG_K: u64 = 2;
const TAG_V: u64 = 3;
const TAG_DO: u64 = 4;

/// Configuration of one executor run. The *data* is decided by
/// `(block, head_dim, seed)`; the *machine* by `(n_sm, perturb)`; the
/// *semantics* by `(precision, inject_atomic)`. A deterministic schedule's
/// output must be invariant under the machine knobs — that is the claim
/// the oracle tests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecConfig {
    /// Elements per tile side (the executor's `Bq = Bc`). Small by design:
    /// 4 keeps a full oracle sweep under a second.
    pub block: usize,
    /// Head dimension `d` of the synthetic Q/K/V.
    pub head_dim: usize,
    /// Seed for the synthetic Q/K/V/dO.
    pub seed: u64,
    /// Accumulation/storage precision of the dQ fold and gradient stores.
    pub precision: Precision,
    /// Machine width for the chain-completion model.
    pub n_sm: usize,
    /// Seeded completion-order jitter ("thread shuffle"); 0 = none.
    pub perturb: u64,
    /// Ignore the schedule's reduction order and fold dQ in raw arrival
    /// order — injected atomicAdd semantics, the oracle's negative probe.
    pub inject_atomic: bool,
    /// Keep each device's intra-device fold order but fold the *devices*
    /// in a seeded (perturb-derived) permutation instead of the schedule's
    /// fixed [`crate::schedule::ClusterSchedule::xdev_order`] — an
    /// unordered cross-device reduction, the multi-GPU negative probe. No
    /// effect on single-device schedules.
    pub inject_xdev: bool,
    /// Rotate each dQ fold order by a key derived from the *batch layout*
    /// (document count and the document's start tile) — a serving
    /// batch-invariance leak, the negative probe of
    /// [`oracle::verify_batch_invariance`]. Provably inert when the mask
    /// has fewer than two documents (batch count 1, or any non-document
    /// mask).
    pub inject_batch: bool,
}

impl ExecConfig {
    /// Canonical small configuration: 4x4 tiles, head dim 8, f32, a
    /// 4-SM machine, no jitter, no injection.
    pub fn new(seed: u64) -> Self {
        Self {
            block: 4,
            head_dim: 8,
            seed,
            precision: Precision::F32,
            n_sm: 4,
            perturb: 0,
            inject_atomic: false,
            inject_xdev: false,
            inject_batch: false,
        }
    }
}

/// Executed gradients and their content hashes.
///
/// Gradient layouts are head-major row-major flats: `dq` is
/// `n_heads * n_q * block` rows by `head_dim` columns flattened, and
/// `dk`/`dv` likewise over KV rows. Hashes are
/// [`fingerprint_f32`] over the exact bit patterns, so a single ULP of
/// drift anywhere changes [`ExecResult::grad_hash`].
#[derive(Debug, Clone)]
pub struct ExecResult {
    /// Combined content hash of (dQ, dK, dV).
    pub grad_hash: u64,
    /// Hash of the dQ flat.
    pub dq_hash: u64,
    /// Hash of the dK flat.
    pub dk_hash: u64,
    /// Hash of the dV flat.
    pub dv_hash: u64,
    /// FLOPs actually executed, counted per tile GEMM — cross-checkable
    /// against [`crate::attention::flops`] (see [`expected_flops`]).
    pub flops: f64,
    /// Tile visits executed (two-pass schedules visit each live tile once
    /// per pass).
    pub tiles_executed: usize,
    /// dQ gradient flat (see the struct docs for layout).
    pub dq: Vec<f32>,
    /// dK gradient flat.
    pub dk: Vec<f32>,
    /// dV gradient flat.
    pub dv: Vec<f32>,
}

/// FLOPs [`execute_backward`] must report for `s`, derived from the
/// schedule's chain structure: 5 GEMMs per fused tile visit, 4 for a
/// dK/dV-only pass-1 visit (`reduce_scale == 0`), 3 for a transposed
/// pass-2 dQ visit. For every fused generator this equals
/// `spec.total_tiles() * `[`crate::attention::flops::bwd_tile_flops`], and
/// for the two-pass baseline it equals
/// [`crate::attention::flops::BWD_TWO_PASS_GEMMS`]` / 5` times that — the
/// analytic cross-check the oracle enforces.
pub fn expected_flops(s: &Schedule, block: usize, head_dim: usize) -> f64 {
    let g = tile_gemm_flops(block, head_dim);
    s.chains
        .iter()
        .map(|c| {
            let gemms = if c.head >= s.spec.n_heads {
                3 // pass-2: recompute S and dP, emit dQ
            } else if c.reduce_scale == 0.0 {
                4 // pass-1: S, dP, dV, dK — no dQ write
            } else {
                5 // fused Algorithm 1 tile
            };
            (c.len() * gemms) as f64 * g
        })
        .sum()
}

/// One head's synthetic operands plus forward-pass statistics.
struct HeadData {
    q: Mat,    // (n_q * block) x head_dim
    k: Mat,    // (n_kv * block) x head_dim
    v: Mat,    // (n_kv * block) x head_dim
    dout: Mat, // (n_q * block) x head_dim
    /// Per-Q-row logsumexp of the live logits (`-inf` if the row has no
    /// live KV tile — such rows are never visited by any chain).
    lse: Vec<f32>,
    /// Per-Q-row `D_i = dot(dO_i, O_i)`, the softmax-backward coefficient.
    dcoef: Vec<f32>,
}

/// Deterministic synthetic matrix: uniform in [-1, 1).
fn gen_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = DetRng::new(seed);
    let data = (0..rows * cols).map(|_| rng.gen_f32_range(-1.0, 1.0)).collect();
    Mat { rows, cols, data }
}

/// Per-document operand layout: the mask's document tile segments paired
/// with one content seed per document (see [`execute_backward_docs`]).
#[derive(Clone, Copy)]
struct DocLayout<'a> {
    /// Half-open `(start, end)` tile ranges, one per document.
    segments: &'a [(usize, usize)],
    /// Content seed of each document.
    seeds: &'a [u64],
    /// Elements per tile side.
    block: usize,
}

/// Deterministic synthetic matrix with *document-relative* content: each
/// document's rows are drawn from a stream seeded by `(seed, doc_seed)`
/// alone, so a document's bits do not depend on where in the sequence the
/// batch compiler placed it. (Plain [`gen_mat`] draws one stream over the
/// whole matrix, which is exactly the position dependence batch
/// invariance must avoid.)
fn gen_mat_docs(rows: usize, cols: usize, seed: u64, docs: DocLayout<'_>) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    for (&(s0, s1), &ds) in docs.segments.iter().zip(docs.seeds) {
        let mut rng = DetRng::new(fnv1a_words([seed, ds]));
        for r in s0 * docs.block..s1 * docs.block {
            for c in 0..cols {
                *m.at_mut(r, c) = rng.gen_f32_range(-1.0, 1.0);
            }
        }
    }
    m
}

/// Softmax scale `1/sqrt(d)`.
fn softmax_scale(head_dim: usize) -> f32 {
    1.0 / (head_dim as f32).sqrt()
}

/// Generate one head's operands and run the (schedule-independent)
/// forward pass: logsumexp per Q row and the D coefficients, computed in
/// f32 with ascending-KV loops so every schedule sees identical bits.
/// With a [`DocLayout`], operand content is document-relative (the
/// serving mode); the forward statistics are document-local either way —
/// under a document mask a Q row's live KV columns all lie in its own
/// document, and the ascending loops walk them in document-relative
/// order.
fn head_data(s: &Schedule, cfg: &ExecConfig, head: usize, docs: Option<DocLayout<'_>>) -> HeadData {
    let spec = &s.spec;
    let (b, d) = (cfg.block, cfg.head_dim);
    let (qr, kr) = (spec.n_q * b, spec.n_kv * b);
    let gen = |rows: usize, tag: u64| -> Mat {
        let seed = fnv1a_words([cfg.seed, head as u64, tag]);
        match docs {
            Some(layout) => gen_mat_docs(rows, d, seed, layout),
            None => gen_mat(rows, d, seed),
        }
    };
    let q = gen(qr, TAG_Q);
    let k = gen(kr, TAG_K);
    let v = gen(kr, TAG_V);
    let dout = gen(qr, TAG_DO);
    let scale = softmax_scale(d);

    let mut lse = vec![f32::NEG_INFINITY; qr];
    let mut dcoef = vec![0.0f32; qr];
    let mut s_row = vec![f32::NEG_INFINITY; kr];
    let mut o_row = vec![0.0f32; d];
    for i in 0..qr {
        let qt = i / b;
        let mut m = f32::NEG_INFINITY;
        for (j, sj) in s_row.iter_mut().enumerate() {
            if spec.live(j / b, qt) {
                let sij = scale * dot_f32(q.row(i), k.row(j));
                *sj = sij;
                m = m.max(sij);
            } else {
                *sj = f32::NEG_INFINITY;
            }
        }
        if m == f32::NEG_INFINITY {
            continue; // fully-masked Q row: O = 0, dQ = 0
        }
        let mut l = 0.0f32;
        for &sj in &s_row {
            if sj > f32::NEG_INFINITY {
                l += (sj - m).exp();
            }
        }
        let lse_i = m + l.ln();
        o_row.fill(0.0);
        for (j, &sj) in s_row.iter().enumerate() {
            if sj > f32::NEG_INFINITY {
                let p = (sj - lse_i).exp();
                for (o, &ve) in o_row.iter_mut().zip(v.row(j)) {
                    *o += p * ve;
                }
            }
        }
        lse[i] = lse_i;
        dcoef[i] = dot_f32(dout.row(i), &o_row);
    }
    HeadData { q, k, v, dout, lse, dcoef }
}

/// One chain's modelled execution interval on the executor's thin machine
/// model — the data behind [`chain_completion_spans`], exposed so the
/// trace layer ([`crate::trace`]) can render and hash executor timelines.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainSpan {
    /// Chain index in the schedule.
    pub chain: usize,
    /// SM the chain ran on.
    pub sm: usize,
    /// Modelled start time (arbitrary units; chains on one SM tile
    /// back-to-back from t = 0).
    pub start: f64,
    /// Modelled completion time.
    pub end: f64,
}

/// Chain execution spans on an `n_sm`-wide machine, *in completion order*
/// (the order dQ partials arrive in [`execute_backward`]): greedy list
/// scheduling in launch order (pinned chains via [`Schedule::placement`],
/// dynamic chains onto the earliest-free SM), with an optional seeded
/// duration jitter and completion tie shuffle when `perturb != 0`. This is
/// the only place machine shape enters the executor.
pub fn chain_completion_spans(s: &Schedule, n_sm: usize, perturb: u64) -> Vec<ChainSpan> {
    if let Some(cluster) = s.cluster.as_ref().filter(|c| c.n_devices > 1) {
        return cluster_completion_spans(s, cluster, n_sm, perturb);
    }
    let n_sm = n_sm.max(1);
    let mut rng = DetRng::new(perturb);
    let mut free = vec![0.0f64; n_sm];
    let mut done: Vec<(f64, u64, ChainSpan)> = Vec::with_capacity(s.chains.len());
    for (i, c) in s.chains.iter().enumerate() {
        let sm = s.placement(i, n_sm).unwrap_or_else(|| {
            let mut best = 0usize;
            for (j, &t) in free.iter().enumerate() {
                if t < free[best] {
                    best = j;
                }
            }
            best
        });
        let jitter = if perturb == 0 { 0.0 } else { 0.05 * rng.gen_f64() };
        let dur = (c.len().max(1) as f64) * c.compute_scale.max(0.1) * (1.0 + jitter);
        let start = free[sm];
        let end = start + dur;
        free[sm] = end;
        let tie = if perturb == 0 { i as u64 } else { rng.next_u64() };
        done.push((end, tie, ChainSpan { chain: i, sm, start, end }));
    }
    done.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .expect("finite times")
            .then(a.1.cmp(&b.1))
            .then(a.2.chain.cmp(&b.2.chain))
    });
    done.into_iter().map(|(_, _, span)| span).collect()
}

/// Multi-device variant of [`chain_completion_spans`]: each device is an
/// independent `n_sm`-wide machine running only its own chains (lanes are
/// namespaced `device * n_sm + local`), with a seeded per-device arrival
/// skew on top of the usual duration jitter when `perturb != 0` — devices
/// never start in lockstep on a real cluster, so the completion (arrival)
/// order of dQ partials interleaves machine-dependently across devices.
/// A deterministic schedule's gradients must be invariant to all of it.
///
/// The full schedule's pinned wave placement indexes the unsharded wave,
/// so per device the model falls back to greedy earliest-free lanes.
fn cluster_completion_spans(
    s: &Schedule,
    cluster: &ClusterSchedule,
    n_sm: usize,
    perturb: u64,
) -> Vec<ChainSpan> {
    let n_sm = n_sm.max(1);
    let mut rng = DetRng::new(perturb);
    let skew: Vec<f64> = (0..cluster.n_devices)
        .map(|_| if perturb == 0 { 0.0 } else { 0.25 * rng.gen_f64() })
        .collect();
    let mut free: Vec<Vec<f64>> = skew.iter().map(|&t| vec![t; n_sm]).collect();
    let mut done: Vec<(f64, u64, ChainSpan)> = Vec::with_capacity(s.chains.len());
    for (i, c) in s.chains.iter().enumerate() {
        let dev = cluster.device[i];
        let lanes = &mut free[dev];
        let mut best = 0usize;
        for (j, &t) in lanes.iter().enumerate() {
            if t < lanes[best] {
                best = j;
            }
        }
        let jitter = if perturb == 0 { 0.0 } else { 0.05 * rng.gen_f64() };
        let dur = (c.len().max(1) as f64) * c.compute_scale.max(0.1) * (1.0 + jitter);
        let start = lanes[best];
        let end = start + dur;
        lanes[best] = end;
        let tie = if perturb == 0 { i as u64 } else { rng.next_u64() };
        done.push((end, tie, ChainSpan { chain: i, sm: dev * n_sm + best, start, end }));
    }
    done.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .expect("finite times")
            .then(a.1.cmp(&b.1))
            .then(a.2.chain.cmp(&b.2.chain))
    });
    done.into_iter().map(|(_, _, span)| span).collect()
}

/// The order chains complete in (see [`chain_completion_spans`]).
fn completion_order(s: &Schedule, n_sm: usize, perturb: u64) -> Vec<usize> {
    chain_completion_spans(s, n_sm, perturb).into_iter().map(|cs| cs.chain).collect()
}

/// One buffered dQ partial: contributing KV tile, whether its chain takes
/// part in the serialized reduction order, the device that produced it
/// (0 for single-device schedules), and the `block x head_dim` tile data
/// (bf16-rounded on store under [`Precision::Bf16`]).
struct Partial {
    kv: usize,
    ordered: bool,
    device: usize,
    tile: Vec<f32>,
}

/// Execute the backward pass of `s` and hash the gradients.
///
/// The schedule is validated first ([`crate::schedule::validate`]); an
/// illegal schedule is an error, never a silently wrong gradient.
///
/// ```
/// use dash::exec::{execute_backward, ExecConfig};
/// use dash::schedule::{fa3, MaskSpec, ProblemSpec};
///
/// let spec = ProblemSpec::square(3, 2, MaskSpec::causal());
/// let sched = fa3(&spec, true);
/// let a = execute_backward(&sched, &ExecConfig::new(7)).unwrap();
/// // Same seed, same schedule: bitwise-identical gradients...
/// let b = execute_backward(&sched, &ExecConfig::new(7)).unwrap();
/// assert_eq!(a.grad_hash, b.grad_hash);
/// // ...even on a machine of a different width.
/// let wide = ExecConfig { n_sm: 13, perturb: 99, ..ExecConfig::new(7) };
/// assert_eq!(execute_backward(&sched, &wide).unwrap().grad_hash, a.grad_hash);
/// ```
pub fn execute_backward(s: &Schedule, cfg: &ExecConfig) -> crate::Result<ExecResult> {
    execute_backward_with(s, cfg, None)
}

/// [`execute_backward`] with *document-seeded* operands: the serving-layer
/// entry point. `doc_seeds[i]` decides the content of the mask's `i`-th
/// document, and each document's Q/K/V/dO bits are generated relative to
/// its own tile range — so the same `(request, segment)` carries the same
/// data wherever a batch compiler places it (see
/// [`crate::traceload::StepSlice::doc_seed`]). Requires a square spec
/// under a [`crate::mask::MaskSpec::Document`] mask with exactly one seed
/// per document.
pub fn execute_backward_docs(
    s: &Schedule,
    cfg: &ExecConfig,
    doc_seeds: &[u64],
) -> crate::Result<ExecResult> {
    execute_backward_with(s, cfg, Some(doc_seeds))
}

/// Shared body of [`execute_backward`] / [`execute_backward_docs`].
fn execute_backward_with(
    s: &Schedule,
    cfg: &ExecConfig,
    doc_seeds: Option<&[u64]>,
) -> crate::Result<ExecResult> {
    validate(s).map_err(|e| anyhow::anyhow!("illegal schedule: {e}"))?;
    anyhow::ensure!(cfg.block >= 1 && cfg.head_dim >= 1, "degenerate tile geometry");
    let spec = &s.spec;
    let (b, d) = (cfg.block, cfg.head_dim);
    let scale = softmax_scale(d);
    let tile_len = b * d;
    let gemm = tile_gemm_flops(b, d);
    let bf16 = cfg.precision == Precision::Bf16;

    let doc_segments = spec.mask.document_segments(spec.n_kv.max(spec.n_q));
    let docs = match doc_seeds {
        None => None,
        Some(seeds) => {
            anyhow::ensure!(
                spec.n_kv == spec.n_q,
                "document-seeded execution needs a square spec, got {}x{}",
                spec.n_kv,
                spec.n_q
            );
            let segments = doc_segments
                .as_deref()
                .ok_or_else(|| anyhow::anyhow!("document-seeded execution needs a document mask"))?;
            anyhow::ensure!(
                segments.len() == seeds.len(),
                "{} doc seeds for {} documents",
                seeds.len(),
                segments.len()
            );
            Some(DocLayout { segments, seeds, block: b })
        }
    };

    let heads: Vec<HeadData> = (0..spec.n_heads).map(|h| head_data(s, cfg, h, docs)).collect();

    // Gradient stores and the per-(head, q-tile) dQ partial buffers.
    let mut dq: Vec<Mat> = (0..spec.n_heads).map(|_| Mat::zeros(spec.n_q * b, d)).collect();
    let mut dk: Vec<Mat> = (0..spec.n_heads).map(|_| Mat::zeros(spec.n_kv * b, d)).collect();
    let mut dv: Vec<Mat> = (0..spec.n_heads).map(|_| Mat::zeros(spec.n_kv * b, d)).collect();
    let mut partials: Vec<Vec<Partial>> =
        (0..spec.n_heads * spec.n_q).map(|_| Vec::new()).collect();

    let mut flops = 0.0f64;
    let mut tiles = 0usize;

    // Scratch tiles, reused across visits.
    let mut p_t = vec![0.0f32; b * b];
    let mut ds_t = vec![0.0f32; b * b];

    for &ci in &completion_order(s, cfg.n_sm, cfg.perturb) {
        let c = &s.chains[ci];
        let head = c.head % spec.n_heads;
        let hd = &heads[head];
        let pass2 = c.head >= spec.n_heads;
        if pass2 {
            // Transposed walk: the chain owns Q tile `c.kv` and folds its
            // dQ locally (f32 registers) over the visited KV tiles.
            let qt = c.kv;
            let mut acc = vec![0.0f32; tile_len];
            for &kvt in &c.q_order {
                tiles += 1;
                flops += 3.0 * gemm;
                compute_p(hd, b, qt, kvt, scale, &mut p_t);
                for bi in 0..b {
                    let i = qt * b + bi;
                    for bj in 0..b {
                        let j = kvt * b + bj;
                        let dp = dot_f32(hd.dout.row(i), hd.v.row(j));
                        ds_t[bi * b + bj] = p_t[bi * b + bj] * (dp - hd.dcoef[i]) * scale;
                    }
                }
                for bi in 0..b {
                    for e in 0..d {
                        let mut x = 0.0f32;
                        for bj in 0..b {
                            x += ds_t[bi * b + bj] * hd.k.at(kvt * b + bj, e);
                        }
                        acc[bi * d + e] += x;
                    }
                }
            }
            store_tile(&mut dq[head], qt * b, &acc, d, bf16);
            continue;
        }

        // Pass-1 / fused chain: owns KV tile `c.kv`, walks live Q tiles.
        let kvt = c.kv;
        let emits_dq = c.reduce_scale > 0.0;
        let mut dk_acc = vec![0.0f32; tile_len];
        let mut dv_acc = vec![0.0f32; tile_len];
        for &qt in &c.q_order {
            tiles += 1;
            flops += if emits_dq { 5.0 } else { 4.0 } * gemm;
            compute_p(hd, b, qt, kvt, scale, &mut p_t);
            // dV += Pᵀ dO and dS = P ∘ (dP − D) · scale.
            for bi in 0..b {
                let i = qt * b + bi;
                let dp_row: Vec<f32> =
                    (0..b).map(|bj| dot_f32(hd.dout.row(i), hd.v.row(kvt * b + bj))).collect();
                for bj in 0..b {
                    let p = p_t[bi * b + bj];
                    ds_t[bi * b + bj] = p * (dp_row[bj] - hd.dcoef[i]) * scale;
                    for e in 0..d {
                        dv_acc[bj * d + e] += p * hd.dout.at(i, e);
                    }
                }
            }
            // dK += dSᵀ Q.
            for bj in 0..b {
                for e in 0..d {
                    let mut x = 0.0f32;
                    for bi in 0..b {
                        x += ds_t[bi * b + bj] * hd.q.at(qt * b + bi, e);
                    }
                    dk_acc[bj * d + e] += x;
                }
            }
            // dQ partial = dS K, buffered for the global fold.
            if emits_dq {
                let mut tile = vec![0.0f32; tile_len];
                for bi in 0..b {
                    for e in 0..d {
                        let mut x = 0.0f32;
                        for bj in 0..b {
                            x += ds_t[bi * b + bj] * hd.k.at(kvt * b + bj, e);
                        }
                        tile[bi * d + e] = x;
                    }
                }
                if bf16 {
                    round_bf16(&mut tile);
                }
                partials[head * spec.n_q + qt].push(Partial {
                    kv: kvt,
                    ordered: c.ordered,
                    device: s.device_of(ci),
                    tile,
                });
            }
        }
        store_tile(&mut dk[head], kvt * b, &dk_acc, d, bf16);
        store_tile(&mut dv[head], kvt * b, &dv_acc, d, bf16);
    }

    // Global dQ fold: the schedule's reduction order when one exists (and
    // no injection), raw arrival order otherwise.
    let use_order = !cfg.inject_atomic && !s.reduction_order.is_empty();
    for head in 0..spec.n_heads {
        for qt in 0..spec.n_q {
            let parts = std::mem::take(&mut partials[head * spec.n_q + qt]);
            if parts.is_empty() {
                continue;
            }
            let mut order: Vec<usize> = if use_order {
                let mut ord = Vec::with_capacity(parts.len());
                for &kv in s.reduction_order_of(head, qt) {
                    if let Some(pos) = parts.iter().position(|p| p.ordered && p.kv == kv) {
                        ord.push(pos);
                    }
                }
                // Unordered contributions (none for the built-in
                // generators) land after the serialized fold, in arrival
                // order.
                ord.extend(parts.iter().enumerate().filter(|(_, p)| !p.ordered).map(|(i, _)| i));
                if cfg.inject_xdev && s.n_devices() > 1 {
                    // Unordered cross-device fold: regroup the ordered
                    // positions by producing device and fold the device
                    // groups in a seeded per-(head, q) permutation — each
                    // device's internal sub-order survives, the fixed
                    // xdev_order does not.
                    let n_dev = s.n_devices() as u64;
                    let r = fnv1a_words([cfg.perturb, head as u64, qt as u64]);
                    let mut devs: Vec<usize> = (0..n_dev as usize).collect();
                    devs.rotate_left((r % n_dev) as usize);
                    if (r / n_dev) % 2 == 1 {
                        devs.reverse();
                    }
                    let mut regrouped = Vec::with_capacity(ord.len());
                    for &dv in &devs {
                        regrouped
                            .extend(ord.iter().copied().filter(|&pos| parts[pos].device == dv));
                    }
                    regrouped
                } else {
                    ord
                }
            } else {
                (0..parts.len()).collect()
            };
            // Batch-layout leak probe: key a rotation (and conditional
            // reversal) of the fold order on the document count and the
            // tile's document start — exactly the quantities a correct
            // serving fold must never consult. With fewer than two
            // documents the key has nothing batch-shaped to leak and the
            // probe leaves the order untouched.
            if cfg.inject_batch && order.len() > 1 {
                if let Some(segs) = doc_segments.as_ref().filter(|segs| segs.len() > 1) {
                    let n = spec.n_kv.max(spec.n_q);
                    let seq = qt + (n - spec.n_q);
                    if let Some(&(ds, _)) = segs.iter().find(|&&(s0, s1)| seq >= s0 && seq < s1) {
                        let r = fnv1a_words([
                            cfg.perturb,
                            segs.len() as u64,
                            ds as u64,
                            head as u64,
                            qt as u64,
                        ]);
                        order.rotate_left(r as usize % order.len());
                        if (r >> 32) & 1 == 1 {
                            order.reverse();
                        }
                    }
                }
            }
            let part_tiles: Vec<Vec<f32>> = parts.into_iter().map(|p| p.tile).collect();
            let folded = reduce_tiles_ordered(tile_len, &part_tiles, &order, cfg.precision);
            let base = qt * b;
            for bi in 0..b {
                for e in 0..d {
                    *dq[head].at_mut(base + bi, e) = folded[bi * d + e];
                }
            }
        }
    }

    let flatten = |ms: &[Mat]| -> Vec<f32> {
        let mut out = Vec::with_capacity(ms.iter().map(|m| m.data.len()).sum());
        for m in ms {
            out.extend_from_slice(&m.data);
        }
        out
    };
    let (dq, dk, dv) = (flatten(&dq), flatten(&dk), flatten(&dv));
    let (dq_hash, dk_hash, dv_hash) =
        (fingerprint_f32(&dq), fingerprint_f32(&dk), fingerprint_f32(&dv));
    Ok(ExecResult {
        grad_hash: fnv1a_words([dq_hash, dk_hash, dv_hash]),
        dq_hash,
        dk_hash,
        dv_hash,
        flops,
        tiles_executed: tiles,
        dq,
        dk,
        dv,
    })
}

/// Per-document gradient hashes of an executed result: one content hash
/// per document of the schedule's mask, covering that document's dQ, dK,
/// and dV rows across every head. This is the per-request identity the
/// serving oracle compares across batch layouts — two executions place a
/// request identically iff its hash here is identical. `None` unless the
/// spec is square under a [`crate::mask::MaskSpec::Document`] mask.
pub fn document_grad_hashes(s: &Schedule, cfg: &ExecConfig, r: &ExecResult) -> Option<Vec<u64>> {
    let spec = &s.spec;
    if spec.n_kv != spec.n_q {
        return None;
    }
    let n = spec.n_kv;
    let segments = spec.mask.document_segments(n)?;
    let (b, d) = (cfg.block, cfg.head_dim);
    let head_len = n * b * d;
    let mut out = Vec::with_capacity(segments.len());
    for &(s0, s1) in &segments {
        let mut words = Vec::with_capacity(spec.n_heads * 3);
        for head in 0..spec.n_heads {
            let lo = head * head_len + s0 * b * d;
            let hi = head * head_len + s1 * b * d;
            words.push(fingerprint_f32(&r.dq[lo..hi]));
            words.push(fingerprint_f32(&r.dk[lo..hi]));
            words.push(fingerprint_f32(&r.dv[lo..hi]));
        }
        out.push(fnv1a_words(words));
    }
    Some(out)
}

/// Recompute the S tile bit-identically to the forward pass and derive
/// P = exp(S - lse) — `p_t` is `b x b` scratch, row-major over (local q
/// row, local kv col). Every Q row of a live tile has a finite lse.
fn compute_p(hd: &HeadData, b: usize, qt: usize, kvt: usize, scale: f32, p_t: &mut [f32]) {
    for bi in 0..b {
        let i = qt * b + bi;
        for bj in 0..b {
            let j = kvt * b + bj;
            let sij = scale * dot_f32(hd.q.row(i), hd.k.row(j));
            p_t[bi * b + bj] = (sij - hd.lse[i]).exp();
        }
    }
}

/// Round a tile to bf16 storage in place.
fn round_bf16(tile: &mut [f32]) {
    for x in tile.iter_mut() {
        *x = crate::numerics::Bf16::from_f32(*x).to_f32();
    }
}

/// Store a `block x head_dim` accumulator tile into gradient rows starting
/// at `row0`, rounding to bf16 storage when requested.
fn store_tile(m: &mut Mat, row0: usize, acc: &[f32], d: usize, bf16: bool) {
    for (idx, &x) in acc.iter().enumerate() {
        let v = if bf16 { crate::numerics::Bf16::from_f32(x).to_f32() } else { x };
        *m.at_mut(row0 + idx / d, idx % d) = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::MaskSpec;
    use crate::schedule::{descending, fa3, two_pass, ProblemSpec};

    fn spec() -> ProblemSpec {
        ProblemSpec::square(4, 2, MaskSpec::causal())
    }

    #[test]
    fn same_config_is_bitwise_reproducible() {
        let s = fa3(&spec(), true);
        let cfg = ExecConfig::new(11);
        let a = execute_backward(&s, &cfg).unwrap();
        let b = execute_backward(&s, &cfg).unwrap();
        assert_eq!(a.grad_hash, b.grad_hash);
        assert_eq!(a.dq, b.dq);
    }

    #[test]
    fn machine_shape_cannot_leak_into_deterministic_gradients() {
        let s = fa3(&spec(), true);
        let base = execute_backward(&s, &ExecConfig::new(3)).unwrap();
        for (n_sm, perturb) in [(1usize, 0u64), (3, 5), (7, 9), (16, 1234)] {
            let cfg = ExecConfig { n_sm, perturb, ..ExecConfig::new(3) };
            let r = execute_backward(&s, &cfg).unwrap();
            assert_eq!(r.grad_hash, base.grad_hash, "n_sm={n_sm} perturb={perturb}");
        }
    }

    #[test]
    fn device_count_cannot_leak_into_deterministic_gradients() {
        use crate::schedule::{ring, zigzag, ScheduleKind};
        let sp = ProblemSpec::square(4, 2, MaskSpec::causal());
        let base = execute_backward(&descending(&sp), &ExecConfig::new(3)).unwrap();
        for d in [1usize, 2, 4] {
            let s = ring(&sp, ScheduleKind::Descending, d).unwrap();
            let cfg = ExecConfig { n_sm: 3, perturb: 7, ..ExecConfig::new(3) };
            let r = execute_backward(&s, &cfg).unwrap();
            assert_eq!(r.grad_hash, base.grad_hash, "ring devices={d}");
        }
        let z = zigzag(&sp, ScheduleKind::Descending, 2).unwrap();
        let r = execute_backward(&z, &ExecConfig::new(3)).unwrap();
        assert_eq!(r.grad_hash, base.grad_hash, "zigzag devices=2");
    }

    #[test]
    fn injected_xdev_fold_changes_f32_bits() {
        use crate::schedule::{ring, ScheduleKind};
        let sp = ProblemSpec::square(6, 2, MaskSpec::full());
        let s = ring(&sp, ScheduleKind::Descending, 2).unwrap();
        let base = execute_backward(&s, &ExecConfig::new(5)).unwrap();
        let mut seen = std::collections::HashSet::new();
        seen.insert(base.grad_hash);
        for perturb in 0..4u64 {
            let cfg = ExecConfig { inject_xdev: true, perturb, ..ExecConfig::new(5) };
            seen.insert(execute_backward(&s, &cfg).unwrap().grad_hash);
        }
        assert!(seen.len() > 1, "unordered cross-device fold must move gradient bits");
        // The probe is cluster-only: on a single-device schedule it is a
        // no-op and the gradients stay on the deterministic hash.
        let plain = descending(&sp);
        let cfg = ExecConfig { inject_xdev: true, perturb: 9, ..ExecConfig::new(5) };
        let det = execute_backward(&plain, &ExecConfig::new(5)).unwrap();
        assert_eq!(execute_backward(&plain, &cfg).unwrap().grad_hash, det.grad_hash);
    }

    #[test]
    fn flop_count_matches_schedule_structure() {
        for s in [fa3(&spec(), true), descending(&spec()), two_pass(&spec())] {
            let cfg = ExecConfig::new(1);
            let r = execute_backward(&s, &cfg).unwrap();
            assert_eq!(r.flops, expected_flops(&s, cfg.block, cfg.head_dim), "{:?}", s.kind);
        }
    }

    #[test]
    fn fused_expected_flops_match_attention_analytics() {
        use crate::attention::flops::{bwd_tile_flops, BWD_FUSED_GEMMS, BWD_TWO_PASS_GEMMS};
        let sp = spec();
        let fused = fa3(&sp, true);
        assert_eq!(
            expected_flops(&fused, 4, 8),
            sp.total_tiles() as f64 * bwd_tile_flops(4, 8)
        );
        let tp = two_pass(&sp);
        assert_eq!(
            expected_flops(&tp, 4, 8),
            sp.total_tiles() as f64 * bwd_tile_flops(4, 8) * BWD_TWO_PASS_GEMMS as f64
                / BWD_FUSED_GEMMS as f64
        );
    }

    #[test]
    fn injected_arrival_order_changes_bf16_bits() {
        // 8 heads x causal 6: plenty of multi-contributor dQ tiles.
        let sp = ProblemSpec::square(6, 8, MaskSpec::causal());
        let s = fa3(&sp, true);
        let det = ExecConfig { precision: Precision::Bf16, ..ExecConfig::new(5) };
        let base = execute_backward(&s, &det).unwrap();
        let mut seen = std::collections::HashSet::new();
        seen.insert(base.grad_hash);
        for perturb in 1..=4u64 {
            let cfg = ExecConfig { inject_atomic: true, perturb, n_sm: 3, ..det };
            seen.insert(execute_backward(&s, &cfg).unwrap().grad_hash);
        }
        assert!(seen.len() > 1, "injected atomic order must move bf16 gradient bits");
    }

    #[test]
    fn illegal_schedule_is_an_error_not_a_gradient() {
        let mut s = fa3(&spec(), true);
        s.chains[0].q_order.pop(); // break coverage
        assert!(execute_backward(&s, &ExecConfig::new(1)).is_err());
    }

    #[test]
    fn injected_batch_fold_changes_bits_only_with_multiple_documents() {
        // Two 3-tile documents: every dQ tile folds 3 partials, so a
        // rotation of the fold order moves f32 bits.
        let sp = ProblemSpec::square(6, 2, MaskSpec::document(vec![3]));
        let s = fa3(&sp, true);
        let base = execute_backward(&s, &ExecConfig::new(5)).unwrap();
        let mut seen = std::collections::HashSet::new();
        seen.insert(base.grad_hash);
        for perturb in 0..4u64 {
            let cfg = ExecConfig { inject_batch: true, perturb, ..ExecConfig::new(5) };
            seen.insert(execute_backward(&s, &cfg).unwrap().grad_hash);
        }
        assert!(seen.len() > 1, "batch-layout fold rotation must move gradient bits");
        // Batch count 1 (a boundary-free document mask) and non-document
        // masks give the probe nothing batch-shaped to key on: provably
        // inert.
        for mask in [MaskSpec::document(vec![]), MaskSpec::causal()] {
            let one = fa3(&ProblemSpec::square(6, 2, mask), true);
            let det = execute_backward(&one, &ExecConfig::new(5)).unwrap();
            let cfg = ExecConfig { inject_batch: true, perturb: 9, ..ExecConfig::new(5) };
            let probed = execute_backward(&one, &cfg).unwrap();
            assert_eq!(probed.grad_hash, det.grad_hash, "inject-batch must be inert");
        }
    }

    #[test]
    fn doc_seeded_operands_are_placement_invariant() {
        // The same 3-tile document content (seed 0xD0C) placed first in
        // one layout and last in another: its per-document gradient hash
        // must not move. FA3's ascending per-(head, q) orders are
        // document-relative under a block-diagonal mask, and doc-seeded
        // operands make the data document-relative too.
        let cfg = ExecConfig::new(7);
        let sp_a = ProblemSpec::square(5, 2, MaskSpec::document(vec![3]));
        let sp_b = ProblemSpec::square(5, 2, MaskSpec::document(vec![2]));
        let sa = fa3(&sp_a, true);
        let sb = fa3(&sp_b, true);
        let ra = execute_backward_docs(&sa, &cfg, &[0xD0C, 0xAAA]).unwrap();
        let rb = execute_backward_docs(&sb, &cfg, &[0xBBB, 0xD0C]).unwrap();
        let ha = document_grad_hashes(&sa, &cfg, &ra).unwrap();
        let hb = document_grad_hashes(&sb, &cfg, &rb).unwrap();
        assert_eq!(ha[0], hb[1], "same (seed, size) document, different placement");
        assert_ne!(ha[1], hb[0], "different seeds must differ");
        // And the whole run stays reproducible.
        let again = execute_backward_docs(&sa, &cfg, &[0xD0C, 0xAAA]).unwrap();
        assert_eq!(again.grad_hash, ra.grad_hash);
    }

    #[test]
    fn doc_seeded_execution_rejects_bad_layouts() {
        let cfg = ExecConfig::new(1);
        // Seed count must match the document count.
        let sp = ProblemSpec::square(4, 1, MaskSpec::document(vec![2]));
        assert!(execute_backward_docs(&fa3(&sp, true), &cfg, &[1]).is_err());
        // Non-document masks have no documents to seed.
        let full = ProblemSpec::square(4, 1, MaskSpec::full());
        assert!(execute_backward_docs(&fa3(&full, true), &cfg, &[1]).is_err());
        // Non-document masks also have no per-document hashes.
        let r = execute_backward(&fa3(&full, true), &cfg).unwrap();
        assert!(document_grad_hashes(&fa3(&full, true), &cfg, &r).is_none());
    }
}
