//! The determinism oracle: executes one schedule many times — across
//! repeated runs, machine widths, and completion-order ("thread")
//! shuffles — and renders a verdict from the observed gradient hashes.
//!
//! A deterministic schedule must produce **one** hash across the whole
//! matrix; `fa3-atomic` (or any run with
//! [`super::ExecConfig::inject_atomic`]) folds dQ in arrival order and is
//! expected to scatter, with the spread quantified the same way the
//! paper's Table 1 quantifies gradient deviation. The oracle also
//! cross-checks the executed FLOP count of every run against the
//! [`crate::attention::flops`] analytics ([`super::expected_flops`]), so
//! a schedule cannot "pass" by silently skipping work.

use super::{
    document_grad_hashes, execute_backward, execute_backward_docs, expected_flops, ExecConfig,
};
use crate::numerics::Precision;
use crate::schedule::{cluster_schedule, ClusterStrategy, ProblemSpec, Schedule, ScheduleKind};
use crate::traceload::{compile, compose_step_schedule, BatchConfig, Trace};
use crate::util::fnv1a_words;
use std::collections::{BTreeMap, HashSet};

/// Shape of one oracle sweep.
#[derive(Debug, Clone)]
pub struct OracleOptions {
    /// Repeated runs per machine width (each with a fresh perturbation).
    pub runs: usize,
    /// Machine widths to execute under — the SM-count axis.
    pub sm_counts: Vec<usize>,
    /// Elements per tile side.
    pub block: usize,
    /// Head dimension of the synthetic operands.
    pub head_dim: usize,
    /// Data seed (also salts the per-execution perturbations).
    pub seed: u64,
    /// Accumulation/storage precision under test.
    pub precision: Precision,
    /// Fold dQ in arrival order regardless of the schedule's reduction
    /// order — the injected-nondeterminism probe.
    pub inject_atomic: bool,
    /// Fold the per-device dQ groups in a seeded permutation instead of
    /// the fixed cross-device order — the multi-GPU injection probe (see
    /// [`super::ExecConfig::inject_xdev`]). No effect on single-device
    /// schedules.
    pub inject_xdev: bool,
    /// Rotate each dQ fold by a batch-layout-derived key — the serving
    /// injection probe (see [`super::ExecConfig::inject_batch`]). Inert
    /// whenever a step's mask has fewer than two documents.
    pub inject_batch: bool,
}

impl OracleOptions {
    /// Default sweep: 2 runs x 3 machine widths (one narrower than any
    /// wave, one paper-shaped, one that divides nothing), 4x4 tiles at
    /// head dim 8, f32, no injection.
    pub fn quick(seed: u64) -> Self {
        Self {
            runs: 2,
            sm_counts: vec![3, 6, 13],
            block: 4,
            head_dim: 8,
            seed,
            precision: Precision::F32,
            inject_atomic: false,
            inject_xdev: false,
            inject_batch: false,
        }
    }
}

/// What the oracle observed for one (schedule, options) case.
#[derive(Debug, Clone)]
pub struct OracleVerdict {
    /// Executions performed (`runs * sm_counts.len()`).
    pub executions: usize,
    /// Distinct gradient hashes observed (1 = bitwise deterministic).
    pub distinct_hashes: usize,
    /// The canonical (first execution) gradient hash.
    pub hash: u64,
    /// Max |dQ - dQ_first| over all executions — 0 for deterministic
    /// schedules, Table-1-scale for atomic ones.
    pub max_abs_dev: f64,
    /// FLOPs each execution performed.
    pub executed_flops: f64,
    /// FLOPs the schedule's structure says it must perform.
    pub expected_flops: f64,
}

impl OracleVerdict {
    /// Bitwise deterministic across the whole sweep?
    pub fn deterministic(&self) -> bool {
        self.distinct_hashes == 1
    }

    /// Did every execution perform exactly the analytic FLOP count?
    pub fn flops_ok(&self) -> bool {
        self.executed_flops == self.expected_flops
    }
}

/// Run the oracle matrix for one schedule: every `(run, n_sm)` cell
/// executes the backward pass under a distinct completion perturbation
/// (run 0 on the first width is the canonical, jitter-free execution) and
/// the verdict aggregates hashes, deviation, and the FLOP cross-check.
pub fn verify_schedule(s: &Schedule, o: &OracleOptions) -> crate::Result<OracleVerdict> {
    anyhow::ensure!(o.runs >= 1 && !o.sm_counts.is_empty(), "empty oracle matrix");
    let want_flops = expected_flops(s, o.block, o.head_dim);
    let mut hashes = HashSet::new();
    let mut first: Option<super::ExecResult> = None;
    let mut max_dev = 0.0f64;
    let mut executions = 0usize;
    for run in 0..o.runs {
        for (wi, &n_sm) in o.sm_counts.iter().enumerate() {
            let canonical = run == 0 && wi == 0;
            let cfg = ExecConfig {
                block: o.block,
                head_dim: o.head_dim,
                seed: o.seed,
                precision: o.precision,
                n_sm,
                perturb: if canonical {
                    0
                } else {
                    fnv1a_words([o.seed, run as u64, n_sm as u64])
                },
                inject_atomic: o.inject_atomic,
                inject_xdev: o.inject_xdev,
                inject_batch: o.inject_batch,
            };
            let r = execute_backward(s, &cfg)?;
            anyhow::ensure!(
                r.flops == want_flops,
                "executed {} FLOPs but the schedule structure implies {} \
                 (run {run}, n_sm {n_sm})",
                r.flops,
                want_flops
            );
            executions += 1;
            hashes.insert(r.grad_hash);
            match &first {
                None => first = Some(r),
                Some(f) => {
                    let dev = f
                        .dq
                        .iter()
                        .zip(&r.dq)
                        .map(|(&a, &b)| (f64::from(a) - f64::from(b)).abs())
                        .fold(0.0, f64::max);
                    max_dev = max_dev.max(dev);
                }
            }
        }
    }
    let first = first.expect("at least one execution");
    Ok(OracleVerdict {
        executions,
        distinct_hashes: hashes.len(),
        hash: first.grad_hash,
        max_abs_dev: max_dev,
        executed_flops: first.flops,
        expected_flops: want_flops,
    })
}

/// Run the oracle across *device counts*: for each `d` in `devices`, build
/// the `strategy`-sharded cluster schedule of `intra` over `spec` and run
/// the full [`verify_schedule`] matrix (runs x machine widths, with
/// per-device arrival skew under perturbation) on it.
///
/// The aggregate verdict's `distinct_hashes == 1` iff the gradients are
/// bitwise-identical across device counts, runs, and SM counts — the
/// cross-device reproducibility claim behind `dash verify --devices`,
/// proved by execution rather than assumed from the construction.
pub fn verify_device_counts(
    spec: &ProblemSpec,
    strategy: ClusterStrategy,
    intra: ScheduleKind,
    devices: &[usize],
    o: &OracleOptions,
) -> crate::Result<OracleVerdict> {
    anyhow::ensure!(!devices.is_empty(), "empty device-count axis");
    let mut canonical = HashSet::new();
    let mut extra_distinct = 0usize;
    let mut executions = 0usize;
    let mut max_dev = 0.0f64;
    let mut first: Option<OracleVerdict> = None;
    for &d in devices {
        let s = cluster_schedule(spec, strategy, intra, d).map_err(|e| anyhow::anyhow!("{e}"))?;
        let v = verify_schedule(&s, o)?;
        executions += v.executions;
        max_dev = max_dev.max(v.max_abs_dev);
        canonical.insert(v.hash);
        extra_distinct += v.distinct_hashes - 1;
        if first.is_none() {
            first = Some(v);
        }
    }
    let first = first.expect("at least one device count");
    Ok(OracleVerdict {
        executions,
        distinct_hashes: canonical.len() + extra_distinct,
        hash: first.hash,
        max_abs_dev: max_dev,
        executed_flops: first.executed_flops,
        expected_flops: first.expected_flops,
    })
}

/// One request's invariance record across the batch matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestInvariance {
    /// Request id ([`crate::traceload::Request::id`]).
    pub id: usize,
    /// The canonical (first cell) per-request gradient hash.
    pub hash: u64,
    /// Distinct per-request hashes observed across all cells
    /// (1 = batch-invariant for this request).
    pub distinct: usize,
}

/// Verdict of one [`verify_batch_invariance`] sweep.
#[derive(Debug, Clone)]
pub struct BatchVerdict {
    /// Batch-layout cells executed (`batch_sizes x admission orders`).
    pub cells: usize,
    /// Serving-step executions performed across all cells.
    pub executions: usize,
    /// Requests in the trace.
    pub requests: usize,
    /// Per-request invariance records, in request-id order.
    pub per_request: Vec<RequestInvariance>,
    /// FLOPs the first cell executed (summed over its steps).
    pub executed_flops: f64,
    /// FLOPs the first cell's composed schedules imply.
    pub expected_flops: f64,
}

impl BatchVerdict {
    /// One gradient hash per request across every batch size and
    /// admission order?
    pub fn invariant(&self) -> bool {
        self.per_request.iter().all(|r| r.distinct == 1)
    }

    /// Total distinct hashes across all requests (equals `requests` iff
    /// [`BatchVerdict::invariant`]).
    pub fn distinct_hashes(&self) -> usize {
        self.per_request.iter().map(|r| r.distinct).sum()
    }

    /// Did every execution perform exactly the analytic FLOP count?
    /// (Enforced per step during the sweep; this reports the first cell's
    /// totals.)
    pub fn flops_ok(&self) -> bool {
        self.executed_flops == self.expected_flops
    }
}

/// The serving-layer oracle: compile `trace` under every `(batch size,
/// admission order)` cell, execute every serving step with
/// document-seeded operands, and check that each *request* lands on one
/// gradient hash across the whole matrix.
///
/// Per cell, a request's hash folds its per-segment document hashes
/// ([`document_grad_hashes`]) in segment order, so it covers the
/// request's entire prompt + decode gradient trajectory. Machine shape is
/// swept too: each step executes under a different `(n_sm, perturb)`
/// drawn from `o`. Order index 0 is FIFO admission; higher indices are
/// seeded shuffles. With [`OracleOptions::inject_batch`] the fold leaks
/// the batch layout and the verdict must flip at batch sizes > 1 — the
/// negative control mirroring [`OracleOptions::inject_xdev`].
pub fn verify_batch_invariance(
    trace: &Trace,
    kind: ScheduleKind,
    batch_sizes: &[usize],
    orders: usize,
    n_heads: usize,
    o: &OracleOptions,
) -> crate::Result<BatchVerdict> {
    anyhow::ensure!(!batch_sizes.is_empty() && orders >= 1, "empty batch matrix");
    anyhow::ensure!(!o.sm_counts.is_empty(), "empty machine-width axis");
    // request id -> set of per-cell hashes (BTreeMap: id-ordered report).
    let mut seen: BTreeMap<usize, (u64, HashSet<u64>)> = BTreeMap::new();
    let mut cells = 0usize;
    let mut executions = 0usize;
    let mut first_cell_flops: Option<(f64, f64)> = None;
    for (bi, &batch) in batch_sizes.iter().enumerate() {
        for oi in 0..orders {
            let admission = if oi == 0 { 0 } else { fnv1a_words([o.seed, oi as u64]) };
            let cfg = BatchConfig { max_batch: batch, chunk_tiles: 0, n_heads, admission };
            let steps = compile(trace, &cfg)?;
            // (request -> (segment, doc hash)) pairs for this cell.
            let mut req_segments: BTreeMap<usize, Vec<(usize, u64)>> = BTreeMap::new();
            let mut cell_flops = (0.0f64, 0.0f64);
            for step in &steps {
                let s = compose_step_schedule(step, kind)?;
                let canonical = bi == 0 && oi == 0 && step.index == 0;
                let ec = ExecConfig {
                    block: o.block,
                    head_dim: o.head_dim,
                    seed: o.seed,
                    precision: o.precision,
                    n_sm: o.sm_counts[step.index % o.sm_counts.len()],
                    perturb: if canonical {
                        0
                    } else {
                        fnv1a_words([o.seed, bi as u64, oi as u64, step.index as u64])
                    },
                    inject_atomic: o.inject_atomic,
                    inject_xdev: o.inject_xdev,
                    inject_batch: o.inject_batch,
                };
                let r = execute_backward_docs(&s, &ec, &step.doc_seeds())?;
                let want = expected_flops(&s, o.block, o.head_dim);
                anyhow::ensure!(
                    r.flops == want,
                    "step {} executed {} FLOPs but its schedule implies {want}",
                    step.index,
                    r.flops
                );
                cell_flops.0 += r.flops;
                cell_flops.1 += want;
                executions += 1;
                let hashes = document_grad_hashes(&s, &ec, &r)
                    .expect("serving steps carry document masks");
                for (slice, &h) in step.slices.iter().zip(&hashes) {
                    req_segments.entry(slice.request).or_default().push((slice.segment, h));
                }
            }
            for (req, mut segs) in req_segments {
                segs.sort_unstable();
                let h = fnv1a_words(segs.iter().flat_map(|&(seg, h)| [seg as u64, h]));
                let entry = seen.entry(req).or_insert_with(|| (h, HashSet::new()));
                entry.1.insert(h);
            }
            cells += 1;
            if first_cell_flops.is_none() {
                first_cell_flops = Some(cell_flops);
            }
        }
    }
    let (executed_flops, expected) = first_cell_flops.expect("at least one cell");
    let per_request: Vec<RequestInvariance> = seen
        .into_iter()
        .map(|(id, (hash, set))| RequestInvariance { id, hash, distinct: set.len() })
        .collect();
    anyhow::ensure!(
        per_request.len() == trace.requests.len(),
        "matrix covered {} of {} requests",
        per_request.len(),
        trace.requests.len()
    );
    Ok(BatchVerdict {
        cells,
        executions,
        requests: per_request.len(),
        per_request,
        executed_flops,
        expected_flops: expected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::MaskSpec;
    use crate::schedule::{fa3, symmetric_shift, ProblemSpec};

    #[test]
    fn deterministic_schedule_gets_one_hash() {
        let spec = ProblemSpec::square(4, 2, MaskSpec::causal());
        for s in [fa3(&spec, true), symmetric_shift(&spec)] {
            for p in [Precision::F32, Precision::Bf16] {
                let o = OracleOptions { precision: p, ..OracleOptions::quick(9) };
                let v = verify_schedule(&s, &o).unwrap();
                assert!(v.deterministic(), "{:?} {p:?}: {v:?}", s.kind);
                assert_eq!(v.max_abs_dev, 0.0);
                assert!(v.flops_ok());
                assert_eq!(v.executions, 6);
            }
        }
    }

    #[test]
    fn atomic_schedule_scatters_in_bf16() {
        let spec = ProblemSpec::square(6, 8, MaskSpec::causal());
        let s = fa3(&spec, false);
        let o = OracleOptions {
            runs: 3,
            precision: Precision::Bf16,
            ..OracleOptions::quick(4)
        };
        let v = verify_schedule(&s, &o).unwrap();
        assert!(!v.deterministic(), "{v:?}");
        assert!(v.max_abs_dev > 0.0);
        assert!(v.flops_ok(), "nondeterminism must not change the work done");
    }

    #[test]
    fn injection_is_caught_on_an_otherwise_deterministic_schedule() {
        let spec = ProblemSpec::square(6, 8, MaskSpec::causal());
        let s = fa3(&spec, true);
        let honest = OracleOptions { precision: Precision::Bf16, ..OracleOptions::quick(4) };
        assert!(verify_schedule(&s, &honest).unwrap().deterministic());
        let injected = OracleOptions { inject_atomic: true, runs: 3, ..honest };
        let v = verify_schedule(&s, &injected).unwrap();
        assert!(!v.deterministic(), "oracle must catch injected atomic order: {v:?}");
    }

    #[test]
    fn device_counts_share_one_hash() {
        let spec = ProblemSpec::square(4, 2, MaskSpec::causal());
        let o = OracleOptions::quick(9);
        let v = verify_device_counts(
            &spec,
            ClusterStrategy::Ring,
            ScheduleKind::Descending,
            &[1, 2, 4],
            &o,
        )
        .unwrap();
        assert!(v.deterministic(), "{v:?}");
        assert_eq!(v.executions, 18); // 3 device counts x 2 runs x 3 widths
        assert_eq!(v.max_abs_dev, 0.0);
        // The cluster hash equals the plain single-device hash: the device
        // axis is invisible to the arithmetic.
        let plain = verify_schedule(&crate::schedule::descending(&spec), &o).unwrap();
        assert_eq!(v.hash, plain.hash);
    }

    #[test]
    fn unordered_cross_device_fold_is_caught() {
        let spec = ProblemSpec::square(6, 4, MaskSpec::full());
        let honest = OracleOptions::quick(4);
        let injected = OracleOptions { inject_xdev: true, runs: 3, ..honest.clone() };
        let v = verify_device_counts(
            &spec,
            ClusterStrategy::Ring,
            ScheduleKind::Descending,
            &[2, 3],
            &injected,
        )
        .unwrap();
        assert!(!v.deterministic(), "oracle must catch the unordered cross-device fold: {v:?}");
        assert!(
            verify_device_counts(
                &spec,
                ClusterStrategy::Ring,
                ScheduleKind::Descending,
                &[2, 3],
                &honest,
            )
            .unwrap()
            .deterministic()
        );
    }

    #[test]
    fn empty_matrix_is_an_error() {
        let spec = ProblemSpec::square(2, 1, MaskSpec::full());
        let s = fa3(&spec, true);
        let o = OracleOptions { sm_counts: vec![], ..OracleOptions::quick(1) };
        assert!(verify_schedule(&s, &o).is_err());
    }

    fn smoke_trace() -> Trace {
        crate::traceload::generate(&crate::traceload::TraceSpec::smoke(42)).unwrap()
    }

    #[test]
    fn batch_matrix_lands_on_one_hash_per_request() {
        let trace = smoke_trace();
        let o = OracleOptions::quick(42);
        let v =
            verify_batch_invariance(&trace, ScheduleKind::Fa3, &[1, 2, 4], 2, 2, &o).unwrap();
        assert!(v.invariant(), "{v:?}");
        assert_eq!(v.cells, 6);
        assert_eq!(v.requests, trace.requests.len());
        assert_eq!(v.distinct_hashes(), v.requests);
        assert!(v.flops_ok());
        assert!(v.executions > v.cells, "continuous batching emits multiple steps per cell");
    }

    #[test]
    fn injected_batch_layout_flips_the_verdict_only_above_batch_one() {
        let trace = smoke_trace();
        let injected = OracleOptions { inject_batch: true, ..OracleOptions::quick(42) };
        let v =
            verify_batch_invariance(&trace, ScheduleKind::Fa3, &[2, 4], 2, 2, &injected).unwrap();
        assert!(!v.invariant(), "batch-layout leak must scatter request hashes: {v:?}");
        assert!(v.flops_ok(), "the leak reorders folds, never changes the work");
        // Batch count 1: every step carries a single document, the probe
        // has nothing to key on, and the verdict stays invariant.
        let single =
            verify_batch_invariance(&trace, ScheduleKind::Fa3, &[1], 3, 2, &injected).unwrap();
        assert!(single.invariant(), "inject-batch must be inert at batch 1: {single:?}");
    }

    #[test]
    fn empty_batch_matrix_is_an_error() {
        let trace = smoke_trace();
        let o = OracleOptions::quick(1);
        assert!(verify_batch_invariance(&trace, ScheduleKind::Fa3, &[], 1, 2, &o).is_err());
        assert!(verify_batch_invariance(&trace, ScheduleKind::Fa3, &[1], 0, 2, &o).is_err());
    }
}
