//! Schedule-independent dense reference backward in f64 — the ground
//! truth the tile executor is validated against.
//!
//! It regenerates exactly the synthetic operands the executor draws (same
//! seeds, same [`crate::util::DetRng`] streams), widens them to f64, and
//! computes the attention backward pass with plain dense loops in
//! ascending index order. No tiles, no schedule, no precision knob: any
//! executor output — whichever schedule produced it — must agree with
//! this to within f32/bf16 accumulation error, which the integration
//! tests assert.

use super::{gen_mat, ExecConfig, TAG_DO, TAG_K, TAG_Q, TAG_V};
use crate::schedule::ProblemSpec;
use crate::util::fnv1a_words;

/// Dense f64 gradients, flattened with the same head-major row-major
/// layout as [`super::ExecResult`]: `dq` is `n_heads * n_q * block` rows
/// by `head_dim` columns, `dk`/`dv` likewise over KV rows.
#[derive(Debug, Clone)]
pub struct RefGrads {
    /// dQ flat.
    pub dq: Vec<f64>,
    /// dK flat.
    pub dk: Vec<f64>,
    /// dV flat.
    pub dv: Vec<f64>,
}

/// Compute the dense f64 reference gradients for the workload the
/// executor would run under `cfg` (only `block`, `head_dim`, and `seed`
/// matter — the machine and precision knobs do not exist here).
pub fn reference_backward(spec: &ProblemSpec, cfg: &ExecConfig) -> RefGrads {
    let (b, d) = (cfg.block, cfg.head_dim);
    let (qr, kr) = (spec.n_q * b, spec.n_kv * b);
    let scale = 1.0f64 / (d as f64).sqrt();

    let mut dq = vec![0.0f64; spec.n_heads * qr * d];
    let mut dk = vec![0.0f64; spec.n_heads * kr * d];
    let mut dv = vec![0.0f64; spec.n_heads * kr * d];

    for head in 0..spec.n_heads {
        let to64 = |m: super::tensor::Mat| -> Vec<f64> {
            m.data.into_iter().map(f64::from).collect()
        };
        let q = to64(gen_mat(qr, d, fnv1a_words([cfg.seed, head as u64, TAG_Q])));
        let k = to64(gen_mat(kr, d, fnv1a_words([cfg.seed, head as u64, TAG_K])));
        let v = to64(gen_mat(kr, d, fnv1a_words([cfg.seed, head as u64, TAG_V])));
        let dout = to64(gen_mat(qr, d, fnv1a_words([cfg.seed, head as u64, TAG_DO])));
        let live = |i: usize, j: usize| spec.live(j / b, i / b);

        let (hq, hk) = (head * qr * d, head * kr * d);
        for i in 0..qr {
            // Row logits and softmax.
            let mut s_row = vec![f64::NEG_INFINITY; kr];
            let mut m = f64::NEG_INFINITY;
            for (j, sj) in s_row.iter_mut().enumerate() {
                if live(i, j) {
                    let mut s = 0.0f64;
                    for e in 0..d {
                        s += q[i * d + e] * k[j * d + e];
                    }
                    *sj = s * scale;
                    m = m.max(*sj);
                }
            }
            if m == f64::NEG_INFINITY {
                continue; // fully-masked Q row
            }
            let l: f64 = s_row.iter().filter(|s| s.is_finite()).map(|&s| (s - m).exp()).sum();
            let lse = m + l.ln();

            // O row and the D coefficient.
            let mut o = vec![0.0f64; d];
            for (j, &sj) in s_row.iter().enumerate() {
                if sj.is_finite() {
                    let p = (sj - lse).exp();
                    for e in 0..d {
                        o[e] += p * v[j * d + e];
                    }
                }
            }
            let dcoef: f64 = (0..d).map(|e| dout[i * d + e] * o[e]).sum();

            // Gradients.
            for (j, &sj) in s_row.iter().enumerate() {
                if !sj.is_finite() {
                    continue;
                }
                let p = (sj - lse).exp();
                let dp: f64 = (0..d).map(|e| dout[i * d + e] * v[j * d + e]).sum();
                let ds = p * (dp - dcoef) * scale;
                for e in 0..d {
                    dq[hq + i * d + e] += ds * k[j * d + e];
                    dk[hk + j * d + e] += ds * q[i * d + e];
                    dv[hk + j * d + e] += p * dout[i * d + e];
                }
            }
        }
    }
    RefGrads { dq, dk, dv }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::{execute_backward, ExecConfig};
    use crate::mask::MaskSpec;
    use crate::schedule::{descending, fa3, two_pass};

    /// Max |a - b| over two flats.
    fn max_dev(a: &[f32], b: &[f64]) -> f64 {
        a.iter().zip(b).map(|(&x, &y)| (f64::from(x) - y).abs()).fold(0.0, f64::max)
    }

    #[test]
    fn executor_agrees_with_dense_reference() {
        for mask in [MaskSpec::full(), MaskSpec::causal(), MaskSpec::sliding_window(2)] {
            let spec = ProblemSpec::square(4, 2, mask);
            let cfg = ExecConfig::new(17);
            let truth = reference_backward(&spec, &cfg);
            for s in [fa3(&spec, true), descending(&spec), two_pass(&spec)] {
                let r = execute_backward(&s, &cfg).unwrap();
                // f32 tile accumulation over O(n) partials of O(1) values:
                // error far below 1e-3.
                assert!(max_dev(&r.dq, &truth.dq) < 1e-3, "{:?} dq", s.kind);
                assert!(max_dev(&r.dk, &truth.dk) < 1e-3, "{:?} dk", s.kind);
                assert!(max_dev(&r.dv, &truth.dv) < 1e-3, "{:?} dv", s.kind);
            }
        }
    }

    #[test]
    fn gradients_are_nonzero() {
        let spec = ProblemSpec::square(3, 1, MaskSpec::causal());
        let g = reference_backward(&spec, &ExecConfig::new(2));
        assert!(g.dq.iter().any(|&x| x.abs() > 1e-6));
        assert!(g.dk.iter().any(|&x| x.abs() > 1e-6));
        assert!(g.dv.iter().any(|&x| x.abs() > 1e-6));
    }
}
