//! Context-parallel (multi-GPU) cluster schedule generators: ring and
//! zigzag-causal KV sharding composed with the per-device generators.
//!
//! ## The invariance construction
//!
//! A cluster schedule is the **full** (unsharded) intra-device schedule,
//! annotated with a device per chain. Chains, Q-tile visit orders, and —
//! critically — the per-(head, q) dQ reduction order are generated on the
//! complete [`ProblemSpec`] and never depend on the device count. Sharding
//! only decides *where* each KV chain runs; the fold order each dQ tile
//! sees is the same total order at every `n_devices`. The cross-device
//! epilogue folds the per-device dQ partials in the fixed
//! [`ClusterSchedule::xdev_order`] (never arrival order), and each device's
//! partial is itself the ordered sub-fold of its own KV contributions. The
//! executor folds every contribution through the full order directly, so
//! gradients are bitwise-identical across device counts *by construction* —
//! this module's job is to make sure nothing about the schedule can break
//! that (see [`crate::exec::oracle::verify_device_counts`] for the proof by
//! execution).
//!
//! ## Sharding strategies
//!
//! * [`ClusterStrategy::Ring`] — contiguous KV slabs: device `d` owns KV
//!   tiles `[d·n/D, (d+1)·n/D)`. The classic ring-attention layout; needs
//!   `n_kv % n_devices == 0`.
//! * [`ClusterStrategy::Zigzag`] — the KV axis splits into `2D` slabs and
//!   device `d` owns slabs `d` and `2D-1-d`. Under a causal mask this pairs
//!   one long-chain slab with one short-chain slab per device (the zigzag
//!   context-parallel trick), balancing work; needs
//!   `n_kv % (2·n_devices) == 0`.
//!
//! ## Composition
//!
//! Intra-device generators compose when their schedule structure survives
//! chain-subset execution: [`super::fa3`] (deterministic), [`descending`],
//! [`shift`], and [`symmetric_shift`]. The non-deterministic
//! ([`ScheduleKind::Fa3Atomic`]) and locally-folding
//! ([`ScheduleKind::TwoPass`]) baselines, and machine-specific placements
//! ([`ScheduleKind::Lpt`], [`ScheduleKind::Tuned`]), return a typed
//! [`ScheduleError::UnsupportedCluster`].

use super::{
    descending, fa3, shift, symmetric_shift, ClusterSchedule, ClusterStrategy, DeviceId,
    ProblemSpec, Schedule, ScheduleError, ScheduleKind,
};

/// Composite schedule names: `<strategy>-<intra>` (e.g. `ring-shift`,
/// `zigzag-descending`, `ring-fa3-det`). Returns `None` when the prefix is
/// not a cluster strategy or the suffix is not a schedule name, so plain
/// names like `fa3-atomic` or `two-pass` fall through to
/// [`ScheduleKind::parse`] unchanged.
pub fn parse_composite(name: &str) -> Option<(ClusterStrategy, ScheduleKind)> {
    let (prefix, rest) = name.split_once('-')?;
    let strategy = ClusterStrategy::parse(prefix)?;
    let kind = ScheduleKind::parse(rest)?;
    Some((strategy, kind))
}

/// Build a context-parallel cluster schedule: the full intra-device
/// schedule of `intra` annotated with a `strategy`-sharded device per
/// chain. `n_devices == 1` produces a degenerate (but well-formed) cluster
/// annotation so single-device cluster runs exercise the same code path.
///
/// The abstract interconnect hop cost is 1.0; CLI paths stamp a
/// [`crate::hw::ClusterProfile`]-derived value before simulating.
pub fn cluster_schedule(
    spec: &ProblemSpec,
    strategy: ClusterStrategy,
    intra: ScheduleKind,
    n_devices: usize,
) -> Result<Schedule, ScheduleError> {
    let unsupported = |reason: String| ScheduleError::UnsupportedCluster {
        kind: intra,
        strategy: strategy.name(),
        reason,
    };
    if n_devices == 0 {
        return Err(unsupported("device count must be at least 1".into()));
    }
    if n_devices > 1 {
        match strategy {
            ClusterStrategy::Ring => {
                if spec.n_kv % n_devices != 0 {
                    return Err(unsupported(format!(
                        "ring sharding needs n_kv divisible by the device count \
                         (n_kv = {}, devices = {n_devices})",
                        spec.n_kv
                    )));
                }
            }
            ClusterStrategy::Zigzag => {
                if spec.n_kv % (2 * n_devices) != 0 {
                    return Err(unsupported(format!(
                        "zigzag sharding needs n_kv divisible by 2x the device count \
                         (n_kv = {}, devices = {n_devices})",
                        spec.n_kv
                    )));
                }
            }
        }
    }
    let mut schedule = match intra {
        ScheduleKind::Fa3 => fa3(spec, true),
        ScheduleKind::Descending => descending(spec),
        ScheduleKind::Shift => shift(spec)?,
        ScheduleKind::SymmetricShift => symmetric_shift(spec),
        other => {
            return Err(unsupported(format!(
                "'{}' cannot run intra-device: cluster composition needs a \
                 deterministic generator whose structure survives chain-subset \
                 execution (fa3-det, descending, shift, symmetric-shift)",
                other.name()
            )))
        }
    };
    let device: Vec<DeviceId> = schedule
        .chains
        .iter()
        .map(|c| shard_device(strategy, c.kv, spec.n_kv, n_devices))
        .collect();
    schedule.cluster = Some(ClusterSchedule {
        strategy,
        n_devices,
        device,
        xdev_order: (0..n_devices).collect(),
        hop_cost: 1.0,
    });
    Ok(schedule)
}

/// Device owning KV tile `kv` under `strategy` with `n_devices` devices.
fn shard_device(
    strategy: ClusterStrategy,
    kv: usize,
    n_kv: usize,
    n_devices: usize,
) -> DeviceId {
    if n_devices <= 1 {
        return 0;
    }
    match strategy {
        ClusterStrategy::Ring => kv * n_devices / n_kv,
        ClusterStrategy::Zigzag => {
            let slab = kv * 2 * n_devices / n_kv;
            slab.min(2 * n_devices - 1 - slab)
        }
    }
}

/// Ring-sharded cluster schedule: contiguous KV slabs per device.
pub fn ring(
    spec: &ProblemSpec,
    intra: ScheduleKind,
    n_devices: usize,
) -> Result<Schedule, ScheduleError> {
    cluster_schedule(spec, ClusterStrategy::Ring, intra, n_devices)
}

/// Zigzag-causal cluster schedule: device `d` owns slabs `d` and `2D-1-d`.
pub fn zigzag(
    spec: &ProblemSpec,
    intra: ScheduleKind,
    n_devices: usize,
) -> Result<Schedule, ScheduleError> {
    cluster_schedule(spec, ClusterStrategy::Zigzag, intra, n_devices)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mask::MaskSpec;
    use crate::schedule::validate::validate;

    #[test]
    fn composite_names_parse() {
        assert_eq!(
            parse_composite("ring-shift"),
            Some((ClusterStrategy::Ring, ScheduleKind::Shift))
        );
        assert_eq!(
            parse_composite("zigzag-descending"),
            Some((ClusterStrategy::Zigzag, ScheduleKind::Descending))
        );
        assert_eq!(
            parse_composite("ring-fa3-det"),
            Some((ClusterStrategy::Ring, ScheduleKind::Fa3))
        );
        // Plain schedule names with dashes fall through untouched.
        assert_eq!(parse_composite("fa3-atomic"), None);
        assert_eq!(parse_composite("two-pass"), None);
        assert_eq!(parse_composite("symmetric-shift"), None);
        assert_eq!(parse_composite("mesh-shift"), None);
    }

    #[test]
    fn ring_assigns_contiguous_slabs() {
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 4).unwrap();
        validate(&s).unwrap();
        let c = s.cluster.as_ref().unwrap();
        assert_eq!(c.n_devices, 4);
        assert_eq!(c.xdev_order, vec![0, 1, 2, 3]);
        for (i, ch) in s.chains.iter().enumerate() {
            assert_eq!(c.device[i], ch.kv / 2, "chain {i} kv {}", ch.kv);
        }
    }

    #[test]
    fn zigzag_pairs_outer_and_inner_slabs() {
        // n_kv = 8, D = 2: slabs of 2 tiles; device 0 owns slabs {0, 3}
        // (kv 0,1,6,7), device 1 owns slabs {1, 2} (kv 2,3,4,5).
        let spec = ProblemSpec::square(8, 1, MaskSpec::causal());
        let s = zigzag(&spec, ScheduleKind::Descending, 2).unwrap();
        validate(&s).unwrap();
        let c = s.cluster.as_ref().unwrap();
        for (i, ch) in s.chains.iter().enumerate() {
            let expect = usize::from((2..6).contains(&ch.kv));
            assert_eq!(c.device[i], expect, "kv {}", ch.kv);
        }
    }

    #[test]
    fn zigzag_balances_causal_work() {
        // The point of zigzag: per-device live-tile counts are equal under
        // a causal mask (ring's are maximally skewed).
        let spec = ProblemSpec::square(8, 1, MaskSpec::causal());
        let s = zigzag(&spec, ScheduleKind::Descending, 2).unwrap();
        let c = s.cluster.as_ref().unwrap();
        let mut tiles = [0usize; 2];
        for (i, ch) in s.chains.iter().enumerate() {
            tiles[c.device[i]] += ch.len();
        }
        assert_eq!(tiles[0], tiles[1], "{tiles:?}");
    }

    #[test]
    fn sharding_preserves_the_full_reduction_order() {
        // The invariance trick: cluster schedules keep the unsharded
        // schedule's fold order verbatim at every device count.
        let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
        let base = descending(&spec);
        for d in [1usize, 2, 4] {
            let s = ring(&spec, ScheduleKind::Descending, d).unwrap();
            assert_eq!(s.reduction_order, base.reduction_order, "devices = {d}");
            assert_eq!(s.chains, base.chains, "devices = {d}");
        }
    }

    #[test]
    fn indivisible_device_counts_are_typed_errors() {
        let spec = ProblemSpec::square(6, 1, MaskSpec::full());
        let e = ring(&spec, ScheduleKind::Fa3, 4).unwrap_err();
        assert!(matches!(e, ScheduleError::UnsupportedCluster { .. }), "{e}");
        // Zigzag needs 2D slabs: 6 % 4 != 0 fails for D = 2, while D = 3
        // works (6 % 6 == 0).
        assert!(zigzag(&spec, ScheduleKind::Fa3, 2).is_err());
        zigzag(&spec, ScheduleKind::Fa3, 3).unwrap();
        assert!(matches!(
            cluster_schedule(&spec, ClusterStrategy::Ring, ScheduleKind::Fa3, 0),
            Err(ScheduleError::UnsupportedCluster { .. })
        ));
    }

    #[test]
    fn unsupported_intra_kinds_are_typed_errors() {
        let spec = ProblemSpec::square(8, 1, MaskSpec::full());
        for kind in [
            ScheduleKind::Fa3Atomic,
            ScheduleKind::TwoPass,
            ScheduleKind::Lpt,
            ScheduleKind::Tuned,
        ] {
            let e = ring(&spec, kind, 2).unwrap_err();
            match e {
                ScheduleError::UnsupportedCluster { kind: k, strategy, .. } => {
                    assert_eq!(k, kind);
                    assert_eq!(strategy, "ring");
                }
                other => panic!("expected UnsupportedCluster, got {other:?}"),
            }
        }
    }

    #[test]
    fn single_device_cluster_is_degenerate_but_well_formed() {
        let spec = ProblemSpec::square(6, 1, MaskSpec::full());
        // D = 1 skips divisibility checks (6 is not divisible by 4 slabs).
        let s = zigzag(&spec, ScheduleKind::Fa3, 1).unwrap();
        let c = s.cluster.as_ref().unwrap();
        assert_eq!(c.n_devices, 1);
        assert!(c.device.iter().all(|&d| d == 0));
        assert_eq!(s.n_devices(), 1);
    }
}
