//! Shift Scheduling (§3.4): the theoretically optimal schedule for full
//! masks under the paper's DAG model.
//!
//! SM `i` (owning KV tile `i`) visits Q tiles cyclically starting from its
//! own index: `(i, i+1, …, n_q-1, 0, …, i-1)`. At global step `t` SM `i`
//! works on Q tile `(i + t) mod n_q` — all SMs touch *distinct* Q tiles at
//! every step, so the serialized per-dQ reductions never conflict and every
//! added dependency edge is depth-monotone (Lemma 1), preserving the
//! balanced-chain critical path `m·n·(c+r)`.
//!
//! The induced reduction order for dQ tile `j` is `j, j-1, …, j+1 (mod n)` —
//! the KV tile whose chain *starts* at `j` contributes first.
//!
//! ## Mask support
//!
//! The conflict-free-step construction needs two structural facts, checked
//! against the live-tile structure (not the mask's variant name):
//!
//! 1. every KV row's chain walks the *entire* Q axis (uniform full-row
//!    chains — rotations of a partial row would revisit masked tiles or
//!    break the distinct-Q-per-step invariant), and
//! 2. `n_kv <= n_q`, so the cyclic starts `kv mod n_q` are all distinct
//!    (with `n_kv > n_q`, rows `kv` and `kv - n_q` would collide on every
//!    step — the off-square bug this check fixes).
//!
//! Anything else returns a typed [`ScheduleError::UnsupportedMask`];
//! callers fall back to [`super::symmetric_shift`] / [`super::descending`].

use super::{Chain, ProblemSpec, Schedule, ScheduleError, ScheduleKind};

/// Build the Shift schedule, or a typed error when the mask/geometry
/// breaks its conflict-free cycle (see the module docs).
///
/// Chains are pinned: chain (head h, kv i) runs on SM `i`, heads pipelined
/// in launch order on the same SM set (requires `n_sm >= n_kv` in the
/// simulator; the figure harness aggregates heads per the paper's §3
/// normalization).
pub fn shift(spec: &ProblemSpec) -> Result<Schedule, ScheduleError> {
    let unsupported = |reason: &str| ScheduleError::UnsupportedMask {
        kind: ScheduleKind::Shift,
        mask: spec.mask.name(),
        reason: reason.into(),
    };
    if (0..spec.n_kv).any(|kv| spec.chain_len(kv) != spec.n_q) {
        return Err(unsupported(
            "the conflict-free cycle needs uniform full-row chains (every KV row \
             live for every Q tile)",
        ));
    }
    if spec.n_kv > spec.n_q {
        return Err(unsupported(
            "n_kv > n_q: cyclic starts repeat mod n_q, so two chains would touch \
             the same Q tile at every step",
        ));
    }
    let mut chains = Vec::with_capacity(spec.n_heads * spec.n_kv);
    let mut pinned = Vec::with_capacity(spec.n_heads * spec.n_kv);
    for head in 0..spec.n_heads {
        for kv in 0..spec.n_kv {
            // Cyclic visit order starting at the chain's own KV index.
            // Distinct starts (kv < n_kv <= n_q) keep every global step
            // conflict-free across the head's chains.
            let q_order: Vec<usize> = (0..spec.n_q).map(|t| (kv + t) % spec.n_q).collect();
            chains.push(Chain::new(head, kv, q_order));
            pinned.push(Some(kv));
        }
    }
    let start_steps = vec![0usize; chains.len()];
    let reduction_order = Schedule::timestamp_reduction_order(spec, &chains, &start_steps);
    Ok(Schedule {
        wave_width: spec.n_kv,
        spec: spec.clone(),
        kind: ScheduleKind::Shift,
        chains,
        pinned,
        reduction_order,
        cluster: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;
    use crate::schedule::MaskSpec;

    #[test]
    fn cyclic_visit_order() {
        let s = shift(&ProblemSpec::square(4, 1, MaskSpec::full())).unwrap();
        assert_eq!(s.chains[0].q_order, vec![0, 1, 2, 3]);
        assert_eq!(s.chains[2].q_order, vec![2, 3, 0, 1]);
        validate(&s).unwrap();
    }

    #[test]
    fn steps_are_conflict_free() {
        // At every step t, all chains of a head visit distinct Q tiles.
        let n = 8;
        let s = shift(&ProblemSpec::square(n, 1, MaskSpec::full())).unwrap();
        for t in 0..n {
            let mut seen = vec![false; n];
            for c in &s.chains {
                let q = c.q_order[t];
                assert!(!seen[q], "conflict at step {t} on q {q}");
                seen[q] = true;
            }
        }
    }

    #[test]
    fn rectangular_full_grid_stays_conflict_free() {
        // Regression for the off-square bug: with n_kv < n_q the cycle
        // must still visit distinct Q tiles at every step and validate.
        let spec = ProblemSpec { n_kv: 4, n_q: 6, n_heads: 2, mask: MaskSpec::full() };
        let s = shift(&spec).unwrap();
        validate(&s).unwrap();
        for t in 0..spec.n_q {
            let mut seen = vec![false; spec.n_q];
            for c in s.chains.iter().filter(|c| c.head == 0) {
                let q = c.q_order[t];
                assert!(!seen[q], "conflict at step {t} on q {q}");
                seen[q] = true;
            }
        }
    }

    #[test]
    fn wide_grid_is_a_typed_error_not_a_broken_schedule() {
        // n_kv > n_q: chains kv and kv - n_q would collide every step.
        // The seed emitted that invalid schedule silently; now it's typed.
        let spec = ProblemSpec { n_kv: 6, n_q: 4, n_heads: 1, mask: MaskSpec::full() };
        assert!(matches!(
            shift(&spec),
            Err(ScheduleError::UnsupportedMask { kind: ScheduleKind::Shift, .. })
        ));
    }

    #[test]
    fn non_full_masks_are_typed_errors() {
        for mask in [
            MaskSpec::causal(),
            MaskSpec::sliding_window(2),
            MaskSpec::document(vec![2]),
        ] {
            let err = shift(&ProblemSpec::square(4, 1, mask.clone())).unwrap_err();
            match err {
                ScheduleError::UnsupportedMask { kind, mask: name, .. } => {
                    assert_eq!(kind, ScheduleKind::Shift);
                    assert_eq!(name, mask.name());
                }
                other => panic!("expected UnsupportedMask, got {other:?}"),
            }
        }
    }

    #[test]
    fn all_live_block_sparse_is_structurally_full_and_supported() {
        // The support check derives from the live-tile structure: a
        // bitmap with every tile set is full-equivalent.
        let mask = MaskSpec::block_sparse(4, 4, vec![true; 16]);
        let s = shift(&ProblemSpec::square(4, 1, mask)).unwrap();
        validate(&s).unwrap();
    }

    #[test]
    fn reduction_order_descends_cyclically_from_diagonal() {
        let s = shift(&ProblemSpec::square(4, 1, MaskSpec::full())).unwrap();
        // dQ tile 2 receives kv 2 (t=0), kv 1 (t=1), kv 0 (t=2), kv 3 (t=3).
        assert_eq!(s.reduction_order_of(0, 2), &[2, 1, 0, 3]);
    }

    #[test]
    fn pinned_to_own_kv() {
        let s = shift(&ProblemSpec::square(4, 2, MaskSpec::full())).unwrap();
        for (i, c) in s.chains.iter().enumerate() {
            assert_eq!(s.pinned[i], Some(c.kv));
        }
        validate(&s).unwrap();
    }
}
