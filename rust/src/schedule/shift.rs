//! Shift Scheduling (§3.4): the theoretically optimal schedule for full
//! masks under the paper's DAG model.
//!
//! SM `i` (owning KV tile `i`) visits Q tiles cyclically starting from its
//! own index: `(i, i+1, …, n-1, 0, …, i-1)`. At global step `t` SM `i`
//! works on Q tile `(i + t) mod n` — all SMs touch *distinct* Q tiles at
//! every step, so the serialized per-dQ reductions never conflict and every
//! added dependency edge is depth-monotone (Lemma 1), preserving the
//! balanced-chain critical path `m·n·(c+r)`.
//!
//! The induced reduction order for dQ tile `j` is `j, j-1, …, j+1 (mod n)` —
//! the KV tile whose chain *starts* at `j` contributes first.

use super::{Chain, Mask, ProblemSpec, Schedule, ScheduleKind};

/// Build the Shift schedule. Defined for full masks (its optimality proof
/// needs uniform chain lengths); callers should use
/// [`super::symmetric_shift`] for causal masks.
///
/// Chains are pinned: chain (head h, kv i) runs on SM `i`, heads pipelined
/// in launch order on the same SM set (requires `n_sm >= n_kv` in the
/// simulator; the figure harness aggregates heads per the paper's §3
/// normalization).
pub fn shift(spec: ProblemSpec) -> Schedule {
    assert_eq!(spec.mask, Mask::Full, "shift scheduling is defined for full masks");
    let n = spec.n_kv;
    let mut chains = Vec::with_capacity(spec.n_heads * n);
    let mut pinned = Vec::with_capacity(spec.n_heads * n);
    for head in 0..spec.n_heads {
        for kv in 0..n {
            // Cyclic visit order starting at the chain's own KV index,
            // truncated/wrapped over the actual number of Q tiles.
            let q_order: Vec<usize> = (0..spec.n_q).map(|t| (kv + t) % spec.n_q).collect();
            chains.push(Chain::new(head, kv, q_order));
            pinned.push(Some(kv));
        }
    }
    let start_steps = vec![0usize; chains.len()];
    let reduction_order = Schedule::timestamp_reduction_order(&spec, &chains, &start_steps);
    Schedule { wave_width: spec.n_kv, spec, kind: ScheduleKind::Shift, chains, pinned, reduction_order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    #[test]
    fn cyclic_visit_order() {
        let s = shift(ProblemSpec::square(4, 1, Mask::Full));
        assert_eq!(s.chains[0].q_order, vec![0, 1, 2, 3]);
        assert_eq!(s.chains[2].q_order, vec![2, 3, 0, 1]);
        validate(&s).unwrap();
    }

    #[test]
    fn steps_are_conflict_free() {
        // At every step t, all chains of a head visit distinct Q tiles.
        let n = 8;
        let s = shift(ProblemSpec::square(n, 1, Mask::Full));
        for t in 0..n {
            let mut seen = vec![false; n];
            for c in &s.chains {
                let q = c.q_order[t];
                assert!(!seen[q], "conflict at step {t} on q {q}");
                seen[q] = true;
            }
        }
    }

    #[test]
    fn reduction_order_descends_cyclically_from_diagonal() {
        let s = shift(ProblemSpec::square(4, 1, Mask::Full));
        // dQ tile 2 receives kv 2 (t=0), kv 1 (t=1), kv 0 (t=2), kv 3 (t=3).
        assert_eq!(s.reduction_order_of(0, 2), &[2, 1, 0, 3]);
    }

    #[test]
    fn pinned_to_own_kv() {
        let s = shift(ProblemSpec::square(4, 2, Mask::Full));
        for (i, c) in s.chains.iter().enumerate() {
            assert_eq!(s.pinned[i], Some(c.kv));
        }
        validate(&s).unwrap();
    }
}
