//! Schedule generators for the deterministic attention backward pass.
//!
//! A *schedule* fixes three coupled decisions (the paper's key insight is
//! that they cannot be optimized in isolation):
//!
//! 1. **Chain assignment** — which SM executes the task chain of each
//!    (head, KV-tile). All tasks of one KV tile must run contiguously on a
//!    single SM so dK/dV stay register-resident (§3.1 constraint).
//! 2. **Q-tile visit order** — the order in which a chain walks its
//!    unmasked Q tiles (ascending for FA3, descending, or cyclically
//!    shifted).
//! 3. **Reduction order** — the total order in which per-KV-tile partial
//!    dQ contributions are folded into each dQ tile. This is what makes the
//!    kernel deterministic; its interaction with (1)+(2) decides the
//!    pipeline bubbles.
//!
//! ## The mask layer
//!
//! Every generator consumes the mask exclusively through
//! [`crate::mask::MaskSpec`] (via [`ProblemSpec::live`] /
//! [`ProblemSpec::chain_len`] / [`ProblemSpec::live_q`]): full, causal
//! (bottom-right aligned on rectangular grids), sliding-window, document /
//! varlen, and explicit block-sparse bitmaps all flow through the same
//! pipeline. Generators split into two families:
//!
//! * **Mask-generic** — [`fa3`], [`descending`], [`two_pass`],
//!   [`lpt_schedule`], and [`symmetric_shift`] derive their chains from
//!   the live-tile structure alone and accept *every* mask (and every
//!   rectangular `n_kv x n_q` grid). Their optimality statements only hold
//!   on their home regimes, but the schedules stay legal and deterministic
//!   everywhere: coverage, contiguity, and total per-(head, q) reduction
//!   orders are mask-derived, never assumed.
//! * **Structure-dependent** — [`shift`] needs uniform full-row chains
//!   with distinct cyclic starts (its conflict-free-step construction);
//!   it *checks* that structure and returns a typed
//!   [`ScheduleError::UnsupportedMask`] instead of emitting a silently
//!   invalid schedule when the mask (or an `n_kv > n_q` grid) breaks it.
//!
//! Generators provided:
//! * [`fa3`] — the FlashAttention-3 deterministic baseline (ascending
//!   Q-tiles, KV-index reduction order),
//! * [`descending`] — Descending Q-Tile Iteration (§3.3),
//! * [`shift`] — Shift Scheduling, optimal for full masks (§3.4),
//! * [`symmetric_shift`] — Symmetric Shift Scheduling, optimal for causal
//!   masks (§3.4, two-phase workload folding; general masks fall back to
//!   a chain-length-balanced pairing),
//! * [`two_pass`] — the Triton-tutorial two-pass deterministic baseline
//!   (separate dK/dV and dQ kernels, extra K/V read),
//! * [`lpt`] — the L2-aware LPT static chain-to-SM assignment (§4.3), both
//!   as an assignment analysis ([`lpt::assign_lpt`]) and as a pinned
//!   schedule generator ([`lpt_schedule`]).
//!
//! Schedules outside these analytic families are synthesized by the
//! search-based autotuner in [`crate::autotune`] and carry
//! [`ScheduleKind::Tuned`].

pub mod cluster;
pub mod descending;
pub mod fa3;
pub mod lpt;
pub mod shift;
pub mod symmetric_shift;
pub mod two_pass;
pub mod validate;

pub use crate::mask::MaskSpec;
pub use cluster::{cluster_schedule, parse_composite, ring, zigzag};
pub use descending::descending;
pub use fa3::fa3;
pub use lpt::{assign_lpt, lpt_schedule, LptAssignment};
pub use shift::shift;
pub use symmetric_shift::symmetric_shift;
pub use two_pass::two_pass;
pub use validate::{validate, ValidationError};

/// Typed failure of a schedule generator: the requested construction is
/// undefined for the problem's mask/geometry. Callers either pick another
/// generator or surface the message — a silently invalid schedule is never
/// produced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// The generator's invariants do not hold under this mask (e.g. Shift
    /// needs uniform full-row chains with distinct cyclic starts).
    UnsupportedMask {
        /// Generator that rejected the problem.
        kind: ScheduleKind,
        /// Canonical mask spelling ([`MaskSpec::name`]).
        mask: String,
        /// Which invariant broke.
        reason: String,
    },
    /// The requested context-parallel composition is undefined: the
    /// intra-device generator or device count cannot be sharded with this
    /// strategy (see [`cluster_schedule`]).
    UnsupportedCluster {
        /// Intra-device generator that was requested.
        kind: ScheduleKind,
        /// Sharding strategy name (`ring` / `zigzag`).
        strategy: &'static str,
        /// Which invariant broke.
        reason: String,
    },
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::UnsupportedMask { kind, mask, reason } => {
                write!(f, "schedule '{}' does not support mask '{mask}': {reason}", kind.name())
            }
            ScheduleError::UnsupportedCluster { kind, strategy, reason } => {
                write!(
                    f,
                    "cluster strategy '{strategy}' cannot compose with schedule '{}': {reason}",
                    kind.name()
                )
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Which schedule family produced a [`Schedule`]; carries the per-schedule
/// hardware cost model hooks (register overhead, implementation complexity).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScheduleKind {
    /// FlashAttention-3 deterministic baseline.
    Fa3,
    /// FlashAttention-3 *non-deterministic* (atomicAdd) — same tile order as
    /// Fa3 but no reduction-order constraint; the Fig-1 reference point.
    Fa3Atomic,
    /// Descending Q-Tile Iteration.
    Descending,
    /// Shift Scheduling (full mask optimal).
    Shift,
    /// Symmetric Shift Scheduling (causal optimal, workload folding).
    SymmetricShift,
    /// Triton-tutorial two-pass deterministic baseline.
    TwoPass,
    /// L2-aware LPT static chain-to-SM assignment over the FA3 tile walk
    /// (§4.3's interleaving policy as a standalone pinned schedule).
    Lpt,
    /// Search-synthesized schedule from the [`crate::autotune`] engine.
    Tuned,
}

impl ScheduleKind {
    /// Extra registers per thread this schedule's bookkeeping needs on top
    /// of the FA3 baseline (§4.3: Symmetric Shift needs ~10 more to manage
    /// the folded task space; Descending is free). Tuned schedules carry
    /// fully table-driven visit/reduction orders and are charged the same
    /// worst-case bookkeeping as Symmetric Shift.
    pub fn register_overhead(self) -> u32 {
        match self {
            ScheduleKind::SymmetricShift | ScheduleKind::Tuned => 10,
            ScheduleKind::Shift => 4,
            _ => 0,
        }
    }

    /// Whether the schedule serializes dQ accumulation (deterministic).
    pub fn deterministic(self) -> bool {
        !matches!(self, ScheduleKind::Fa3Atomic)
    }

    /// Human-readable name used in figures and CLI.
    pub fn name(self) -> &'static str {
        match self {
            ScheduleKind::Fa3 => "fa3-det",
            ScheduleKind::Fa3Atomic => "fa3-atomic",
            ScheduleKind::Descending => "descending",
            ScheduleKind::Shift => "shift",
            ScheduleKind::SymmetricShift => "symmetric-shift",
            ScheduleKind::TwoPass => "two-pass",
            ScheduleKind::Lpt => "lpt",
            ScheduleKind::Tuned => "tuned",
        }
    }

    /// Parse a schedule name as used by the CLI `--schedule` option and the
    /// trainer config. Accepts every [`ScheduleKind::name`] spelling plus
    /// the common short aliases.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fa3" | "fa3-det" => Some(ScheduleKind::Fa3),
            "fa3-atomic" | "atomic" => Some(ScheduleKind::Fa3Atomic),
            "descending" | "desc" => Some(ScheduleKind::Descending),
            "shift" => Some(ScheduleKind::Shift),
            "symmetric-shift" | "symshift" => Some(ScheduleKind::SymmetricShift),
            "two-pass" | "twopass" => Some(ScheduleKind::TwoPass),
            "lpt" => Some(ScheduleKind::Lpt),
            "tuned" => Some(ScheduleKind::Tuned),
            _ => None,
        }
    }
}

/// Problem geometry: the abstract model of §3 ("number of KV tiles equals
/// the number of SMs" is the default but not required by the simulator).
/// Rectangular grids (`n_kv != n_q`) are first-class; the mask decides
/// tile liveness through the [`MaskSpec`] layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProblemSpec {
    /// KV tiles per head (`n` in the paper when `n_kv == n_sm`).
    pub n_kv: usize,
    /// Q tiles per head.
    pub n_q: usize,
    /// Attention heads to pipeline (`m` in the paper; includes the batch
    /// dimension — a (batch, head) pair is one independent head instance).
    pub n_heads: usize,
    /// Mask shape.
    pub mask: MaskSpec,
}

impl ProblemSpec {
    /// Square spec with `n` KV and Q tiles (the paper's setting).
    pub fn square(n: usize, n_heads: usize, mask: MaskSpec) -> Self {
        Self { n_kv: n, n_q: n, n_heads, mask }
    }

    /// Is tile `(kv, q)` live under this spec's mask and grid?
    pub fn live(&self, kv: usize, q: usize) -> bool {
        self.mask.live(kv, q, self.n_kv, self.n_q)
    }

    /// Number of live Q tiles owned by KV row `kv`.
    pub fn chain_len(&self, kv: usize) -> usize {
        self.mask.chain_len(kv, self.n_kv, self.n_q)
    }

    /// Live Q tiles of KV row `kv`, ascending.
    pub fn live_q(&self, kv: usize) -> Vec<usize> {
        self.mask.live_q(kv, self.n_kv, self.n_q)
    }

    /// Per-KV-row live-Q sets (ascending walks), one mask scan — the
    /// head-invariant precompute every generator shares.
    pub fn live_rows(&self) -> Vec<Vec<usize>> {
        (0..self.n_kv).map(|kv| self.live_q(kv)).collect()
    }

    /// [`ProblemSpec::live_rows`] with each row's walk reversed
    /// (descending-Q generators).
    pub fn live_rows_desc(&self) -> Vec<Vec<usize>> {
        (0..self.n_kv)
            .map(|kv| self.live_q(kv).into_iter().rev().collect())
            .collect()
    }

    /// Total live tiles across all heads.
    pub fn total_tiles(&self) -> usize {
        self.mask.total_tiles(self.n_kv, self.n_q) * self.n_heads
    }
}

/// One contiguous unit of SM work: the full task chain of one (head, KV
/// tile). `q_order[t]` is the Q tile visited at local step `t`.
#[derive(Debug, Clone, PartialEq)]
pub struct Chain {
    /// Head instance index in `0..n_heads` (two-pass schedules use
    /// `n_heads..2*n_heads` as virtual heads for their second pass).
    pub head: usize,
    /// KV tile index in `0..n_kv` (the owned axis; pass-2 chains of the
    /// two-pass baseline own a Q tile instead and walk KV tiles).
    pub kv: usize,
    /// Visit order over live Q tiles (each exactly once).
    pub q_order: Vec<usize>,
    /// Compute-cost multiplier vs. the fused baseline tile (e.g. the
    /// two-pass dQ kernel re-reads K/V and recomputes S/P).
    pub compute_scale: f64,
    /// Reduction-cost multiplier (0.0 = no global dQ write, e.g. a
    /// dK/dV-only pass folds in registers).
    pub reduce_scale: f64,
    /// Whether this chain's reductions participate in the serialized
    /// per-(head, q) accumulation order. `false` models atomicAdd
    /// (non-deterministic) or purely local folds.
    pub ordered: bool,
}

impl Chain {
    /// A standard fused-kernel chain: unit costs, ordered reductions.
    pub fn new(head: usize, kv: usize, q_order: Vec<usize>) -> Self {
        Self { head, kv, q_order, compute_scale: 1.0, reduce_scale: 1.0, ordered: true }
    }

    /// Number of (compute, reduce) task pairs in this chain.
    pub fn len(&self) -> usize {
        self.q_order.len()
    }

    /// True if the chain has no tasks (fully masked KV tile).
    pub fn is_empty(&self) -> bool {
        self.q_order.is_empty()
    }
}

/// Device index within a cluster (the sequence-parallel rank). Rank 0 is
/// the rank whose partials fold first in the default cross-device order.
pub type DeviceId = usize;

/// How KV-tile chains are sharded across devices in a context-parallel
/// cluster schedule (see [`cluster_schedule`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ClusterStrategy {
    /// Contiguous KV slabs: device `d` owns KV tiles
    /// `[d*n_kv/D, (d+1)*n_kv/D)` — the classic ring-attention rotation
    /// order.
    Ring,
    /// Zigzag-causal slabs: the KV axis splits into `2D` slabs and device
    /// `d` owns slabs `d` and `2D-1-d`, balancing causal-mask work (each
    /// device gets one long-chain and one short-chain slab).
    Zigzag,
}

impl ClusterStrategy {
    /// Canonical name, the prefix of composite schedule names
    /// (`ring-shift`, `zigzag-descending`).
    pub fn name(self) -> &'static str {
        match self {
            ClusterStrategy::Ring => "ring",
            ClusterStrategy::Zigzag => "zigzag",
        }
    }

    /// Parse a strategy name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "ring" => Some(ClusterStrategy::Ring),
            "zigzag" => Some(ClusterStrategy::Zigzag),
            _ => None,
        }
    }
}

/// The device axis of a [`Schedule`]: which device runs each chain, and the
/// fixed cross-device reduction order. The intra-device chain set, visit
/// orders, and the per-(head, q) dQ reduction order are those of the
/// *full* (unsharded) schedule — that is the invariance trick: because the
/// fold order never depends on the device count, gradients are
/// bitwise-identical across `n_devices` by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSchedule {
    /// Sharding strategy that produced the device assignment.
    pub strategy: ClusterStrategy,
    /// Number of devices (sequence-parallel degree).
    pub n_devices: usize,
    /// `device[i]` = device that runs `chains[i]`.
    pub device: Vec<DeviceId>,
    /// Fixed order in which device partials fold during the cross-device
    /// reduction epilogue (a typed, total order — never arrival order).
    pub xdev_order: Vec<DeviceId>,
    /// Cost in cycles of one interconnect hop (one pipeline stage of the
    /// ring reduce). `1.0` on the abstract interconnect; CLI paths stamp
    /// the [`crate::hw::ClusterProfile`]-derived value before simulating.
    pub hop_cost: f64,
}

/// A complete schedule: launch-ordered chains with optional SM pinning and
/// an explicit per-(head, q) reduction order.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Geometry this schedule was generated for.
    pub spec: ProblemSpec,
    /// Which generator produced it.
    pub kind: ScheduleKind,
    /// Chains in launch order. The simulator's work queue follows this
    /// order when chains are not pinned. KV rows with no live tiles
    /// (possible under sliding-window / document / sparse masks) get no
    /// chain at all.
    pub chains: Vec<Chain>,
    /// `pinned[i]` = SM *slot* that must run `chains[i]`, or `None` for
    /// dynamic (persistent-CTA work-queue) assignment. Slots are relative
    /// to the chain's head wave: the simulator places a pinned chain on
    /// SM `(head * wave_width + slot % wave_width) % n_sm`, so pinned
    /// schedules tile across machines larger than one wave.
    pub pinned: Vec<Option<usize>>,
    /// Number of SM slots one head's wave occupies (`n` for shift, `n/2`
    /// for symmetric shift). Ignored for fully-unpinned schedules.
    pub wave_width: usize,
    /// For each (head, q): the total order of KV contributions to dQ.
    /// Indexed `head * n_q + q`. Empty for non-deterministic schedules
    /// (atomic accumulation has no prescribed order).
    pub reduction_order: Vec<Vec<usize>>,
    /// Device axis for context-parallel (multi-GPU) schedules; `None` for
    /// plain single-device schedules. When present, `chains[i]` runs on
    /// `cluster.device[i]` and the backward pass ends in a fixed
    /// cross-device fold (see [`cluster_schedule`]).
    pub cluster: Option<ClusterSchedule>,
}

impl Schedule {
    /// Accessor: reduction order for (head, q).
    pub fn reduction_order_of(&self, head: usize, q: usize) -> &[usize] {
        &self.reduction_order[head * self.spec.n_q + q]
    }

    /// Number of devices this schedule spans (1 for single-device
    /// schedules — with or without a degenerate cluster annotation).
    pub fn n_devices(&self) -> usize {
        self.cluster.as_ref().map_or(1, |c| c.n_devices)
    }

    /// Device that runs chain `i` (0 for single-device schedules).
    pub fn device_of(&self, i: usize) -> DeviceId {
        self.cluster.as_ref().map_or(0, |c| c.device[i])
    }

    /// Display name: the plain generator name for single-device schedules
    /// (so every existing output surface is byte-identical), the composite
    /// `<strategy>-<kind>` spelling for cluster schedules.
    pub fn display_name(&self) -> String {
        match &self.cluster {
            Some(c) => format!("{}-{}", c.strategy.name(), self.kind.name()),
            None => self.kind.name().to_string(),
        }
    }

    /// Physical SM for chain `i` on an `n_sm`-SM machine, or `None` for
    /// dynamically-assigned chains. Pinned slots tile in *aligned* waves:
    /// the machine hosts `floor(n_sm / wave_width)` concurrent head waves
    /// (leftover SMs idle — real grid quantization); heads beyond that
    /// queue behind earlier heads on the same wave's SMs. Alignment keeps
    /// every wave's chains starting together, which the shift schedules'
    /// conflict-free timestamp construction relies on.
    pub fn placement(&self, i: usize, n_sm: usize) -> Option<usize> {
        self.pinned[i].map(|slot| {
            let head = self.chains[i].head;
            let slot = slot % self.wave_width;
            let waves = n_sm / self.wave_width;
            if waves == 0 {
                // Machine smaller than one wave: quantize within it.
                slot % n_sm
            } else {
                (head % waves) * self.wave_width + slot
            }
        })
    }

    /// Total tasks across all chains.
    pub fn total_tasks(&self) -> usize {
        self.chains.iter().map(Chain::len).sum()
    }

    /// Request (document) index of chain `i` under a
    /// [`MaskSpec::Document`] mask — the serving-layer annotation: a trace
    /// batch compiles each request to one document, so this maps every
    /// chain back to the request whose gradients it computes. `None` for
    /// non-document masks. Two-pass virtual-head chains (which own a Q
    /// tile instead of a KV tile) resolve through the Q axis, so the
    /// annotation is total for every generator.
    pub fn chain_request(&self, i: usize) -> Option<usize> {
        let n = self.spec.n_kv.max(self.spec.n_q);
        let segments = self.spec.mask.document_segments(n)?;
        let ch = &self.chains[i];
        // Bottom-right alignment: axis tile -> sequence tile, Q axis for
        // pass-2 virtual heads, KV axis otherwise.
        let seq_tile = if ch.head >= self.spec.n_heads {
            ch.kv + (n - self.spec.n_q)
        } else {
            ch.kv + (n - self.spec.n_kv)
        };
        segments.iter().position(|&(s, e)| seq_tile >= s && seq_tile < e)
    }

    /// Build the canonical FA3-style reduction order (ascending KV index
    /// among live tiles) for every (head, q).
    pub(crate) fn ascending_reduction_order(spec: &ProblemSpec) -> Vec<Vec<usize>> {
        // Contributor columns are head-invariant: scan the mask once and
        // repeat per head.
        let per_q: Vec<Vec<usize>> = (0..spec.n_q)
            .map(|q| (0..spec.n_kv).filter(|&kv| spec.live(kv, q)).collect())
            .collect();
        let mut out = Vec::with_capacity(spec.n_heads * spec.n_q);
        for _head in 0..spec.n_heads {
            out.extend(per_q.iter().cloned());
        }
        out
    }

    /// Derive the reduction order from chain-local step timestamps: the KV
    /// contributions to each (head, q) ordered by the local step at which
    /// their chain visits q (ties broken by KV index — used by shift-style
    /// schedules where steps are conflict-free by construction).
    pub(crate) fn timestamp_reduction_order(
        spec: &ProblemSpec,
        chains: &[Chain],
        // Global offset of each chain's step 0 (e.g. phase offsets).
        chain_start_step: &[usize],
    ) -> Vec<Vec<usize>> {
        let mut buckets: Vec<Vec<(usize, usize)>> = vec![Vec::new(); spec.n_heads * spec.n_q];
        for (ci, ch) in chains.iter().enumerate() {
            for (t, &q) in ch.q_order.iter().enumerate() {
                buckets[ch.head * spec.n_q + q].push((chain_start_step[ci] + t, ch.kv));
            }
        }
        buckets
            .into_iter()
            .map(|mut b| {
                b.sort_unstable();
                b.into_iter().map(|(_, kv)| kv).collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_live_causal() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::causal());
        assert!(spec.live(0, 0));
        assert!(spec.live(1, 3));
        assert!(!spec.live(3, 1));
    }

    #[test]
    fn causal_chain_lengths_decrease_linearly() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::causal());
        let lens: Vec<_> = (0..4).map(|kv| spec.chain_len(kv)).collect();
        assert_eq!(lens, vec![4, 3, 2, 1]);
    }

    #[test]
    fn total_tiles_triangle_number() {
        assert_eq!(MaskSpec::causal().total_tiles(8, 8), 36);
        assert_eq!(MaskSpec::full().total_tiles(8, 8), 64);
    }

    #[test]
    fn register_overhead_matches_paper() {
        assert_eq!(ScheduleKind::SymmetricShift.register_overhead(), 10);
        assert_eq!(ScheduleKind::Descending.register_overhead(), 0);
    }

    #[test]
    fn spec_total_tiles_scales_with_heads() {
        let s = ProblemSpec::square(4, 3, MaskSpec::causal());
        assert_eq!(s.total_tiles(), 30);
    }

    #[test]
    fn rectangular_causal_spec_is_bottom_right_aligned() {
        // The regression the MaskSpec layer exists for: n_kv != n_q causal
        // specs must align to the bottom-right corner, not the top-left.
        let s = ProblemSpec { n_kv: 6, n_q: 3, n_heads: 1, mask: MaskSpec::causal() };
        assert_eq!(s.live_q(5), vec![2]); // last KV row: only the last Q tile
        assert_eq!(s.live_q(0), vec![0, 1, 2]);
        assert_eq!(s.chain_len(3), 3); // kv 0..=3 all see q >= kv - 3
        assert_eq!(s.total_tiles(), 3 + 3 + 3 + 3 + 2 + 1);
    }

    #[test]
    fn schedule_error_displays_its_context() {
        let e = ScheduleError::UnsupportedMask {
            kind: ScheduleKind::Shift,
            mask: "swa:4".into(),
            reason: "needs uniform full-row chains".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("shift") && msg.contains("swa:4"), "{msg}");
    }

    #[test]
    fn kind_names_round_trip_through_parse() {
        for kind in [
            ScheduleKind::Fa3,
            ScheduleKind::Fa3Atomic,
            ScheduleKind::Descending,
            ScheduleKind::Shift,
            ScheduleKind::SymmetricShift,
            ScheduleKind::TwoPass,
            ScheduleKind::Lpt,
            ScheduleKind::Tuned,
        ] {
            assert_eq!(ScheduleKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ScheduleKind::parse("symshift"), Some(ScheduleKind::SymmetricShift));
        assert_eq!(ScheduleKind::parse("nope"), None);
    }

    #[test]
    fn cluster_strategy_names_round_trip() {
        for s in [ClusterStrategy::Ring, ClusterStrategy::Zigzag] {
            assert_eq!(ClusterStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(ClusterStrategy::parse("mesh"), None);
    }

    #[test]
    fn device_helpers_default_to_single_device() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::full());
        let s = fa3(&spec, true);
        assert_eq!(s.n_devices(), 1);
        assert_eq!(s.device_of(0), 0);
        assert_eq!(s.display_name(), "fa3-det");
    }

    #[test]
    fn chain_request_annotates_document_schedules() {
        // Three documents: [0,3), [3,5), [5,8).
        let spec = ProblemSpec::square(8, 2, MaskSpec::document(vec![3, 5]));
        let s = fa3(&spec, true);
        for i in 0..s.chains.len() {
            let expect = match s.chains[i].kv {
                0..=2 => 0,
                3..=4 => 1,
                _ => 2,
            };
            assert_eq!(s.chain_request(i), Some(expect), "chain {i}");
        }
        // Two-pass virtual heads own Q tiles; the annotation still holds.
        let tp = two_pass(&spec);
        for i in 0..tp.chains.len() {
            let r = tp.chain_request(i);
            assert!(r.is_some(), "two-pass chain {i} unannotated");
            let expect = match tp.chains[i].kv {
                0..=2 => 0,
                3..=4 => 1,
                _ => 2,
            };
            assert_eq!(r, Some(expect), "chain {i}");
        }
        // Non-document masks carry no request axis.
        let full = fa3(&ProblemSpec::square(4, 1, MaskSpec::full()), true);
        assert_eq!(full.chain_request(0), None);
    }

    #[test]
    fn display_name_composes_strategy_and_kind() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Descending, 2).unwrap();
        assert_eq!(s.display_name(), "ring-descending");
        assert_eq!(s.n_devices(), 2);
    }
}
