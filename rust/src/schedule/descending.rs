//! Descending Q-Tile Iteration (§3.3): the robust heuristic for causal masks.
//!
//! Each chain walks its live Q tiles in *reverse* order. Under a causal mask
//! every chain's first task is q = n_q - 1, which all KV tiles share, so the
//! serialized reduction for the last dQ tile drains immediately and
//! dependencies resolve front-to-back. Crucially, short chains (large KV
//! index) finish first, releasing their SMs for the next head's *long*
//! chains — the pairing that yields `T ≈ m(n+1)(c+r)/2 + (n-1)r` for an even
//! number of heads.
//!
//! The launch order interleaves heads so that freed SMs pick up the next
//! head's longest remaining chain first (the paper's "tightly coupled
//! pipeline"): within each head chains are launched in *descending* chain
//! length? No — FA3's grid launches KV-ascending; the pairing emerges
//! because the work queue is consumed in launch order and short chains
//! finish early. We reproduce that: KV-ascending launch per head, dynamic
//! assignment, descending q walk.

use super::{Chain, ProblemSpec, Schedule, ScheduleKind};

/// Build the Descending Q-Tile Iteration schedule (works for both masks;
/// for full masks it is mainly useful as an ablation).
pub fn descending(spec: ProblemSpec) -> Schedule {
    descending_with_interleave(spec, spec.n_heads)
}

/// Descending Q-tile iteration with an explicit head-interleave width
/// (same L2-aware LPT chain scheduler as the FA3 baseline — the heuristic
/// changes the Q walk, not the kernel's launch order).
pub fn descending_with_interleave(spec: ProblemSpec, interleave: usize) -> Schedule {
    let w = interleave.clamp(1, spec.n_heads.max(1));
    let mut chains = Vec::with_capacity(spec.n_heads * spec.n_kv);
    for group in 0..spec.n_heads.div_ceil(w) {
        let heads = (group * w)..((group * w + w).min(spec.n_heads));
        for kv in 0..spec.n_kv {
            for head in heads.clone() {
                let q_order: Vec<usize> =
                    (0..spec.n_q).rev().filter(|&q| spec.mask.live(kv, q)).collect();
                chains.push(Chain::new(head, kv, q_order));
            }
        }
    }
    // Reduction order stays ascending-KV (the FA3 semaphore order): the
    // descending heuristic changes *when* contributions are produced, not
    // the serialization order itself. Because every chain produces its
    // q = n-1 contribution at local step 0, ascending-KV consumption is
    // immediately satisfiable step by step.
    let reduction_order = Schedule::ascending_reduction_order(&spec);
    let pinned = vec![None; chains.len()];
    Schedule { wave_width: spec.n_kv, spec, kind: ScheduleKind::Descending, chains, pinned, reduction_order }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Mask;
    use crate::schedule::validate::validate;

    #[test]
    fn causal_chains_walk_reverse() {
        let s = descending(ProblemSpec::square(4, 1, Mask::Causal));
        assert_eq!(s.chains[0].q_order, vec![3, 2, 1, 0]);
        assert_eq!(s.chains[2].q_order, vec![3, 2]);
        validate(&s).unwrap();
    }

    #[test]
    fn full_mask_valid() {
        let s = descending(ProblemSpec::square(6, 2, Mask::Full));
        validate(&s).unwrap();
        assert!(s.chains.iter().all(|c| c.q_order.first() == Some(&5)));
    }

    #[test]
    fn first_steps_all_touch_last_q() {
        // The property that makes the heuristic work: every chain's first
        // produced contribution is for the same (last) dQ tile, so the
        // serialized reduction starts draining at step 0.
        let s = descending(ProblemSpec::square(8, 1, Mask::Causal));
        for c in &s.chains {
            assert_eq!(c.q_order[0], 7);
        }
    }
}
