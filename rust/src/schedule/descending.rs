//! Descending Q-Tile Iteration (§3.3): the robust heuristic for causal masks.
//!
//! Each chain walks its live Q tiles in *reverse* order. Under a causal mask
//! every chain's first task is q = n_q - 1, which all KV tiles share, so the
//! serialized reduction for the last dQ tile drains immediately and
//! dependencies resolve front-to-back. Crucially, short chains (large KV
//! index) finish first, releasing their SMs for the next head's *long*
//! chains — the pairing that yields `T ≈ m(n+1)(c+r)/2 + (n-1)r` for an even
//! number of heads.
//!
//! The construction is mask-generic: the walk is simply the reverse of the
//! mask's live-Q set per KV row ([`ProblemSpec::live_q`]), so
//! sliding-window, document, sparse, and rectangular-causal specs all work;
//! fully-masked KV rows get no chain.
//!
//! The launch order interleaves heads so that freed SMs pick up the next
//! head's longest remaining chain first (the paper's "tightly coupled
//! pipeline"): within each head chains are launched in *descending* chain
//! length? No — FA3's grid launches KV-ascending; the pairing emerges
//! because the work queue is consumed in launch order and short chains
//! finish early. We reproduce that: KV-ascending launch per head, dynamic
//! assignment, descending q walk.

use super::{Chain, ProblemSpec, Schedule, ScheduleKind};

/// Build the Descending Q-Tile Iteration schedule (works for every mask;
/// for full masks it is mainly useful as an ablation).
pub fn descending(spec: &ProblemSpec) -> Schedule {
    descending_with_interleave(spec, spec.n_heads)
}

/// Descending Q-tile iteration with an explicit head-interleave width
/// (same L2-aware LPT chain scheduler as the FA3 baseline — the heuristic
/// changes the Q walk, not the kernel's launch order).
pub fn descending_with_interleave(spec: &ProblemSpec, interleave: usize) -> Schedule {
    let w = interleave.clamp(1, spec.n_heads.max(1));
    let walks = spec.live_rows_desc();
    let mut chains = Vec::with_capacity(spec.n_heads * spec.n_kv);
    for group in 0..spec.n_heads.div_ceil(w) {
        let heads = (group * w)..((group * w + w).min(spec.n_heads));
        for (kv, q_order) in walks.iter().enumerate() {
            if q_order.is_empty() {
                continue;
            }
            for head in heads.clone() {
                chains.push(Chain::new(head, kv, q_order.clone()));
            }
        }
    }
    // Reduction order stays ascending-KV (the FA3 semaphore order): the
    // descending heuristic changes *when* contributions are produced, not
    // the serialization order itself. Because every chain produces its
    // last-live-q contribution at local step 0, ascending-KV consumption
    // is immediately satisfiable step by step.
    let reduction_order = Schedule::ascending_reduction_order(spec);
    let pinned = vec![None; chains.len()];
    Schedule {
        wave_width: spec.n_kv,
        spec: spec.clone(),
        kind: ScheduleKind::Descending,
        chains,
        pinned,
        reduction_order,
        cluster: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;
    use crate::schedule::MaskSpec;

    #[test]
    fn causal_chains_walk_reverse() {
        let s = descending(&ProblemSpec::square(4, 1, MaskSpec::causal()));
        assert_eq!(s.chains[0].q_order, vec![3, 2, 1, 0]);
        assert_eq!(s.chains[2].q_order, vec![3, 2]);
        validate(&s).unwrap();
    }

    #[test]
    fn full_mask_valid() {
        let s = descending(&ProblemSpec::square(6, 2, MaskSpec::full()));
        validate(&s).unwrap();
        assert!(s.chains.iter().all(|c| c.q_order.first() == Some(&5)));
    }

    #[test]
    fn first_steps_all_touch_last_q() {
        // The property that makes the heuristic work: every chain's first
        // produced contribution is for the same (last) dQ tile, so the
        // serialized reduction starts draining at step 0.
        let s = descending(&ProblemSpec::square(8, 1, MaskSpec::causal()));
        for c in &s.chains {
            assert_eq!(c.q_order[0], 7);
        }
    }

    #[test]
    fn sliding_window_walks_reverse_of_live_band() {
        let s = descending(&ProblemSpec::square(6, 1, MaskSpec::sliding_window(2)));
        validate(&s).unwrap();
        // kv 3's band is q in {3, 4}; walked in reverse.
        let c = s.chains.iter().find(|c| c.kv == 3).unwrap();
        assert_eq!(c.q_order, vec![4, 3]);
    }

    #[test]
    fn fully_masked_kv_rows_get_no_chain() {
        // Rectangular causal, n_kv < n_q: every row is live; but a narrow
        // sliding window on a wide grid leaves early KV rows empty.
        let spec = ProblemSpec { n_kv: 8, n_q: 4, n_heads: 1, mask: MaskSpec::sliding_window(1) };
        // Bottom-right diagonal: only kv = q + 4 rows are live.
        let s = descending(&spec);
        validate(&s).unwrap();
        assert_eq!(s.chains.len(), 4);
        assert!(s.chains.iter().all(|c| c.kv >= 4 && c.q_order.len() == 1));
    }
}
