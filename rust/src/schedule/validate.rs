//! Legality checks for schedules — the §3.1 constraints as executable
//! invariants, used by unit tests, property tests, and the CLI explorer.

use super::{Chain, Schedule, ScheduleKind};
use std::collections::HashSet;

/// Ways a schedule can be illegal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ValidationError {
    /// A live tile is never computed, or computed more than once.
    Coverage { head: usize, kv: usize, q: usize, count: usize },
    /// A chain visits a masked tile.
    MaskedTile { head: usize, kv: usize, q: usize },
    /// Two chains share the same (head, kv) — violates the contiguity
    /// constraint (dK/dV must stay register-resident on one SM).
    SplitKvTile { head: usize, kv: usize },
    /// A deterministic (ordered) chain's (head, q) has no reduction order,
    /// or the order misses / duplicates a contributing KV tile.
    BadReductionOrder { head: usize, q: usize, detail: String },
    /// A pinned SM index is out of range for the declared SM count.
    PinOutOfRange { chain: usize, sm: usize },
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}

impl std::error::Error for ValidationError {}

/// Validate a schedule against the §3.1 constraints. Two-pass schedules
/// are validated per pass (each pass must cover the live grid exactly once).
pub fn validate(s: &Schedule) -> Result<(), ValidationError> {
    let spec = &s.spec;
    let two_pass = s.kind == ScheduleKind::TwoPass;

    // --- contiguity: one chain per (head, kv) -------------------------
    let mut owners: HashSet<(usize, usize)> = HashSet::new();
    for c in &s.chains {
        if !owners.insert((c.head, c.kv)) {
            return Err(ValidationError::SplitKvTile { head: c.head, kv: c.kv });
        }
    }

    // --- coverage + mask ----------------------------------------------
    // For two-pass, pass-2 chains live in a transposed grid; check each
    // pass independently.
    let check_cover = |chains: &[&Chain], n_own: usize, n_walk: usize, transposed: bool|
        -> Result<(), ValidationError> {
        let mut count = vec![0usize; spec.n_heads * n_own * n_walk];
        for c in chains {
            let head = c.head % spec.n_heads;
            for &w in &c.q_order {
                let (kv, q) = if transposed { (w, c.kv) } else { (c.kv, w) };
                if !spec.live(kv, q) {
                    return Err(ValidationError::MaskedTile { head, kv, q });
                }
                count[(head * n_own + c.kv) * n_walk + w] += 1;
            }
        }
        for head in 0..spec.n_heads {
            for own in 0..n_own {
                for w in 0..n_walk {
                    let (kv, q) = if transposed { (w, own) } else { (own, w) };
                    let c = count[(head * n_own + own) * n_walk + w];
                    let want = usize::from(spec.live(kv, q));
                    if c != want {
                        return Err(ValidationError::Coverage { head, kv, q, count: c });
                    }
                }
            }
        }
        Ok(())
    };

    if two_pass {
        let p1: Vec<&Chain> = s.chains.iter().filter(|c| c.head < spec.n_heads).collect();
        let p2: Vec<&Chain> = s.chains.iter().filter(|c| c.head >= spec.n_heads).collect();
        check_cover(&p1, spec.n_kv, spec.n_q, false)?;
        check_cover(&p2, spec.n_q, spec.n_kv, true)?;
    } else {
        let all: Vec<&Chain> = s.chains.iter().collect();
        check_cover(&all, spec.n_kv, spec.n_q, false)?;
    }

    // --- reduction order: total, exact, per ordered (head, q) ----------
    if s.chains.iter().any(|c| c.ordered) {
        for head in 0..spec.n_heads {
            for q in 0..spec.n_q {
                let contributors: HashSet<usize> = s
                    .chains
                    .iter()
                    .filter(|c| c.ordered && c.head == head && c.q_order.contains(&q))
                    .map(|c| c.kv)
                    .collect();
                if contributors.is_empty() {
                    continue;
                }
                if s.reduction_order.len() <= head * spec.n_q + q {
                    return Err(ValidationError::BadReductionOrder {
                        head,
                        q,
                        detail: "missing order table".into(),
                    });
                }
                let order = s.reduction_order_of(head, q);
                let order_set: HashSet<usize> = order.iter().copied().collect();
                if order.len() != order_set.len() || order_set != contributors {
                    return Err(ValidationError::BadReductionOrder {
                        head,
                        q,
                        detail: format!(
                            "order {order:?} vs contributors {contributors:?}"
                        ),
                    });
                }
            }
        }
    }

    // --- pinning sanity -------------------------------------------------
    // Pins are wave-relative slots: they must fit either the paper's
    // head-aggregated machine (n_kv SMs, the shift/symmetric-shift
    // normalization) or the schedule's own declared wave width (LPT and
    // tuned schedules pin absolute machine slots, wave_width = n_sm).
    let slot_limit = spec.n_kv.max(s.wave_width).max(2);
    for (i, p) in s.pinned.iter().enumerate() {
        if let Some(sm) = *p {
            if sm >= slot_limit {
                return Err(ValidationError::PinOutOfRange { chain: i, sm });
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, MaskSpec, ProblemSpec, Schedule};

    fn base() -> Schedule {
        fa3(&ProblemSpec::square(4, 1, MaskSpec::causal()), true)
    }

    #[test]
    fn valid_baseline_passes() {
        assert!(validate(&base()).is_ok());
    }

    #[test]
    fn missing_tile_detected() {
        let mut s = base();
        s.chains[0].q_order.pop();
        assert!(matches!(validate(&s), Err(ValidationError::Coverage { .. })));
    }

    #[test]
    fn duplicate_tile_detected() {
        let mut s = base();
        s.chains[0].q_order.push(1);
        assert!(matches!(validate(&s), Err(ValidationError::Coverage { .. })));
    }

    #[test]
    fn masked_tile_detected() {
        let mut s = base();
        // kv=3 visiting q=0 violates causality.
        s.chains[3].q_order.insert(0, 0);
        assert!(matches!(validate(&s), Err(ValidationError::MaskedTile { .. })));
    }

    #[test]
    fn split_kv_tile_detected() {
        let mut s = base();
        let dup = s.chains[0].clone();
        s.chains.push(dup);
        s.pinned.push(None);
        assert!(matches!(validate(&s), Err(ValidationError::SplitKvTile { .. })));
    }

    #[test]
    fn pin_beyond_wave_and_grid_detected() {
        let mut s = base(); // n_kv = 4, wave_width = 4
        s.pinned[0] = Some(s.wave_width.max(s.spec.n_kv)); // first illegal slot
        assert!(matches!(validate(&s), Err(ValidationError::PinOutOfRange { chain: 0, .. })));
        // A wider declared wave legitimizes the same slot.
        s.wave_width = 16;
        assert!(validate(&s).is_ok());
    }

    #[test]
    fn corrupt_reduction_order_detected() {
        let mut s = base();
        s.reduction_order[3].swap_remove(0); // q=3 loses a contributor
        assert!(matches!(
            validate(&s),
            Err(ValidationError::BadReductionOrder { q: 3, .. })
        ));
    }
}
