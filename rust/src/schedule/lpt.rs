//! L2-aware LPT (Longest Processing Time) chain-to-SM assignment — the
//! multi-head interleaving policy the FA3 causal backward kernel uses
//! (§4.3: "the L2-aware LPT scheduler interleaves multiple heads across
//! SMs"). Used by the simulator when a schedule leaves chains unpinned and
//! static assignment is requested, and by the figure harness to study how
//! interleaving masks causal stalls at small head footprints.

use super::{Chain, ProblemSpec, Schedule, ScheduleKind};

/// Result of a static LPT assignment: for each SM, the ordered chain list.
#[derive(Debug, Clone)]
pub struct LptAssignment {
    /// `per_sm[s]` = indices into `schedule.chains` in execution order.
    pub per_sm: Vec<Vec<usize>>,
    /// Predicted per-SM total work (task counts, compute_scale-weighted).
    pub load: Vec<f64>,
}

/// Assign unpinned chains to `n_sm` SMs by LPT with an L2-affinity tie
/// break: chains sorted by descending work; each goes to the least-loaded
/// SM, preferring (on near-ties within `affinity_slack`) an SM in the same
/// L2 segment as the chain's head's previous chains, to model the L2-aware
/// placement that keeps a head's K/V tiles in one cache segment.
///
/// Pinned chains keep their pins and pre-charge their SM's load.
pub fn assign_lpt(
    schedule: &Schedule,
    n_sm: usize,
    n_segments: usize,
    affinity_slack: f64,
) -> LptAssignment {
    assert!(n_sm > 0 && n_segments > 0);
    let seg_of = |sm: usize| sm * n_segments / n_sm;
    let work = |c: &Chain| c.len() as f64 * c.compute_scale.max(0.1);

    let mut per_sm: Vec<Vec<usize>> = vec![Vec::new(); n_sm];
    let mut load = vec![0.0f64; n_sm];

    // Pinned chains first (in launch order), placed via the wave formula.
    for (i, c) in schedule.chains.iter().enumerate() {
        if let Some(sm) = schedule.placement(i, n_sm) {
            per_sm[sm].push(i);
            load[sm] += work(c);
        }
    }

    // Head -> segment affinity accumulated as chains are placed.
    let mut head_segment: std::collections::HashMap<usize, usize> =
        std::collections::HashMap::new();

    // LPT over the unpinned chains.
    let mut order: Vec<usize> = (0..schedule.chains.len())
        .filter(|&i| schedule.pinned[i].is_none())
        .collect();
    order.sort_by(|&a, &b| {
        work(&schedule.chains[b])
            .partial_cmp(&work(&schedule.chains[a]))
            .unwrap()
            .then(a.cmp(&b))
    });

    for i in order {
        let c = &schedule.chains[i];
        // Least-loaded SM.
        let best = (0..n_sm)
            .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
            .unwrap();
        // Prefer an SM in the head's segment if within slack of best.
        let chosen = match head_segment.get(&c.head) {
            Some(&seg) => (0..n_sm)
                .filter(|&sm| seg_of(sm) == seg && load[sm] <= load[best] + affinity_slack)
                .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
                .unwrap_or(best),
            None => best,
        };
        head_segment.entry(c.head).or_insert_with(|| seg_of(chosen));
        per_sm[chosen].push(i);
        load[chosen] += work(c);
    }

    // Execution order within an SM must respect launch order (persistent
    // CTAs drain the grid in launch order), so re-sort each SM's list.
    for l in &mut per_sm {
        l.sort_unstable();
    }
    LptAssignment { per_sm, load }
}

/// Build a complete *pinned* LPT schedule for an `n_sm`-SM machine: FA3
/// tile walks (ascending live Q tiles, ascending-KV reduction order) with
/// chains statically placed by longest-processing-time-first onto the
/// least-loaded SM. This is §4.3's interleaving policy promoted to a
/// standalone [`ScheduleKind::Lpt`] schedule: on causal masks it balances
/// the linearly-decreasing chain lengths across SMs without relying on the
/// dynamic work queue, which makes the placement (and therefore the whole
/// execution) reproducible and DAG-analyzable.
///
/// Deadlock-freedom: launch order is head-major/KV-ascending and the
/// reduction order is ascending-KV, so every wait points at a chain with a
/// strictly smaller launch index, and within an SM chains execute in launch
/// order — no cyclic wait is possible regardless of the LPT placement.
pub fn lpt_schedule(spec: &ProblemSpec, n_sm: usize) -> Schedule {
    let n_sm = n_sm.max(1);
    let live = spec.live_rows();
    let mut chains = Vec::with_capacity(spec.n_heads * spec.n_kv);
    for head in 0..spec.n_heads {
        for (kv, q_order) in live.iter().enumerate() {
            if q_order.is_empty() {
                continue;
            }
            chains.push(Chain::new(head, kv, q_order.clone()));
        }
    }

    // LPT: longest chains first, each onto the currently least-loaded SM
    // (ties broken by lowest SM index, then lowest chain index — fully
    // deterministic).
    let mut order: Vec<usize> = (0..chains.len()).collect();
    order.sort_by(|&a, &b| chains[b].len().cmp(&chains[a].len()).then(a.cmp(&b)));
    let mut load = vec![0usize; n_sm];
    let mut pinned: Vec<Option<usize>> = vec![None; chains.len()];
    for i in order {
        let sm = (0..n_sm).min_by(|&a, &b| load[a].cmp(&load[b]).then(a.cmp(&b))).unwrap();
        pinned[i] = Some(sm);
        load[sm] += chains[i].len();
    }

    let reduction_order = Schedule::ascending_reduction_order(spec);
    // `wave_width = n_sm` makes `Schedule::placement` the identity on the
    // pinned slot for an `n_sm`-SM machine (one machine-wide wave).
    Schedule {
        wave_width: n_sm,
        spec: spec.clone(),
        kind: ScheduleKind::Lpt,
        chains,
        pinned,
        reduction_order,
        cluster: None,
    }
}

/// Load-imbalance ratio: max / mean per-SM load (1.0 = perfect).
pub fn imbalance(a: &LptAssignment) -> f64 {
    let max = a.load.iter().fold(0.0f64, |m, &v| m.max(v));
    let mean = a.load.iter().sum::<f64>() / a.load.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{descending, fa3, MaskSpec, ProblemSpec};

    #[test]
    fn all_chains_assigned_exactly_once() {
        let s = fa3(&ProblemSpec::square(8, 4, MaskSpec::causal()), true);
        let a = assign_lpt(&s, 6, 2, 0.5);
        let mut seen = vec![false; s.chains.len()];
        for l in &a.per_sm {
            for &i in l {
                assert!(!seen[i]);
                seen[i] = true;
            }
        }
        assert!(seen.into_iter().all(|b| b));
    }

    #[test]
    fn causal_lpt_is_reasonably_balanced() {
        let s = fa3(&ProblemSpec::square(16, 2, MaskSpec::causal()), true);
        let a = assign_lpt(&s, 8, 4, 0.5);
        assert!(imbalance(&a) < 1.3, "imbalance {}", imbalance(&a));
    }

    #[test]
    fn pinned_chains_keep_pins() {
        use crate::schedule::symmetric_shift;
        let s = symmetric_shift(&ProblemSpec::square(8, 1, MaskSpec::causal()));
        let a = assign_lpt(&s, 8, 2, 0.5);
        for i in 0..s.chains.len() {
            let sm = s.placement(i, 8).unwrap();
            assert!(a.per_sm[sm].contains(&i));
        }
    }

    #[test]
    fn within_sm_order_respects_launch_order() {
        let s = descending(&ProblemSpec::square(8, 3, MaskSpec::causal()));
        let a = assign_lpt(&s, 4, 2, 0.5);
        for l in &a.per_sm {
            assert!(l.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn lpt_schedule_is_valid_and_fully_pinned() {
        use crate::schedule::validate::validate;
        for (n, m, mask, n_sm) in [
            (8usize, 2usize, MaskSpec::causal(), 4usize),
            (8, 2, MaskSpec::full(), 8),
            (7, 3, MaskSpec::causal(), 13),
            (8, 2, MaskSpec::sliding_window(3), 5),
            (8, 2, MaskSpec::document(vec![3, 6]), 6),
        ] {
            let s = lpt_schedule(&ProblemSpec::square(n, m, mask), n_sm);
            validate(&s).unwrap();
            assert_eq!(s.kind, ScheduleKind::Lpt);
            assert!(s.pinned.iter().all(|p| matches!(p, Some(sm) if *sm < n_sm)));
        }
    }

    #[test]
    fn lpt_schedule_balances_causal_chains() {
        let n = 16;
        let n_sm = 4;
        let s = lpt_schedule(&ProblemSpec::square(n, 1, MaskSpec::causal()), n_sm);
        let mut load = vec![0usize; n_sm];
        for (i, c) in s.chains.iter().enumerate() {
            load[s.placement(i, n_sm).unwrap()] += c.len();
        }
        let total: usize = load.iter().sum();
        let max = *load.iter().max().unwrap();
        // LPT on decreasing chain lengths lands within one longest chain of
        // the perfect split.
        assert!(max <= total / n_sm + n, "load {load:?}");
        assert_eq!(total, s.spec.total_tiles());
    }

    #[test]
    fn lpt_schedule_simulates_without_deadlock() {
        use crate::sim::{simulate, SimConfig};
        for n_sm in [3usize, 8, 13] {
            let s = lpt_schedule(&ProblemSpec::square(8, 3, MaskSpec::causal()), n_sm);
            let r = simulate(&s, &SimConfig::ideal(n_sm)).unwrap();
            assert_eq!(r.n_tasks, s.total_tasks());
        }
    }
}
