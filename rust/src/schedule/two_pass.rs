//! Triton-tutorial style two-pass deterministic baseline (§5 Related Works).
//!
//! Pass 1 parallelizes over KV tiles and computes dK/dV only — local
//! register-resident reductions, no global dQ write (`reduce_scale = 0`,
//! unordered). Pass 2 parallelizes over *Q* tiles: each chain owns one dQ
//! tile and re-walks its live KV tiles, recomputing S/P and folding dQ
//! locally — trivially deterministic, but it re-reads K/V from HBM and
//! duplicates the tile GEMMs, charged via [`TWO_PASS_COST_MULTIPLIER`].
//!
//! Launch order places every pass-1 chain before every pass-2 chain; the
//! simulator's in-order work queue therefore approximates the kernel
//! boundary (a true grid-wide barrier is slightly stricter; the difference
//! only narrows the two-pass baseline's loss, so this is conservative in
//! the baseline's favor).

use super::{Chain, ProblemSpec, Schedule, ScheduleKind};

/// Compute-cost multiplier for pass-2 (dQ) tasks relative to a fused-kernel
/// tile: S and dS are recomputed and K/V re-read through HBM. Calibrated so
/// the two-pass baseline lands ~20-35% below fused FA3, matching the
/// Triton curves in the paper's Fig 9.
pub const TWO_PASS_COST_MULTIPLIER: f64 = 1.30;

/// Build the two-pass schedule. Pass-2 chains use virtual head indices
/// `n_heads + head` and own a *Q* tile (stored in the `kv` slot), walking
/// live KV tiles in ascending order.
pub fn two_pass(spec: &ProblemSpec) -> Schedule {
    let mut chains = Vec::new();
    // Both axes' live sets are head-invariant: scan the mask once each.
    let live_rows = spec.live_rows();
    let live_cols: Vec<Vec<usize>> = (0..spec.n_q)
        .map(|q| (0..spec.n_kv).filter(|&kv| spec.live(kv, q)).collect())
        .collect();
    // Pass 1: dK/dV — KV-parallel, no global reduction.
    for head in 0..spec.n_heads {
        for (kv, q_order) in live_rows.iter().enumerate() {
            if q_order.is_empty() {
                continue;
            }
            let mut c = Chain::new(head, kv, q_order.clone());
            c.reduce_scale = 0.0;
            c.ordered = false;
            chains.push(c);
        }
    }
    // Pass 2: dQ — Q-parallel, local fold, extra compute.
    for head in 0..spec.n_heads {
        for (q, kv_order) in live_cols.iter().enumerate() {
            if kv_order.is_empty() {
                continue;
            }
            let mut c = Chain::new(spec.n_heads + head, q, kv_order.clone());
            c.compute_scale = TWO_PASS_COST_MULTIPLIER;
            c.reduce_scale = 0.0;
            c.ordered = false;
            chains.push(c);
        }
    }
    let pinned = vec![None; chains.len()];
    // No serialized global reductions anywhere.
    Schedule {
        wave_width: spec.n_kv,
        spec: spec.clone(),
        kind: ScheduleKind::TwoPass,
        chains,
        pinned,
        reduction_order: Vec::new(),
        cluster: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::MaskSpec;

    #[test]
    fn both_passes_present_with_equal_tile_counts() {
        let spec = ProblemSpec::square(4, 2, MaskSpec::causal());
        let s = two_pass(&spec);
        assert_eq!(s.chains.len(), 16);
        let pass1: usize = s.chains.iter().filter(|c| c.head < 2).map(Chain::len).sum();
        let pass2: usize = s.chains.iter().filter(|c| c.head >= 2).map(Chain::len).sum();
        assert_eq!(pass1, 20);
        assert_eq!(pass2, 20);
    }

    #[test]
    fn pass2_walks_live_kv_with_cost_penalty() {
        let spec = ProblemSpec::square(4, 1, MaskSpec::causal());
        let s = two_pass(&spec);
        let c = s.chains.iter().find(|c| c.head == 1 && c.kv == 2).unwrap();
        assert_eq!(c.q_order, vec![0, 1, 2]); // kv tiles <= q=2
        assert_eq!(c.compute_scale, TWO_PASS_COST_MULTIPLIER);
        assert_eq!(c.reduce_scale, 0.0);
        assert!(!c.ordered);
    }

    #[test]
    fn no_chain_is_ordered() {
        let s = two_pass(&ProblemSpec::square(8, 2, MaskSpec::full()));
        assert!(s.chains.iter().all(|c| !c.ordered));
        assert!(s.reduction_order.is_empty());
    }

    #[test]
    fn pass1_launches_before_pass2() {
        let spec = ProblemSpec::square(4, 2, MaskSpec::full());
        let s = two_pass(&spec);
        let first_pass2 = s.chains.iter().position(|c| c.head >= 2).unwrap();
        assert!(s.chains[..first_pass2].iter().all(|c| c.head < 2));
    }
}
