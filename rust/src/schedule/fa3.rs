//! FlashAttention-3 deterministic baseline schedule (§3.2).
//!
//! Chain assignment: head-major launch order, one chain per (head, KV tile),
//! KV index ascending within a head. Q-tile visit order: ascending over the
//! mask's live tiles (from the diagonal for causal masks). Reduction order:
//! ascending KV index — the CTA launch order, which is what FA3's semaphore
//! serializes on. Mask-generic: the walk is [`ProblemSpec::live_q`], so
//! every [`crate::mask::MaskSpec`] shape and rectangular grid works;
//! fully-masked KV rows launch no chain.
//!
//! Under a full mask this pipelines reasonably (Fig 3a: only a startup
//! bubble of `(n-1)·r`); under a causal mask it stalls badly because KV tile
//! `i`'s *first* task (q = i) needs contributions from every earlier KV tile,
//! which arrive late in their chains (Fig 3b).

use super::{Chain, ProblemSpec, Schedule, ScheduleKind};

/// Build the FA3 baseline schedule. `deterministic = false` produces the
/// atomic-accumulation variant (same tile order, no reduction order) used
/// as the non-deterministic reference in Fig 1.
pub fn fa3(spec: &ProblemSpec, deterministic: bool) -> Schedule {
    fa3_with_interleave(spec, deterministic, spec.n_heads)
}

/// FA3 baseline with an explicit head-interleave width.
///
/// The kernel's L2-aware LPT scheduler launches longest chains first with
/// heads interleaved — but only as many heads as keep their K/V working
/// sets resident in L2 (`interleave` heads per group). Small footprints
/// (short sequences / hd64) interleave many heads and mask each other's
/// reduction stalls; long sequences fit only a few heads and the §3.2
/// per-head bubble surfaces — exactly the Fig 1 degradation trend.
pub fn fa3_with_interleave(
    spec: &ProblemSpec,
    deterministic: bool,
    interleave: usize,
) -> Schedule {
    let w = interleave.clamp(1, spec.n_heads.max(1));
    let live = spec.live_rows();
    let mut chains = Vec::with_capacity(spec.n_heads * spec.n_kv);
    for group in 0..spec.n_heads.div_ceil(w) {
        let heads = (group * w)..((group * w + w).min(spec.n_heads));
        for (kv, q_order) in live.iter().enumerate() {
            if q_order.is_empty() {
                continue;
            }
            for head in heads.clone() {
                let mut c = Chain::new(head, kv, q_order.clone());
                // Atomic accumulation still pays the L2 read-modify-write
                // (`r`) but imposes no ordering.
                c.ordered = deterministic;
                chains.push(c);
            }
        }
    }
    let reduction_order = if deterministic {
        Schedule::ascending_reduction_order(spec)
    } else {
        Vec::new()
    };
    let pinned = vec![None; chains.len()];
    Schedule {
        wave_width: spec.n_kv,
        spec: spec.clone(),
        kind: if deterministic { ScheduleKind::Fa3 } else { ScheduleKind::Fa3Atomic },
        chains,
        pinned,
        reduction_order,
        cluster: None,
    }
}

/// Convenience: the non-deterministic (atomicAdd) FA3 reference.
pub fn fa3_atomic(spec: &ProblemSpec) -> Schedule {
    fa3(spec, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;
    use crate::schedule::MaskSpec;

    #[test]
    fn full_mask_chains_cover_grid() {
        let s = fa3(&ProblemSpec::square(4, 2, MaskSpec::full()), true);
        assert_eq!(s.chains.len(), 8);
        assert!(s.chains.iter().all(|c| c.q_order == vec![0, 1, 2, 3]));
        validate(&s).unwrap();
    }

    #[test]
    fn causal_chains_start_at_diagonal() {
        let s = fa3(&ProblemSpec::square(4, 1, MaskSpec::causal()), true);
        assert_eq!(s.chains[2].q_order, vec![2, 3]);
        assert_eq!(s.chains[3].q_order, vec![3]);
        validate(&s).unwrap();
    }

    #[test]
    fn rectangular_causal_chains_align_bottom_right() {
        // n_kv = 6, n_q = 3: KV row 5 owns only the last Q tile; KV row 0
        // owns the whole row. The seed's `q >= kv` rule would instead give
        // rows 3..6 nothing and mis-cover the grid.
        let spec = ProblemSpec { n_kv: 6, n_q: 3, n_heads: 1, mask: MaskSpec::causal() };
        let s = fa3(&spec, true);
        validate(&s).unwrap();
        assert_eq!(s.chains.iter().find(|c| c.kv == 5).unwrap().q_order, vec![2]);
        assert_eq!(s.chains.iter().find(|c| c.kv == 0).unwrap().q_order, vec![0, 1, 2]);
        assert_eq!(s.total_tasks(), spec.total_tiles());
    }

    #[test]
    fn document_mask_chains_stay_in_their_block() {
        let spec = ProblemSpec::square(6, 1, MaskSpec::document(vec![3]));
        let s = fa3(&spec, true);
        validate(&s).unwrap();
        for c in &s.chains {
            let doc = usize::from(c.kv >= 3);
            assert!(c.q_order.iter().all(|&q| usize::from(q >= 3) == doc), "{c:?}");
        }
    }

    #[test]
    fn reduction_order_is_ascending_kv() {
        let s = fa3(&ProblemSpec::square(4, 1, MaskSpec::causal()), true);
        assert_eq!(s.reduction_order_of(0, 3), &[0, 1, 2, 3]);
        assert_eq!(s.reduction_order_of(0, 1), &[0, 1]);
    }

    #[test]
    fn atomic_variant_has_no_reduction_order() {
        let s = fa3_atomic(&ProblemSpec::square(4, 1, MaskSpec::full()));
        assert!(s.reduction_order.is_empty());
        assert!(!s.kind.deterministic());
        validate(&s).unwrap();
    }
}
