//! Symmetric Shift Scheduling (§3.4): the theoretically optimal causal-mask
//! schedule via symmetric pairing and two-phase workload folding.
//!
//! Causal chain lengths decrease linearly (KV tile `i` has `n - i` tasks).
//! Pairing KV tile `i` (length `n-i`) with KV tile `n-1-i` (length `i+1`) on
//! one SM gives every SM exactly `n+1` tasks — perfect balance; a head then
//! occupies `n/2` SMs, and two heads fill the machine, yielding
//! `T = m(n+1)(c+r)/2`.
//!
//! The two phases (Fig 7):
//! * **Phase 1** — the dense lower-left rectangle (KV `i < n/2`, Q `j >= n/2`)
//!   executes a cyclic shift: SM `s` visits `q = n/2 + ((s + t) mod (n/2))`.
//! * **Phase 2** — the residual triangles fold into a conceptual square:
//!   SM `s` walks the upper-left triangle top-down from the diagonal
//!   (`q = s, s+1, …, n/2-1`, still KV tile `s` — contiguous with phase 1),
//!   then the lower-right triangle bottom-up (KV tile `n-1-s`,
//!   `q = n-1, n-2, …, n-1-s`).
//!
//! Every global step touches distinct Q tiles across SMs, so the
//! timestamp-induced reduction order is conflict-free and depth-monotone
//! (Lemma 1) — no pipeline bubbles.
//!
//! ## Mask support
//!
//! The exact folded construction is specific to standard causal masks on
//! even square grids (the paper's setting; `seqlen / 128` is even for every
//! benchmark configuration). Every other (mask, grid) combination —
//! rectangular causal, causal offsets, sliding-window, document, sparse,
//! odd grids — generalizes through [`ProblemSpec::chain_len`]: live chains
//! are paired longest-with-shortest onto SM slots for balance, launched in
//! ascending KV order with descending Q walks and the ascending-KV
//! reduction order. That keeps every reduction wait pointing at an
//! earlier-launched chain (the same deadlock-freedom argument as
//! [`super::lpt_schedule`]) while preserving the pairing idea that makes
//! symmetric shift near-optimal off its home regime.

use super::{Chain, MaskSpec, ProblemSpec, Schedule, ScheduleKind};

/// Build the Symmetric Shift schedule: the exact two-phase folding on its
/// home regime (standard causal, even square grid), the chain-length
/// pairing fallback everywhere else. Defined for every mask.
pub fn symmetric_shift(spec: &ProblemSpec) -> Schedule {
    let home = matches!(spec.mask, MaskSpec::Causal { offset: 0 })
        && spec.n_kv == spec.n_q
        && spec.n_kv % 2 == 0
        && spec.n_kv >= 2;
    if home {
        folded(spec)
    } else {
        paired_fallback(spec)
    }
}

/// The exact two-phase folded construction (even square causal grids).
fn folded(spec: &ProblemSpec) -> Schedule {
    let n = spec.n_kv;
    let h = n / 2;
    let mut chains = Vec::new();
    let mut pinned = Vec::new();
    let mut start_steps = Vec::new();
    for head in 0..spec.n_heads {
        // A head occupies h SM slots (wave_width = h): the placement
        // formula alternates heads across SM halves so two heads fill all
        // n SMs, matching the paper's pipelined timeline.
        for s in 0..h {
            // Chain A: KV tile s — phase-1 rectangle then phase-2 left
            // triangle, one contiguous chain.
            let mut q_order: Vec<usize> = (0..h).map(|t| h + ((s + t) % h)).collect();
            q_order.extend(s..h);
            chains.push(Chain::new(head, s, q_order));
            pinned.push(Some(s));
            start_steps.push(0);

            // Chain B: KV tile n-1-s — phase-2 right triangle, bottom-up.
            let q_order_b: Vec<usize> = ((n - 1 - s)..n).rev().collect();
            chains.push(Chain::new(head, n - 1 - s, q_order_b));
            pinned.push(Some(s));
            // Chain B starts after chain A: h (rect) + (h - s) (left tri).
            start_steps.push(2 * h - s);
        }
    }
    let reduction_order = Schedule::timestamp_reduction_order(spec, &chains, &start_steps);
    Schedule {
        wave_width: h,
        spec: spec.clone(),
        kind: ScheduleKind::SymmetricShift,
        chains,
        pinned,
        reduction_order,
        cluster: None,
    }
}

/// Chain-length-balanced pairing with a descending Q walk — the
/// general-shape fallback for any mask and rectangular grids.
///
/// Live KV rows are ranked by chain length (longest first) and slotted so
/// that rank `i` shares an SM with rank `2h-1-i` — longest with shortest.
/// Launch order stays ascending KV, so with the ascending-KV reduction
/// order every wait targets an earlier-launched chain and within-SM
/// execution (launch order) can never deadlock.
fn paired_fallback(spec: &ProblemSpec) -> Schedule {
    let lens: Vec<usize> = (0..spec.n_kv).map(|kv| spec.chain_len(kv)).collect();
    let mut ranked: Vec<usize> = (0..spec.n_kv).filter(|&kv| lens[kv] > 0).collect();
    ranked.sort_by(|&a, &b| lens[b].cmp(&lens[a]).then(a.cmp(&b)));
    let h = ranked.len().div_ceil(2).max(1);
    let mut slot_of = vec![0usize; spec.n_kv];
    for (rank, &kv) in ranked.iter().enumerate() {
        slot_of[kv] = if rank < h { rank } else { 2 * h - 1 - rank };
    }

    let walks = spec.live_rows_desc();
    let mut chains = Vec::new();
    let mut pinned = Vec::new();
    for head in 0..spec.n_heads {
        for (kv, walk) in walks.iter().enumerate() {
            if walk.is_empty() {
                continue;
            }
            chains.push(Chain::new(head, kv, walk.clone()));
            pinned.push(Some(slot_of[kv]));
        }
    }
    // Descending walks drain last-q first; the ascending-KV semaphore order
    // is immediately satisfiable (same argument as `descending`).
    let reduction_order = Schedule::ascending_reduction_order(spec);
    Schedule {
        wave_width: h,
        spec: spec.clone(),
        kind: ScheduleKind::SymmetricShift,
        chains,
        pinned,
        reduction_order,
        cluster: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    #[test]
    fn folded_chains_are_balanced() {
        let n = 8;
        let s = symmetric_shift(&ProblemSpec::square(n, 1, MaskSpec::causal()));
        validate(&s).unwrap();
        // Per-SM total work = n + 1 tasks.
        let mut per_sm = vec![0usize; n];
        for (i, c) in s.chains.iter().enumerate() {
            per_sm[s.placement(i, n).unwrap()] += c.len();
        }
        for sm in 0..n / 2 {
            assert_eq!(per_sm[sm], n + 1, "SM {sm} unbalanced");
        }
    }

    #[test]
    fn folded_steps_are_conflict_free() {
        // No two SMs of a head touch the same Q tile at the same global step.
        let n = 8;
        let h = n / 2;
        let s = symmetric_shift(&ProblemSpec::square(n, 1, MaskSpec::causal()));
        // Reconstruct (sm -> step -> q) from chain order: chains on one SM
        // execute back to back.
        let mut timeline: Vec<Vec<usize>> = vec![Vec::new(); h];
        for (i, c) in s.chains.iter().enumerate() {
            timeline[s.placement(i, n).unwrap()].extend(&c.q_order);
        }
        let max_steps = timeline.iter().map(Vec::len).max().unwrap();
        for t in 0..max_steps {
            let qs: Vec<_> = timeline.iter().filter_map(|tl| tl.get(t)).collect();
            let mut dedup = qs.clone();
            dedup.sort();
            dedup.dedup();
            assert_eq!(dedup.len(), qs.len(), "Q conflict at step {t}: {qs:?}");
        }
    }

    #[test]
    fn folded_chain_a_contiguous_rect_then_triangle() {
        let s = symmetric_shift(&ProblemSpec::square(8, 1, MaskSpec::causal()));
        // SM 0 / chain A (kv 0): rect visits q 4..8 cyclic from 4, then 0..4.
        assert_eq!(s.chains[0].q_order, vec![4, 5, 6, 7, 0, 1, 2, 3]);
        // SM 1 / chain A (kv 1): rect from 5, then triangle 1..4.
        assert_eq!(s.chains[2].q_order, vec![5, 6, 7, 4, 1, 2, 3]);
    }

    #[test]
    fn folded_chain_b_bottom_up() {
        let s = symmetric_shift(&ProblemSpec::square(8, 1, MaskSpec::causal()));
        // SM 2 / chain B = kv 5: q = 7, 6, 5.
        let b = &s.chains[5];
        assert_eq!(b.kv, 5);
        assert_eq!(b.q_order, vec![7, 6, 5]);
    }

    #[test]
    fn odd_n_fallback_is_valid_and_balanced() {
        let s = symmetric_shift(&ProblemSpec::square(7, 2, MaskSpec::causal()));
        validate(&s).unwrap();
        let mut per_sm = std::collections::HashMap::new();
        for (i, c) in s.chains.iter().enumerate().filter(|(_, c)| c.head == 0) {
            *per_sm.entry(s.placement(i, 7).unwrap()).or_insert(0usize) += c.len();
        }
        let max = *per_sm.values().max().unwrap();
        // Longest-with-shortest pairing keeps every SM within one longest
        // chain of the perfect split.
        assert!(max <= 7 + 1, "fallback imbalance: {per_sm:?}");
        // And every live tile is covered exactly once (validate above).
    }

    #[test]
    fn rectangular_causal_fallback_validates_and_simulates() {
        use crate::sim::{simulate, SimConfig};
        for (n_kv, n_q) in [(6usize, 3usize), (3, 6), (5, 8)] {
            let spec =
                ProblemSpec { n_kv, n_q, n_heads: 2, mask: MaskSpec::causal() };
            let s = symmetric_shift(&spec);
            validate(&s).unwrap();
            let r = simulate(&s, &SimConfig::ideal(n_kv.max(2))).unwrap();
            assert_eq!(r.n_tasks, s.total_tasks());
        }
    }

    #[test]
    fn sliding_window_and_document_masks_validate() {
        for mask in [MaskSpec::sliding_window(2), MaskSpec::document(vec![3, 5])] {
            let spec = ProblemSpec::square(8, 2, mask);
            let s = symmetric_shift(&spec);
            validate(&s).unwrap();
            assert_eq!(s.total_tasks(), spec.total_tiles());
        }
    }

    #[test]
    fn multi_head_alternates_sm_halves() {
        let s = symmetric_shift(&ProblemSpec::square(4, 2, MaskSpec::causal()));
        let head_sms = |h: usize| -> Vec<usize> {
            s.chains
                .iter()
                .enumerate()
                .filter(|(_, c)| c.head == h)
                .map(|(i, _)| s.placement(i, 4).unwrap())
                .collect()
        };
        assert!(head_sms(0).iter().all(|&sm| sm < 2));
        assert!(head_sms(1).iter().all(|&sm| sm >= 2));
    }
}
