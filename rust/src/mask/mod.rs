//! First-class attention-mask layer: the innermost type of the whole
//! scheduling pipeline.
//!
//! Every stage — schedule generators, legality validation, the DAG
//! lower-bound oracle, the simulator workload, the autotune cache key, the
//! figure harnesses, and the CLI — consumes the mask through this one
//! interface. A mask answers exactly four questions about an
//! `n_kv x n_q` *tile* grid (block granularity, matching FA3's block
//! skipping: a partially-masked tile is charged as a full tile):
//!
//! * [`MaskSpec::live`] — is tile `(kv, q)` computed?
//! * [`MaskSpec::chain_len`] — how many live Q tiles does KV row `kv` own?
//! * [`MaskSpec::total_tiles`] — how much work is there in total?
//! * [`MaskSpec::name`] / [`MaskSpec::parse`] / [`MaskSpec::fingerprint`] —
//!   a canonical, round-trippable spelling (CLI, cache files) and a
//!   filesystem-safe identity token (autotune cache keys; content-hashed
//!   for data-dependent masks, so two different document layouts can never
//!   share a tuned schedule).
//!
//! ## Rectangular grids and bottom-right alignment
//!
//! `Causal` aligns the diagonal to the *bottom-right* corner of the grid —
//! the FlashAttention/cuDNN convention for `n_kv != n_q`: the last Q tile
//! always sees every KV tile, and earlier Q tiles see proportionally
//! fewer. On square grids this reduces to the familiar `q >= kv`. (The
//! seed's two-variant enum hard-coded `q >= kv`, which silently
//! misaligns every rectangular causal spec — the bug this layer fixes.)
//!
//! ## Supported shapes
//!
//! | spec                    | tile `(kv, q)` live iff                          |
//! |-------------------------|--------------------------------------------------|
//! | `full`                  | always                                           |
//! | `causal[:k]`            | `q - q_diag(kv) >= -k` (bottom-right diagonal)   |
//! | `swa:W`                 | causal and within `W` tiles of the diagonal      |
//! | `doc:b1,b2,...`         | `kv` and `q` fall in the same document           |
//! | `sparse:KxQ:<hex>`      | explicit bitmap bit set                          |

use crate::util::fnv1a_words;
use crate::Result;

/// Attention-mask shape at tile granularity. See the module docs for the
/// liveness rule of each variant.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum MaskSpec {
    /// Every (kv, q) tile is computed — multi-modal / vision / diffusion.
    Full,
    /// Causal mask, bottom-right aligned on rectangular grids. `offset`
    /// shifts the diagonal: positive widens (each Q tile sees `offset`
    /// extra KV tiles), negative narrows. `offset = 0` is standard
    /// causal; on square grids it is `q >= kv`.
    Causal {
        /// Diagonal shift in tiles (0 = standard causal).
        offset: isize,
    },
    /// Sliding-window attention: causal, but each Q tile sees only the
    /// `window` KV tiles ending at its (bottom-right aligned) diagonal.
    SlidingWindow {
        /// Window width in tiles (>= 1; the diagonal tile counts).
        window: usize,
    },
    /// Document / varlen packing: the sequence is a concatenation of
    /// documents and attention never crosses a document boundary
    /// (block-diagonal). `boundaries` are the *sequence*-tile indices
    /// where a new document starts (sorted, deduplicated, non-zero — use
    /// [`MaskSpec::document`] to canonicalize); tiles past the last
    /// boundary form the final document. On rectangular grids both axes
    /// are bottom-right aligned to the `max(n_kv, n_q)`-tile sequence,
    /// matching the causal/sliding-window convention.
    Document {
        /// Sorted, deduplicated, non-zero document start indices (tiles).
        boundaries: Vec<usize>,
    },
    /// Arbitrary block-sparse mask from an explicit live-tile bitmap
    /// (row-major over `n_kv x n_q`). Tiles outside the declared grid are
    /// dead.
    BlockSparse {
        /// KV rows the bitmap describes.
        n_kv: usize,
        /// Q columns the bitmap describes.
        n_q: usize,
        /// Row-major liveness, `bitmap[kv * n_q + q]`.
        bitmap: Vec<bool>,
    },
}

/// Document index of tile `t`: the number of document starts at or before
/// it. Tiles past the last boundary belong to the final document. Counts
/// rather than binary-searches so a non-canonical boundary list (unsorted
/// or duplicated — constructible through the public enum fields) still
/// behaves exactly like its canonical form: duplicates and reordering
/// shift both sides of the same-document comparison equally.
fn doc_of(boundaries: &[usize], t: usize) -> usize {
    boundaries.iter().filter(|&&b| b <= t).count()
}

/// Canonical form of a boundary list: sorted, deduplicated, zeros dropped
/// — what [`MaskSpec::document`] produces and what identity strings
/// (name/fingerprint) must be computed over, so equivalent masks can
/// never spell or key differently.
fn canonical_boundaries(boundaries: &[usize]) -> Vec<usize> {
    let mut b: Vec<usize> = boundaries.iter().copied().filter(|&x| x > 0).collect();
    b.sort_unstable();
    b.dedup();
    b
}

/// Bitmap -> hex nibbles, 4 bits per character, MSB-first, final nibble
/// zero-padded.
fn bitmap_to_hex(bitmap: &[bool]) -> String {
    bitmap
        .chunks(4)
        .map(|c| {
            let mut v = 0u32;
            for (i, &b) in c.iter().enumerate() {
                if b {
                    v |= 1 << (3 - i);
                }
            }
            char::from_digit(v, 16).expect("nibble < 16")
        })
        .collect()
}

/// Inverse of [`bitmap_to_hex`] for a known bitmap length.
fn bitmap_from_hex(s: &str, len: usize) -> Option<Vec<bool>> {
    let mut out = Vec::with_capacity(s.len() * 4);
    for ch in s.chars() {
        let v = ch.to_digit(16)?;
        for i in 0..4 {
            out.push(v & (1 << (3 - i)) != 0);
        }
    }
    if out.len() < len {
        return None;
    }
    if out[len..].iter().any(|&b| b) {
        return None; // padding bits must be zero
    }
    out.truncate(len);
    Some(out)
}

impl MaskSpec {
    /// The full (dense) mask.
    pub const fn full() -> Self {
        MaskSpec::Full
    }

    /// Standard causal mask (offset 0).
    pub const fn causal() -> Self {
        MaskSpec::Causal { offset: 0 }
    }

    /// Causal mask with a shifted diagonal.
    pub const fn causal_with_offset(offset: isize) -> Self {
        MaskSpec::Causal { offset }
    }

    /// Sliding-window mask of `window` tiles (clamped to >= 1).
    pub const fn sliding_window(window: usize) -> Self {
        MaskSpec::SlidingWindow { window: if window == 0 { 1 } else { window } }
    }

    /// Document mask from document start indices (canonicalized: sorted,
    /// deduplicated, zeros dropped — a start at 0 is implicit).
    pub fn document(mut boundaries: Vec<usize>) -> Self {
        boundaries.sort_unstable();
        boundaries.dedup();
        boundaries.retain(|&b| b > 0);
        MaskSpec::Document { boundaries }
    }

    /// Block-sparse mask from an explicit row-major bitmap.
    ///
    /// Panics if `bitmap.len() != n_kv * n_q`.
    pub fn block_sparse(n_kv: usize, n_q: usize, bitmap: Vec<bool>) -> Self {
        assert_eq!(bitmap.len(), n_kv * n_q, "bitmap must cover the declared grid");
        MaskSpec::BlockSparse { n_kv, n_q, bitmap }
    }

    /// Is tile `(kv, q)` live on an `n_kv x n_q` grid? Out-of-grid tiles
    /// are dead.
    pub fn live(&self, kv: usize, q: usize, n_kv: usize, n_q: usize) -> bool {
        if kv >= n_kv || q >= n_q {
            return false;
        }
        match self {
            MaskSpec::Full => true,
            MaskSpec::Causal { offset } => {
                // Bottom-right aligned diagonal: Q tile q's last visible
                // KV tile is q + (n_kv - n_q) (+ offset). i128 arithmetic
                // so no parseable offset/grid can overflow (isize::MIN
                // from the CLI must be a wrong answer, never a wrapped
                // garbage mask).
                q as i128 >= kv as i128 + (n_q as i128 - n_kv as i128) - *offset as i128
            }
            MaskSpec::SlidingWindow { window } => {
                let diag = q as i128 + n_kv as i128 - n_q as i128;
                let d = diag - kv as i128;
                d >= 0 && d < (*window).max(1) as i128
            }
            MaskSpec::Document { boundaries } => {
                // Bottom-right aligned like Causal/SlidingWindow: on a
                // rectangular grid both axes cover the *trailing* tiles of
                // the max(n_kv, n_q)-tile sequence, so boundaries index
                // sequence tiles, not raw axis tiles.
                let n = n_kv.max(n_q);
                doc_of(boundaries, kv + (n - n_kv)) == doc_of(boundaries, q + (n - n_q))
            }
            MaskSpec::BlockSparse { n_kv: bkv, n_q: bq, bitmap } => {
                kv < *bkv && q < *bq && bitmap[kv * bq + q]
            }
        }
    }

    /// Number of live Q tiles for KV row `kv` on an `n_kv x n_q` grid.
    pub fn chain_len(&self, kv: usize, n_kv: usize, n_q: usize) -> usize {
        match self {
            MaskSpec::Full => {
                if kv < n_kv {
                    n_q
                } else {
                    0
                }
            }
            _ => (0..n_q).filter(|&q| self.live(kv, q, n_kv, n_q)).count(),
        }
    }

    /// Live Q tiles of KV row `kv` in ascending order.
    pub fn live_q(&self, kv: usize, n_kv: usize, n_q: usize) -> Vec<usize> {
        (0..n_q).filter(|&q| self.live(kv, q, n_kv, n_q)).collect()
    }

    /// Total live tiles on an `n_kv x n_q` grid.
    pub fn total_tiles(&self, n_kv: usize, n_q: usize) -> usize {
        (0..n_kv).map(|kv| self.chain_len(kv, n_kv, n_q)).sum()
    }

    /// Canonical spelling — the CLI/cache-file format; round-trips
    /// through [`MaskSpec::parse`] for canonically-constructed masks.
    pub fn name(&self) -> String {
        match self {
            MaskSpec::Full => "full".into(),
            MaskSpec::Causal { offset: 0 } => "causal".into(),
            MaskSpec::Causal { offset } => format!("causal:{offset}"),
            MaskSpec::SlidingWindow { window } => format!("swa:{window}"),
            MaskSpec::Document { boundaries } => {
                let canon = canonical_boundaries(boundaries);
                if canon.is_empty() {
                    // Canonical spelling for the boundary-free (single
                    // document) mask — "doc:" stays a parse error (typo
                    // guard) and this must round-trip for cache decode.
                    return "doc:-".into();
                }
                let list: Vec<String> = canon.iter().map(ToString::to_string).collect();
                format!("doc:{}", list.join(","))
            }
            MaskSpec::BlockSparse { n_kv, n_q, bitmap } => {
                format!("sparse:{n_kv}x{n_q}:{}", bitmap_to_hex(bitmap))
            }
        }
    }

    /// Inverse of [`MaskSpec::name`]. Accepts `full`, `causal`,
    /// `causal:<offset>`, `swa:<window>`, `doc:<b1,b2,...>`, and
    /// `sparse:<kv>x<q>:<hex>`. Returns `None` for anything else (the CLI
    /// layers file loading on top via [`resolve`]).
    ///
    /// ```
    /// use dash::mask::MaskSpec;
    ///
    /// assert_eq!(MaskSpec::parse("causal"), Some(MaskSpec::causal()));
    /// assert_eq!(MaskSpec::parse("swa:4"), Some(MaskSpec::sliding_window(4)));
    /// let doc = MaskSpec::parse("doc:3,5").unwrap();
    /// assert_eq!(doc, MaskSpec::document(vec![3, 5]));
    /// assert_eq!(MaskSpec::parse(&doc.name()), Some(doc)); // round-trips
    /// assert_eq!(MaskSpec::parse("swa:0"), None); // zero-width window
    /// ```
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => return Some(MaskSpec::full()),
            "causal" => return Some(MaskSpec::causal()),
            _ => {}
        }
        if let Some(rest) = s.strip_prefix("causal:") {
            return rest.parse::<isize>().ok().map(|offset| MaskSpec::Causal { offset });
        }
        if let Some(rest) = s.strip_prefix("swa:") {
            return rest
                .parse::<usize>()
                .ok()
                .filter(|&w| w >= 1)
                .map(|window| MaskSpec::SlidingWindow { window });
        }
        if let Some(rest) = s.strip_prefix("doc:") {
            if rest == "-" {
                return Some(MaskSpec::document(Vec::new()));
            }
            let mut boundaries = Vec::new();
            for tok in rest.split(',') {
                boundaries.push(tok.trim().parse::<usize>().ok()?);
            }
            if boundaries.is_empty() {
                return None;
            }
            return Some(MaskSpec::document(boundaries));
        }
        if let Some(rest) = s.strip_prefix("sparse:") {
            let (dims, hex) = rest.split_once(':')?;
            let (a, b) = dims.split_once('x')?;
            let n_kv: usize = a.parse().ok()?;
            let n_q: usize = b.parse().ok()?;
            let bitmap = bitmap_from_hex(hex, n_kv.checked_mul(n_q)?)?;
            return Some(MaskSpec::BlockSparse { n_kv, n_q, bitmap });
        }
        None
    }

    /// The document segments of a [`MaskSpec::Document`] mask on an
    /// `n`-tile (square) sequence, as half-open `(start, end)` tile
    /// ranges in sequence order. `None` for every other shape. Boundaries
    /// at or past `n` are ignored (they start no segment inside the
    /// grid); a boundary-free mask is the single segment `(0, n)`.
    ///
    /// This is the extraction surface for per-request slicing: the trace
    /// batch compiler lays requests out as documents, and the batch
    /// oracle pulls each request's gradient rows back out through these
    /// ranges.
    pub fn document_segments(&self, n: usize) -> Option<Vec<(usize, usize)>> {
        let MaskSpec::Document { boundaries } = self else { return None };
        if n == 0 {
            return Some(Vec::new());
        }
        let canon = canonical_boundaries(boundaries);
        let mut starts = vec![0usize];
        starts.extend(canon.into_iter().filter(|&b| b < n));
        let mut out = Vec::with_capacity(starts.len());
        for (i, &s) in starts.iter().enumerate() {
            let e = starts.get(i + 1).copied().unwrap_or(n);
            out.push((s, e));
        }
        Some(out)
    }

    /// Filesystem-safe identity token for cache keys (alphanumeric, `-`,
    /// `x` only). Parameter-free shapes spell themselves; data-dependent
    /// shapes (document boundaries, sparse bitmaps) are content-hashed, so
    /// distinct layouts always key distinctly.
    pub fn fingerprint(&self) -> String {
        match self {
            MaskSpec::Full => "full".into(),
            MaskSpec::Causal { offset: 0 } => "causal".into(),
            MaskSpec::Causal { offset } if *offset > 0 => format!("causal-p{offset}"),
            MaskSpec::Causal { offset } => format!("causal-m{}", offset.unsigned_abs()),
            MaskSpec::SlidingWindow { window } => format!("swa{window}"),
            MaskSpec::Document { boundaries } => {
                let canon = canonical_boundaries(boundaries);
                let h = fnv1a_words(canon.iter().map(|&b| b as u64));
                format!("doc-{h:016x}")
            }
            MaskSpec::BlockSparse { n_kv, n_q, bitmap } => {
                let h = fnv1a_words(bitmap.iter().map(|&b| b as u64));
                format!("bs{n_kv}x{n_q}-{h:016x}")
            }
        }
    }
}

/// CLI-facing resolver: [`MaskSpec::parse`] first; a `doc:<path>` whose
/// payload is not an inline boundary list is read from disk (one boundary
/// list, comma- or whitespace-separated tile indices).
pub fn resolve(arg: &str) -> Result<MaskSpec> {
    if let Some(m) = MaskSpec::parse(arg) {
        return Ok(m);
    }
    if let Some(path) = arg.strip_prefix("doc:") {
        if std::path::Path::new(path).exists() {
            let text = std::fs::read_to_string(path)?;
            let mut boundaries = Vec::new();
            for tok in text.split(|c: char| c == ',' || c.is_whitespace()) {
                if tok.is_empty() {
                    continue;
                }
                boundaries.push(tok.parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("bad document boundary '{tok}' in {path}")
                })?);
            }
            if boundaries.is_empty() {
                anyhow::bail!("document boundary file {path} is empty");
            }
            return Ok(MaskSpec::document(boundaries));
        }
        anyhow::bail!(
            "mask 'doc:{path}': neither an inline boundary list nor a readable file"
        );
    }
    anyhow::bail!(
        "unknown mask '{arg}' (expected full | causal[:offset] | swa:<window> | \
         doc:<b1,b2,...|file> | sparse:<kv>x<q>:<hex>)"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn square_causal_matches_the_classic_rule() {
        let m = MaskSpec::causal();
        for kv in 0..6 {
            for q in 0..6 {
                assert_eq!(m.live(kv, q, 6, 6), q >= kv, "({kv},{q})");
            }
        }
    }

    #[test]
    fn rectangular_causal_is_bottom_right_aligned() {
        let m = MaskSpec::causal();
        // Decode-style grid: more KV than Q. The LAST Q tile sees every
        // KV tile; the first sees only the leading n_kv - n_q + 1.
        let (n_kv, n_q) = (8, 4);
        assert!((0..n_kv).all(|kv| m.live(kv, n_q - 1, n_kv, n_q)));
        assert_eq!(m.chain_len(0, n_kv, n_q), n_q);
        assert_eq!(m.chain_len(7, n_kv, n_q), 1); // only q = 3
        assert!(!m.live(5, 0, n_kv, n_q));
        assert!(m.live(4, 0, n_kv, n_q));
        // Tall grid: more Q than KV — the top Q rows see nothing.
        let (n_kv, n_q) = (4, 8);
        assert_eq!(m.chain_len(0, n_kv, n_q), 4); // q >= 4
        assert_eq!(m.chain_len(3, n_kv, n_q), 1); // q = 7 only
        assert!(!m.live(0, 3, n_kv, n_q));
        assert!(m.live(0, 4, n_kv, n_q));
        assert!((0..n_kv).all(|kv| m.live(kv, n_q - 1, n_kv, n_q)));
    }

    #[test]
    fn causal_offset_shifts_the_diagonal() {
        let wide = MaskSpec::causal_with_offset(1);
        assert!(wide.live(1, 0, 4, 4)); // one tile above the diagonal
        assert!(!wide.live(2, 0, 4, 4));
        let narrow = MaskSpec::causal_with_offset(-1);
        assert!(!narrow.live(2, 2, 4, 4)); // diagonal itself is masked
        assert!(narrow.live(1, 2, 4, 4));
    }

    #[test]
    fn sliding_window_bands_the_diagonal() {
        let m = MaskSpec::sliding_window(2);
        // Square 6x6: row q sees kv in {q-1, q}.
        assert!(m.live(3, 3, 6, 6));
        assert!(m.live(2, 3, 6, 6));
        assert!(!m.live(1, 3, 6, 6));
        assert!(!m.live(4, 3, 6, 6));
        assert_eq!(m.chain_len(0, 6, 6), 2); // q = 0, 1
        assert_eq!(m.chain_len(5, 6, 6), 1); // q = 5 only
        assert_eq!(m.total_tiles(6, 6), 11); // 6 diagonal + 5 sub-diagonal
    }

    #[test]
    fn sliding_window_window_one_is_the_diagonal() {
        let m = MaskSpec::sliding_window(1);
        assert_eq!(m.total_tiles(5, 5), 5);
        assert!((0..5).all(|i| m.live(i, i, 5, 5)));
    }

    #[test]
    fn document_mask_is_block_diagonal() {
        // Docs: tiles [0,3), [3,5), [5,8).
        let m = MaskSpec::document(vec![3, 5]);
        assert!(m.live(0, 2, 8, 8));
        assert!(!m.live(0, 3, 8, 8));
        assert!(m.live(3, 4, 8, 8));
        assert!(!m.live(4, 5, 8, 8));
        assert!(m.live(6, 7, 8, 8));
        assert_eq!(m.total_tiles(8, 8), 9 + 4 + 9);
    }

    #[test]
    fn rectangular_document_mask_is_bottom_right_aligned() {
        // 8-tile sequence split at tile 4; the 4 Q tiles are the trailing
        // sequence tiles (bottom-right convention), so every Q tile lives
        // in document 1 and must never see the first document's KV tiles.
        let m = MaskSpec::document(vec![4]);
        let (n_kv, n_q) = (8, 4);
        for q in 0..n_q {
            for kv in 0..4 {
                assert!(!m.live(kv, q, n_kv, n_q), "({kv},{q}) crosses the boundary");
            }
            for kv in 4..8 {
                assert!(m.live(kv, q, n_kv, n_q), "({kv},{q}) must be live");
            }
        }
        // Transposed grid: the 4 KV tiles are the trailing sequence tiles.
        let (n_kv, n_q) = (4, 8);
        for kv in 0..n_kv {
            for q in 0..4 {
                assert!(!m.live(kv, q, n_kv, n_q));
            }
            for q in 4..8 {
                assert!(m.live(kv, q, n_kv, n_q));
            }
        }
    }

    #[test]
    fn boundary_free_document_round_trips_as_doc_dash() {
        // `doc:0` canonicalizes to no boundaries; its spelling must still
        // round-trip (cache decode depends on it).
        let m = MaskSpec::document(vec![0]);
        assert_eq!(m, MaskSpec::Document { boundaries: vec![] });
        assert_eq!(m.name(), "doc:-");
        assert_eq!(MaskSpec::parse("doc:-"), Some(m.clone()));
        assert_eq!(MaskSpec::parse(&m.name()), Some(m.clone()));
        assert_eq!(MaskSpec::parse("doc:0"), Some(m));
        assert_eq!(MaskSpec::parse("doc:"), None);
    }

    #[test]
    fn document_constructor_canonicalizes() {
        assert_eq!(
            MaskSpec::document(vec![5, 0, 3, 5]),
            MaskSpec::Document { boundaries: vec![3, 5] }
        );
    }

    #[test]
    fn non_canonical_document_fields_behave_like_their_canonical_form() {
        // The variant fields are public, so a raw unsorted/duplicated
        // boundary list is constructible; liveness, spelling, and cache
        // fingerprints must all match the canonical mask.
        let raw = MaskSpec::Document { boundaries: vec![5, 3, 0, 5] };
        let canon = MaskSpec::document(vec![3, 5]);
        for kv in 0..8 {
            for q in 0..8 {
                assert_eq!(raw.live(kv, q, 8, 8), canon.live(kv, q, 8, 8), "({kv},{q})");
            }
        }
        assert_eq!(raw.name(), canon.name());
        assert_eq!(raw.fingerprint(), canon.fingerprint());
        assert_eq!(MaskSpec::parse(&raw.name()), Some(canon));
    }

    #[test]
    fn block_sparse_reads_the_bitmap() {
        let m = MaskSpec::block_sparse(2, 3, vec![true, false, true, false, true, false]);
        assert!(m.live(0, 0, 2, 3));
        assert!(!m.live(0, 1, 2, 3));
        assert!(m.live(1, 1, 2, 3));
        assert!(!m.live(1, 2, 2, 3));
        assert_eq!(m.total_tiles(2, 3), 3);
        // Tiles outside the declared bitmap grid are dead.
        assert!(!m.live(2, 0, 4, 4));
    }

    #[test]
    fn out_of_grid_tiles_are_dead_for_every_shape() {
        for m in [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::sliding_window(3),
            MaskSpec::document(vec![2]),
        ] {
            assert!(!m.live(4, 0, 4, 4));
            assert!(!m.live(0, 4, 4, 4));
        }
    }

    #[test]
    fn chain_len_agrees_with_live_counts() {
        let masks = [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::causal_with_offset(2),
            MaskSpec::sliding_window(3),
            MaskSpec::document(vec![2, 5]),
        ];
        for m in &masks {
            for (n_kv, n_q) in [(4usize, 4usize), (4, 7), (7, 4)] {
                let mut total = 0;
                for kv in 0..n_kv {
                    let by_live = (0..n_q).filter(|&q| m.live(kv, q, n_kv, n_q)).count();
                    assert_eq!(m.chain_len(kv, n_kv, n_q), by_live, "{m:?} kv={kv}");
                    total += by_live;
                }
                assert_eq!(m.total_tiles(n_kv, n_q), total, "{m:?}");
            }
        }
    }

    #[test]
    fn names_round_trip_through_parse() {
        let masks = [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::causal_with_offset(2),
            MaskSpec::causal_with_offset(-1),
            MaskSpec::sliding_window(4),
            MaskSpec::document(vec![3, 5, 9]),
            MaskSpec::block_sparse(2, 3, vec![true, false, true, true, false, false]),
        ];
        for m in &masks {
            assert_eq!(MaskSpec::parse(&m.name()).as_ref(), Some(m), "{}", m.name());
        }
        assert_eq!(MaskSpec::parse("diagonal"), None);
        assert_eq!(MaskSpec::parse("swa:0"), None);
        assert_eq!(MaskSpec::parse("doc:"), None);
        assert_eq!(MaskSpec::parse("sparse:2x2:zz"), None);
    }

    #[test]
    fn fingerprints_are_filesystem_safe_and_content_distinct() {
        let masks = [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::causal_with_offset(-2),
            MaskSpec::sliding_window(8),
            MaskSpec::document(vec![3, 5]),
            MaskSpec::document(vec![3, 6]),
            MaskSpec::block_sparse(2, 2, vec![true, false, false, true]),
            MaskSpec::block_sparse(2, 2, vec![true, true, false, true]),
        ];
        let fps: Vec<String> = masks.iter().map(MaskSpec::fingerprint).collect();
        for fp in &fps {
            assert!(
                fp.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == 'x'),
                "{fp}"
            );
        }
        let mut dedup = fps.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), fps.len(), "fingerprints must be distinct: {fps:?}");
    }

    #[test]
    fn document_segments_partition_the_sequence() {
        let m = MaskSpec::document(vec![3, 5]);
        assert_eq!(m.document_segments(8), Some(vec![(0, 3), (3, 5), (5, 8)]));
        // Boundary-free: one segment covering everything.
        assert_eq!(MaskSpec::document(vec![]).document_segments(6), Some(vec![(0, 6)]));
        // Boundaries at or past n start nothing inside the grid.
        assert_eq!(m.document_segments(4), Some(vec![(0, 3), (3, 4)]));
        assert_eq!(m.document_segments(3), Some(vec![(0, 3)]));
        assert_eq!(m.document_segments(0), Some(vec![]));
        // Non-canonical public-field construction matches the canonical form.
        let raw = MaskSpec::Document { boundaries: vec![5, 3, 0, 5] };
        assert_eq!(raw.document_segments(8), m.document_segments(8));
        // Non-document shapes have no segments.
        assert_eq!(MaskSpec::full().document_segments(8), None);
        assert_eq!(MaskSpec::causal().document_segments(8), None);
        // Segments always tile [0, n) exactly.
        for segs in [m.document_segments(8).unwrap(), m.document_segments(4).unwrap()] {
            let mut cursor = 0;
            for (s, e) in segs {
                assert_eq!(s, cursor);
                assert!(e > s);
                cursor = e;
            }
        }
    }

    #[test]
    fn resolve_reads_document_files() {
        let path = std::env::temp_dir()
            .join(format!("dash-maskdoc-{}.txt", std::process::id()));
        std::fs::write(&path, "3, 5\n9").unwrap();
        let m = resolve(&format!("doc:{}", path.display())).unwrap();
        assert_eq!(m, MaskSpec::document(vec![3, 5, 9]));
        let _ = std::fs::remove_file(&path);
        assert!(resolve("doc:/definitely/not/a/file").is_err());
        assert!(resolve("nonsense").is_err());
        assert_eq!(resolve("swa:4").unwrap(), MaskSpec::sliding_window(4));
    }
}
