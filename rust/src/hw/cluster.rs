//! Cluster description: N GPUs plus the interconnect between them, as a
//! first-class, swappable input — the multi-device analogue of
//! [`GpuProfile`].
//!
//! A [`ClusterProfile`] bundles the per-device GPU profiles with a
//! [`LinkModel`] (NVLink / InfiniBand bandwidth + latency presets, or a
//! calibrated custom link) and serializes to JSON exactly like
//! [`GpuProfile`] does (`dash hw --export-cluster`, `--cluster <path>`).
//! Clusters are homogeneous by default: mixing different GPU profiles is
//! rejected by [`ClusterProfile::validate`] unless `allow_mixed` is set
//! explicitly in the profile JSON, because a heterogeneous cluster changes
//! every load-balance assumption the sharding strategies make.
//!
//! The CLI resolves `--cluster` arguments through [`resolve_cluster`]:
//! `<link>:<n>x<gpu>` (e.g. `nvlink:2xh800`, `ib:4xa100`),
//! `abstract:<n>` for the paper's unit-cost machine over an ideal link,
//! or a path to a cluster-profile JSON.

use super::presets;
use super::profile::GpuProfile;
use crate::util::{fnv1a_words, Json};
use crate::Result;
use std::path::Path;

/// On-disk format version for cluster-profile JSON.
const FORMAT_VERSION: f64 = 1.0;

/// Interconnect model between devices: sustained-effective per-direction
/// bandwidth and one-way latency. `bandwidth_gbps == 0 && latency_us == 0`
/// is the *ideal-link* sentinel (the abstract machine's interconnect:
/// every hop costs one cycle).
#[derive(Debug, Clone, PartialEq)]
pub struct LinkModel {
    /// Preset name (`nvlink` / `ib` / `ideal`) or a custom label.
    pub name: String,
    /// Sustained per-direction bandwidth in GB/s (0 = ideal sentinel).
    pub bandwidth_gbps: f64,
    /// One-way latency in microseconds (0 = ideal sentinel).
    pub latency_us: f64,
}

/// Built-in link preset names accepted by [`LinkModel::preset`] and the
/// `--cluster` grammar.
pub const LINK_PRESET_NAMES: [&str; 3] = ["nvlink", "ib", "ideal"];

impl LinkModel {
    /// Intra-node NVLink (NVLink4-class): ~400 GB/s sustained per
    /// direction, ~2 us one-way software latency.
    pub fn nvlink() -> Self {
        Self { name: "nvlink".into(), bandwidth_gbps: 400.0, latency_us: 2.0 }
    }

    /// Inter-node InfiniBand (NDR-class NIC per GPU): ~50 GB/s sustained,
    /// ~5 us one-way latency.
    pub fn infiniband() -> Self {
        Self { name: "ib".into(), bandwidth_gbps: 50.0, latency_us: 5.0 }
    }

    /// The ideal link: every hop costs one abstract cycle, matching the
    /// paper's unit-cost machine model.
    pub fn ideal() -> Self {
        Self { name: "ideal".into(), bandwidth_gbps: 0.0, latency_us: 0.0 }
    }

    /// Is this the ideal-link sentinel?
    pub fn is_ideal(&self) -> bool {
        self.bandwidth_gbps == 0.0 && self.latency_us == 0.0
    }

    /// Look up a built-in link preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "nvlink" => Some(Self::nvlink()),
            "ib" | "infiniband" => Some(Self::infiniband()),
            "ideal" => Some(Self::ideal()),
            _ => None,
        }
    }

    /// Sanity checks: finite, non-negative; a non-ideal link needs strictly
    /// positive bandwidth *and* latency (a zero in one field only is a
    /// half-written sentinel, not a physical link).
    pub fn validate(&self) -> std::result::Result<(), String> {
        if !self.bandwidth_gbps.is_finite() || !self.latency_us.is_finite() {
            return Err(format!("link '{}': non-finite bandwidth/latency", self.name));
        }
        if self.bandwidth_gbps < 0.0 || self.latency_us < 0.0 {
            return Err(format!("link '{}': negative bandwidth/latency", self.name));
        }
        if !self.is_ideal() && (self.bandwidth_gbps == 0.0 || self.latency_us == 0.0) {
            return Err(format!(
                "link '{}': a concrete link needs bandwidth > 0 and latency > 0 \
                 (set both to 0 for the ideal-link sentinel)",
                self.name
            ));
        }
        Ok(())
    }
}

/// A cluster: per-device GPU profiles plus the interconnect between them.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterProfile {
    /// Cluster name (used in messages and fingerprint-keyed cache paths).
    pub name: String,
    /// One [`GpuProfile`] per device, device index = position.
    pub devices: Vec<GpuProfile>,
    /// Interconnect between the devices.
    pub link: LinkModel,
    /// Explicit opt-in for heterogeneous clusters (mixed GPU profiles).
    /// Off by default: mixed clusters break the sharding strategies'
    /// load-balance assumptions, so they must be requested in the profile
    /// JSON, never inferred.
    pub allow_mixed: bool,
}

impl ClusterProfile {
    /// Homogeneous cluster: `n_devices` copies of one GPU profile.
    pub fn uniform(name: &str, n_devices: usize, gpu: GpuProfile, link: LinkModel) -> Self {
        Self {
            name: name.to_string(),
            devices: vec![gpu; n_devices],
            link,
            allow_mixed: false,
        }
    }

    /// Number of devices in the cluster.
    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Sanity checks: at least one device, every device profile valid, the
    /// link valid, and — unless `allow_mixed` — all devices identical
    /// (by [`GpuProfile::fingerprint`]).
    pub fn validate(&self) -> std::result::Result<(), String> {
        if self.devices.is_empty() {
            return Err(format!("cluster '{}': no devices", self.name));
        }
        for (i, d) in self.devices.iter().enumerate() {
            d.validate()
                .map_err(|e| format!("cluster '{}' device {i}: {e}", self.name))?;
        }
        self.link.validate().map_err(|e| format!("cluster '{}': {e}", self.name))?;
        if !self.allow_mixed {
            let first = self.devices[0].fingerprint();
            if let Some(i) =
                (1..self.devices.len()).find(|&i| self.devices[i].fingerprint() != first)
            {
                return Err(format!(
                    "cluster '{}' mixes GPU profiles ('{}' at device 0 vs '{}' at \
                     device {i}); heterogeneous clusters need \"allow_mixed\": true \
                     in the profile JSON",
                    self.name, self.devices[0].name, self.devices[i].name
                ));
            }
        }
        Ok(())
    }

    /// Stable identity for cache keying, the cluster analogue of
    /// [`GpuProfile::fingerprint`]: 0 for the fully-abstract cluster
    /// (all-abstract devices over the ideal link, the paper's machine
    /// model), an FNV-1a fold of device count + per-device fingerprints +
    /// link bits otherwise. Append-only: new fields must fold *after* the
    /// existing ones.
    pub fn fingerprint(&self) -> u64 {
        let abstract_cluster =
            self.devices.iter().all(GpuProfile::is_abstract) && self.link.is_ideal();
        if abstract_cluster {
            return 0;
        }
        let mut words = vec![self.devices.len() as u64];
        words.extend(self.devices.iter().map(GpuProfile::fingerprint));
        words.push(self.link.bandwidth_gbps.to_bits());
        words.push(self.link.latency_us.to_bits());
        fnv1a_words(words)
    }

    /// Cost in device-0 clock cycles of one interconnect hop carrying a
    /// KV tile's dK/dV partial pair (`2 * block * head_dim` bf16 elements,
    /// 2 bytes each): one-way latency plus serialization time. The ideal
    /// link (or a fully-abstract cluster) costs the paper's unit hop, 1.0.
    pub fn hop_cycles(&self, block: usize, head_dim: usize) -> f64 {
        let clock = self.devices.first().map_or(0.0, |d| d.clock_ghz);
        if self.link.is_ideal() || clock <= 0.0 {
            return 1.0;
        }
        let bytes = (2 * block * head_dim * 2) as f64;
        // clock [GHz] = cycles/ns; latency_us * 1000 = ns; bandwidth
        // [GB/s] = bytes/ns.
        let latency_cycles = self.link.latency_us * 1000.0 * clock;
        let transfer_cycles = bytes / self.link.bandwidth_gbps * clock;
        latency_cycles + transfer_cycles
    }

    /// Serialize to the cluster-profile JSON document.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("version".into(), Json::Num(FORMAT_VERSION)),
            ("name".into(), Json::Str(self.name.clone())),
            (
                "devices".into(),
                Json::Arr(self.devices.iter().map(GpuProfile::to_json).collect()),
            ),
            (
                "link".into(),
                Json::Obj(vec![
                    ("name".into(), Json::Str(self.link.name.clone())),
                    ("bandwidth_gbps".into(), Json::Num(self.link.bandwidth_gbps)),
                    ("latency_us".into(), Json::Num(self.link.latency_us)),
                ]),
            ),
            ("allow_mixed".into(), Json::Bool(self.allow_mixed)),
        ])
    }

    /// Decode a cluster-profile JSON document (strict: missing fields and
    /// invalid clusters are errors, mirroring [`GpuProfile::from_json`]).
    pub fn from_json(doc: &Json) -> Result<ClusterProfile> {
        let version = doc.get("version").and_then(Json::as_f64).unwrap_or(FORMAT_VERSION);
        if version != FORMAT_VERSION {
            anyhow::bail!("unsupported cluster-profile format version {version}");
        }
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("cluster JSON missing string field 'name'"))?
            .to_string();
        let devices = doc
            .get("devices")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("cluster JSON missing array field 'devices'"))?
            .iter()
            .map(GpuProfile::from_json)
            .collect::<Result<Vec<_>>>()?;
        let link_doc = doc
            .get("link")
            .ok_or_else(|| anyhow::anyhow!("cluster JSON missing object field 'link'"))?;
        let link_num = |key: &str| -> Result<f64> {
            link_doc.get(key).and_then(Json::as_f64).ok_or_else(|| {
                anyhow::anyhow!("cluster JSON missing numeric link field '{key}'")
            })
        };
        let link = LinkModel {
            name: link_doc
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("cluster JSON missing link field 'name'"))?
                .to_string(),
            bandwidth_gbps: link_num("bandwidth_gbps")?,
            latency_us: link_num("latency_us")?,
        };
        let allow_mixed = matches!(doc.get("allow_mixed"), Some(Json::Bool(true)));
        let profile = ClusterProfile { name, devices, link, allow_mixed };
        profile.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(profile)
    }

    /// Write the cluster profile to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    /// Read a cluster profile from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<ClusterProfile> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read cluster '{}': {e}", path.display()))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad cluster JSON '{}': {e:#}", path.display()))?;
        Self::from_json(&doc)
            .map_err(|e| anyhow::anyhow!("bad cluster '{}': {e:#}", path.display()))
    }
}

/// Resolve a `--cluster` argument:
///
/// * `<link>:<n>x<gpu>` — a homogeneous preset cluster, e.g.
///   `nvlink:2xh800`, `ib:4xa100` (any GPU preset name works);
/// * `abstract:<n>` — `n` abstract machines over the ideal link (the
///   paper's machine model at cluster scale);
/// * otherwise a path to a cluster-profile JSON written by
///   [`ClusterProfile::save`] / `dash hw --export-cluster`.
pub fn resolve_cluster(arg: &str) -> Result<ClusterProfile> {
    if let Some((link_name, rest)) = arg.split_once(':') {
        if link_name == "abstract" {
            if let Ok(n) = rest.parse::<usize>() {
                if n == 0 {
                    anyhow::bail!("cluster 'abstract:{n}': need at least one device");
                }
                let profile = ClusterProfile::uniform(
                    arg,
                    n,
                    presets::abstract_machine(),
                    LinkModel::ideal(),
                );
                profile.validate().map_err(|e| anyhow::anyhow!(e))?;
                return Ok(profile);
            }
        } else if let Some(link) = LinkModel::preset(link_name) {
            if let Some((count, gpu_name)) = rest.split_once('x') {
                if let Ok(n) = count.parse::<usize>() {
                    if n == 0 {
                        anyhow::bail!("cluster '{arg}': need at least one device");
                    }
                    let gpu = presets::resolve(gpu_name)?;
                    let profile = ClusterProfile::uniform(arg, n, gpu, link);
                    profile.validate().map_err(|e| anyhow::anyhow!(e))?;
                    return Ok(profile);
                }
            }
            anyhow::bail!(
                "bad cluster spec '{arg}' — expected '{link_name}:<n>x<gpu>' \
                 (e.g. '{link_name}:2xh800')"
            );
        }
    }
    if Path::new(arg).exists() {
        return ClusterProfile::load(arg);
    }
    anyhow::bail!(
        "unknown cluster '{arg}' — expected '<link>:<n>x<gpu>' with link in {} \
         (e.g. 'nvlink:2xh800'), 'abstract:<n>', or a cluster-profile JSON path",
        LINK_PRESET_NAMES.join("|")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_cluster_validates_and_fingerprints() {
        let c = ClusterProfile::uniform("c", 4, presets::h800(), LinkModel::nvlink());
        c.validate().unwrap();
        assert_eq!(c.n_devices(), 4);
        assert_ne!(c.fingerprint(), 0);
        // Fingerprint keys on the device count and the link.
        let c2 = ClusterProfile::uniform("c", 2, presets::h800(), LinkModel::nvlink());
        assert_ne!(c.fingerprint(), c2.fingerprint());
        let mut c3 = c.clone();
        c3.link = LinkModel::infiniband();
        assert_ne!(c.fingerprint(), c3.fingerprint());
    }

    #[test]
    fn abstract_cluster_fingerprints_zero() {
        let c = ClusterProfile::uniform(
            "abs",
            4,
            presets::abstract_machine(),
            LinkModel::ideal(),
        );
        c.validate().unwrap();
        assert_eq!(c.fingerprint(), 0);
        assert_eq!(c.hop_cycles(128, 64), 1.0);
    }

    #[test]
    fn mixed_profiles_need_explicit_opt_in() {
        let mut c = ClusterProfile::uniform("mix", 2, presets::h800(), LinkModel::nvlink());
        c.devices[1] = presets::a100();
        let err = c.validate().unwrap_err();
        assert!(err.contains("allow_mixed"), "{err}");
        c.allow_mixed = true;
        c.validate().unwrap();
    }

    #[test]
    fn concrete_hop_costs_scale_with_latency_and_bandwidth() {
        let nv = ClusterProfile::uniform("nv", 2, presets::h800(), LinkModel::nvlink());
        let ib = ClusterProfile::uniform("ib", 2, presets::h800(), LinkModel::infiniband());
        let hop_nv = nv.hop_cycles(128, 64);
        let hop_ib = ib.hop_cycles(128, 64);
        assert!(hop_nv > 1.0);
        assert!(hop_ib > hop_nv, "IB ({hop_ib}) should cost more than NVLink ({hop_nv})");
        // More payload, more cycles.
        assert!(nv.hop_cycles(256, 64) > hop_nv);
    }

    #[test]
    fn half_written_link_sentinel_is_rejected() {
        let mut link = LinkModel::nvlink();
        link.latency_us = 0.0;
        let c = ClusterProfile::uniform("bad", 2, presets::h800(), link);
        assert!(c.validate().is_err());
    }
}
