//! [`GpuProfile`]: one GPU's capabilities, and the profile-driven builders
//! that turn it into simulator inputs.
//!
//! Everything the simulator, figure harness, and autotuner previously read
//! from hard-coded H800 constants is derived from a profile here. The
//! special "abstract" profile (`n_sm == 0`) is the paper's §3 machine:
//! unit compute cost, `r/c = 0.25`, as many SMs as the workload has KV
//! tiles, no L2 latency, no register spills.

use crate::attention::flops;
use crate::schedule::{MaskSpec, ScheduleKind};
use crate::sim::{CostModel, L2Model, RegisterModel, SimConfig};
use crate::util::fnv1a_words;

/// A GPU's capabilities, as the scheduling stack consumes them.
///
/// All quantities are *sustained-effective* numbers for the FA3-class
/// attention backward (e.g. `flops_per_cycle_per_sm` is the dense BF16
/// tensor-core peak derated to realistic MXU/WGMMA efficiency), because
/// that is what the cost model calibrates against.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    /// Display name (`h800`, `h100`, `a100`, `abstract`, or custom).
    /// Not part of the fingerprint — identity is the numbers.
    pub name: String,
    /// Streaming multiprocessors. `0` means "abstract machine": the width
    /// follows the workload (`n_sm = n_kv`) and unit costs apply.
    pub n_sm: usize,
    /// Sustained SM clock, GHz.
    pub clock_ghz: f64,
    /// Effective BF16 FLOPs per cycle per SM.
    pub flops_per_cycle_per_sm: f64,
    /// L2 cache capacity, bytes.
    pub l2_bytes: usize,
    /// Effective L2 bandwidth per SM for dQ read-modify-write, bytes/cycle.
    pub l2_bytes_per_cycle_per_sm: f64,
    /// Physical L2 locality domains (segmented-L2 signalling model).
    pub l2_segments: usize,
    /// Same-segment signal latency, cycles.
    pub l2_local_latency: f64,
    /// Cross-segment signal latency, cycles.
    pub l2_remote_latency: f64,
    /// Usable shared memory per SM, bytes (drives CTA co-residency).
    pub smem_bytes_per_sm: usize,
    /// Per-thread register allocation limit.
    pub reg_per_thread: u32,
    /// Register file per SM, bytes.
    pub regfile_bytes_per_sm: usize,
}

impl GpuProfile {
    /// The paper's abstract machine (`n_sm = n_kv`, unit costs)?
    pub fn is_abstract(&self) -> bool {
        self.n_sm == 0
    }

    /// Machine width for a workload with `n_kv` KV tiles per head: the
    /// profile's SM count, or `n_kv` on the abstract machine.
    pub fn n_sm_for(&self, n_kv: usize) -> usize {
        if self.is_abstract() {
            n_kv.max(1)
        } else {
            self.n_sm
        }
    }

    /// Whole-machine effective BF16 FLOPs/s (zero on the abstract machine,
    /// which has no physical rate).
    pub fn machine_flops(&self) -> f64 {
        self.n_sm as f64 * self.flops_per_cycle_per_sm * self.clock_ghz * 1e9
    }

    /// Base compute cost of one backward tile, cycles (unit cost on the
    /// abstract machine).
    pub fn compute_cycles(&self, block: usize, head_dim: usize) -> f64 {
        if self.is_abstract() {
            return 1.0;
        }
        flops::bwd_tile_flops(block, head_dim) / self.flops_per_cycle_per_sm
    }

    /// Base reduction cost of one backward tile, cycles: read-modify-write
    /// of a `block x head_dim` fp32 dQ tile through L2 (`r/c = 0.25` with
    /// unit compute on the abstract machine).
    pub fn reduce_cycles(&self, block: usize, head_dim: usize) -> f64 {
        if self.is_abstract() {
            return 0.25;
        }
        let bytes = 2.0 * (block * head_dim * 4) as f64;
        bytes / self.l2_bytes_per_cycle_per_sm
    }

    /// SMEM footprint of one FA3-backward CTA: five bf16 tiles resident
    /// (K, V, Q, dO, and the dQ-writer staging) plus the fp32 S/dS scratch.
    pub fn cta_smem_bytes(block: usize, head_dim: usize) -> usize {
        5 * block * head_dim * 2 + 2 * block * block
    }

    /// Co-resident CTAs per SM for a tile shape, from the SMEM budget
    /// (capped at 2, the FA3 persistent-CTA design point). On the H800/H100
    /// this reproduces the paper's rule — 2 CTAs at headdim <= 64, 1 at
    /// headdim 128 — while the A100's smaller SMEM admits only 1 even at
    /// headdim 64.
    pub fn occupancy(&self, block: usize, head_dim: usize) -> usize {
        if self.is_abstract() {
            return 1;
        }
        (self.smem_bytes_per_sm / Self::cta_smem_bytes(block, head_dim).max(1)).clamp(1, 2)
    }

    /// Heads whose K/V working sets fit in L2 simultaneously — the
    /// interleave width of the L2-aware LPT chain scheduler (§4.3). Full
    /// masks launch head-major (uniform chains give LPT nothing to
    /// balance), so they report width 1; so does the abstract machine,
    /// which has no L2. Every non-uniform mask (causal, sliding-window,
    /// document, sparse) interleaves.
    pub fn head_interleave(&self, seqlen: usize, head_dim: usize, mask: &MaskSpec) -> usize {
        if matches!(mask, MaskSpec::Full) || self.is_abstract() {
            return 1;
        }
        let footprint = seqlen * head_dim * 2 /* K+V */ * 2 /* bf16 */;
        (self.l2_bytes / footprint.max(1)).max(1)
    }

    /// Segmented-L2 signalling model for this part.
    pub fn l2_model(&self) -> L2Model {
        L2Model {
            n_segments: self.l2_segments.max(1),
            local_latency: self.l2_local_latency,
            remote_latency: self.l2_remote_latency,
        }
    }

    /// Register-pressure model for this part (calibration points for the
    /// FA3 backward kernel, limit from the profile).
    pub fn register_model(&self) -> RegisterModel {
        RegisterModel { reg_limit: self.reg_per_thread, ..RegisterModel::default() }
    }

    /// Stable identity hash over every capability number (the name is
    /// excluded: a renamed copy is the same hardware). Folded into the
    /// autotune [`crate::autotune::WorkloadFingerprint`], so schedules
    /// tuned for one part never serve another. The abstract machine
    /// fingerprints as 0 — hand-specified abstract cost models are
    /// hardware-anonymous by design.
    pub fn fingerprint(&self) -> u64 {
        if self.is_abstract() {
            return 0;
        }
        fnv1a_words([
            self.n_sm as u64,
            self.clock_ghz.to_bits(),
            self.flops_per_cycle_per_sm.to_bits(),
            self.l2_bytes as u64,
            self.l2_bytes_per_cycle_per_sm.to_bits(),
            self.l2_segments as u64,
            self.l2_local_latency.to_bits(),
            self.l2_remote_latency.to_bits(),
            self.smem_bytes_per_sm as u64,
            self.reg_per_thread as u64,
            self.regfile_bytes_per_sm as u64,
        ])
    }

    /// Structural sanity: a concrete profile must have positive rates.
    pub fn validate(&self) -> Result<(), String> {
        if self.is_abstract() {
            return Ok(());
        }
        let checks = [
            (self.clock_ghz > 0.0, "clock_ghz must be > 0"),
            (self.flops_per_cycle_per_sm > 0.0, "flops_per_cycle_per_sm must be > 0"),
            (self.l2_bytes_per_cycle_per_sm > 0.0, "l2_bytes_per_cycle_per_sm must be > 0"),
            (self.l2_bytes > 0, "l2_bytes must be > 0"),
            (self.smem_bytes_per_sm > 0, "smem_bytes_per_sm must be > 0"),
            (self.reg_per_thread > 0, "reg_per_thread must be > 0"),
        ];
        for (ok, msg) in checks {
            if !ok {
                return Err(format!("profile '{}': {msg}", self.name));
            }
        }
        Ok(())
    }
}

/// A profile bundled with the hardware-effect models derived from it — the
/// unit the workload runner and figure harness consume. `ideal` keeps the
/// profile's geometry and rates but switches off the two §4 effects
/// (L2 signalling latency, register spills).
#[derive(Debug, Clone)]
pub struct Machine {
    /// The GPU description.
    pub profile: GpuProfile,
    /// Inter-SM signalling model (profile-derived, or ideal).
    pub l2: L2Model,
    /// Register-pressure model (profile-derived, or unlimited).
    pub reg: RegisterModel,
}

impl Machine {
    /// The profile with its real hardware effects.
    pub fn real(profile: GpuProfile) -> Self {
        let l2 = profile.l2_model();
        let reg = profile.register_model();
        Self { profile, l2, reg }
    }

    /// The profile with idealized effects (zero-latency L2, no spills) —
    /// the figure harness's `--ideal` mode.
    pub fn ideal(profile: GpuProfile) -> Self {
        Self { l2: L2Model::ideal(), reg: RegisterModel::unlimited(), profile }
    }

    /// FA3-pipeline simulator configuration for a tile shape on this
    /// machine: async dQ-writer of depth 2, SMEM-derived co-residency,
    /// profile-fingerprinted so tuned-schedule cache keys are
    /// hardware-exact.
    pub fn sim_config(
        &self,
        kind: ScheduleKind,
        n_kv: usize,
        block: usize,
        head_dim: usize,
    ) -> SimConfig {
        let cost = CostModel {
            compute: self.profile.compute_cycles(block, head_dim),
            reduce: self.profile.reduce_cycles(block, head_dim),
            spill_factor: self.reg.spill_factor(kind, head_dim),
            l2: self.l2,
        };
        let mut cfg = SimConfig::fa3_pipeline(
            self.profile.n_sm_for(n_kv),
            cost,
            self.profile.occupancy(block, head_dim),
        );
        cfg.hw_fingerprint = self.profile.fingerprint();
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn h800_reproduces_the_paper_occupancy_rule() {
        let p = presets::h800();
        assert_eq!(p.occupancy(128, 64), 2);
        assert_eq!(p.occupancy(128, 96), 1);
        assert_eq!(p.occupancy(128, 128), 1);
    }

    #[test]
    fn a100_smem_admits_one_cta_even_at_hd64() {
        let p = presets::a100();
        assert_eq!(p.occupancy(128, 64), 1);
    }

    #[test]
    fn abstract_machine_is_the_paper_model() {
        let p = presets::abstract_machine();
        assert!(p.is_abstract());
        assert_eq!(p.n_sm_for(16), 16);
        assert_eq!(p.compute_cycles(128, 128), 1.0);
        assert_eq!(p.reduce_cycles(128, 128), 0.25);
        assert_eq!(p.occupancy(128, 64), 1);
        assert_eq!(p.fingerprint(), 0);
        assert_eq!(p.l2_model().signal_latency(0, 7, 8), 0.0);
    }

    #[test]
    fn fingerprint_ignores_the_name_only() {
        let a = presets::h800();
        let mut renamed = a.clone();
        renamed.name = "my-h800".into();
        assert_eq!(a.fingerprint(), renamed.fingerprint());

        let mut overclocked = a.clone();
        overclocked.clock_ghz *= 1.05;
        assert_ne!(a.fingerprint(), overclocked.fingerprint());

        let mut wider = a.clone();
        wider.n_sm += 1;
        assert_ne!(a.fingerprint(), wider.fingerprint());
    }

    #[test]
    fn compute_cycles_scale_with_head_dim() {
        let p = presets::h800();
        let ratio = p.compute_cycles(128, 128) / p.compute_cycles(128, 64);
        assert!((ratio - 2.0).abs() < 1e-9);
        let r_ratio = p.reduce_cycles(128, 128) / p.reduce_cycles(128, 64);
        assert!((r_ratio - 2.0).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_zero_rates() {
        let mut p = presets::h100();
        assert!(p.validate().is_ok());
        p.clock_ghz = 0.0;
        assert!(p.validate().is_err());
        assert!(presets::abstract_machine().validate().is_ok());
    }

    #[test]
    fn head_interleave_widens_with_l2() {
        let p = presets::h800();
        let narrow = p.head_interleave(16384, 128, &MaskSpec::causal());
        let wide = p.head_interleave(1024, 64, &MaskSpec::causal());
        assert!(wide > narrow);
        assert_eq!(p.head_interleave(1024, 64, &MaskSpec::full()), 1);
    }
}
