//! Hardware-profile layer: the machine description as a first-class,
//! swappable input.
//!
//! The paper's evaluation is calibrated to one part — the NVIDIA H800
//! (132 SMs, 50 MiB L2) — and earlier revisions of this repo inherited
//! that as a constants module every stage reached into. But schedule
//! quality depends on the `n_sm`-vs-`n_kv` regime, and determinism
//! guarantees must survive hardware changes, so the GPU description is now
//! an explicit layer between workload definition and everything downstream:
//!
//! * [`GpuProfile`] — SM count, clock, BF16 FLOPs/cycle/SM, L2 capacity +
//!   bandwidth + segmentation, SMEM/register-file sizes, plus derived
//!   builders for every simulator input ([`crate::sim::CostModel`],
//!   [`crate::sim::L2Model`], [`crate::sim::RegisterModel`], occupancy,
//!   head-interleave width) and a stable [`GpuProfile::fingerprint`] that
//!   keys the autotune schedule cache — an H100-tuned schedule can never
//!   serve an H800 query.
//! * [`presets`] — built-in profiles (`h800`, `h100`, `a100`, and
//!   `abstract`, the paper's unit-cost `n_sm = n_kv` machine), plus
//!   [`presets::resolve`] which also accepts a profile-JSON path for
//!   custom/calibrated parts.
//! * [`io`] — JSON serialization (via the in-tree [`crate::util::json`])
//!   so calibrated profiles round-trip through files and the
//!   `dash hw --export` / `--gpu <path>` CLI surface.
//! * [`Machine`] — a profile bundled with the L2/register effect models
//!   derived from it (or idealized away), the unit the figure harness and
//!   workload runner consume.

//! * [`cluster`] — the multi-device analogue: [`ClusterProfile`] bundles
//!   N GPU profiles with a [`LinkModel`] interconnect (NVLink/IB presets),
//!   round-trips through JSON the same way, and fingerprints the topology
//!   so cluster-tuned schedules never leak across device counts or links.

pub mod cluster;
pub mod io;
pub mod presets;
pub mod profile;

pub use cluster::{resolve_cluster, ClusterProfile, LinkModel};
pub use presets::{preset, resolve, PRESET_NAMES};
pub use profile::{GpuProfile, Machine};
