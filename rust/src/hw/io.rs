//! JSON serialization for [`GpuProfile`]: custom/calibrated profiles
//! round-trip through files (`dash hw --export`, `--gpu <path>`).
//!
//! The format is one flat object, field names matching the struct. Parsing
//! is strict about types but order-insensitive; a malformed file is an
//! error (unlike the schedule cache, a profile is an *input*, and silently
//! substituting defaults would change every downstream number).

use super::profile::GpuProfile;
use crate::util::Json;
use crate::Result;
use std::path::Path;

/// On-disk format version.
const FORMAT_VERSION: f64 = 1.0;

impl GpuProfile {
    /// Serialize to the profile-JSON document.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("version".into(), Json::Num(FORMAT_VERSION)),
            ("name".into(), Json::Str(self.name.clone())),
            ("n_sm".into(), Json::Num(self.n_sm as f64)),
            ("clock_ghz".into(), Json::Num(self.clock_ghz)),
            (
                "flops_per_cycle_per_sm".into(),
                Json::Num(self.flops_per_cycle_per_sm),
            ),
            ("l2_bytes".into(), Json::Num(self.l2_bytes as f64)),
            (
                "l2_bytes_per_cycle_per_sm".into(),
                Json::Num(self.l2_bytes_per_cycle_per_sm),
            ),
            ("l2_segments".into(), Json::Num(self.l2_segments as f64)),
            ("l2_local_latency".into(), Json::Num(self.l2_local_latency)),
            ("l2_remote_latency".into(), Json::Num(self.l2_remote_latency)),
            ("smem_bytes_per_sm".into(), Json::Num(self.smem_bytes_per_sm as f64)),
            ("reg_per_thread".into(), Json::Num(self.reg_per_thread as f64)),
            (
                "regfile_bytes_per_sm".into(),
                Json::Num(self.regfile_bytes_per_sm as f64),
            ),
        ])
    }

    /// Decode a profile-JSON document.
    pub fn from_json(doc: &Json) -> Result<GpuProfile> {
        let version = doc.get("version").and_then(Json::as_f64).unwrap_or(FORMAT_VERSION);
        if version != FORMAT_VERSION {
            anyhow::bail!("unsupported profile format version {version}");
        }
        let num = |key: &str| -> Result<f64> {
            doc.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("profile JSON missing numeric field '{key}'"))
        };
        let int = |key: &str| -> Result<usize> {
            doc.get(key)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("profile JSON missing integer field '{key}'"))
        };
        let profile = GpuProfile {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("profile JSON missing string field 'name'"))?
                .to_string(),
            n_sm: int("n_sm")?,
            clock_ghz: num("clock_ghz")?,
            flops_per_cycle_per_sm: num("flops_per_cycle_per_sm")?,
            l2_bytes: int("l2_bytes")?,
            l2_bytes_per_cycle_per_sm: num("l2_bytes_per_cycle_per_sm")?,
            l2_segments: int("l2_segments")?,
            l2_local_latency: num("l2_local_latency")?,
            l2_remote_latency: num("l2_remote_latency")?,
            smem_bytes_per_sm: int("smem_bytes_per_sm")?,
            reg_per_thread: num("reg_per_thread")? as u32,
            regfile_bytes_per_sm: int("regfile_bytes_per_sm")?,
        };
        // `n_sm == 0` is the abstract-machine sentinel: it discards every
        // calibrated number in the file and fingerprints as 0. Accept it
        // only when the file *says* it is the abstract machine, so a typo'd
        // custom profile fails loudly instead of silently degrading.
        if profile.is_abstract() && profile.name != "abstract" {
            anyhow::bail!(
                "profile '{}' has n_sm = 0, the abstract-machine sentinel; set \
                 n_sm > 0 for a concrete part (or name the profile 'abstract')",
                profile.name
            );
        }
        profile.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(profile)
    }

    /// Write the profile to a JSON file.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().dump())?;
        Ok(())
    }

    /// Read a profile from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<GpuProfile> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read profile '{}': {e}", path.display()))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad profile JSON '{}': {e:#}", path.display()))?;
        Self::from_json(&doc)
            .map_err(|e| anyhow::anyhow!("bad profile '{}': {e:#}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::presets;

    #[test]
    fn presets_round_trip_through_json_text() {
        for name in presets::PRESET_NAMES {
            let p = presets::preset(name).unwrap();
            let text = p.to_json().dump();
            let back = GpuProfile::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back, p, "{name}");
            assert_eq!(back.fingerprint(), p.fingerprint(), "{name}");
        }
    }

    #[test]
    fn save_and_load_round_trip() {
        let path = std::env::temp_dir()
            .join(format!("dash-hw-{}-roundtrip.json", std::process::id()));
        let p = presets::a100();
        p.save(&path).unwrap();
        let back = GpuProfile::load(&path).unwrap();
        assert_eq!(back, p);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_field_is_an_error() {
        let doc = Json::parse(r#"{"name":"x","n_sm":10}"#).unwrap();
        assert!(GpuProfile::from_json(&doc).is_err());
    }

    #[test]
    fn invalid_profile_is_rejected_on_load() {
        let mut p = presets::h800();
        p.clock_ghz = 0.0;
        let doc = Json::parse(&p.to_json().dump()).unwrap();
        assert!(GpuProfile::from_json(&doc).is_err());
    }

    #[test]
    fn zeroed_n_sm_in_a_custom_profile_fails_loudly() {
        // n_sm = 0 would silently turn a calibrated part into the abstract
        // machine (unit costs, fingerprint 0); only the profile actually
        // named "abstract" may use the sentinel.
        let mut p = presets::h800();
        p.name = "my-part".into();
        p.n_sm = 0;
        let doc = Json::parse(&p.to_json().dump()).unwrap();
        let err = GpuProfile::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("abstract-machine sentinel"), "{err}");
        // The genuine abstract preset still round-trips.
        let abs = presets::abstract_machine();
        let doc = Json::parse(&abs.to_json().dump()).unwrap();
        assert_eq!(GpuProfile::from_json(&doc).unwrap(), abs);
    }
}
