//! Built-in GPU profiles, and the `--gpu` argument resolver.
//!
//! Sources for the numbers: vendor datasheets for geometry/clock/SMEM,
//! the paper's §4 microbenchmarks for the L2 signalling latencies, and the
//! FA3-reported sustained tensor-core efficiency (~65% of dense BF16 peak
//! for the backward pass) for the effective FLOPs rates. Custom or
//! re-calibrated parts load from JSON via [`resolve`] / `dash hw`.

use super::profile::GpuProfile;
use crate::Result;

/// Names accepted by `--gpu` (besides a profile-JSON path).
pub const PRESET_NAMES: [&str; 4] = ["h800", "h100", "a100", "abstract"];

/// NVIDIA H800 SXM — the paper's evaluation part. 132 SMs at 1.98 GHz,
/// 50 MiB L2; dense BF16 tensor-core peak ~3,787 FLOPs/cycle/SM derated to
/// ~65% sustained.
pub fn h800() -> GpuProfile {
    GpuProfile {
        name: "h800".into(),
        n_sm: 132,
        clock_ghz: 1.98,
        flops_per_cycle_per_sm: 2460.0,
        l2_bytes: 50 * 1024 * 1024,
        l2_bytes_per_cycle_per_sm: 32.0,
        l2_segments: 4,
        l2_local_latency: 200.0,
        l2_remote_latency: 500.0,
        smem_bytes_per_sm: 228 * 1024,
        reg_per_thread: 255,
        regfile_bytes_per_sm: 256 * 1024,
    }
}

/// NVIDIA H100 PCIe — same Hopper SM as the H800 but the narrower, slower
/// PCIe configuration: 114 SMs at ~1.755 GHz. (The H800 SXM is the
/// export-variant of the H100 SXM with identical on-die compute, so the
/// PCIe part is the interesting cross-GPU contrast.)
pub fn h100() -> GpuProfile {
    GpuProfile {
        name: "h100".into(),
        n_sm: 114,
        clock_ghz: 1.755,
        flops_per_cycle_per_sm: 2460.0,
        l2_bytes: 50 * 1024 * 1024,
        l2_bytes_per_cycle_per_sm: 32.0,
        l2_segments: 4,
        l2_local_latency: 200.0,
        l2_remote_latency: 500.0,
        smem_bytes_per_sm: 228 * 1024,
        reg_per_thread: 255,
        regfile_bytes_per_sm: 256 * 1024,
    }
}

/// NVIDIA A100 SXM 80GB — the previous generation: 108 SMs at 1.41 GHz,
/// 40 MiB L2 in two physical partitions, 164 KiB SMEM/SM (too small for
/// two co-resident FA3-backward CTAs even at headdim 64). Dense BF16 peak
/// ~2,048 FLOPs/cycle/SM, same 65% sustained derate.
pub fn a100() -> GpuProfile {
    GpuProfile {
        name: "a100".into(),
        n_sm: 108,
        clock_ghz: 1.41,
        flops_per_cycle_per_sm: 1330.0,
        l2_bytes: 40 * 1024 * 1024,
        l2_bytes_per_cycle_per_sm: 20.0,
        l2_segments: 2,
        l2_local_latency: 200.0,
        l2_remote_latency: 400.0,
        smem_bytes_per_sm: 164 * 1024,
        reg_per_thread: 255,
        regfile_bytes_per_sm: 256 * 1024,
    }
}

/// The paper's §3 abstract machine: as many SMs as the workload has KV
/// tiles (`n_sm = 0` sentinel), unit compute cost, `r/c = 0.25`, no L2
/// latency, no register spills.
pub fn abstract_machine() -> GpuProfile {
    GpuProfile {
        name: "abstract".into(),
        n_sm: 0,
        clock_ghz: 1.0,
        flops_per_cycle_per_sm: 1.0,
        l2_bytes: 0,
        l2_bytes_per_cycle_per_sm: 0.0,
        l2_segments: 1,
        l2_local_latency: 0.0,
        l2_remote_latency: 0.0,
        smem_bytes_per_sm: 0,
        reg_per_thread: u32::MAX,
        regfile_bytes_per_sm: 0,
    }
}

/// Look up a built-in preset by name.
pub fn preset(name: &str) -> Option<GpuProfile> {
    match name {
        "h800" => Some(h800()),
        "h100" => Some(h100()),
        "a100" => Some(a100()),
        "abstract" => Some(abstract_machine()),
        _ => None,
    }
}

/// Resolve a `--gpu` argument: a preset name, or a path to a profile JSON
/// written by [`GpuProfile::save`] / `dash hw --export`.
pub fn resolve(arg: &str) -> Result<GpuProfile> {
    if let Some(p) = preset(arg) {
        return Ok(p);
    }
    if std::path::Path::new(arg).exists() {
        return GpuProfile::load(arg);
    }
    anyhow::bail!(
        "unknown GPU profile '{arg}' — expected one of {} or a profile JSON path",
        PRESET_NAMES.join("|")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_preset_resolves_and_validates() {
        for name in PRESET_NAMES {
            let p = resolve(name).unwrap();
            assert_eq!(p.name, name);
            p.validate().unwrap();
        }
    }

    #[test]
    fn presets_are_pairwise_distinct_hardware() {
        let all: Vec<GpuProfile> = PRESET_NAMES.iter().map(|n| preset(n).unwrap()).collect();
        for i in 0..all.len() {
            for j in (i + 1)..all.len() {
                assert_ne!(
                    all[i].fingerprint(),
                    all[j].fingerprint(),
                    "{} vs {}",
                    all[i].name,
                    all[j].name
                );
            }
        }
    }

    #[test]
    fn unknown_name_errors_with_the_preset_list() {
        let err = resolve("h900").unwrap_err().to_string();
        assert!(err.contains("h800|h100|a100|abstract"), "{err}");
    }

    #[test]
    fn h100_is_narrower_and_slower_than_h800() {
        assert!(h100().n_sm < h800().n_sm);
        assert!(h100().clock_ghz < h800().clock_ghz);
        assert!(h100().machine_flops() < h800().machine_flops());
    }
}
