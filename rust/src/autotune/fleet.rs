//! Fleet-scale tuning: structured cache keys, warm-start transfer, and the
//! batch tuning queue behind `dash tune --queue`.
//!
//! The persistent cache keys tuned schedules by the opaque string
//! [`super::fingerprint::WorkloadFingerprint::key`] produces. That format
//! is append-only and already carries everything a *structured* key needs —
//! this module parses it back:
//!
//! ```text
//! {n_kv}x{n_q}-h{heads}-{mask_fingerprint}-sm{n_sm}-{cost_hash:016x}
//!     [-dev{n_devices}x{cluster_hash:016x}]
//! ```
//!
//! The mask fingerprint may itself contain `-` (e.g. `causal-p2`,
//! `doc-<hash>`, `bs2x2-<hash>`), so [`StructuredKey::parse`] consumes the
//! grammar from both ends and keeps the middle as the fingerprint; its
//! leading alphabetic run is the **mask family** (`full`, `causal`, `swa`,
//! `doc`, `bs`). Parsing the existing grammar — instead of changing it —
//! keeps every cache ever written valid.
//!
//! **Warm-start transfer.** A cold workload rarely arrives alone: the
//! fleet has usually already tuned the same mask family on the same cost
//! model at a nearby size. [`nearest_neighbor`] picks the closest such
//! entry (a pure function of the key set — see its tie-break contract) and
//! [`warm_start`] turns it into extra seed candidates for
//! [`super::search::tune_seeded`]: the cached schedule verbatim when the
//! tile geometry matches exactly (the cache key also encodes `n_sm` and
//! the cost hash, so equal-geometry entries tuned under other machine
//! widths exist), else the neighbor's winning seed family regenerated on
//! the target geometry. Seeding is additive — the analytic generators stay
//! in the pool — so a warm-started result is never worse than the best
//! analytic schedule, the same guarantee cold search gives, while the
//! search budget can be cut ~10x (the ROADMAP acceptance metric; the
//! `tune` baseline suite pins the tuned-at-n=64-applied-at-n=96
//! generalization gap at exactly 0 in the closed-form regime).
//!
//! **Batch mode.** [`run_queue`] drains a workload list into one shared
//! cache: identical keys are deduped, workloads are processed in sorted
//! key order (so the report is independent of the input order), and each
//! outcome records its provenance — `hit` (already cached), `warm`
//! (transferred from a named neighbor, including entries tuned earlier in
//! the same drain), or `cold`. `dash tune --queue` wraps this in a
//! [`super::cache::CacheLock`] so concurrent drains serialize on the cache
//! file.

use super::cache::ScheduleCache;
use super::search::{tune_seeded, TuneOptions, TuneResult};
use crate::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass, validate, ProblemSpec,
    Schedule,
};
use crate::util::Json;
use crate::Result;

/// A fingerprint key parsed back into its fields. Field meanings match
/// [`super::fingerprint::WorkloadFingerprint`]; the mask is kept as its
/// fingerprint string (the key does not store enough to rebuild a
/// [`crate::schedule::MaskSpec`], and neighbor selection only needs
/// equality and the family).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StructuredKey {
    /// KV tiles per head.
    pub n_kv: usize,
    /// Q tiles per head.
    pub n_q: usize,
    /// Head instances.
    pub heads: usize,
    /// The mask's [`crate::schedule::MaskSpec::fingerprint`] string.
    pub mask_fingerprint: String,
    /// SMs the entry was tuned for.
    pub n_sm: usize,
    /// Cost-model + hardware-profile hash (the "profile hash").
    pub cost_hash: u64,
    /// Devices the entry was tuned for (1 = single GPU).
    pub n_devices: usize,
    /// Cluster topology hash (0 for single GPU).
    pub cluster_hash: u64,
}

impl StructuredKey {
    /// Parse a cache key. Returns `None` for anything that does not match
    /// the grammar exactly (foreign keys in a shared cache are skipped,
    /// not fatal).
    pub fn parse(key: &str) -> Option<Self> {
        // Optional cluster suffix: "-dev{n}x{16 hex}".
        let (body, n_devices, cluster_hash) = match split_dev_suffix(key) {
            Some((body, d, c)) => (body, d, c),
            None => (key, 1, 0),
        };
        let parts: Vec<&str> = body.split('-').collect();
        // Minimum: geometry, heads, mask (>= 1 part), sm, cost hash.
        if parts.len() < 5 {
            return None;
        }
        let (n_kv, n_q) = parse_geometry(parts[0])?;
        let heads: usize = parts[1].strip_prefix('h')?.parse().ok()?;
        let cost_hash = parse_hash16(parts[parts.len() - 1])?;
        let n_sm: usize = parts[parts.len() - 2].strip_prefix("sm")?.parse().ok()?;
        let mask_fingerprint = parts[2..parts.len() - 2].join("-");
        if mask_fingerprint.is_empty() {
            return None;
        }
        Some(Self {
            n_kv,
            n_q,
            heads,
            mask_fingerprint,
            n_sm,
            cost_hash,
            n_devices,
            cluster_hash,
        })
    }

    /// Re-serialize to the exact key string this was parsed from
    /// (`parse` and `key` round-trip byte-for-byte).
    pub fn key(&self) -> String {
        let mut k = format!(
            "{}x{}-h{}-{}-sm{}-{:016x}",
            self.n_kv, self.n_q, self.heads, self.mask_fingerprint, self.n_sm, self.cost_hash
        );
        if self.n_devices != 1 || self.cluster_hash != 0 {
            k.push_str(&format!("-dev{}x{:016x}", self.n_devices, self.cluster_hash));
        }
        k
    }

    /// The mask family: the fingerprint's leading alphabetic run (`full`,
    /// `causal`, `swa`, `doc`, `bs`). Two keys in one family share mask
    /// *shape*, not necessarily content — `causal-p2` and `causal` are
    /// both `causal`.
    pub fn mask_family(&self) -> &str {
        let end = self
            .mask_fingerprint
            .find(|c: char| !c.is_ascii_alphabetic())
            .unwrap_or(self.mask_fingerprint.len());
        &self.mask_fingerprint[..end]
    }

    /// Whether `other` may donate a warm start to `self`: same mask
    /// family, head count, cost/profile hash, and cluster identity. Size
    /// fields (`n_kv`, `n_q`, `n_sm`) are exactly what transfer is allowed
    /// to bridge.
    pub fn transfer_compatible(&self, other: &Self) -> bool {
        self.mask_family() == other.mask_family()
            && self.heads == other.heads
            && self.cost_hash == other.cost_hash
            && self.n_devices == other.n_devices
            && self.cluster_hash == other.cluster_hash
    }

    /// Neighbor ranking tuple: smaller is closer. Distance in `n_kv`
    /// dominates, then `n_q`, then `n_sm`; every distance tie prefers the
    /// *smaller* size (schedules generalize up more gracefully than down),
    /// and the final tie-break is the lexicographic key — so the minimum
    /// is unique and [`nearest_neighbor`] is a pure function of the key
    /// set, independent of iteration order.
    fn distance_rank(&self, target: &Self) -> (usize, usize, usize, usize, usize, usize, String) {
        (
            self.n_kv.abs_diff(target.n_kv),
            self.n_kv,
            self.n_q.abs_diff(target.n_q),
            self.n_q,
            self.n_sm.abs_diff(target.n_sm),
            self.n_sm,
            self.key(),
        )
    }
}

fn parse_geometry(tok: &str) -> Option<(usize, usize)> {
    let (kv, q) = tok.split_once('x')?;
    Some((kv.parse().ok()?, q.parse().ok()?))
}

fn parse_hash16(tok: &str) -> Option<u64> {
    if tok.len() != 16 || !tok.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(tok, 16).ok()
}

fn split_dev_suffix(key: &str) -> Option<(&str, usize, u64)> {
    let at = key.rfind("-dev")?;
    let rest = &key[at + 4..];
    let (devices, hash) = rest.split_once('x')?;
    let n_devices: usize = devices.parse().ok()?;
    let cluster_hash = parse_hash16(hash)?;
    Some((&key[..at], n_devices, cluster_hash))
}

/// The nearest transfer-compatible cached key to `target`, by
/// [`StructuredKey::distance_rank`]. The exact target key and unparsable
/// keys are skipped. Pure in the *set* of keys: any permutation of
/// `candidates` returns the same neighbor.
pub fn nearest_neighbor<'a, I>(target: &StructuredKey, candidates: I) -> Option<StructuredKey>
where
    I: IntoIterator<Item = &'a str>,
{
    let target_key = target.key();
    candidates
        .into_iter()
        .filter(|k| *k != target_key)
        .filter_map(StructuredKey::parse)
        .filter(|k| target.transfer_compatible(k))
        .min_by_key(|k| k.distance_rank(target))
}

/// A warm start assembled from the nearest cached neighbor.
#[derive(Debug, Clone)]
pub struct WarmStart {
    /// The donating cache key.
    pub from_key: String,
    /// Extra seed candidates for [`tune_seeded`].
    pub seeds: Vec<Schedule>,
    /// True when the neighbor's tile geometry equals the target's, so the
    /// cached schedule transferred verbatim.
    pub exact_geometry: bool,
}

/// Build a warm start for `(spec, key)` from `cache`, or `None` when no
/// transfer-compatible neighbor exists. The transferred candidate is the
/// neighbor's schedule itself on an exact geometry match, else the
/// neighbor's winning seed family regenerated on the target geometry
/// (unknown or non-analytic seed names fall back to deterministic FA3).
pub fn warm_start(spec: &ProblemSpec, key: &str, cache: &ScheduleCache) -> Option<WarmStart> {
    let target = StructuredKey::parse(key)?;
    let neighbor = nearest_neighbor(&target, cache.keys())?;
    let neighbor_key = neighbor.key();
    let cached = cache.entry(&neighbor_key)?;
    let exact_geometry = cached.schedule.spec == *spec;
    let candidate = if exact_geometry {
        cached.schedule
    } else {
        regenerate_seed(&cached.seed_name, spec, target.n_sm)
    };
    let mut seeds = Vec::new();
    if candidate.spec == *spec && validate(&candidate).is_ok() {
        seeds.push(candidate);
    }
    Some(WarmStart { from_key: neighbor_key, seeds, exact_geometry })
}

/// Regenerate the analytic family named `seed_name` on `spec`. Schedule
/// kinds the generator menu cannot rebuild (including `tuned`, recorded
/// when an exact-geometry transfer won the greedy phase) fall back to
/// deterministic FA3 — always legal, never fatal.
fn regenerate_seed(seed_name: &str, spec: &ProblemSpec, n_sm: usize) -> Schedule {
    match seed_name {
        "descending" => descending(spec),
        "lpt" => lpt_schedule(spec, n_sm),
        "symmetric-shift" => symmetric_shift(spec),
        "two-pass" => two_pass(spec),
        "shift" => shift(spec).unwrap_or_else(|_| fa3(spec, true)),
        _ => fa3(spec, true),
    }
}

/// Outcome of a warm-capable tuning run.
#[derive(Debug, Clone)]
pub struct WarmTune {
    /// The tuning result (same guarantees as [`super::search::tune`]).
    pub result: TuneResult,
    /// The donating cache key, when a neighbor warm-started the search.
    pub source: Option<String>,
}

/// Tune `spec`, warm-starting from the nearest cached neighbor when one
/// exists. With an empty (or neighbor-free) cache this is byte-identical
/// to a cold [`super::search::tune`] run.
pub fn tune_warm(
    spec: &ProblemSpec,
    opts: &TuneOptions,
    key: &str,
    cache: &ScheduleCache,
) -> Result<WarmTune> {
    let warm = warm_start(spec, key, cache);
    let seeds = warm.as_ref().map(|w| w.seeds.as_slice()).unwrap_or(&[]);
    let result = tune_seeded(spec, opts, seeds)?;
    Ok(WarmTune { result, source: warm.map(|w| w.from_key) })
}

// ---------------------------------------------------------------------------
// Batch queue
// ---------------------------------------------------------------------------

/// One workload drawn from a `--queue` specs file.
#[derive(Debug, Clone)]
pub struct QueueSpec {
    /// The tuning problem.
    pub spec: ProblemSpec,
    /// Machine width to tune for (0 = default to `spec.n_kv`).
    pub n_sm: usize,
    /// Per-workload cold-budget override.
    pub budget: Option<usize>,
}

/// Parse a queue specs file: a JSON array of objects with fields `n`
/// (required), `n_q` (default `n`), `heads` (default 4), `mask` (default
/// `causal`; full `dash` mask grammar), `n_sm` (default `n`), and `budget`
/// (default: the run's `--budget`).
pub fn parse_queue(text: &str) -> Result<Vec<QueueSpec>> {
    let doc = Json::parse(text)?;
    let arr = doc
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("queue file must be a JSON array of workload objects"))?;
    let mut out = Vec::new();
    for (i, item) in arr.iter().enumerate() {
        let n = item
            .get("n")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("queue entry {i}: missing required field 'n'"))?;
        anyhow::ensure!(n > 0, "queue entry {i}: 'n' must be positive");
        let n_q = item.get("n_q").and_then(Json::as_usize).unwrap_or(n);
        let heads = item.get("heads").and_then(Json::as_usize).unwrap_or(4);
        let mask = match item.get("mask").and_then(Json::as_str) {
            Some(m) => crate::mask::resolve(m)
                .map_err(|e| anyhow::anyhow!("queue entry {i}: bad mask: {e:#}"))?,
            None => crate::mask::resolve("causal")?,
        };
        let n_sm = item.get("n_sm").and_then(Json::as_usize).unwrap_or(n);
        let budget = item.get("budget").and_then(Json::as_usize);
        out.push(QueueSpec {
            spec: ProblemSpec { n_kv: n, n_q, n_heads: heads, mask },
            n_sm,
            budget,
        });
    }
    Ok(out)
}

/// Where a queue outcome's schedule came from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Provenance {
    /// Served from the cache without searching.
    Hit,
    /// Searched, warm-started from the named cache key.
    Warm(String),
    /// Searched cold (no transfer-compatible neighbor).
    Cold,
}

impl Provenance {
    /// Short label for tables.
    pub fn label(&self) -> &'static str {
        match self {
            Provenance::Hit => "hit",
            Provenance::Warm(_) => "warm",
            Provenance::Cold => "cold",
        }
    }
}

/// One drained queue workload.
#[derive(Debug, Clone)]
pub struct QueueOutcome {
    /// The workload's cache key.
    pub key: String,
    /// The tuning problem.
    pub spec: ProblemSpec,
    /// Machine width tuned for.
    pub n_sm: usize,
    /// hit / warm / cold.
    pub provenance: Provenance,
    /// Makespan of the served or tuned schedule.
    pub makespan: f64,
    /// Lower bound recorded for the workload.
    pub bound: f64,
    /// Proposals evaluated (0 for hits).
    pub evaluated: usize,
}

impl QueueOutcome {
    /// Relative optimality gap vs the recorded bound.
    pub fn gap(&self) -> f64 {
        if self.bound > 0.0 {
            (self.makespan - self.bound).max(0.0) / self.bound
        } else {
            0.0
        }
    }
}

/// A drained queue: per-workload outcomes in sorted key order.
#[derive(Debug, Clone)]
pub struct QueueReport {
    /// One outcome per distinct key, sorted by key.
    pub outcomes: Vec<QueueOutcome>,
    /// Queue entries dropped as duplicates of an earlier identical key.
    pub deduped: usize,
}

impl QueueReport {
    /// Outcome counts as (hit, warm, cold).
    pub fn tally(&self) -> (usize, usize, usize) {
        let mut t = (0, 0, 0);
        for o in &self.outcomes {
            match o.provenance {
                Provenance::Hit => t.0 += 1,
                Provenance::Warm(_) => t.1 += 1,
                Provenance::Cold => t.2 += 1,
            }
        }
        t
    }
}

/// Drain `queue` into `cache`. `base` supplies the cost model (its
/// `sim.n_sm` is overridden per workload), the seed, the round batch, and
/// the default cold budget; `warm_budget` is the (typically ~10x smaller)
/// budget used when a neighbor warm-starts a workload (0 = use the cold
/// budget). Workloads are deduped by key and processed in sorted key
/// order, so the report — and the final cache contents — are pure
/// functions of the queue *set*: input order never matters. Entries tuned
/// earlier in the drain are visible as warm-start donors to later ones.
///
/// The caller owns persistence (and locking): this function only mutates
/// `cache` in memory.
pub fn run_queue(
    queue: &[QueueSpec],
    base: &TuneOptions,
    warm_budget: usize,
    cache: &mut ScheduleCache,
) -> Result<QueueReport> {
    use super::fingerprint::WorkloadFingerprint;

    // Key every entry, then dedupe + sort for order independence.
    let mut keyed: Vec<(String, &QueueSpec)> = queue
        .iter()
        .map(|qs| {
            let mut sim = base.sim;
            sim.n_sm = if qs.n_sm == 0 { qs.spec.n_kv } else { qs.n_sm };
            (WorkloadFingerprint::new(&qs.spec, &sim).key(), qs)
        })
        .collect();
    keyed.sort_by(|a, b| a.0.cmp(&b.0));
    let before = keyed.len();
    keyed.dedup_by(|a, b| a.0 == b.0);
    let deduped = before - keyed.len();

    let mut outcomes = Vec::with_capacity(keyed.len());
    for (key, qs) in keyed {
        let mut sim = base.sim;
        sim.n_sm = if qs.n_sm == 0 { qs.spec.n_kv } else { qs.n_sm };
        if let Some(hit) = cache.get(&key, &qs.spec) {
            outcomes.push(QueueOutcome {
                key,
                spec: qs.spec.clone(),
                n_sm: sim.n_sm,
                provenance: Provenance::Hit,
                makespan: hit.makespan,
                bound: hit.lower_bound,
                evaluated: 0,
            });
            continue;
        }
        let cold_budget = qs.budget.unwrap_or(base.budget);
        let warm = warm_start(&qs.spec, &key, cache);
        let (budget, seeds, provenance) = match &warm {
            Some(w) if !w.seeds.is_empty() => (
                if warm_budget == 0 { cold_budget } else { warm_budget },
                w.seeds.as_slice(),
                Provenance::Warm(w.from_key.clone()),
            ),
            _ => (cold_budget, &[][..], Provenance::Cold),
        };
        let opts = TuneOptions { budget, sim, ..*base };
        let result = tune_seeded(&qs.spec, &opts, seeds)?;
        cache.put(&key, &result);
        outcomes.push(QueueOutcome {
            key,
            spec: qs.spec.clone(),
            n_sm: sim.n_sm,
            provenance,
            makespan: result.makespan,
            bound: result.bound.overall(),
            evaluated: result.evaluated,
        });
    }
    Ok(QueueReport { outcomes, deduped })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::WorkloadFingerprint;
    use crate::schedule::MaskSpec;
    use crate::sim::SimConfig;

    fn key_for(n: usize, heads: usize, mask: MaskSpec, n_sm: usize) -> String {
        let spec = ProblemSpec::square(n, heads, mask);
        WorkloadFingerprint::new(&spec, &SimConfig::ideal(n_sm)).key()
    }

    #[test]
    fn parse_round_trips_every_mask_shape() {
        for mask in [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::causal_with_offset(-2),
            MaskSpec::causal_with_offset(3),
            MaskSpec::sliding_window(4),
            MaskSpec::document(vec![3, 7]),
            MaskSpec::block_sparse(2, 2, vec![true, false, true, true]),
        ] {
            let key = key_for(12, 3, mask, 7);
            let parsed = StructuredKey::parse(&key).expect("own keys must parse");
            assert_eq!(parsed.key(), key, "parse/key must round-trip");
            assert_eq!((parsed.n_kv, parsed.n_q, parsed.heads, parsed.n_sm), (12, 12, 3, 7));
            assert_eq!((parsed.n_devices, parsed.cluster_hash), (1, 0));
        }
    }

    #[test]
    fn parse_round_trips_cluster_keys() {
        let spec = ProblemSpec::square(8, 2, MaskSpec::causal());
        let key = WorkloadFingerprint::new(&spec, &SimConfig::ideal(8))
            .with_cluster(4, 0xABCD_EF01_2345_6789)
            .key();
        let parsed = StructuredKey::parse(&key).unwrap();
        assert_eq!(parsed.n_devices, 4);
        assert_eq!(parsed.cluster_hash, 0xABCD_EF01_2345_6789);
        assert_eq!(parsed.key(), key);
    }

    #[test]
    fn mask_family_strips_parameters_and_hashes() {
        let fam = |mask: MaskSpec| {
            StructuredKey::parse(&key_for(8, 2, mask, 8)).unwrap().mask_family().to_string()
        };
        assert_eq!(fam(MaskSpec::full()), "full");
        assert_eq!(fam(MaskSpec::causal()), "causal");
        assert_eq!(fam(MaskSpec::causal_with_offset(2)), "causal");
        assert_eq!(fam(MaskSpec::sliding_window(3)), "swa");
        assert_eq!(fam(MaskSpec::document(vec![4])), "doc");
        assert_eq!(fam(MaskSpec::block_sparse(2, 2, vec![true; 4])), "bs");
    }

    #[test]
    fn garbage_keys_do_not_parse() {
        for bad in [
            "",
            "8x8",
            "8x8-h2",
            "8x8-h2-sm8-0000000000000000",       // missing mask
            "8x8-h2-causal-sm8-abc",             // short hash
            "8x8-h2-causal-sm8-zzzzzzzzzzzzzzzz", // non-hex hash
            "axb-h2-causal-sm8-0000000000000000", // non-numeric geometry
            "8x8-hx-causal-sm8-0000000000000000", // non-numeric heads
        ] {
            assert!(StructuredKey::parse(bad).is_none(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn neighbor_selection_is_a_pure_function_of_the_key_set() {
        let target = StructuredKey::parse(&key_for(64, 2, MaskSpec::causal(), 64)).unwrap();
        let mut keys = vec![
            key_for(32, 2, MaskSpec::causal(), 32),
            key_for(96, 2, MaskSpec::causal(), 96),
            key_for(48, 2, MaskSpec::causal(), 48),
            key_for(64, 3, MaskSpec::causal(), 64), // wrong heads
            key_for(62, 2, MaskSpec::full(), 62),   // wrong family
        ];
        let want = key_for(48, 2, MaskSpec::causal(), 48); // distance 16 beats 32
        for rotation in 0..keys.len() {
            keys.rotate_left(1);
            let got = nearest_neighbor(&target, keys.iter().map(String::as_str)).unwrap();
            assert_eq!(got.key(), want, "rotation {rotation} changed the neighbor");
        }
    }

    #[test]
    fn neighbor_distance_ties_prefer_the_smaller_size() {
        // 56 and 72 are both 8 away from 64: the documented tie-break
        // takes the smaller n_kv.
        let target = StructuredKey::parse(&key_for(64, 2, MaskSpec::causal(), 64)).unwrap();
        let keys = [
            key_for(72, 2, MaskSpec::causal(), 72),
            key_for(56, 2, MaskSpec::causal(), 56),
        ];
        let got = nearest_neighbor(&target, keys.iter().map(String::as_str)).unwrap();
        assert_eq!(got.n_kv, 56, "distance ties must break to the smaller size");
    }

    #[test]
    fn the_exact_target_key_is_never_its_own_neighbor() {
        let key = key_for(64, 2, MaskSpec::causal(), 64);
        let target = StructuredKey::parse(&key).unwrap();
        assert!(nearest_neighbor(&target, [key.as_str()]).is_none());
    }

    #[test]
    fn queue_parsing_applies_defaults_and_rejects_garbage() {
        let q = parse_queue(
            r#"[{"n": 8, "heads": 2, "mask": "causal"},
                {"n": 6, "n_q": 4, "n_sm": 3, "budget": 17}]"#,
        )
        .unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!((q[0].spec.n_kv, q[0].spec.n_q, q[0].spec.n_heads), (8, 8, 2));
        assert_eq!(q[0].n_sm, 8);
        assert_eq!(q[0].budget, None);
        assert_eq!((q[1].spec.n_kv, q[1].spec.n_q), (6, 4));
        assert_eq!(q[1].n_sm, 3);
        assert_eq!(q[1].budget, Some(17));
        assert!(parse_queue("{}").is_err(), "non-array must be rejected");
        assert!(parse_queue(r#"[{"heads": 2}]"#).is_err(), "missing n must be rejected");
    }

    #[test]
    fn warm_start_transfers_the_cached_schedule_on_exact_geometry() {
        use crate::autotune::{tune, TuneOptions};
        let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
        // Same geometry tuned on a *narrower* machine: different key, same
        // spec — the verbatim-transfer case.
        let sim_narrow = SimConfig::ideal(3);
        let donor = tune(
            &spec,
            &TuneOptions { budget: 30, seed: 1, sim: sim_narrow, batch: 1, threads: 1 },
        )
        .unwrap();
        let donor_key = WorkloadFingerprint::new(&spec, &sim_narrow).key();
        let mut cache = ScheduleCache::open("warm-exact-never-written.json");
        cache.put(&donor_key, &donor);
        let sim_wide = SimConfig::ideal(6);
        let target_key = WorkloadFingerprint::new(&spec, &sim_wide).key();
        let ws = warm_start(&spec, &target_key, &cache).expect("neighbor must be found");
        assert_eq!(ws.from_key, donor_key);
        assert!(ws.exact_geometry);
        assert_eq!(ws.seeds.len(), 1);
        assert_eq!(ws.seeds[0].spec, spec);
    }

    #[test]
    fn warm_start_regenerates_the_seed_family_across_sizes() {
        use crate::autotune::{tune, TuneOptions};
        let donor_spec = ProblemSpec::square(8, 2, MaskSpec::causal());
        let sim8 = SimConfig::ideal(8);
        let donor = tune(
            &donor_spec,
            &TuneOptions { budget: 30, seed: 1, sim: sim8, batch: 1, threads: 1 },
        )
        .unwrap();
        let mut cache = ScheduleCache::open("warm-regen-never-written.json");
        cache.put(&WorkloadFingerprint::new(&donor_spec, &sim8).key(), &donor);
        let target_spec = ProblemSpec::square(12, 2, MaskSpec::causal());
        let sim12 = SimConfig::ideal(12);
        let target_key = WorkloadFingerprint::new(&target_spec, &sim12).key();
        let ws = warm_start(&target_spec, &target_key, &cache).unwrap();
        assert!(!ws.exact_geometry);
        assert_eq!(ws.seeds.len(), 1);
        assert_eq!(ws.seeds[0].spec, target_spec, "seed must be rebuilt on the target");
        validate(&ws.seeds[0]).unwrap();
    }

    #[test]
    fn empty_cache_warm_tune_is_a_cold_tune() {
        use crate::autotune::{tune, TuneOptions};
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let sim = SimConfig::ideal(5);
        let opts = TuneOptions { budget: 60, seed: 7, sim, batch: 1, threads: 1 };
        let key = WorkloadFingerprint::new(&spec, &sim).key();
        let cache = ScheduleCache::open("warm-empty-never-written.json");
        let warm = tune_warm(&spec, &opts, &key, &cache).unwrap();
        assert!(warm.source.is_none());
        let cold = tune(&spec, &opts).unwrap();
        assert_eq!(warm.result.makespan.to_bits(), cold.makespan.to_bits());
        assert_eq!(
            (warm.result.evaluated, warm.result.skipped_invalid, warm.result.skipped_sim),
            (cold.evaluated, cold.skipped_invalid, cold.skipped_sim)
        );
    }
}
