//! Portfolio search: race independent annealing replicas, keep the best.
//!
//! One local-search trajectory can stall on a plateau its move set cannot
//! cross downhill. A portfolio runs `replicas` trajectories from the same
//! greedy seeding but with *independent deterministic RNG streams* and a
//! temperature ladder: replica 0 anneals at temperature zero — which makes
//! it bit-identical to the classic [`super::search::tune`] loop, so a
//! portfolio of one is exactly the old tuner — while higher replicas accept
//! limited uphill moves with Metropolis probability, letting them escape
//! plateaus the greedy replica cannot.
//!
//! Determinism contract (the reason this module exists at fleet scale):
//!
//! * each replica's RNG stream is a pure function of `(seed, replica
//!   index)` — no replica ever observes another's draws;
//! * every replica scores its own candidates serially through one reused
//!   [`Simulator`], so a replica's trajectory is independent of how
//!   replicas are packed onto worker threads;
//! * replicas fan out over [`par_map_init`], which returns results in
//!   input order at any thread count;
//! * the portfolio winner is the smallest `(makespan, replica index)` —
//!   a total order, so ties break to the lowest index.
//!
//! Together these make `dash tune --portfolio N --threads T` bitwise-stable
//! in `T`: the CI acceptance byte-compares the `--threads 1` and
//! `--threads 4` outputs.

use super::oracle::lower_bound;
use super::search::{analytic_seeds, TuneOptions, TuneResult};
use crate::schedule::{validate, ProblemSpec, Schedule, ScheduleKind};
use crate::sim::{SimConfig, Simulator};
use crate::util::{par_map_init, DetRng};
use crate::Result;

/// Per-replica RNG stream separator. Replica 0 multiplies to zero, so its
/// stream — and therefore its whole trajectory — is byte-identical to the
/// classic single-trajectory tuner.
const STREAM_MIX: u64 = 0x9E37_79B9_7F4A_7C15;

/// Portfolio knobs: the classic [`TuneOptions`] plus a replica count.
/// `budget` and `batch` apply *per replica*; `threads` caps the outer
/// replica fan-out (each replica is serial inside).
#[derive(Debug, Clone, Copy)]
pub struct PortfolioOptions {
    /// Independent annealing replicas to race (clamped to >= 1).
    pub replicas: usize,
    /// Local-search proposals per replica.
    pub budget: usize,
    /// Base RNG seed; replica `k` draws from stream `seed ⊕ mix(k)`.
    pub seed: u64,
    /// Scoring configuration (span recording is forced off internally).
    pub sim: SimConfig,
    /// Proposals drawn per search round within each replica.
    pub batch: usize,
    /// Worker threads for the replica fan-out: `0` = all host cores,
    /// `1` = serial. Never changes any result.
    pub threads: usize,
}

impl PortfolioOptions {
    /// Defaults for interactive `dash tune --portfolio` runs.
    pub fn new(sim: SimConfig) -> Self {
        Self { replicas: 4, budget: 400, seed: 42, sim, batch: 8, threads: 0 }
    }
}

/// Summary of one replica's trajectory (the winner's full [`TuneResult`]
/// lives on [`PortfolioResult::winner`]).
#[derive(Debug, Clone)]
pub struct ReplicaReport {
    /// Replica index (also its RNG stream and tie-break rank).
    pub index: usize,
    /// Annealing temperature this replica ran at (0 for replica 0).
    pub temperature: f64,
    /// Best makespan the replica found.
    pub makespan: f64,
    /// Proposals scored without error.
    pub evaluated: usize,
    /// Strict improvements accepted.
    pub improvements: usize,
    /// Uphill moves accepted under the Metropolis rule (always 0 for
    /// replica 0).
    pub uphill: usize,
    /// Proposals dropped before scoring (no-op move or illegal candidate).
    pub skipped_invalid: usize,
    /// Proposals that validated but failed simulation.
    pub skipped_sim: usize,
}

/// Outcome of one portfolio run.
#[derive(Debug, Clone)]
pub struct PortfolioResult {
    /// The winning replica's result (smallest `(makespan, index)`).
    pub winner: TuneResult,
    /// Which replica won.
    pub winner_index: usize,
    /// Every replica's summary, in replica order.
    pub replicas: Vec<ReplicaReport>,
}

impl PortfolioResult {
    /// Largest minus smallest replica makespan — 0 when every replica
    /// agrees (e.g. all certify a home-regime seed optimal).
    pub fn makespan_spread(&self) -> f64 {
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for r in &self.replicas {
            lo = lo.min(r.makespan);
            hi = hi.max(r.makespan);
        }
        if self.replicas.is_empty() {
            0.0
        } else {
            hi - lo
        }
    }
}

/// The temperature ladder: replica 0 is greedy (temperature 0 — the
/// classic tuner), replica `k > 0` anneals at `k/50` of the seed makespan,
/// so the scale tracks the problem instead of an absolute constant.
fn temperature(index: usize, seed_makespan: f64) -> f64 {
    if index == 0 {
        0.0
    } else {
        seed_makespan * index as f64 / 50.0
    }
}

/// Race `opts.replicas` annealing replicas and keep the best result.
/// Errors only if no analytic seed is feasible (as [`super::search::tune`]).
pub fn tune_portfolio(spec: &ProblemSpec, opts: &PortfolioOptions) -> Result<PortfolioResult> {
    let replicas: Vec<usize> = (0..opts.replicas.max(1)).collect();
    let runs = par_map_init(&replicas, opts.threads, Simulator::new, |sim, &k| {
        run_replica(spec, opts, k, sim)
    });
    let mut results = Vec::with_capacity(runs.len());
    for run in runs {
        results.push(run?);
    }
    // Winner: smallest (makespan, replica index). Strict `<` over the
    // in-order scan makes the lowest index take ties.
    let mut winner_index = 0usize;
    for (k, (result, _, _)) in results.iter().enumerate() {
        if result.makespan < results[winner_index].0.makespan {
            winner_index = k;
        }
    }
    let reports = results
        .iter()
        .enumerate()
        .map(|(k, (r, uphill, temp))| ReplicaReport {
            index: k,
            temperature: *temp,
            makespan: r.makespan,
            evaluated: r.evaluated,
            improvements: r.improvements,
            uphill: *uphill,
            skipped_invalid: r.skipped_invalid,
            skipped_sim: r.skipped_sim,
        })
        .collect();
    let winner = results.swap_remove(winner_index).0;
    Ok(PortfolioResult { winner, winner_index, replicas: reports })
}

/// One replica: greedy seeding (identical across replicas — it draws no
/// RNG), then annealed local search on the replica's private stream.
/// Returns `(result, uphill accepts, temperature)`.
///
/// At temperature 0 the acceptance rule degenerates to the classic
/// non-regression rule *without consuming an RNG draw*, so replica 0's
/// trajectory — schedule, makespan bits, and all four counters — is
/// exactly [`super::search::tune`] at `threads = 1`. The tests pin this.
fn run_replica(
    spec: &ProblemSpec,
    opts: &PortfolioOptions,
    index: usize,
    sim: &mut Simulator,
) -> Result<(TuneResult, usize, f64)> {
    let mut sim_cfg = opts.sim;
    sim_cfg.record_spans = false;
    let batch = opts.batch.max(1);
    let bound = lower_bound(spec, &sim_cfg);

    // --- greedy seeding (same rule as search::tune) ----------------------
    let mut seeds: Vec<Schedule> = analytic_seeds(spec, sim_cfg.n_sm)
        .into_iter()
        .filter(|s| validate(s).is_ok())
        .collect();
    let scored: Vec<_> = seeds.iter().map(|s| sim.run(s, &sim_cfg)).collect();
    let mut best: Option<(usize, f64)> = None;
    for (i, run) in scored.iter().enumerate() {
        let Ok(run) = run else { continue };
        if best.map_or(true, |(_, t)| run.makespan < t) {
            best = Some((i, run.makespan));
        }
    }
    let (best_idx, mut incumbent_t) =
        best.ok_or_else(|| anyhow::anyhow!("no analytic seed is feasible for {spec:?}"))?;
    let mut incumbent = seeds.swap_remove(best_idx);
    let seed_kind = incumbent.kind;
    let seed_makespan = incumbent_t;
    incumbent.kind = ScheduleKind::Tuned;

    // --- annealed local search -------------------------------------------
    let temp = temperature(index, seed_makespan);
    let mut rng =
        DetRng::new(opts.seed ^ 0xDA5_11_5C_4ED ^ (index as u64).wrapping_mul(STREAM_MIX));
    // Track the best-so-far separately: an annealing incumbent may wander
    // uphill. At temperature 0 the incumbent never leaves the best level,
    // so `best_s` IS the incumbent — classic semantics, plateau drift
    // included.
    let mut best_s = incumbent.clone();
    let mut best_t = incumbent_t;
    let mut evaluated = 0usize;
    let mut improvements = 0usize;
    let mut uphill = 0usize;
    let mut skipped_invalid = 0usize;
    let mut skipped_sim = 0usize;
    let mut spent = 0usize;
    let mut candidates: Vec<Schedule> = Vec::new();
    while spent < opts.budget {
        if best_t <= bound.overall() + 1e-9 {
            break; // certified optimal — nothing left to find
        }
        let k = batch.min(opts.budget - spent);
        spent += k;
        candidates.clear();
        for _ in 0..k {
            match super::moves::propose(&incumbent, &mut rng, &sim_cfg) {
                Some(c) if validate(&c).is_ok() => candidates.push(c),
                _ => skipped_invalid += 1,
            }
        }
        if candidates.is_empty() {
            continue;
        }
        let round: Vec<_> = candidates.iter().map(|s| sim.run(s, &sim_cfg)).collect();
        let mut winner: Option<(usize, f64)> = None;
        for (i, run) in round.iter().enumerate() {
            match run {
                Ok(r) => {
                    evaluated += 1;
                    if winner.map_or(true, |(_, t)| r.makespan < t) {
                        winner = Some((i, r.makespan));
                    }
                }
                Err(_) => skipped_sim += 1,
            }
        }
        let Some((wi, wt)) = winner else { continue };
        let accept = if wt <= incumbent_t + 1e-12 {
            true
        } else if temp > 0.0 {
            // The uphill draw happens ONLY on a strict regression at
            // positive temperature, so the temperature-0 stream never
            // consumes it — the bit-compat invariant with search::tune.
            rng.gen_f64() < (-(wt - incumbent_t) / temp).exp()
        } else {
            false
        };
        if accept {
            if wt < incumbent_t - 1e-12 {
                improvements += 1;
            } else if wt > incumbent_t + 1e-12 {
                uphill += 1;
            }
            incumbent = candidates.swap_remove(wi);
            incumbent_t = wt;
            if incumbent_t <= best_t + 1e-12 {
                best_s = incumbent.clone();
                best_t = incumbent_t;
            }
        }
    }

    Ok((
        TuneResult {
            schedule: best_s,
            makespan: best_t,
            seed_kind,
            seed_makespan,
            bound,
            evaluated,
            improvements,
            skipped_invalid,
            skipped_sim,
        },
        uphill,
        temp,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{tune, TuneOptions};
    use crate::schedule::MaskSpec;

    fn opts(n_sm: usize, replicas: usize, budget: usize) -> PortfolioOptions {
        PortfolioOptions {
            replicas,
            budget,
            seed: 7,
            sim: SimConfig::ideal(n_sm),
            batch: 4,
            threads: 1,
        }
    }

    fn chain_ids(s: &Schedule) -> Vec<(usize, usize)> {
        s.chains.iter().map(|c| (c.head, c.kv)).collect()
    }

    #[test]
    fn replica_zero_is_bitwise_the_classic_tuner() {
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let o = opts(5, 4, 120);
        let classic = tune(
            &spec,
            &TuneOptions { budget: o.budget, seed: o.seed, sim: o.sim, batch: o.batch, threads: 1 },
        )
        .unwrap();
        let portfolio = tune_portfolio(&spec, &o).unwrap();
        let zero = &portfolio.replicas[0];
        assert_eq!(zero.makespan.to_bits(), classic.makespan.to_bits());
        assert_eq!(zero.temperature, 0.0);
        assert_eq!(zero.uphill, 0, "temperature 0 never accepts uphill");
        assert_eq!(
            (zero.evaluated, zero.improvements, zero.skipped_invalid, zero.skipped_sim),
            (
                classic.evaluated,
                classic.improvements,
                classic.skipped_invalid,
                classic.skipped_sim
            )
        );
    }

    #[test]
    fn portfolio_of_one_matches_classic_tune_exactly() {
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let o = opts(5, 1, 120);
        let classic = tune(
            &spec,
            &TuneOptions { budget: o.budget, seed: o.seed, sim: o.sim, batch: o.batch, threads: 1 },
        )
        .unwrap();
        let p = tune_portfolio(&spec, &o).unwrap();
        assert_eq!(p.winner_index, 0);
        assert_eq!(p.winner.makespan.to_bits(), classic.makespan.to_bits());
        assert_eq!(chain_ids(&p.winner.schedule), chain_ids(&classic.schedule));
        assert_eq!(p.winner.schedule.reduction_order, classic.schedule.reduction_order);
        assert_eq!(p.winner.schedule.pinned, classic.schedule.pinned);
    }

    #[test]
    fn thread_count_never_changes_the_portfolio() {
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let base = opts(5, 4, 100);
        let a = tune_portfolio(&spec, &base).unwrap();
        for threads in [2usize, 8] {
            let b = tune_portfolio(&spec, &PortfolioOptions { threads, ..base }).unwrap();
            assert_eq!(a.winner_index, b.winner_index, "threads={threads}");
            assert_eq!(a.winner.makespan.to_bits(), b.winner.makespan.to_bits());
            assert_eq!(chain_ids(&a.winner.schedule), chain_ids(&b.winner.schedule));
            assert_eq!(
                a.winner.schedule.reduction_order,
                b.winner.schedule.reduction_order
            );
            for (ra, rb) in a.replicas.iter().zip(&b.replicas) {
                assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
                assert_eq!(
                    (ra.evaluated, ra.improvements, ra.uphill, ra.skipped_invalid, ra.skipped_sim),
                    (rb.evaluated, rb.improvements, rb.uphill, rb.skipped_invalid, rb.skipped_sim)
                );
            }
        }
    }

    #[test]
    fn winner_is_the_smallest_makespan_earliest_index() {
        let spec = ProblemSpec::square(9, 2, MaskSpec::causal());
        let p = tune_portfolio(&spec, &opts(5, 5, 80)).unwrap();
        for r in &p.replicas {
            assert!(
                p.winner.makespan <= r.makespan + 1e-12,
                "winner {} beaten by replica {} at {}",
                p.winner.makespan,
                r.index,
                r.makespan
            );
        }
        let first_best =
            p.replicas.iter().find(|r| r.makespan == p.winner.makespan).unwrap();
        assert_eq!(p.winner_index, first_best.index, "ties must break to the lowest index");
    }

    #[test]
    fn portfolio_never_loses_to_the_analytic_seeds() {
        for mask in [MaskSpec::full(), MaskSpec::causal(), MaskSpec::sliding_window(3)] {
            let spec = ProblemSpec::square(8, 2, mask);
            let p = tune_portfolio(&spec, &opts(5, 3, 60)).unwrap();
            assert!(p.winner.makespan <= p.winner.seed_makespan + 1e-9);
            assert!(p.winner.makespan >= p.winner.bound.overall() - 1e-9);
            validate(&p.winner.schedule).unwrap();
            assert_eq!(p.winner.schedule.kind, ScheduleKind::Tuned);
        }
    }

    #[test]
    fn home_regime_replicas_all_certify_and_skip_search() {
        // The analytic seed meets the bound, so every replica exits before
        // proposing: zero counters, equal makespans, winner index 0. These
        // are the closed forms the committed BENCH_tune.json pins.
        let full = tune_portfolio(
            &ProblemSpec::square(8, 3, MaskSpec::full()),
            &opts(8, 3, 64),
        )
        .unwrap();
        assert_eq!(full.winner.makespan, 30.0);
        assert_eq!(full.winner_index, 0);
        assert_eq!(full.makespan_spread(), 0.0);
        let causal = tune_portfolio(
            &ProblemSpec::square(8, 2, MaskSpec::causal()),
            &opts(8, 3, 64),
        )
        .unwrap();
        assert_eq!(causal.winner.makespan, 11.25);
        for p in [&full, &causal] {
            for r in &p.replicas {
                assert_eq!(r.evaluated, 0);
                assert_eq!(r.improvements + r.uphill, 0);
                assert_eq!(r.skipped_invalid + r.skipped_sim, 0);
                assert_eq!(r.makespan, p.winner.makespan);
            }
        }
    }
}
