//! Lower-bound oracle: provable makespan floors for a [`ProblemSpec`] on an
//! `n_sm`-SM machine, independent of any concrete schedule *within the
//! fused-kernel task model* — every live tile pays one compute `c` and one
//! ordered global reduction `r` (unit `compute_scale`/`reduce_scale`,
//! ordered chains). That is exactly the space the autotuner searches; the
//! two-pass baseline trades its reductions for duplicated compute and is
//! outside this model (its 1.30x compute multiplier happens to exceed the
//! default 1 + r/c, but nothing here relies on that).
//!
//! Three relaxations, each a valid bound on every legal fused schedule:
//!
//! * **Work bound** — `total_tasks / n_sm` serial task costs: even a
//!   perfectly balanced machine cannot finish faster than its share of the
//!   total work.
//! * **Chain bound** — the §3.1 contiguity constraint makes each (head, KV
//!   tile) chain serial on one SM; the longest chain's critical path is a
//!   floor. Computed as the critical path of the chain-relaxation DAG
//!   (infinite SMs, no cross-chain dependencies) via [`crate::dag::Dag`].
//! * **Reduction bound** — dQ accumulation for one (head, q) column is
//!   serialized no matter which schedule orders it; a column with `k`
//!   contributors needs at least one compute plus `k` folds. Computed as
//!   the critical path of the column-relaxation DAG. (This term assumes
//!   *deterministic* accumulation — exactly the schedules the tuner
//!   synthesizes; on square grids it is dominated by the chain bound, so
//!   the overall bound also holds for the atomic baseline.)
//!
//! The tuner reports `makespan / overall - 1` as its *optimality gap*: a
//! gap of zero is a certificate that search found a true optimum for the
//! modelled machine (the paper's closed-form schedules hit it on their home
//! regimes — Shift at full/`n_sm = n`, Symmetric Shift at causal/even `n`).
//!
//! All three bounds assume the synchronous §3 execution model when
//! `writer_depth == 0 && occupancy == 1` (each task occupies its SM for
//! `c + r`); under a pipelined config the reduction cost is overlapped and
//! the bounds conservatively drop to compute-only terms, staying valid.

use crate::dag::{Dag, EdgeKind};
use crate::schedule::ProblemSpec;
use crate::sim::SimConfig;

/// The three relaxation bounds and their maximum.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LowerBound {
    /// Total-work / machine-width bound.
    pub work: f64,
    /// Longest serial chain bound (DAG critical path, infinite SMs).
    pub chain: f64,
    /// Serialized dQ-column bound (DAG critical path, infinite SMs).
    pub reduction: f64,
}

impl LowerBound {
    /// The binding floor: the maximum of the three relaxations.
    pub fn overall(&self) -> f64 {
        self.work.max(self.chain).max(self.reduction)
    }

    /// Relative optimality gap of an achieved makespan vs this bound
    /// (0.0 = provably optimal; bounded below by 0 for legal makespans).
    pub fn gap(&self, makespan: f64) -> f64 {
        let lb = self.overall();
        if lb <= 0.0 {
            0.0
        } else {
            (makespan - lb).max(0.0) / lb
        }
    }
}

/// Compute the lower bound for a problem under a scoring configuration.
pub fn lower_bound(spec: &ProblemSpec, sim: &SimConfig) -> LowerBound {
    let c = sim.cost.compute * sim.cost.spill_factor;
    let r = sim.cost.reduce;
    let n_sm = sim.n_sm.max(1);
    // Synchronous §3 model: the reduce phase sits on the SM's serial path.
    // Any pipelining (writer depth / co-resident CTAs) can overlap it, so
    // only the synchronous config may charge `r` per task in the work and
    // chain relaxations.
    let synchronous = sim.writer_depth == 0 && sim.occupancy <= 1;

    // --- work bound ----------------------------------------------------
    let total = spec.total_tiles();
    let work = if synchronous {
        // Tasks are atomic and identical: some SM runs >= ceil(T / n_sm)
        // of them back to back.
        total.div_ceil(n_sm) as f64 * (c + r)
    } else {
        total as f64 * c / n_sm as f64
    };

    // --- chain bound (DAG relaxation: one head, no cross-chain edges) ---
    let mut chain_dag = Dag::new();
    for kv in 0..spec.n_kv {
        let len = spec.chain_len(kv);
        if len == 0 {
            continue;
        }
        let mut prev = None;
        for _ in 0..len {
            let a = chain_dag.add_node();
            let b = chain_dag.add_node();
            chain_dag.add_edge(a, b, c, EdgeKind::Phase);
            let end = if synchronous {
                let e = chain_dag.add_node();
                chain_dag.add_edge(b, e, r, EdgeKind::Phase);
                e
            } else {
                b
            };
            if let Some(p) = prev {
                chain_dag.add_edge(p, a, 0.0, EdgeKind::Dependency);
            }
            prev = Some(end);
        }
        if !synchronous {
            // The chain's final fold cannot be overlapped by later compute.
            if let Some(p) = prev {
                let e = chain_dag.add_node();
                chain_dag.add_edge(p, e, r, EdgeKind::Phase);
            }
        }
    }
    let chain = chain_dag.critical_path().expect("chain relaxation is a path forest");

    // --- reduction bound (DAG relaxation: serialized dQ columns) --------
    let mut col_dag = Dag::new();
    for q in 0..spec.n_q {
        let k = (0..spec.n_kv).filter(|&kv| spec.live(kv, q)).count();
        if k == 0 {
            continue;
        }
        // One contribution must be computed before any fold, then the k
        // folds are serialized by determinism.
        let mut prev = col_dag.add_node();
        let first_fold = col_dag.add_node();
        col_dag.add_edge(prev, first_fold, c, EdgeKind::Phase);
        prev = first_fold;
        for _ in 0..k {
            let nxt = col_dag.add_node();
            col_dag.add_edge(prev, nxt, r, EdgeKind::Phase);
            prev = nxt;
        }
    }
    let reduction = col_dag.critical_path().expect("column relaxation is a path forest");

    LowerBound { work, chain, reduction }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, shift, symmetric_shift, MaskSpec};
    use crate::sim::simulate;

    #[test]
    fn shift_meets_the_bound_on_its_home_regime() {
        // Full mask, n_sm = n: the work bound is m·n·(c+r) and Shift
        // achieves it exactly — gap 0.
        let (n, m) = (8, 3);
        let spec = ProblemSpec::square(n, m, MaskSpec::full());
        let cfg = SimConfig::ideal(n);
        let lb = lower_bound(&spec, &cfg);
        assert!((lb.overall() - (m * n) as f64 * 1.25).abs() < 1e-9);
        let mk = simulate(&shift(&spec).unwrap(), &cfg).unwrap().makespan;
        assert!(lb.gap(mk) < 1e-9, "gap {}", lb.gap(mk));
    }

    #[test]
    fn symmetric_shift_meets_the_bound_on_even_causal() {
        let (n, m) = (8, 2);
        let spec = ProblemSpec::square(n, m, MaskSpec::causal());
        let cfg = SimConfig::ideal(n);
        let lb = lower_bound(&spec, &cfg);
        // ceil(m·n(n+1)/2 / n)·(c+r) = m(n+1)(c+r)/2 for even m·(n+1)... the
        // triangle total splits evenly here.
        let mk = simulate(&symmetric_shift(&spec), &cfg).unwrap().makespan;
        assert!(lb.gap(mk) < 1e-9, "lb {:?} vs makespan {mk}", lb);
    }

    #[test]
    fn bound_never_exceeds_a_real_makespan() {
        for n in [3usize, 5, 8, 12] {
            for m in [1usize, 2, 5] {
                for mask in [
                    MaskSpec::full(),
                    MaskSpec::causal(),
                    MaskSpec::sliding_window(2),
                    MaskSpec::document(vec![2]),
                ] {
                    for n_sm in [2usize, 4, 13] {
                        let spec = ProblemSpec::square(n, m, mask.clone());
                        let cfg = SimConfig::ideal(n_sm);
                        let lb = lower_bound(&spec, &cfg).overall();
                        let mk = simulate(&fa3(&spec, true), &cfg).unwrap().makespan;
                        assert!(
                            mk >= lb - 1e-9,
                            "n={n} m={m} {mask:?} n_sm={n_sm}: makespan {mk} < bound {lb}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn chain_bound_dominates_on_tall_causal_few_heads() {
        // One head, many SMs: the KV-0 chain (n tasks) is the floor.
        let spec = ProblemSpec::square(16, 1, MaskSpec::causal());
        let lb = lower_bound(&spec, &SimConfig::ideal(64));
        assert!((lb.chain - 16.0 * 1.25).abs() < 1e-9);
        assert!(lb.chain >= lb.work);
    }

    #[test]
    fn pipelined_bound_is_weaker_but_positive() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::full());
        let sync = lower_bound(&spec, &SimConfig::ideal(8));
        let mut piped_cfg = SimConfig::ideal(8);
        piped_cfg.writer_depth = 2;
        let piped = lower_bound(&spec, &piped_cfg);
        assert!(piped.overall() > 0.0);
        assert!(piped.overall() <= sync.overall() + 1e-9);
    }
}
