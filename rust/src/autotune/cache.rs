//! Persistent schedule cache: tuned schedules survive the process.
//!
//! Search is the expensive part of autotuning; the artifact it produces is
//! a small table of visit orders, pins, and reduction orders. This module
//! stores those tables as JSON (via the in-tree [`crate::util::json`])
//! keyed by [`super::fingerprint::WorkloadFingerprint::key`], so a second
//! `dash tune` on the same workload is a file read, not a search.
//!
//! Robustness rules: a missing or corrupt cache file is an empty cache
//! (never an error — the cache is an accelerator, not a dependency), and
//! every entry is re-validated against the §3.1 invariants on read, so a
//! hand-edited or stale entry degrades to a cache miss instead of smuggling
//! an illegal schedule into the pipeline.

use super::search::TuneResult;
use crate::schedule::{validate, Chain, MaskSpec, ProblemSpec, Schedule, ScheduleKind};
use crate::util::Json;
use crate::Result;
use std::path::{Path, PathBuf};

/// Default cache location for `dash tune` (relative to the working dir).
pub const DEFAULT_CACHE_PATH: &str = "tuned_schedules.json";

/// On-disk format version (bump on incompatible schema changes).
const FORMAT_VERSION: f64 = 1.0;

/// One cached tuning outcome.
#[derive(Debug, Clone)]
pub struct CachedSchedule {
    /// The reconstructed schedule (`kind == ScheduleKind::Tuned`).
    pub schedule: Schedule,
    /// Makespan recorded at tuning time (under the fingerprinted config).
    pub makespan: f64,
    /// Lower bound recorded at tuning time.
    pub lower_bound: f64,
    /// Name of the analytic seed the search started from.
    pub seed_name: String,
}

/// An insertion-ordered key -> entry map, JSON-backed.
#[derive(Debug)]
pub struct ScheduleCache {
    path: PathBuf,
    entries: Vec<(String, Json)>,
}

impl ScheduleCache {
    /// Open (or conceptually create) the cache at `path`. Missing or
    /// unparsable files — and files written by an incompatible format
    /// version — yield an empty cache.
    pub fn open(path: impl AsRef<Path>) -> Self {
        let path = path.as_ref().to_path_buf();
        let entries = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .filter(|doc| {
                doc.get("version").and_then(Json::as_f64) == Some(FORMAT_VERSION)
            })
            .and_then(|doc| {
                doc.get("entries").and_then(Json::as_obj).map(<[(String, Json)]>::to_vec)
            })
            .unwrap_or_default();
        Self { path, entries }
    }

    /// Number of stored entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True if no entries are stored.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up a fingerprint key; decode, cross-check against `spec`, and
    /// re-validate. Any mismatch is a miss.
    pub fn get(&self, key: &str, spec: &ProblemSpec) -> Option<CachedSchedule> {
        let cached = self.entry(key)?;
        if cached.schedule.spec != *spec {
            return None;
        }
        Some(cached)
    }

    /// Look up a key without a caller-spec cross-check (the entry is still
    /// decoded and re-validated against its *own* recorded spec). The
    /// warm-start path ([`super::fleet`]) uses this to read neighbor
    /// entries whose geometry intentionally differs from the target.
    pub fn entry(&self, key: &str) -> Option<CachedSchedule> {
        let entry = self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)?;
        let cached = decode_entry(entry)?;
        if validate(&cached.schedule).is_err() {
            return None;
        }
        Some(cached)
    }

    /// The stored keys, in insertion order — the haystack for
    /// [`super::fleet::nearest_neighbor`].
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(k, _)| k.as_str())
    }

    /// Insert or replace the entry for `key`.
    pub fn put(&mut self, key: &str, result: &TuneResult) {
        let value = encode_entry(result);
        match self.entries.iter_mut().find(|(k, _)| k == key) {
            Some((_, v)) => *v = value,
            None => self.entries.push((key.to_string(), value)),
        }
    }

    /// Write the cache back to disk: write a `.tmp` sibling, then rename
    /// over the target. Rename within one directory is atomic, so a batch
    /// run killed mid-save can never leave a torn cache file (which
    /// [`ScheduleCache::open`] would degrade to an empty cache, silently
    /// discarding every tuned schedule).
    pub fn save(&self) -> Result<()> {
        let doc = Json::Obj(vec![
            ("version".into(), Json::Num(FORMAT_VERSION)),
            ("entries".into(), Json::Obj(self.entries.clone())),
        ]);
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut tmp_name = self.path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        let tmp = PathBuf::from(tmp_name);
        std::fs::write(&tmp, doc.dump())?;
        std::fs::rename(&tmp, &self.path)?;
        Ok(())
    }

    /// Cache file location.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// Advisory file lock for a shared cache: `dash tune --queue` runs take it
/// before draining a queue into one cache so two concurrent batch runs
/// serialize their read-modify-write instead of losing each other's
/// entries. The lock is a `<cache>.lock` sibling created with
/// `create_new` (atomic on every platform we build for), holding the
/// owner's PID for post-mortem debugging; it is advisory — plain
/// `dash tune` single-point runs do not take it.
///
/// A lock whose file is older than [`CacheLock::STALE_AFTER`] is presumed
/// abandoned by a crashed holder (a clean holder removes it on drop) and
/// is stolen.
#[derive(Debug)]
pub struct CacheLock {
    path: PathBuf,
}

impl CacheLock {
    /// Age after which a lock file is treated as abandoned and stolen.
    pub const STALE_AFTER: std::time::Duration = std::time::Duration::from_secs(300);

    /// Acquire the lock guarding `cache_path`, waiting up to `timeout`.
    pub fn acquire(cache_path: &Path, timeout: std::time::Duration) -> Result<Self> {
        use std::io::Write;
        let mut lock_name = cache_path.as_os_str().to_owned();
        lock_name.push(".lock");
        let path = PathBuf::from(lock_name);
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let start = std::time::Instant::now();
        loop {
            match std::fs::OpenOptions::new().write(true).create_new(true).open(&path) {
                Ok(mut f) => {
                    let _ = write!(f, "{}", std::process::id());
                    return Ok(Self { path });
                }
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                    let stale = std::fs::metadata(&path)
                        .and_then(|m| m.modified())
                        .ok()
                        .and_then(|m| m.elapsed().ok())
                        .is_some_and(|age| age > Self::STALE_AFTER);
                    if stale {
                        // Steal: the holder crashed without unlinking.
                        let _ = std::fs::remove_file(&path);
                        continue;
                    }
                    anyhow::ensure!(
                        start.elapsed() < timeout,
                        "cache lock {} is held by another tuning run (remove the file \
                         if its owner is gone)",
                        path.display()
                    );
                    std::thread::sleep(std::time::Duration::from_millis(50));
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Lock file location.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for CacheLock {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.path);
    }
}

fn encode_entry(result: &TuneResult) -> Json {
    let s = &result.schedule;
    let spec = Json::Obj(vec![
        ("n_kv".into(), Json::Num(s.spec.n_kv as f64)),
        ("n_q".into(), Json::Num(s.spec.n_q as f64)),
        ("n_heads".into(), Json::Num(s.spec.n_heads as f64)),
        ("mask".into(), Json::Str(s.spec.mask.name().into())),
    ]);
    let chains = Json::Arr(
        s.chains
            .iter()
            .map(|c| {
                Json::Obj(vec![
                    ("head".into(), Json::Num(c.head as f64)),
                    ("kv".into(), Json::Num(c.kv as f64)),
                    (
                        "q".into(),
                        Json::Arr(c.q_order.iter().map(|&q| Json::Num(q as f64)).collect()),
                    ),
                    ("compute_scale".into(), Json::Num(c.compute_scale)),
                    ("reduce_scale".into(), Json::Num(c.reduce_scale)),
                    ("ordered".into(), Json::Bool(c.ordered)),
                ])
            })
            .collect(),
    );
    let pinned = Json::Arr(
        s.pinned
            .iter()
            .map(|p| match p {
                Some(sm) => Json::Num(*sm as f64),
                None => Json::Null,
            })
            .collect(),
    );
    let reduction = Json::Arr(
        s.reduction_order
            .iter()
            .map(|o| Json::Arr(o.iter().map(|&kv| Json::Num(kv as f64)).collect()))
            .collect(),
    );
    Json::Obj(vec![
        ("spec".into(), spec),
        ("wave_width".into(), Json::Num(s.wave_width as f64)),
        ("chains".into(), chains),
        ("pinned".into(), pinned),
        ("reduction_order".into(), reduction),
        ("makespan".into(), Json::Num(result.makespan)),
        ("lower_bound".into(), Json::Num(result.bound.overall())),
        ("seed".into(), Json::Str(result.seed_kind.name().into())),
    ])
}

fn decode_entry(entry: &Json) -> Option<CachedSchedule> {
    let spec_j = entry.get("spec")?;
    let mask = MaskSpec::parse(spec_j.get("mask")?.as_str()?)?;
    let spec = ProblemSpec {
        n_kv: spec_j.get("n_kv")?.as_usize()?,
        n_q: spec_j.get("n_q")?.as_usize()?,
        n_heads: spec_j.get("n_heads")?.as_usize()?,
        mask,
    };

    let mut chains = Vec::new();
    for c in entry.get("chains")?.as_arr()? {
        let q_order = c
            .get("q")?
            .as_arr()?
            .iter()
            .map(Json::as_usize)
            .collect::<Option<Vec<_>>>()?;
        chains.push(Chain {
            head: c.get("head")?.as_usize()?,
            kv: c.get("kv")?.as_usize()?,
            q_order,
            compute_scale: c.get("compute_scale")?.as_f64()?,
            reduce_scale: c.get("reduce_scale")?.as_f64()?,
            ordered: matches!(c.get("ordered")?, Json::Bool(true)),
        });
    }

    let pinned = entry
        .get("pinned")?
        .as_arr()?
        .iter()
        .map(|p| match p {
            Json::Null => Some(None),
            other => other.as_usize().map(Some),
        })
        .collect::<Option<Vec<_>>>()?;
    if pinned.len() != chains.len() {
        return None;
    }

    let reduction_order = entry
        .get("reduction_order")?
        .as_arr()?
        .iter()
        .map(|o| o.as_arr().and_then(|a| a.iter().map(Json::as_usize).collect()))
        .collect::<Option<Vec<Vec<usize>>>>()?;

    let schedule = Schedule {
        spec,
        kind: ScheduleKind::Tuned,
        chains,
        pinned,
        wave_width: entry.get("wave_width")?.as_usize()?,
        reduction_order,
        cluster: None,
    };
    Some(CachedSchedule {
        schedule,
        makespan: entry.get("makespan")?.as_f64()?,
        lower_bound: entry.get("lower_bound")?.as_f64()?,
        seed_name: entry.get("seed")?.as_str()?.to_string(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autotune::{tune, TuneOptions, WorkloadFingerprint};
    use crate::sim::SimConfig;

    fn tmp_path(tag: &str) -> PathBuf {
        // A per-test atomic counter joins the PID: PIDs get reused across
        // CI container runs, and `cargo test` runs tests in parallel, so a
        // PID+tag path alone can collide with a leftover file from an
        // earlier run of the same test binary.
        use std::sync::atomic::{AtomicUsize, Ordering};
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let serial = NEXT.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir()
            .join(format!("dash-cache-{}-{serial}-{tag}.json", std::process::id()))
    }

    #[test]
    fn round_trip_preserves_the_schedule() {
        let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
        let sim = SimConfig::ideal(4);
        let result = tune(&spec, &TuneOptions { budget: 30, seed: 1, sim, batch: 1, threads: 1 })
            .unwrap();
        let key = WorkloadFingerprint::new(&spec, &sim).key();

        let path = tmp_path("roundtrip");
        let mut cache = ScheduleCache::open(&path);
        cache.put(&key, &result);
        cache.save().unwrap();

        let reloaded = ScheduleCache::open(&path);
        let hit = reloaded.get(&key, &spec).expect("entry must round-trip");
        assert_eq!(hit.makespan, result.makespan);
        assert_eq!(hit.schedule.chains.len(), result.schedule.chains.len());
        assert_eq!(hit.schedule.reduction_order, result.schedule.reduction_order);
        assert_eq!(hit.schedule.pinned, result.schedule.pinned);
        assert_eq!(hit.seed_name, result.seed_kind.name());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn wrong_spec_is_a_miss() {
        let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
        let sim = SimConfig::ideal(4);
        let result = tune(&spec, &TuneOptions { budget: 10, seed: 1, sim, batch: 1, threads: 1 })
            .unwrap();
        let key = WorkloadFingerprint::new(&spec, &sim).key();
        let mut cache = ScheduleCache::open(tmp_path("wrongspec"));
        cache.put(&key, &result);
        let other = ProblemSpec::square(6, 3, MaskSpec::causal());
        assert!(cache.get(&key, &other).is_none());
        assert!(cache.get(&key, &spec).is_some());
    }

    #[test]
    fn new_mask_shapes_round_trip_and_key_distinctly() {
        // Satellite/acceptance: swa and doc workloads must persist, reload,
        // and never collide with each other or with causal entries.
        let sim = SimConfig::ideal(4);
        let path = tmp_path("maskshapes");
        let mut cache = ScheduleCache::open(&path);
        let specs = [
            ProblemSpec::square(6, 2, MaskSpec::sliding_window(2)),
            ProblemSpec::square(6, 2, MaskSpec::document(vec![2, 4])),
            ProblemSpec::square(6, 2, MaskSpec::causal()),
        ];
        let mut keys = Vec::new();
        for spec in &specs {
            let result =
                tune(spec, &TuneOptions { budget: 10, seed: 1, sim, batch: 1, threads: 1 })
                    .unwrap();
            let key = WorkloadFingerprint::new(spec, &sim).key();
            cache.put(&key, &result);
            keys.push(key);
        }
        let mut dedup = keys.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), specs.len(), "mask shapes must key distinctly: {keys:?}");
        cache.save().unwrap();
        let reloaded = ScheduleCache::open(&path);
        for (spec, key) in specs.iter().zip(&keys) {
            let hit = reloaded.get(key, spec).expect("mask spec must round-trip");
            assert_eq!(hit.schedule.spec, *spec);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_is_an_empty_cache() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "{not json").unwrap();
        let cache = ScheduleCache::open(&path);
        assert!(cache.is_empty());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn incompatible_version_is_an_empty_cache() {
        let path = tmp_path("version");
        std::fs::write(&path, r#"{"version":99,"entries":{"k":{"bogus":1}}}"#).unwrap();
        let cache = ScheduleCache::open(&path);
        assert!(cache.is_empty(), "future-format entries must not be served");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_is_an_empty_cache() {
        let cache = ScheduleCache::open(tmp_path("definitely-missing"));
        assert!(cache.is_empty());
        assert_eq!(cache.len(), 0);
    }

    #[test]
    fn save_is_write_temp_then_rename() {
        let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
        let sim = SimConfig::ideal(4);
        let result = tune(&spec, &TuneOptions { budget: 10, seed: 1, sim, batch: 1, threads: 1 })
            .unwrap();
        let key = WorkloadFingerprint::new(&spec, &sim).key();
        let path = tmp_path("atomic");
        let mut cache = ScheduleCache::open(&path);
        cache.put(&key, &result);
        cache.save().unwrap();
        // No .tmp sibling survives a clean save, and the target parses.
        let mut tmp_name = path.as_os_str().to_owned();
        tmp_name.push(".tmp");
        assert!(!PathBuf::from(tmp_name).exists(), "temp file must be renamed away");
        assert_eq!(ScheduleCache::open(&path).len(), 1);
        // Saving over an existing file goes through the same rename.
        cache.save().unwrap();
        assert_eq!(ScheduleCache::open(&path).len(), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn entry_skips_the_spec_cross_check_but_still_validates() {
        let spec = ProblemSpec::square(6, 2, MaskSpec::causal());
        let sim = SimConfig::ideal(4);
        let result = tune(&spec, &TuneOptions { budget: 10, seed: 1, sim, batch: 1, threads: 1 })
            .unwrap();
        let key = WorkloadFingerprint::new(&spec, &sim).key();
        let mut cache = ScheduleCache::open(tmp_path("entry"));
        cache.put(&key, &result);
        // `get` against a different spec misses; `entry` still serves the
        // (validated) schedule for warm-start transfer.
        let other = ProblemSpec::square(6, 3, MaskSpec::causal());
        assert!(cache.get(&key, &other).is_none());
        let hit = cache.entry(&key).expect("entry ignores the caller spec");
        assert_eq!(hit.schedule.spec, spec);
        assert_eq!(cache.keys().collect::<Vec<_>>(), vec![key.as_str()]);
    }

    #[test]
    fn lock_excludes_a_second_holder_and_releases_on_drop() {
        let cache_path = tmp_path("locked");
        let lock = CacheLock::acquire(&cache_path, std::time::Duration::ZERO).unwrap();
        assert!(lock.path().exists());
        let contended = CacheLock::acquire(&cache_path, std::time::Duration::ZERO);
        assert!(contended.is_err(), "held lock must not be re-acquired");
        let lock_file = lock.path().to_path_buf();
        drop(lock);
        assert!(!lock_file.exists(), "drop must remove the lock file");
        // Re-acquirable after release.
        let again = CacheLock::acquire(&cache_path, std::time::Duration::ZERO).unwrap();
        drop(again);
    }
}
