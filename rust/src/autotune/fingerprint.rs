//! Workload fingerprints: the persistent-cache key for tuned schedules.
//!
//! A tuned schedule is only reusable for the exact optimization problem it
//! was searched on: the tile geometry, the head count, the mask, the SM
//! count, the cost model the simulator scored candidates with, *and* the
//! hardware profile those costs were derived from. The fingerprint folds
//! all of those into a short stable string so cache hits are
//! exact-by-construction: a changed cost model can never smuggle a stale
//! schedule back in, and — because the
//! [`crate::hw::GpuProfile::fingerprint`] is threaded through
//! [`SimConfig::hw_fingerprint`] — a schedule tuned for one GPU can never
//! serve another, even when the per-cycle cost numbers coincide (e.g. two
//! parts differing only in clock).

use crate::schedule::{MaskSpec, ProblemSpec};
use crate::sim::SimConfig;
use crate::util::fnv1a_words;

/// Identity of one tuning problem.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadFingerprint {
    /// KV tiles per head.
    pub n_kv: usize,
    /// Q tiles per head.
    pub n_q: usize,
    /// Head instances.
    pub n_heads: usize,
    /// Mask shape. Data-dependent masks (document boundaries, sparse
    /// bitmaps) enter the key through their content hash
    /// ([`MaskSpec::fingerprint`]), so two different layouts never share
    /// a cached schedule. The hash is canonical over boundaries, so a
    /// serving step compiled by [`crate::traceload::compile`] keys
    /// identically to the same layout spelled by hand (`doc:b1,b2,...`)
    /// — trace workloads share the tuning cache with hand-built ones for
    /// free.
    pub mask: MaskSpec,
    /// SMs the schedule was tuned for.
    pub n_sm: usize,
    /// FNV-1a hash over the scoring [`SimConfig`]'s cost model (compute,
    /// reduce, spill, L2 latencies), pipeline shape (writer depth,
    /// occupancy), and the hardware-profile identity
    /// ([`SimConfig::hw_fingerprint`]; 0 for abstract costs).
    pub cost_hash: u64,
    /// Devices the schedule was tuned for (1 for single-GPU problems —
    /// the historical key format, which must not change).
    pub n_devices: usize,
    /// Cluster topology identity ([`crate::hw::ClusterProfile::fingerprint`];
    /// 0 for single-GPU or fully abstract clusters). A schedule tuned on
    /// one interconnect can never serve another.
    pub cluster_hash: u64,
}

impl WorkloadFingerprint {
    /// Fingerprint a (problem, scoring config) pair.
    pub fn new(spec: &ProblemSpec, sim: &SimConfig) -> Self {
        // Word order is part of the persisted-key format — append only.
        let h = fnv1a_words([
            sim.cost.compute.to_bits(),
            sim.cost.reduce.to_bits(),
            sim.cost.spill_factor.to_bits(),
            sim.cost.l2.n_segments as u64,
            sim.cost.l2.local_latency.to_bits(),
            sim.cost.l2.remote_latency.to_bits(),
            sim.writer_depth as u64,
            sim.occupancy as u64,
            sim.hw_fingerprint,
        ]);
        Self {
            n_kv: spec.n_kv,
            n_q: spec.n_q,
            n_heads: spec.n_heads,
            mask: spec.mask.clone(),
            n_sm: sim.n_sm,
            cost_hash: h,
            n_devices: 1,
            cluster_hash: 0,
        }
    }

    /// Re-key the fingerprint for a multi-device tuning problem. The
    /// single-GPU identity (`n_devices == 1`, `cluster_hash == 0`) is the
    /// default from [`WorkloadFingerprint::new`] and keeps the historical
    /// key format untouched.
    pub fn with_cluster(mut self, n_devices: usize, cluster_hash: u64) -> Self {
        self.n_devices = n_devices;
        self.cluster_hash = cluster_hash;
        self
    }

    /// Stable cache key, e.g. `16x16-h8-causal-sm13-9b3a...`. Multi-device
    /// problems append `-dev<n>x<cluster_hash>`; the single-GPU key is
    /// byte-identical to the pre-cluster format so existing caches stay
    /// valid.
    pub fn key(&self) -> String {
        let mut k = format!(
            "{}x{}-h{}-{}-sm{}-{:016x}",
            self.n_kv,
            self.n_q,
            self.n_heads,
            self.mask.fingerprint(),
            self.n_sm,
            self.cost_hash
        );
        if self.n_devices != 1 || self.cluster_hash != 0 {
            k.push_str(&format!("-dev{}x{:016x}", self.n_devices, self.cluster_hash));
        }
        k
    }

}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CostModel, SimConfig};

    #[test]
    fn identical_problems_share_a_key() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let cfg = SimConfig::ideal(8);
        assert_eq!(
            WorkloadFingerprint::new(&spec, &cfg).key(),
            WorkloadFingerprint::new(&spec, &cfg).key()
        );
    }

    #[test]
    fn geometry_and_cost_changes_change_the_key() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let cfg = SimConfig::ideal(8);
        let base = WorkloadFingerprint::new(&spec, &cfg).key();

        let other_spec = ProblemSpec::square(8, 5, MaskSpec::causal());
        assert_ne!(WorkloadFingerprint::new(&other_spec, &cfg).key(), base);

        let full = ProblemSpec::square(8, 4, MaskSpec::full());
        assert_ne!(WorkloadFingerprint::new(&full, &cfg).key(), base);

        // New mask shapes must re-key — including content changes inside
        // one shape (different windows, different document layouts).
        let swa4 = ProblemSpec::square(8, 4, MaskSpec::sliding_window(4));
        let swa5 = ProblemSpec::square(8, 4, MaskSpec::sliding_window(5));
        assert_ne!(WorkloadFingerprint::new(&swa4, &cfg).key(), base);
        assert_ne!(
            WorkloadFingerprint::new(&swa4, &cfg).key(),
            WorkloadFingerprint::new(&swa5, &cfg).key()
        );
        let doc_a = ProblemSpec::square(8, 4, MaskSpec::document(vec![3]));
        let doc_b = ProblemSpec::square(8, 4, MaskSpec::document(vec![4]));
        assert_ne!(
            WorkloadFingerprint::new(&doc_a, &cfg).key(),
            WorkloadFingerprint::new(&doc_b, &cfg).key()
        );

        let mut other_cfg = cfg;
        other_cfg.cost = CostModel { reduce: 0.5, ..cfg.cost };
        assert_ne!(WorkloadFingerprint::new(&spec, &other_cfg).key(), base);

        let mut more_sms = cfg;
        more_sms.n_sm = 13;
        assert_ne!(WorkloadFingerprint::new(&spec, &more_sms).key(), base);
    }

    #[test]
    fn hardware_identity_changes_the_key_even_with_equal_costs() {
        // Two parts with identical per-cycle costs (e.g. a clock-only
        // difference) must still key separately: the profile fingerprint
        // is part of the workload identity.
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let cfg = SimConfig::ideal(8);
        let mut other_hw = cfg;
        other_hw.hw_fingerprint = 0xDEAD_BEEF;
        assert_ne!(
            WorkloadFingerprint::new(&spec, &other_hw).key(),
            WorkloadFingerprint::new(&spec, &cfg).key()
        );
    }

    #[test]
    fn cluster_identity_rekeys_without_touching_single_gpu_keys() {
        let spec = ProblemSpec::square(8, 4, MaskSpec::causal());
        let cfg = SimConfig::ideal(8);
        let base = WorkloadFingerprint::new(&spec, &cfg);
        let single = base.clone().key();
        assert!(!single.contains("dev"), "single-GPU keys keep the historical format");
        let two = base.clone().with_cluster(2, 0xABCD).key();
        assert!(two.starts_with(&single) && two.contains("-dev2x"));
        // Device count and topology each re-key.
        assert_ne!(two, base.clone().with_cluster(4, 0xABCD).key());
        assert_ne!(two, base.clone().with_cluster(2, 0xABCE).key());
        // Degenerate cluster annotation (1 device, abstract link) is
        // identical to the single-GPU key: same tuning problem.
        assert_eq!(base.clone().with_cluster(1, 0).key(), single);
    }

    #[test]
    fn trace_compiled_steps_share_hand_built_document_keys() {
        // A batched serving step is an ordinary document-mask problem: its
        // fingerprint must be byte-identical to the same boundaries
        // spelled by hand (the `doc:b1,b2,...` CLI grammar), so trace
        // workloads hit cache entries tuned for hand-built masks and vice
        // versa.
        let trace = crate::traceload::generate(&crate::traceload::TraceSpec::smoke(42)).unwrap();
        let steps =
            crate::traceload::compile(&trace, &crate::traceload::BatchConfig::new(3, 4)).unwrap();
        let step = steps.iter().max_by_key(|s| s.slices.len()).unwrap();
        assert!(step.slices.len() > 1, "smoke trace batches at least one step");
        let spelled = format!(
            "doc:{}",
            step.slices[1..]
                .iter()
                .map(|s| s.start_tile.to_string())
                .collect::<Vec<_>>()
                .join(",")
        );
        let hand = MaskSpec::parse(&spelled).expect("spelled boundaries parse");
        let hand_spec = ProblemSpec::square(step.total_tiles(), step.spec.n_heads, hand);
        let cfg = SimConfig::ideal(step.total_tiles());
        assert_eq!(
            WorkloadFingerprint::new(&step.spec, &cfg).key(),
            WorkloadFingerprint::new(&hand_spec, &cfg).key(),
            "trace-compiled and hand-built document masks must share one cache key"
        );
    }

    #[test]
    fn key_is_filesystem_safe() {
        for mask in [
            MaskSpec::full(),
            MaskSpec::causal_with_offset(-2),
            MaskSpec::sliding_window(4),
            MaskSpec::document(vec![5, 9]),
            MaskSpec::block_sparse(2, 2, vec![true, false, true, true]),
        ] {
            let spec = ProblemSpec::square(32, 8, mask);
            let k = WorkloadFingerprint::new(&spec, &SimConfig::ideal(13)).key();
            assert!(
                k.chars().all(|c| c.is_ascii_alphanumeric() || c == '-' || c == 'x'),
                "{k}"
            );
        }
    }
}
