//! Greedy-seeded local search over the schedule space.
//!
//! The tuner never starts cold: it seeds from every analytic generator that
//! is defined for the problem's mask (FA3, Descending, LPT, and Shift /
//! Symmetric Shift on their home masks), scores each on the target
//! [`SimConfig`], and keeps the best as the incumbent. Local search then
//! applies the [`super::moves`] operators — chain swaps, visit-order
//! rotations, reduction-order repairs — accepting any candidate that is
//! legal ([`crate::schedule::validate`]), simulates without deadlock, and
//! does not regress the makespan. Two consequences:
//!
//! 1. a tuned schedule is **never worse than the best analytic schedule**
//!    under the scoring config (the seeds are reachable outcomes), and
//! 2. every accepted candidate is a fully concrete, legal, deterministic
//!    schedule — there is no repair debt at the end of search.
//!
//! Search stops early when the incumbent meets the [`super::oracle`] lower
//! bound (a proof of optimality for the modelled machine).

use super::oracle::{lower_bound, LowerBound};
use crate::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, validate, ProblemSpec, Schedule,
    ScheduleKind,
};
use crate::sim::{simulate, SimConfig};
use crate::util::DetRng;
use crate::Result;

/// Tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct TuneOptions {
    /// Local-search proposals to evaluate.
    pub budget: usize,
    /// RNG seed (the whole search is deterministic given options + spec).
    pub seed: u64,
    /// Scoring configuration: machine width and cost model. Span recording
    /// is forced off internally.
    pub sim: SimConfig,
}

impl TuneOptions {
    /// Defaults for interactive `dash tune` runs.
    pub fn new(sim: SimConfig) -> Self {
        Self { budget: 400, seed: 42, sim }
    }

    /// A small-budget configuration for callers that need a tuned schedule
    /// inline (figure harness, `--schedule tuned`) without a full search.
    pub fn quick(sim: SimConfig) -> Self {
        Self { budget: 48, seed: 42, sim }
    }
}

/// Outcome of one tuning run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// The synthesized schedule (`kind == ScheduleKind::Tuned`).
    pub schedule: Schedule,
    /// Its simulated makespan under the scoring config.
    pub makespan: f64,
    /// Which analytic seed won the greedy phase.
    pub seed_kind: ScheduleKind,
    /// The best analytic makespan (the search starting point).
    pub seed_makespan: f64,
    /// The lower-bound oracle's verdict for this problem.
    pub bound: LowerBound,
    /// Proposals actually evaluated (legal + simulated).
    pub evaluated: usize,
    /// Proposals accepted as strict improvements.
    pub improvements: usize,
}

impl TuneResult {
    /// Relative optimality gap vs the lower bound (0 = provably optimal).
    pub fn gap(&self) -> f64 {
        self.bound.gap(self.makespan)
    }

    /// Relative improvement over the best analytic seed.
    pub fn improvement(&self) -> f64 {
        if self.seed_makespan <= 0.0 {
            0.0
        } else {
            (self.seed_makespan - self.makespan) / self.seed_makespan
        }
    }
}

/// The analytic generators applicable to `spec` on an `n_sm` machine.
/// Always non-empty (FA3, Descending, LPT, and Symmetric Shift's pairing
/// fallback are mask-agnostic); Shift joins only when the live-tile
/// structure supports its conflict-free cycle.
pub fn analytic_seeds(spec: &ProblemSpec, n_sm: usize) -> Vec<Schedule> {
    let mut seeds =
        vec![fa3(spec, true), descending(spec), lpt_schedule(spec, n_sm), symmetric_shift(spec)];
    if let Ok(s) = shift(spec) {
        seeds.push(s);
    }
    seeds
}

/// Run the tuner. Errors only if no analytic seed yields a legal,
/// simulatable schedule (which cannot happen for non-degenerate specs —
/// FA3 with dynamic assignment is deadlock-free on any machine width).
pub fn tune(spec: &ProblemSpec, opts: &TuneOptions) -> Result<TuneResult> {
    let mut sim_cfg = opts.sim;
    sim_cfg.record_spans = false;
    let bound = lower_bound(spec, &sim_cfg);

    // --- greedy seeding --------------------------------------------------
    // Pinned closed-form schedules can deadlock off their home regime
    // (e.g. Shift folded onto n_sm < n); such seeds are skipped, not fatal.
    let mut best: Option<(Schedule, f64)> = None;
    for seed in analytic_seeds(spec, sim_cfg.n_sm) {
        if validate(&seed).is_err() {
            continue;
        }
        let Ok(run) = simulate(&seed, &sim_cfg) else { continue };
        if best.as_ref().map_or(true, |(_, t)| run.makespan < *t) {
            best = Some((seed, run.makespan));
        }
    }
    let (mut incumbent, mut incumbent_t) =
        best.ok_or_else(|| anyhow::anyhow!("no analytic seed is feasible for {spec:?}"))?;
    let seed_kind = incumbent.kind;
    let seed_makespan = incumbent_t;
    incumbent.kind = ScheduleKind::Tuned;

    // --- local search -----------------------------------------------------
    let mut rng = DetRng::new(opts.seed ^ 0xDA5_11_5C_4ED);
    let mut evaluated = 0usize;
    let mut improvements = 0usize;
    for _ in 0..opts.budget {
        if incumbent_t <= bound.overall() + 1e-9 {
            break; // certified optimal — nothing left to find
        }
        let Some(candidate) = super::moves::propose(&incumbent, &mut rng, &sim_cfg) else {
            continue;
        };
        if validate(&candidate).is_err() {
            continue;
        }
        let Ok(run) = simulate(&candidate, &sim_cfg) else { continue };
        evaluated += 1;
        // Accept non-regressions: equal-makespan drift lets search cross
        // plateaus (e.g. a pin swap that only pays off after a rotation).
        if run.makespan <= incumbent_t + 1e-12 {
            if run.makespan < incumbent_t - 1e-12 {
                improvements += 1;
            }
            incumbent = candidate;
            incumbent_t = run.makespan;
        }
    }

    Ok(TuneResult {
        schedule: incumbent,
        makespan: incumbent_t,
        seed_kind,
        seed_makespan,
        bound,
        evaluated,
        improvements,
    })
}

/// Convenience for call sites that accept a [`ScheduleKind`] and must map
/// `Tuned` to a concrete schedule without running a full `dash tune`
/// session: consult the default on-disk cache, else quick-tune inline
/// (without writing the cache — only `dash tune` persists results).
pub fn tuned_schedule_for(spec: &ProblemSpec, sim: &SimConfig) -> Schedule {
    let fp = super::fingerprint::WorkloadFingerprint::new(spec, sim);
    let cache = super::cache::ScheduleCache::open(super::cache::DEFAULT_CACHE_PATH);
    if let Some(hit) = cache.get(&fp.key(), spec) {
        return hit.schedule;
    }
    // Be loud about the fallback: a quick-tune result is NOT the schedule a
    // previous full `dash tune` may have reported under other options.
    eprintln!(
        "note: no cached tuned schedule for {} in {}; quick-tuning inline \
         (budget {}) — run `dash tune` to search properly and persist",
        fp.key(),
        super::cache::DEFAULT_CACHE_PATH,
        TuneOptions::quick(*sim).budget
    );
    tune(spec, &TuneOptions::quick(*sim))
        .expect("quick tuning always has a feasible FA3 seed")
        .schedule
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(n_sm: usize, budget: usize) -> TuneOptions {
        TuneOptions { budget, seed: 7, sim: SimConfig::ideal(n_sm) }
    }

    #[test]
    fn tuned_never_loses_to_analytic_seeds() {
        use crate::schedule::MaskSpec;
        for mask in [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::sliding_window(3),
            MaskSpec::document(vec![3]),
        ] {
            for (n, n_sm) in [(6usize, 6usize), (8, 4), (5, 13)] {
                let spec = ProblemSpec::square(n, 2, mask.clone());
                let r = tune(&spec, &opts(n_sm, 60)).unwrap();
                assert!(
                    r.makespan <= r.seed_makespan + 1e-9,
                    "{mask:?} n={n} n_sm={n_sm}: tuned {} vs seed {}",
                    r.makespan,
                    r.seed_makespan
                );
                assert!(r.makespan >= r.bound.overall() - 1e-9);
                validate(&r.schedule).unwrap();
                assert_eq!(r.schedule.kind, ScheduleKind::Tuned);
            }
        }
    }

    #[test]
    fn home_regimes_certify_optimal_and_skip_search() {
        // Shift / Symmetric Shift seeds already meet the bound, so zero
        // proposals should be evaluated.
        use crate::schedule::MaskSpec;
        let full = tune(&ProblemSpec::square(8, 3, MaskSpec::full()), &opts(8, 100)).unwrap();
        assert!(full.gap() < 1e-9);
        assert_eq!(full.evaluated, 0);
        let causal =
            tune(&ProblemSpec::square(8, 2, MaskSpec::causal()), &opts(8, 100)).unwrap();
        assert!(causal.gap() < 1e-9);
        assert_eq!(causal.evaluated, 0);
    }

    #[test]
    fn search_is_deterministic() {
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(7, 3, MaskSpec::causal());
        let a = tune(&spec, &opts(5, 80)).unwrap();
        let b = tune(&spec, &opts(5, 80)).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.schedule.reduction_order, b.schedule.reduction_order);
        assert_eq!(
            a.schedule.chains.iter().map(|c| (c.head, c.kv)).collect::<Vec<_>>(),
            b.schedule.chains.iter().map(|c| (c.head, c.kv)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn off_regime_search_improves_on_the_seed_sometimes() {
        // Odd tiles, mismatched SM count: the analytic formulas are out of
        // their element. The tuner must at minimum hold the line; assert
        // it evaluated real candidates.
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let r = tune(&spec, &opts(5, 150)).unwrap();
        assert!(
            r.evaluated > 0 || r.gap() < 1e-9,
            "off-regime search should explore unless the seed is already optimal"
        );
        assert!(r.makespan <= r.seed_makespan + 1e-9);
    }
}
