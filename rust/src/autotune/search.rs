//! Greedy-seeded local search over the schedule space.
//!
//! The tuner never starts cold: it seeds from every analytic generator that
//! is defined for the problem's mask (FA3, Descending, LPT, and Shift /
//! Symmetric Shift on their home masks), scores each on the target
//! [`SimConfig`], and keeps the best as the incumbent. Local search then
//! applies the [`super::moves`] operators — chain swaps, visit-order
//! rotations, reduction-order repairs — accepting any candidate that is
//! legal ([`crate::schedule::validate`]), simulates without deadlock, and
//! does not regress the makespan. Two consequences:
//!
//! 1. a tuned schedule is **never worse than the best analytic schedule**
//!    under the scoring config (the seeds are reachable outcomes), and
//! 2. every accepted candidate is a fully concrete, legal, deterministic
//!    schedule — there is no repair debt at the end of search.
//!
//! Search stops early when the incumbent meets the [`super::oracle`] lower
//! bound (a proof of optimality for the modelled machine).
//!
//! # Batched evaluation
//!
//! Each round draws `batch` proposals *serially* from the single RNG —
//! the proposal stream depends only on `(budget, batch, seed)` — then
//! scores them concurrently via [`crate::sim::simulate_batch`] and accepts
//! the winner by smallest `(makespan, proposal index)`. Because the winner
//! rule is a total order over the round and batch results come back in
//! input order, the search trajectory is bitwise-identical at any
//! `threads` setting; `batch = 1` reproduces the classic serial
//! propose-one/score-one loop exactly. Seed scoring fans out the same way.

use super::oracle::{lower_bound, LowerBound};
use crate::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, validate, ProblemSpec, Schedule,
    ScheduleKind,
};
use crate::sim::{simulate_batch, SimConfig, SimError, SimResult, Simulator};
use crate::util::DetRng;
use crate::Result;

/// Tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct TuneOptions {
    /// Local-search proposals to evaluate.
    pub budget: usize,
    /// RNG seed (the whole search is deterministic given options + spec).
    pub seed: u64,
    /// Scoring configuration: machine width and cost model. Span recording
    /// is forced off internally.
    pub sim: SimConfig,
    /// Proposals drawn and scored per search round (clamped to >= 1).
    /// Changes the trajectory (a round accepts only its best candidate);
    /// `1` is the classic serial loop.
    pub batch: usize,
    /// Worker threads for candidate/seed scoring: `0` = all host cores,
    /// `1` = serial in the calling thread. Never changes the result.
    pub threads: usize,
}

impl TuneOptions {
    /// Defaults for interactive `dash tune` runs: batched rounds of 8,
    /// scored across all host cores.
    pub fn new(sim: SimConfig) -> Self {
        Self { budget: 400, seed: 42, sim, batch: 8, threads: 0 }
    }

    /// A small-budget configuration for callers that need a tuned schedule
    /// inline (figure harness, `--schedule tuned`) without a full search.
    /// Serial (`batch = 1`, `threads = 1`): these call sites often already
    /// run inside a sweep-level `par_map` fan-out.
    pub fn quick(sim: SimConfig) -> Self {
        Self { budget: 48, seed: 42, sim, batch: 1, threads: 1 }
    }
}

/// Outcome of one tuning run.
#[derive(Debug, Clone)]
pub struct TuneResult {
    /// The synthesized schedule (`kind == ScheduleKind::Tuned`).
    pub schedule: Schedule,
    /// Its simulated makespan under the scoring config.
    pub makespan: f64,
    /// Which analytic seed won the greedy phase.
    pub seed_kind: ScheduleKind,
    /// The best analytic makespan (the search starting point).
    pub seed_makespan: f64,
    /// The lower-bound oracle's verdict for this problem.
    pub bound: LowerBound,
    /// Proposals actually evaluated (legal + simulated).
    pub evaluated: usize,
    /// Proposals accepted as strict improvements.
    pub improvements: usize,
    /// Proposals dropped before scoring: the move generator returned
    /// nothing, or the candidate failed [`crate::schedule::validate`].
    pub skipped_invalid: usize,
    /// Proposals that validated but failed simulation (deadlock).
    pub skipped_sim: usize,
}

impl TuneResult {
    /// Relative optimality gap vs the lower bound (0 = provably optimal).
    pub fn gap(&self) -> f64 {
        self.bound.gap(self.makespan)
    }

    /// Relative improvement over the best analytic seed.
    pub fn improvement(&self) -> f64 {
        if self.seed_makespan <= 0.0 {
            0.0
        } else {
            (self.seed_makespan - self.makespan) / self.seed_makespan
        }
    }
}

/// The analytic generators applicable to `spec` on an `n_sm` machine.
/// Always non-empty (FA3, Descending, LPT, and Symmetric Shift's pairing
/// fallback are mask-agnostic); Shift joins only when the live-tile
/// structure supports its conflict-free cycle.
pub fn analytic_seeds(spec: &ProblemSpec, n_sm: usize) -> Vec<Schedule> {
    let mut seeds =
        vec![fa3(spec, true), descending(spec), lpt_schedule(spec, n_sm), symmetric_shift(spec)];
    if let Ok(s) = shift(spec) {
        seeds.push(s);
    }
    seeds
}

/// Score `candidates` in input order: serial through the caller's reused
/// [`Simulator`] when `threads == 1` (or there is at most one candidate),
/// else fanned out via [`simulate_batch`]. Both paths are bitwise-equal.
fn score(
    candidates: &[Schedule],
    cfg: &SimConfig,
    threads: usize,
    sim: &mut Simulator,
) -> Vec<std::result::Result<SimResult, SimError>> {
    if threads == 1 || candidates.len() <= 1 {
        candidates.iter().map(|s| sim.run(s, cfg)).collect()
    } else {
        simulate_batch(candidates, cfg, threads)
    }
}

/// Run the tuner. Errors only if no analytic seed yields a legal,
/// simulatable schedule (which cannot happen for non-degenerate specs —
/// FA3 with dynamic assignment is deadlock-free on any machine width).
pub fn tune(spec: &ProblemSpec, opts: &TuneOptions) -> Result<TuneResult> {
    tune_seeded(spec, opts, &[])
}

/// [`tune`] with extra seed candidates — the warm-start entry point used
/// by [`super::fleet`]. `extra_seeds` (e.g. a schedule transferred from
/// the nearest cached neighbor) join the greedy seeding pool *after* the
/// analytic generators, so the tie-break keeps the analytic winner and
/// `tune_seeded(spec, opts, &[])` is byte-identical to [`tune`]. Extra
/// seeds for a different [`ProblemSpec`] or failing
/// [`crate::schedule::validate`] are silently dropped — a bad transfer
/// degrades to a classic cold search, never an error.
pub fn tune_seeded(
    spec: &ProblemSpec,
    opts: &TuneOptions,
    extra_seeds: &[Schedule],
) -> Result<TuneResult> {
    let mut sim_cfg = opts.sim;
    sim_cfg.record_spans = false;
    let batch = opts.batch.max(1);
    let bound = lower_bound(spec, &sim_cfg);
    // One buffered simulation context for every serial score in this
    // search (parallel rounds hold one per worker inside simulate_batch).
    let mut sim = Simulator::new();

    // --- greedy seeding --------------------------------------------------
    // Pinned closed-form schedules can deadlock off their home regime
    // (e.g. Shift folded onto n_sm < n); such seeds are skipped, not fatal.
    // Valid seeds are scored as one batch; ties keep the earliest seed.
    let mut seeds: Vec<Schedule> = analytic_seeds(spec, sim_cfg.n_sm)
        .into_iter()
        .chain(extra_seeds.iter().filter(|s| s.spec == *spec).cloned())
        .filter(|s| validate(s).is_ok())
        .collect();
    let mut best: Option<(usize, f64)> = None;
    for (i, run) in score(&seeds, &sim_cfg, opts.threads, &mut sim).iter().enumerate() {
        let Ok(run) = run else { continue };
        if best.map_or(true, |(_, t)| run.makespan < t) {
            best = Some((i, run.makespan));
        }
    }
    let (best_idx, mut incumbent_t) =
        best.ok_or_else(|| anyhow::anyhow!("no analytic seed is feasible for {spec:?}"))?;
    let mut incumbent = seeds.swap_remove(best_idx);
    let seed_kind = incumbent.kind;
    let seed_makespan = incumbent_t;
    incumbent.kind = ScheduleKind::Tuned;

    // --- local search -----------------------------------------------------
    let mut rng = DetRng::new(opts.seed ^ 0xDA5_11_5C_4ED);
    let mut evaluated = 0usize;
    let mut improvements = 0usize;
    let mut skipped_invalid = 0usize;
    let mut skipped_sim = 0usize;
    let mut spent = 0usize;
    let mut candidates: Vec<Schedule> = Vec::new();
    while spent < opts.budget {
        if incumbent_t <= bound.overall() + 1e-9 {
            break; // certified optimal — nothing left to find
        }
        let k = batch.min(opts.budget - spent);
        spent += k;
        // Proposals come off the single RNG serially, so the trajectory
        // depends on (budget, batch, seed) — never on the thread count.
        candidates.clear();
        for _ in 0..k {
            match super::moves::propose(&incumbent, &mut rng, &sim_cfg) {
                Some(c) if validate(&c).is_ok() => candidates.push(c),
                _ => skipped_invalid += 1,
            }
        }
        if candidates.is_empty() {
            continue;
        }
        // Deterministic winner: smallest (makespan, proposal index), so
        // the earliest candidate takes ties at any thread count.
        let mut winner: Option<(usize, f64)> = None;
        for (i, run) in score(&candidates, &sim_cfg, opts.threads, &mut sim).iter().enumerate() {
            match run {
                Ok(r) => {
                    evaluated += 1;
                    if winner.map_or(true, |(_, t)| r.makespan < t) {
                        winner = Some((i, r.makespan));
                    }
                }
                Err(_) => skipped_sim += 1,
            }
        }
        let Some((wi, wt)) = winner else { continue };
        // Accept non-regressions: equal-makespan drift lets search cross
        // plateaus (e.g. a pin swap that only pays off after a rotation).
        if wt <= incumbent_t + 1e-12 {
            if wt < incumbent_t - 1e-12 {
                improvements += 1;
            }
            incumbent = candidates.swap_remove(wi);
            incumbent_t = wt;
        }
    }

    Ok(TuneResult {
        schedule: incumbent,
        makespan: incumbent_t,
        seed_kind,
        seed_makespan,
        bound,
        evaluated,
        improvements,
        skipped_invalid,
        skipped_sim,
    })
}

/// Convenience for call sites that accept a [`ScheduleKind`] and must map
/// `Tuned` to a concrete schedule without running a full `dash tune`
/// session: consult the default on-disk cache, else quick-tune inline
/// (without writing the cache — only `dash tune` persists results).
pub fn tuned_schedule_for(spec: &ProblemSpec, sim: &SimConfig) -> Schedule {
    let fp = super::fingerprint::WorkloadFingerprint::new(spec, sim);
    let cache = super::cache::ScheduleCache::open(super::cache::DEFAULT_CACHE_PATH);
    if let Some(hit) = cache.get(&fp.key(), spec) {
        return hit.schedule;
    }
    // Be loud about the fallback: a quick-tune result is NOT the schedule a
    // previous full `dash tune` may have reported under other options.
    eprintln!(
        "note: no cached tuned schedule for {} in {}; quick-tuning inline \
         (budget {}) — run `dash tune` to search properly and persist",
        fp.key(),
        super::cache::DEFAULT_CACHE_PATH,
        TuneOptions::quick(*sim).budget
    );
    tune(spec, &TuneOptions::quick(*sim))
        .expect("quick tuning always has a feasible FA3 seed")
        .schedule
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(n_sm: usize, budget: usize) -> TuneOptions {
        TuneOptions { budget, seed: 7, sim: SimConfig::ideal(n_sm), batch: 1, threads: 1 }
    }

    #[test]
    fn tuned_never_loses_to_analytic_seeds() {
        use crate::schedule::MaskSpec;
        for mask in [
            MaskSpec::full(),
            MaskSpec::causal(),
            MaskSpec::sliding_window(3),
            MaskSpec::document(vec![3]),
        ] {
            for (n, n_sm) in [(6usize, 6usize), (8, 4), (5, 13)] {
                let spec = ProblemSpec::square(n, 2, mask.clone());
                let r = tune(&spec, &opts(n_sm, 60)).unwrap();
                assert!(
                    r.makespan <= r.seed_makespan + 1e-9,
                    "{mask:?} n={n} n_sm={n_sm}: tuned {} vs seed {}",
                    r.makespan,
                    r.seed_makespan
                );
                assert!(r.makespan >= r.bound.overall() - 1e-9);
                validate(&r.schedule).unwrap();
                assert_eq!(r.schedule.kind, ScheduleKind::Tuned);
            }
        }
    }

    #[test]
    fn batched_search_never_loses_either() {
        use crate::schedule::MaskSpec;
        for mask in [MaskSpec::full(), MaskSpec::causal()] {
            let spec = ProblemSpec::square(9, 2, mask);
            let o = TuneOptions { batch: 6, threads: 2, ..opts(5, 60) };
            let r = tune(&spec, &o).unwrap();
            assert!(r.makespan <= r.seed_makespan + 1e-9);
            assert!(r.makespan >= r.bound.overall() - 1e-9);
            validate(&r.schedule).unwrap();
        }
    }

    #[test]
    fn home_regimes_certify_optimal_and_skip_search() {
        // Shift / Symmetric Shift seeds already meet the bound, so zero
        // proposals should be evaluated (or skipped).
        use crate::schedule::MaskSpec;
        let full = tune(&ProblemSpec::square(8, 3, MaskSpec::full()), &opts(8, 100)).unwrap();
        assert!(full.gap() < 1e-9);
        assert_eq!(full.evaluated, 0);
        assert_eq!(full.skipped_invalid + full.skipped_sim, 0);
        let causal =
            tune(&ProblemSpec::square(8, 2, MaskSpec::causal()), &opts(8, 100)).unwrap();
        assert!(causal.gap() < 1e-9);
        assert_eq!(causal.evaluated, 0);
        assert_eq!(causal.skipped_invalid + causal.skipped_sim, 0);
    }

    #[test]
    fn search_is_deterministic() {
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(7, 3, MaskSpec::causal());
        let a = tune(&spec, &opts(5, 80)).unwrap();
        let b = tune(&spec, &opts(5, 80)).unwrap();
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.schedule.reduction_order, b.schedule.reduction_order);
        assert_eq!(
            a.schedule.chains.iter().map(|c| (c.head, c.kv)).collect::<Vec<_>>(),
            b.schedule.chains.iter().map(|c| (c.head, c.kv)).collect::<Vec<_>>()
        );
    }

    #[test]
    fn thread_count_never_changes_the_winner() {
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let base = TuneOptions { batch: 4, threads: 1, ..opts(5, 120) };
        let a = tune(&spec, &base).unwrap();
        for threads in [2usize, 8] {
            let b = tune(&spec, &TuneOptions { threads, ..base }).unwrap();
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "threads={threads}");
            assert_eq!(a.schedule.reduction_order, b.schedule.reduction_order);
            assert_eq!(
                a.schedule.chains.iter().map(|c| (c.head, c.kv)).collect::<Vec<_>>(),
                b.schedule.chains.iter().map(|c| (c.head, c.kv)).collect::<Vec<_>>()
            );
            assert_eq!(
                (a.evaluated, a.improvements, a.skipped_invalid, a.skipped_sim),
                (b.evaluated, b.improvements, b.skipped_invalid, b.skipped_sim)
            );
        }
    }

    #[test]
    fn counters_account_for_every_proposal() {
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        for batch in [1usize, 4, 7] {
            let o = TuneOptions { batch, ..opts(5, 50) };
            let r = tune(&spec, &o).unwrap();
            let drawn = r.evaluated + r.skipped_invalid + r.skipped_sim;
            assert!(drawn <= o.budget, "batch={batch}: drew {drawn} > budget");
            if r.gap() > 1e-9 {
                // No early optimality exit: the whole budget was drawn.
                assert_eq!(drawn, o.budget, "batch={batch}");
            }
        }
    }

    #[test]
    fn seeded_tune_with_no_extras_is_bitwise_the_classic_tune() {
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let o = opts(5, 80);
        let a = tune(&spec, &o).unwrap();
        let b = tune_seeded(&spec, &o, &[]).unwrap();
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.schedule.reduction_order, b.schedule.reduction_order);
        assert_eq!(
            (a.evaluated, a.improvements, a.skipped_invalid, a.skipped_sim),
            (b.evaluated, b.improvements, b.skipped_invalid, b.skipped_sim)
        );
    }

    #[test]
    fn foreign_spec_extras_are_dropped_not_fatal() {
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let other = ProblemSpec::square(7, 2, MaskSpec::full());
        let o = opts(5, 80);
        let stray = crate::schedule::fa3(&other, true);
        let a = tune(&spec, &o).unwrap();
        let b = tune_seeded(&spec, &o, &[stray]).unwrap();
        // The stray seed is for another problem: it must not enter the
        // pool, so the trajectory is untouched.
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(
            (a.evaluated, a.improvements, a.skipped_invalid, a.skipped_sim),
            (b.evaluated, b.improvements, b.skipped_invalid, b.skipped_sim)
        );
    }

    #[test]
    fn off_regime_search_improves_on_the_seed_sometimes() {
        // Odd tiles, mismatched SM count: the analytic formulas are out of
        // their element. The tuner must at minimum hold the line; assert
        // it evaluated real candidates.
        use crate::schedule::MaskSpec;
        let spec = ProblemSpec::square(9, 3, MaskSpec::causal());
        let r = tune(&spec, &opts(5, 150)).unwrap();
        assert!(
            r.evaluated > 0 || r.gap() < 1e-9,
            "off-regime search should explore unless the seed is already optimal"
        );
        assert!(r.makespan <= r.seed_makespan + 1e-9);
    }
}
