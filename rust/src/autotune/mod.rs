//! Search-based schedule synthesis with a persistent tuning cache.
//!
//! The paper derives Shift and Symmetric Shift as closed-form schedules
//! that are optimal *within its DAG model* — for square, even tile grids
//! with `n_sm = n`. Real workloads stray from that regime: odd tile
//! counts, head counts that don't divide the SM count, machines narrower
//! or wider than a wave, r/c ratios off the calibrated point. This module
//! turns the repo's fixed schedule menu into a general deterministic
//! scheduling engine:
//!
//! * [`fingerprint`] — a workload identity `(n_kv, n_q, heads, mask, n_sm,
//!   cost-model hash)` that keys everything below;
//! * [`oracle`] — provable lower bounds from [`crate::dag`] critical-path
//!   relaxations, so every tuned schedule ships with an optimality gap;
//! * [`moves`] — legality-preserving local-search operators over chain
//!   assignment, visit order, and reduction order;
//! * [`search`] — greedy seeding from the analytic generators plus
//!   local search, scored by the [`crate::sim`] engine; tuned schedules
//!   are never worse than the best analytic schedule by construction;
//! * [`cache`] — a JSON-persisted store of tuned schedules, re-validated
//!   on read, so search cost is paid once per workload.
//!
//! Entry points: `dash tune` on the CLI,
//! [`crate::bench_harness::tune_sweep`] for the tuned-vs-analytic
//! artifact, and [`ScheduleKind::Tuned`](crate::schedule::ScheduleKind)
//! anywhere a schedule kind is accepted (via [`tuned_schedule_for`]).

pub mod cache;
pub mod fingerprint;
pub mod moves;
pub mod oracle;
pub mod search;

pub use cache::{CachedSchedule, ScheduleCache, DEFAULT_CACHE_PATH};
pub use fingerprint::WorkloadFingerprint;
pub use oracle::{lower_bound, LowerBound};
pub use search::{analytic_seeds, tune, tuned_schedule_for, TuneOptions, TuneResult};
