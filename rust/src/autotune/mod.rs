//! Search-based schedule synthesis with a persistent tuning cache.
//!
//! The paper derives Shift and Symmetric Shift as closed-form schedules
//! that are optimal *within its DAG model* — for square, even tile grids
//! with `n_sm = n`. Real workloads stray from that regime: odd tile
//! counts, head counts that don't divide the SM count, machines narrower
//! or wider than a wave, r/c ratios off the calibrated point. This module
//! turns the repo's fixed schedule menu into a general deterministic
//! scheduling engine:
//!
//! * [`fingerprint`] — a workload identity `(n_kv, n_q, heads, mask, n_sm,
//!   cost-model hash)` that keys everything below;
//! * [`oracle`] — provable lower bounds from [`crate::dag`] critical-path
//!   relaxations, so every tuned schedule ships with an optimality gap;
//! * [`moves`] — legality-preserving local-search operators over chain
//!   assignment, visit order, and reduction order;
//! * [`search`] — greedy seeding from the analytic generators plus
//!   local search, scored by the [`crate::sim`] engine; tuned schedules
//!   are never worse than the best analytic schedule by construction;
//! * [`portfolio`] — multi-replica racing over the same move set: an
//!   annealing temperature ladder on independent deterministic RNG
//!   streams, winner by smallest `(makespan, replica index)`, bitwise
//!   stable at any thread count;
//! * [`fleet`] — the fleet-scale layer: structured cache keys parsed back
//!   from the fingerprint grammar, nearest-neighbor warm-start transfer,
//!   and the batch tuning queue behind `dash tune --queue`;
//! * [`cache`] — a JSON-persisted store of tuned schedules, re-validated
//!   on read (atomic save, advisory [`CacheLock`] for shared batch
//!   drains), so search cost is paid once per fleet.
//!
//! Entry points: `dash tune` on the CLI,
//! [`crate::bench_harness::tune_sweep`] for the tuned-vs-analytic
//! artifact, and [`ScheduleKind::Tuned`](crate::schedule::ScheduleKind)
//! anywhere a schedule kind is accepted (via [`tuned_schedule_for`]).

pub mod cache;
pub mod fingerprint;
pub mod fleet;
pub mod moves;
pub mod oracle;
pub mod portfolio;
pub mod search;

pub use cache::{CacheLock, CachedSchedule, ScheduleCache, DEFAULT_CACHE_PATH};
pub use fingerprint::WorkloadFingerprint;
pub use fleet::{
    nearest_neighbor, parse_queue, run_queue, tune_warm, warm_start, Provenance, QueueOutcome,
    QueueReport, QueueSpec, StructuredKey, WarmStart, WarmTune,
};
pub use oracle::{lower_bound, LowerBound};
pub use portfolio::{tune_portfolio, PortfolioOptions, PortfolioResult, ReplicaReport};
pub use search::{
    analytic_seeds, tune, tune_seeded, tuned_schedule_for, TuneOptions, TuneResult,
};
