//! Local-search moves over candidate schedules.
//!
//! Each move perturbs exactly one of the three coupled decisions a schedule
//! fixes (chain assignment, Q-tile visit order, reduction order — see
//! [`crate::schedule`]), and always preserves *static* legality: visit
//! orders stay permutations of the same live-tile sets, pins stay inside
//! the declared wave, and reduction orders are only ever rebuilt total.
//! Dynamic legality (deadlock-freedom) is not guaranteed — the search loop
//! screens candidates through [`crate::schedule::validate`] and rejects any
//! whose simulation returns an error, so an aggressive move can never
//! corrupt the incumbent.

use crate::schedule::Schedule;
use crate::sim::{simulate, SimConfig};
use crate::util::DetRng;

/// Propose one mutated candidate from `s`, or `None` when the drawn move
/// has no effect on this schedule (e.g. rotating a length-1 chain).
pub fn propose(s: &Schedule, rng: &mut DetRng, sim: &SimConfig) -> Option<Schedule> {
    match rng.gen_range(6) {
        0 => rotate_visit(s, rng),
        1 => swap_adjacent_visit(s, rng),
        2 => swap_launch(s, rng),
        3 => swap_pins(s, rng),
        4 => repin(s, rng),
        _ => repair_reduction(s, sim),
    }
}

/// Pick a chain with at least `min_len` tasks.
fn pick_chain(s: &Schedule, rng: &mut DetRng, min_len: usize) -> Option<usize> {
    let eligible: Vec<usize> =
        (0..s.chains.len()).filter(|&i| s.chains[i].len() >= min_len).collect();
    if eligible.is_empty() {
        None
    } else {
        Some(eligible[rng.gen_range(eligible.len())])
    }
}

/// Visit-order rotation: cyclically rotate one chain's Q walk. This is the
/// generalized form of the shift family's construction (a shift schedule
/// *is* FA3 with per-chain rotations), so rotations can rediscover and
/// locally extend it on geometries the closed form does not cover.
pub fn rotate_visit(s: &Schedule, rng: &mut DetRng) -> Option<Schedule> {
    let ci = pick_chain(s, rng, 2)?;
    let mut out = s.clone();
    let len = out.chains[ci].q_order.len();
    let k = 1 + rng.gen_range(len - 1);
    out.chains[ci].q_order.rotate_left(k);
    Some(out)
}

/// Visit-order transposition: swap two adjacent steps of one chain's walk —
/// the fine-grained counterpart to rotation.
pub fn swap_adjacent_visit(s: &Schedule, rng: &mut DetRng) -> Option<Schedule> {
    let ci = pick_chain(s, rng, 2)?;
    let mut out = s.clone();
    let len = out.chains[ci].q_order.len();
    let i = rng.gen_range(len - 1);
    out.chains[ci].q_order.swap(i, i + 1);
    Some(out)
}

/// Chain swap (launch order): exchange two chains' launch positions. Each
/// chain keeps its own pin, so for pinned schedules this reorders execution
/// within an SM and for dynamic schedules it reorders the grid queue.
pub fn swap_launch(s: &Schedule, rng: &mut DetRng) -> Option<Schedule> {
    let n = s.chains.len();
    if n < 2 {
        return None;
    }
    let i = rng.gen_range(n);
    let j = rng.gen_range(n);
    if i == j {
        return None;
    }
    let mut out = s.clone();
    out.chains.swap(i, j);
    out.pinned.swap(i, j); // the pin travels with its chain
    Some(out)
}

/// Chain swap (assignment): exchange two chains' pin slots (launch order
/// unchanged). No-op for fully dynamic schedules.
pub fn swap_pins(s: &Schedule, rng: &mut DetRng) -> Option<Schedule> {
    let n = s.chains.len();
    if n < 2 {
        return None;
    }
    let i = rng.gen_range(n);
    let j = rng.gen_range(n);
    if i == j || s.pinned[i] == s.pinned[j] {
        return None;
    }
    let mut out = s.clone();
    out.pinned.swap(i, j);
    Some(out)
}

/// Re-pin one chain: move it to a random slot of the declared wave, or
/// release it to the dynamic work queue. Lets search trade the shift
/// family's static placement against FA3-style dynamic balancing.
pub fn repin(s: &Schedule, rng: &mut DetRng) -> Option<Schedule> {
    let n = s.chains.len();
    if n == 0 || s.wave_width == 0 {
        return None;
    }
    let i = rng.gen_range(n);
    // 1-in-4 proposals unpin; the rest draw a wave slot.
    let new_pin = if rng.gen_range(4) == 0 { None } else { Some(rng.gen_range(s.wave_width)) };
    if s.pinned[i] == new_pin {
        return None;
    }
    let mut out = s.clone();
    out.pinned[i] = new_pin;
    Some(out)
}

/// Reduction-order repair: rebuild every (head, q) fold order from the
/// production times of an *unordered* relaxation run. Simulating the
/// candidate with all ordering constraints dropped reveals when each
/// contribution would naturally be ready; folding in that order (ties by KV
/// index, so the result is deterministic) minimizes token-wait stalls for
/// the current chain layout. This is the move that re-synchronizes the
/// reduction order after rotations and re-pins have changed the timeline.
pub fn repair_reduction(s: &Schedule, sim: &SimConfig) -> Option<Schedule> {
    if s.reduction_order.is_empty() || !s.chains.iter().any(|c| c.ordered) {
        return None;
    }
    let mut relaxed = s.clone();
    for c in &mut relaxed.chains {
        c.ordered = false;
    }
    relaxed.reduction_order = Vec::new();
    let mut cfg = *sim;
    cfg.record_spans = true;
    let run = simulate(&relaxed, &cfg).ok()?;

    let spec = &s.spec;
    let mut buckets: Vec<Vec<(f64, usize)>> = vec![Vec::new(); spec.n_heads * spec.n_q];
    for span in &run.spans {
        if s.chains[span.chain].ordered && span.head < spec.n_heads {
            buckets[span.head * spec.n_q + span.q].push((span.reduce_end, span.kv));
        }
    }
    let order: Vec<Vec<usize>> = buckets
        .into_iter()
        .map(|mut b| {
            b.sort_by(|a, c| a.0.total_cmp(&c.0).then(a.1.cmp(&c.1)));
            b.into_iter().map(|(_, kv)| kv).collect()
        })
        .collect();
    if order == s.reduction_order {
        return None;
    }
    let mut out = s.clone();
    out.reduction_order = order;
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, shift, validate, MaskSpec, ProblemSpec};

    fn base() -> Schedule {
        fa3(&ProblemSpec::square(6, 2, MaskSpec::causal()), true)
    }

    #[test]
    fn rotation_preserves_coverage() {
        let s = base();
        let mut rng = DetRng::new(1);
        for _ in 0..50 {
            if let Some(c) = rotate_visit(&s, &mut rng) {
                validate(&c).unwrap();
            }
        }
    }

    #[test]
    fn launch_and_pin_swaps_preserve_legality() {
        let s = shift(&ProblemSpec::square(6, 2, MaskSpec::full())).unwrap();
        let mut rng = DetRng::new(2);
        for _ in 0..50 {
            if let Some(c) = swap_launch(&s, &mut rng) {
                validate(&c).unwrap();
            }
            if let Some(c) = swap_pins(&s, &mut rng) {
                validate(&c).unwrap();
            }
            if let Some(c) = repin(&s, &mut rng) {
                validate(&c).unwrap();
            }
        }
    }

    #[test]
    fn repair_reduction_yields_total_orders() {
        // Scramble the visit orders, then repair: result must validate.
        let mut s = base();
        let mut rng = DetRng::new(3);
        for c in &mut s.chains {
            rng.shuffle(&mut c.q_order);
        }
        let cfg = SimConfig::ideal(6);
        if let Some(fixed) = repair_reduction(&s, &cfg) {
            validate(&fixed).unwrap();
        }
    }

    #[test]
    fn propose_is_deterministic_per_seed() {
        let s = base();
        let cfg = SimConfig::ideal(6);
        let run = |seed| {
            let mut rng = DetRng::new(seed);
            (0..20)
                .map(|_| propose(&s, &mut rng, &cfg).map(|c| c.chains[0].q_order.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(9), run(9));
    }
}
