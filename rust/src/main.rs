//! `dash` — CLI for the DASH reproduction.
//!
//! Subcommands map 1:1 onto the paper's artifacts plus the engine layers
//! grown on top — see [`dash::cli::USAGE`] for the command list and
//! `docs/CLI.md` for the full reference (each command also answers
//! `--help` with the exact text the docs embed).
//!
//! The machine is selected with the global `--gpu <preset|path>` flag
//! (presets `h800`/`h100`/`a100`/`abstract`, or a profile JSON written by
//! `dash hw --export`); nothing below hard-codes a concrete GPU.
//!
//! Argument parsing is hand-rolled (`--key value` / `--flag`): the build is
//! fully offline, see `rust/src/util`.

use dash::bench_harness as figs;
use dash::cli;
#[cfg(feature = "pjrt")]
use dash::coordinator::config::DeterminismMode;
#[cfg(feature = "pjrt")]
use dash::coordinator::{TrainConfig, Trainer};
use dash::dag::{build_schedule_dag, check_depth_monotone, ChainSpec, DagBuildOptions};
use dash::hw::{self, GpuProfile, Machine};
use dash::mask::MaskSpec;
use dash::schedule::{self, ClusterStrategy, ProblemSpec, Schedule, ScheduleKind};
use dash::sim::{
    cluster_lane_labels, render_gantt, render_gantt_cluster, render_gantt_csv, simulate,
    CostModel, L2Model, SimConfig,
};
use std::collections::HashMap;

const USAGE: &str = cli::USAGE;

/// Parsed `--key value` options plus boolean flags.
struct Opts {
    vals: HashMap<String, String>,
    flags: Vec<String>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Self, String> {
        let mut vals = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let Some(key) = a.strip_prefix("--") else {
                return Err(format!("unexpected argument '{a}'"));
            };
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                vals.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(Self { vals, flags })
    }

    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.vals.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("bad value for --{name}: '{v}'")),
        }
    }

    fn get_opt(&self, name: &str) -> Option<&str> {
        self.vals.get(name).map(String::as_str)
    }

    fn schedule(&self) -> Result<ScheduleKind, String> {
        let name = self.get_opt("schedule").unwrap_or("fa3");
        ScheduleKind::parse(name).ok_or_else(|| format!("unknown schedule '{name}'"))
    }

    fn mask(&self) -> Result<MaskSpec, String> {
        let name = self.get_opt("mask").unwrap_or("causal");
        dash::mask::resolve(name).map_err(|e| format!("{e:#}"))
    }

    /// Resolve `--gpu` (preset name or profile-JSON path), defaulting to
    /// `default_name` when the flag is absent.
    fn gpu(&self, default_name: &str) -> Result<GpuProfile, String> {
        let arg = self.get_opt("gpu").unwrap_or(default_name);
        hw::resolve(arg).map_err(|e| format!("{e:#}"))
    }
}

/// Build a schedule for the configuration it will actually run under: the
/// sim config drives LPT's machine width and — for `tuned` — the cost-model
/// fingerprint used for the cache lookup (so `dash tune` results are found)
/// and for any inline quick-tune fallback.
fn build(kind: ScheduleKind, spec: &ProblemSpec, sim: &SimConfig) -> dash::Result<Schedule> {
    Ok(match kind {
        ScheduleKind::Fa3 => schedule::fa3(spec, true),
        ScheduleKind::Fa3Atomic => schedule::fa3(spec, false),
        ScheduleKind::Descending => schedule::descending(spec),
        // Structure-dependent: surfaces a typed unsupported-mask error.
        ScheduleKind::Shift => schedule::shift(spec)?,
        ScheduleKind::SymmetricShift => schedule::symmetric_shift(spec),
        ScheduleKind::TwoPass => schedule::two_pass(spec),
        ScheduleKind::Lpt => schedule::lpt_schedule(spec, sim.n_sm),
        ScheduleKind::Tuned => dash::autotune::tuned_schedule_for(spec, sim),
    })
}

/// One `--schedule` token: a plain generator name, or a cluster composite
/// (`<ring|zigzag>-<kind>`, e.g. `ring-shift`) for `--devices` runs.
fn parse_schedule_token(name: &str) -> Result<(Option<ClusterStrategy>, ScheduleKind), String> {
    if let Some(kind) = ScheduleKind::parse(name) {
        return Ok((None, kind));
    }
    if let Some((strategy, kind)) = schedule::parse_composite(name) {
        return Ok((Some(strategy), kind));
    }
    Err(format!(
        "unknown schedule '{name}' (plain kinds: see `dash simulate --help`; \
         cluster composites: <ring|zigzag>-<kind>, e.g. ring-shift)"
    ))
}

/// Display spelling of a parsed schedule token (matches
/// `Schedule::display_name` on the built schedule).
fn token_name(token: (Option<ClusterStrategy>, ScheduleKind)) -> String {
    match token.0 {
        Some(st) => format!("{}-{}", st.name(), token.1.name()),
        None => token.1.name().to_string(),
    }
}

/// Resolve `--cluster` into the per-hop cycle cost a `--devices` run pays
/// on each cross-device reduction step: the paper's unit hop when the
/// flag is absent or the cluster is fully abstract.
fn hop_cost_for(opts: &Opts, block: usize, head_dim: usize) -> dash::Result<f64> {
    match opts.get_opt("cluster") {
        None => Ok(1.0),
        Some(arg) => Ok(hw::resolve_cluster(arg)?.hop_cycles(block, head_dim)),
    }
}

/// Build the (possibly device-sharded) schedule for one CLI request:
/// `build` for plain single-device runs; for a cluster composite, the
/// strategy-sharded schedule with the interconnect hop cost stamped on.
fn build_sharded(
    token: (Option<ClusterStrategy>, ScheduleKind),
    spec: &ProblemSpec,
    sim: &SimConfig,
    devices: usize,
    hop_cost: f64,
) -> dash::Result<Schedule> {
    match token.0 {
        None if devices <= 1 => build(token.1, spec, sim),
        None => anyhow::bail!(
            "--devices {devices} needs a cluster schedule — spell it \
             <ring|zigzag>-<kind>, e.g. ring-shift or zigzag-descending"
        ),
        Some(strategy) => {
            let mut s = schedule::cluster_schedule(spec, strategy, token.1, devices)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            if let Some(c) = s.cluster.as_mut() {
                c.hop_cost = hop_cost;
            }
            Ok(s)
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some((cmd, rest)) = args.split_first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    // `dash baseline <save|list|check>` / `dash trace
    // <generate|simulate|verify>` — the commands with a positional
    // sub-action, split off before option parsing.
    let (action, rest) = match rest.split_first() {
        Some((a, tail))
            if (cmd == "baseline" || cmd == "trace") && !a.starts_with("--") =>
        {
            (Some(a.as_str()), tail)
        }
        _ => (None, rest),
    };
    let opts = match Opts::parse(rest) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(cmd, action, &opts) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(cmd: &str, action: Option<&str>, opts: &Opts) -> dash::Result<()> {
    // `dash <command> --help`: the per-command reference (the exact text
    // docs/CLI.md embeds — see rust/tests/docs.rs).
    if opts.flag("help") || opts.flag("h") {
        if let Some(help) = cli::help_for(cmd) {
            println!("{help}");
            return Ok(());
        }
    }
    match cmd {
        "simulate" => cmd_simulate(opts),
        "gantt" => cmd_gantt(opts),
        "timeline" => cmd_timeline(opts),
        "flamegraph" => cmd_flamegraph(opts),
        "figures" => cmd_figures(opts),
        "tune" => cmd_tune(opts),
        "verify" => cmd_verify(opts),
        "trace" => cmd_trace(action, opts),
        "baseline" => cmd_baseline(action, opts),
        "hw" => cmd_hw(opts),
        "train" => cmd_train(opts),
        "audit" => cmd_audit(opts),
        "explore" => cmd_explore(opts),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
}

fn err(e: String) -> anyhow::Error {
    anyhow::anyhow!(e)
}

/// Scoring configuration for `simulate`/`tune`: abstract profiles keep the
/// paper's unit-cost knobs (`--r-over-c`, `--l2`); concrete profiles derive
/// everything — costs, spill inflation for `kind`, pipeline shape,
/// fingerprint — from [`Machine::sim_config`], the one profile-to-SimConfig
/// recipe, so `tune` and `simulate --schedule tuned` agree on the cache key
/// by construction. CLI flags override on top (and enter the fingerprint,
/// identically in every command).
fn sim_config_for(
    opts: &Opts,
    profile: &GpuProfile,
    kind: ScheduleKind,
    n: usize,
) -> Result<SimConfig, String> {
    if profile.is_abstract() {
        let r_over_c: f64 = opts.get("r-over-c", 0.25)?;
        return Ok(SimConfig {
            n_sm: opts.get("n-sm", n)?,
            cost: CostModel {
                compute: 1.0,
                reduce: r_over_c,
                spill_factor: 1.0,
                l2: if opts.flag("l2") { L2Model::default() } else { L2Model::ideal() },
            },
            record_spans: false,
            writer_depth: opts.get("writer-depth", 0)?,
            occupancy: opts.get("occupancy", 1)?,
            hw_fingerprint: 0,
        });
    }
    let head_dim: usize = opts.get("head-dim", 128)?;
    let mut cfg = Machine::real(profile.clone()).sim_config(kind, n, 128, head_dim);
    cfg.n_sm = opts.get("n-sm", cfg.n_sm)?;
    cfg.writer_depth = opts.get("writer-depth", cfg.writer_depth)?;
    cfg.occupancy = opts.get("occupancy", cfg.occupancy)?;
    Ok(cfg)
}

fn cmd_simulate(opts: &Opts) -> dash::Result<()> {
    let token = parse_schedule_token(opts.get_opt("schedule").unwrap_or("fa3")).map_err(err)?;
    let kind = token.1;
    let n: usize = opts.get("n", 8).map_err(err)?;
    let n_q: usize = opts.get("n-q", n).map_err(err)?;
    let heads: usize = opts.get("heads", 4).map_err(err)?;
    let devices: usize = opts.get("devices", 1).map_err(err)?;
    let mask = opts.mask().map_err(err)?;
    let profile = opts.gpu("abstract").map_err(err)?;
    let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
    let cfg = sim_config_for(opts, &profile, kind, n).map_err(err)?;
    let head_dim: usize = opts.get("head-dim", 128).map_err(err)?;
    let hop = hop_cost_for(opts, 128, head_dim)?;
    let s = build_sharded(token, &spec, &cfg, devices, hop)?;
    let r = simulate(&s, &cfg)?;
    println!(
        "schedule={} mask={} n={n}x{n_q} heads={heads} gpu={} n_sm={}\n makespan={:.2} utilization={:.1}% stalls={:.2} tasks={}",
        s.display_name(),
        spec.mask.name(),
        profile.name,
        cfg.n_sm,
        r.makespan,
        r.utilization() * 100.0,
        r.stall_time,
        r.n_tasks
    );
    let dag = build_schedule_dag(
        &s,
        cfg.n_sm,
        DagBuildOptions {
            compute_cost: cfg.cost.compute,
            reduce_cost: cfg.cost.reduce,
            dependency_latency: 0.0,
        },
    );
    // Tuned schedules may place chains differently than the DAG builder's
    // static round-robin, which can make this particular static relaxation
    // cyclic even though the dynamic execution above succeeded.
    match dag.dag.critical_path() {
        Some(cp) => println!(" DAG critical path (static placement): {cp:.2}"),
        None => println!(" DAG critical path (static placement): n/a (dynamic-only schedule)"),
    }
    Ok(())
}

fn cmd_gantt(opts: &Opts) -> dash::Result<()> {
    let token = parse_schedule_token(opts.get_opt("schedule").unwrap_or("fa3")).map_err(err)?;
    let n: usize = opts.get("n", 4).map_err(err)?;
    let n_q: usize = opts.get("n-q", n).map_err(err)?;
    let heads: usize = opts.get("heads", 2).map_err(err)?;
    let width: usize = opts.get("width", 100).map_err(err)?;
    let devices: usize = opts.get("devices", 1).map_err(err)?;
    let mask = opts.mask().map_err(err)?;
    let cfg = SimConfig {
        n_sm: n,
        cost: CostModel::default(),
        record_spans: true,
        writer_depth: opts.get("writer-depth", 0).map_err(err)?,
        occupancy: opts.get("occupancy", 1).map_err(err)?,
        hw_fingerprint: 0,
    };
    let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
    let hop = hop_cost_for(opts, 128, 128)?;
    let s = build_sharded(token, &spec, &cfg, devices, hop)?;
    let r = simulate(&s, &cfg)?;
    if opts.flag("csv") {
        println!("{}", render_gantt_csv(&r.spans));
    } else {
        println!(
            "{} | mask {} | n={n}x{n_q} heads={heads} | makespan {:.2}",
            s.display_name(),
            spec.mask.name(),
            r.makespan
        );
        if s.n_devices() > 1 {
            let d = s.n_devices();
            let labels = cluster_lane_labels(d, cfg.n_sm * cfg.occupancy.max(1), d);
            println!("{}", render_gantt_cluster(&r.spans, &r.links, &labels, width));
        } else {
            println!("{}", render_gantt(&r.spans, n, width));
        }
    }
    Ok(())
}

/// Build the typed trace of one schedule under the CLI's machine flags,
/// from either engine (`--source sim|exec`) — shared by `timeline` and
/// `flamegraph`.
fn trace_for(
    opts: &Opts,
    token: (Option<ClusterStrategy>, ScheduleKind),
    spec: &ProblemSpec,
    cfg: &SimConfig,
    devices: usize,
    hop_cost: f64,
) -> dash::Result<dash::trace::SimTrace> {
    let s = build_sharded(token, spec, cfg, devices, hop_cost)?;
    match opts.get_opt("source").unwrap_or("sim") {
        "sim" => Ok(dash::trace::trace_simulation(&s, cfg)?),
        "exec" => {
            let ecfg = dash::exec::ExecConfig { n_sm: cfg.n_sm, ..dash::exec::ExecConfig::new(42) };
            Ok(dash::trace::trace_execution(&s, &ecfg))
        }
        other => anyhow::bail!("unknown --source '{other}' (sim|exec)"),
    }
}

fn cmd_timeline(opts: &Opts) -> dash::Result<()> {
    use dash::trace::timeline::{timeline_diff_html, timeline_html};

    let token = parse_schedule_token(opts.get_opt("schedule").unwrap_or("fa3")).map_err(err)?;
    let kind = token.1;
    let n: usize = opts.get("n", 8).map_err(err)?;
    let n_q: usize = opts.get("n-q", n).map_err(err)?;
    let heads: usize = opts.get("heads", 2).map_err(err)?;
    let devices: usize = opts.get("devices", 1).map_err(err)?;
    let mask = opts.mask().map_err(err)?;
    let profile = opts.gpu("abstract").map_err(err)?;
    let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
    let cfg = sim_config_for(opts, &profile, kind, n).map_err(err)?;
    let hop = hop_cost_for(opts, 128, opts.get("head-dim", 128).map_err(err)?)?;
    let out = opts.get_opt("out").unwrap_or("timeline.html");

    let a = trace_for(opts, token, &spec, &cfg, devices, hop)?;
    let html = match opts.get_opt("diff") {
        Some(other) => {
            let t2 = parse_schedule_token(other)
                .map_err(|_| anyhow::anyhow!("unknown --diff schedule '{other}'"))?;
            let b = trace_for(opts, t2, &spec, &cfg, devices, hop)?;
            println!(
                "diff {} vs {} on {} (n={n}x{n_q} heads={heads}): hashes {:016x} / {:016x}",
                token_name(token),
                token_name(t2),
                spec.mask.name(),
                a.content_hash(),
                b.content_hash()
            );
            timeline_diff_html(&a, &b)
        }
        None => {
            println!(
                "{} on {} (n={n}x{n_q} heads={heads}): {} events, makespan {:.2}, trace hash {:016x}",
                token_name(token),
                spec.mask.name(),
                a.events.len(),
                a.makespan,
                a.content_hash()
            );
            timeline_html(&a)
        }
    };
    std::fs::write(out, &html)?;
    println!("timeline -> {out} ({} bytes, self-contained)", html.len());
    Ok(())
}

fn cmd_flamegraph(opts: &Opts) -> dash::Result<()> {
    use dash::trace::flamegraph::{attribute, render_folded, render_text};

    let token = parse_schedule_token(opts.get_opt("schedule").unwrap_or("fa3")).map_err(err)?;
    let kind = token.1;
    let n: usize = opts.get("n", 8).map_err(err)?;
    let n_q: usize = opts.get("n-q", n).map_err(err)?;
    let heads: usize = opts.get("heads", 2).map_err(err)?;
    let devices: usize = opts.get("devices", 1).map_err(err)?;
    let mask = opts.mask().map_err(err)?;
    let profile = opts.gpu("abstract").map_err(err)?;
    let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
    let cfg = sim_config_for(opts, &profile, kind, n).map_err(err)?;
    let hop = hop_cost_for(opts, 128, opts.get("head-dim", 128).map_err(err)?)?;

    let trace = trace_for(opts, token, &spec, &cfg, devices, hop)?;
    let report = attribute(&trace);
    let text = if opts.flag("folded") { render_folded(&report) } else { render_text(&report) };
    match opts.get_opt("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("flamegraph -> {path} ({} chains)", report.chains.len());
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_baseline(action: Option<&str>, opts: &Opts) -> dash::Result<()> {
    use dash::trace::baseline::{self as bl, BaselineSnapshot};
    use std::path::{Path, PathBuf};

    let dir = PathBuf::from(opts.get_opt("dir").unwrap_or("."));
    let suite = opts.get_opt("suite").unwrap_or("smoke");
    let tol: f64 = opts.get("tolerance", 0.02).map_err(err)?;
    match action {
        Some("save") => {
            let mut snap = bl::run_suite(suite)?;
            if let Some(name) = opts.get_opt("name") {
                snap.name = name.to_string();
            }
            let path = snap.save(&dir)?;
            println!(
                "baseline '{}' ({} suite, {} points) -> {}",
                snap.name,
                snap.suite,
                snap.points.len(),
                path.display()
            );
        }
        Some("list") => {
            let snaps = bl::list_snapshots(&dir)?;
            if snaps.is_empty() {
                println!("no BENCH_*.json snapshots in {}", dir.display());
            }
            for (name, s) in snaps {
                println!("  BENCH_{name}.json  suite={:<10} points={}", s.suite, s.points.len());
            }
        }
        Some("check") => {
            let name = opts.get_opt("name").unwrap_or(suite);
            let base = BaselineSnapshot::load(&bl::snapshot_path(&dir, name))?;
            let current = match opts.get_opt("against") {
                Some(p) => BaselineSnapshot::load(Path::new(p))?,
                None => {
                    anyhow::ensure!(
                        matches!(
                            base.suite.as_str(),
                            "smoke" | "grid" | "core" | "cluster" | "trace" | "tune"
                        ),
                        "snapshot '{name}' was produced by the '{}' suite, which is not \
                         re-runnable here; compare against a fresh export with \
                         --against <BENCH_file.json>",
                        base.suite
                    );
                    bl::run_suite(&base.suite)?
                }
            };
            let report = bl::compare(&base, &current, tol);
            print!("{}", bl::render_report(&report, tol));
            anyhow::ensure!(
                report.passed(),
                "baseline check against BENCH_{name}.json failed: {} regression(s), \
                 {} missing point(s)",
                report.regressions.len(),
                report.missing.len()
            );
        }
        Some(other) => anyhow::bail!("unknown baseline action '{other}' (save|list|check)"),
        None => anyhow::bail!("dash baseline needs an action: save|list|check"),
    }
    Ok(())
}

fn cmd_figures(opts: &Opts) -> dash::Result<()> {
    let ideal = opts.flag("ideal");
    let csv = opts.flag("csv");
    let fig = opts.get_opt("fig").unwrap_or("all");
    let profile = opts.gpu("h800").map_err(err)?;
    if profile.is_abstract() {
        anyhow::bail!(
            "`dash figures` needs a concrete GPU profile (h800|h100|a100 or a \
             profile JSON) — the abstract machine has no clock or FLOPs rate"
        );
    }
    let machine =
        if ideal { Machine::ideal(profile) } else { Machine::real(profile) };
    let m = &machine;
    println!(
        "(modelled GPU: {}{})",
        m.profile.name,
        if ideal { ", idealized L2/registers" } else { "" }
    );
    let want = |f: &str| fig == "all" || fig == f;
    fn show<T: figs::TableRow>(title: &str, rows: &[T], csv: bool) {
        println!("== {title} ==");
        if csv {
            println!("{}", figs::render_csv(rows));
        } else {
            println!("{}", figs::render_table(rows));
        }
    }
    // Every figures run also feeds the perf trajectory: the tabulated rows
    // become a BENCH_figures.json baseline snapshot (see `dash baseline`)
    // unless --no-bench.
    use dash::trace::baseline::{points_from_rows, BaselinePoint, BaselineSnapshot};
    let bench = !opts.flag("no-bench");
    let mut bench_points: Vec<BaselinePoint> = Vec::new();
    if want("1") {
        let rows = figs::fig1_degradation(m);
        bench_points.extend(points_from_rows("fig1", &rows));
        show("Figure 1 (right): deterministic-mode degradation", &rows, csv);
    }
    if want("8") {
        let rows = figs::fig8_full_mask(m);
        bench_points.extend(points_from_rows("fig8", &rows));
        show("Figure 8: full-mask backward throughput", &rows, csv);
    }
    if want("9") {
        let rows = figs::fig9_causal_mask(m);
        bench_points.extend(points_from_rows("fig9", &rows));
        show("Figure 9: causal-mask backward throughput", &rows, csv);
    }
    if want("10a") {
        let rows = figs::fig10a_end_to_end(m);
        bench_points.extend(points_from_rows("fig10a", &rows));
        show("Figure 10a: end-to-end block speedup", &rows, csv);
    }
    if want("10b") {
        show("Figure 10b: kernel time breakdown", &figs::fig10b_breakdown(m), csv);
    }
    if want("table1") {
        show("Table 1: gradient deviation over 10 runs", &figs::table1_determinism(10, 42), csv);
    }
    // Explicit request only (not part of `all`): the sweep runs ~24 fresh
    // searches, and it always models the ideal abstract machine — `--ideal`
    // has no effect on it, unlike the hardware-model figures above.
    if fig == "tune" {
        let rows = figs::tune_sweep(4, 200, 42);
        bench_points.extend(points_from_rows("tune", &rows));
        show("Autotuner: tuned vs best analytic schedule (ideal machine)", &rows, csv);
    }
    // Explicit only, like `tune`: executes real backward passes through
    // the numeric oracle (ideal abstract machine; `--ideal` is moot).
    if fig == "dvt" {
        show(
            "Determinism vs throughput (numeric oracle, ideal machine)",
            &figs::determinism_throughput_table(6, 2, 42)?,
            csv,
        );
    }
    if bench && !bench_points.is_empty() {
        let snap = BaselineSnapshot {
            name: "figures".into(),
            suite: "external".into(),
            points: bench_points,
        };
        let path = snap.save(std::path::Path::new("."))?;
        println!(
            "baseline snapshot -> {} ({} points; gate with `dash baseline check --name \
             figures --against <other>`, disable with --no-bench)",
            path.display(),
            snap.points.len()
        );
    }
    Ok(())
}

/// `dash verify` — the numeric determinism oracle (see `dash verify
/// --help` / docs/CLI.md). Exits nonzero if any deterministic generator
/// fails bitwise verification or a FLOP cross-check mismatches.
fn cmd_verify(opts: &Opts) -> dash::Result<()> {
    use dash::coordinator::ReproManifest;
    use dash::exec::{execute_backward, verify_device_counts, ExecConfig, OracleOptions};
    use dash::numerics::Precision;

    let n: usize = opts.get("n", 6).map_err(err)?;
    let n_q: usize = opts.get("n-q", n).map_err(err)?;
    let heads: usize = opts.get("heads", 2).map_err(err)?;
    let runs: usize = opts.get("runs", 2).map_err(err)?;
    let block: usize = opts.get("block", 4).map_err(err)?;
    let head_dim: usize = opts.get("head-dim", 8).map_err(err)?;
    let seed: u64 = opts.get("seed", 42).map_err(err)?;
    let precisions: Vec<Precision> = match opts.get_opt("precision").unwrap_or("both") {
        "both" => vec![Precision::F32, Precision::Bf16],
        p => vec![Precision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown precision '{p}' (f32|bf16|both)"))?],
    };
    // `--sms` overrides the default width sweep (VerifyOptions::defaults).
    let sm_counts: Option<Vec<usize>> = match opts.get_opt("sms") {
        None => None,
        Some(list) => Some(
            list.split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow::anyhow!("bad --sms '{list}'"))
                })
                .collect::<dash::Result<Vec<usize>>>()?,
        ),
    };
    // The canonical executor config: machine shape must not matter for
    // deterministic schedules, so manifests pin the jitter-free n-wide run.
    let canonical = |precision: Precision, spec: &ProblemSpec| ExecConfig {
        block,
        head_dim,
        seed,
        precision,
        n_sm: spec.n_kv.max(2),
        perturb: 0,
        inject_atomic: false,
        inject_xdev: false,
        inject_batch: false,
    };

    // --check: re-execute a manifest's workload and attest the bits.
    if let Some(path) = opts.get_opt("check") {
        let m = ReproManifest::load(path)?;
        let kind = ScheduleKind::parse(&m.schedule)
            .ok_or_else(|| anyhow::anyhow!("manifest schedule '{}' unknown", m.schedule))?;
        // A tuned schedule is a search result keyed to ambient cache
        // state, not a function of the manifest coordinates — re-deriving
        // it here could "diverge" without any numeric change. Refuse
        // rather than false-alarm.
        anyhow::ensure!(
            kind != ScheduleKind::Tuned,
            "manifest attests a tuned schedule, which is not re-derivable from its \
             coordinates (the search result depends on the tuning cache); attest an \
             analytic generator instead"
        );
        let mask = MaskSpec::parse(&m.mask)
            .ok_or_else(|| anyhow::anyhow!("manifest mask '{}' unknown", m.mask))?;
        let spec = ProblemSpec { n_kv: m.n_kv, n_q: m.n_q, n_heads: m.n_heads, mask };
        let s = build(kind, &spec, &SimConfig::ideal(m.n_kv.max(1)))?;
        let cfg = ExecConfig {
            block: m.block,
            head_dim: m.head_dim,
            seed: m.seed,
            precision: m.precision,
            n_sm: m.n_kv.max(2),
            perturb: 0,
            inject_atomic: false,
            inject_xdev: false,
            inject_batch: false,
        };
        let r = execute_backward(&s, &cfg)?;
        anyhow::ensure!(
            m.attests(&r),
            "DIVERGED: re-execution hash {:016x} != manifest {:016x} ({} on {})",
            r.grad_hash,
            m.grad_hash,
            m.schedule,
            m.mask
        );
        // The schedule timeline is attested alongside the numeric state:
        // the canonical executor trace must rehash identically too.
        let trace_hash = dash::trace::trace_execution(&s, &cfg).content_hash();
        anyhow::ensure!(
            m.trace_hash == 0 || m.trace_hash == trace_hash,
            "DIVERGED: re-derived trace hash {:016x} != manifest {:016x} ({} on {} — \
             same gradients, different schedule timeline)",
            trace_hash,
            m.trace_hash,
            m.schedule,
            m.mask
        );
        println!(
            "PASS: {} on {} reproduces gradient hash {:016x} and trace hash {:016x} \
             ({} FLOPs) bit-for-bit",
            m.schedule, m.mask, m.grad_hash, m.trace_hash, m.flops
        );
        return Ok(());
    }

    // --manifest: attest one workload point and write it to disk.
    if let Some(path) = opts.get_opt("manifest") {
        let kind = opts.schedule().map_err(err)?;
        anyhow::ensure!(
            kind != ScheduleKind::Tuned,
            "cannot write a manifest for a tuned schedule: the search result depends \
             on the tuning cache, so `--check` could not re-derive it from the \
             manifest coordinates; attest an analytic generator instead"
        );
        let mask = opts.mask().map_err(err)?;
        let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
        let s = build(kind, &spec, &SimConfig::ideal(n.max(1)))?;
        let cfg = canonical(precisions[0], &spec);
        let r = execute_backward(&s, &cfg)?;
        let trace_hash = dash::trace::trace_execution(&s, &cfg).content_hash();
        let m = ReproManifest::from_exec(kind.name(), &spec.mask.name(), &spec, &cfg, &r)
            .with_trace_hash(trace_hash);
        m.save(path)?;
        println!(
            "manifest -> {path}: {} on {} grad_hash {:016x} trace_hash {trace_hash:016x} \
             ({} precision); verify later with `dash verify --check {path}`",
            kind.name(),
            spec.mask.name(),
            r.grad_hash,
            cfg.precision.name()
        );
        return Ok(());
    }

    // --devices: the cross-device determinism matrix. For every requested
    // cluster composite (and precision), the oracle executes the sharded
    // backward pass at each device count — with per-device arrival skew
    // folded through the fixed cross-device reduction order — and demands
    // ONE gradient hash across device counts, runs, and machine widths.
    if let Some(list) = opts.get_opt("devices") {
        let devices: Vec<usize> = list
            .split(',')
            .map(|t| {
                t.trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&d| d >= 1)
                    .ok_or_else(|| anyhow::anyhow!("bad --devices '{list}'"))
            })
            .collect::<dash::Result<Vec<usize>>>()?;
        // Device-mode geometry defaults to n=8: every strategy's
        // divisibility constraint holds up to 4 devices (zigzag needs
        // n_kv % 2D == 0).
        let n: usize = opts.get("n", 8).map_err(err)?;
        let n_q: usize = opts.get("n-q", n).map_err(err)?;
        let sms = sm_counts.unwrap_or_else(|| vec![3, n.max(2), 2 * n + 1]);
        let inject = opts.flag("inject-xdev");
        let tokens: Vec<(ClusterStrategy, ScheduleKind)> = opts
            .get_opt("schedule")
            .unwrap_or("ring-shift,zigzag-descending")
            .split(',')
            .map(|t| {
                schedule::parse_composite(t.trim()).ok_or_else(|| {
                    anyhow::anyhow!(
                        "--devices needs cluster composites (<ring|zigzag>-<kind>), \
                         got '{t}'"
                    )
                })
            })
            .collect::<dash::Result<Vec<_>>>()?;
        println!(
            "cross-device oracle: devices [{list}] n={n}x{n_q} heads={heads} block={block} \
             head_dim={head_dim} seed={seed} | {runs} runs x SMs {sms:?} per device count"
        );
        let mut cases = 0usize;
        let mut scattered = 0usize;
        for &(strategy, intra) in &tokens {
            // Structure-dependent intra generators (shift) only exist on
            // full-structured grids; everything else defaults to causal.
            let mask = match opts.get_opt("mask") {
                Some(m) => dash::mask::resolve(m)?,
                None if intra == ScheduleKind::Shift => MaskSpec::full(),
                None => MaskSpec::causal(),
            };
            let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
            for &precision in &precisions {
                let o = OracleOptions {
                    runs,
                    sm_counts: sms.clone(),
                    block,
                    head_dim,
                    seed,
                    precision,
                    inject_atomic: false,
                    inject_xdev: inject,
                    inject_batch: false,
                };
                let v = verify_device_counts(&spec, strategy, intra, &devices, &o)?;
                cases += 1;
                if !v.deterministic() {
                    scattered += 1;
                }
                println!(
                    " {:<22} {:<8} {:<5} execs {:>3}  hashes {:>2}  bitwise {:<3}  \
                     grad_hash {:016x}",
                    format!("{}-{}", strategy.name(), intra.name()),
                    spec.mask.name(),
                    precision.name(),
                    v.executions,
                    v.distinct_hashes,
                    if v.deterministic() { "YES" } else { "no" },
                    v.hash
                );
            }
        }
        if inject {
            // The negative control: an unordered cross-device fold MUST be
            // caught, and a caught injection is still a determinism
            // violation — either way this mode exits nonzero.
            anyhow::bail!(
                "{}",
                if scattered > 0 {
                    format!(
                        "injected unordered cross-device fold caught: {scattered}/{cases} \
                         case(s) scattered (expected under --inject-xdev)"
                    )
                } else {
                    format!(
                        "oracle failed to flag the injected cross-device fold in any of \
                         {cases} case(s)"
                    )
                }
            );
        }
        anyhow::ensure!(
            scattered == 0,
            "cross-device determinism violation: {scattered}/{cases} case(s) produced \
             multiple gradient hashes"
        );
        println!(
            "cross-device determinism: {cases}/{cases} case(s) bitwise-identical across \
             device counts {{{list}}}, {runs} runs, and {} machine widths",
            sms.len()
        );
        return Ok(());
    }

    // The verification matrix: the canned sweep (shared with `dash
    // figures --fig dvt`), with user-supplied fields overriding.
    let mut vo = figs::VerifyOptions::defaults(n, heads, seed);
    vo.n_q = n_q;
    vo.runs = runs;
    if let Some(sms) = sm_counts {
        vo.sm_counts = sms;
    }
    vo.block = block;
    vo.head_dim = head_dim;
    vo.precisions = precisions;
    vo.include_injected = !opts.flag("no-inject");
    if let Some(m) = opts.get_opt("mask") {
        vo.masks = vec![dash::mask::resolve(m)?];
    }
    match opts.get_opt("schedule") {
        None | Some("all") => {}
        Some(name) => {
            vo.kinds = vec![ScheduleKind::parse(name)
                .ok_or_else(|| anyhow::anyhow!("unknown schedule '{name}'"))?];
        }
    }
    println!(
        "determinism oracle: n={n}x{n_q} heads={heads} block={block} head_dim={head_dim} \
         seed={seed} | {} runs x SMs {:?} per case",
        vo.runs, vo.sm_counts
    );
    let rows = figs::verify_matrix(&vo)?;
    // An empty matrix must not read as a pass (e.g. `--schedule shift
    // --mask swa:2 --no-inject` yields no verifiable combination).
    anyhow::ensure!(
        !rows.is_empty(),
        "no verifiable (schedule, mask) combinations — structure-dependent \
         generators (shift) support only full-structured masks"
    );
    if opts.flag("csv") {
        println!("{}", figs::render_csv(&rows));
    } else {
        println!("{}", figs::render_table(&rows));
    }

    let is_control =
        |r: &figs::DvtRow| r.schedule == "fa3-atomic" || r.schedule == "fa3-det+inject";
    let det_rows: Vec<&figs::DvtRow> = rows.iter().filter(|r| !is_control(r)).collect();
    let det_ok = det_rows.iter().filter(|r| r.deterministic).count();
    let controls: Vec<&figs::DvtRow> =
        rows.iter().filter(|r| is_control(r) && r.precision == "bf16").collect();
    let caught = controls.iter().filter(|r| !r.deterministic).count();
    println!(
        "deterministic generators: {det_ok}/{} cases bitwise-identical across \
         {} executions each ({} runs x {} machine widths + completion shuffles)",
        det_rows.len(),
        vo.runs * vo.sm_counts.len(),
        vo.runs,
        vo.sm_counts.len()
    );
    if !controls.is_empty() {
        println!(
            "negative controls (atomic / injected, bf16): {caught}/{} correctly \
             flagged nondeterministic",
            controls.len()
        );
    }
    anyhow::ensure!(
        det_ok == det_rows.len(),
        "determinism violation: {} deterministic case(s) produced multiple hashes",
        det_rows.len() - det_ok
    );
    anyhow::ensure!(
        controls.is_empty() || caught > 0,
        "oracle failed to flag any bf16 negative control as nondeterministic"
    );
    Ok(())
}

/// `dash trace` — the serving-scenario layer: deterministic request
/// traces, continuous-batching compilation, and the per-request
/// batch-invariance oracle (see `dash trace --help` / docs/CLI.md).
fn cmd_trace(action: Option<&str>, opts: &Opts) -> dash::Result<()> {
    use dash::exec::{verify_batch_invariance, OracleOptions};
    use dash::numerics::Precision;
    use dash::traceload::{compile, compose_step_schedule, generate, BatchConfig, TraceSpec};

    let spec = match opts.get_opt("spec") {
        Some(path) => TraceSpec::load(path)?,
        None => {
            let mut s = TraceSpec::smoke(opts.get("seed", 42).map_err(err)?);
            s.requests = opts.get("requests", s.requests).map_err(err)?;
            s
        }
    };
    let trace = generate(&spec)?;
    let heads: usize = opts.get("heads", 2).map_err(err)?;
    match action {
        Some("generate") => {
            println!(
                "trace '{}' seed {}: {} requests over {} arrival step(s), {} tiles total",
                spec.name,
                spec.seed,
                trace.requests.len(),
                trace.horizon() + 1,
                trace.total_tiles()
            );
            println!("  {:>4} {:>8} {:>7} {:>7}", "id", "arrival", "prompt", "decode");
            for r in &trace.requests {
                println!(
                    "  {:>4} {:>8} {:>7} {:>7}",
                    r.id, r.arrival_step, r.prompt_tiles, r.decode_tiles
                );
            }
            if let Some(path) = opts.get_opt("export") {
                spec.save(path)?;
                println!(
                    "spec -> {path} (round-trips byte-identically; replay with --spec {path})"
                );
            }
        }
        Some("simulate") => {
            let kind = opts.schedule().map_err(err)?;
            let cfg = BatchConfig {
                max_batch: opts.get("batch", 4).map_err(err)?,
                chunk_tiles: opts.get("chunk", 0).map_err(err)?,
                n_heads: heads,
                admission: 0,
            };
            let steps = compile(&trace, &cfg)?;
            println!(
                "trace '{}' seed {}: {} requests -> {} serving step(s) (batch {}, chunk {}, \
                 schedule {})",
                spec.name,
                spec.seed,
                trace.requests.len(),
                steps.len(),
                cfg.max_batch,
                cfg.chunk_tiles,
                kind.name()
            );
            let mut total = 0.0;
            for step in &steps {
                let s = compose_step_schedule(step, kind)?;
                let sim = SimConfig::ideal(step.total_tiles().max(1));
                let r = simulate(&s, &sim)?;
                total += r.makespan;
                let reqs: Vec<String> = step
                    .slices
                    .iter()
                    .map(|sl| format!("{}:{}", sl.request, sl.phase.name()))
                    .collect();
                println!(
                    " step {:>3}  tiles {:>3}  makespan {:>8.2}  util {:>5.1}%  [{}]",
                    step.index,
                    step.total_tiles(),
                    r.makespan,
                    r.utilization() * 100.0,
                    reqs.join(" ")
                );
            }
            println!(
                "total makespan {total:.2} over {} step(s) (ideal abstract machine)",
                steps.len()
            );
        }
        Some("verify") => {
            let batch_sizes: Vec<usize> = opts
                .get_opt("batch-sizes")
                .unwrap_or("1,2,4")
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .ok()
                        .filter(|&b| b >= 1)
                        .ok_or_else(|| anyhow::anyhow!("bad --batch-sizes '{t}'"))
                })
                .collect::<dash::Result<Vec<usize>>>()?;
            let orders: usize = opts.get("orders", 3).map_err(err)?;
            anyhow::ensure!(orders >= 1, "--orders must be >= 1");
            let inject = opts.flag("inject-batch");
            let precisions: Vec<Precision> = match opts.get_opt("precision").unwrap_or("both") {
                "both" => vec![Precision::F32, Precision::Bf16],
                p => vec![Precision::parse(p)
                    .ok_or_else(|| anyhow::anyhow!("unknown precision '{p}' (f32|bf16|both)"))?],
            };
            let kinds: Vec<ScheduleKind> = match opts.get_opt("schedule") {
                None | Some("all") => vec![
                    ScheduleKind::Fa3,
                    ScheduleKind::Descending,
                    ScheduleKind::Shift,
                    ScheduleKind::SymmetricShift,
                    ScheduleKind::TwoPass,
                    ScheduleKind::Lpt,
                    ScheduleKind::Tuned,
                ],
                Some(name) => vec![ScheduleKind::parse(name)
                    .ok_or_else(|| anyhow::anyhow!("unknown schedule '{name}'"))?],
            };
            println!(
                "batch-invariance oracle: trace '{}' seed {} ({} requests) | batch sizes \
                 {batch_sizes:?} x {orders} admission order(s), heads {heads}",
                spec.name,
                spec.seed,
                trace.requests.len()
            );
            let mut cases = 0usize;
            let mut flipped = 0usize;
            for &kind in &kinds {
                for &precision in &precisions {
                    let o = OracleOptions {
                        block: opts.get("block", 4).map_err(err)?,
                        head_dim: opts.get("head-dim", 8).map_err(err)?,
                        precision,
                        inject_batch: inject,
                        ..OracleOptions::quick(spec.seed)
                    };
                    let v =
                        verify_batch_invariance(&trace, kind, &batch_sizes, orders, heads, &o)?;
                    cases += 1;
                    if !v.invariant() {
                        flipped += 1;
                    }
                    anyhow::ensure!(
                        v.flops_ok(),
                        "{}: executed FLOPs diverge from the analytic count",
                        kind.name()
                    );
                    println!(
                        " {:<16} {:<5} cells {:>2}  steps {:>4}  request hashes {:>2}/{:<2} \
                         invariant {}",
                        kind.name(),
                        precision.name(),
                        v.cells,
                        v.executions,
                        v.distinct_hashes(),
                        v.requests,
                        if v.invariant() { "YES" } else { "no" }
                    );
                }
            }
            if inject {
                // The serving negative control mirrors --inject-xdev: a
                // batch-layout-keyed fold MUST break per-request
                // invariance somewhere, and a caught injection is still a
                // violation — either way this mode exits nonzero.
                anyhow::bail!(
                    "{}",
                    if flipped > 0 {
                        format!(
                            "injected batch-layout fold caught: {flipped}/{cases} case(s) \
                             lost per-request invariance (expected under --inject-batch)"
                        )
                    } else {
                        format!(
                            "oracle failed to flag the injected batch-layout fold in any of \
                             {cases} case(s)"
                        )
                    }
                );
            }
            anyhow::ensure!(
                flipped == 0,
                "batch-invariance violation: {flipped}/{cases} case(s) produced multiple \
                 per-request hashes"
            );
            println!(
                "batch invariance: {cases}/{cases} case(s) — one gradient hash per request \
                 across batch sizes {batch_sizes:?} and {orders} admission order(s)"
            );
        }
        Some(other) => {
            anyhow::bail!("unknown trace action '{other}' (generate|simulate|verify)")
        }
        None => anyhow::bail!("dash trace needs an action: generate|simulate|verify"),
    }
    Ok(())
}

/// Persist a `tune --sweep` run as the BENCH_tune_sweep.json baseline
/// snapshot (opt out with --no-bench), so every sweep feeds the perf
/// trajectory — see `dash baseline`.
fn save_sweep_bench(
    opts: &Opts,
    points: Vec<dash::trace::baseline::BaselinePoint>,
) -> dash::Result<()> {
    if opts.flag("no-bench") || points.is_empty() {
        return Ok(());
    }
    let snap = dash::trace::baseline::BaselineSnapshot {
        name: "tune_sweep".into(),
        suite: "external".into(),
        points,
    };
    let path = snap.save(std::path::Path::new("."))?;
    println!(
        "baseline snapshot -> {} ({} points; disable with --no-bench)",
        path.display(),
        snap.points.len()
    );
    Ok(())
}

fn cmd_tune(opts: &Opts) -> dash::Result<()> {
    use dash::autotune::{tune, ScheduleCache, TuneOptions, WorkloadFingerprint};

    let budget: usize = opts.get("budget", 400).map_err(err)?;
    let seed: u64 = opts.get("seed", 42).map_err(err)?;
    let batch: usize = opts.get("batch", 8).map_err(err)?;
    let threads: usize = opts.get("threads", 0).map_err(err)?;
    if batch == 0 {
        return Err(err("--batch must be at least 1".to_string()));
    }

    if opts.flag("sweep") {
        let heads: usize = opts.get("heads", 4).map_err(err)?;
        // With --gpu, the same grid runs per profile (comma list = the
        // cross-GPU comparison); without it, the legacy ideal-machine grid.
        if let Some(gpu_arg) = opts.get_opt("gpu") {
            let profiles = gpu_arg
                .split(',')
                .map(|a| hw::resolve(a.trim()))
                .collect::<dash::Result<Vec<GpuProfile>>>()?;
            let names: Vec<&str> = profiles.iter().map(|p| p.name.as_str()).collect();
            println!(
                "cross-GPU tuned sweep: gpus={} heads={heads} budget={budget} seed={seed} \
                 (masks full+causal, n in {:?}, head_dim in {:?})",
                names.join(","),
                figs::CROSS_GPU_NS,
                figs::CROSS_GPU_HEAD_DIMS
            );
            let rows = figs::cross_gpu_sweep(&profiles, heads, budget, seed);
            if opts.flag("csv") {
                println!("{}", figs::render_csv(&rows));
            } else {
                println!("{}", figs::render_table(&rows));
            }
            if let Some(path) = opts.get_opt("json") {
                std::fs::write(path, figs::cross_gpu_json(&rows).dump())?;
                println!("json artifact -> {path}");
            }
            save_sweep_bench(opts, dash::trace::baseline::points_from_rows("cross_gpu", &rows))?;
            return Ok(());
        }
        println!(
            "tuned-vs-analytic sweep: heads={heads} budget={budget} seed={seed} \
             (masks full+causal, n in {:?}, n_sm in {:?})",
            figs::TUNE_SWEEP_NS,
            figs::TUNE_SWEEP_SMS
        );
        let rows = figs::tune_sweep(heads, budget, seed);
        if opts.flag("csv") {
            println!("{}", figs::render_csv(&rows));
        } else {
            println!("{}", figs::render_table(&rows));
        }
        let wins = rows.iter().filter(|r| r.speedup > 1.0 + 1e-9).count();
        let optimal = rows.iter().filter(|r| r.gap_pct < 1e-6).count();
        println!(
            "{} points: tuned strictly beats the best analytic schedule on {wins}, \
             certified optimal (gap 0) on {optimal}, never loses.",
            rows.len()
        );
        save_sweep_bench(opts, dash::trace::baseline::points_from_rows("sweep", &rows))?;
        return Ok(());
    }

    if let Some(queue_path) = opts.get_opt("queue") {
        return cmd_tune_queue(opts, queue_path, budget, seed, batch, threads);
    }

    let n: usize = opts.get("n", 8).map_err(err)?;
    let n_q: usize = opts.get("n-q", n).map_err(err)?;
    let heads: usize = opts.get("heads", 4).map_err(err)?;
    let mask = opts.mask().map_err(err)?;
    let profile = opts.gpu("abstract").map_err(err)?;
    let spec = ProblemSpec { n_kv: n, n_q, n_heads: heads, mask };
    // Score as ScheduleKind::Tuned — the same kind `simulate --schedule
    // tuned` fingerprints with, so entries persisted here are found there.
    let sim = sim_config_for(opts, &profile, ScheduleKind::Tuned, n).map_err(err)?;

    // Cluster identity enters the cache key (and nothing else): a
    // schedule tuned for one device count / interconnect never serves
    // another, while `--devices 1` without `--cluster` keeps the
    // historical single-GPU key byte-for-byte.
    let devices: usize = opts.get("devices", 1).map_err(err)?;
    let cluster_hash = match opts.get_opt("cluster") {
        None => 0,
        Some(arg) => hw::resolve_cluster(arg)?.fingerprint(),
    };
    let fingerprint = WorkloadFingerprint::new(&spec, &sim).with_cluster(devices, cluster_hash);
    let key = fingerprint.key();
    let cache_path = opts.get_opt("cache").unwrap_or(dash::autotune::DEFAULT_CACHE_PATH);
    let use_cache = !opts.flag("no-cache");

    println!(
        "workload {key}: n={n}x{n_q} heads={heads} mask={} gpu={} n_sm={} r/c={:.3}",
        spec.mask.name(),
        profile.name,
        sim.n_sm,
        sim.cost.reduce / sim.cost.compute
    );

    // Entries are re-validated against the §3.1 invariants inside
    // `ScheduleCache::get`, so a hit is a legal schedule by construction.
    let retune = opts.flag("retune");
    let mut cache = use_cache.then(|| ScheduleCache::open(cache_path));
    if let Some(cache) = cache.as_ref().filter(|_| !retune) {
        if let Some(hit) = cache.get(&key, &spec) {
            let gap = if hit.lower_bound > 0.0 {
                (hit.makespan - hit.lower_bound).max(0.0) / hit.lower_bound
            } else {
                0.0
            };
            println!("cache HIT ({cache_path}) — skipping search");
            println!(
                " makespan {:.2} | lower bound {:.2} | optimality gap {:.2}% | seeded from {}",
                hit.makespan,
                hit.lower_bound,
                gap * 100.0,
                hit.seed_name
            );
            println!(" schedule: {} chains, validates OK", hit.schedule.chains.len());
            return Ok(());
        }
        println!("cache miss ({cache_path}) — searching (budget {budget})");
    } else if retune && use_cache {
        println!("--retune: ignoring any cached entry — searching (budget {budget})");
    } else {
        println!("cache disabled — searching (budget {budget})");
    }

    if opts.get_opt("portfolio").is_some() || opts.flag("portfolio") {
        use dash::autotune::{tune_portfolio, PortfolioOptions};
        let replicas: usize = opts.get("portfolio", 4).map_err(err)?;
        anyhow::ensure!(replicas >= 1, "--portfolio needs at least one replica");
        let p = tune_portfolio(
            &spec,
            &PortfolioOptions { replicas, budget, seed, sim, batch, threads },
        )?;
        schedule::validate(&p.winner.schedule).map_err(|e| anyhow::anyhow!("{e}"))?;
        // No thread count in this output: CI byte-compares portfolio runs
        // across --threads settings.
        println!(
            " portfolio: {replicas} replica(s) raced, winner replica {} \
             (makespan spread {:.2})",
            p.winner_index,
            p.makespan_spread()
        );
        print!("{}", figs::render_table(&figs::replica_rows(&p)));
        print_tune_summary(&p.winner, sim.n_sm, &format!(" (batch {batch})"));
        if let Some(cache) = &mut cache {
            cache.put(&key, &p.winner);
            cache.save()?;
            println!(" cached -> {cache_path} ({} entries)", cache.len());
        }
        return Ok(());
    }

    // On a miss, warm-start from the nearest structured-key neighbor in
    // the cache (same mask family, heads, and cost model) unless told not
    // to — the fleet setting runs warm starts at ~10x smaller budgets.
    let warm = cache
        .as_ref()
        .filter(|_| !opts.flag("no-warm"))
        .and_then(|c| dash::autotune::warm_start(&spec, &key, c))
        .filter(|w| !w.seeds.is_empty());
    let result = match &warm {
        Some(w) => {
            let warm_budget: usize = opts.get("warm-budget", budget).map_err(err)?;
            println!(
                " warm start from {} ({}; budget {warm_budget})",
                w.from_key,
                if w.exact_geometry { "same geometry" } else { "regenerated seed family" }
            );
            dash::autotune::tune_seeded(
                &spec,
                &TuneOptions { budget: warm_budget, seed, sim, batch, threads },
                &w.seeds,
            )?
        }
        None => tune(&spec, &TuneOptions { budget, seed, sim, batch, threads })?,
    };
    schedule::validate(&result.schedule).map_err(|e| anyhow::anyhow!("{e}"))?;
    print_tune_summary(
        &result,
        sim.n_sm,
        &format!(
            " (batch {batch}, threads {})",
            if threads == 0 { "auto".to_string() } else { threads.to_string() }
        ),
    );
    if let Some(cache) = &mut cache {
        cache.put(&key, &result);
        cache.save()?;
        println!(" cached -> {cache_path} ({} entries)", cache.len());
    }
    Ok(())
}

/// The shared `dash tune` result block. `skipped_detail` carries the
/// mode-specific tail of the skipped-proposals line (the portfolio path
/// must keep thread counts out of its output).
fn print_tune_summary(result: &dash::autotune::TuneResult, n_sm: usize, skipped_detail: &str) {
    println!(
        " schedule: {} chains over {} SMs, validates OK",
        result.schedule.chains.len(),
        n_sm
    );
    println!(
        " best analytic seed: {:<16} makespan {:.2}",
        result.seed_kind.name(),
        result.seed_makespan
    );
    println!(
        " tuned:              {:<16} makespan {:.2}  ({} proposals evaluated, {} improvements)",
        "tuned",
        result.makespan,
        result.evaluated,
        result.improvements
    );
    println!(
        " proposals skipped: {} illegal, {} simulation-rejected{skipped_detail}",
        result.skipped_invalid, result.skipped_sim
    );
    println!(
        " lower bound {:.2} (work {:.2} | chain {:.2} | reduction {:.2})",
        result.bound.overall(),
        result.bound.work,
        result.bound.chain,
        result.bound.reduction
    );
    println!(
        " optimality gap {:.2}%{} | improvement over analytic {:.2}%",
        result.gap() * 100.0,
        if result.gap() < 1e-9 { " (certified optimal)" } else { "" },
        result.improvement() * 100.0
    );
}

/// `dash tune --queue`: drain a workload-specs file into one shared cache
/// under an advisory file lock, deduping identical keys and reporting
/// hit/warm/cold provenance per workload.
fn cmd_tune_queue(
    opts: &Opts,
    queue_path: &str,
    budget: usize,
    seed: u64,
    batch: usize,
    threads: usize,
) -> dash::Result<()> {
    use dash::autotune::{parse_queue, run_queue, CacheLock, ScheduleCache, TuneOptions};
    use std::time::Duration;

    let profile = opts.gpu("abstract").map_err(err)?;
    // Per-spec geometry (including n_sm) comes from the queue file; the
    // cost model, budgets, and seed are shared across the drain.
    let sim = sim_config_for(opts, &profile, ScheduleKind::Tuned, 8).map_err(err)?;
    let warm_budget: usize = opts.get("warm-budget", 0).map_err(err)?;

    let text = std::fs::read_to_string(queue_path)
        .map_err(|e| anyhow::anyhow!("reading queue {queue_path}: {e}"))?;
    let queue = parse_queue(&text)?;
    anyhow::ensure!(!queue.is_empty(), "queue {queue_path} holds no specs");

    let cache_path = opts.get_opt("cache").unwrap_or(dash::autotune::DEFAULT_CACHE_PATH);
    let use_cache = !opts.flag("no-cache");
    println!(
        "tune queue: {} spec(s) from {queue_path} -> {} (budget {budget}, warm budget {}, \
         seed {seed})",
        queue.len(),
        if use_cache { cache_path } else { "(cache disabled)" },
        if warm_budget == 0 { "= cold".to_string() } else { warm_budget.to_string() },
    );

    // Advisory lock so concurrent fleet drains of one shared cache file
    // serialize instead of clobbering each other's saves.
    let _lock = if use_cache {
        Some(CacheLock::acquire(std::path::Path::new(cache_path), Duration::from_secs(30))?)
    } else {
        None
    };
    let mut cache = if use_cache {
        ScheduleCache::open(cache_path)
    } else {
        // Throwaway store: never read from disk, never saved — hits and
        // warm starts still dedupe within this drain.
        ScheduleCache::open(
            std::env::temp_dir().join(format!("dash-tune-queue-{}.json", std::process::id())),
        )
    };
    let base = TuneOptions { budget, seed, sim, batch, threads };
    let report = run_queue(&queue, &base, warm_budget, &mut cache)?;

    let rows = figs::queue_rows(&report);
    if opts.flag("csv") {
        print!("{}", figs::render_csv(&rows));
    } else {
        print!("{}", figs::render_table(&rows));
    }
    let (hit, warm, cold) = report.tally();
    println!(
        "{} workload(s): {hit} hit, {warm} warm, {cold} cold ({} duplicate spec(s) deduped)",
        report.outcomes.len(),
        report.deduped
    );
    if use_cache {
        cache.save()?;
        println!("cache -> {cache_path} ({} entries)", cache.len());
    }
    Ok(())
}

fn cmd_hw(opts: &Opts) -> dash::Result<()> {
    if let Some(arg) = opts.get_opt("cluster") {
        let c = hw::resolve_cluster(arg)?;
        println!("{}", c.to_json().dump());
        println!(
            "derived: {} x {} over {} | hop(block 128, hd 64) {:.1} cycles | \
             fingerprint {:016x}",
            c.n_devices(),
            c.devices[0].name,
            c.link.name,
            c.hop_cycles(128, 64),
            c.fingerprint()
        );
        return Ok(());
    }
    if let Some(arg) = opts.get_opt("export-cluster") {
        let c = hw::resolve_cluster(arg)?;
        let out = opts.get_opt("out").unwrap_or("cluster.json");
        c.save(out)?;
        println!("wrote {out} — edit it and pass `--cluster {out}` to any command");
        return Ok(());
    }
    if let Some(arg) = opts.get_opt("show") {
        let p = hw::resolve(arg)?;
        println!("{}", p.to_json().dump());
        if p.is_abstract() {
            println!("(the paper's §3 model: n_sm = n_kv, unit costs, no L2/register effects)");
        } else {
            println!(
                "derived: {:.0} effective BF16 TFLOPs | occupancy hd64={} hd128={} | \
                 L2 {} MiB in {} segments | fingerprint {:016x}",
                p.machine_flops() / 1e12,
                p.occupancy(128, 64),
                p.occupancy(128, 128),
                p.l2_bytes / (1024 * 1024),
                p.l2_segments,
                p.fingerprint()
            );
        }
        return Ok(());
    }
    if let Some(arg) = opts.get_opt("export") {
        let p = hw::resolve(arg)?;
        let default_out = format!("{}.json", p.name);
        let out = opts.get_opt("out").unwrap_or(&default_out);
        p.save(out)?;
        println!("wrote {out} — edit it and pass `--gpu {out}` to any command");
        return Ok(());
    }
    println!("built-in GPU profiles (select with --gpu <name>, or --gpu <profile.json>):");
    for name in hw::PRESET_NAMES {
        let p = hw::preset(name).expect("preset list is self-consistent");
        if p.is_abstract() {
            println!("  {name:<9} the paper's §3 model: n_sm = n_kv, unit costs, ideal L2");
        } else {
            println!(
                "  {name:<9} {:>3} SMs @ {:.2} GHz | {:>2} MiB L2 | {:.0} effective BF16 TFLOPs",
                p.n_sm,
                p.clock_ghz,
                p.l2_bytes / (1024 * 1024),
                p.machine_flops() / 1e12
            );
        }
    }
    println!("custom parts: `dash hw --export h800 --out my_gpu.json`, edit, `--gpu my_gpu.json`");
    println!(
        "clusters: `dash hw --cluster nvlink:2xh800 | ib:4xa100 | abstract:<n> | <file.json>` \
         to inspect, `--export-cluster` to write one"
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn load_config(opts: &Opts) -> dash::Result<TrainConfig> {
    match opts.get_opt("config") {
        Some(p) => TrainConfig::load(p),
        None => Ok(TrainConfig::default()),
    }
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train(_opts: &Opts) -> dash::Result<()> {
    anyhow::bail!(
        "`dash train` executes the AOT artifacts via PJRT, which this binary was \
         built without; rebuild with `cargo build --features pjrt` (needs the xla crate)"
    )
}

#[cfg(not(feature = "pjrt"))]
fn cmd_audit(_opts: &Opts) -> dash::Result<()> {
    anyhow::bail!(
        "`dash audit` executes the AOT artifacts via PJRT, which this binary was \
         built without; rebuild with `cargo build --features pjrt` (needs the xla crate)"
    )
}

#[cfg(feature = "pjrt")]
fn cmd_train(opts: &Opts) -> dash::Result<()> {
    let mut cfg = load_config(opts)?;
    if let Some(s) = opts.get_opt("steps") {
        cfg.steps = s.parse()?;
    }
    println!(
        "training: {} params, {} steps, batch {} x seqlen {}, determinism {:?}",
        cfg.param_count(),
        cfg.steps,
        cfg.batch,
        cfg.seqlen,
        cfg.determinism
    );
    let mut t = Trainer::new(cfg)?;
    t.run()?;
    println!(
        "done: loss {:.4} -> {:.4}, {:.0} tok/s, final fingerprint {:016x}",
        t.metrics.first_loss(),
        t.metrics.final_loss(5),
        t.metrics.tokens_per_second(),
        t.param_fingerprint()?
    );
    if let Some(p) = opts.get_opt("loss-csv") {
        std::fs::write(p, t.metrics.to_csv())?;
        println!("loss curve -> {p}");
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_audit(opts: &Opts) -> dash::Result<()> {
    let mut cfg = match opts.get_opt("config") {
        Some(p) => TrainConfig::load(p)?,
        None => TrainConfig { microbatches: 4, batch: 8, ..TrainConfig::default() },
    };
    cfg.steps = opts.get("steps", 20).map_err(err)?;
    cfg.determinism =
        if opts.flag("shuffled") { DeterminismMode::Shuffled } else { DeterminismMode::Deterministic };
    println!("audit: two runs of {} steps, determinism {:?}", cfg.steps, cfg.determinism);
    let run = |salt: u64| -> dash::Result<dash::coordinator::RunFingerprint> {
        let mut t = Trainer::new(cfg.clone())?;
        t.shuffle_salt = salt;
        t.run()?;
        Ok(t.fingerprint.clone())
    };
    let a = run(1)?;
    let b = run(2)?;
    match a.first_divergence(&b) {
        None => println!("PASS: runs are bitwise identical at every checkpoint"),
        Some(s) => println!("DIVERGED at step {s} (expected for --shuffled)"),
    }
    Ok(())
}

fn cmd_explore(opts: &Opts) -> dash::Result<()> {
    let n: usize = opts.get("n", 8).map_err(err)?;
    let heads: usize = opts.get("heads", 4).map_err(err)?;
    if opts.flag("lemma") {
        let spec = ChainSpec { n_chains: 4, chain_len: 6, edge_weight: 1.0 };
        println!(
            "Lemma 1 demo on 4 isomorphic chains of 6 edges (CP = {}):",
            spec.base_critical_path()
        );
        let fwd = check_depth_monotone(&spec, &[(spec.node(0, 2), spec.node(1, 5))]);
        println!(
            "  depth 2 -> 5 edge: CP {} (preserved: {})",
            fwd.final_cp.unwrap(),
            fwd.predicts_preserved()
        );
        let bwd = check_depth_monotone(&spec, &[(spec.node(0, 5), spec.node(1, 2))]);
        println!(
            "  depth 5 -> 2 edge: CP {} (violations: {})",
            bwd.final_cp.unwrap(),
            bwd.violations.len()
        );
        return Ok(());
    }
    println!("schedule comparison, n={n}, heads={heads}, c=1.0, r=0.25, ideal machine:");
    for (kind, mask) in [
        (ScheduleKind::Fa3Atomic, MaskSpec::full()),
        (ScheduleKind::Fa3, MaskSpec::full()),
        (ScheduleKind::Shift, MaskSpec::full()),
        (ScheduleKind::Fa3Atomic, MaskSpec::causal()),
        (ScheduleKind::Fa3, MaskSpec::causal()),
        (ScheduleKind::Descending, MaskSpec::causal()),
        (ScheduleKind::Lpt, MaskSpec::causal()),
        (ScheduleKind::SymmetricShift, MaskSpec::causal()),
        (ScheduleKind::TwoPass, MaskSpec::causal()),
        (ScheduleKind::Descending, MaskSpec::sliding_window(2)),
        (ScheduleKind::SymmetricShift, MaskSpec::sliding_window(2)),
    ] {
        let spec = ProblemSpec::square(n, heads, mask);
        let s = build(kind, &spec, &SimConfig::ideal(n))?;
        let r = simulate(&s, &SimConfig::ideal(n))?;
        println!(
            "  {:<16} {:<12} makespan {:>9.2}  util {:>5.1}%  stalls {:>8.2}",
            kind.name(),
            spec.mask.name(),
            r.makespan,
            r.utilization() * 100.0,
            r.stall_time
        );
    }
    Ok(())
}
