//! Trace workload specification: request count, length distributions, and
//! the arrival process — with a strict JSON codec and typed validation.
//!
//! Lengths are in *tiles* (the schedule layer's unit); a real deployment
//! maps tokens to tiles by the kernel block size. Every field is checked
//! by [`TraceSpec::validate`]: non-finite or non-positive parameters are
//! rejected with typed errors so a malformed spec can never silently
//! produce a degenerate trace.

use crate::util::{DetRng, Json};
use anyhow::{bail, Context, Result};

/// A request-length distribution (prompt or decode), sampled in tiles.
#[derive(Debug, Clone, PartialEq)]
pub enum LengthModel {
    /// Zipf over `1..=max_tiles` with the given exponent: the classic
    /// heavy-head shape of production prompt lengths (many short, few
    /// long).
    Zipf {
        /// Largest length the model can emit (tiles, >= 1).
        max_tiles: usize,
        /// Zipf exponent `s > 0`; larger = heavier head.
        exponent: f64,
    },
    /// Log-normal, rounded up to whole tiles and clamped to
    /// `1..=max_tiles` — the empirical fit for decode lengths.
    LogNormal {
        /// Mean of the underlying normal (of `ln x`).
        mu: f64,
        /// Standard deviation of the underlying normal (>= 0, finite).
        sigma: f64,
        /// Clamp ceiling in tiles (>= 1).
        max_tiles: usize,
    },
    /// Every request gets exactly this many tiles (degenerate but useful
    /// for closed-form baselines).
    Fixed {
        /// The constant length in tiles (>= 1).
        tiles: usize,
    },
}

impl LengthModel {
    /// Draw one length in tiles (always >= 1, <= the model's cap).
    pub fn sample(&self, rng: &mut DetRng) -> usize {
        match *self {
            LengthModel::Zipf { max_tiles, exponent } => rng.gen_zipf(max_tiles, exponent),
            LengthModel::LogNormal { mu, sigma, max_tiles } => {
                (rng.gen_log_normal(mu, sigma).ceil() as usize).clamp(1, max_tiles)
            }
            LengthModel::Fixed { tiles } => tiles,
        }
    }

    /// Largest length this model can emit.
    pub fn max(&self) -> usize {
        match *self {
            LengthModel::Zipf { max_tiles, .. } | LengthModel::LogNormal { max_tiles, .. } => {
                max_tiles
            }
            LengthModel::Fixed { tiles } => tiles,
        }
    }

    fn validate(&self, what: &str) -> Result<()> {
        match *self {
            LengthModel::Zipf { max_tiles, exponent } => {
                if max_tiles == 0 {
                    bail!("{what}: zipf max_tiles must be >= 1");
                }
                if !(exponent > 0.0 && exponent.is_finite()) {
                    bail!("{what}: zipf exponent must be finite and > 0, got {exponent}");
                }
            }
            LengthModel::LogNormal { mu, sigma, max_tiles } => {
                if max_tiles == 0 {
                    bail!("{what}: log-normal max_tiles must be >= 1");
                }
                if !mu.is_finite() || !sigma.is_finite() {
                    bail!("{what}: log-normal mu/sigma must be finite, got mu={mu} sigma={sigma}");
                }
                if sigma < 0.0 {
                    bail!("{what}: log-normal sigma must be >= 0, got {sigma}");
                }
            }
            LengthModel::Fixed { tiles } => {
                if tiles == 0 {
                    bail!("{what}: fixed tiles must be >= 1");
                }
            }
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        match *self {
            LengthModel::Zipf { max_tiles, exponent } => Json::Obj(vec![
                ("model".into(), Json::Str("zipf".into())),
                ("max_tiles".into(), Json::Num(max_tiles as f64)),
                ("exponent".into(), Json::Num(exponent)),
            ]),
            LengthModel::LogNormal { mu, sigma, max_tiles } => Json::Obj(vec![
                ("model".into(), Json::Str("log-normal".into())),
                ("mu".into(), Json::Num(mu)),
                ("sigma".into(), Json::Num(sigma)),
                ("max_tiles".into(), Json::Num(max_tiles as f64)),
            ]),
            LengthModel::Fixed { tiles } => Json::Obj(vec![
                ("model".into(), Json::Str("fixed".into())),
                ("tiles".into(), Json::Num(tiles as f64)),
            ]),
        }
    }

    fn from_json(j: &Json, what: &str) -> Result<Self> {
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .with_context(|| format!("{what}: missing 'model' field"))?;
        let num = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .with_context(|| format!("{what}: {model} model needs numeric '{key}'"))
        };
        let tiles = |key: &str| -> Result<usize> {
            j.get(key)
                .and_then(Json::as_usize)
                .with_context(|| format!("{what}: {model} model needs integer '{key}'"))
        };
        match model {
            "zipf" => Ok(LengthModel::Zipf { max_tiles: tiles("max_tiles")?, exponent: num("exponent")? }),
            "log-normal" => Ok(LengthModel::LogNormal {
                mu: num("mu")?,
                sigma: num("sigma")?,
                max_tiles: tiles("max_tiles")?,
            }),
            "fixed" => Ok(LengthModel::Fixed { tiles: tiles("tiles")? }),
            other => bail!("{what}: unknown length model '{other}' (expected 'zipf', 'log-normal', or 'fixed')"),
        }
    }
}

/// The request arrival process, in requests per engine step.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalModel {
    /// Independent Poisson arrivals: `Poisson(rate)` new requests per step.
    Poisson {
        /// Mean arrivals per step (finite, > 0).
        rate: f64,
    },
    /// Bursty arrivals: every `period` steps a burst of
    /// `Poisson(rate * period)` requests lands at once, nothing in
    /// between — same long-run rate as the Poisson model, maximally
    /// clumped admission.
    Bursty {
        /// Long-run mean arrivals per step (finite, > 0).
        rate: f64,
        /// Steps between bursts (>= 1).
        period: usize,
    },
}

impl ArrivalModel {
    /// Arrivals landing at engine step `step`.
    pub fn sample(&self, step: usize, rng: &mut DetRng) -> usize {
        match *self {
            ArrivalModel::Poisson { rate } => rng.gen_poisson(rate),
            ArrivalModel::Bursty { rate, period } => {
                if step % period == 0 {
                    rng.gen_poisson(rate * period as f64)
                } else {
                    0
                }
            }
        }
    }

    fn validate(&self) -> Result<()> {
        let rate = match *self {
            ArrivalModel::Poisson { rate } => rate,
            ArrivalModel::Bursty { rate, period } => {
                if period == 0 {
                    bail!("arrival: bursty period must be >= 1");
                }
                rate
            }
        };
        if !(rate > 0.0 && rate.is_finite()) {
            bail!("arrival: rate must be finite and > 0, got {rate}");
        }
        Ok(())
    }

    fn to_json(&self) -> Json {
        match *self {
            ArrivalModel::Poisson { rate } => Json::Obj(vec![
                ("model".into(), Json::Str("poisson".into())),
                ("rate".into(), Json::Num(rate)),
            ]),
            ArrivalModel::Bursty { rate, period } => Json::Obj(vec![
                ("model".into(), Json::Str("bursty".into())),
                ("rate".into(), Json::Num(rate)),
                ("period".into(), Json::Num(period as f64)),
            ]),
        }
    }

    fn from_json(j: &Json) -> Result<Self> {
        let model = j
            .get("model")
            .and_then(Json::as_str)
            .context("arrival: missing 'model' field")?;
        let rate = j
            .get("rate")
            .and_then(Json::as_f64)
            .with_context(|| format!("arrival: {model} model needs numeric 'rate'"))?;
        match model {
            "poisson" => Ok(ArrivalModel::Poisson { rate }),
            "bursty" => Ok(ArrivalModel::Bursty {
                rate,
                period: j
                    .get("period")
                    .and_then(Json::as_usize)
                    .context("arrival: bursty model needs integer 'period'")?,
            }),
            other => bail!("arrival: unknown model '{other}' (expected 'poisson' or 'bursty')"),
        }
    }
}

/// A complete serving-workload description. The trace it generates is a
/// pure function of this value (see [`crate::traceload::generate`]).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    /// Human-readable workload name (carried into exported artifacts).
    pub name: String,
    /// RNG seed: the single source of randomness for the whole trace.
    pub seed: u64,
    /// Number of requests to generate (>= 1).
    pub requests: usize,
    /// Prompt-length distribution (tiles).
    pub prompt: LengthModel,
    /// Decode-length distribution (tiles).
    pub decode: LengthModel,
    /// Arrival process.
    pub arrival: ArrivalModel,
}

impl TraceSpec {
    /// A small, fast default workload: 8 Zipf prompts with log-normal
    /// decodes under Poisson arrivals — the smoke spec the CLI and tests
    /// share.
    pub fn smoke(seed: u64) -> Self {
        Self {
            name: "smoke".into(),
            seed,
            requests: 8,
            prompt: LengthModel::Zipf { max_tiles: 6, exponent: 1.1 },
            decode: LengthModel::LogNormal { mu: 0.7, sigma: 0.4, max_tiles: 4 },
            arrival: ArrivalModel::Poisson { rate: 1.5 },
        }
    }

    /// Check every field; typed error (never a panic) on the first
    /// violation.
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("trace spec: name must be non-empty");
        }
        if self.requests == 0 {
            bail!("trace spec: requests must be >= 1");
        }
        self.prompt.validate("prompt")?;
        self.decode.validate("decode")?;
        self.arrival.validate()
    }

    /// Serialize to a [`Json`] object (insertion-ordered, so the dump is
    /// canonical for a given spec).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("requests".into(), Json::Num(self.requests as f64)),
            ("prompt".into(), self.prompt.to_json()),
            ("decode".into(), self.decode.to_json()),
            ("arrival".into(), self.arrival.to_json()),
        ])
    }

    /// Parse from a [`Json`] object and [`TraceSpec::validate`] the
    /// result, so a loaded spec is always usable.
    pub fn from_json(j: &Json) -> Result<Self> {
        let spec = Self {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .context("trace spec: missing string 'name'")?
                .to_string(),
            seed: j
                .get("seed")
                .and_then(Json::as_usize)
                .context("trace spec: missing integer 'seed'")? as u64,
            requests: j
                .get("requests")
                .and_then(Json::as_usize)
                .context("trace spec: missing integer 'requests'")?,
            prompt: LengthModel::from_json(
                j.get("prompt").context("trace spec: missing 'prompt'")?,
                "prompt",
            )?,
            decode: LengthModel::from_json(
                j.get("decode").context("trace spec: missing 'decode'")?,
                "decode",
            )?,
            arrival: ArrivalModel::from_json(
                j.get("arrival").context("trace spec: missing 'arrival'")?,
            )?,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Canonical JSON text (what `dash trace generate --export` writes).
    pub fn dump(&self) -> String {
        self.to_json().dump()
    }

    /// Parse from JSON text (strict: trailing garbage, missing fields,
    /// unknown models, and invalid parameters are all typed errors).
    pub fn parse(text: &str) -> Result<Self> {
        Self::from_json(&Json::parse(text).context("trace spec: invalid JSON")?)
    }

    /// Write the canonical JSON to `path`.
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.dump()).with_context(|| format!("writing trace spec {path}"))
    }

    /// Load and validate a spec from `path`.
    pub fn load(path: &str) -> Result<Self> {
        Self::parse(
            &std::fs::read_to_string(path).with_context(|| format!("reading trace spec {path}"))?,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_spec_round_trips_byte_identically() {
        let spec = TraceSpec::smoke(42);
        let text = spec.dump();
        let back = TraceSpec::parse(&text).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.dump(), text, "re-dump must be byte-identical");
    }

    #[test]
    fn malformed_specs_are_typed_errors() {
        // Truncated JSON.
        assert!(TraceSpec::parse("{\"name\": \"x\"").is_err());
        // Missing fields.
        assert!(TraceSpec::parse("{\"name\": \"x\", \"seed\": 1}").is_err());
        // Unknown length model.
        let mut j = TraceSpec::smoke(1).to_json();
        if let Json::Obj(fields) = &mut j {
            for (k, v) in fields.iter_mut() {
                if k == "prompt" {
                    *v = Json::Obj(vec![("model".into(), Json::Str("pareto".into()))]);
                }
            }
        }
        let err = TraceSpec::from_json(&j).unwrap_err().to_string();
        assert!(err.contains("pareto"), "{err}");
    }

    #[test]
    fn invalid_parameters_are_rejected_by_validate() {
        let base = TraceSpec::smoke(1);
        let bad = [
            TraceSpec { requests: 0, ..base.clone() },
            TraceSpec { name: String::new(), ..base.clone() },
            TraceSpec {
                prompt: LengthModel::Zipf { max_tiles: 0, exponent: 1.0 },
                ..base.clone()
            },
            TraceSpec {
                prompt: LengthModel::Zipf { max_tiles: 4, exponent: -1.0 },
                ..base.clone()
            },
            TraceSpec {
                decode: LengthModel::LogNormal { mu: f64::NAN, sigma: 0.5, max_tiles: 4 },
                ..base.clone()
            },
            TraceSpec {
                decode: LengthModel::LogNormal { mu: 0.0, sigma: -0.5, max_tiles: 4 },
                ..base.clone()
            },
            TraceSpec { decode: LengthModel::Fixed { tiles: 0 }, ..base.clone() },
            TraceSpec { arrival: ArrivalModel::Poisson { rate: -2.0 }, ..base.clone() },
            TraceSpec { arrival: ArrivalModel::Poisson { rate: f64::INFINITY }, ..base.clone() },
            TraceSpec { arrival: ArrivalModel::Bursty { rate: 1.0, period: 0 }, ..base.clone() },
        ];
        for spec in bad {
            assert!(spec.validate().is_err(), "{spec:?} should be rejected");
        }
        base.validate().unwrap();
    }

    #[test]
    fn length_models_respect_their_caps() {
        let mut rng = DetRng::new(7);
        let zipf = LengthModel::Zipf { max_tiles: 5, exponent: 1.0 };
        let ln = LengthModel::LogNormal { mu: 1.0, sigma: 0.8, max_tiles: 6 };
        let fixed = LengthModel::Fixed { tiles: 3 };
        for _ in 0..1000 {
            assert!((1..=5).contains(&zipf.sample(&mut rng)));
            assert!((1..=6).contains(&ln.sample(&mut rng)));
            assert_eq!(fixed.sample(&mut rng), 3);
        }
        assert_eq!(zipf.max(), 5);
        assert_eq!(ln.max(), 6);
        assert_eq!(fixed.max(), 3);
    }

    #[test]
    fn bursty_arrivals_land_only_on_period_boundaries() {
        let m = ArrivalModel::Bursty { rate: 2.0, period: 4 };
        let mut rng = DetRng::new(11);
        for step in 0..32 {
            let n = m.sample(step, &mut rng);
            if step % 4 != 0 {
                assert_eq!(n, 0, "step {step}");
            }
        }
    }
}
