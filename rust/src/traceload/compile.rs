//! The batch compiler: continuous batching from a [`Trace`] to a sequence
//! of [`ServingStep`]s, each an ordinary [`ProblemSpec`] with a
//! [`MaskSpec::Document`] mask — one document per in-flight request
//! segment — plus the schedule composition that makes every step's
//! gradient bits *per-request* invariant to batch size and admission
//! order.
//!
//! ## The invariance construction
//!
//! A request's step-`j` segment always has the same tile count (a pure
//! function of the request and the [`BatchConfig`] chunking policy, never
//! of who else is in the batch). [`compose_step_schedule`] builds each
//! segment's chains and reduction order on a *singleton* spec of exactly
//! that size and then translates them by the segment's start tile — so
//! the fold order inside a segment is decided before the batch exists.
//! Combined with request-seeded operand content
//! ([`crate::traceload::Request::segment_seed`] →
//! [`crate::exec::execute_backward_docs`]), a request's gradient slice is
//! bitwise-identical wherever the batch compiler places it. The exec
//! oracle's `verify_batch_invariance` proves exactly this, and
//! `--inject-batch` breaks exactly this (a batch-layout-keyed fold
//! rotation) as the negative control.

use super::gen::Trace;
use crate::autotune::{tune, TuneOptions};
use crate::mask::MaskSpec;
use crate::schedule::{
    descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass, validate, Chain, ProblemSpec,
    Schedule, ScheduleKind,
};
use crate::sim::SimConfig;
use crate::util::fnv1a_words;
use anyhow::{bail, Context, Result};

/// Which serving phase a step slice belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// The whole prompt in one segment.
    Prefill,
    /// One chunk of a prompt split across steps.
    ChunkedPrefill,
    /// A single decode tile.
    Decode,
}

impl Phase {
    /// Display name (CLI tables, traces).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::ChunkedPrefill => "chunked-prefill",
            Phase::Decode => "decode",
        }
    }
}

/// One request's contribution to one serving step: a contiguous run of
/// tiles forming one document of the step's mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepSlice {
    /// Request id ([`crate::traceload::Request::id`]).
    pub request: usize,
    /// Serving phase of this segment.
    pub phase: Phase,
    /// Per-request segment index (0 = first prompt chunk; decode segments
    /// continue the count). The pair `(request, segment)` identifies the
    /// segment's content everywhere it may be scheduled.
    pub segment: usize,
    /// First tile of the segment within the step's sequence axis.
    pub start_tile: usize,
    /// Segment length in tiles (>= 1).
    pub tiles: usize,
}

impl StepSlice {
    /// Operand content seed for this slice — depends on `(request,
    /// segment)` only, so identical segments get identical data in every
    /// batch layout (see [`crate::exec::execute_backward_docs`]).
    pub fn doc_seed(&self) -> u64 {
        fnv1a_words([self.request as u64, self.segment as u64])
    }
}

/// One engine step compiled to schedule-stack vocabulary.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingStep {
    /// Emission index (0-based over non-empty steps).
    pub index: usize,
    /// The step as an ordinary problem: square grid of the batch's total
    /// tiles under a document mask with one document per slice.
    pub spec: ProblemSpec,
    /// Slices in admission order; `start_tile` runs are contiguous and
    /// cover the spec's sequence axis exactly.
    pub slices: Vec<StepSlice>,
}

impl ServingStep {
    /// Per-document operand seeds, aligned with the mask's document
    /// segments (the argument [`crate::exec::execute_backward_docs`]
    /// expects).
    pub fn doc_seeds(&self) -> Vec<u64> {
        self.slices.iter().map(StepSlice::doc_seed).collect()
    }

    /// Total tiles in the step (the spec's sequence length in tiles).
    pub fn total_tiles(&self) -> usize {
        self.spec.n_kv
    }
}

/// Continuous-batching policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchConfig {
    /// Maximum in-flight requests per step (>= 1).
    pub max_batch: usize,
    /// Prefill chunk size in tiles; `0` = unchunked (the whole prompt in
    /// one prefill segment).
    pub chunk_tiles: usize,
    /// Attention heads of every compiled step spec (>= 1).
    pub n_heads: usize,
    /// Admission-order key: `0` = FIFO by request id; any other value
    /// seeds a deterministic shuffle of the waiting queue — the knob the
    /// invariance matrix sweeps.
    pub admission: u64,
}

impl BatchConfig {
    /// FIFO admission with unchunked prefill.
    pub fn new(max_batch: usize, n_heads: usize) -> Self {
        Self { max_batch, chunk_tiles: 0, n_heads, admission: 0 }
    }

    fn validate(&self) -> Result<()> {
        if self.max_batch == 0 {
            bail!("batch config: max_batch must be >= 1");
        }
        if self.n_heads == 0 {
            bail!("batch config: n_heads must be >= 1");
        }
        Ok(())
    }
}

/// In-flight request state during compilation.
struct Active {
    request: usize,
    segment: usize,
    prompt_left: usize,
    prompt_total: usize,
    decode_left: usize,
}

/// Compile `trace` into serving steps under `cfg`. Deterministic: the
/// step sequence is a pure function of `(trace, cfg)`. Every request
/// contributes the same `(segment, tiles, phase)` sequence under every
/// `max_batch` and `admission` — only the grouping into steps changes.
pub fn compile(trace: &Trace, cfg: &BatchConfig) -> Result<Vec<ServingStep>> {
    cfg.validate()?;
    let mut steps = Vec::new();
    let mut pending: Vec<usize> = Vec::new(); // request indices, arrival order
    let mut active: Vec<Active> = Vec::new(); // admission order
    let mut next_arrival = 0usize;
    let mut done = 0usize;
    let mut engine_step = 0usize;
    // Defensive bound: every emitted step retires >= 1 tile, and empty
    // steps only occur while arrivals are still due.
    let horizon = trace.horizon();
    let budget = horizon + trace.total_tiles() + trace.requests.len() + 2;
    while done < trace.requests.len() {
        if engine_step > budget {
            bail!("trace '{}': compiler exceeded its step budget", trace.spec.name);
        }
        // Arrivals land, then admission fills free slots in key order.
        while next_arrival < trace.requests.len()
            && trace.requests[next_arrival].arrival_step <= engine_step
        {
            pending.push(next_arrival);
            next_arrival += 1;
        }
        pending.sort_by_key(|&i| {
            let id = trace.requests[i].id as u64;
            if cfg.admission == 0 {
                id
            } else {
                fnv1a_words([cfg.admission, id])
            }
        });
        while active.len() < cfg.max_batch && !pending.is_empty() {
            let i = pending.remove(0);
            let r = &trace.requests[i];
            active.push(Active {
                request: r.id,
                segment: 0,
                prompt_left: r.prompt_tiles,
                prompt_total: r.prompt_tiles,
                decode_left: r.decode_tiles,
            });
        }
        if active.is_empty() {
            engine_step += 1;
            continue;
        }
        // Each active request contributes exactly one segment this step.
        let mut slices = Vec::with_capacity(active.len());
        let mut start_tile = 0usize;
        for a in &mut active {
            let (tiles, phase) = if a.prompt_left > 0 {
                let chunk = if cfg.chunk_tiles == 0 {
                    a.prompt_left
                } else {
                    cfg.chunk_tiles.min(a.prompt_left)
                };
                let phase = if a.segment == 0 && chunk == a.prompt_total {
                    Phase::Prefill
                } else {
                    Phase::ChunkedPrefill
                };
                a.prompt_left -= chunk;
                (chunk, phase)
            } else {
                a.decode_left -= 1;
                (1, Phase::Decode)
            };
            slices.push(StepSlice {
                request: a.request,
                phase,
                segment: a.segment,
                start_tile,
                tiles,
            });
            a.segment += 1;
            start_tile += tiles;
        }
        let boundaries: Vec<usize> = slices[1..].iter().map(|s| s.start_tile).collect();
        let spec =
            ProblemSpec::square(start_tile, cfg.n_heads, MaskSpec::document(boundaries));
        steps.push(ServingStep { index: steps.len(), spec, slices });
        // Retire finished requests; freed slots admit next step.
        let before = active.len();
        active.retain(|a| a.prompt_left > 0 || a.decode_left > 0);
        done += before - active.len();
        engine_step += 1;
    }
    Ok(steps)
}

/// Build the singleton schedule for one `tiles`-tile full-mask segment.
/// The result depends on `(tiles, n_heads, kind)` only — the fact the
/// whole invariance proof leans on.
fn singleton_schedule(tiles: usize, n_heads: usize, kind: ScheduleKind) -> Result<Schedule> {
    let sub = ProblemSpec::square(tiles, n_heads, MaskSpec::full());
    Ok(match kind {
        ScheduleKind::Fa3 => fa3(&sub, true),
        ScheduleKind::Fa3Atomic => fa3(&sub, false),
        ScheduleKind::Descending => descending(&sub),
        ScheduleKind::SymmetricShift => symmetric_shift(&sub),
        ScheduleKind::TwoPass => two_pass(&sub),
        ScheduleKind::Lpt => lpt_schedule(&sub, sub.n_kv),
        ScheduleKind::Shift => shift(&sub)
            .with_context(|| format!("shift on a {tiles}-tile full segment"))?,
        ScheduleKind::Tuned => {
            let sim = SimConfig::ideal(sub.n_kv);
            tune(&sub, &TuneOptions { budget: 24, seed: 7, sim, batch: 1, threads: 1 })
                .context("tuning a trace segment")?
                .schedule
        }
    })
}

/// Compose the step schedule: per-slice singleton schedules translated by
/// each slice's start tile and concatenated in slice order. Chains keep
/// their singleton visit and reduction orders (offset, never reordered),
/// pins are dropped (the composed schedule is work-queue scheduled), and
/// the result is checked by [`validate()`](crate::schedule::validate())
/// before it is returned.
pub fn compose_step_schedule(step: &ServingStep, kind: ScheduleKind) -> Result<Schedule> {
    let n_heads = step.spec.n_heads;
    let total = step.spec.n_kv;
    let mut chains: Vec<Chain> = Vec::new();
    // Non-deterministic (atomic) singletons carry no reduction order; the
    // composition preserves that — orders stay empty for them.
    let mut reduction_order: Vec<Vec<usize>> = vec![Vec::new(); n_heads * total];
    let mut any_order = false;
    for slice in &step.slices {
        let sub = singleton_schedule(slice.tiles, n_heads, kind)?;
        let off = slice.start_tile;
        for ch in &sub.chains {
            chains.push(Chain {
                head: ch.head,
                kv: ch.kv + off,
                q_order: ch.q_order.iter().map(|&q| q + off).collect(),
                compute_scale: ch.compute_scale,
                reduce_scale: ch.reduce_scale,
                ordered: ch.ordered,
            });
        }
        if !sub.reduction_order.is_empty() {
            any_order = true;
            for head in 0..n_heads {
                for q in 0..slice.tiles {
                    reduction_order[head * total + off + q] = sub.reduction_order
                        [head * slice.tiles + q]
                        .iter()
                        .map(|&kv| kv + off)
                        .collect();
                }
            }
        }
    }
    let composed = Schedule {
        spec: step.spec.clone(),
        kind,
        pinned: vec![None; chains.len()],
        wave_width: 1,
        reduction_order: if any_order { reduction_order } else { Vec::new() },
        chains,
        cluster: None,
    };
    validate(&composed).map_err(|e| anyhow::anyhow!("composed step schedule invalid: {e:?}"))?;
    Ok(composed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traceload::gen::generate;
    use crate::traceload::spec::TraceSpec;

    fn smoke_trace() -> Trace {
        generate(&TraceSpec::smoke(42)).unwrap()
    }

    /// A request's segment script: the (segment, tiles, phase) sequence.
    fn script(steps: &[ServingStep], request: usize) -> Vec<(usize, usize, Phase)> {
        let mut out: Vec<_> = steps
            .iter()
            .flat_map(|st| st.slices.iter())
            .filter(|s| s.request == request)
            .map(|s| (s.segment, s.tiles, s.phase))
            .collect();
        out.sort_unstable_by_key(|&(seg, _, _)| seg);
        out
    }

    #[test]
    fn steps_tile_the_sequence_axis_exactly() {
        let trace = smoke_trace();
        let steps = compile(&trace, &BatchConfig::new(3, 2)).unwrap();
        assert!(!steps.is_empty());
        let mut seen_tiles = 0;
        for (i, st) in steps.iter().enumerate() {
            assert_eq!(st.index, i);
            assert!(st.slices.len() <= 3);
            let mut cursor = 0;
            for s in &st.slices {
                assert_eq!(s.start_tile, cursor, "slices must be contiguous");
                assert!(s.tiles >= 1);
                cursor += s.tiles;
            }
            assert_eq!(st.total_tiles(), cursor);
            assert_eq!(
                st.spec.mask.document_segments(cursor).unwrap().len(),
                st.slices.len(),
                "one document per slice"
            );
            seen_tiles += cursor;
        }
        assert_eq!(seen_tiles, trace.total_tiles(), "every tile served exactly once");
    }

    #[test]
    fn segment_scripts_are_batch_and_admission_invariant() {
        let trace = smoke_trace();
        let fifo1 = compile(&trace, &BatchConfig::new(1, 2)).unwrap();
        let fifo4 = compile(&trace, &BatchConfig::new(4, 2)).unwrap();
        let shuffled = compile(
            &trace,
            &BatchConfig { admission: 99, ..BatchConfig::new(4, 2) },
        )
        .unwrap();
        for r in &trace.requests {
            let s = script(&fifo1, r.id);
            assert_eq!(script(&fifo4, r.id), s, "request {} script changed with batch", r.id);
            assert_eq!(script(&shuffled, r.id), s, "request {} script changed with order", r.id);
            assert_eq!(s.len(), 1 + r.decode_tiles, "unchunked: one prefill + decodes");
            assert_eq!(s[0], (0, r.prompt_tiles, Phase::Prefill));
        }
    }

    #[test]
    fn chunked_prefill_splits_prompts_deterministically() {
        let trace = smoke_trace();
        let cfg = BatchConfig { chunk_tiles: 2, ..BatchConfig::new(2, 2) };
        let steps = compile(&trace, &cfg).unwrap();
        for r in &trace.requests {
            let s = script(&steps, r.id);
            let chunks = r.prompt_tiles.div_ceil(2);
            assert_eq!(s.len(), chunks + r.decode_tiles);
            let prompt_tiles: usize =
                s.iter().filter(|&&(_, _, p)| p != Phase::Decode).map(|&(_, t, _)| t).sum();
            assert_eq!(prompt_tiles, r.prompt_tiles);
            if chunks > 1 {
                assert!(s[..chunks].iter().all(|&(_, _, p)| p == Phase::ChunkedPrefill));
            }
            assert!(s[chunks..].iter().all(|&(_, t, p)| p == Phase::Decode && t == 1));
        }
    }

    #[test]
    fn compilation_is_deterministic() {
        let trace = smoke_trace();
        let cfg = BatchConfig { admission: 7, chunk_tiles: 2, ..BatchConfig::new(3, 2) };
        assert_eq!(compile(&trace, &cfg).unwrap(), compile(&trace, &cfg).unwrap());
    }

    #[test]
    fn bad_batch_configs_are_rejected() {
        let trace = smoke_trace();
        assert!(compile(&trace, &BatchConfig::new(0, 2)).is_err());
        assert!(compile(&trace, &BatchConfig::new(2, 0)).is_err());
    }

    #[test]
    fn composed_schedules_validate_for_every_kind() {
        let trace = smoke_trace();
        let steps = compile(&trace, &BatchConfig::new(3, 2)).unwrap();
        let step = steps.iter().max_by_key(|s| s.slices.len()).unwrap();
        for kind in [
            ScheduleKind::Fa3,
            ScheduleKind::Fa3Atomic,
            ScheduleKind::Descending,
            ScheduleKind::SymmetricShift,
            ScheduleKind::TwoPass,
            ScheduleKind::Lpt,
            ScheduleKind::Shift,
            ScheduleKind::Tuned,
        ] {
            let s = compose_step_schedule(step, kind).unwrap();
            assert_eq!(s.kind, kind);
            assert_eq!(s.spec, step.spec);
            // Every chain annotates back to the request whose slice it
            // computes.
            for i in 0..s.chains.len() {
                let doc = s.chain_request(i).expect("document mask annotates");
                assert!(doc < step.slices.len());
            }
        }
    }

    #[test]
    fn doc_seeds_follow_request_and_segment() {
        let trace = smoke_trace();
        let a = compile(&trace, &BatchConfig::new(1, 2)).unwrap();
        let b = compile(&trace, &BatchConfig::new(4, 2)).unwrap();
        // Collect seed per (request, segment) from both compilations: the
        // same segment must carry the same seed in either layout.
        let collect = |steps: &[ServingStep]| {
            let mut m: Vec<((usize, usize), u64)> = steps
                .iter()
                .flat_map(|st| st.slices.iter())
                .map(|s| ((s.request, s.segment), s.doc_seed()))
                .collect();
            m.sort_unstable();
            m
        };
        assert_eq!(collect(&a), collect(&b));
    }
}
