//! Serving-scenario layer: deterministic request traces and the batch
//! compiler that folds them onto the schedule stack.
//!
//! The pipeline has three stages, each replayable from a single seed:
//!
//! 1. **Spec** ([`TraceSpec`]) — the workload description: how many
//!    requests, their prompt/decode length distributions (Zipf,
//!    log-normal, fixed) and the arrival process (Poisson or bursty).
//!    Serializes to strict JSON; malformed specs are typed errors, never
//!    panics.
//! 2. **Trace** ([`generate`] → [`Trace`]) — the concrete request list:
//!    every request gets an id, an arrival step, a prompt length and a
//!    decode length, all drawn from one [`crate::util::DetRng`] stream so
//!    the whole trace is a pure function of the spec.
//! 3. **Serving steps** ([`compile`] → [`ServingStep`]) — continuous
//!    batching: at each engine step the compiler admits arrived requests
//!    up to the batch cap, gives every active request one segment
//!    (a prefill chunk or a one-tile decode), and emits the step as an
//!    ordinary [`crate::schedule::ProblemSpec`] with a
//!    [`crate::mask::MaskSpec::Document`] mask whose boundaries are the
//!    request segment edges. From there the seven generators, the
//!    simulator, the autotuner, and the exec oracle all apply unchanged.
//!
//! The batch-invariance claim (one gradient hash per request across batch
//! sizes and admission orders) is enforced by
//! [`crate::exec::verify_batch_invariance`]; the construction that makes
//! it true — per-request schedule composition and request-seeded operands
//! — lives in [`compose_step_schedule`] and
//! [`crate::exec::execute_backward_docs`].

pub mod compile;
pub mod gen;
pub mod spec;

pub use compile::{compile, compose_step_schedule, BatchConfig, Phase, ServingStep, StepSlice};
pub use gen::{generate, Request, Trace};
pub use spec::{ArrivalModel, LengthModel, TraceSpec};
