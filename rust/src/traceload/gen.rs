//! Trace generation: expand a [`TraceSpec`] into the concrete request
//! list. One [`DetRng`] stream drives every draw (arrival counts, prompt
//! lengths, decode lengths, in that fixed interleaving), so the trace is
//! a pure function of the spec — byte-identical across runs, machines,
//! and thread counts.

use super::spec::TraceSpec;
use crate::util::{fnv1a_words, DetRng};
use anyhow::{bail, Result};

/// One generated inference request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    /// Stable request id: generation order, and the identity under which
    /// batch invariance is proved (operand content is seeded by this id,
    /// never by batch position).
    pub id: usize,
    /// Engine step at which the request arrives.
    pub arrival_step: usize,
    /// Prompt length in tiles (>= 1).
    pub prompt_tiles: usize,
    /// Decode length in tiles (>= 1).
    pub decode_tiles: usize,
}

impl Request {
    /// Content seed for decode segment `segment` of this request (segment
    /// 0 is the prompt). Identical (request, segment) pairs get identical
    /// operand content no matter where the batch compiler places them —
    /// the data half of the batch-invariance construction.
    pub fn segment_seed(&self, segment: usize) -> u64 {
        fnv1a_words([self.id as u64, segment as u64])
    }
}

/// A generated trace: the spec it came from plus the request list.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// The generating spec (kept so exports are self-describing).
    pub spec: TraceSpec,
    /// Requests in arrival order (ties broken by id).
    pub requests: Vec<Request>,
}

impl Trace {
    /// Total prompt + decode tiles across all requests.
    pub fn total_tiles(&self) -> usize {
        self.requests.iter().map(|r| r.prompt_tiles + r.decode_tiles).sum()
    }

    /// Last arrival step in the trace.
    pub fn horizon(&self) -> usize {
        self.requests.iter().map(|r| r.arrival_step).max().unwrap_or(0)
    }
}

/// Safety valve: a valid arrival model produces a request every few steps;
/// this bound is astronomically beyond any plausible gap.
const MAX_EMPTY_STEPS: usize = 1_000_000;

/// Generate the trace for `spec`. Deterministic: same spec (including
/// seed) → bitwise-identical trace. Errors only on an invalid spec or an
/// arrival process that stalls past the safety bound.
pub fn generate(spec: &TraceSpec) -> Result<Trace> {
    spec.validate()?;
    let mut rng = DetRng::new(spec.seed);
    let mut requests = Vec::with_capacity(spec.requests);
    let mut step = 0usize;
    let mut empty = 0usize;
    while requests.len() < spec.requests {
        let arrivals = spec.arrival.sample(step, &mut rng);
        if arrivals == 0 {
            empty += 1;
            if empty > MAX_EMPTY_STEPS {
                bail!(
                    "trace '{}': arrival process produced no request in {MAX_EMPTY_STEPS} steps",
                    spec.name
                );
            }
        } else {
            empty = 0;
        }
        for _ in 0..arrivals {
            if requests.len() == spec.requests {
                break; // truncate the final burst at the request budget
            }
            let id = requests.len();
            let prompt_tiles = spec.prompt.sample(&mut rng);
            let decode_tiles = spec.decode.sample(&mut rng);
            requests.push(Request { id, arrival_step: step, prompt_tiles, decode_tiles });
        }
        step += 1;
    }
    Ok(Trace { spec: spec.clone(), requests })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traceload::spec::{ArrivalModel, LengthModel};

    #[test]
    fn generation_is_bitwise_deterministic_and_seed_sensitive() {
        let spec = TraceSpec::smoke(42);
        let a = generate(&spec).unwrap();
        let b = generate(&spec).unwrap();
        assert_eq!(a, b, "same spec must replay identically");
        let c = generate(&TraceSpec { seed: 43, ..spec }).unwrap();
        assert_ne!(a.requests, c.requests, "adjacent seeds must diverge");
    }

    #[test]
    fn requests_are_well_formed() {
        let t = generate(&TraceSpec::smoke(7)).unwrap();
        assert_eq!(t.requests.len(), 8);
        let mut last_arrival = 0;
        for (i, r) in t.requests.iter().enumerate() {
            assert_eq!(r.id, i, "ids are generation order");
            assert!(r.prompt_tiles >= 1 && r.prompt_tiles <= t.spec.prompt.max());
            assert!(r.decode_tiles >= 1 && r.decode_tiles <= t.spec.decode.max());
            assert!(r.arrival_step >= last_arrival, "arrivals are monotone");
            last_arrival = r.arrival_step;
        }
        assert!(t.total_tiles() >= 16, "every request has >= 2 tiles");
        assert_eq!(t.horizon(), last_arrival);
    }

    #[test]
    fn bursty_traces_clump_arrivals() {
        let spec = TraceSpec {
            arrival: ArrivalModel::Bursty { rate: 2.0, period: 5 },
            requests: 12,
            ..TraceSpec::smoke(3)
        };
        let t = generate(&spec).unwrap();
        assert!(t.requests.iter().all(|r| r.arrival_step % 5 == 0));
    }

    #[test]
    fn segment_seeds_depend_on_request_and_segment_only() {
        let r0 = Request { id: 0, arrival_step: 0, prompt_tiles: 2, decode_tiles: 1 };
        let moved = Request { id: 0, arrival_step: 9, prompt_tiles: 2, decode_tiles: 1 };
        assert_eq!(r0.segment_seed(1), moved.segment_seed(1), "placement-invariant");
        assert_ne!(r0.segment_seed(0), r0.segment_seed(1));
        let r1 = Request { id: 1, ..r0 };
        assert_ne!(r0.segment_seed(0), r1.segment_seed(0));
    }

    #[test]
    fn invalid_spec_is_rejected_before_sampling() {
        let spec = TraceSpec { prompt: LengthModel::Fixed { tiles: 0 }, ..TraceSpec::smoke(1) };
        assert!(generate(&spec).is_err());
    }
}
