//! Structured schedule observability: typed event traces of simulated and
//! executed schedules, content-hashed for determinism checking.
//!
//! The simulator ([`crate::sim`]) and the numeric executor ([`crate::exec`])
//! both produce timelines, but until this layer they spoke different
//! dialects — `TaskSpan`s for Gantt charts on one side, an opaque
//! completion order on the other. [`SimTrace`] is the common currency:
//! every tile compute, reduction fold, stall interval and L2 wait becomes
//! a typed [`TraceEvent`] on an SM lane, and the whole trace is
//! content-hashed ([`SimTrace::content_hash`]) so two runs can be compared
//! bit-for-bit and the hash attested in a
//! [`crate::coordinator::ReproManifest`].
//!
//! Three consumers sit on top:
//!
//! * [`timeline`] — a self-contained interactive HTML timeline (per-SM
//!   lanes, hover detail, schedule diff) behind `dash timeline`;
//! * [`flamegraph`] — per-chain makespan attribution (compute / reduce /
//!   stall / L2 / pipeline wait) behind `dash flamegraph`;
//! * [`baseline`] — named `BENCH_<name>.json` performance snapshots with
//!   a regression gate behind `dash baseline save/list/check`.
//!
//! Invariants the trace layer guarantees (and `rust/tests/trace_invariants.rs`
//! enforces): events are sorted by `(sm, t_start)` and never overlap within
//! a lane; on the paper's synchronous abstract machine every lane tiles
//! gaplessly, so per-lane `compute + reduce + stall == lane makespan`; and
//! the hash of a deterministic generator's trace is bitwise-stable across
//! repeated runs.

pub mod baseline;
pub mod flamegraph;
pub mod timeline;

use crate::exec::{chain_completion_spans, ExecConfig};
use crate::schedule::Schedule;
use crate::sim::{simulate, SimConfig, SimError, SimResult, TaskSpan};
use crate::util::fnv1a_words;

/// What an interval of SM time was spent on.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceKind {
    /// Tile compute (the S/P recompute and dK/dV/dQ GEMMs).
    Compute,
    /// Pipeline wait: compute finished but the SM's dQ-writer warp was
    /// still draining an earlier tile (`writer_depth` back-pressure).
    Wait,
    /// Token stall: the fold sat blocked on the serialized per-(head, q)
    /// accumulation order — the determinism cost the paper measures.
    Stall,
    /// The tail of a token stall spent on L2 signal propagation from the
    /// previous contributor's SM segment.
    L2,
    /// The dQ reduction fold itself.
    Reduce,
    /// A cross-device transfer on an interconnect link lane (cluster
    /// schedules only): one hop of the fixed-order ring reduce-scatter.
    Transfer,
}

impl TraceKind {
    /// Stable lowercase name (used in folded stacks, CSV and HTML).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Compute => "compute",
            TraceKind::Wait => "wait",
            TraceKind::Stall => "stall",
            TraceKind::L2 => "l2",
            TraceKind::Reduce => "reduce",
            TraceKind::Transfer => "transfer",
        }
    }

    /// Stable numeric code folded into [`SimTrace::content_hash`].
    pub fn code(self) -> u64 {
        match self {
            TraceKind::Compute => 0,
            TraceKind::Wait => 1,
            TraceKind::Stall => 2,
            TraceKind::L2 => 3,
            TraceKind::Reduce => 4,
            TraceKind::Transfer => 5,
        }
    }
}

/// The (head, kv, q) tile coordinates an event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct TaskId {
    /// Head instance (virtual pass-2 heads keep their `n_heads..2*n_heads`
    /// index so the two passes stay distinguishable).
    pub head: usize,
    /// KV tile — for a [`TraceKind::Reduce`] event, the tile whose dQ
    /// partial is being folded.
    pub kv: usize,
    /// Q tile.
    pub q: usize,
}

/// One typed interval of SM time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    /// Interval start (cycles in sim traces, model units in exec traces).
    pub t_start: f64,
    /// Interval end (`> t_start`; zero-length intervals are not emitted).
    pub t_end: f64,
    /// SM execution slot the interval occupied.
    pub sm: usize,
    /// Chain index in the schedule.
    pub chain: usize,
    /// What the time was spent on.
    pub kind: TraceKind,
    /// Tile coordinates.
    pub task: TaskId,
}

impl TraceEvent {
    /// Interval duration.
    pub fn dur(&self) -> f64 {
        self.t_end - self.t_start
    }
}

/// Which engine produced a trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceSource {
    /// The discrete-event simulator ([`crate::sim::simulate`]).
    Sim,
    /// The numeric executor's machine model
    /// ([`crate::exec::chain_completion_spans`] plus its global dQ fold).
    Exec,
}

impl TraceSource {
    /// Stable lowercase name.
    pub fn name(self) -> &'static str {
        match self {
            TraceSource::Sim => "sim",
            TraceSource::Exec => "exec",
        }
    }

    /// Stable numeric code folded into [`SimTrace::content_hash`].
    pub fn code(self) -> u64 {
        match self {
            TraceSource::Sim => 0,
            TraceSource::Exec => 1,
        }
    }
}

/// Per-kind time totals over a trace (see [`SimTrace::totals`]).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TraceTotals {
    /// Total [`TraceKind::Compute`] time.
    pub compute: f64,
    /// Total [`TraceKind::Wait`] time.
    pub wait: f64,
    /// Total [`TraceKind::Stall`] time (excluding the L2 tail).
    pub stall: f64,
    /// Total [`TraceKind::L2`] time.
    pub l2: f64,
    /// Total [`TraceKind::Reduce`] time.
    pub reduce: f64,
    /// Total [`TraceKind::Transfer`] time (zero for single-device traces).
    pub transfer: f64,
}

impl TraceTotals {
    /// Sum of all six buckets.
    pub fn total(&self) -> f64 {
        self.compute + self.wait + self.stall + self.l2 + self.reduce + self.transfer
    }
}

/// A complete typed timeline of one schedule on one machine.
#[derive(Debug, Clone, PartialEq)]
pub struct SimTrace {
    /// Generator name ([`crate::schedule::ScheduleKind::name`]).
    pub schedule: String,
    /// Mask name ([`crate::mask::MaskSpec::name`]).
    pub mask: String,
    /// KV tiles per head.
    pub n_kv: usize,
    /// Q tiles per head.
    pub n_q: usize,
    /// Head instances.
    pub n_heads: usize,
    /// Which engine produced the trace.
    pub source: TraceSource,
    /// Machine width in execution slots (`n_sm * occupancy` for sim
    /// traces, `n_sm` for exec traces).
    pub n_lanes: usize,
    /// Timeline end: the engine's makespan.
    pub makespan: f64,
    /// Events sorted by `(sm, t_start)`.
    pub events: Vec<TraceEvent>,
    /// Lane display labels (`dev<d>/sm<s>` + `link<i>`) for multi-device
    /// traces; empty for single-device traces, whose lanes keep the
    /// implicit `SM<i>` naming. Presentation only — deliberately excluded
    /// from [`SimTrace::content_hash`] so the hash of a single-device
    /// trace is unchanged by the device axis.
    pub lane_labels: Vec<String>,
}

impl SimTrace {
    /// Content hash of the trace: workload identity, machine width and the
    /// exact bit pattern of every event interval, FNV-1a-folded. Two
    /// traces hash equal iff the timelines are bitwise identical — this is
    /// the value `dash verify` records in the
    /// [`crate::coordinator::ReproManifest`].
    pub fn content_hash(&self) -> u64 {
        let mut words: Vec<u64> = Vec::with_capacity(16 + self.events.len() * 8);
        words.push(self.source.code());
        words.push(self.n_kv as u64);
        words.push(self.n_q as u64);
        words.push(self.n_heads as u64);
        words.push(self.n_lanes as u64);
        words.push(self.makespan.to_bits());
        words.push(self.schedule.len() as u64);
        words.extend(self.schedule.bytes().map(u64::from));
        words.push(self.mask.len() as u64);
        words.extend(self.mask.bytes().map(u64::from));
        for e in &self.events {
            words.push(e.sm as u64);
            words.push(e.chain as u64);
            words.push(e.kind.code());
            words.push(e.task.head as u64);
            words.push(e.task.kv as u64);
            words.push(e.task.q as u64);
            words.push(e.t_start.to_bits());
            words.push(e.t_end.to_bits());
        }
        fnv1a_words(words)
    }

    /// Per-kind time totals across all lanes.
    pub fn totals(&self) -> TraceTotals {
        let mut t = TraceTotals::default();
        for e in &self.events {
            let d = e.dur();
            match e.kind {
                TraceKind::Compute => t.compute += d,
                TraceKind::Wait => t.wait += d,
                TraceKind::Stall => t.stall += d,
                TraceKind::L2 => t.l2 += d,
                TraceKind::Reduce => t.reduce += d,
                TraceKind::Transfer => t.transfer += d,
            }
        }
        t
    }

    /// Number of lanes that carry at least one event.
    pub fn lanes_used(&self) -> usize {
        let mut seen = vec![false; self.n_lanes];
        for e in &self.events {
            if e.sm < self.n_lanes {
                seen[e.sm] = true;
            }
        }
        seen.iter().filter(|&&s| s).count()
    }

    /// Latest `t_end` on lane `sm` (0.0 if the lane is empty).
    pub fn lane_end(&self, sm: usize) -> f64 {
        self.events.iter().filter(|e| e.sm == sm).map(|e| e.t_end).fold(0.0f64, f64::max)
    }
}

/// Push `[a, b]` as a `kind` event if it has strictly positive length.
fn push_event(
    events: &mut Vec<TraceEvent>,
    a: f64,
    b: f64,
    sm: usize,
    chain: usize,
    kind: TraceKind,
    task: TaskId,
) {
    if b > a {
        events.push(TraceEvent { t_start: a, t_end: b, sm, chain, kind, task });
    }
}

fn sort_events(events: &mut [TraceEvent]) {
    events.sort_by(|a, b| {
        a.sm.cmp(&b.sm).then(a.t_start.partial_cmp(&b.t_start).expect("finite event times"))
    });
}

/// Convert recorded simulator spans into a typed trace. Exposed so callers
/// that already hold a [`SimResult`] (with `record_spans` on) can avoid a
/// second simulation; most callers want [`trace_simulation`].
///
/// Multi-device (cluster) results gain one extra lane per interconnect
/// link, carrying the ring reduce-scatter hops as [`TraceKind::Transfer`]
/// events (`task.head` = source device, `task.kv` = destination device,
/// `task.q` = pipeline step), and namespaced [`SimTrace::lane_labels`].
/// Single-device results produce byte-identical traces to before the
/// device axis existed.
pub fn trace_from_sim(s: &Schedule, config: &SimConfig, result: &SimResult) -> SimTrace {
    let mut events = Vec::with_capacity(result.spans.len() * 3);
    for sp in &result.spans {
        let task = TaskId { head: sp.head, kv: sp.kv, q: sp.q };
        let l2_start = sp.reduce_start - sp.l2_wait;
        push_event(&mut events, sp.compute_start, sp.compute_end, sp.sm, sp.chain, TraceKind::Compute, task);
        push_event(&mut events, sp.compute_end, sp.ready, sp.sm, sp.chain, TraceKind::Wait, task);
        push_event(&mut events, sp.ready, l2_start, sp.sm, sp.chain, TraceKind::Stall, task);
        push_event(&mut events, l2_start, sp.reduce_start, sp.sm, sp.chain, TraceKind::L2, task);
        push_event(&mut events, sp.reduce_start, sp.reduce_end, sp.sm, sp.chain, TraceKind::Reduce, task);
    }
    let lanes_per_dev = config.n_sm.max(1) * config.occupancy.max(1);
    let (n_lanes, lane_labels) = match s.cluster.as_ref().filter(|c| c.n_devices > 1) {
        Some(c) => {
            let d = c.n_devices;
            for l in &result.links {
                let task = TaskId { head: l.src, kv: l.dst, q: l.step };
                push_event(
                    &mut events,
                    l.t_start,
                    l.t_end,
                    d * lanes_per_dev + l.link,
                    s.chains.len() + l.link,
                    TraceKind::Transfer,
                    task,
                );
            }
            (d * lanes_per_dev + d, crate::sim::cluster_lane_labels(d, lanes_per_dev, d))
        }
        None => (lanes_per_dev, Vec::new()),
    };
    sort_events(&mut events);
    SimTrace {
        schedule: s.display_name(),
        mask: s.spec.mask.name(),
        n_kv: s.spec.n_kv,
        n_q: s.spec.n_q,
        n_heads: s.spec.n_heads,
        source: TraceSource::Sim,
        n_lanes,
        makespan: result.makespan,
        events,
        lane_labels,
    }
}

/// Simulate `s` under `config` (span recording forced on) and return the
/// typed trace of the run.
pub fn trace_simulation(s: &Schedule, config: &SimConfig) -> Result<SimTrace, SimError> {
    let mut cfg = *config;
    cfg.record_spans = true;
    let result = simulate(s, &cfg)?;
    Ok(trace_from_sim(s, &cfg, &result))
}

/// Trace the numeric executor's machine model for `s` under `cfg`:
/// per-chain compute intervals from [`chain_completion_spans`] (subdivided
/// evenly over the chain's tile visits), followed by the global dQ fold
/// replayed as a serial sequence of unit-time [`TraceKind::Reduce`] events
/// in exactly the order [`crate::exec::execute_backward`] folds partials —
/// so a sim trace and an exec trace of the same schedule can be checked
/// for task-order agreement even though their clocks differ.
pub fn trace_execution(s: &Schedule, cfg: &ExecConfig) -> SimTrace {
    let spans = chain_completion_spans(s, cfg.n_sm, cfg.perturb);
    let n_heads = s.spec.n_heads;
    let mut chain_sm = vec![0usize; s.chains.len()];
    let mut events = Vec::new();

    // Compute intervals: each chain's span split evenly across its visits
    // (the last tile pinned to the span end so rounding never leaks past
    // the chain boundary).
    let mut makespan = 0.0f64;
    for cs in &spans {
        chain_sm[cs.chain] = cs.sm;
        makespan = makespan.max(cs.end);
        let c = &s.chains[cs.chain];
        let n = c.q_order.len();
        if n == 0 {
            continue;
        }
        let pass2 = c.head >= n_heads;
        let step = (cs.end - cs.start) / n as f64;
        for (i, &t) in c.q_order.iter().enumerate() {
            let a = cs.start + step * i as f64;
            let b = if i + 1 == n { cs.end } else { cs.start + step * (i + 1) as f64 };
            // Pass-2 chains own a Q tile and walk KV tiles.
            let task = if pass2 {
                TaskId { head: c.head, kv: t, q: c.kv }
            } else {
                TaskId { head: c.head, kv: c.kv, q: t }
            };
            push_event(&mut events, a, b, cs.sm, cs.chain, TraceKind::Compute, task);
        }
    }

    // The global dQ fold, replayed on a logical clock after all compute:
    // one unit-time Reduce event per folded partial, serial, in the exact
    // order `execute_backward` visits them. Each event sits on the lane of
    // the chain that produced the partial.
    let use_order = !cfg.inject_atomic && !s.reduction_order.is_empty();
    let mut t = makespan;
    for head in 0..n_heads {
        for qt in 0..s.spec.n_q {
            // Arrival order of (chain, kv, ordered) partials for this
            // (head, q): fused chains of this head that visit qt and emit
            // dQ, in completion order.
            let parts: Vec<(usize, usize, bool)> = spans
                .iter()
                .filter_map(|cs| {
                    let c = &s.chains[cs.chain];
                    let fused = c.head < n_heads && c.head == head;
                    if fused && c.reduce_scale > 0.0 && c.q_order.contains(&qt) {
                        Some((cs.chain, c.kv, c.ordered))
                    } else {
                        None
                    }
                })
                .collect();
            if parts.is_empty() {
                continue;
            }
            let order: Vec<usize> = if use_order {
                let mut ord = Vec::with_capacity(parts.len());
                for &kv in s.reduction_order_of(head, qt) {
                    if let Some(pos) = parts.iter().position(|p| p.2 && p.1 == kv) {
                        ord.push(pos);
                    }
                }
                ord.extend(parts.iter().enumerate().filter(|(_, p)| !p.2).map(|(i, _)| i));
                ord
            } else {
                (0..parts.len()).collect()
            };
            for pos in order {
                let (chain, kv, _) = parts[pos];
                let task = TaskId { head, kv, q: qt };
                push_event(&mut events, t, t + 1.0, chain_sm[chain], chain, TraceKind::Reduce, task);
                t += 1.0;
            }
        }
    }

    sort_events(&mut events);
    SimTrace {
        schedule: s.display_name(),
        mask: s.spec.mask.name(),
        n_kv: s.spec.n_kv,
        n_q: s.spec.n_q,
        n_heads,
        source: TraceSource::Exec,
        // Cluster schedules namespace executor lanes per device
        // (`device * n_sm + local`, see
        // [`crate::exec::chain_completion_spans`]); single-device traces
        // keep the plain `n_sm` width and implicit `SM<i>` labels.
        n_lanes: cfg.n_sm.max(1) * s.n_devices(),
        makespan: t.max(makespan),
        events,
        lane_labels: if s.n_devices() > 1 {
            crate::sim::cluster_lane_labels(s.n_devices(), cfg.n_sm.max(1), 0)
        } else {
            Vec::new()
        },
    }
}

/// The per-(head, q) KV fold sequence a trace implies: for every
/// `(head, q)` with at least one [`TraceKind::Reduce`] event, the KV tiles
/// in fold-time order. This is the task-ordering view that must agree
/// between a sim trace and an exec trace of the same schedule.
pub fn reduce_order_by_task(trace: &SimTrace) -> Vec<((usize, usize), Vec<usize>)> {
    let mut folds: Vec<(&TraceEvent, usize)> = trace
        .events
        .iter()
        .filter(|e| e.kind == TraceKind::Reduce)
        .map(|e| (e, e.task.kv))
        .collect();
    folds.sort_by(|a, b| {
        (a.0.task.head, a.0.task.q)
            .cmp(&(b.0.task.head, b.0.task.q))
            .then(a.0.t_start.partial_cmp(&b.0.t_start).expect("finite event times"))
    });
    let mut out: Vec<((usize, usize), Vec<usize>)> = Vec::new();
    for (e, kv) in folds {
        let key = (e.task.head, e.task.q);
        match out.last_mut() {
            Some((k, seq)) if *k == key => seq.push(kv),
            _ => out.push((key, vec![kv])),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, shift, MaskSpec, ProblemSpec};

    fn spec() -> ProblemSpec {
        ProblemSpec::square(4, 2, MaskSpec::full())
    }

    #[test]
    fn sim_trace_covers_every_task_and_hash_is_stable() {
        let s = shift(&spec()).expect("shift exists for full mask");
        let cfg = SimConfig::ideal(4);
        let a = trace_simulation(&s, &cfg).unwrap();
        let b = trace_simulation(&s, &cfg).unwrap();
        assert_eq!(a.content_hash(), b.content_hash());
        let n_compute = a.events.iter().filter(|e| e.kind == TraceKind::Compute).count();
        assert_eq!(n_compute, s.total_tasks());
        // Ideal shift schedule: zero stall, zero wait.
        let t = a.totals();
        assert!(t.stall.abs() < 1e-9 && t.wait.abs() < 1e-9 && t.l2.abs() < 1e-9);
        assert!(t.compute > 0.0 && t.reduce > 0.0);
    }

    #[test]
    fn content_hash_distinguishes_schedules_and_sources() {
        let cfg = SimConfig::ideal(4);
        let a = trace_simulation(&shift(&spec()).unwrap(), &cfg).unwrap();
        let b = trace_simulation(&fa3(&spec(), true), &cfg).unwrap();
        assert_ne!(a.content_hash(), b.content_hash());
        let e = trace_execution(&shift(&spec()).unwrap(), &ExecConfig::new(1));
        assert_ne!(a.content_hash(), e.content_hash());
    }

    #[test]
    fn exec_trace_fold_order_matches_the_schedule() {
        let s = shift(&spec()).unwrap();
        let tr = trace_execution(&s, &ExecConfig::new(1));
        for ((head, q), kvs) in reduce_order_by_task(&tr) {
            assert_eq!(kvs.as_slice(), s.reduction_order_of(head, q), "fold order for ({head},{q})");
        }
    }

    #[test]
    fn cluster_traces_carry_link_lanes_and_transfer_events() {
        use crate::schedule::{ring, ScheduleKind};
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 2).unwrap();
        let cfg = SimConfig::ideal(8);
        let tr = trace_simulation(&s, &cfg).unwrap();
        assert_eq!(tr.schedule, "ring-shift");
        assert_eq!(tr.n_lanes, 2 * 8 + 2);
        assert_eq!(tr.lane_labels.len(), tr.n_lanes);
        assert_eq!(tr.lane_labels[0], "dev0/sm0");
        assert_eq!(tr.lane_labels[16], "link0");
        let transfers: Vec<_> =
            tr.events.iter().filter(|e| e.kind == TraceKind::Transfer).collect();
        assert_eq!(transfers.len(), 2);
        for e in &transfers {
            assert!(e.sm >= 16, "transfers live on link lanes");
            assert_eq!(e.task.kv, (e.task.head + 1) % 2, "dst = src + 1 on the ring");
        }
        assert!((tr.totals().transfer - 2.0).abs() < 1e-9);
        // The hash is sensitive to the link timeline: a different hop cost
        // must produce a different trace hash.
        let mut s2 = s.clone();
        s2.cluster.as_mut().unwrap().hop_cost = 2.0;
        assert_ne!(
            trace_simulation(&s2, &cfg).unwrap().content_hash(),
            tr.content_hash()
        );
    }

    #[test]
    fn lane_accounting_is_consistent() {
        let s = fa3(&spec(), true);
        let tr = trace_simulation(&s, &SimConfig::ideal(4)).unwrap();
        assert_eq!(tr.n_lanes, 4);
        assert_eq!(tr.lanes_used(), 4);
        for sm in 0..4 {
            assert!(tr.lane_end(sm) <= tr.makespan + 1e-9);
        }
    }
}
