//! Interactive HTML timelines of a [`SimTrace`] — the `dash timeline`
//! surface.
//!
//! The exported page is fully self-contained: styles and script are
//! inlined, event data is embedded as a literal array, and nothing
//! references the network (CI asserts the output never contains the
//! substring `"` + `http` + `"`). Each SM lane is a row; events are
//! colored by [`TraceKind`] with hover detail, and the diff page stacks
//! two traces of the same workload with divergent intervals outlined and
//! summarized.

use super::{SimTrace, TraceEvent, TraceKind};

/// One pair of events that exist in both traces but disagree in time or
/// placement (see [`diff_traces`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DivergedPair {
    /// The event in the first trace.
    pub a: TraceEvent,
    /// The matching event in the second trace.
    pub b: TraceEvent,
    /// `max(|Δt_start|, |Δt_end|)` between the two.
    pub shift: f64,
}

/// Alignment of two traces of the same workload (see [`diff_traces`]).
#[derive(Debug, Clone, Default)]
pub struct TraceDiff {
    /// Event pairs present in both traces and bitwise-agreeing (within
    /// the alignment epsilon, on the same lane).
    pub aligned: usize,
    /// Event pairs present in both traces but shifted in time or moved
    /// to a different lane.
    pub diverged: Vec<DivergedPair>,
    /// Events only the first trace has.
    pub only_a: Vec<TraceEvent>,
    /// Events only the second trace has.
    pub only_b: Vec<TraceEvent>,
    /// Largest time shift over all diverged pairs.
    pub max_shift: f64,
}

impl TraceDiff {
    /// True when the two traces describe the identical timeline.
    pub fn identical(&self) -> bool {
        self.diverged.is_empty() && self.only_a.is_empty() && self.only_b.is_empty()
    }
}

/// Identity used to align events across traces: what happened to which
/// tile, ignoring when and where.
fn align_key(e: &TraceEvent) -> (u64, usize, usize, usize) {
    (e.kind.code(), e.task.head, e.task.kv, e.task.q)
}

/// Align two traces of the same workload event-by-event. Events are keyed
/// by `(kind, head, kv, q)`; duplicate keys (e.g. a two-pass schedule
/// visiting a tile once per pass) are paired by occurrence index in time
/// order. A pair diverges when its interval shifts by more than `eps` or
/// it moved to a different lane.
pub fn diff_traces(a: &SimTrace, b: &SimTrace, eps: f64) -> TraceDiff {
    let in_time_order = |t: &SimTrace| -> Vec<TraceEvent> {
        let mut ev = t.events.clone();
        ev.sort_by(|x, y| {
            align_key(x)
                .cmp(&align_key(y))
                .then(x.t_start.partial_cmp(&y.t_start).expect("finite event times"))
        });
        ev
    };
    let (ea, eb) = (in_time_order(a), in_time_order(b));
    let mut diff = TraceDiff::default();
    let (mut i, mut j) = (0usize, 0usize);
    while i < ea.len() && j < eb.len() {
        let (ka, kb) = (align_key(&ea[i]), align_key(&eb[j]));
        if ka < kb {
            diff.only_a.push(ea[i]);
            i += 1;
        } else if kb < ka {
            diff.only_b.push(eb[j]);
            j += 1;
        } else {
            let (x, y) = (ea[i], eb[j]);
            let shift = (x.t_start - y.t_start).abs().max((x.t_end - y.t_end).abs());
            if shift > eps || x.sm != y.sm {
                diff.max_shift = diff.max_shift.max(shift);
                diff.diverged.push(DivergedPair { a: x, b: y, shift });
            } else {
                diff.aligned += 1;
            }
            i += 1;
            j += 1;
        }
    }
    diff.only_a.extend_from_slice(&ea[i..]);
    diff.only_b.extend_from_slice(&eb[j..]);
    diff
}

/// Human-readable diff summary (also embedded verbatim in the diff HTML).
pub fn diff_summary(a: &SimTrace, b: &SimTrace, diff: &TraceDiff) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "A: {}/{} {}x{}x{} [{}]  makespan {:.3}  hash {:016x}\n",
        a.schedule, a.mask, a.n_kv, a.n_q, a.n_heads, a.source.name(), a.makespan, a.content_hash()
    ));
    out.push_str(&format!(
        "B: {}/{} {}x{}x{} [{}]  makespan {:.3}  hash {:016x}\n",
        b.schedule, b.mask, b.n_kv, b.n_q, b.n_heads, b.source.name(), b.makespan, b.content_hash()
    ));
    if diff.identical() {
        out.push_str(&format!("identical timelines ({} events aligned)\n", diff.aligned));
    } else {
        out.push_str(&format!(
            "{} aligned, {} diverged (max shift {:.3}), {} only in A, {} only in B\n",
            diff.aligned,
            diff.diverged.len(),
            diff.max_shift,
            diff.only_a.len(),
            diff.only_b.len()
        ));
    }
    out
}

/// Render events as a JS array literal `[[sm,chain,kind,head,kv,q,t0,t1],...]`.
fn events_js(events: &[TraceEvent]) -> String {
    let mut out = String::from("[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "[{},{},{},{},{},{},{},{}]",
            e.sm,
            e.chain,
            e.kind.code(),
            e.task.head,
            e.task.kv,
            e.task.q,
            e.t_start,
            e.t_end
        ));
    }
    out.push(']');
    out
}

/// Render a JS array of 0/1 divergence flags parallel to `events`.
fn flags_js(events: &[TraceEvent], diverged: &[TraceEvent]) -> String {
    let mut out = String::from("[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push(if diverged.contains(e) { '1' } else { '0' });
    }
    out.push(']');
    out
}

const STYLE: &str = r##"
body { font: 13px/1.4 monospace; background: #16181d; color: #d8dce3; margin: 16px; }
h1 { font-size: 16px; }
.meta { color: #8a93a3; margin-bottom: 10px; }
.legend span { display: inline-block; margin-right: 14px; }
.swatch { display: inline-block; width: 10px; height: 10px; margin-right: 4px; border-radius: 2px; }
.chart { margin: 14px 0 24px 0; }
.lane { position: relative; height: 18px; margin: 2px 0; background: #1e2128; border-radius: 2px; }
.lanelabel { position: absolute; left: 4px; top: 1px; color: #717a8a; }
.ev { position: absolute; top: 2px; height: 14px; border-radius: 1px; opacity: 0.95; }
.ev.k0 { background: #4c9f70; }
.ev.k1 { background: #c2b280; }
.ev.k2 { background: #d9534f; }
.ev.k3 { background: #b06a3b; }
.ev.k4 { background: #5b7fbf; }
.ev.diff { outline: 2px solid #ff2e88; z-index: 2; }
#tip { position: fixed; display: none; background: #262b35; color: #e8ecf3;
       border: 1px solid #414a5c; padding: 4px 8px; pointer-events: none; z-index: 10; }
pre.summary { background: #1e2128; padding: 10px; border-radius: 4px; }
"##;

const SCRIPT: &str = r##"
var KINDS = ['compute', 'wait', 'stall', 'l2', 'reduce'];
var tip = document.getElementById('tip');
function showTip(ev, e) {
  tip.style.display = 'block';
  tip.style.left = (ev.clientX + 12) + 'px';
  tip.style.top = (ev.clientY + 12) + 'px';
  tip.textContent = KINDS[e[2]] + '  chain ' + e[1] + '  (h' + e[3] + ', kv' + e[4] +
    ', q' + e[5] + ')  t=[' + e[6].toFixed(3) + ', ' + e[7].toFixed(3) + ']  sm' + e[0];
}
function hideTip() { tip.style.display = 'none'; }
function paint(id, data, makespan, lanes, flags) {
  var host = document.getElementById(id);
  var width = Math.max(host.clientWidth, 400) - 70;
  var scale = width / (makespan > 0 ? makespan : 1);
  var rows = [];
  for (var i = 0; i < lanes; i++) {
    var row = document.createElement('div');
    row.className = 'lane';
    var label = document.createElement('span');
    label.className = 'lanelabel';
    label.textContent = 'SM' + i;
    row.appendChild(label);
    host.appendChild(row);
    rows.push(row);
  }
  data.forEach(function (e, i) {
    if (e[0] >= rows.length) { return; }
    var d = document.createElement('div');
    d.className = 'ev k' + e[2] + ((flags && flags[i]) ? ' diff' : '');
    d.style.left = (60 + e[6] * scale) + 'px';
    d.style.width = Math.max(1, (e[7] - e[6]) * scale - 0.5) + 'px';
    d.addEventListener('mousemove', function (ev) { showTip(ev, e); });
    d.addEventListener('mouseleave', hideTip);
    rows[e[0]].appendChild(d);
  });
}
"##;

const LEGEND: &str = r##"<div class="legend">
<span><span class="swatch" style="background:#4c9f70"></span>compute</span>
<span><span class="swatch" style="background:#c2b280"></span>wait</span>
<span><span class="swatch" style="background:#d9534f"></span>stall</span>
<span><span class="swatch" style="background:#b06a3b"></span>l2</span>
<span><span class="swatch" style="background:#5b7fbf"></span>reduce</span>
<span><span class="swatch" style="outline:2px solid #ff2e88"></span>diverged</span>
</div>
"##;

/// Style/script/legend variants for multi-device traces: a `transfer`
/// event class (k5), device-namespaced lane labels, and a legend entry.
/// Kept as separate constants so the single-device page stays
/// byte-identical to the pre-cluster output.
const STYLE_XDEV: &str = ".ev.k5 { background: #8e6bbf; }\n";

const SCRIPT_XDEV: &str = r##"
var KINDS = ['compute', 'wait', 'stall', 'l2', 'reduce', 'transfer'];
var tip = document.getElementById('tip');
function laneName(i) { return LABELS[i] || ('SM' + i); }
function showTip(ev, e) {
  tip.style.display = 'block';
  tip.style.left = (ev.clientX + 12) + 'px';
  tip.style.top = (ev.clientY + 12) + 'px';
  tip.textContent = KINDS[e[2]] + '  chain ' + e[1] + '  (h' + e[3] + ', kv' + e[4] +
    ', q' + e[5] + ')  t=[' + e[6].toFixed(3) + ', ' + e[7].toFixed(3) + ']  ' + laneName(e[0]);
}
function hideTip() { tip.style.display = 'none'; }
function paint(id, data, makespan, lanes, flags) {
  var host = document.getElementById(id);
  var width = Math.max(host.clientWidth, 400) - 70;
  var scale = width / (makespan > 0 ? makespan : 1);
  var rows = [];
  for (var i = 0; i < lanes; i++) {
    var row = document.createElement('div');
    row.className = 'lane';
    var label = document.createElement('span');
    label.className = 'lanelabel';
    label.textContent = laneName(i);
    row.appendChild(label);
    host.appendChild(row);
    rows.push(row);
  }
  data.forEach(function (e, i) {
    if (e[0] >= rows.length) { return; }
    var d = document.createElement('div');
    d.className = 'ev k' + e[2] + ((flags && flags[i]) ? ' diff' : '');
    d.style.left = (60 + e[6] * scale) + 'px';
    d.style.width = Math.max(1, (e[7] - e[6]) * scale - 0.5) + 'px';
    d.addEventListener('mousemove', function (ev) { showTip(ev, e); });
    d.addEventListener('mouseleave', hideTip);
    rows[e[0]].appendChild(d);
  });
}
"##;

const LEGEND_XDEV: &str = r##"<div class="legend">
<span><span class="swatch" style="background:#4c9f70"></span>compute</span>
<span><span class="swatch" style="background:#c2b280"></span>wait</span>
<span><span class="swatch" style="background:#d9534f"></span>stall</span>
<span><span class="swatch" style="background:#b06a3b"></span>l2</span>
<span><span class="swatch" style="background:#5b7fbf"></span>reduce</span>
<span><span class="swatch" style="background:#8e6bbf"></span>transfer</span>
<span><span class="swatch" style="outline:2px solid #ff2e88"></span>diverged</span>
</div>
"##;

/// Render lane labels as a JS string-array literal.
fn labels_js(labels: &[String]) -> String {
    let mut out = String::from("[");
    for (i, l) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\'');
        // Labels are generated (`dev<d>/sm<s>`, `link<i>`) but escape
        // defensively anyway.
        out.push_str(&l.replace('\\', "\\\\").replace('\'', "\\'"));
        out.push('\'');
    }
    out.push(']');
    out
}

fn page_open(title: &str) -> String {
    let mut out = String::from("<!DOCTYPE html>\n<html><head><meta charset=\"utf-8\">\n<title>");
    out.push_str(title);
    out.push_str("</title>\n<style>");
    out.push_str(STYLE);
    out.push_str("</style></head>\n<body>\n<div id=\"tip\"></div>\n");
    out
}

fn page_open_xdev(title: &str) -> String {
    let mut out = String::from("<!DOCTYPE html>\n<html><head><meta charset=\"utf-8\">\n<title>");
    out.push_str(title);
    out.push_str("</title>\n<style>");
    out.push_str(STYLE);
    out.push_str(STYLE_XDEV);
    out.push_str("</style></head>\n<body>\n<div id=\"tip\"></div>\n");
    out
}

fn meta_line(t: &SimTrace) -> String {
    format!(
        "<div class=\"meta\">{} on {} mask, {}x{}x{} tiles, {} lanes [{}] — makespan {:.3}, \
         {} events, trace hash <b>{:016x}</b></div>\n",
        t.schedule,
        t.mask,
        t.n_kv,
        t.n_q,
        t.n_heads,
        t.n_lanes,
        t.source.name(),
        t.makespan,
        t.events.len(),
        t.content_hash()
    )
}

/// Render one trace as a standalone interactive HTML page. Traces with
/// [`SimTrace::lane_labels`] (multi-device) get device-namespaced lane
/// names and a `transfer` event class; label-less traces render the exact
/// pre-cluster page.
pub fn timeline_html(t: &SimTrace) -> String {
    if !t.lane_labels.is_empty() {
        let mut out = page_open_xdev("dash timeline");
        out.push_str(&format!("<h1>dash timeline — {}/{}</h1>\n", t.schedule, t.mask));
        out.push_str(&meta_line(t));
        out.push_str(LEGEND_XDEV);
        out.push_str("<div class=\"chart\" id=\"c0\"></div>\n<script>");
        out.push_str(&format!("var LABELS = {};\n", labels_js(&t.lane_labels)));
        out.push_str(SCRIPT_XDEV);
        out.push_str(&format!(
            "paint('c0', {}, {}, {}, null);",
            events_js(&t.events),
            t.makespan,
            t.n_lanes
        ));
        out.push_str("</script>\n</body></html>\n");
        return out;
    }
    let mut out = page_open("dash timeline");
    out.push_str(&format!("<h1>dash timeline — {}/{}</h1>\n", t.schedule, t.mask));
    out.push_str(&meta_line(t));
    out.push_str(LEGEND);
    out.push_str("<div class=\"chart\" id=\"c0\"></div>\n<script>");
    out.push_str(SCRIPT);
    out.push_str(&format!(
        "paint('c0', {}, {}, {}, null);",
        events_js(&t.events),
        t.makespan,
        t.n_lanes
    ));
    out.push_str("</script>\n</body></html>\n");
    out
}

/// Render two traces of the same workload as a stacked diff page:
/// lane-by-lane timelines with divergent intervals outlined, plus the
/// [`diff_summary`] embedded verbatim for scripted inspection.
pub fn timeline_diff_html(a: &SimTrace, b: &SimTrace) -> String {
    let diff = diff_traces(a, b, 1e-9);
    let div_a: Vec<TraceEvent> = diff.diverged.iter().map(|p| p.a).collect();
    let div_b: Vec<TraceEvent> = diff.diverged.iter().map(|p| p.b).collect();
    let mut out = page_open("dash timeline diff");
    out.push_str(&format!(
        "<h1>dash timeline diff — {} vs {} ({})</h1>\n",
        a.schedule, b.schedule, a.mask
    ));
    out.push_str("<pre class=\"summary\">");
    out.push_str(&diff_summary(a, b, &diff));
    out.push_str("</pre>\n");
    out.push_str(LEGEND);
    out.push_str("<h1>A</h1>\n");
    out.push_str(&meta_line(a));
    out.push_str("<div class=\"chart\" id=\"c0\"></div>\n");
    out.push_str("<h1>B</h1>\n");
    out.push_str(&meta_line(b));
    out.push_str("<div class=\"chart\" id=\"c1\"></div>\n<script>");
    out.push_str(SCRIPT);
    out.push_str(&format!(
        "paint('c0', {}, {}, {}, {});\n",
        events_js(&a.events),
        a.makespan,
        a.n_lanes,
        flags_js(&a.events, &div_a)
    ));
    out.push_str(&format!(
        "paint('c1', {}, {}, {}, {});",
        events_js(&b.events),
        b.makespan,
        b.n_lanes,
        flags_js(&b.events, &div_b)
    ));
    out.push_str("</script>\n</body></html>\n");
    out
}

/// True when `kind` contributes to the stall accounting (token stall or
/// its L2 tail).
pub fn is_stall_kind(kind: TraceKind) -> bool {
    matches!(kind, TraceKind::Stall | TraceKind::L2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, shift, MaskSpec, ProblemSpec};
    use crate::sim::SimConfig;
    use crate::trace::trace_simulation;

    fn spec() -> ProblemSpec {
        ProblemSpec::square(4, 2, MaskSpec::full())
    }

    #[test]
    fn html_is_self_contained() {
        let tr = trace_simulation(&shift(&spec()).unwrap(), &SimConfig::ideal(4)).unwrap();
        let html = timeline_html(&tr);
        assert!(!html.to_lowercase().contains("http"), "timeline must not reference the network");
        assert!(html.contains("<!DOCTYPE html>") && html.contains("SM"));
        assert!(html.contains(&format!("{:016x}", tr.content_hash())));
    }

    #[test]
    fn cluster_html_names_device_and_link_lanes() {
        use crate::schedule::{ring, ScheduleKind};
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 2).unwrap();
        let tr = trace_simulation(&s, &SimConfig::ideal(8)).unwrap();
        assert!(!tr.lane_labels.is_empty());
        let html = timeline_html(&tr);
        assert!(!html.to_lowercase().contains("http"), "timeline must not reference the network");
        assert!(html.contains("'dev1/sm0'") && html.contains("'link1'"));
        assert!(html.contains("transfer") && html.contains(".ev.k5"));
        // Single-device pages keep the label-free script.
        let plain =
            trace_simulation(&shift(&spec).unwrap(), &SimConfig::ideal(8)).unwrap();
        assert!(!timeline_html(&plain).contains("LABELS"));
    }

    #[test]
    fn identical_traces_diff_clean() {
        let tr = trace_simulation(&shift(&spec()).unwrap(), &SimConfig::ideal(4)).unwrap();
        let d = diff_traces(&tr, &tr, 1e-9);
        assert!(d.identical());
        assert_eq!(d.aligned, tr.events.len());
        let html = timeline_diff_html(&tr, &tr);
        assert!(html.contains("identical timelines"));
        assert!(!html.to_lowercase().contains("http"));
    }

    #[test]
    fn different_schedules_diverge() {
        let cfg = SimConfig::ideal(4);
        let a = trace_simulation(&shift(&spec()).unwrap(), &cfg).unwrap();
        let b = trace_simulation(&fa3(&spec(), true), &cfg).unwrap();
        let d = diff_traces(&a, &b, 1e-9);
        assert!(!d.identical());
        let html = timeline_diff_html(&a, &b);
        assert!(html.contains("diverged"));
    }
}
