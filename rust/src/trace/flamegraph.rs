//! Makespan attribution — the `dash flamegraph` surface.
//!
//! Folds a [`SimTrace`] into per-chain time buckets (compute / reduce /
//! token stall / L2 / pipeline wait) plus end-of-timeline idle per lane,
//! so the paper's "up to 37.9% deterministic overhead" decomposes into
//! named stalls on named chains. Output is a text table and a
//! folded-stacks dump consumable by standard flamegraph tooling
//! (`stack;frames count` lines).
//!
//! The accounting is exact by construction: every event lands in exactly
//! one chain bucket, and `attributed + idle == makespan * lanes_used`
//! (enforced in `rust/tests/trace_invariants.rs`).

use super::{SimTrace, TraceKind};

/// One chain's time buckets.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainFrame {
    /// Chain index in the schedule.
    pub chain: usize,
    /// Head instance the chain belongs to.
    pub head: usize,
    /// KV tile the chain owns (Q tile for two-pass pass-2 chains).
    pub kv: usize,
    /// Compute time.
    pub compute: f64,
    /// Reduce time.
    pub reduce: f64,
    /// Token-stall time (excluding the L2 tail).
    pub stall: f64,
    /// L2 signal-propagation time.
    pub l2: f64,
    /// Pipeline (writer back-pressure) wait time.
    pub wait: f64,
    /// Cross-device transfer time (cluster traces only; link pseudo-chains
    /// carry the interconnect hops).
    pub transfer: f64,
}

impl ChainFrame {
    /// Total time attributed to this chain.
    pub fn total(&self) -> f64 {
        self.compute + self.reduce + self.stall + self.l2 + self.wait + self.transfer
    }
}

/// A full makespan-attribution report for one trace.
#[derive(Debug, Clone, PartialEq)]
pub struct FlameReport {
    /// Generator name.
    pub schedule: String,
    /// Mask name.
    pub mask: String,
    /// The trace's makespan.
    pub makespan: f64,
    /// Lanes that carried at least one event.
    pub lanes_used: usize,
    /// Per-chain buckets, sorted by descending total time.
    pub chains: Vec<ChainFrame>,
    /// Idle time outside each used lane's event window: the end-of-timeline
    /// tail (`makespan - lane_end(sm)`) plus any leading gap before the
    /// lane's first event. Single-device lanes all start at t = 0, so there
    /// the leading term is zero; interconnect link lanes sit idle until the
    /// cross-device epilogue begins.
    pub idle: f64,
}

impl FlameReport {
    /// Time attributed to chains (everything except `idle`).
    pub fn attributed(&self) -> f64 {
        self.chains.iter().map(ChainFrame::total).sum()
    }

    /// The exact budget the report must account for:
    /// `makespan * lanes_used`.
    pub fn budget(&self) -> f64 {
        self.makespan * self.lanes_used as f64
    }
}

/// Fold a trace into a [`FlameReport`]. Every event is bucketed under its
/// chain; lane time after the last event on each used lane becomes `idle`.
pub fn attribute(trace: &SimTrace) -> FlameReport {
    let n_chains = trace.events.iter().map(|e| e.chain + 1).max().unwrap_or(0);
    let mut frames: Vec<Option<ChainFrame>> = vec![None; n_chains];
    for e in &trace.events {
        let f = frames[e.chain].get_or_insert(ChainFrame {
            chain: e.chain,
            head: e.task.head,
            kv: e.task.kv,
            compute: 0.0,
            reduce: 0.0,
            stall: 0.0,
            l2: 0.0,
            wait: 0.0,
            transfer: 0.0,
        });
        let d = e.dur();
        match e.kind {
            TraceKind::Compute => f.compute += d,
            TraceKind::Reduce => f.reduce += d,
            TraceKind::Stall => f.stall += d,
            TraceKind::L2 => f.l2 += d,
            TraceKind::Wait => f.wait += d,
            TraceKind::Transfer => f.transfer += d,
        }
    }
    let mut chains: Vec<ChainFrame> = frames.into_iter().flatten().collect();
    chains.sort_by(|a, b| {
        b.total().partial_cmp(&a.total()).expect("finite totals").then(a.chain.cmp(&b.chain))
    });
    let mut idle = 0.0;
    for sm in 0..trace.n_lanes {
        let end = trace.lane_end(sm);
        if end > 0.0 {
            let start =
                trace.events.iter().filter(|e| e.sm == sm).map(|e| e.t_start).fold(end, f64::min);
            idle += (trace.makespan - end) + start;
        }
    }
    FlameReport {
        schedule: trace.schedule.clone(),
        mask: trace.mask.clone(),
        makespan: trace.makespan,
        lanes_used: trace.lanes_used(),
        chains,
        idle,
    }
}

fn pct(x: f64, budget: f64) -> f64 {
    if budget > 0.0 {
        100.0 * x / budget
    } else {
        0.0
    }
}

/// Render the report as an aligned text table with a totals footer. A
/// `transfer` column appears only when some chain carries transfer time
/// (multi-device traces), so single-device output is byte-identical to the
/// pre-cluster format.
pub fn render_text(r: &FlameReport) -> String {
    let budget = r.budget();
    let has_transfer = r.chains.iter().any(|f| f.transfer > 0.0);
    let mut out = format!(
        "makespan attribution — {}/{} (makespan {:.3} x {} lanes = {:.3} lane-cycles)\n\n",
        r.schedule, r.mask, r.makespan, r.lanes_used, budget
    );
    if has_transfer {
        out.push_str(&format!(
            "{:>6} {:>5} {:>5} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>7}\n",
            "chain", "head", "kv", "compute", "reduce", "stall", "l2", "wait", "transfer", "total",
            "pct"
        ));
    } else {
        out.push_str(&format!(
            "{:>6} {:>5} {:>5} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>7}\n",
            "chain", "head", "kv", "compute", "reduce", "stall", "l2", "wait", "total", "pct"
        ));
    }
    for f in &r.chains {
        if has_transfer {
            out.push_str(&format!(
                "{:>6} {:>5} {:>5} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>6.2}%\n",
                f.chain,
                f.head,
                f.kv,
                f.compute,
                f.reduce,
                f.stall,
                f.l2,
                f.wait,
                f.transfer,
                f.total(),
                pct(f.total(), budget)
            ));
        } else {
            out.push_str(&format!(
                "{:>6} {:>5} {:>5} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>6.2}%\n",
                f.chain,
                f.head,
                f.kv,
                f.compute,
                f.reduce,
                f.stall,
                f.l2,
                f.wait,
                f.total(),
                pct(f.total(), budget)
            ));
        }
    }
    let attributed = r.attributed();
    out.push_str(&format!(
        "\nattributed {:.3} ({:.2}%)  idle {:.3} ({:.2}%)  of {:.3} lane-cycles\n",
        attributed,
        pct(attributed, budget),
        r.idle,
        pct(r.idle, budget),
        budget
    ));
    let stall = r.chains.iter().map(|f| f.stall + f.l2).sum::<f64>();
    out.push_str(&format!(
        "determinism cost (stall + l2): {:.3} lane-cycles ({:.2}% of makespan budget)\n",
        stall,
        pct(stall, budget)
    ));
    out
}

/// Render folded stacks (`stack;frames count` per line, counts scaled by
/// `x1000` and rounded so zero-cost frames drop out) for external
/// flamegraph tooling. Idle time appears as a `dash;<schedule>;idle`
/// frame so the stacks sum to the full makespan budget.
pub fn render_folded(r: &FlameReport) -> String {
    let mut out = String::new();
    let mut line = |stack: String, t: f64| {
        let count = (t * 1000.0).round() as i64;
        if count > 0 {
            out.push_str(&format!("{stack} {count}\n"));
        }
    };
    for f in &r.chains {
        let base = format!("dash;{};chain{}_h{}_kv{}", r.schedule, f.chain, f.head, f.kv);
        line(format!("{base};compute"), f.compute);
        line(format!("{base};reduce"), f.reduce);
        line(format!("{base};stall"), f.stall);
        line(format!("{base};l2"), f.l2);
        line(format!("{base};wait"), f.wait);
        line(format!("{base};transfer"), f.transfer);
    }
    line(format!("dash;{};idle", r.schedule), r.idle);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{fa3, shift, MaskSpec, ProblemSpec};
    use crate::sim::SimConfig;
    use crate::trace::trace_simulation;

    fn report(n: usize, heads: usize) -> FlameReport {
        let spec = ProblemSpec::square(n, heads, MaskSpec::full());
        let tr = trace_simulation(&fa3(&spec, true), &SimConfig::ideal(n)).unwrap();
        attribute(&tr)
    }

    #[test]
    fn attribution_covers_the_full_budget() {
        let r = report(4, 2);
        assert!(r.budget() > 0.0);
        assert!(
            (r.attributed() + r.idle - r.budget()).abs() < 1e-6,
            "attributed {} + idle {} != budget {}",
            r.attributed(),
            r.idle,
            r.budget()
        );
    }

    #[test]
    fn shift_on_ideal_machine_has_zero_stall_and_idle() {
        let spec = ProblemSpec::square(4, 2, MaskSpec::full());
        let tr = trace_simulation(&shift(&spec).unwrap(), &SimConfig::ideal(4)).unwrap();
        let r = attribute(&tr);
        let stall: f64 = r.chains.iter().map(|f| f.stall + f.l2 + f.wait).sum();
        assert!(stall.abs() < 1e-9 && r.idle.abs() < 1e-9);
    }

    #[test]
    fn cluster_traces_attribute_transfer_to_link_frames() {
        use crate::schedule::{ring, ScheduleKind};
        let spec = ProblemSpec::square(8, 2, MaskSpec::full());
        let s = ring(&spec, ScheduleKind::Shift, 2).unwrap();
        let tr = trace_simulation(&s, &SimConfig::ideal(8)).unwrap();
        let r = attribute(&tr);
        let transfer: f64 = r.chains.iter().map(|f| f.transfer).sum();
        assert!((transfer - 2.0).abs() < 1e-9, "2 links x 1 hop cycle: {transfer}");
        // The full budget still balances with link lanes included.
        assert!((r.attributed() + r.idle - r.budget()).abs() < 1e-6);
        let text = render_text(&r);
        assert!(text.contains("transfer"), "multi-device table gains the column");
        let folded = render_folded(&r);
        assert!(folded.contains(";transfer "));
        // Single-device reports keep the pre-cluster table shape.
        let single = render_text(&report(4, 2));
        assert!(!single.contains("transfer"));
    }

    #[test]
    fn renders_are_complete() {
        let r = report(4, 2);
        let text = render_text(&r);
        assert!(text.contains("attributed") && text.contains("determinism cost"));
        let folded = render_folded(&r);
        assert!(folded.lines().count() >= r.chains.len());
        for l in folded.lines() {
            let (stack, count) = l.rsplit_once(' ').expect("stack count");
            assert!(stack.starts_with("dash;"));
            assert!(count.parse::<i64>().unwrap() > 0);
        }
    }
}
