//! Named performance baselines with a regression gate — the
//! `dash baseline save/list/check` surface.
//!
//! A [`BaselineSnapshot`] is a set of measurement points (one per
//! generator x mask x geometry), each carrying named metrics (makespan,
//! utilization, stall fraction, ...), persisted as `BENCH_<name>.json`.
//! `check` re-runs the snapshot's suite on the paper's abstract machine —
//! deliberately machine-independent, so CI on any runner reproduces the
//! same numbers — and fails when a gated metric regresses beyond a
//! tolerance. Which direction counts as a regression is derived from the
//! metric's name ([`metric_direction`]), so snapshots written by the
//! figure/tune harnesses gate automatically too.

use crate::autotune::{tune, TuneOptions};
use crate::bench_harness::TableRow;
use crate::schedule::fa3::fa3_atomic;
use crate::schedule::{
    cluster_schedule, descending, fa3, lpt_schedule, shift, symmetric_shift, two_pass,
    ClusterStrategy, MaskSpec, ProblemSpec, Schedule, ScheduleKind,
};
use crate::sim::{simulate, simulate_batch, SimConfig, Simulator};
use crate::trace::trace_from_sim;
use crate::traceload::{
    compile, compose_step_schedule, ArrivalModel, BatchConfig, LengthModel, Request, Trace,
    TraceSpec,
};
use crate::util::Json;
use std::path::{Path, PathBuf};

/// Snapshot file-format version.
pub const BASELINE_VERSION: f64 = 1.0;

/// One measured point: an identity string and its named metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselinePoint {
    /// Stable identity, e.g. `shift/full/n8/h2`.
    pub id: String,
    /// Named metric values, in emission order.
    pub metrics: Vec<(String, f64)>,
}

impl BaselinePoint {
    /// Look up a metric by name.
    pub fn metric(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }
}

/// A named set of baseline points.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineSnapshot {
    /// Snapshot name (the `<name>` in `BENCH_<name>.json`).
    pub name: String,
    /// Which suite produced the points: `smoke`, `grid`, `core`,
    /// `cluster`, `trace`, and `tune` are re-runnable by [`run_suite`];
    /// anything else (e.g. `external`, the figure/tune harness exports)
    /// can only be checked `--against` another file.
    pub suite: String,
    /// The measured points.
    pub points: Vec<BaselinePoint>,
}

/// Whether a larger or a smaller value of a metric is better.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricDirection {
    /// Smaller is better (makespans, stalls, gaps, deviations).
    LowerIsBetter,
    /// Larger is better (throughput, utilization, speedups).
    HigherIsBetter,
    /// Any drift beyond tolerance is a regression (task counts,
    /// proposal counters — determinism invariants, not performance).
    Exact,
}

/// Gate direction for a metric, from its name. `None` means the metric is
/// informational (seeds, hashes, wall-clock timings) and never gated.
pub fn metric_direction(name: &str) -> Option<MetricDirection> {
    const EXACT: &[&str] = &["tasks", "count", "evaluated", "skipped"];
    const LOWER: &[&str] =
        &["makespan", "mksp", "stall", "gap", "cycles", "dev", "degradation", "_ms", "_us"];
    const HIGHER: &[&str] = &["tflops", "util", "speedup", "throughput"];
    let n = name.to_ascii_lowercase();
    if EXACT.iter().any(|p| n.contains(p)) {
        Some(MetricDirection::Exact)
    } else if LOWER.iter().any(|p| n.contains(p)) {
        Some(MetricDirection::LowerIsBetter)
    } else if HIGHER.iter().any(|p| n.contains(p)) {
        Some(MetricDirection::HigherIsBetter)
    } else {
        None
    }
}

/// One gated metric that moved the wrong way beyond tolerance.
#[derive(Debug, Clone, PartialEq)]
pub struct Regression {
    /// Point identity.
    pub point: String,
    /// Metric name.
    pub metric: String,
    /// Baseline value.
    pub baseline: f64,
    /// Current value.
    pub current: f64,
    /// Signed relative change in percent (`100 * (cur - base) / |base|`).
    pub delta_pct: f64,
}

/// Outcome of comparing a current run against a baseline.
#[derive(Debug, Clone, Default)]
pub struct CompareReport {
    /// Gated (point, metric) pairs checked.
    pub checked: usize,
    /// Gated metrics that regressed beyond tolerance.
    pub regressions: Vec<Regression>,
    /// Baseline point ids absent from the current run.
    pub missing: Vec<String>,
    /// Gated metrics that improved beyond tolerance.
    pub improved: usize,
}

impl CompareReport {
    /// True when nothing regressed and no baseline point went missing.
    pub fn passed(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Compare `current` against `baseline`: every gated metric of every
/// baseline point must be matched in `current` within `tol` relative
/// tolerance (ungated metrics and extra current-only points are ignored).
pub fn compare(baseline: &BaselineSnapshot, current: &BaselineSnapshot, tol: f64) -> CompareReport {
    let mut report = CompareReport::default();
    for bp in &baseline.points {
        let Some(cp) = current.points.iter().find(|p| p.id == bp.id) else {
            report.missing.push(bp.id.clone());
            continue;
        };
        for (name, base) in &bp.metrics {
            let Some(dir) = metric_direction(name) else { continue };
            let Some(cur) = cp.metric(name) else {
                report.missing.push(format!("{}:{}", bp.id, name));
                continue;
            };
            report.checked += 1;
            let slack = base.abs() * tol + 1e-9;
            let (regressed, improved) = match dir {
                MetricDirection::LowerIsBetter => (cur > base + slack, cur < base - slack),
                MetricDirection::HigherIsBetter => (cur < base - slack, cur > base + slack),
                MetricDirection::Exact => ((cur - base).abs() > slack, false),
            };
            if regressed {
                let delta_pct =
                    if base.abs() > 0.0 { 100.0 * (cur - base) / base.abs() } else { 100.0 };
                report.regressions.push(Regression {
                    point: bp.id.clone(),
                    metric: name.clone(),
                    baseline: *base,
                    current: cur,
                    delta_pct,
                });
            } else if improved {
                report.improved += 1;
            }
        }
    }
    report
}

/// Render a comparison as a human-readable report.
pub fn render_report(report: &CompareReport, tol: f64) -> String {
    let mut out = String::new();
    for r in &report.regressions {
        out.push_str(&format!(
            "REGRESSION  {} {}: {} -> {} ({:+.2}%, tolerance {:.1}%)\n",
            r.point,
            r.metric,
            r.baseline,
            r.current,
            r.delta_pct,
            100.0 * tol
        ));
    }
    for m in &report.missing {
        out.push_str(&format!("MISSING     {m}\n"));
    }
    out.push_str(&format!(
        "{}: {} metrics checked, {} regressed, {} improved, {} missing\n",
        if report.passed() { "PASS" } else { "FAIL" },
        report.checked,
        report.regressions.len(),
        report.improved,
        report.missing.len()
    ));
    out
}

/// The snapshot's on-disk path under `dir`.
pub fn snapshot_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("BENCH_{name}.json"))
}

impl BaselineSnapshot {
    /// Serialize to the `BENCH_*.json` format.
    pub fn to_json(&self) -> String {
        let points = self
            .points
            .iter()
            .map(|p| {
                let metrics =
                    p.metrics.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect::<Vec<_>>();
                Json::Obj(vec![
                    ("id".to_string(), Json::Str(p.id.clone())),
                    ("metrics".to_string(), Json::Obj(metrics)),
                ])
            })
            .collect::<Vec<_>>();
        Json::Obj(vec![
            ("version".to_string(), Json::Num(BASELINE_VERSION)),
            ("name".to_string(), Json::Str(self.name.clone())),
            ("suite".to_string(), Json::Str(self.suite.clone())),
            ("points".to_string(), Json::Arr(points)),
        ])
        .dump()
    }

    /// Parse the `BENCH_*.json` format.
    pub fn from_json(text: &str) -> crate::Result<Self> {
        let j = Json::parse(text)?;
        let version = j.get("version").and_then(Json::as_f64).unwrap_or(0.0);
        anyhow::ensure!(version == BASELINE_VERSION, "unsupported baseline version {version}");
        let need_str = |key: &str| -> crate::Result<String> {
            Ok(j.get(key)
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("baseline missing '{key}'"))?
                .to_string())
        };
        let mut points = Vec::new();
        for p in j.get("points").and_then(Json::as_arr).unwrap_or(&[]) {
            let id = p
                .get("id")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("baseline point missing 'id'"))?
                .to_string();
            let mut metrics = Vec::new();
            for (k, v) in p.get("metrics").and_then(Json::as_obj).unwrap_or(&[]) {
                let v = v.as_f64().ok_or_else(|| anyhow::anyhow!("metric '{k}' not numeric"))?;
                metrics.push((k.clone(), v));
            }
            points.push(BaselinePoint { id, metrics });
        }
        Ok(Self { name: need_str("name")?, suite: need_str("suite")?, points })
    }

    /// Write the snapshot to `dir/BENCH_<name>.json`; returns the path.
    pub fn save(&self, dir: &Path) -> crate::Result<PathBuf> {
        let path = snapshot_path(dir, &self.name);
        std::fs::write(&path, self.to_json() + "\n")?;
        Ok(path)
    }

    /// Load a snapshot file.
    pub fn load(path: &Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        Self::from_json(&text)
    }
}

/// All `BENCH_*.json` snapshots under `dir`, sorted by name.
pub fn list_snapshots(dir: &Path) -> crate::Result<Vec<(String, BaselineSnapshot)>> {
    let mut out = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(out),
    };
    for entry in entries.flatten() {
        let file = entry.file_name().to_string_lossy().to_string();
        if let Some(name) = file.strip_prefix("BENCH_").and_then(|f| f.strip_suffix(".json")) {
            if let Ok(snap) = BaselineSnapshot::load(&entry.path()) {
                out.push((name.to_string(), snap));
            }
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

/// Coordinate columns: their cells become part of a point's identity
/// rather than metrics (see [`points_from_rows`]).
const COORD_COLS: &[&str] =
    &["gpu", "mask", "schedule", "analytic", "model", "prec", "head_dim", "seqlen", "n", "n_sm", "heads"];

/// Convert bench-harness table rows into baseline points: coordinate
/// columns form the id (prefixed with `prefix`), every other
/// `f64`-parseable cell becomes a metric, and non-numeric informational
/// cells (hashes, verdicts) are dropped.
pub fn points_from_rows<T: TableRow>(prefix: &str, rows: &[T]) -> Vec<BaselinePoint> {
    rows.iter()
        .map(|row| {
            let mut id_parts = vec![prefix.to_string()];
            let mut metrics = Vec::new();
            for (name, value) in row.cells() {
                if COORD_COLS.contains(&name) {
                    if matches!(name, "gpu" | "mask" | "schedule" | "model" | "prec" | "analytic") {
                        id_parts.push(value);
                    } else {
                        id_parts.push(format!("{name}{value}"));
                    }
                } else if let Ok(v) = value.parse::<f64>() {
                    metrics.push((name.to_string(), v));
                }
            }
            BaselinePoint { id: id_parts.join("/"), metrics }
        })
        .collect()
}

/// Measure one schedule on the paper's ideal abstract machine and return
/// its baseline point.
fn measure(s: &Schedule, n_sm: usize) -> crate::Result<BaselinePoint> {
    let mut cfg = SimConfig::ideal(n_sm);
    cfg.record_spans = true;
    let r = simulate(s, &cfg).map_err(|e| anyhow::anyhow!("simulate: {e}"))?;
    let trace = trace_from_sim(s, &cfg, &r);
    let mut id = format!(
        "{}/{}/n{}/h{}",
        s.display_name(),
        s.spec.mask.name(),
        s.spec.n_kv,
        s.spec.n_heads
    );
    // Cluster points append the device count; single-device ids (including
    // degenerate 1-device cluster schedules, which still spell the
    // composite name) keep the historical format.
    if s.n_devices() > 1 {
        id.push_str(&format!("/dev{}", s.n_devices()));
    }
    Ok(BaselinePoint {
        id,
        metrics: vec![
            ("makespan".to_string(), r.makespan),
            ("utilization".to_string(), r.utilization()),
            ("stall_frac".to_string(), crate::sim::metrics::stall_fraction(&trace)),
            ("tasks".to_string(), r.n_tasks as f64),
        ],
    })
}

/// Generators a suite measures, by canonical name.
fn generate(name: &str, spec: &ProblemSpec, n_sm: usize) -> Option<Schedule> {
    match name {
        "fa3-det" => Some(fa3(spec, true)),
        "fa3-atomic" => Some(fa3_atomic(spec)),
        "descending" => Some(descending(spec)),
        "shift" => shift(spec).ok(),
        "symmetric-shift" => Some(symmetric_shift(spec)),
        "two-pass" => Some(two_pass(spec)),
        "lpt" => Some(lpt_schedule(spec, n_sm)),
        _ => None,
    }
}

/// Measure one schedule without span recording — the hot-path variant the
/// `core` suite uses at n >= 256, where building a full trace for
/// `stall_frac` would dominate the measurement it is trying to take.
fn measure_fast(s: &Schedule, n_sm: usize) -> crate::Result<BaselinePoint> {
    let cfg = SimConfig::ideal(n_sm);
    let r = simulate(s, &cfg).map_err(|e| anyhow::anyhow!("simulate: {e}"))?;
    let id = format!(
        "{}/{}/n{}/h{}",
        s.kind.name(),
        s.spec.mask.name(),
        s.spec.n_kv,
        s.spec.n_heads
    );
    Ok(BaselinePoint {
        id,
        metrics: vec![
            ("makespan".to_string(), r.makespan),
            ("utilization".to_string(), r.utilization()),
            ("tasks".to_string(), r.n_tasks as f64),
        ],
    })
}

/// The machine-independent points of the `core` suite: large-grid
/// closed-form schedules (every value hand-derivable: shift/full makespans
/// are `h * n * 1.25`, symmetric-shift/causal `h * (n + 1) * 1.25 / 2`,
/// utilization exactly `c / (c + r) = 0.8` on packed home regimes) plus
/// two home-regime tuner points whose proposal counters must stay pinned
/// at zero (the seed meets the bound, so search exits before proposing).
fn core_points() -> crate::Result<Vec<BaselinePoint>> {
    let mut points = Vec::new();
    let spec = ProblemSpec::square(256, 4, MaskSpec::full());
    points.push(measure_fast(&shift(&spec).map_err(|e| anyhow::anyhow!("{e}"))?, 256)?);
    let spec = ProblemSpec::square(512, 2, MaskSpec::full());
    points.push(measure_fast(&shift(&spec).map_err(|e| anyhow::anyhow!("{e}"))?, 512)?);
    let spec = ProblemSpec::square(256, 2, MaskSpec::causal());
    points.push(measure_fast(&symmetric_shift(&spec), 256)?);
    for (mask, heads) in [(MaskSpec::full(), 3usize), (MaskSpec::causal(), 2)] {
        let spec = ProblemSpec::square(8, heads, mask);
        let opts = TuneOptions {
            budget: 64,
            seed: 42,
            sim: SimConfig::ideal(8),
            batch: 8,
            threads: 1,
        };
        let r = tune(&spec, &opts)?;
        points.push(BaselinePoint {
            id: format!("tune/{}/n8/h{heads}/sm8", spec.mask.name()),
            metrics: vec![
                ("makespan".to_string(), r.makespan),
                ("evaluated".to_string(), r.evaluated as f64),
                ("skipped_invalid".to_string(), r.skipped_invalid as f64),
                ("skipped_sim".to_string(), r.skipped_sim as f64),
            ],
        });
    }
    Ok(points)
}

/// Wall-clock point of the `core` suite: `reps` simulations of the
/// symmetric-shift causal n = 256 grid through each engine entry point
/// (fresh allocation per call, one reused [`Simulator`], and
/// [`simulate_batch`] across host cores). Metric names are chosen to stay
/// ungated by [`metric_direction`] — timings are machine-dependent, so the
/// gate ignores them; the speedup ratios land in the saved artifact for
/// humans to read.
fn core_wall_point(reps: usize) -> crate::Result<BaselinePoint> {
    use std::time::Instant;
    let spec = ProblemSpec::square(256, 2, MaskSpec::causal());
    let s = symmetric_shift(&spec);
    let cfg = SimConfig::ideal(256);
    let t0 = Instant::now();
    for _ in 0..reps {
        simulate(&s, &cfg).map_err(|e| anyhow::anyhow!("simulate: {e}"))?;
    }
    let t_alloc = t0.elapsed().as_secs_f64();
    let mut sim = Simulator::new();
    let t0 = Instant::now();
    for _ in 0..reps {
        sim.run(&s, &cfg).map_err(|e| anyhow::anyhow!("simulate: {e}"))?;
    }
    let t_buffered = t0.elapsed().as_secs_f64();
    let group: Vec<Schedule> = vec![s; 8];
    let rounds = reps.div_ceil(group.len());
    let t0 = Instant::now();
    for _ in 0..rounds {
        for r in simulate_batch(&group, &cfg, 0) {
            r.map_err(|e| anyhow::anyhow!("simulate: {e}"))?;
        }
    }
    let t_batch = t0.elapsed().as_secs_f64() * reps as f64 / (rounds * group.len()) as f64;
    Ok(BaselinePoint {
        id: "wall/symmetric-shift/causal/n256/h2".to_string(),
        metrics: vec![
            ("reps".to_string(), reps as f64),
            ("t_alloc_s".to_string(), t_alloc),
            ("t_buffered_s".to_string(), t_buffered),
            ("t_batch_s".to_string(), t_batch),
            ("x_buffered".to_string(), t_alloc / t_buffered.max(1e-12)),
            ("x_batch".to_string(), t_alloc / t_batch.max(1e-12)),
        ],
    })
}

/// The machine-independent points of the `tune` suite: the fleet-tuning
/// acceptance numbers, every one a closed form.
///
/// * Portfolio points — 3-replica races on the n = 8 home regimes, where
///   every replica's analytic seed already meets the DAG bound: winner
///   makespans are the work bounds (`full/h3` -> `3 * 8 * 1.25 = 30`,
///   `causal/h2` -> `(8 + 1) * 1.25 = 11.25`), every proposal counter is
///   pinned at zero (search exits before proposing), the makespan spread
///   across replicas is 0, and replica 0 wins the tie.
/// * Warm-start points — the ROADMAP transfer metric in the certified
///   regime: causal h = 2 tuned cold at n = 64 (`65 * 1.25 = 81.25`),
///   donated through a cache, and warm-started at n = 96 with a 10x
///   smaller budget (40 vs 400). The warm run must still meet the bound
///   (`97 * 1.25 = 121.25`, gap 0 — i.e. 100% of the tuned-vs-analytic
///   gain retained, gated as `retention_util`), with a cold full-budget
///   n = 96 reference point alongside. `neighbor_count = 1` pins that the
///   warm start actually found the n = 64 donor.
fn tune_points() -> crate::Result<Vec<BaselinePoint>> {
    use crate::autotune::{
        fleet, tune_portfolio, PortfolioOptions, ScheduleCache, WorkloadFingerprint,
    };

    let mut points = Vec::new();

    // --- portfolio racing on the home regimes ----------------------------
    for (mask, heads) in [(MaskSpec::full(), 3usize), (MaskSpec::causal(), 2)] {
        let spec = ProblemSpec::square(8, heads, mask);
        let opts = PortfolioOptions {
            replicas: 3,
            budget: 64,
            seed: 42,
            sim: SimConfig::ideal(8),
            batch: 8,
            threads: 1,
        };
        let r = tune_portfolio(&spec, &opts)?;
        let evaluated_total: usize = r.replicas.iter().map(|p| p.evaluated).sum();
        let skipped_total: usize =
            r.replicas.iter().map(|p| p.skipped_invalid + p.skipped_sim).sum();
        points.push(BaselinePoint {
            id: format!("portfolio/{}/n8/h{heads}/sm8", spec.mask.name()),
            metrics: vec![
                ("mksp".to_string(), r.winner.makespan),
                ("mksp_spread".to_string(), r.makespan_spread()),
                ("replica_count".to_string(), r.replicas.len() as f64),
                ("winner_replica".to_string(), r.winner_index as f64),
                ("evaluated".to_string(), r.winner.evaluated as f64),
                ("evaluated_total".to_string(), evaluated_total as f64),
                ("skipped_total".to_string(), skipped_total as f64),
            ],
        });
    }

    // --- warm-start transfer: tuned at n = 64, applied at n = 96 ---------
    let spec64 = ProblemSpec::square(64, 2, MaskSpec::causal());
    let sim64 = SimConfig::ideal(64);
    let cold_opts = TuneOptions { budget: 400, seed: 42, sim: sim64, batch: 8, threads: 1 };
    let cold64 = tune(&spec64, &cold_opts)?;
    let tune_point = |id: String, r: &crate::autotune::TuneResult| BaselinePoint {
        id,
        metrics: vec![
            ("mksp".to_string(), r.makespan),
            ("gap".to_string(), r.gap()),
            ("evaluated".to_string(), r.evaluated as f64),
            ("skipped".to_string(), (r.skipped_invalid + r.skipped_sim) as f64),
        ],
    };
    points.push(tune_point("warmstart/cold/causal/n64/h2/sm64".to_string(), &cold64));

    // Donate the n = 64 entry through an in-memory cache (the path is
    // never saved or read from disk).
    let mut cache = ScheduleCache::open("baseline-warmstart-never-written.json");
    cache.put(&WorkloadFingerprint::new(&spec64, &sim64).key(), &cold64);

    let spec96 = ProblemSpec::square(96, 2, MaskSpec::causal());
    let sim96 = SimConfig::ideal(96);
    let cold96 = tune(&spec96, &TuneOptions { sim: sim96, ..cold_opts })?;
    points.push(tune_point("warmstart/cold/causal/n96/h2/sm96".to_string(), &cold96));

    let warm_opts = TuneOptions { budget: 40, sim: sim96, ..cold_opts };
    let key96 = WorkloadFingerprint::new(&spec96, &sim96).key();
    let warm = fleet::tune_warm(&spec96, &warm_opts, &key96, &cache)?;
    // Retained share of the cold run's tuned-vs-analytic gain, in percent
    // (higher is better, so the `util` suffix gates it that way). In the
    // certified regime both gains are 0 — the warm run retains everything
    // exactly when it, too, meets the bound.
    let seed_gain = cold96.seed_makespan - cold96.makespan;
    let retention = if seed_gain > 1e-9 {
        100.0 * (cold96.seed_makespan - warm.result.makespan).max(0.0) / seed_gain
    } else if warm.result.makespan <= cold96.makespan + 1e-9 {
        100.0
    } else {
        0.0
    };
    let mut warm_point = tune_point("warmstart/warm/causal/n96/h2/sm96".to_string(), &warm.result);
    warm_point.metrics.push((
        "neighbor_count".to_string(),
        warm.source.is_some() as usize as f64,
    ));
    warm_point
        .metrics
        .push(("budget_pct".to_string(), 100.0 * warm_opts.budget as f64 / cold_opts.budget as f64));
    warm_point.metrics.push(("retention_util".to_string(), retention));
    points.push(warm_point);

    Ok(points)
}

/// The hand-pinned serving trace the `trace` suite measures: four
/// requests with fixed prompt/decode lengths and staggered arrivals,
/// written out literally (a fixture, not a sample — the spec only records
/// the envelope), so every downstream number is auditable by hand.
fn serving_trace() -> Trace {
    let spec = TraceSpec {
        name: "baseline-serving".to_string(),
        seed: 0,
        requests: 4,
        prompt: LengthModel::Fixed { tiles: 4 },
        decode: LengthModel::Fixed { tiles: 3 },
        arrival: ArrivalModel::Poisson { rate: 1.0 },
    };
    // (arrival_step, prompt_tiles, decode_tiles) per request.
    let table = [(0usize, 3usize, 2usize), (0, 2, 1), (1, 4, 2), (3, 1, 3)];
    let requests = table
        .iter()
        .enumerate()
        .map(|(id, &(arrival_step, prompt_tiles, decode_tiles))| Request {
            id,
            arrival_step,
            prompt_tiles,
            decode_tiles,
        })
        .collect();
    Trace { spec, requests }
}

/// The machine-independent points of the `trace` suite: the hand-pinned
/// serving trace batch-compiled at three continuous-batching configs and
/// simulated step by step (shift singletons, one head — the regime where
/// every composed chain gets its own lane, so a step's makespan is
/// exactly `1.25 * max_slice_tiles` with zero stalls, and every metric is
/// a closed form over the hand-derivable step sequence).
fn trace_points() -> crate::Result<Vec<BaselinePoint>> {
    let trace = serving_trace();
    let mut points = Vec::new();
    for (max_batch, chunk_tiles) in [(2usize, 0usize), (2, 2), (4, 0)] {
        let cfg = BatchConfig { max_batch, chunk_tiles, n_heads: 1, admission: 0 };
        let steps = compile(&trace, &cfg)?;
        let (mut makespan, mut stall, mut busy, mut cap) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let (mut tasks, mut tiles) = (0usize, 0usize);
        for step in &steps {
            let s = compose_step_schedule(step, ScheduleKind::Shift)?;
            let r = simulate(&s, &SimConfig::ideal(step.total_tiles()))
                .map_err(|e| anyhow::anyhow!("simulate: {e}"))?;
            makespan += r.makespan;
            stall += r.stall_time;
            busy += r.busy_time;
            cap += r.makespan * r.n_sm_used as f64;
            tasks += r.n_tasks;
            tiles += step.total_tiles();
        }
        points.push(BaselinePoint {
            id: format!("serving/shift/b{max_batch}/chunk{chunk_tiles}"),
            metrics: vec![
                ("makespan_total".to_string(), makespan),
                ("utilization".to_string(), busy / cap),
                ("stall_time".to_string(), stall),
                ("step_count".to_string(), steps.len() as f64),
                ("tile_count".to_string(), tiles as f64),
                ("tasks".to_string(), tasks as f64),
            ],
        });
    }
    Ok(points)
}

/// Run a named re-runnable suite on the abstract machine.
///
/// * `smoke` — the four closed-form points the engine tests pin
///   (shift/full at two head counts, symmetric-shift/causal, and a
///   2-device ring-shift), n = 8. Fast, and every value is analytically
///   known — the CI gate.
/// * `grid` — all seven deterministic generators x {full, causal} at
///   n = 8, skipping generator/mask pairs that don't exist (shift needs
///   the full mask).
/// * `core` — the simulator hot-path suite: closed-form points at
///   n = 256/512 and home-regime tuner counters (all machine-independent
///   and gated), plus a 1000-rep wall-clock comparison of the three engine
///   entry points (ungated; doubles as the release-mode perf smoke).
/// * `cluster` — the multi-device closed forms: ring-shift/full at 1, 2,
///   and 4 devices plus zigzag-shift/full at 2, all n = 8 on the ideal
///   unit-hop link (per-device wave `h * (n / D) * 1.25` plus `D - 1`
///   ring-reduce hops).
/// * `trace` — the serving closed forms: a hand-pinned four-request trace
///   batch-compiled at three continuous-batching configs (batch 2, batch 2
///   with 2-tile prefill chunks, batch 4) and simulated step by step; with
///   one head and shift singletons every composed chain owns a lane, so
///   each step's makespan is exactly `1.25 * max_slice_tiles`, stall-free.
/// * `tune` — the fleet-tuning closed forms: 3-replica portfolio races on
///   the n = 8 home regimes (winner makespans are the work bounds, all
///   counters 0) and the warm-start transfer pair — cold-tuned at n = 64,
///   warm-started at n = 96 on a 10x smaller budget, still meeting the
///   DAG bound (gap 0, 100% gain retention).
pub fn run_suite(suite: &str) -> crate::Result<BaselineSnapshot> {
    let n = 8usize;
    let mut points = Vec::new();
    let cluster_point =
        |strategy: ClusterStrategy, devices: usize| -> crate::Result<BaselinePoint> {
            let spec = ProblemSpec::square(n, 2, MaskSpec::full());
            let s = cluster_schedule(&spec, strategy, ScheduleKind::Shift, devices)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            measure(&s, n)
        };
    match suite {
        "smoke" => {
            for heads in [2usize, 3] {
                let spec = ProblemSpec::square(n, heads, MaskSpec::full());
                points.push(measure(&shift(&spec).map_err(|e| anyhow::anyhow!("{e}"))?, n)?);
            }
            let spec = ProblemSpec::square(n, 2, MaskSpec::causal());
            points.push(measure(&symmetric_shift(&spec), n)?);
            points.push(cluster_point(ClusterStrategy::Ring, 2)?);
        }
        "grid" => {
            const GENS: &[&str] = &[
                "fa3-det",
                "fa3-atomic",
                "descending",
                "shift",
                "symmetric-shift",
                "two-pass",
                "lpt",
            ];
            for mask in [MaskSpec::full(), MaskSpec::causal()] {
                let spec = ProblemSpec::square(n, 2, mask);
                for g in GENS {
                    if let Some(s) = generate(g, &spec, n) {
                        points.push(measure(&s, n)?);
                    }
                }
            }
        }
        "core" => {
            points.extend(core_points()?);
            points.push(core_wall_point(1000)?);
        }
        "cluster" => {
            for devices in [1usize, 2, 4] {
                points.push(cluster_point(ClusterStrategy::Ring, devices)?);
            }
            points.push(cluster_point(ClusterStrategy::Zigzag, 2)?);
        }
        "trace" => points.extend(trace_points()?),
        "tune" => points.extend(tune_points()?),
        other => anyhow::bail!(
            "unknown suite '{other}' (expected 'smoke', 'grid', 'core', 'cluster', 'trace', \
             or 'tune')"
        ),
    }
    Ok(BaselineSnapshot { name: suite.to_string(), suite: suite.to_string(), points })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_suite_matches_the_closed_forms() {
        let snap = run_suite("smoke").unwrap();
        assert_eq!(snap.points.len(), 4);
        // shift full: makespan = m * n * 1.25 exactly (engine test pin).
        let p = &snap.points[0];
        assert_eq!(p.id, "shift/full/n8/h2");
        assert_eq!(p.metric("makespan"), Some(20.0));
        assert_eq!(p.metric("stall_frac"), Some(0.0));
        let p3 = &snap.points[1];
        assert_eq!(p3.metric("makespan"), Some(30.0));
        // symmetric-shift causal: m * (n + 1) * 1.25 / 2 exactly.
        let ss = &snap.points[2];
        assert_eq!(ss.id, "symmetric-shift/causal/n8/h2");
        assert_eq!(ss.metric("makespan"), Some(11.25));
        // 2-device ring: per-device wave h * (n/D) * 1.25 = 10, plus one
        // unit ring-reduce hop; utilization = 128 / (11 * 16) = 8/11.
        let ring = &snap.points[3];
        assert_eq!(ring.id, "ring-shift/full/n8/h2/dev2");
        assert_eq!(ring.metric("makespan"), Some(11.0));
        assert_eq!(ring.metric("utilization"), Some(8.0 / 11.0));
        assert_eq!(ring.metric("stall_frac"), Some(0.0));
        assert_eq!(ring.metric("tasks"), Some(128.0));
    }

    #[test]
    fn cluster_suite_matches_the_closed_forms() {
        let snap = run_suite("cluster").unwrap();
        let get = |id: &str| snap.points.iter().find(|p| p.id == id).unwrap();
        // D = 1: the degenerate cluster annotation runs the plain engine —
        // same numbers as shift/full/n8/h2, composite name, no suffix.
        let p = get("ring-shift/full/n8/h2");
        assert_eq!(p.metric("makespan"), Some(20.0));
        assert_eq!(p.metric("utilization"), Some(0.8));
        // D devices: wave = 2 * (8 / D) * 1.25, plus D - 1 unit hops.
        let p = get("ring-shift/full/n8/h2/dev2");
        assert_eq!(p.metric("makespan"), Some(11.0));
        let p = get("ring-shift/full/n8/h2/dev4");
        assert_eq!(p.metric("makespan"), Some(13.0));
        assert_eq!(p.metric("utilization"), Some(8.0 / 13.0));
        // Zigzag on a full mask: per-device work is identical to ring's
        // (every tile live), so the closed form matches dev2 ring.
        let p = get("zigzag-shift/full/n8/h2/dev2");
        assert_eq!(p.metric("makespan"), Some(11.0));
        for p in &snap.points {
            assert_eq!(p.metric("tasks"), Some(128.0), "{}", p.id);
            assert_eq!(p.metric("stall_frac"), Some(0.0), "{}", p.id);
        }
    }

    #[test]
    fn committed_cluster_snapshot_matches_a_fresh_run() {
        // Zero tolerance in both directions: every value in the committed
        // BENCH_cluster.json is a closed form, so a fresh run must
        // reproduce it exactly — and vice versa, so the committed file
        // cannot silently lag the suite.
        let path =
            Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("BENCH_cluster.json");
        let committed =
            BaselineSnapshot::load(&path).expect("committed BENCH_cluster.json parses");
        assert_eq!(committed.suite, "cluster");
        assert_eq!(committed.points.len(), 4);
        let fresh = run_suite("cluster").unwrap();
        let report = compare(&committed, &fresh, 0.0);
        assert!(report.passed(), "committed snapshot drifted: {report:?}");
        let reverse = compare(&fresh, &committed, 0.0);
        assert!(reverse.passed(), "committed snapshot lags the suite: {reverse:?}");
    }

    #[test]
    fn trace_suite_matches_the_closed_forms() {
        // One head + shift singletons: every composed chain owns a lane,
        // so a step costs 1.25 * max_slice_tiles with zero stalls, busy
        // time is the task count, and the lane capacity is
        // makespan * total_tiles. Summing the hand-compiled step
        // sequences of the pinned trace (prompts 3/2/4/1, decodes
        // 2/1/2/3, arrivals 0/0/1/3) gives every value below.
        let snap = run_suite("trace").unwrap();
        assert_eq!(snap.points.len(), 3);
        let get = |id: &str| snap.points.iter().find(|p| p.id == id).unwrap();
        // batch 2, unchunked: steps tile 5,2,5,2,2,1,1 with makespans
        // 3.75, 1.25, 5, 1.25, 1.25, 1.25, 1.25.
        let p = get("serving/shift/b2/chunk0");
        assert_eq!(p.metric("makespan_total"), Some(15.0));
        assert_eq!(p.metric("step_count"), Some(7.0));
        assert_eq!(p.metric("tile_count"), Some(18.0));
        assert_eq!(p.metric("tasks"), Some(38.0));
        assert_eq!(p.metric("stall_time"), Some(0.0));
        assert_eq!(p.metric("utilization"), Some(38.0 / 53.75));
        // 2-tile prefill chunks cap the largest slice at 2: total
        // makespan drops (13.75 < 15) and so does the quadratic prefill
        // work (26 tasks vs 38) — the chunking win, pinned.
        let p = get("serving/shift/b2/chunk2");
        assert_eq!(p.metric("makespan_total"), Some(13.75));
        assert_eq!(p.metric("step_count"), Some(8.0));
        assert_eq!(p.metric("tile_count"), Some(18.0));
        assert_eq!(p.metric("tasks"), Some(26.0));
        assert_eq!(p.metric("stall_time"), Some(0.0));
        assert_eq!(p.metric("utilization"), Some(26.0 / 35.0));
        // batch 4 admits everything as it lands: fewer, wider steps with
        // the same total work as batch 2.
        let p = get("serving/shift/b4/chunk0");
        assert_eq!(p.metric("makespan_total"), Some(15.0));
        assert_eq!(p.metric("step_count"), Some(7.0));
        assert_eq!(p.metric("tile_count"), Some(18.0));
        assert_eq!(p.metric("tasks"), Some(38.0));
        assert_eq!(p.metric("stall_time"), Some(0.0));
        assert_eq!(p.metric("utilization"), Some(38.0 / 57.5));
    }

    #[test]
    fn committed_trace_snapshot_matches_a_fresh_run() {
        // Zero tolerance in both directions, like the cluster snapshot:
        // every value in the committed BENCH_trace.json is a closed form,
        // so a fresh run must reproduce it exactly — and vice versa, so
        // the committed file cannot silently lag the suite.
        let path =
            Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("BENCH_trace.json");
        let committed = BaselineSnapshot::load(&path).expect("committed BENCH_trace.json parses");
        assert_eq!(committed.suite, "trace");
        assert_eq!(committed.points.len(), 3);
        let fresh = run_suite("trace").unwrap();
        let report = compare(&committed, &fresh, 0.0);
        assert!(report.passed(), "committed snapshot drifted: {report:?}");
        let reverse = compare(&fresh, &committed, 0.0);
        assert!(reverse.passed(), "committed snapshot lags the suite: {reverse:?}");
    }

    #[test]
    fn tune_suite_matches_the_closed_forms() {
        let snap = run_suite("tune").unwrap();
        assert_eq!(snap.points.len(), 5);
        let get = |id: &str| snap.points.iter().find(|p| p.id == id).unwrap();
        // Portfolio home regimes: every replica's analytic seed meets the
        // bound, so the races certify without a single proposal and the
        // tie goes to replica 0.
        for (id, mksp) in
            [("portfolio/full/n8/h3/sm8", 30.0), ("portfolio/causal/n8/h2/sm8", 11.25)]
        {
            let p = get(id);
            assert_eq!(p.metric("mksp"), Some(mksp), "{id}");
            assert_eq!(p.metric("mksp_spread"), Some(0.0), "{id}");
            assert_eq!(p.metric("replica_count"), Some(3.0), "{id}");
            assert_eq!(p.metric("winner_replica"), Some(0.0), "{id}");
            assert_eq!(p.metric("evaluated"), Some(0.0), "{id}");
            assert_eq!(p.metric("evaluated_total"), Some(0.0), "{id}");
            assert_eq!(p.metric("skipped_total"), Some(0.0), "{id}");
        }
        // Warm-start transfer: symmetric-shift certifies at both sizes —
        // makespan is the work bound (n + 1) * 1.25, gap 0, no search.
        for (id, mksp) in [
            ("warmstart/cold/causal/n64/h2/sm64", 81.25),
            ("warmstart/cold/causal/n96/h2/sm96", 121.25),
            ("warmstart/warm/causal/n96/h2/sm96", 121.25),
        ] {
            let p = get(id);
            assert_eq!(p.metric("mksp"), Some(mksp), "{id}");
            assert_eq!(p.metric("gap"), Some(0.0), "{id}");
            assert_eq!(p.metric("evaluated"), Some(0.0), "{id}");
            assert_eq!(p.metric("skipped"), Some(0.0), "{id}");
        }
        // The warm run found the n = 64 donor, spent 10% of the cold
        // budget, and retained 100% of the tuned-vs-analytic gain.
        let p = get("warmstart/warm/causal/n96/h2/sm96");
        assert_eq!(p.metric("neighbor_count"), Some(1.0));
        assert_eq!(p.metric("budget_pct"), Some(10.0));
        assert_eq!(p.metric("retention_util"), Some(100.0));
    }

    #[test]
    fn committed_tune_snapshot_matches_a_fresh_run() {
        // Zero tolerance in both directions, like the cluster and trace
        // snapshots: every value in the committed BENCH_tune.json is a
        // closed form, so a fresh run must reproduce it exactly — and
        // vice versa, so the committed file cannot silently lag the suite.
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("BENCH_tune.json");
        let committed = BaselineSnapshot::load(&path).expect("committed BENCH_tune.json parses");
        assert_eq!(committed.suite, "tune");
        assert_eq!(committed.points.len(), 5);
        let fresh = run_suite("tune").unwrap();
        let report = compare(&committed, &fresh, 0.0);
        assert!(report.passed(), "committed snapshot drifted: {report:?}");
        let reverse = compare(&fresh, &committed, 0.0);
        assert!(reverse.passed(), "committed snapshot lags the suite: {reverse:?}");
    }

    #[test]
    fn snapshot_round_trips_through_json() {
        let snap = run_suite("smoke").unwrap();
        let back = BaselineSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn regression_detection_is_directional() {
        let base = run_suite("smoke").unwrap();
        let mut worse = base.clone();
        worse.points[0].metrics[0].1 *= 1.10; // makespan +10%: lower-is-better
        let r = compare(&base, &worse, 0.01);
        assert!(!r.passed());
        assert_eq!(r.regressions.len(), 1);
        assert_eq!(r.regressions[0].metric, "makespan");
        // The same +10% on utilization (higher-is-better) is an improvement.
        let mut better = base.clone();
        better.points[0].metrics[1].1 *= 1.10;
        let r = compare(&base, &better, 0.01);
        assert!(r.passed());
        assert_eq!(r.improved, 1);
        // Identical snapshots pass with zero noise.
        assert!(compare(&base, &base, 0.0).passed());
    }

    #[test]
    fn missing_points_fail_the_gate() {
        let base = run_suite("smoke").unwrap();
        let mut cur = base.clone();
        cur.points.remove(0);
        let r = compare(&base, &cur, 0.05);
        assert!(!r.passed());
        assert_eq!(r.missing, vec!["shift/full/n8/h2".to_string()]);
        assert!(render_report(&r, 0.05).contains("MISSING"));
    }

    #[test]
    fn directions_cover_the_harness_metric_names() {
        assert_eq!(metric_direction("makespan"), Some(MetricDirection::LowerIsBetter));
        assert_eq!(metric_direction("tuned_mksp"), Some(MetricDirection::LowerIsBetter));
        assert_eq!(metric_direction("stall_frac"), Some(MetricDirection::LowerIsBetter));
        assert_eq!(metric_direction("degradation_pct"), Some(MetricDirection::LowerIsBetter));
        assert_eq!(metric_direction("tuned_us"), Some(MetricDirection::LowerIsBetter));
        assert_eq!(metric_direction("det_tflops"), Some(MetricDirection::HigherIsBetter));
        assert_eq!(metric_direction("utilization"), Some(MetricDirection::HigherIsBetter));
        assert_eq!(metric_direction("speedup"), Some(MetricDirection::HigherIsBetter));
        assert_eq!(metric_direction("tasks"), Some(MetricDirection::Exact));
        assert_eq!(metric_direction("evaluated"), Some(MetricDirection::Exact));
        assert_eq!(metric_direction("skipped_invalid"), Some(MetricDirection::Exact));
        assert_eq!(metric_direction("seed"), None);
        // Wall-clock timings are machine-dependent and must stay ungated.
        assert_eq!(metric_direction("t_alloc_s"), None);
        assert_eq!(metric_direction("t_buffered_s"), None);
        assert_eq!(metric_direction("x_batch"), None);
    }

    #[test]
    fn exact_metrics_regress_in_both_directions() {
        let base = run_suite("smoke").unwrap();
        for scale in [1.5, 0.5] {
            let mut cur = base.clone();
            let tasks = cur.points[0]
                .metrics
                .iter_mut()
                .find(|(k, _)| k == "tasks")
                .unwrap();
            tasks.1 *= scale;
            let r = compare(&base, &cur, 0.05);
            assert!(!r.passed(), "task-count drift x{scale} must fail the gate");
            assert_eq!(r.regressions[0].metric, "tasks");
        }
    }

    #[test]
    fn core_points_match_the_closed_forms() {
        let points = core_points().unwrap();
        let get = |id: &str| points.iter().find(|p| p.id == id).unwrap();
        // shift/full: makespan = h * n * (c + r) = h * n * 1.25; packed
        // home regime, so utilization is exactly c / (c + r) = 0.8.
        let p = get("shift/full/n256/h4");
        assert_eq!(p.metric("makespan"), Some(1280.0));
        assert_eq!(p.metric("utilization"), Some(0.8));
        assert_eq!(p.metric("tasks"), Some(262144.0));
        let p = get("shift/full/n512/h2");
        assert_eq!(p.metric("makespan"), Some(1280.0));
        assert_eq!(p.metric("utilization"), Some(0.8));
        assert_eq!(p.metric("tasks"), Some(524288.0));
        // symmetric-shift/causal: makespan = h * (n + 1) * 1.25 / 2.
        let p = get("symmetric-shift/causal/n256/h2");
        assert_eq!(p.metric("makespan"), Some(321.25));
        assert_eq!(p.metric("utilization"), Some(0.8));
        assert_eq!(p.metric("tasks"), Some(65792.0));
        // Home-regime tuner points: the seed meets the bound, so search
        // exits with every proposal counter still at zero.
        for (id, mksp) in [("tune/full/n8/h3/sm8", 30.0), ("tune/causal/n8/h2/sm8", 11.25)] {
            let p = get(id);
            assert_eq!(p.metric("makespan"), Some(mksp));
            assert_eq!(p.metric("evaluated"), Some(0.0));
            assert_eq!(p.metric("skipped_invalid"), Some(0.0));
            assert_eq!(p.metric("skipped_sim"), Some(0.0));
        }
    }

    #[test]
    fn committed_core_snapshot_matches_the_closed_forms() {
        // Zero tolerance: the committed BENCH_core.json holds only the
        // machine-independent skeleton (closed-form makespans, task
        // counts, tuner counters), so a fresh run must match exactly.
        // The wall-clock point is current-run-only and is ignored by
        // `compare` by design.
        let path =
            Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("BENCH_core.json");
        let committed = BaselineSnapshot::load(&path).expect("committed BENCH_core.json parses");
        assert_eq!(committed.suite, "core");
        assert_eq!(committed.points.len(), 5);
        let fresh = BaselineSnapshot {
            name: "core".to_string(),
            suite: "core".to_string(),
            points: core_points().unwrap(),
        };
        let report = compare(&committed, &fresh, 0.0);
        assert!(report.passed(), "committed snapshot drifted: {report:?}");
    }

    #[test]
    fn core_wall_point_reports_all_entry_points() {
        // Tiny rep count: shape check only — timings are machine noise.
        let p = core_wall_point(2).unwrap();
        assert_eq!(p.id, "wall/symmetric-shift/causal/n256/h2");
        for m in ["t_alloc_s", "t_buffered_s", "t_batch_s", "x_buffered", "x_batch"] {
            let v = p.metric(m).unwrap();
            assert!(v.is_finite() && v > 0.0, "{m} = {v}");
        }
    }

    #[test]
    fn grid_suite_covers_both_masks() {
        let snap = run_suite("grid").unwrap();
        // 7 generators on full + 6 on causal (shift needs the full mask).
        assert_eq!(snap.points.len(), 13);
        assert!(snap.points.iter().all(|p| p.metric("makespan").unwrap() > 0.0));
    }
}
