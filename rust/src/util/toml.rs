//! Minimal TOML subset for run configs: flat `key = value` pairs with
//! string / integer / float / boolean values, `#` comments, and one level
//! of `[section]` headers (flattened to `section.key`).

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    /// Quoted string.
    Str(String),
    /// Integer.
    Int(i64),
    /// Float.
    Float(f64),
    /// Boolean.
    Bool(bool),
}

impl TomlValue {
    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric accessor (int or float).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(i) => Some(*i as f64),
            TomlValue::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// Unsigned integer accessor.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    /// Boolean accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse a TOML-subset document into flat `section.key -> value` pairs.
pub fn parse(text: &str) -> Result<BTreeMap<String, TomlValue>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let Some(name) = name.strip_suffix(']') else {
                bail!("line {}: malformed section header", lineno + 1)
            };
            section = name.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value'", lineno + 1)
        };
        let key = key.trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let full_key = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        out.insert(full_key, parse_value(value.trim(), lineno + 1)?);
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(v: &str, lineno: usize) -> Result<TomlValue> {
    if let Some(stripped) = v.strip_prefix('"') {
        let Some(s) = stripped.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string")
        };
        return Ok(TomlValue::Str(s.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    match v {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = v.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{v}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_config() {
        let doc = r#"
            # run config
            steps = 200
            lr = 3e-2
            seed = 42
            schedule = "descending"
            deterministic = true
        "#;
        let t = parse(doc).unwrap();
        assert_eq!(t["steps"].as_usize(), Some(200));
        assert_eq!(t["lr"].as_f64(), Some(0.03));
        assert_eq!(t["schedule"].as_str(), Some("descending"));
        assert_eq!(t["deterministic"].as_bool(), Some(true));
    }

    #[test]
    fn sections_flatten() {
        let t = parse("[model]\nd_model = 256\n[data]\nseqlen = 128").unwrap();
        assert_eq!(t["model.d_model"].as_usize(), Some(256));
        assert_eq!(t["data.seqlen"].as_usize(), Some(128));
    }

    #[test]
    fn comments_and_underscores() {
        let t = parse("tokens = 16_384  # total").unwrap();
        assert_eq!(t["tokens"].as_usize(), Some(16384));
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(t["name"].as_str(), Some("a#b"));
    }

    #[test]
    fn errors_reported_with_line() {
        let err = parse("good = 1\nbad line").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }
}
