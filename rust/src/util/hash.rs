//! Shared FNV-1a folding for persisted identities — autotune cache keys,
//! hardware-profile fingerprints, mask fingerprints. One implementation so
//! the constants and byte order can never silently diverge between the
//! stores that persist these hashes. (The coordinator's run fingerprints
//! hash raw f32 bit streams with their own 4-byte stride and deliberately
//! stay separate — see `coordinator::repro`.)

/// FNV-1a 64-bit offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold `words` into a 64-bit FNV-1a hash, one little-endian byte at a
/// time — identical to hashing the concatenated byte stream.
pub fn fnv1a_words<I: IntoIterator<Item = u64>>(words: I) -> u64 {
    let mut h = FNV_OFFSET;
    for w in words {
        for byte in w.to_le_bytes() {
            h ^= byte as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_input_is_the_offset_basis() {
        assert_eq!(fnv1a_words([]), FNV_OFFSET);
    }

    #[test]
    fn order_and_content_sensitive() {
        assert_ne!(fnv1a_words([1, 2]), fnv1a_words([2, 1]));
        assert_ne!(fnv1a_words([1]), fnv1a_words([2]));
        assert_eq!(fnv1a_words([7, 9]), fnv1a_words(vec![7, 9]));
    }

    #[test]
    fn matches_the_reference_vector() {
        // FNV-1a of the single byte 0x61 ('a') padded to a LE u64 word:
        // fold 'a' then seven zero bytes — pinned so the persisted-key
        // format can never drift unnoticed.
        let h = fnv1a_words([0x61]);
        let mut want = FNV_OFFSET;
        for byte in [0x61u64, 0, 0, 0, 0, 0, 0, 0] {
            want ^= byte;
            want = want.wrapping_mul(FNV_PRIME);
        }
        assert_eq!(h, want);
    }
}
