//! Micro-benchmark harness for the `harness = false` bench targets:
//! warmup + timed iterations, reporting min/mean/p50 — small, dependency-
//! free, and good enough to rank schedules and catch hot-path regressions.

use std::time::Instant;

/// One benchmark's timing summary.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// Iterations measured.
    pub iters: usize,
    /// Minimum iteration time, seconds.
    pub min_s: f64,
    /// Mean iteration time, seconds.
    pub mean_s: f64,
    /// Median iteration time, seconds.
    pub p50_s: f64,
}

impl BenchStats {
    fn fmt_time(s: f64) -> String {
        if s >= 1.0 {
            format!("{s:.3} s")
        } else if s >= 1e-3 {
            format!("{:.3} ms", s * 1e3)
        } else if s >= 1e-6 {
            format!("{:.3} µs", s * 1e6)
        } else {
            format!("{:.1} ns", s * 1e9)
        }
    }
}

/// Bench runner. Use from a plain `main()`:
///
/// ```ignore
/// let mut t = BenchTimer::new("fig8");
/// t.bench("shift/seq4096", || { run_point(...); });
/// ```
pub struct BenchTimer {
    group: String,
    /// Collected (name, stats) rows.
    pub results: Vec<(String, BenchStats)>,
    /// Target time per benchmark, seconds.
    pub target_seconds: f64,
}

impl BenchTimer {
    /// New group with a ~1s-per-bench budget.
    pub fn new(group: impl Into<String>) -> Self {
        Self { group: group.into(), results: Vec::new(), target_seconds: 1.0 }
    }

    /// Time a closure: warm up, pick an iteration count that fills the
    /// budget, measure each iteration, print and record the stats.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchStats {
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((self.target_seconds / once) as usize).clamp(3, 10_000);

        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            times.push(t.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = BenchStats {
            iters,
            min_s: times[0],
            mean_s: times.iter().sum::<f64>() / iters as f64,
            p50_s: times[iters / 2],
        };
        println!(
            "{:<48} {:>12} min  {:>12} p50  {:>12} mean  ({} iters)",
            format!("{}/{}", self.group, name),
            BenchStats::fmt_time(stats.min_s),
            BenchStats::fmt_time(stats.p50_s),
            BenchStats::fmt_time(stats.mean_s),
            stats.iters
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    /// Time a closure exactly once — for workloads that are themselves
    /// repetition loops (e.g. "1000 simulations through one buffer"),
    /// where the calibrated re-runs of [`BenchTimer::bench`] would
    /// multiply an already-long measurement. Prints and records the same
    /// row shape with `min = p50 = mean`.
    pub fn once<F: FnOnce()>(&mut self, name: &str, f: F) -> BenchStats {
        let t0 = Instant::now();
        f();
        let s = t0.elapsed().as_secs_f64();
        let stats = BenchStats { iters: 1, min_s: s, mean_s: s, p50_s: s };
        println!(
            "{:<48} {:>12} min  {:>12} p50  {:>12} mean  ({} iters)",
            format!("{}/{}", self.group, name),
            BenchStats::fmt_time(stats.min_s),
            BenchStats::fmt_time(stats.p50_s),
            BenchStats::fmt_time(stats.mean_s),
            stats.iters
        );
        self.results.push((name.to_string(), stats));
        stats
    }

    /// Print a closing line (so bench output is self-delimiting in logs).
    pub fn finish(&self) {
        println!("-- {}: {} benchmarks --", self.group, self.results.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records() {
        let mut t = BenchTimer::new("test");
        t.target_seconds = 0.01;
        let s = t.bench("noop", || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.iters >= 3);
        assert!(s.min_s <= s.mean_s);
        assert_eq!(t.results.len(), 1);
    }

    #[test]
    fn once_runs_exactly_one_iteration() {
        let mut t = BenchTimer::new("test");
        let mut calls = 0usize;
        let s = t.once("single", || calls += 1);
        assert_eq!(calls, 1);
        assert_eq!(s.iters, 1);
        assert_eq!(s.min_s, s.mean_s);
        assert_eq!(s.min_s, s.p50_s);
        assert_eq!(t.results.len(), 1);
    }

    #[test]
    fn formats_units() {
        assert!(BenchStats::fmt_time(2.0).contains('s'));
        assert!(BenchStats::fmt_time(2e-3).contains("ms"));
        assert!(BenchStats::fmt_time(2e-6).contains("µs"));
        assert!(BenchStats::fmt_time(2e-9).contains("ns"));
    }
}
