//! Minimal JSON: enough to read/write the artifact manifest and figure
//! dumps. Supports objects, arrays, strings (with escapes), numbers, bools,
//! null. No external dependencies; insertion order preserved for objects.

use anyhow::{bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// null
    Null,
    /// true/false
    Bool(bool),
    /// Any number (stored as f64; integers round-trip up to 2^53).
    Num(f64),
    /// String
    Str(String),
    /// Array
    Arr(Vec<Json>),
    /// Object (insertion-ordered)
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer accessor (exact for |n| <= 2^53).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|f| f.fract() == 0.0 && *f >= 0.0).map(|f| f as usize)
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object accessor.
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut v = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                loop {
                    self.ws();
                    v.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(v));
                        }
                        _ => bail!("expected ',' or ']' at byte {}", self.i),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    self.ws();
                    let v = self.value()?;
                    fields.push((k, v));
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => bail!("expected ',' or '}}' at byte {}", self.i),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => bail!("unexpected byte at {}", self.i),
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let Some(e) = self.peek() else { bail!("bad escape") };
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("unknown escape \\{}", e as char),
                    }
                }
                _ => {
                    // Re-scan as UTF-8: back up and take the full char.
                    self.i -= 1;
                    let rest = std::str::from_utf8(&self.b[self.i..])?;
                    let ch = rest.chars().next().unwrap();
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{"modules": {"train_step": {"hlo_file": "ts.hlo.txt", "inputs": [{"name": "x", "shape": [2,3], "dtype": "f32"}], "meta": {"n_params": 31}}}}"#;
        let v = Json::parse(text).unwrap();
        let module = v.get("modules").unwrap().get("train_step").unwrap();
        assert_eq!(module.get("hlo_file").unwrap().as_str(), Some("ts.hlo.txt"));
        let shape = module.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(3));
        // dump -> parse -> equal
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn escapes_and_unicode() {
        let v = Json::parse(r#""a\n\"b\" é ü""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\"b\" é ü"));
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("42").unwrap().as_usize(), Some(42));
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(vec![]));
    }
}
