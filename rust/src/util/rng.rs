//! Deterministic RNG: splitmix64 core with xoshiro256++ stream — fast,
//! seedable, stable across platforms and releases (unlike `std`'s
//! RandomState). Every stochastic choice in the repo flows through this so
//! that runs are reproducible from seeds alone.

/// A small, fast, deterministic RNG (xoshiro256++ seeded via splitmix64).
#[derive(Debug, Clone)]
pub struct DetRng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl DetRng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw u64 (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire reduction; n > 0).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Standard-normal-ish sample (sum of 12 uniforms - 6; exact normality
    /// is irrelevant here, determinism and zero mean are what matter).
    pub fn gen_gauss(&mut self) -> f32 {
        let s: f64 = (0..12).map(|_| self.gen_f64()).sum();
        (s - 6.0) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }

    /// Weighted index sample (weights must be positive, non-empty).
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.gen_f64() as f32 * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Zipf sample in `[1, n]` with exponent `s > 0` (linear-scan CDF
    /// inversion — exact, and `n` here is a tile count, so the scan is
    /// cheap). Rank 1 is the most probable outcome.
    pub fn gen_zipf(&mut self, n: usize, s: f64) -> usize {
        assert!(n > 0 && s > 0.0 && s.is_finite());
        let norm: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        let mut x = self.gen_f64() * norm;
        for k in 1..=n {
            let w = (k as f64).powf(-s);
            if x < w {
                return k;
            }
            x -= w;
        }
        n
    }

    /// Log-normal sample `exp(mu + sigma * z)` with `z` drawn from the
    /// same sum-of-12-uniforms approximate normal as [`DetRng::gen_gauss`],
    /// kept in f64 end to end.
    pub fn gen_log_normal(&mut self, mu: f64, sigma: f64) -> f64 {
        let z: f64 = (0..12).map(|_| self.gen_f64()).sum::<f64>() - 6.0;
        (mu + sigma * z).exp()
    }

    /// Poisson sample with rate `lambda > 0` (Knuth's product-of-uniforms
    /// method — exact for the small per-step rates traces use).
    pub fn gen_poisson(&mut self, lambda: f64) -> usize {
        assert!(lambda > 0.0 && lambda.is_finite());
        let limit = (-lambda).exp();
        let mut k = 0usize;
        let mut p = 1.0f64;
        loop {
            p *= self.gen_f64();
            if p <= limit {
                return k;
            }
            k += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = DetRng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = DetRng::new(7); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(DetRng::new(1).next_u64(), DetRng::new(2).next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = DetRng::new(3);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = DetRng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.gen_range(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 800.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut v: Vec<usize> = (0..50).collect();
        DetRng::new(11).shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn gauss_zero_mean() {
        let mut r = DetRng::new(13);
        let mean: f64 = (0..10_000).map(|_| r.gen_gauss() as f64).sum::<f64>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = DetRng::new(17);
        let w = [8.0f32, 1.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[0] > 7_000, "{counts:?}");
    }

    #[test]
    fn trace_samplers_are_bitwise_deterministic() {
        let draw = |seed: u64| -> (Vec<usize>, Vec<u64>, Vec<usize>) {
            let mut r = DetRng::new(seed);
            let z: Vec<usize> = (0..64).map(|_| r.gen_zipf(16, 1.1)).collect();
            let l: Vec<u64> = (0..64).map(|_| r.gen_log_normal(1.0, 0.5).to_bits()).collect();
            let p: Vec<usize> = (0..64).map(|_| r.gen_poisson(2.5)).collect();
            (z, l, p)
        };
        assert_eq!(draw(42), draw(42), "repeated runs must match bitwise");
        // Adjacent seeds diverge: nearby streams share no structure.
        assert_ne!(draw(42).0, draw(43).0);
        assert_ne!(draw(42).1, draw(43).1);
        assert_ne!(draw(42).2, draw(43).2);
    }

    #[test]
    fn zipf_bounds_and_head_heaviness() {
        let mut r = DetRng::new(19);
        let n = 12;
        let mut counts = vec![0usize; n + 1];
        for _ in 0..10_000 {
            let k = r.gen_zipf(n, 1.0);
            assert!((1..=n).contains(&k));
            counts[k] += 1;
        }
        // Monotone head: rank 1 strictly dominates rank 2 dominates the tail.
        assert!(counts[1] > counts[2], "{counts:?}");
        assert!(counts[2] > counts[n], "{counts:?}");
        // Closed form: P(1) = 1 / H_n; for n = 12, H_12 ~ 3.1032.
        let h_n: f64 = (1..=n).map(|k| 1.0 / k as f64).sum();
        let p1 = counts[1] as f64 / 10_000.0;
        assert!((p1 - 1.0 / h_n).abs() < 0.03, "P(1) = {p1}, expected {}", 1.0 / h_n);
    }

    #[test]
    fn log_normal_mean_and_tail() {
        let (mu, sigma) = (1.0f64, 0.5f64);
        let mut r = DetRng::new(23);
        let draws: Vec<f64> = (0..10_000).map(|_| r.gen_log_normal(mu, sigma)).collect();
        assert!(draws.iter().all(|&x| x > 0.0 && x.is_finite()));
        // E[X] = exp(mu + sigma^2 / 2) ~ 3.08 for these parameters.
        let mean = draws.iter().sum::<f64>() / draws.len() as f64;
        let expect = (mu + sigma * sigma / 2.0).exp();
        assert!((mean - expect).abs() / expect < 0.05, "mean {mean}, expected {expect}");
        // Tail sanity: the approximate normal is bounded by +-6 sigma.
        let max = draws.iter().fold(0.0f64, |a, &b| a.max(b));
        assert!(max < (mu + 6.0 * sigma).exp() + 1e-9, "max {max}");
        assert!(max > expect, "some draw must land above the mean");
    }

    #[test]
    fn poisson_mean_within_tolerance() {
        for lambda in [0.5f64, 2.0, 6.0] {
            let mut r = DetRng::new(29);
            let total: usize = (0..10_000).map(|_| r.gen_poisson(lambda)).sum();
            let mean = total as f64 / 10_000.0;
            // E[X] = lambda; 10k draws put the sample mean well within 5%.
            assert!(
                (mean - lambda).abs() / lambda < 0.05,
                "lambda {lambda}: mean {mean}"
            );
        }
    }
}
