//! Deterministic RNG: splitmix64 core with xoshiro256++ stream — fast,
//! seedable, stable across platforms and releases (unlike `std`'s
//! RandomState). Every stochastic choice in the repo flows through this so
//! that runs are reproducible from seeds alone.

/// A small, fast, deterministic RNG (xoshiro256++ seeded via splitmix64).
#[derive(Debug, Clone)]
pub struct DetRng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl DetRng {
    /// Seed deterministically.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw u64 (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0].wrapping_add(s[3]).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` (Lemire reduction; n > 0).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.gen_f64() as f32) * (hi - lo)
    }

    /// Standard-normal-ish sample (sum of 12 uniforms - 6; exact normality
    /// is irrelevant here, determinism and zero mean are what matter).
    pub fn gen_gauss(&mut self) -> f32 {
        let s: f64 = (0..12).map(|_| self.gen_f64()).sum();
        (s - 6.0) as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_range(i + 1);
            v.swap(i, j);
        }
    }

    /// Weighted index sample (weights must be positive, non-empty).
    pub fn weighted(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut x = self.gen_f64() as f32 * total;
        for (i, &w) in weights.iter().enumerate() {
            if x < w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = DetRng::new(7); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = DetRng::new(7); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(DetRng::new(1).next_u64(), DetRng::new(2).next_u64());
    }

    #[test]
    fn range_bounds() {
        let mut r = DetRng::new(3);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = DetRng::new(5);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.gen_range(10)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 800.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut v: Vec<usize> = (0..50).collect();
        DetRng::new(11).shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn gauss_zero_mean() {
        let mut r = DetRng::new(13);
        let mean: f64 = (0..10_000).map(|_| r.gen_gauss() as f64).sum::<f64>() / 10_000.0;
        assert!(mean.abs() < 0.05, "mean {mean}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = DetRng::new(17);
        let w = [8.0f32, 1.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert!(counts[0] > 7_000, "{counts:?}");
    }
}
