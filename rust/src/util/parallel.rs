//! Deterministic parallel map over host cores.
//!
//! The build is fully offline (no rayon), so the figure/tune sweeps use
//! this small scoped-thread work-stealing map instead: workers pull item
//! indices from an atomic counter, and results are reassembled in input
//! order — the output is bit-identical to the serial `.map()` regardless
//! of thread count or interleaving, which is what a reproducibility
//! artifact demands of its own harness.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` on up to `available_parallelism` threads,
/// returning results in input order. Falls back to a serial map for 0 or 1
/// items (or single-core hosts). Panics in `f` propagate.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = std::thread::available_parallelism().map_or(1, |p| p.get()).min(n);
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(&items[i])));
                    }
                    got
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    });
    let mut indexed: Vec<(usize, R)> = parts.into_iter().flatten().collect();
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map_in_order() {
        let items: Vec<usize> = (0..257).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        assert_eq!(par_map(&items, |&x| x * x + 1), serial);
    }

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u32> = vec![];
        assert_eq!(par_map(&none, |&x| x), none);
        assert_eq!(par_map(&[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn deterministic_across_runs() {
        let items: Vec<u64> = (0..64).collect();
        let a = par_map(&items, |&x| x.wrapping_mul(0x9e37_79b9));
        let b = par_map(&items, |&x| x.wrapping_mul(0x9e37_79b9));
        assert_eq!(a, b);
    }
}
