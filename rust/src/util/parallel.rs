//! Deterministic parallel map over host cores.
//!
//! The build is fully offline (no rayon), so the figure/tune sweeps and
//! the simulator's batched evaluation use this small scoped-thread
//! work-stealing map instead: workers pull item indices from an atomic
//! counter, and results are reassembled in input order — the output is
//! bit-identical to the serial `.map()` regardless of thread count or
//! interleaving, which is what a reproducibility artifact demands of its
//! own harness.
//!
//! Three entry points, least to most general:
//!
//! * [`par_map`] — map over all host cores (the figure-sweep default);
//! * [`par_map_threads`] — map with an explicit thread cap (`0` = all
//!   cores, `1` = serial in the calling thread, no spawn);
//! * [`par_map_init`] — map with per-worker state created *inside* each
//!   worker by an `init` closure and reused across every item that worker
//!   pulls. This is how [`crate::sim::simulate_batch`] amortizes one
//!   [`crate::sim::Simulator`]'s buffers over a whole batch, and how
//!   [`crate::autotune::tune_portfolio`] races its annealed replicas (one
//!   simulator per worker, one RNG stream per replica): the state never
//!   crosses threads, so it needs neither `Send` nor `Sync`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` on up to `available_parallelism` threads,
/// returning results in input order. Falls back to a serial map for 0 or 1
/// items (or single-core hosts). Panics in `f` propagate.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_threads(items, 0, f)
}

/// [`par_map`] with an explicit thread cap: `0` means all host cores,
/// `1` runs serially in the calling thread (no spawn). The cap never
/// changes the output, only the wall-clock.
pub fn par_map_threads<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_init(items, threads, || (), |_, item| f(item))
}

/// Map with per-worker state: each worker thread calls `init()` once and
/// threads the resulting state mutably through every item it processes.
/// `threads` caps the worker count (`0` = all host cores; always clamped
/// to the item count). Results return in input order — bit-identical to
/// `let mut s = init(); items.iter().map(|it| f(&mut s, it))` whenever `f`
/// is deterministic and independent of the state's history (the contract
/// [`crate::sim::Simulator::run`] provides by resetting its buffers).
///
/// The state is created and dropped inside its worker, so `S` needs no
/// `Send`/`Sync`; panics in `init` or `f` propagate.
pub fn par_map_init<T, S, R, I, F>(items: &[T], threads: usize, init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &T) -> R + Sync,
{
    let n = items.len();
    let avail = std::thread::available_parallelism().map_or(1, |p| p.get());
    let threads = if threads == 0 { avail } else { threads }.min(n);
    if threads <= 1 {
        let mut state = init();
        return items.iter().map(|item| f(&mut state, item)).collect();
    }
    let next = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut state = init();
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(&mut state, &items[i])));
                    }
                    got
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    });
    let mut indexed: Vec<(usize, R)> = parts.into_iter().flatten().collect();
    indexed.sort_by_key(|&(i, _)| i);
    indexed.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_serial_map_in_order() {
        let items: Vec<usize> = (0..257).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x + 1).collect();
        assert_eq!(par_map(&items, |&x| x * x + 1), serial);
    }

    #[test]
    fn empty_and_singleton() {
        let none: Vec<u32> = vec![];
        assert_eq!(par_map(&none, |&x| x), none);
        assert_eq!(par_map(&[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn deterministic_across_runs() {
        let items: Vec<u64> = (0..64).collect();
        let a = par_map(&items, |&x| x.wrapping_mul(0x9e37_79b9));
        let b = par_map(&items, |&x| x.wrapping_mul(0x9e37_79b9));
        assert_eq!(a, b);
    }

    #[test]
    fn thread_cap_never_changes_results() {
        let items: Vec<u64> = (0..123).collect();
        let want: Vec<u64> = items.iter().map(|&x| x * 3 + 1).collect();
        for threads in [0usize, 1, 2, 3, 8, 64] {
            assert_eq!(par_map_threads(&items, threads, |&x| x * 3 + 1), want, "t={threads}");
        }
    }

    #[test]
    fn worker_state_is_reused_but_invisible_in_output() {
        // Each worker counts how many items it has seen in its local state;
        // the output must not depend on that partitioning.
        let items: Vec<u32> = (0..200).collect();
        for threads in [1usize, 2, 7] {
            let out = par_map_init(
                &items,
                threads,
                || 0usize,
                |seen, &x| {
                    *seen += 1;
                    assert!(*seen >= 1);
                    x + 1
                },
            );
            let want: Vec<u32> = items.iter().map(|&x| x + 1).collect();
            assert_eq!(out, want, "t={threads}");
        }
    }

    #[test]
    fn init_state_needs_no_send() {
        // Rc is !Send: the per-worker state stays inside its thread.
        use std::rc::Rc;
        let items: Vec<usize> = (0..50).collect();
        let out = par_map_init(&items, 4, || Rc::new(2usize), |s, &x| x * **s);
        assert_eq!(out, items.iter().map(|&x| x * 2).collect::<Vec<_>>());
    }
}
