//! Self-contained utility substrates. The build is fully offline (only the
//! `xla` FFI crate and `anyhow` are external), so the usual ecosystem
//! pieces — deterministic RNG, JSON, a TOML subset, micro-benchmarking —
//! are implemented here, each small, tested, and exactly as deterministic
//! as a reproducibility paper demands.

pub mod hash;
pub mod json;
pub mod parallel;
pub mod rng;
pub mod timer;
pub mod toml;

pub use hash::fnv1a_words;
pub use json::Json;
pub use parallel::{par_map, par_map_init, par_map_threads};
pub use rng::DetRng;
pub use timer::BenchTimer;
