//! PJRT client wrapper: compile-once executable cache + typed execute
//! helpers over the `xla` crate (xla_extension 0.5.1, CPU plugin).

use super::artifacts::ArtifactManifest;
use crate::Result;
use anyhow::Context;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Process-wide PJRT engine: one CPU client plus a compile cache keyed by
/// module name (XLA compilation of the train step takes ~seconds; the hot
/// loop must never recompile).
pub struct Engine {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, Arc<LoadedModule>>>,
}

/// A compiled module ready to execute.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    /// Module name (for diagnostics).
    pub name: String,
    /// Number of outputs the module produces (after untupling).
    pub n_outputs: usize,
}

impl Engine {
    /// Create a CPU PJRT engine.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, cache: Mutex::new(HashMap::new()) })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile a module from the manifest, with caching.
    pub fn load(&self, manifest: &ArtifactManifest, module: &str) -> Result<Arc<LoadedModule>> {
        if let Some(m) = self.cache.lock().unwrap().get(module) {
            return Ok(m.clone());
        }
        let path = manifest.hlo_path(module)?;
        let n_outputs = manifest.spec(module)?.outputs.len();
        let loaded = Arc::new(self.compile_hlo_file(&path, module, n_outputs)?);
        self.cache.lock().unwrap().insert(module.to_string(), loaded.clone());
        Ok(loaded)
    }

    /// Compile an HLO text file directly (no manifest).
    pub fn compile_hlo_file(
        &self,
        path: &std::path::Path,
        name: &str,
        n_outputs: usize,
    ) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("XLA-compiling module '{name}'"))?;
        Ok(LoadedModule { exe, name: name.to_string(), n_outputs })
    }

    /// Copy a host literal to a device buffer (device 0).
    pub fn to_device(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        let devices = self.client.devices();
        Ok(self.client.buffer_from_host_literal(devices.first(), lit)?)
    }
}

impl LoadedModule {
    /// Execute with host literals; returns untupled output literals.
    ///
    /// The AOT path lowers with `return_tuple=True`, so the raw result is a
    /// single tuple literal which we decompose; a non-tuple single output
    /// is returned as-is.
    pub fn run_literals(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = args.iter().collect();
        self.run_literal_refs(&refs)
    }

    /// Execute with *borrowed* literals — the hot path: parameter tensors
    /// stay owned by the trainer and are never deep-copied into the call
    /// (xla::Literal::clone is a full host copy).
    pub fn run_literal_refs(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing module '{}'", self.name))?;
        let bufs = &out[0];
        self.untuple(bufs)
    }

    /// Execute with device-resident buffers (the hot path — no host copies
    /// of the inputs); returns output *buffers*, tuple output decomposed
    /// via a host hop only when the module returns a tuple.
    pub fn run_buffers(&self, args: &[xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let out = self
            .exe
            .execute_b::<xla::PjRtBuffer>(args)
            .with_context(|| format!("executing module '{}' (buffers)", self.name))?;
        Ok(out.into_iter().next().expect("one device"))
    }

    /// Untuple a device result into host literals.
    pub fn untuple(&self, bufs: &[xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        if bufs.len() > 1 {
            // Already untupled by PJRT.
            return bufs.iter().map(|b| Ok(b.to_literal_sync()?)).collect();
        }
        let lit = bufs[0].to_literal_sync()?;
        let shape = lit.shape()?;
        match shape {
            xla::Shape::Tuple(_) => {
                let mut lit = lit;
                Ok(lit.decompose_tuple()?)
            }
            _ => Ok(vec![lit]),
        }
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(numel == data.len(), "shape {shape:?} vs {} elements", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let numel: usize = shape.iter().product();
    anyhow::ensure!(numel == data.len(), "shape {shape:?} vs {} elements", data.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Extract an f32 vector from a literal (converting from bf16/f64 if the
/// module computed in another precision).
pub fn f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    let lit = match lit.ty()? {
        xla::ElementType::F32 => lit.clone(),
        _ => lit.convert(xla::PrimitiveType::F32)?,
    };
    Ok(lit.to_vec::<f32>()?)
}
