//! Artifact manifest: shapes/dtypes/param layout of every AOT-compiled
//! module, written by `python/compile/aot.py` as `artifacts/manifest.json`.

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor crossing the Python -> Rust boundary.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// Logical name (e.g. `params.blocks.0.wq`).
    pub name: String,
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Dtype string (`f32`, `bf16`, `i32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("tensor spec missing name"))?
                .to_string(),
            shape: v
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("tensor spec missing shape"))?
                .iter()
                .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                .collect::<Result<_>>()?,
            dtype: v
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f32")
                .to_string(),
        })
    }
}

/// One AOT-compiled module: its HLO file plus input/output signatures.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// HLO text file, relative to the manifest directory.
    pub hlo_file: String,
    /// Inputs, in call order.
    pub inputs: Vec<TensorSpec>,
    /// Outputs, in tuple order.
    pub outputs: Vec<TensorSpec>,
    /// Free-form metadata (model config, schedule kind, ...).
    pub meta: Json,
}

impl ArtifactSpec {
    /// Integer metadata lookup.
    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta.get(key).and_then(Json::as_usize)
    }
}

/// The `artifacts/manifest.json` contents.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Modules by name (`train_step`, `attn_bwd`, ...).
    pub modules: BTreeMap<String, ArtifactSpec>,
    /// Directory the manifest was loaded from.
    pub root: PathBuf,
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("cannot read {}; run `make artifacts` first", path.display())
        })?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&json, dir)
    }

    /// Parse from a JSON value (exposed for tests).
    pub fn from_json(json: &Json, dir: &Path) -> Result<Self> {
        let mods = json
            .get("modules")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'modules' object"))?;
        let mut modules = BTreeMap::new();
        for (name, m) in mods {
            let parse_tensors = |key: &str| -> Result<Vec<TensorSpec>> {
                m.get(key)
                    .and_then(Json::as_arr)
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let spec = ArtifactSpec {
                hlo_file: m
                    .get("hlo_file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("module '{name}' missing hlo_file"))?
                    .to_string(),
                inputs: parse_tensors("inputs")
                    .with_context(|| format!("module '{name}' inputs"))?,
                outputs: parse_tensors("outputs")
                    .with_context(|| format!("module '{name}' outputs"))?,
                meta: m.get("meta").cloned().unwrap_or(Json::Obj(vec![])),
            };
            modules.insert(name.clone(), spec);
        }
        Ok(Self { modules, root: dir.to_path_buf() })
    }

    /// Absolute path of a module's HLO file.
    pub fn hlo_path(&self, module: &str) -> Result<PathBuf> {
        Ok(self.root.join(&self.spec(module)?.hlo_file))
    }

    /// Module spec accessor.
    pub fn spec(&self, module: &str) -> Result<&ArtifactSpec> {
        self.modules.get(module).ok_or_else(|| {
            anyhow!(
                "module '{module}' not in manifest (have: {:?})",
                self.modules.keys().collect::<Vec<_>>()
            )
        })
    }

    /// True if the artifacts directory exists and has a manifest — used by
    /// integration tests to skip gracefully before `make artifacts`.
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> ArtifactManifest {
        let json = Json::parse(
            r#"{
            "modules": {
                "train_step": {
                    "hlo_file": "train_step.hlo.txt",
                    "inputs": [{"name": "x", "shape": [2, 3], "dtype": "f32"}],
                    "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
                    "meta": {"n_params": 31}
                }
            }
        }"#,
        )
        .unwrap();
        ArtifactManifest::from_json(&json, Path::new("/tmp/artifacts")).unwrap()
    }

    #[test]
    fn manifest_parses() {
        let m = manifest();
        let spec = m.spec("train_step").unwrap();
        assert_eq!(spec.inputs[0].numel(), 6);
        assert_eq!(spec.meta_usize("n_params"), Some(31));
        assert_eq!(spec.outputs[0].shape, Vec::<usize>::new());
        assert!(m.spec("nope").is_err());
        assert_eq!(
            m.hlo_path("train_step").unwrap(),
            PathBuf::from("/tmp/artifacts/train_step.hlo.txt")
        );
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let err = ArtifactManifest::load("/nonexistent").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn availability_probe() {
        assert!(!ArtifactManifest::available("/nonexistent"));
    }
}
