//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! only bridge at run time. Interchange is HLO *text* (not serialized
//! protos — jax >= 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids).

mod artifacts;
pub mod client;

pub use artifacts::{ArtifactManifest, ArtifactSpec, TensorSpec};
pub use client::{literal_f32, literal_i32, f32_vec, Engine, LoadedModule};
